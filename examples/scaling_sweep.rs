//! Sync-SGD scaling sweep (the Fig 1 scenario) plus the §2.1 communication
//! comparison: per-step bytes for sync SGD vs amortized codistillation
//! checkpoint exchange, across worker counts.
//!
//! Also exercises the REAL allreduce path — an explicit 4-worker group
//! (grad fan-out → tree reduce → apply) — and checks it tracks the fused
//! large-batch equivalent.
//!
//! Run: `cargo run --release --example scaling_sweep -- [steps=N]`

use codistill::codistill::Member;
use codistill::config::Settings;
use codistill::data::shard::{ShardMode, ShardPlan};
use codistill::experiments::common::{corpus_for, lm_member, open_bundle};
use codistill::models::lm::{LmSyncGroup, SmoothingMode};
use codistill::netsim::{sweep::step_time_sweep, ClusterModel};

fn main() -> anyhow::Result<()> {
    let mut s = Settings::new();
    for kv in std::env::args().skip(1).filter(|a| a.contains('=')) {
        s.apply(&kv)?;
    }
    let steps = s.u64_or("steps", 30)?;

    // --- Analytic cluster sweep (paper-scale worker counts).
    println!("cluster model (40 MB gradients):");
    println!("  workers  step_time  sgd_bytes/step  codistill_bytes/step");
    for (w, t) in step_time_sweep(&[32, 64, 128, 256], 40_000_000, 300, 7) {
        let m = ClusterModel::gpu_cluster(w, 40_000_000);
        println!(
            "  {w:>7}  {t:>8.3}s  {:>14}  {:>20.0}",
            m.sync_sgd_bytes_per_step(),
            m.codistill_bytes_per_step()
        );
    }

    // --- Real allreduce group vs fused equivalent.
    let worker_bundle = open_bundle(&s, "lm_w8")?;
    let fused_bundle = open_bundle(&s, "lm_b32")?;
    let corpus = corpus_for(&fused_bundle)?;
    let streams: Vec<u64> = (0..32).collect();
    let val: Vec<u64> = (3_000_000..3_000_032).collect();
    let mut group = LmSyncGroup::new(
        &worker_bundle,
        &fused_bundle,
        11,
        5,
        4,
        &streams,
        &val,
        &corpus,
        2,
    )?;
    let plan = ShardPlan::new(1, 32, ShardMode::Disjoint);
    let mut fused = lm_member(&fused_bundle, &plan, 0, 11, 5, SmoothingMode::None, 2)?;

    println!("\nexplicit 4-worker allreduce group vs fused batch-32 step:");
    for step in 0..steps {
        let g = group.train_step(0.0, 0.03)?;
        let f = fused.train_step(0.0, 0.03)?;
        if step % 10 == 0 || step + 1 == steps {
            println!(
                "  step {:>3}: group loss {:.4} | fused loss {:.4}",
                step + 1,
                g.loss,
                f.loss
            );
        }
    }
    let gl = group.evaluate()?.loss;
    let fl = fused.evaluate()?.loss;
    println!("  final val loss: group {gl:.4} vs fused {fl:.4}");
    println!(
        "  param-space mean|Δ|: {:.5}",
        group
            .params()
            .prefix_mean_abs_diff(fused.params(), "params.")?
    );
    Ok(())
}

//! Codistillation topologies on the LM: pair vs ring vs fully-connected
//! with four members (the paper's §4 "other topologies" discussion).
//!
//! Run: `cargo run --release --example codistill_lm -- [steps=N]`

use codistill::codistill::{DistillSchedule, LrSchedule, Member, Orchestrator, OrchestratorConfig, Topology};
use codistill::config::Settings;
use codistill::data::shard::{ShardMode, ShardPlan};
use codistill::experiments::common::{lm_member, open_bundle};
use codistill::models::lm::SmoothingMode;

fn main() -> anyhow::Result<()> {
    let mut s = Settings::new();
    for kv in std::env::args().skip(1).filter(|a| a.contains('=')) {
        s.apply(&kv)?;
    }
    let steps = s.u64_or("steps", 150)?;
    let n = s.usize_or("members", 2)?;
    let bundle = open_bundle(&s, "lm_b64")?;

    for topology in [Topology::Pair, Topology::Ring, Topology::FullyConnected] {
        let plan = ShardPlan::new(n, 64, ShardMode::Disjoint);
        let mut members: Vec<Box<dyn Member>> = (0..n)
            .map(|g| {
                Ok(Box::new(lm_member(
                    &bundle,
                    &plan,
                    g,
                    7,
                    (g + 1) as i32,
                    SmoothingMode::None,
                    2,
                )?) as Box<dyn Member>)
            })
            .collect::<anyhow::Result<_>>()?;
        let cfg = OrchestratorConfig {
            total_steps: steps,
            reload_interval: 25,
            extra_staleness: 0,
            eval_every: steps,
            distill: DistillSchedule::new(steps / 3, steps / 6, 1.0),
            lr: LrSchedule::Constant(0.03),
            topology,
            cluster: None,
            seed: 7,
            delta: false,
            verbose: false,
        };
        let log = Orchestrator::new(cfg).run(&mut members)?;
        println!(
            "{topology:?}: mean final val loss {:.4}",
            log.final_mean_loss().unwrap_or(f64::NAN)
        );
    }
    Ok(())
}

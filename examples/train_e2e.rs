//! End-to-end driver: train the transformer LM through the full system on
//! the synthetic corpus and log the loss curve — the repo's "all layers
//! compose" proof (system prompt deliverable): Rust coordinator + data
//! pipeline + AOT JAX/Pallas artifacts + PJRT runtime, a few hundred steps.
//!
//! The default `tfm` bundle is small so this finishes in minutes on CPU;
//! rebuild artifacts with `python -m compile.aot --only tfm
//! --tfm-preset=100m --force` for the ~100M-parameter configuration (same
//! interface, hours on CPU).
//!
//! Run: `cargo run --release --example train_e2e -- [steps=N] [lr=F]`

use codistill::config::Settings;
use codistill::data::corpus::{Batcher, CorpusConfig};
use codistill::experiments::common::{open_bundle, results_dir};
use codistill::metrics::CsvWriter;
use codistill::models::lm::{run_mapped, zeros_for_prefix};
use codistill::runtime::{Tensor, TensorMap};

fn main() -> anyhow::Result<()> {
    let mut s = Settings::new();
    for kv in std::env::args().skip(1).filter(|a| a.contains('=')) {
        s.apply(&kv)?;
    }
    let steps = s.u64_or("steps", 300)?;
    let lr = s.f32_or("lr", 3e-3)?;
    let eval_every = s.u64_or("eval_every", 25)?;

    let bundle = open_bundle(&s, "tfm")?;
    let vocab = bundle.meta_usize("vocab")?;
    let batch = bundle.meta_usize("batch")?;
    let seq = bundle.meta_usize("seq")?;
    println!(
        "transformer: vocab={vocab} d_model={} layers={} batch={batch} seq={seq}",
        bundle.meta("d_model").unwrap(),
        bundle.meta("n_layers").unwrap()
    );

    let train_step = bundle.exe("train_step")?;
    let eval_exe = bundle.exe("eval")?;
    let init = bundle.exe("init")?;

    // init params + optimizer state
    let outs = init.run(&[&Tensor::scalar_i32(1)])?;
    let mut vars = TensorMap::from_outputs(init.spec(), outs)?;
    vars.merge(zeros_for_prefix(train_step.spec(), "opt."));
    let n_params = vars.prefix_numel("params.");
    println!("parameters: {n_params} ({:.1} MB f32)", n_params as f64 * 4.0 / 1e6);

    let corpus = CorpusConfig {
        vocab,
        ..CorpusConfig::default()
    };
    let streams: Vec<u64> = (0..batch as u64).collect();
    let val_streams: Vec<u64> = (1_000_000..1_000_000 + batch as u64).collect();
    let mut batcher = Batcher::new(&corpus, 42, &streams, seq);
    let mut val_batcher = Batcher::new(&corpus, 42, &val_streams, seq);

    let zero_probs = Tensor::full_f32(&[batch * seq, vocab], 0.0);
    let mut csv = CsvWriter::create(
        &results_dir(&s).join("train_e2e.csv"),
        &["step", "train_loss", "val_loss"],
    )?;

    let t0 = std::time::Instant::now();
    let mut last_train = f32::NAN;
    for step in 0..steps {
        let tokens = batcher.next_batch()?;
        let mut extra = TensorMap::new();
        extra.insert("tokens", tokens);
        extra.insert("teacher_probs", zero_probs.clone());
        extra.insert("distill_w", Tensor::scalar_f32(0.0));
        extra.insert("lr", Tensor::scalar_f32(lr));
        let outs = run_mapped(&train_step, &vars, &extra)?;
        last_train = outs.get("loss")?.item_f32()?;
        vars.adopt_prefix(&outs, "params.", "params.");
        vars.adopt_prefix(&outs, "opt.", "opt.");

        if (step + 1) % eval_every == 0 || step + 1 == steps {
            let mut sum = 0.0f64;
            let mut count = 0.0f64;
            for _ in 0..2 {
                let vt = val_batcher.next_batch()?;
                let mut ex = TensorMap::new();
                ex.insert("tokens", vt);
                let eo = run_mapped(&eval_exe, &vars, &ex)?;
                sum += eo.get("sum_loss")?.item_f32()? as f64;
                count += eo.get("count")?.item_f32()? as f64;
            }
            let val = sum / count;
            println!(
                "step {:>5}  train {:.4}  val {:.4}  ({:.2} steps/s)",
                step + 1,
                last_train,
                val,
                (step + 1) as f64 / t0.elapsed().as_secs_f64()
            );
            csv.num_row(&[(step + 1) as f64, last_train as f64, val])?;
        }
    }
    let path = csv.finish()?;
    println!("loss curve written to {}", path.display());
    Ok(())
}

//! Quickstart: train the LSTM LM for 100 steps through the full stack
//! (Rust coordinator → PJRT → AOT-compiled JAX/Pallas artifacts), then
//! enable two-way codistillation and watch the ψ loss engage.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use codistill::codistill::{DistillSchedule, LrSchedule, Member, Orchestrator, OrchestratorConfig, Topology};
use codistill::config::Settings;
use codistill::data::shard::{ShardMode, ShardPlan};
use codistill::experiments::common::{lm_member, open_bundle};
use codistill::models::lm::SmoothingMode;

fn main() -> anyhow::Result<()> {
    let s = Settings::new();
    // 1. Open an artifact bundle (compiled once by `make artifacts`).
    let bundle = open_bundle(&s, "lm_b64")?;
    println!(
        "bundle lm_b64: vocab={} hidden={} batch={}",
        bundle.meta("vocab").unwrap(),
        bundle.meta("hidden").unwrap(),
        bundle.meta("batch").unwrap()
    );

    // 2. Two codistilling members on disjoint shards of the synthetic
    //    Common Crawl stand-in.
    let plan = ShardPlan::new(2, 64, ShardMode::Disjoint);
    let mut members: Vec<Box<dyn Member>> = vec![
        Box::new(lm_member(&bundle, &plan, 0, 42, 1, SmoothingMode::None, 2)?),
        Box::new(lm_member(&bundle, &plan, 1, 42, 2, SmoothingMode::None, 2)?),
    ];

    // 3. Orchestrate: burn-in 40 steps, then ramp the distillation term in;
    //    checkpoints exchanged every 20 steps.
    let cfg = OrchestratorConfig {
        total_steps: 100,
        reload_interval: 20,
        extra_staleness: 0,
        eval_every: 25,
        distill: DistillSchedule::new(40, 20, 1.0),
        lr: LrSchedule::Constant(0.03),
        topology: Topology::Pair,
        cluster: None,
        seed: 42,
        delta: false,
        verbose: true,
    };
    let orch = Orchestrator::new(cfg);
    let log = orch.run(&mut members)?;

    for (i, curve) in log.eval.iter().enumerate() {
        let first = curve.first().unwrap();
        let last = curve.last().unwrap();
        println!(
            "member {i}: val loss {:.4} (step {}) -> {:.4} (step {})",
            first.loss, first.step, last.loss, last.step
        );
    }
    println!("quickstart OK");
    Ok(())
}

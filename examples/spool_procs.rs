//! OS-process-level coordinator harness: spawn N real `codistill
//! coordinate` child processes over ONE spool directory and assert they
//! converge and exchange **deltas** — multi-process orchestration
//! exercised with actual process isolation, not just threads.
//!
//! Each child hosts a disjoint slice of global member ids
//! (`member_base`) over the deterministic `testkit::DriftMember` fleet
//! (`mock=true`, so no artifact bundle or XLA backend is needed), with
//! `--delta` incremental reloads. The children cooperate purely through
//! `CKPT0003` files + the digest-carrying `MANIFEST` in the shared
//! directory. The harness asserts, from each child's stdout:
//!
//! * clean exit, with every hosted member reaching its final eval;
//! * convergence: drift dynamics contract, so every member's final val
//!   loss lands in the attractor band well below its starting loss, and
//!   the members agree across processes;
//! * delta exchange actually engaged: the frozen `params.table` window
//!   is skipped (`unchanged > 0`) and delta fetches outnumber full ones.
//!
//! Run via `make test-procs` (which builds the binary first), or
//! directly with `CODISTILL_BIN=path/to/codistill cargo run --release
//! --example spool_procs`.

use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::process::{Command, Stdio};

const PROCS: usize = 2;
const MEMBERS_PER_PROC: usize = 2;
const STEPS: u64 = 240;

/// Locate the `codistill` binary: `$CODISTILL_BIN`, else next to this
/// example (`target/<profile>/examples/spool_procs` ->
/// `target/<profile>/codistill`).
fn codistill_bin() -> Result<PathBuf> {
    if let Some(p) = std::env::var_os("CODISTILL_BIN") {
        return Ok(PathBuf::from(p));
    }
    let exe = std::env::current_exe().context("resolving current_exe")?;
    let profile_dir = exe
        .parent()
        .and_then(|d| d.parent())
        .context("examples dir has no parent")?;
    for candidate in [
        profile_dir.join("codistill"),
        profile_dir.join("codistill.exe"),
    ] {
        if candidate.exists() {
            return Ok(candidate);
        }
    }
    bail!(
        "codistill binary not found next to {} — run `make test-procs` \
         (it builds the binary first) or set CODISTILL_BIN",
        exe.display()
    )
}

/// `key=value` fields out of the `[coordinate] delta exchange:` line.
fn delta_field(stdout: &str, key: &str) -> Option<u64> {
    let line = stdout
        .lines()
        .find(|l| l.contains("delta exchange:"))?;
    line.split_whitespace()
        .find_map(|tok| tok.strip_prefix(&format!("{key}=")))
        .and_then(|v| v.parse().ok())
}

fn main() -> Result<()> {
    let bin = codistill_bin()?;
    let spool = std::env::temp_dir().join(format!("codistill_procs_{}", std::process::id()));
    std::fs::remove_dir_all(&spool).ok();

    println!(
        "[spool_procs] spawning {PROCS} `codistill coordinate` processes \
         ({MEMBERS_PER_PROC} members each) over {}",
        spool.display()
    );
    let mut children = Vec::new();
    for p in 0..PROCS {
        let child = Command::new(&bin)
            .args(["coordinate", "--transport", "spool", "--delta"])
            .arg(format!("spool_dir={}", spool.display()))
            .arg("mock=true")
            .arg("mock_frozen=256")
            .arg(format!("members={MEMBERS_PER_PROC}"))
            .arg(format!("member_base={}", p * MEMBERS_PER_PROC))
            .arg(format!("seed={}", 42 + p as u64))
            .arg(format!("steps={STEPS}"))
            .arg("reload=20")
            .arg("burn_in=40")
            .arg("ramp=20")
            .arg(format!("eval_every={STEPS}"))
            .arg("lr=0.2")
            .arg("liveness_grace=50")
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning {}", bin.display()))?;
        children.push((p, child));
    }

    let mut final_losses: Vec<f64> = Vec::new();
    for (p, child) in children {
        let out = child
            .wait_with_output()
            .with_context(|| format!("waiting for child {p}"))?;
        let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
        print!("{stdout}");
        if !out.status.success() {
            bail!("child {p} exited with {:?}", out.status);
        }

        // every hosted member reported a final eval at the last local step
        let mut member_lines = 0usize;
        for line in stdout.lines() {
            if let Some(rest) = line.strip_prefix("[coordinate] member ") {
                let loss: f64 = rest
                    .split("final val loss ")
                    .nth(1)
                    .and_then(|t| t.split_whitespace().next())
                    .context("unparsable member line")?
                    .parse()?;
                member_lines += 1;
                final_losses.push(loss);
            }
        }
        if member_lines != MEMBERS_PER_PROC {
            bail!("child {p}: {member_lines} of {MEMBERS_PER_PROC} members finished");
        }

        // delta exchange engaged: frozen windows skipped, deltas dominate
        let unchanged = delta_field(&stdout, "unchanged")
            .with_context(|| format!("child {p}: no delta accounting line"))?;
        let deltas = delta_field(&stdout, "delta").unwrap_or(0);
        let full = delta_field(&stdout, "full").unwrap_or(0);
        if unchanged == 0 {
            bail!("child {p}: delta exchange never skipped an unchanged window");
        }
        if deltas <= full {
            bail!("child {p}: {deltas} delta vs {full} full fetches — deltas should dominate");
        }
    }

    // Convergence: DriftMember dynamics contract toward a bounded
    // attractor (|w| well under 0.25 ⇒ eval loss = 1 + mean|w| < 1.25,
    // from starting losses ≥ 1.5), and codistillation pulls the members
    // together across processes.
    for &loss in &final_losses {
        if !(1.0..1.25).contains(&loss) {
            bail!("member did not converge: final loss {loss} outside [1.0, 1.25)");
        }
    }
    let min = final_losses.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = final_losses.iter().cloned().fold(0.0f64, f64::max);
    if max - min > 0.2 {
        bail!("members disagree: final losses span [{min}, {max}]");
    }

    std::fs::remove_dir_all(&spool).ok();
    println!(
        "[spool_procs] OK: {} members over {PROCS} processes converged \
         (losses in [{min:.4}, {max:.4}]) and exchanged deltas",
        final_losses.len()
    );
    Ok(())
}

//! The §2.2 fault-tolerance demo: three members codistilling through the
//! multi-process coordinator over a socket exchange, while a seeded fault
//! plan blacks one member out and a third member joins mid-run.
//!
//! Uses `testkit::DriftMember` (deterministic, no artifacts/XLA needed)
//! so the coordinator mechanics — liveness, mid-run join, cadence skew,
//! fault tolerance — are observable anywhere:
//!
//! Run: `cargo run --release --example coordinator_faults -- [steps=N] [fault_seed=N]`

use codistill::codistill::{
    Coordinator, CoordinatorConfig, DistillSchedule, ExchangeTransport, FaultPlan, Faulty,
    HostedMember, LrSchedule, SocketServer, SocketTransport, Topology,
};
use codistill::config::Settings;
use codistill::testkit::DriftMember;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let mut s = Settings::new();
    for kv in std::env::args().skip(1).filter(|a| a.contains('=')) {
        s.apply(&kv)?;
    }
    let steps = s.u64_or("steps", 160)?;
    let fault_seed = s.u64_or("fault_seed", 9)?;

    let cfg = CoordinatorConfig {
        total_steps: steps,
        reload_interval: 10,
        eval_every: steps / 4,
        distill: DistillSchedule::new(steps / 8, steps / 16, 1.0),
        lr: LrSchedule::Constant(0.2),
        topology: Topology::FullyConnected,
        liveness_grace: 35,
        seed: fault_seed,
        delta: false,
        verbose: true,
    };

    // The exchange: a socket server, with a seeded fault plan on top —
    // member 1 blacked out around mid-run, plus a sprinkle of stale reads.
    let server = SocketServer::bind_tcp("127.0.0.1:0", 8)?;
    let client: Arc<dyn ExchangeTransport> = Arc::new(SocketTransport::connect_tcp(server.addr()));
    let plan = FaultPlan::new(fault_seed)
        .with_stale_reads(0.25)
        .with_blackout(1, steps / 4, steps / 2);
    let faulty = Arc::new(Faulty::wrap(client, plan));

    // Members 0 and 1 run from the start on skewed publish cadences;
    // member 2 joins halfway through and bootstraps from a peer.
    let mut hosted = vec![
        HostedMember::new(0, Box::new(DriftMember::new(0)), 10),
        HostedMember::new(1, Box::new(DriftMember::new(1)), 15).with_offset(3),
        HostedMember::new(2, Box::new(DriftMember::new(2)), 10).with_join_delay(steps / 2),
    ];

    let log = Coordinator::new(cfg, faulty.clone()).run(&mut hosted)?;

    println!("\n== run summary ==");
    for (i, curve) in log.eval.iter().enumerate() {
        if let Some(last) = curve.last() {
            println!(
                "member {}: final val loss {:.4} at local step {}",
                log.ids[i], last.loss, last.step
            );
        }
    }
    for j in &log.joins {
        println!(
            "member {} joined at tick {} (bootstrapped from {:?})",
            j.member, j.tick, j.bootstrapped_from
        );
    }
    println!(
        "staleness samples: {}, skipped teachers: {}, tolerated exchange errors: {}",
        log.staleness.len(),
        log.skipped_teachers.len(),
        log.exchange_errors.len()
    );
    println!("injected faults ({} total):", faulty.fault_log().len());
    print!("{}", faulty.fault_log_text());
    Ok(())
}

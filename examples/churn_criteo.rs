//! Prediction churn on the Criteo stand-in (paper §3.5 / Table 1), as a
//! minimal standalone scenario: train the same DNN twice with different
//! seeds, and a codistilled pair twice, then compare mean |Δp|.
//!
//! Run: `cargo run --release --example churn_criteo -- [steps=N]`

use codistill::codistill::{DistillSchedule, Member};
use codistill::config::Settings;
use codistill::experiments::common::open_bundle;
use codistill::metrics::mean_abs_diff;
use codistill::models::criteo::{CriteoMember, CriteoValSet};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let mut s = Settings::new();
    for kv in std::env::args().skip(1).filter(|a| a.contains('=')) {
        s.apply(&kv)?;
    }
    let steps = s.u64_or("steps", 200)?;
    let lr = s.f32_or("lr", 0.05)?;
    let bundle = open_bundle(&s, "criteo")?;
    let buckets = bundle.meta_usize("buckets")?;
    let batch = bundle.meta_usize("batch")?;
    let val = CriteoValSet::generate(42, 9_999_999, buckets, batch, 6)?;

    // Two independent retrains of the plain DNN.
    let mut preds = Vec::new();
    for seed in [1i32, 2] {
        let mut m = CriteoMember::new(&bundle, 42, seed as u64 * 10, seed, val.clone())?;
        for _ in 0..steps {
            m.train_step(0.0, lr)?;
        }
        println!("DNN retrain {seed}: val logloss {:.4}", m.evaluate()?.loss);
        preds.push(m.val_predictions()?);
    }
    let dnn_churn = mean_abs_diff(&preds[0], &preds[1])?;

    // Two retrains of a two-way codistilled pair (pick copy A each time).
    let sched = DistillSchedule::new(steps / 4, steps / 8, 1.0);
    let mut cod_preds = Vec::new();
    for seed in [11i32, 22] {
        let mut a = CriteoMember::new(&bundle, 42, seed as u64 * 10, seed, val.clone())?;
        let mut b = CriteoMember::new(&bundle, 42, seed as u64 * 10 + 1, seed + 50, val.clone())?;
        for step in 0..steps {
            if step % 20 == 0 {
                let ca = Arc::new(a.snapshot()?);
                let cb = Arc::new(b.snapshot()?);
                a.set_teachers(vec![cb])?;
                b.set_teachers(vec![ca])?;
            }
            let w = sched.weight_at(step);
            a.train_step(w, lr)?;
            b.train_step(w, lr)?;
        }
        println!(
            "codistilled retrain {seed}: val logloss {:.4}",
            a.evaluate()?.loss
        );
        cod_preds.push(a.val_predictions()?);
    }
    let cod_churn = mean_abs_diff(&cod_preds[0], &cod_preds[1])?;

    println!("\nchurn (mean |Δp| between retrains):");
    println!("  plain DNN:       {dnn_churn:.4}");
    println!("  codistilled DNN: {cod_churn:.4}");
    if cod_churn < dnn_churn {
        println!(
            "  -> codistillation reduced churn by {:.0}% (paper: ~35%)",
            100.0 * (1.0 - cod_churn / dnn_churn)
        );
    }
    Ok(())
}

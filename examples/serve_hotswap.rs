//! Serving with zero-downtime hot swap, end to end over a spool-dir
//! exchange: a publisher (deterministic drift member standing in for the
//! distilled model's training job) writes checkpoints into a shared
//! directory; a background subscription follows them delta-aware and
//! hot-swaps each fresh plane into a batching inference server while an
//! open-loop load generator keeps traffic flowing. No artifacts or XLA
//! backend needed — the mock forward runs anywhere.
//!
//! Run: `cargo run --release --example serve_hotswap`
//!
//! The same wiring is available from the CLI as `codistill serve
//! --transport spool` (see `codistill::experiments::serve`).

use codistill::codistill::serve::{
    open_loop, InferenceServer, LoadSpec, OpenLoopSpec, ServeConfig,
};
use codistill::codistill::{
    ExchangeTransport, Member, SpoolDir, SubscribeConfig, Subscription,
};
use codistill::models::MockForward;
use codistill::testkit::DriftMember;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    // 1. A spool-dir exchange: publisher and subscriber hold separate
    //    handles on the same directory, exactly like two processes would.
    let dir = std::env::temp_dir().join(format!("serve_hotswap_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let publisher: Arc<dyn ExchangeTransport> = Arc::new(SpoolDir::open(&dir, 8)?);
    let reader: Arc<dyn ExchangeTransport> = Arc::new(SpoolDir::open(&dir, 8)?);

    // 2. The inference server: micro-batching workers over an atomically
    //    swappable plane, with a fixed probe set for churn accounting.
    let server = Arc::new(InferenceServer::start(
        Arc::new(MockForward::new()),
        ServeConfig::default(),
    ));

    // 3. The subscription: follows member 0's publications (delta-aware)
    //    and hot-swaps each verified plane into the server.
    let mut sub = Subscription::spawn(
        reader,
        SubscribeConfig {
            poll_interval: Duration::from_millis(2),
            ..SubscribeConfig::default()
        },
        {
            let server = server.clone();
            move |ck| server.install(ck)
        },
    );

    // 4. The publisher: five checkpoints, each gated on the previous
    //    install so every publication becomes a distinct hot swap.
    let wait_install = |server: &InferenceServer, step: u64| -> anyhow::Result<()> {
        let t0 = Instant::now();
        while server.installed_step() != Some(step) {
            anyhow::ensure!(
                t0.elapsed() < Duration::from_secs(10),
                "install of step {step} did not land"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        Ok(())
    };
    let mut member = DriftMember::with_frozen(0, 256);
    for _ in 0..5 {
        member.train_step(0.0, 0.1)?;
    }
    publisher.publish(member.snapshot()?)?;
    wait_install(&server, member.steps_done())?;

    let pub_handle = std::thread::spawn({
        let (publisher, server) = (publisher.clone(), server.clone());
        move || -> anyhow::Result<()> {
            for _ in 0..4 {
                std::thread::sleep(Duration::from_millis(10));
                for _ in 0..5 {
                    member.train_step(0.0, 0.1)?;
                }
                publisher.publish(member.snapshot()?)?;
                wait_install(&server, member.steps_done())?;
            }
            Ok(())
        }
    });

    // 5. Open-loop traffic across the swaps.
    let run = open_loop(
        &server,
        &OpenLoopSpec {
            load: LoadSpec {
                requests: 2000,
                ..LoadSpec::default()
            },
            rps: 10_000.0,
        },
    );
    pub_handle.join().expect("publisher panicked")?;
    sub.stop();
    let sub_stats = sub.stats();
    server.shutdown();

    // 6. The reports.
    println!(
        "load: sent={} ok={} failed={} goodput={:.0} req/s",
        run.report.sent,
        run.report.ok,
        run.report.failed,
        run.report.goodput()
    );
    println!("latency: {}", run.report.latency.summary_ms());
    for line in server.stats().throughput_lines("serve") {
        println!("{line}");
    }
    let (churn, log) = server.churn();
    print!("{log}");
    println!(
        "hot swaps: {} — churn {:.6} ± {:.6} (mean ± half-range)",
        server.swaps(),
        churn.mean(),
        churn.half_range()
    );
    println!(
        "subscription: polls={} installs={} delta_fetches={} windows_unchanged={}",
        sub_stats.polls,
        sub_stats.installs,
        sub_stats.delta.delta_fetches,
        sub_stats.delta.windows_unchanged
    );
    anyhow::ensure!(run.report.failed == 0, "hot swap dropped requests");
    anyhow::ensure!(server.swaps() >= 4, "expected 4 mid-traffic swaps");
    std::fs::remove_dir_all(&dir).ok();
    println!("serve_hotswap OK");
    Ok(())
}

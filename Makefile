# Build/test/bench entry points. The Rust workspace lives in rust/ and
# builds fully offline (vendored deps; see rust/Cargo.toml).

.PHONY: build test check test-faults test-scenarios test-procs test-wire test-lossy test-serve test-fanout test-obs bench bench-snapshot artifacts python-tests clean

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

# Lint + test gate: rustfmt and clippy when the toolchain ships them
# (skipped with a notice otherwise, so `make check` works on minimal
# toolchains), then the tier-1 test suite and the serving-tier
# integration suite.
check: test-lossy test-serve test-fanout test-obs
	cd rust && if cargo fmt --version >/dev/null 2>&1; then \
		cargo fmt --all -- --check; \
	else echo "make check: rustfmt unavailable, skipping fmt"; fi
	cd rust && if cargo clippy --version >/dev/null 2>&1; then \
		cargo clippy -p codistill --all-targets -- -D warnings; \
	else echo "make check: clippy unavailable, skipping lints"; fi
	cd rust && cargo test -q

# Deterministic fault-injection matrix: the coordinator over
# Faulty-wrapped transports (delayed publishes, dropped/erroring fetches,
# stale reads, blackouts, mid-run joins) under a pinned seed list. Same
# seeds => byte-identical fault and staleness logs.
test-faults:
	cd rust && CODISTILL_FAULT_SEEDS="11 23 47" cargo test --test coordinator_faults -q

# Churn-scenario matrix: the declarative scenario engine
# (codistill::scenario — spot-preemption waves, zone outages, flash
# crowds, flaky exchanges) driving an O(100)-member coordinator fleet
# over a Retry-wrapped Faulty socket transport, plus the wire-level
# retry tests (torn mid-DELTA replies recover against a healthy
# server). Same scenario file + seed => byte-identical staleness,
# fault, and retry logs.
test-scenarios:
	cd rust && CODISTILL_FAULT_SEEDS="11 23 47" cargo test -q --test scenario_churn --test retry_transport

# OS-process-level coordinator harness: N real `codistill coordinate`
# child processes (deterministic mock members, --delta incremental
# reloads) over ONE spool directory; asserts they converge and actually
# exchanged deltas (unchanged windows skipped). Builds the binary first
# so the example can spawn it.
test-procs:
	cd rust && cargo build --release --bin codistill
	cd rust && cargo run --release --example spool_procs

# Wire-path hardening + codec interop tests: the socket malformed-frame
# guards (hostile reply counts error instead of allocating), the codec
# capability negotiation (encoded DELTA/FETCH frames, legacy-server
# fallback), and the transport-equivalence matrix that pins codec-on
# installs byte-identical to codec-off over every backend.
test-wire:
	cd rust && cargo test -q --lib transport::socket
	cd rust && cargo test -q --lib transport::codec
	cd rust && cargo test -q --test transport_equivalence

# Lossy-exchange quality gate: the fp16/int8 quantizing codecs and the
# publisher-side error-feedback accumulator. Pins the orchestrated
# int8+feedback mock run within tolerance of the lossless reference
# (and feedback-off measurably worse), CKPT0005 lossy installs
# byte-identical over inproc/spool/socket/relay/faulty backends with
# corrupt payloads failing the decoded-payload digest, and the
# exact-or-raw codec laws over every wire id (NaN/inf/denormal edges).
test-lossy:
	cd rust && cargo test -q --lib transport::codec
	cd rust && cargo test -q --lib transport::feedback
	cd rust && cargo test -q --test lossy_exchange

# Serving-tier acceptance suite: the batching inference server under
# open-loop load with >=3 checkpoint hot swaps landing mid-traffic —
# zero failed or torn requests (every response re-derived exactly
# against the retained checkpoints), byte-identical churn logs across
# two same-seed runs, and the subscription loop over spool and socket
# transports.
test-serve:
	cd rust && cargo test -q --test serve_hotswap

# Fan-out soak: >=512 concurrent readers against one event-driven socket
# server (zero protocol errors, thread count bounded — no
# thread-per-connection), every reader byte-identical to the publisher,
# plus a relayed soak per seed (two relays over a Faulty upstream link).
# Same seed => byte-identical sorted digest logs across two runs.
test-fanout:
	cd rust && CODISTILL_FAULT_SEEDS="11 23 47" cargo test -q --test fanout_scale

# Observability suite: the codistill::obs event journal and recorder
# (unit tests), plus the journal acceptance matrix — orchestrator,
# coordinator, and serving tier over Retry(Faulty(Socket)) stacks, each
# asserting same-seed byte-identical JSONL traces and replay texts, the
# from_jsonl round trip, and the netsim::calibrate fit pinned on the
# committed fixture trace (modeled exchange within 25% of measured).
test-obs:
	cd rust && cargo test -q --lib codistill::obs
	cd rust && cargo test -q --lib netsim::calibrate
	cd rust && cargo test -q --test obs_journal

# Hot-path microbenchmarks. Writes the human table to stdout and the
# machine-readable trajectory to BENCH_hotpath.json at the repo root.
# Includes the concurrent-vs-serial socket fetch rows
# (sections.socket_concurrency) that track the thread-per-connection
# server upgrade, and the full/delta/delta+codec byte rows
# (sections.compressed_exchange) that track the window-codec layer —
# including the raw/rle/fp16/int8(+feedback) lossy rows, which assert
# the int8 delta moves <= half the delta+RLE bytes at changed
# fraction 0.25.
bench:
	cd rust && cargo bench --bench perf_hotpath -- json=../BENCH_hotpath.json

# Archive the current BENCH_hotpath.json under bench_history/ with a
# UTC timestamp, so the per-PR perf trajectory keeps its raw snapshots
# alongside the mutable head file. Run after `make bench`.
bench-snapshot:
	mkdir -p bench_history
	cp BENCH_hotpath.json "bench_history/BENCH_hotpath_$$(date -u +%Y%m%dT%H%M%SZ).json"
	ls bench_history/

# AOT-lower the JAX/Pallas models to HLO-text artifact bundles consumed by
# the Rust coordinator (needs the python env; see python/compile/aot.py).
artifacts:
	cd python && python3 compile/aot.py --out ../rust/artifacts

python-tests:
	cd python && python3 -m pytest tests -q

clean:
	cd rust && cargo clean

# Build/test/bench entry points. The Rust workspace lives in rust/ and
# builds fully offline (vendored deps; see rust/Cargo.toml).

.PHONY: build test check bench artifacts python-tests clean

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

# Lint + test gate: rustfmt and clippy when the toolchain ships them
# (skipped with a notice otherwise, so `make check` works on minimal
# toolchains), then the tier-1 test suite.
check:
	cd rust && if cargo fmt --version >/dev/null 2>&1; then \
		cargo fmt --all -- --check; \
	else echo "make check: rustfmt unavailable, skipping fmt"; fi
	cd rust && if cargo clippy --version >/dev/null 2>&1; then \
		cargo clippy -p codistill --all-targets -- -D warnings; \
	else echo "make check: clippy unavailable, skipping lints"; fi
	cd rust && cargo test -q

# Hot-path microbenchmarks. Writes the human table to stdout and the
# machine-readable trajectory to BENCH_hotpath.json at the repo root.
bench:
	cd rust && cargo bench --bench perf_hotpath -- json=../BENCH_hotpath.json

# AOT-lower the JAX/Pallas models to HLO-text artifact bundles consumed by
# the Rust coordinator (needs the python env; see python/compile/aot.py).
artifacts:
	cd python && python3 compile/aot.py --out ../rust/artifacts

python-tests:
	cd python && python3 -m pytest tests -q

clean:
	cd rust && cargo clean

//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The build environment has no network access and no crates.io mirror, so
//! this crate provides the exact surface the workspace uses — `Result`,
//! `Error`, the `Context` extension trait, and the `anyhow!` / `bail!` /
//! `ensure!` macros — with the same semantics for that subset:
//!
//! * `Error` captures a message chain (outermost context first, like
//!   anyhow's `Display`/`Debug` split).
//! * `?` converts any `std::error::Error + Send + Sync + 'static`.
//! * `.context(..)` / `.with_context(..)` work on both `Result<T, E>`
//!   (std errors) and `Result<T, Error>` and on `Option<T>`.
//!
//! Dropping the real `anyhow` crate back in is a one-line Cargo.toml change;
//! nothing here extends the upstream API.

use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with a defaultable error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-chain error. Outermost (most recent) context first.
pub struct Error {
    /// `chain[0]` is what `Display` shows; the rest are "Caused by" frames.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Create from a std error, capturing its `source()` chain.
    pub fn from_std<E: std::error::Error + ?Sized>(err: &E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(e) = src {
            chain.push(e.to_string());
            src = e.source();
        }
        Error { chain }
    }

    /// Wrap with an outer context frame (like `anyhow::Error::context`).
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost message (deepest cause).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, colon-separated (anyhow-style).
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, frame) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {frame}")?;
            }
        }
        Ok(())
    }
}

// `?` conversion from any std error. `Error` itself deliberately does NOT
// implement `std::error::Error`, exactly like upstream anyhow, so this
// blanket impl cannot overlap the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        Error::from_std(&err)
    }
}

mod private {
    /// Converts both std errors and `Error` into `Error` — the sealed
    /// extension-trait trick upstream anyhow uses for `Context`.
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> super::Error {
            super::Error::from_std(&self)
        }
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
}

/// Attach context to errors (and to `None`).
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoError> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string, a printable value, or both.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(::std::concat!("condition failed: ", ::std::stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "missing file");
    }

    #[test]
    fn context_wraps_outermost_first() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        assert_eq!(e.root_cause(), "missing file");
        assert_eq!(format!("{e:#}"), "opening config: missing file");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");

        let n: Option<u32> = None;
        let e = n.context("was none").unwrap_err();
        assert_eq!(e.to_string(), "was none");
    }

    #[test]
    fn macros_accept_all_forms() {
        fn f(v: usize) -> Result<usize> {
            ensure!(v < 10, "v too big: {v}");
            if v == 5 {
                bail!("five is right out");
            }
            let msg = String::from("owned message");
            if v == 6 {
                bail!(msg);
            }
            Ok(v)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(12).unwrap_err().to_string(), "v too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(f(6).unwrap_err().to_string(), "owned message");
    }
}

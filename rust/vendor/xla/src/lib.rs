//! Host-side stub of the `xla` PJRT wrapper crate.
//!
//! The coordinator only needs two things from the real crate:
//!
//! 1. **`Literal`** — the host tensor interchange type. This stub implements
//!    it for real (typed storage + dims), so every pure-host path
//!    (`Tensor::to_literal` / `from_literal`, constant-input caching,
//!    the tensor<->literal boundary benchmarks) works unchanged.
//! 2. **PJRT compilation/execution** — `PjRtClient::cpu()` and everything
//!    downstream of it return a descriptive error. Artifact-backed tests and
//!    experiments detect the missing backend (or the missing `artifacts/`
//!    directory) and skip, exactly as they do on a machine without the XLA
//!    shared library.
//!
//! Replacing this stub with the real crate is a Cargo.toml path swap; the
//! API below mirrors the subset of xla-rs 0.5 the workspace calls.

use std::fmt::{self, Display};

/// Error type mirroring `xla::Error` (implements `std::error::Error`, so
/// `anyhow`'s `?`/`.context()` work on it).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn backend_unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the real PJRT backend; this build links the vendored \
         host-side stub (rust/vendor/xla). Swap in the real `xla` crate to \
         compile/execute HLO artifacts."
    ))
}

/// Element types crossing the runtime boundary (full PJRT set; the stub
/// stores only the four the coordinator uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
    C64,
    C128,
}

/// Typed storage behind a [`Literal`]. Public (doc-hidden) only so the
/// sealed [`NativeType`] trait can name it in its method signatures.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    F64(Vec<f64>),
    S32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

impl Storage {
    fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::F64(v) => v.len(),
            Storage::S32(v) => v.len(),
            Storage::U32(v) => v.len(),
            Storage::Tuple(v) => v.len(),
        }
    }

    fn ty(&self) -> Option<ElementType> {
        match self {
            Storage::F32(_) => Some(ElementType::F32),
            Storage::F64(_) => Some(ElementType::F64),
            Storage::S32(_) => Some(ElementType::S32),
            Storage::U32(_) => Some(ElementType::U32),
            Storage::Tuple(_) => None,
        }
    }
}

/// Sealed conversion between native element types and [`Storage`].
pub trait NativeType: Copy + private::Sealed {
    #[doc(hidden)]
    fn wrap(data: Vec<Self>) -> Storage;
    #[doc(hidden)]
    fn unwrap(storage: &Storage) -> Result<Vec<Self>>;
}

mod private {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for f64 {}
    impl Sealed for i32 {}
    impl Sealed for u32 {}
}

macro_rules! native {
    ($t:ty, $variant:ident, $name:literal) => {
        impl NativeType for $t {
            fn wrap(data: Vec<Self>) -> Storage {
                Storage::$variant(data)
            }
            fn unwrap(storage: &Storage) -> Result<Vec<Self>> {
                match storage {
                    Storage::$variant(v) => Ok(v.clone()),
                    other => Err(Error(format!(
                        "literal is {:?}, expected {}",
                        other.ty(),
                        $name
                    ))),
                }
            }
        }
    };
}

native!(f32, F32, "f32");
native!(f64, F64, "f64");
native!(i32, S32, "s32");
native!(u32, U32, "u32");

/// Array shape of a non-tuple literal: dims + element type.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host literal: dense typed buffer + dims (or a tuple of literals).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a native slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let dims = vec![data.len() as i64];
        Literal {
            storage: T::wrap(data.to_vec()),
            dims,
        }
    }

    /// Tuple literal (as produced by `return_tuple=True` executables).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        let n = parts.len() as i64;
        Literal {
            storage: Storage::Tuple(parts),
            dims: vec![n],
        }
    }

    /// Reinterpret with new dims; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.storage, Storage::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".into()));
        }
        let numel: i64 = dims.iter().product();
        if numel as usize != self.storage.len() {
            return Err(Error(format!(
                "reshape to {:?} wants {} elems, literal has {}",
                dims,
                numel,
                self.storage.len()
            )));
        }
        Ok(Literal {
            storage: self.storage.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Shape of a non-tuple literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.storage.ty() {
            Some(ty) => Ok(ArrayShape {
                dims: self.dims.clone(),
                ty,
            }),
            None => Err(Error("tuple literal has no array shape".into())),
        }
    }

    /// Copy out as a native vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.storage)
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.storage {
            Storage::Tuple(parts) => Ok(parts.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module (stub: parsing requires the real backend).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        Err(backend_unavailable(&format!("parsing HLO text {path}")))
    }
}

/// XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client (stub: creation reports the missing backend).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(backend_unavailable("creating a PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(backend_unavailable("compiling an XLA computation"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Accepts both `&[Literal]` and `&[&Literal]`, like the real crate.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(backend_unavailable("executing a compiled artifact"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(backend_unavailable("fetching a device buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2.0f32])]);
        assert!(t.array_shape().is_err());
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<i32>().unwrap(), vec![1]);
    }

    #[test]
    fn backend_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo.txt").is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stub"), "{msg}");
    }
}

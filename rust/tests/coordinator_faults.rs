//! The §2.2 fault-tolerance story as deterministic tests: a coordinator
//! hosting members with per-member publish cadences, mid-run joins, and a
//! publish-recency liveness table, driven over `Faulty`-wrapped
//! transports so stale teachers, dropped/erroring fetches, delayed
//! publishes, and member blackouts are scripted, seeded scenarios — and
//! every one of them must still converge to (nearly) the fault-free
//! answer.
//!
//! `make test-faults` runs this suite over the seed list in
//! `CODISTILL_FAULT_SEEDS` (default `11 23 47`).

use codistill::codistill::transport::FaultKind;
use codistill::codistill::{
    Codec, Coordinator, CoordinatorConfig, CoordinatorLog, DistillSchedule, ExchangeTransport,
    FaultPlan, Faulty, HostedMember, InProcess, LrSchedule, Member, SocketServer, SocketTransport,
    Topology,
};
use codistill::testkit::{DriftMember, DriftProbe};
use std::sync::{Arc, Mutex};

fn cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        total_steps: 160,
        reload_interval: 10,
        eval_every: 40,
        distill: DistillSchedule::new(20, 10, 1.0),
        lr: LrSchedule::Constant(0.2),
        topology: Topology::FullyConnected,
        liveness_grace: 35,
        seed: 5,
        delta: false,
        publish_codec: Codec::Raw,
        error_feedback: false,
        verbose: false,
    }
}

/// Host `n` drift members (publish every 10 local steps); `join_delays[i]`
/// applies when present. Returns (hosted, probes).
fn drift_fleet(n: usize, join_delays: &[u64]) -> (Vec<HostedMember>, Vec<Arc<Mutex<DriftProbe>>>) {
    let probes: Vec<Arc<Mutex<DriftProbe>>> =
        (0..n).map(|_| Arc::new(Mutex::new(DriftProbe::default()))).collect();
    let hosted = (0..n)
        .map(|i| {
            let mut h = HostedMember::new(
                i,
                Box::new(DriftMember::with_probe(i, probes[i].clone())) as Box<dyn Member>,
                10,
            );
            if let Some(&d) = join_delays.get(i) {
                h.join_delay = d;
            }
            h
        })
        .collect();
    (hosted, probes)
}

fn run_over(
    transport: Arc<dyn ExchangeTransport>,
    join_delays: &[u64],
) -> (CoordinatorLog, Vec<Arc<Mutex<DriftProbe>>>) {
    let (mut hosted, probes) = drift_fleet(3, join_delays);
    let log = Coordinator::new(cfg(), transport).run(&mut hosted).unwrap();
    (log, probes)
}

/// The fault-free in-process reference run (same join schedule).
fn fault_free_baseline(join_delays: &[u64]) -> f64 {
    let (log, _) = run_over(Arc::new(InProcess::new(8)), join_delays);
    log.final_mean_loss().unwrap()
}

fn assert_within_pct(tag: &str, got: f64, want: f64, pct: f64) {
    let tol = want.abs() * pct / 100.0;
    assert!(
        (got - want).abs() <= tol,
        "{tag}: final mean loss {got:.5} not within {pct}% of fault-free {want:.5}"
    );
}

/// Seeds for the fault matrix: `CODISTILL_FAULT_SEEDS="a b c"` (the
/// `make test-faults` pin) or a fixed default list.
fn fault_seeds() -> Vec<u64> {
    std::env::var("CODISTILL_FAULT_SEEDS")
        .ok()
        .map(|v| v.split_whitespace().filter_map(|t| t.parse().ok()).collect::<Vec<u64>>())
        .filter(|v: &Vec<u64>| !v.is_empty())
        .unwrap_or_else(|| vec![11, 23, 47])
}

/// The ISSUE acceptance scenario: 3 members over a `Faulty`-wrapped
/// socket transport, member 1 blacked out across a full publish interval,
/// member 2 joining mid-run — the run must land within 5% of the
/// fault-free in-process run, and the same `FaultPlan` seed must replay a
/// byte-identical staleness log.
#[test]
fn faulty_socket_run_converges_and_replays_byte_identical() {
    let joins = [0u64, 0, 60];
    let baseline = fault_free_baseline(&joins);

    let run_faulty = || {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
        let client: Arc<dyn ExchangeTransport> =
            Arc::new(SocketTransport::connect_tcp(server.addr()));
        // Blackout [45, 56): member 1's step-50 publication (one full
        // publish interval's worth of exchange) vanishes.
        let faulty = Arc::new(Faulty::wrap(client, FaultPlan::new(9).with_blackout(1, 45, 56)));
        let (log, probes) = run_over(faulty.clone(), &joins);
        let faults = faulty.fault_log();
        drop(server);
        (log, probes, faults)
    };

    let (log1, probes1, faults1) = run_faulty();
    let (log2, _, faults2) = run_faulty();

    // Convergence: within 5% of the fault-free in-process run.
    assert_within_pct("faulty socket", log1.final_mean_loss().unwrap(), baseline, 5.0);

    // The blackout really fired, exactly once per invocation.
    assert_eq!(faults1.len(), 1, "{faults1:?}");
    assert_eq!(faults1[0].kind, FaultKind::BlackoutPublish);
    assert_eq!((faults1[0].member, faults1[0].salt), (1, 50));
    assert_eq!(faults1, faults2);

    // The joiner bootstrapped from the freshest peer checkpoint.
    assert_eq!(log1.joins.len(), 1);
    assert_eq!(log1.joins[0].member, 2);
    let (peer, peer_step) = log1.joins[0].bootstrapped_from.expect("no bootstrap source");
    assert!(peer < 2, "bootstrapped from itself or unknown peer {peer}");
    assert!(peer_step >= 50, "bootstrap checkpoint stale: step {peer_step}");
    assert!(probes1[2].lock().unwrap().bootstrapped.is_some());

    // Reproducibility: byte-identical staleness logs across invocations.
    let text1 = log1.staleness_log_text();
    let text2 = log2.staleness_log_text();
    assert!(!text1.is_empty(), "run never observed teacher staleness");
    assert_eq!(text1.as_bytes(), text2.as_bytes(), "staleness log not reproducible");
}

/// Every fault class, over the pinned seed list: runs converge to within
/// 5% of the fault-free run and never error out of the coordinator.
#[test]
fn fault_matrix_converges_under_every_class() {
    let baseline = fault_free_baseline(&[]);
    let classes: Vec<(&str, Box<dyn Fn(u64) -> FaultPlan>)> = vec![
        (
            "delayed-publish",
            Box::new(|s| FaultPlan::new(s).with_delayed_publishes(0.5)),
        ),
        (
            "dropped-fetch",
            Box::new(|s| FaultPlan::new(s).with_dropped_fetches(0.3)),
        ),
        (
            "errored-fetch",
            Box::new(|s| FaultPlan::new(s).with_erroring_fetches(0.3)),
        ),
        (
            "stale-read",
            Box::new(|s| FaultPlan::new(s).with_stale_reads(0.5)),
        ),
        (
            "blackout",
            Box::new(|s| FaultPlan::new(s).with_blackout(1, 40, 90)),
        ),
    ];
    for seed in fault_seeds() {
        for (name, make_plan) in &classes {
            let faulty = Arc::new(Faulty::wrap(
                Arc::new(InProcess::new(8)),
                make_plan(seed),
            ));
            let (log, _) = run_over(faulty.clone(), &[]);
            assert_within_pct(
                &format!("{name} seed {seed}"),
                log.final_mean_loss().unwrap(),
                baseline,
                5.0,
            );
            if *name == "blackout" {
                assert!(
                    faulty
                        .fault_log()
                        .iter()
                        .all(|e| e.kind == FaultKind::BlackoutPublish && e.member == 1),
                    "unexpected fault mix: {:?}",
                    faulty.fault_log()
                );
                assert!(!faulty.fault_log().is_empty());
            }
        }
    }
}

/// A mid-run joiner seeds from a peer and runs its own local burn-in:
/// the ψ weight it sees starts at zero regardless of how far the
/// incumbents have ramped.
#[test]
fn joiner_enters_distill_ramp_at_its_own_local_step() {
    let (log, probes) = run_over(Arc::new(InProcess::new(8)), &[0, 0, 80]);
    // incumbents are past burn-in (step 20) + ramp by tick 80
    let joiner = probes[2].lock().unwrap();
    assert_eq!(log.joins.len(), 1);
    assert!(joiner.bootstrapped.is_some(), "joiner never bootstrapped");
    let ws = &joiner.distill_ws;
    assert_eq!(ws.len(), 160, "joiner ran a full local schedule");
    assert!(
        ws[..20].iter().all(|&w| w == 0.0),
        "joiner skipped its local burn-in: {:?}",
        &ws[..25]
    );
    assert!(
        ws[30..].iter().all(|&w| w == 1.0),
        "joiner never finished its local ramp"
    );
    // and the incumbents' ramps were unaffected by the join
    let incumbent = probes[0].lock().unwrap();
    let w0 = &incumbent.distill_ws;
    assert!(w0[..20].iter().all(|&w| w == 0.0) && w0[30..].iter().all(|&w| w == 1.0));
}

/// A member silent past `liveness_grace` is dropped from teacher sets —
/// and re-adopted once it publishes again.
#[test]
fn dead_member_is_dropped_from_teacher_sets_until_it_returns() {
    let mut c = cfg();
    c.liveness_grace = 25;
    let probes: Vec<Arc<Mutex<DriftProbe>>> =
        (0..3).map(|_| Arc::new(Mutex::new(DriftProbe::default()))).collect();
    let mut hosted: Vec<HostedMember> = (0..3)
        .map(|i| {
            HostedMember::new(
                i,
                Box::new(DriftMember::with_probe(i, probes[i].clone())) as Box<dyn Member>,
                10,
            )
        })
        .collect();
    // Member 1 goes silent from step 30 to step 99: publishes at steps
    // 30..=90 are dropped, far past the 25-tick grace.
    let faulty = Arc::new(Faulty::wrap(
        Arc::new(InProcess::new(8)),
        FaultPlan::new(3).with_blackout(1, 30, 100),
    ));
    Coordinator::new(c, faulty).run(&mut hosted).unwrap();

    let counts = probes[0].lock().unwrap().teacher_counts.clone();
    assert!(
        counts.contains(&2),
        "member 0 never saw both peers live: {counts:?}"
    );
    assert!(
        counts.contains(&1),
        "member 0 never dropped the dead peer: {counts:?}"
    );
    assert_eq!(
        *counts.last().unwrap(),
        2,
        "returned member never re-adopted: {counts:?}"
    );
}

/// End-of-run drain: publications `Faulty` delayed past their member's
/// final cadence still land — `Coordinator::run` flushes the transport
/// stack before returning, so the final manifest holds every member's
/// last checkpoint even when its very last publish drew the delay fault.
#[test]
fn delayed_publishes_drain_into_the_final_manifest() {
    for seed in fault_seeds() {
        let faulty = Arc::new(Faulty::wrap(
            Arc::new(InProcess::new(8)),
            FaultPlan::new(seed).with_delayed_publishes(0.6),
        ));
        let _ = run_over(faulty.clone(), &[]);
        assert!(
            faulty
                .fault_log()
                .iter()
                .any(|e| e.kind == FaultKind::DelayedPublish),
            "seed {seed}: the delay fault never fired"
        );
        // Every member's last checkpoint (local step 160) is in the
        // manifest and fetchable after the drain.
        let beats = faulty.last_steps().unwrap();
        assert_eq!(
            beats,
            vec![(0, 160), (1, 160), (2, 160)],
            "seed {seed}: final manifest incomplete"
        );
        for m in 0..3 {
            let ck = faulty.latest(m).unwrap().expect("missing final checkpoint");
            assert_eq!(ck.step, 160, "seed {seed}: member {m} fetches a stale final");
        }
    }
}

/// Publish-cadence skew: members on different cadences still converge,
/// and the observed staleness actually shows the skew (samples beyond the
/// uniform-cadence bound).
#[test]
fn publish_cadence_skew_converges_with_visible_staleness() {
    let baseline = fault_free_baseline(&[]);
    let probes: Vec<Arc<Mutex<DriftProbe>>> =
        (0..3).map(|_| Arc::new(Mutex::new(DriftProbe::default()))).collect();
    let mut hosted: Vec<HostedMember> = (0..3)
        .map(|i| {
            HostedMember::new(
                i,
                Box::new(DriftMember::with_probe(i, probes[i].clone())) as Box<dyn Member>,
                [10u64, 15, 25][i],
            )
            .with_offset([0u64, 3, 7][i])
        })
        .collect();
    let log = Coordinator::new(cfg(), Arc::new(InProcess::new(8)))
        .run(&mut hosted)
        .unwrap();
    assert_within_pct("skewed cadences", log.final_mean_loss().unwrap(), baseline, 5.0);
    let max_staleness = log.staleness.iter().map(|&(_, _, s)| s).max().unwrap();
    assert!(
        max_staleness > 10,
        "skewed cadences never exceeded the uniform staleness bound: {max_staleness}"
    );
}

/// Two coordinators (threads) host disjoint member subsets against one
/// socket exchange — no lockstep loop anywhere, cooperation only through
/// published checkpoints. Both must converge near the single-coordinator
/// fault-free run.
#[test]
fn two_coordinators_share_one_socket_exchange() {
    let baseline = fault_free_baseline(&[]);
    let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
    let addr = server.addr().to_string();

    let spawn_coordinator = |ids: Vec<usize>, addr: String| {
        std::thread::spawn(move || {
            let mut hosted: Vec<HostedMember> = ids
                .into_iter()
                .map(|i| {
                    HostedMember::new(
                        i,
                        Box::new(DriftMember::new(i))
                            as Box<dyn Member>,
                        10,
                    )
                })
                .collect();
            let transport: Arc<dyn ExchangeTransport> =
                Arc::new(SocketTransport::connect_tcp(&addr));
            Coordinator::new(cfg(), transport).run(&mut hosted).unwrap()
        })
    };
    let a = spawn_coordinator(vec![0, 1], addr.clone());
    // Small head start so A's first publications exist before B's fast
    // mock members race through their schedule (B still overlaps A for
    // almost the whole run).
    std::thread::sleep(std::time::Duration::from_millis(20));
    let b = spawn_coordinator(vec![2], addr);
    let log_a = a.join().unwrap();
    let log_b = b.join().unwrap();
    drop(server);

    assert_eq!(log_a.ids, vec![0, 1]);
    assert_eq!(log_b.ids, vec![2]);
    // Thread interleaving makes staleness nondeterministic here, but both
    // processes' members must converge and must actually have exchanged.
    assert!(!log_a.staleness.is_empty() && !log_b.staleness.is_empty());
    assert_within_pct("coordinator A", log_a.final_mean_loss().unwrap(), baseline, 10.0);
    assert_within_pct("coordinator B", log_b.final_mean_loss().unwrap(), baseline, 10.0);
}

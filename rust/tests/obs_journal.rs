//! The `codistill::obs` journal acceptance suite (`make test-obs`):
//!
//! * **Same-seed byte-identity** across the run matrix — orchestrator,
//!   coordinator, and serving tier, each over a `Retry(Faulty(Socket))`
//!   stack — two runs with the same seed must serialize byte-identical
//!   JSONL traces, and every replay text derived from the journal
//!   (retry log, fault log, staleness log, swap log) must replay
//!   byte-identical too.
//! * **View coherence** — the journal-derived replay text equals the
//!   subsystem's own log rendering (`RunLog::staleness_log_text`, the
//!   server's churn log), so the shared renderer really is the single
//!   source of those bytes.
//! * **Round trip** — `EventJournal::from_jsonl(to_jsonl())` is
//!   lossless for every event kind a real run produces.
//! * **Calibration pin** — `netsim::calibrate` fitted on the committed
//!   fixture trace models the compressed exchange within 25% of the
//!   measured wall time (the ISSUE acceptance bound).

use codistill::codistill::{
    Codec, Coordinator, CoordinatorConfig, DistillSchedule, EventJournal, ExchangeTransport,
    FaultPlan, Faulty, HostedMember, LrSchedule, Member, Orchestrator, OrchestratorConfig,
    Recorder, Retry, RetryPolicy, SocketServer, SocketTransport, SubscribeConfig, Subscription,
    Topology,
};
use codistill::codistill::serve::{InferenceServer, ServeConfig};
use codistill::models::MockForward;
use codistill::netsim::calibrate;
use codistill::testkit::DriftMember;
use std::sync::Arc;
use std::time::{Duration, Instant};

const SEED: u64 = 23;

/// One run's observable artifacts: the serialized journal plus every
/// replay text derived from it.
struct Artifacts {
    jsonl: String,
    retry_text: String,
    fault_text: String,
    staleness_text: String,
    swap_text: String,
}

impl Artifacts {
    fn from_recorder(rec: &Recorder) -> Self {
        let j = rec.journal();
        Artifacts {
            jsonl: rec.to_jsonl(),
            retry_text: j.retry_log_text(),
            fault_text: j.fault_log_text(),
            staleness_text: j.staleness_log_text(),
            swap_text: j.swap_log_text(),
        }
    }

    fn assert_bytes_eq(&self, other: &Self, tag: &str) {
        assert_eq!(
            self.jsonl.as_bytes(),
            other.jsonl.as_bytes(),
            "{tag}: JSONL traces differ across same-seed runs"
        );
        for (name, a, b) in [
            ("retry", &self.retry_text, &other.retry_text),
            ("fault", &self.fault_text, &other.fault_text),
            ("staleness", &self.staleness_text, &other.staleness_text),
            ("swap", &self.swap_text, &other.swap_text),
        ] {
            assert_eq!(
                a.as_bytes(),
                b.as_bytes(),
                "{tag}: {name} replay text differs across same-seed runs"
            );
        }
    }
}

fn count(jsonl: &str, ev: &str) -> usize {
    let needle = format!("\"ev\":\"{ev}\"");
    jsonl.matches(&needle).count()
}

/// `Retry(Faulty(Socket))` over a fresh TCP exchange server, all three
/// decorators recording into `rec`. Returns the stack plus the server
/// handle (kept alive for the run's duration).
fn faulty_socket_stack(
    rec: &Recorder,
    plan: FaultPlan,
) -> (Arc<dyn ExchangeTransport>, SocketServer) {
    let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
    let client: Arc<dyn ExchangeTransport> =
        Arc::new(SocketTransport::connect_tcp(server.addr()));
    let faulty = Arc::new(Faulty::wrap(client, plan).with_recorder(rec.clone()));
    let retry = Arc::new(
        Retry::wrap(faulty, RetryPolicy::immediate(3, SEED)).with_recorder(rec.clone()),
    );
    (retry, server)
}

// ---------------------------------------------------------------- matrix

/// Orchestrator leg: lockstep loop, int8 publishes with error feedback,
/// delta teacher reads, stale-read + blackout faults.
fn run_orchestrator_leg() -> Artifacts {
    let rec = Recorder::sim(SEED);
    let plan = FaultPlan::new(SEED)
        .with_stale_reads(0.4)
        .with_blackout(1, 25, 35);
    let (transport, server) = faulty_socket_stack(&rec, plan);
    let cfg = OrchestratorConfig {
        total_steps: 40,
        reload_interval: 10,
        extra_staleness: 0,
        eval_every: 40,
        distill: DistillSchedule::new(10, 10, 1.0),
        lr: LrSchedule::Constant(0.1),
        topology: Topology::FullyConnected,
        cluster: None,
        seed: SEED,
        delta: true,
        publish_codec: Codec::Int8,
        error_feedback: true,
        verbose: false,
    };
    let mut members: Vec<Box<dyn Member>> = (0..2)
        .map(|i| Box::new(DriftMember::new(i)) as Box<dyn Member>)
        .collect();
    let orch = Orchestrator::with_transport(cfg, transport).with_recorder(rec.clone());
    let log = orch.run(&mut members).unwrap();

    // The RunLog's replay text and the journal's fold are the same bytes
    // (shared renderer over the same staleness observations).
    assert_eq!(
        log.staleness_log_text().as_bytes(),
        rec.journal().staleness_log_text().as_bytes(),
        "RunLog and journal disagree on the staleness replay"
    );
    drop(server);
    Artifacts::from_recorder(&rec)
}

/// Coordinator leg: per-member cadences, a mid-run joiner, erroring +
/// dropped + stale fetches pushed through the retry layer.
fn run_coordinator_leg() -> Artifacts {
    let rec = Recorder::sim(SEED);
    let plan = FaultPlan::new(SEED)
        .with_erroring_fetches(0.25)
        .with_dropped_fetches(0.15)
        .with_stale_reads(0.25);
    let (transport, server) = faulty_socket_stack(&rec, plan);
    let cfg = CoordinatorConfig {
        total_steps: 80,
        reload_interval: 10,
        eval_every: 40,
        distill: DistillSchedule::new(20, 10, 1.0),
        lr: LrSchedule::Constant(0.2),
        topology: Topology::FullyConnected,
        liveness_grace: 35,
        seed: SEED,
        delta: true,
        publish_codec: Codec::Int8,
        error_feedback: true,
        verbose: false,
    };
    let mut hosted: Vec<HostedMember> = (0..3)
        .map(|i| {
            let mut h = HostedMember::new(
                i,
                Box::new(DriftMember::new(i)) as Box<dyn Member>,
                10,
            );
            if i == 2 {
                h.join_delay = 30;
            }
            h
        })
        .collect();
    let log = Coordinator::new(cfg, transport)
        .with_recorder(rec.clone())
        .run(&mut hosted)
        .unwrap();

    assert_eq!(
        log.staleness_log_text().as_bytes(),
        rec.journal().staleness_log_text().as_bytes(),
        "CoordinatorLog and journal disagree on the staleness replay"
    );
    drop(server);
    Artifacts::from_recorder(&rec)
}

/// Serving leg: gated publisher, delta subscription, hot swaps into the
/// inference server — every publication is a distinct install, so the
/// event order publish -> fetch -> install -> swap is scheduling-free.
fn run_serve_leg() -> (Artifacts, String) {
    let rec = Recorder::sim(SEED);
    let (transport, server) = faulty_socket_stack(&rec, FaultPlan::new(SEED));

    let srv = Arc::new(InferenceServer::start(
        Arc::new(MockForward::new()),
        ServeConfig {
            max_batch_items: 16,
            max_delay: Duration::from_millis(1),
            workers: 2,
            probe: (0..8).collect(),
        },
    ));
    srv.set_recorder(rec.clone());

    let sub_server = srv.clone();
    let mut sub = Subscription::spawn_recorded(
        transport.clone(),
        SubscribeConfig {
            member: 0,
            poll_interval: Duration::from_millis(1),
            delta: true,
            codec: Codec::Raw,
        },
        Some(rec.clone()),
        move |ck| sub_server.install(ck),
    );

    let mut m = DriftMember::with_frozen(0, 64);
    for _ in 0..4 {
        for _ in 0..5 {
            m.train_step(0.0, 0.1).unwrap();
        }
        let ck = m.snapshot().unwrap();
        let step = ck.step;
        rec.record(codistill::codistill::Event::Publish {
            member: ck.member,
            step: ck.step,
            bytes: ck.flat().layout().total_bytes() as u64,
            dur_us: 0,
        });
        transport.publish(ck).unwrap();
        let t0 = Instant::now();
        while srv.installed_step() != Some(step) {
            assert!(
                t0.elapsed() < Duration::from_secs(20),
                "install of step {step} never landed"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    sub.stop();
    let (_, churn_log) = srv.churn();
    srv.shutdown();
    drop(server);
    (Artifacts::from_recorder(&rec), churn_log)
}

// ----------------------------------------------------------------- tests

#[test]
fn orchestrator_trace_is_byte_identical_across_same_seed_runs() {
    let a = run_orchestrator_leg();
    let b = run_orchestrator_leg();
    a.assert_bytes_eq(&b, "orchestrator");

    // The leg actually exercised the event kinds it claims to pin.
    assert!(count(&a.jsonl, "publish") >= 10, "publishes missing:\n{}", a.jsonl);
    assert!(count(&a.jsonl, "quantize") >= 10, "int8 feedback never journaled");
    assert!(count(&a.jsonl, "fetch") > 0 && count(&a.jsonl, "delta_install") > 0);
    assert!(count(&a.jsonl, "staleness") > 0);
    assert!(
        a.fault_text.contains("blackout-publish 1 30"),
        "scripted blackout missing from the fault replay:\n{}",
        a.fault_text
    );
}

#[test]
fn coordinator_trace_is_byte_identical_across_same_seed_runs() {
    let a = run_coordinator_leg();
    let b = run_coordinator_leg();
    a.assert_bytes_eq(&b, "coordinator");

    assert!(count(&a.jsonl, "publish") > 0);
    assert!(count(&a.jsonl, "rejoin") >= 1, "the delayed joiner never journaled");
    assert!(
        count(&a.jsonl, "fault") > 0,
        "fetch fault classes never fired — the plan is not exercising the stack"
    );
    assert!(
        count(&a.jsonl, "retry") > 0,
        "no retry attempts journaled despite erroring fetches"
    );
    assert!(!a.retry_text.is_empty() && !a.fault_text.is_empty());
}

#[test]
fn serve_trace_is_byte_identical_and_matches_the_server_swap_log() {
    let (a, churn_log) = run_serve_leg();
    let (b, _) = run_serve_leg();
    a.assert_bytes_eq(&b, "serve");

    // 4 gated publications: 4 installs, 3 swaps, one fetch per install.
    assert_eq!(count(&a.jsonl, "publish"), 4, "{}", a.jsonl);
    assert_eq!(count(&a.jsonl, "delta_install"), 4, "{}", a.jsonl);
    assert_eq!(count(&a.jsonl, "swap"), 3, "{}", a.jsonl);

    // The journal's swap fold and the server's own churn log are the
    // same bytes — one renderer, two paths.
    assert_eq!(
        a.swap_text.as_bytes(),
        churn_log.as_bytes(),
        "journal swap replay differs from the server churn log"
    );
}

#[test]
fn traces_round_trip_through_from_jsonl() {
    for (tag, jsonl) in [
        ("orchestrator", run_orchestrator_leg().jsonl),
        ("coordinator", run_coordinator_leg().jsonl),
        ("serve", run_serve_leg().0.jsonl),
    ] {
        let parsed = EventJournal::from_jsonl(&jsonl).unwrap();
        assert_eq!(
            parsed.to_jsonl().as_bytes(),
            jsonl.as_bytes(),
            "{tag}: from_jsonl(to_jsonl()) is lossy"
        );
    }
}

/// The ISSUE acceptance pin: calibration fitted on the committed fixture
/// trace (1 GB/s medium, 200us latency, 4 MB plane, 2 members, delta
/// steady state moving 2/8 windows at a 0.26 wire ratio) must model the
/// compressed exchange within 25% of the trace's measured wall time.
#[test]
fn calibrate_pins_the_committed_fixture_within_tolerance() {
    let trace = include_str!("data/calibrate_fixture.jsonl");
    let cal = calibrate(trace).unwrap();

    assert_eq!(cal.model.model_bytes, 4_000_000);
    assert_eq!(cal.model.workers, 2);
    assert_eq!(cal.model.reload_interval, 50);
    assert_eq!(cal.teachers, 1);
    assert!(
        (cal.model.bandwidth_bps - 1e9).abs() / 1e9 < 0.05,
        "fitted bandwidth {:.3e} B/s",
        cal.model.bandwidth_bps
    );
    assert!(
        (cal.model.latency_s - 200e-6).abs() < 50e-6,
        "fitted latency {:.1}us",
        cal.model.latency_s * 1e6
    );
    assert!((cal.changed_fraction - 0.25).abs() < 1e-9, "f = {}", cal.changed_fraction);
    assert!(
        cal.rel_error() <= 0.25,
        "modeled {:.4e}s vs measured {:.4e}s: rel error {:.1}% > 25%",
        cal.modeled_exchange_s,
        cal.measured_exchange_s,
        cal.rel_error() * 100.0
    );
    // The report renders without panicking and names the fit.
    assert!(cal.report().contains("[calibrate]"));
}

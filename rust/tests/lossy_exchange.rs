//! Lossy exchange quality gate (ISSUE 9).
//!
//! The paper's premise is that online distillation tolerates stale,
//! *imprecise* teacher weights, so the exchange may quantize — but only
//! the publisher may quantize, exactly once, with the error accounted
//! for. These tests pin the three legs of that contract:
//!
//! 1. **Quality**: same-seed orchestrated mock runs with `--compress
//!    codec=int8 --error-feedback` stay within a pinned tolerance of the
//!    lossless reference, while the *feedback-off* run's accumulated
//!    quantization bias grows linearly with publish count — measurably
//!    (>3x) worse than the telescoping feedback-on carry.
//! 2. **Transport invisibility**: a plane prepared by [`ErrorFeedback`]
//!    installs byte-identically over inproc, CKPT0005 spool files,
//!    encoded socket frames, a relay hop, and fault injection — and a
//!    corrupt lossy payload fails the decoded-payload digest loudly.
//! 3. **Codec laws**: for every registered wire id, `Codec::encode` is
//!    exact-or-raw (decode(encode(x)) is bit-identical for *arbitrary*
//!    input, NaN and inf included) and never larger than raw; loss only
//!    ever enters through `ErrorFeedback::prepare`, within documented
//!    bounds.

use codistill::codistill::transport::spool::spool_file_name;
use codistill::codistill::transport::{DeltaCache, ErrorFeedback};
use codistill::codistill::{
    Checkpoint, Codec, DistillSchedule, EvalStats, ExchangeTransport, FaultPlan, Faulty,
    InProcess, LrSchedule, Member, Orchestrator, OrchestratorConfig, Relay, RelayConfig, RunLog,
    SocketServer, SocketTransport, SpoolDir, StepStats, Topology,
};
use codistill::runtime::{Tensor, TensorMap};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

const W: usize = 4;
/// The int8 grid step for windows with amax in (0.062, 0.124]: the
/// power-of-two scale 2^-10. `GateMember` keeps every window inside
/// that band so the bias arithmetic below is exact.
const STEP: f64 = 0.0009765625;
/// What int8 does to a frozen 0.1 window without feedback: 0.1 / 2^-10
/// rounds to q=102, so every publish installs 102 * 2^-10 =
/// 0.099609375 — a constant bias of one third of a step, every time.
const TABLE_BIAS: f64 = 0.1 - 0.099609375;

/// Deterministic member for the quality gate. `params.w` drifts inside
/// [-0.124, 0.124] (one int8 scale band) and is pulled toward the
/// installed teachers' mean; `params.table` is frozen at 0.1 — a value
/// *off* the int8 grid, so every lossy publish quantizes it and the
/// probe can watch the installed bias. Eval loss is `1 + mean|w|`.
struct GateMember {
    id: usize,
    step: u64,
    params: TensorMap,
    teacher_mean: Option<Vec<f32>>,
    /// Mean installed `params.table` value, one entry per reload.
    table_installs: Arc<Mutex<Vec<f32>>>,
}

impl GateMember {
    fn new(id: usize, table_installs: Arc<Mutex<Vec<f32>>>) -> Self {
        let init: Vec<f32> = (0..W)
            .map(|k| 0.02 + 0.03 * id as f32 + 0.01 * k as f32)
            .collect();
        let mut params = TensorMap::new();
        params.insert("params.w", Tensor::f32(&[W], init).unwrap());
        params.insert("params.table", Tensor::f32(&[16], vec![0.1; 16]).unwrap());
        GateMember {
            id,
            step: 0,
            params,
            teacher_mean: None,
            table_installs,
        }
    }

    fn w(&self) -> Vec<f32> {
        self.params
            .get("params.w")
            .unwrap()
            .as_f32()
            .unwrap()
            .to_vec()
    }
}

impl Member for GateMember {
    fn train_step(&mut self, distill_w: f32, lr: f32) -> anyhow::Result<StepStats> {
        let teacher = self.teacher_mean.clone();
        let step = self.step;
        let id = self.id as u64;
        let w = self.params.get_mut("params.w")?.as_f32_mut()?;
        let mut distill_loss = 0.0f32;
        for (k, v) in w.iter_mut().enumerate() {
            // drift in [-0.1, 0.1]: |w| stays under 127 * 2^-10 = 0.124
            let drift = (((step * 7 + id * 13 + k as u64 * 5) % 11) as f32) * 0.02 - 0.1;
            *v = *v * (1.0 - lr) + lr * drift;
            if distill_w > 0.0 {
                if let Some(t) = &teacher {
                    let pull = t[k] - *v;
                    *v += distill_w * lr * 0.5 * pull;
                    distill_loss += pull * pull;
                }
            }
        }
        self.step += 1;
        let loss = w.iter().map(|v| v.abs()).sum::<f32>() / W as f32;
        Ok(StepStats {
            step: self.step,
            loss,
            distill_loss,
        })
    }

    fn snapshot(&self) -> anyhow::Result<Checkpoint> {
        Ok(Checkpoint::new(self.id, self.step, self.params.clone()))
    }

    fn set_teachers(&mut self, peers: Vec<Arc<Checkpoint>>) -> anyhow::Result<()> {
        let mut mean = vec![0.0f32; W];
        let mut table = 0.0f32;
        for p in &peers {
            for (m, v) in mean.iter_mut().zip(p.flat().view("params.w")?) {
                *m += *v;
            }
            table += p.flat().view("params.table")?[0];
        }
        for m in &mut mean {
            *m /= peers.len() as f32;
        }
        self.teacher_mean = Some(mean);
        self.table_installs
            .lock()
            .unwrap()
            .push(table / peers.len() as f32);
        Ok(())
    }

    fn evaluate(&mut self) -> anyhow::Result<EvalStats> {
        let loss = 1.0 + self.w().iter().map(|v| v.abs() as f64).sum::<f64>() / W as f64;
        Ok(EvalStats {
            loss,
            accuracy: None,
        })
    }

    fn steps_done(&self) -> u64 {
        self.step
    }

    fn params(&self) -> &TensorMap {
        &self.params
    }
}

const GATE_MEMBERS: usize = 3;

fn gate_cfg(codec: Codec, feedback: bool) -> OrchestratorConfig {
    OrchestratorConfig {
        total_steps: 400,
        reload_interval: 5,
        extra_staleness: 0,
        eval_every: 100,
        distill: DistillSchedule::new(5, 5, 1.0),
        lr: LrSchedule::Constant(0.25),
        topology: Topology::FullyConnected,
        cluster: None,
        seed: 3,
        delta: true,
        publish_codec: codec,
        error_feedback: feedback,
        verbose: false,
    }
}

/// Run the gate fixture; returns the log and every installed teacher
/// `params.table` mean, pooled across members in install order.
fn gate_run(codec: Codec, feedback: bool) -> (RunLog, Vec<f32>) {
    let installs = Arc::new(Mutex::new(Vec::new()));
    let mut members: Vec<Box<dyn Member>> = (0..GATE_MEMBERS)
        .map(|i| Box::new(GateMember::new(i, installs.clone())) as Box<dyn Member>)
        .collect();
    let log = Orchestrator::with_transport(gate_cfg(codec, feedback), Arc::new(InProcess::new(8)))
        .run(&mut members)
        .unwrap();
    let got = installs.lock().unwrap().clone();
    (log, got)
}

#[test]
fn quality_gate_int8_with_feedback_tracks_lossless() {
    let (reference, _) = gate_run(Codec::Raw, false);
    let (on, on_installs) = gate_run(Codec::Int8, true);
    let (off, off_installs) = gate_run(Codec::Int8, false);
    assert!(reference.feedback.is_none(), "lossless run grew feedback stats");

    // Eval curves: both lossy runs stay within a pinned tolerance of the
    // lossless reference at every eval point — teacher quantization
    // error is at most half a 2^-10 grid step per element, and the
    // contraction in the member dynamics keeps it there.
    for (tag, lossy) in [("feedback-on", &on), ("feedback-off", &off)] {
        assert_eq!(lossy.eval.len(), reference.eval.len(), "{tag}");
        for (m, (ra, la)) in reference.eval.iter().zip(&lossy.eval).enumerate() {
            assert_eq!(ra.len(), la.len(), "{tag}: member {m} curve length");
            for (rp, lp) in ra.iter().zip(la) {
                assert_eq!(rp.step, lp.step, "{tag}: member {m}");
                assert!(
                    (rp.loss - lp.loss).abs() <= 0.02,
                    "{tag}: member {m} step {} eval {} vs lossless {}",
                    rp.step,
                    lp.loss,
                    rp.loss
                );
            }
        }
    }

    // The frozen 0.1 table is off the int8 grid. Without feedback every
    // install lands on the same rounded code: a constant bias of
    // TABLE_BIAS per install, forever. With the carry the published code
    // alternates around the true value, so per-install error stays
    // under one grid step and the *accumulated* error telescopes.
    assert!(off_installs.len() >= 50, "gate fixture barely exchanged");
    assert_eq!(off_installs.len(), on_installs.len());
    for v in &off_installs {
        assert!(
            ((0.1 - *v) as f64 - TABLE_BIAS).abs() < 1e-6,
            "feedback-off install {v} is not the constant-bias code"
        );
    }
    for v in &on_installs {
        assert!(
            ((0.1 - *v) as f64).abs() <= STEP + 1e-7,
            "feedback-on install {v} strayed beyond one grid step"
        );
    }
    let mean_err = |installs: &[f32]| {
        installs.iter().map(|v| 0.1 - *v as f64).sum::<f64>() / installs.len() as f64
    };
    let (on_err, off_err) = (mean_err(&on_installs).abs(), mean_err(&off_installs).abs());
    assert!(on_err < 1.5e-4, "feedback-on mean bias {on_err} too large");
    assert!(off_err > 3.5e-4, "feedback-off mean bias {off_err} suspiciously small");
    assert!(
        off_err > 2.0 * on_err.max(1e-6),
        "feedback-off bias {off_err} not measurably worse than feedback-on {on_err}"
    );

    // And the publisher-side accounting agrees: feedback-off max |bias|
    // grows linearly in publishes; the feedback-on carry bounds it by
    // half a grid step per window.
    let on_stats = on.feedback.expect("feedback-on run lost its stats");
    let off_stats = off.feedback.expect("feedback-off run lost its stats");
    assert!(on_stats.windows_quantized > 0 && off_stats.windows_quantized > 0);
    assert!(
        on_stats.bytes_quantized < on_stats.bytes_raw_equiv,
        "int8 windows did not shrink: {on_stats:?}"
    );
    let publishes_per_member = off_stats.publishes as f64 / GATE_MEMBERS as f64;
    assert!(
        off_stats.max_abs_bias >= 0.9 * publishes_per_member * TABLE_BIAS,
        "feedback-off bias {} did not accumulate over ~{publishes_per_member} publishes",
        off_stats.max_abs_bias
    );
    assert!(
        on_stats.max_abs_bias <= 1.0e-3,
        "feedback-on bias {} escaped the half-step carry bound",
        on_stats.max_abs_bias
    );
    assert!(
        off_stats.max_abs_bias > 3.0 * on_stats.max_abs_bias,
        "feedback-off bias {} not >3x feedback-on {}",
        off_stats.max_abs_bias,
        on_stats.max_abs_bias
    );
}

// ---------------------------------------------------- transport invisibility

fn tdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("codistill_lossy_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// A two-window checkpoint with off-grid values: `params.hot` varies per
/// step, `params.cold` never changes.
fn offgrid_ckpt(member: usize, step: u64, hot: f32) -> Checkpoint {
    let mut params = TensorMap::new();
    let vals: Vec<f32> = (0..W).map(|k| hot + 0.0137 * k as f32).collect();
    params.insert("params.hot", Tensor::f32(&[W], vals).unwrap());
    params.insert("params.cold", Tensor::f32(&[W], vec![0.1; W]).unwrap());
    Checkpoint::new(member, step, params)
}

/// The publisher-side sequence every backend below replays: off-grid
/// planes quantized through the orchestrator's publish path. Feedback
/// stays off here so the frozen `params.cold` window quantizes to the
/// *same* code every publish (the carry would alternate adjacent codes,
/// which is the point of the quality gate, not of transport
/// invisibility) and the delta reader can digest-skip it.
fn prepared_sequence() -> Vec<Checkpoint> {
    let mut fb = ErrorFeedback::new(Codec::Int8, false);
    [1u64, 5, 9]
        .into_iter()
        .enumerate()
        .map(|(i, step)| {
            fb.prepare(offgrid_ckpt(0, step, 0.31 + 0.017 * i as f32))
                .unwrap()
        })
        .collect()
}

#[test]
fn lossy_installs_byte_identical_on_all_backends() {
    let cks = prepared_sequence();
    let by_step = |step: u64| cks.iter().find(|c| c.step == step).unwrap();

    let dir = tdir("backends");
    let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
    // (tag, transport, cache codec). Spool and socket negotiate the
    // codec at the transport; inproc and faulty at the spec level.
    let cases: Vec<(&str, Arc<dyn ExchangeTransport>, Option<Codec>)> = vec![
        ("inproc", Arc::new(InProcess::new(8)), Some(Codec::Int8)),
        (
            "spool",
            Arc::new(SpoolDir::open(&dir, 8).unwrap().with_codec(Codec::Int8)),
            None,
        ),
        (
            "socket",
            Arc::new(SocketTransport::connect_tcp(server.addr()).with_codec(Codec::Int8)),
            None,
        ),
        (
            "faulty",
            Arc::new(Faulty::wrap(
                Arc::new(InProcess::new(8)),
                FaultPlan::new(31).with_stale_reads(0.5),
            )),
            Some(Codec::Int8),
        ),
    ];
    for (tag, transport, cache_codec) in &cases {
        let mut cache = match cache_codec {
            Some(c) => DeltaCache::new().with_codec(*c),
            None => DeltaCache::new(),
        };
        for ck in &cks {
            transport.publish(ck.clone()).unwrap();
            // stale reads may serve an older publication: compare
            // against whatever prepared step actually arrived
            let got = cache.latest(transport.as_ref(), 0).unwrap().unwrap();
            let want = by_step(got.step);
            assert_eq!(
                got.flat().data(),
                want.flat().data(),
                "{tag}: lossy install diverged from the prepared plane"
            );
            assert_eq!(
                got.window_digests().as_ref(),
                want.window_digests().as_ref(),
                "{tag}: digest table diverged"
            );
        }
        let stats = cache.stats();
        assert!(
            stats.windows_encoded > 0,
            "{tag}: int8 never engaged on prepared planes: {stats:?}"
        );
        assert!(
            stats.windows_unchanged > 0,
            "{tag}: cold window moved every fetch: {stats:?}"
        );
    }
    // the spool medium really is CKPT0005
    let magic = &std::fs::read(dir.join(spool_file_name(0, 9))).unwrap()[..8];
    assert_eq!(magic, b"CKPT0005");
    drop(cases);
    drop(server);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn lossy_installs_byte_identical_through_a_faulty_relay_hop() {
    use std::time::{Duration, Instant};

    let cks = prepared_sequence();
    let hub = Arc::new(InProcess::new(8));
    // half the hub-link fetches error: the relay must still converge on
    // the exact prepared bytes
    let flaky: Arc<dyn ExchangeTransport> = Arc::new(Faulty::wrap(
        hub.clone(),
        FaultPlan::new(11).with_erroring_fetches(0.5),
    ));
    let relay = Relay::spawn_tcp(
        flaky,
        "127.0.0.1:0",
        RelayConfig {
            poll_interval: Duration::from_millis(1),
            delta: true,
            codec: Codec::Int8,
            ..RelayConfig::default()
        },
    )
    .unwrap();
    let leaf = SocketTransport::connect_tcp(relay.addr()).with_codec(Codec::Int8);
    let mut reader = DeltaCache::new();

    for ck in &cks {
        let step = ck.step;
        hub.publish(ck.clone()).unwrap();
        let deadline = Instant::now() + Duration::from_secs(30);
        let got = loop {
            if let Ok(Some(got)) = reader.latest(&leaf, 0) {
                if got.step >= step {
                    break got;
                }
            }
            assert!(
                Instant::now() < deadline,
                "prepared step {step} never reached the leaf"
            );
            std::thread::sleep(Duration::from_millis(1));
        };
        assert_eq!(got.step, step);
        assert_eq!(
            got.flat().data(),
            ck.flat().data(),
            "relay hop diverged the lossy install at step {step}"
        );
        assert_eq!(got.window_digests().as_ref(), ck.window_digests().as_ref());
    }
    assert!(
        relay.stats().tolerated_errors > 0,
        "fault plan never errored the hub link"
    );
}

#[test]
fn corrupt_lossy_payload_fails_loudly() {
    let cks = prepared_sequence();
    let dir = tdir("corrupt");
    let spool = SpoolDir::open(&dir, 8).unwrap().with_codec(Codec::Int8);
    let mut cache = DeltaCache::new();
    spool.publish(cks[0].clone()).unwrap();
    cache.latest(&spool, 0).unwrap().unwrap();
    spool.publish(cks[1].clone()).unwrap();

    // flip one bit inside the encoded int8 payload (the file tail is
    // payloads then an 8-byte residual count)
    let path = dir.join(spool_file_name(0, 5));
    let mut raw = std::fs::read(&path).unwrap();
    let n = raw.len();
    raw[n - 8 - 1] ^= 0x20;
    std::fs::write(&path, &raw).unwrap();

    // delta pread: the decoded-payload digest check must reject it
    let reader = SpoolDir::open(&dir, 8).unwrap();
    let err = format!("{:#}", cache.latest(&reader, 0).unwrap_err());
    assert!(
        err.contains("corrupt") || err.contains("digest mismatch"),
        "unexpected corruption error: {err}"
    );
    // full load: same corruption, same loud failure
    assert!(SpoolDir::open(&dir, 8).unwrap().latest(0).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------------------- codec laws

fn edge_payloads() -> Vec<Vec<f32>> {
    vec![
        vec![],
        vec![0.0],
        vec![-0.0, 0.0, -0.0, 0.0],
        vec![0.25; 300],                   // constant, on every grid
        vec![0.1; 300],                    // constant, off the int8 grid
        vec![f32::NAN, 1.0, -1.0, 0.5],
        vec![f32::INFINITY, f32::NEG_INFINITY, 0.5, -0.5],
        vec![1e-40, -1e-42, 1e-38, -0.0], // f32 denormals
        vec![3.4e38, -3.4e38, 1e-45, 0.0], // extremes both ways
        (0..257).map(|i| 0.37 + i as f32 * 1.3e-3).collect(),
        (0..64).map(|i| ((i * 2654435761u64 as usize) % 97) as f32 * 0.011 - 0.5).collect(),
    ]
}

#[test]
fn every_codec_id_roundtrips_exact_or_raw_and_never_larger() {
    for id in 0u8..=3 {
        let codec = Codec::from_id(id).unwrap();
        assert_eq!(codec.id(), id);
        for (pi, p) in edge_payloads().into_iter().enumerate() {
            let (tag, enc) = codec.encode(&p);
            assert!(
                enc.len() <= p.len() * 4,
                "{} payload #{pi}: encoded {} B > raw {} B",
                codec.name(),
                enc.len(),
                p.len() * 4
            );
            assert!(
                tag.wire_len_ok(enc.len() as u64, p.len()),
                "{} payload #{pi}: tag {} rejects its own length",
                codec.name(),
                tag.name()
            );
            let back = tag.decode(&enc, p.len()).unwrap();
            let a: Vec<u32> = p.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = back.iter().map(|v| v.to_bits()).collect();
            assert_eq!(
                a, b,
                "{} payload #{pi}: transport-level encode was not exact-or-raw",
                codec.name()
            );
        }
    }
    for bad in [4u8, 17, 255] {
        let err = format!("{:#}", Codec::from_id(bad).unwrap_err());
        assert!(
            err.contains("unknown window codec id"),
            "id {bad}: unexpected error {err}"
        );
    }
}

#[test]
fn every_codec_id_roundtrips_random_windows() {
    use codistill::testkit::{forall, in_range};
    forall::<(u64, u64)>("codec exact-or-raw", 0x10_55, 96, |&(len_raw, bits)| {
        let len = in_range(len_raw, 1, 96);
        let data: Vec<f32> = (0..len)
            .map(|i| f32::from_bits((bits as u32).wrapping_mul(2_654_435_769).wrapping_add(i as u32 * 0x9e37)))
            .collect();
        (0u8..=3).all(|id| {
            let codec = Codec::from_id(id).unwrap();
            let (tag, enc) = codec.encode(&data);
            if enc.len() > data.len() * 4 {
                return false;
            }
            match tag.decode(&enc, len) {
                Ok(back) => back
                    .iter()
                    .zip(&data)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                Err(_) => false,
            }
        })
    });
}

#[test]
fn prepared_lossy_windows_stay_within_documented_tolerance() {
    // Loss enters only via ErrorFeedback::prepare; its error bounds are
    // the module-documented ones: fp16 relative 2^-11 (absolute 2^-24
    // once subnormal), int8 absolute amax/127 (= scale/2 at worst).
    let windows: Vec<Vec<f32>> = vec![
        (0..128).map(|i| 0.001 + i as f32 * 0.0173).collect(),
        (0..64).map(|i| -3.0 + i as f32 * 0.09).collect(),
        vec![1e-40, 2e-40, -1e-39, 5e-41],
        vec![0.1; 32],
    ];
    for codec in [Codec::Fp16, Codec::Int8] {
        for (wi, vals) in windows.iter().enumerate() {
            let mut params = TensorMap::new();
            params.insert("params.x", Tensor::f32(&[vals.len()], vals.clone()).unwrap());
            let mut fb = ErrorFeedback::new(codec, false);
            let prepared = fb.prepare(Checkpoint::new(0, 1, params)).unwrap();
            let got = prepared.flat().view("params.x").unwrap();
            let amax = vals.iter().fold(0f64, |m, v| m.max(v.abs() as f64));
            for (x, y) in vals.iter().zip(got) {
                let err = (*x as f64 - *y as f64).abs();
                let bound = match codec {
                    Codec::Fp16 => (x.abs() as f64 * 2f64.powi(-11)).max(2f64.powi(-24)),
                    _ => amax / 127.0 + 1e-12,
                };
                assert!(
                    err <= bound,
                    "{} window #{wi}: |{x} - {y}| = {err} > {bound}",
                    codec.name()
                );
            }
            // and what prepare published is exactly what transports
            // re-encode losslessly under the lossy tag
            let (tag, enc) = codec.encode(got);
            if tag == codec {
                let back = tag.decode(&enc, got.len()).unwrap();
                assert!(back.iter().zip(got).all(|(a, b)| a.to_bits() == b.to_bits()));
            }
        }
    }
}

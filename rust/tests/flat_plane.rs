//! Flat-plane integration tests (no XLA): property-style equivalence of
//! the three reduce strategies over ragged tensor sets, flat gather/scatter
//! round trips, and checkpoint format compatibility (CKPT0002 writer +
//! CKPT0001 reader/writer).

use codistill::codistill::Checkpoint;
use codistill::prng::Pcg64;
use codistill::runtime::flat::{FlatBuffer, FlatLayout};
use codistill::runtime::{Tensor, TensorMap};
use codistill::sgd::allreduce::{allreduce_mean, ReduceStrategy};
use std::sync::Arc;

/// Worker counts the paper's group sweeps actually use.
const WORKER_COUNTS: [usize; 6] = [1, 2, 3, 5, 8, 13];

/// A ragged leaf set: `k` tensors with pseudo-random small shapes.
fn ragged_shapes(rng: &mut Pcg64, k: usize) -> Vec<(String, Vec<usize>)> {
    (0..k)
        .map(|i| {
            let rank = 1 + (rng.below(3) as usize); // 1..=3
            let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(7) as usize).collect();
            (format!("grads.t{i:02}"), shape)
        })
        .collect()
}

/// One worker's map over the given leaf shapes, values seeded per worker.
fn worker_map(shapes: &[(String, Vec<usize>)], w: usize, seed: u64) -> TensorMap {
    let mut rng = Pcg64::new(seed ^ (w as u64).wrapping_mul(0x9e3779b97f4a7c15));
    let mut m = TensorMap::new();
    for (name, shape) in shapes {
        let numel: usize = shape.iter().product();
        let data: Vec<f32> = (0..numel).map(|_| rng.normal() as f32).collect();
        m.insert(name.clone(), Tensor::f32(shape, data).unwrap());
    }
    // Off-prefix cargo every worker carries.
    m.insert("loss", Tensor::scalar_f32(w as f32));
    m
}

#[test]
fn flat_equals_tree_equals_naive_over_ragged_sets() {
    for (case, &workers) in WORKER_COUNTS.iter().enumerate().map(|(c, w)| (c as u64, w)) {
        for leaves in [1usize, 3, 9] {
            let mut rng = Pcg64::new(1000 + case * 17 + leaves as u64);
            let shapes = ragged_shapes(&mut rng, leaves);
            let make = || -> Vec<TensorMap> {
                (0..workers).map(|w| worker_map(&shapes, w, 42 + case)).collect()
            };
            let a = allreduce_mean(make(), "grads.", ReduceStrategy::Naive).unwrap();
            let b = allreduce_mean(make(), "grads.", ReduceStrategy::Tree).unwrap();
            let c = allreduce_mean(make(), "grads.", ReduceStrategy::Flat).unwrap();
            for (name, _) in &shapes {
                let va = a.get(name).unwrap().as_f32().unwrap();
                let vb = b.get(name).unwrap().as_f32().unwrap();
                let vc = c.get(name).unwrap().as_f32().unwrap();
                for i in 0..va.len() {
                    assert!(
                        (va[i] - vb[i]).abs() < 1e-5,
                        "tree diverged: w={workers} {name}[{i}]: {} vs {}",
                        va[i],
                        vb[i]
                    );
                    assert!(
                        (va[i] - vc[i]).abs() < 1e-5,
                        "flat diverged: w={workers} {name}[{i}]: {} vs {}",
                        va[i],
                        vc[i]
                    );
                }
            }
            // worker 0's off-prefix entries ride along in every strategy
            assert_eq!(c.get("loss").unwrap().item_f32().unwrap(), 0.0);
        }
    }
}

#[test]
fn flat_mean_matches_analytic_value() {
    // Values are w (worker index) everywhere: mean must be (W-1)/2.
    for workers in WORKER_COUNTS {
        let ws: Vec<TensorMap> = (0..workers)
            .map(|w| {
                let mut m = TensorMap::new();
                m.insert("grads.w", Tensor::f32(&[33], vec![w as f32; 33]).unwrap());
                m
            })
            .collect();
        let r = allreduce_mean(ws, "grads.", ReduceStrategy::Flat).unwrap();
        let want = (workers as f32 - 1.0) / 2.0;
        for v in r.get("grads.w").unwrap().as_f32().unwrap() {
            assert!((v - want).abs() < 1e-6, "w={workers}: {v} vs {want}");
        }
    }
}

#[test]
fn gather_scatter_roundtrips_ragged_maps() {
    for case in 0..20u64 {
        let mut rng = Pcg64::new(777 + case);
        let shapes = ragged_shapes(&mut rng, 1 + (case as usize % 7));
        let m = worker_map(&shapes, 0, case);
        let layout = Arc::new(FlatLayout::from_map(&m, "grads."));
        let buf = FlatBuffer::gather(layout.clone(), &m).unwrap();
        assert_eq!(buf.data().len(), layout.total_len());
        let round = buf.to_map().unwrap();
        for (name, shape) in &shapes {
            let orig = m.get(name).unwrap();
            let got = round.get(name).unwrap();
            assert_eq!(got.shape(), shape.as_slice(), "{name}");
            assert_eq!(got.as_f32().unwrap(), orig.as_f32().unwrap(), "{name}");
        }
        // windows are name-sorted and contiguous
        let mut offset = 0usize;
        for e in layout.entries() {
            assert_eq!(e.offset, offset, "{}", e.name);
            offset += e.len;
        }
        assert_eq!(offset, layout.total_len());
    }
}

fn mixed_checkpoint(step: u64) -> Checkpoint {
    let mut rng = Pcg64::new(step);
    let mut params = TensorMap::new();
    for (name, shape) in ragged_shapes(&mut rng, 5) {
        let name = name.replace("grads.", "params.");
        let numel: usize = shape.iter().product();
        let data: Vec<f32> = (0..numel).map(|_| rng.normal() as f32).collect();
        params.insert(name, Tensor::f32(&shape, data).unwrap());
    }
    params.insert("params.vocab_ids", Tensor::i32(&[4], vec![3, 1, 4, 1]).unwrap());
    Checkpoint::new(2, step, params)
}

fn assert_same_params(a: &Checkpoint, b: &Checkpoint) {
    let pa = a.params();
    let pb = b.params();
    let names_a: Vec<&str> = pa.names().collect();
    let names_b: Vec<&str> = pb.names().collect();
    assert_eq!(names_a, names_b);
    for name in names_a {
        let ta = pa.get(name).unwrap();
        let tb = pb.get(name).unwrap();
        assert_eq!(ta.shape(), tb.shape(), "{name}");
        match (ta.as_f32(), tb.as_f32()) {
            (Ok(x), Ok(y)) => assert_eq!(x, y, "{name}"),
            _ => assert_eq!(
                ta.as_i32().unwrap(),
                tb.as_i32().unwrap(),
                "{name}"
            ),
        }
    }
}

#[test]
fn flat_checkpoint_roundtrips_both_formats() {
    let dir = std::env::temp_dir().join(format!("codistill_flatplane_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ck = mixed_checkpoint(42);

    // CKPT0002: contiguous flat payload.
    let p2 = dir.join("v2.ckpt");
    ck.save(&p2).unwrap();
    let l2 = Checkpoint::load(&p2).unwrap();
    assert_eq!((l2.member, l2.step), (2, 42));
    assert_same_params(&ck, &l2);
    assert!(l2.flat().layout().same_plane(ck.flat().layout()));

    // CKPT0001: legacy per-tensor framing, same reader entry point.
    let p1 = dir.join("v1.ckpt");
    ck.save_v1(&p1).unwrap();
    let raw = std::fs::read(&p1).unwrap();
    assert_eq!(&raw[..8], b"CKPT0001");
    let l1 = Checkpoint::load(&p1).unwrap();
    assert_eq!((l1.member, l1.step), (2, 42));
    assert_same_params(&ck, &l1);

    // and a flat-built checkpoint equals its v1 round trip on the plane too
    assert_eq!(l1.flat().data(), ck.flat().data());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn scatter_reload_preserves_untouched_entries() {
    let ck = mixed_checkpoint(7);
    let mut dst = ck.params();
    // perturb, then reload from the checkpoint plane
    for (_, t) in dst.prefix_iter_mut("params.") {
        if let Ok(d) = t.as_f32_mut() {
            for v in d.iter_mut() {
                *v += 100.0;
            }
        }
    }
    dst.insert("state.h", Tensor::f32(&[2], vec![9.0, 9.0]).unwrap());
    ck.scatter_params_into(&mut dst).unwrap();
    assert_same_params(&ck, &Checkpoint::new(2, 7, {
        let mut p = TensorMap::new();
        p.adopt_prefix(&dst, "params.", "params.");
        p
    }));
    // non-param storage untouched by the reload
    assert_eq!(dst.get("state.h").unwrap().as_f32().unwrap(), &[9.0, 9.0]);
}

/// Property (seeded cases, testkit-style): for random layouts, the window
/// addressing surface — `byte_range`, `window_range`, `write_window` —
/// round-trips every leaf, and concatenating the windows in layout order
/// reassembles the exact plane bytes. This is the invariant every sharded
/// transport fetch (spool `pread`, socket `FETCH`) leans on.
#[test]
fn property_window_addressing_roundtrips_random_layouts() {
    for case in 0..40u64 {
        let mut rng = Pcg64::new(0xF1A7 ^ case.wrapping_mul(0x9e3779b97f4a7c15));
        let k = 1 + rng.below(9) as usize;
        let shapes = ragged_shapes(&mut rng, k);
        let map = worker_map(&shapes, case as usize, 99);
        let layout = Arc::new(FlatLayout::from_map(&map, "grads."));
        let full = FlatBuffer::gather(layout.clone(), &map).unwrap();

        // windows pack densely, byte ranges are 4x element ranges, and
        // both addressing forms agree
        let mut expect_offset = 0usize;
        for e in layout.entries() {
            assert_eq!(e.offset, expect_offset, "case {case}: {:?}", e.name);
            assert_eq!(e.byte_range(), e.offset * 4..(e.offset + e.len) * 4);
            assert_eq!(layout.window_range(&e.name), Some(e.range()));
            expect_offset += e.len;
        }
        assert_eq!(expect_offset, layout.total_len(), "case {case}");
        assert_eq!(layout.total_bytes(), layout.total_len() * 4);

        // write_window reassembles the plane from its windows in any order
        let mut names: Vec<String> = layout.names().map(|s| s.to_string()).collect();
        rng.shuffle(&mut names);
        let mut assembled = FlatBuffer::zeros(layout.clone());
        for name in &names {
            assembled
                .write_window(name, full.view(name).unwrap())
                .unwrap();
        }
        assert_eq!(assembled.data(), full.data(), "case {case}");

        // concatenated windows in layout order ARE the plane bytes
        let concat: Vec<f32> = layout
            .entries()
            .iter()
            .flat_map(|e| full.view(&e.name).unwrap().to_vec())
            .collect();
        assert_eq!(concat, full.data(), "case {case}");
    }
}

//! Fan-out soak: the event-driven socket server must carry hundreds of
//! concurrent readers on ONE loop thread — no thread-per-connection
//! explosion, no protocol errors, every reader's installed plane
//! byte-identical to the publisher's — and the relay tier must hold the
//! same guarantee one hop further down a tree.
//!
//! Determinism contract: the soak is seeded (plane contents derive from
//! the seed) and the sorted final-digest log is byte-identical across two
//! runs of the same seed, so a failure replays. `make test-fanout` runs
//! the relayed soak over the seed list in `CODISTILL_FAULT_SEEDS`
//! (default `11 23 47`).

use codistill::codistill::transport::DeltaCache;
use codistill::codistill::{
    Checkpoint, Codec, ExchangeTransport, FaultPlan, Faulty, Relay, RelayConfig, SocketServer,
    SocketTransport,
};
use codistill::runtime::{Tensor, TensorMap};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Concurrent readers in the headline soak (the ISSUE floor).
const READERS: usize = 512;
/// Drift-fleet size: readers round-robin across these members.
const MEMBERS: usize = 4;
/// Publications per member; readers run until they install the last one.
const FINAL_STEP: u64 = 6;
/// Per-reader deadline: generous because 512 readers share one loop
/// thread on a possibly loaded CI box — correctness, not latency, is
/// under test here.
const DEADLINE: Duration = Duration::from_secs(120);

/// Seeds for the relayed soak matrix: `CODISTILL_FAULT_SEEDS="a b c"`
/// (the `make test-fanout` pin) or a fixed default list.
fn fault_seeds() -> Vec<u64> {
    std::env::var("CODISTILL_FAULT_SEEDS")
        .ok()
        .map(|v| v.split_whitespace().filter_map(|t| t.parse().ok()).collect::<Vec<u64>>())
        .filter(|v: &Vec<u64>| !v.is_empty())
        .unwrap_or_else(|| vec![11, 23, 47])
}

/// Deterministic publication: every byte a function of (seed, member,
/// step), so the expected digests can be recomputed without touching the
/// wire. `params.table` is step-invariant — the frozen window a delta
/// reader must skip on every reload after the first.
fn plane(seed: u64, member: usize, step: u64) -> Checkpoint {
    let hot: Vec<f32> = (0..1024u64)
        .map(|k| ((seed * 31 + member as u64 * 13 + step * 7 + k) % 97) as f32 * 0.125)
        .collect();
    let mut params = TensorMap::new();
    params.insert("params.hot", Tensor::f32(&[1024], hot).unwrap());
    params.insert(
        "params.table",
        Tensor::f32(&[256], vec![0.25 * (member as f32 + 1.0); 256]).unwrap(),
    );
    Checkpoint::new(member, step, params)
}

/// `Threads:` from /proc/self/status — the process-wide thread count the
/// soak bounds. Non-Linux returns None and the bound is skipped.
fn thread_count() -> Option<usize> {
    if !cfg!(target_os = "linux") {
        return None;
    }
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("Threads:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

/// One reader's terminal record: deterministic given the seed (digests
/// derive from plane bytes, which derive from the seed), so sorting these
/// lines yields a replay-comparable log.
fn digest_line(reader: usize, ck: &Checkpoint) -> String {
    let digests: Vec<String> = ck
        .window_digests()
        .iter()
        .map(|d| format!("{d:016x}"))
        .collect();
    format!(
        "reader={reader:04} member={} step={} digests={}",
        ck.member,
        ck.step,
        digests.join(",")
    )
}

struct SoakOutcome {
    /// Sorted per-reader digest lines (the replay log).
    log: Vec<String>,
    /// Reader-visible transport errors (MUST be zero on a clean fabric).
    errors: usize,
    /// Peak process thread count minus the pre-spawn baseline.
    thread_growth: Option<usize>,
}

/// Spawn `readers` small-stack reader threads against `addr` while the
/// fleet publishes, and collect every reader's final installed plane.
/// Even readers run the delta+codec path, odd readers the classic
/// full-plane path — both must land on identical bytes.
fn run_readers(addr: &str, readers: usize, errors: &Arc<AtomicUsize>) -> Vec<String> {
    let log: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::with_capacity(readers)));
    let mut handles = Vec::with_capacity(readers);
    for i in 0..readers {
        let addr = addr.to_string();
        let log = log.clone();
        let errors = errors.clone();
        let h = std::thread::Builder::new()
            .name(format!("fanout-reader-{i}"))
            // deliberately tiny: 512 readers must not need big stacks,
            // and the server side adds NO threads for them at all
            .stack_size(128 * 1024)
            .spawn(move || {
                let member = i % MEMBERS;
                let t = SocketTransport::connect_tcp(&addr).with_codec(Codec::Shuffle);
                let mut cache = DeltaCache::new().with_codec(Codec::Shuffle);
                let t0 = Instant::now();
                loop {
                    let got = if i % 2 == 0 {
                        cache.latest(&t, member)
                    } else {
                        t.latest(member)
                    };
                    match got {
                        Ok(Some(ck)) if ck.step >= FINAL_STEP => {
                            log.lock().unwrap().push(digest_line(i, &ck));
                            return;
                        }
                        Ok(_) => {}
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    assert!(
                        t0.elapsed() < DEADLINE,
                        "reader {i} never saw member {member} reach step {FINAL_STEP}"
                    );
                    std::thread::sleep(Duration::from_millis(2));
                }
            })
            .unwrap();
        handles.push(h);
    }
    for h in handles {
        h.join().expect("reader thread panicked");
    }
    let mut lines = Arc::try_unwrap(log).unwrap().into_inner().unwrap();
    lines.sort();
    lines
}

/// The headline soak: `READERS` concurrent readers against one
/// event-driven server while the fleet publishes live.
fn run_hub_soak(seed: u64) -> SoakOutcome {
    let baseline = thread_count();
    let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
    let errors = Arc::new(AtomicUsize::new(0));

    // peak-thread monitor: samples while the soak runs
    let peak = Arc::new(AtomicUsize::new(0));
    let stop_monitor = Arc::new(AtomicBool::new(false));
    let monitor = {
        let peak = peak.clone();
        let stop = stop_monitor.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                if let Some(n) = thread_count() {
                    peak.fetch_max(n, Ordering::Relaxed);
                }
                std::thread::sleep(Duration::from_millis(10));
            }
        })
    };

    // live publisher: first publication up front so readers never spin on
    // an empty hub, the rest land while readers are mid-flight
    let publisher = {
        let addr = server.addr().to_string();
        std::thread::spawn(move || {
            let t = SocketTransport::connect_tcp(&addr);
            for step in 1..=FINAL_STEP {
                for member in 0..MEMBERS {
                    t.publish(plane(seed, member, step)).unwrap();
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        })
    };

    let log = run_readers(server.addr(), READERS, &errors);
    publisher.join().unwrap();
    stop_monitor.store(true, Ordering::Relaxed);
    monitor.join().unwrap();

    SoakOutcome {
        log,
        errors: errors.load(Ordering::Relaxed),
        thread_growth: baseline.map(|b| peak.load(Ordering::Relaxed).saturating_sub(b)),
    }
}

/// Expected digest suffix for `member`'s final publication, recomputed
/// from the seed without any transport in the loop.
fn expected_suffix(seed: u64, member: usize) -> String {
    let ck = plane(seed, member, FINAL_STEP);
    let digests: Vec<String> = ck
        .window_digests()
        .iter()
        .map(|d| format!("{d:016x}"))
        .collect();
    format!("member={member} step={FINAL_STEP} digests={}", digests.join(","))
}

#[test]
fn soak_512_readers_zero_errors_bounded_threads_replay_identical() {
    let seed = *fault_seeds().first().unwrap_or(&11);
    let first = run_hub_soak(seed);

    // zero protocol errors on a clean fabric
    assert_eq!(first.errors, 0, "readers saw transport errors:\n{:?}", first.log);
    // every reader finished and installed the publisher's exact bytes
    assert_eq!(first.log.len(), READERS);
    for line in &first.log {
        let member: usize = line
            .split("member=")
            .nth(1)
            .and_then(|s| s.split_whitespace().next())
            .and_then(|s| s.parse().ok())
            .unwrap();
        let want = expected_suffix(seed, member);
        assert!(
            line.ends_with(&want),
            "digest mismatch:\n  got  {line}\n  want ...{want}"
        );
    }

    // the event loop serves 512 connections without a thread per
    // connection: growth is the reader threads themselves plus slack for
    // the loop/publisher/monitor and any sibling test running in
    // parallel under libtest — NOT 2x the reader count
    if let Some(growth) = first.thread_growth {
        assert!(
            growth <= READERS + 128,
            "thread growth {growth} suggests thread-per-connection serving"
        );
    }

    // replay: same seed, second run, byte-identical sorted log
    let second = run_hub_soak(seed);
    assert_eq!(second.errors, 0);
    assert_eq!(first.log, second.log, "same-seed soak logs diverged");
}

/// Relayed soak, one per configured seed: hub behind a seeded `Faulty`
/// upstream link, two relays subscribed to it, readers split across the
/// relays. Injected upstream faults may surface to a reader whose relay
/// mirror is still cold (the fetch passes through) — those retries are
/// expected; what must hold is that every reader STILL lands on the
/// hub's exact bytes and that two runs of a seed replay identically.
#[test]
fn relayed_soak_replays_per_seed() {
    const RELAY_READERS: usize = 64;
    for seed in fault_seeds() {
        let run = |seed: u64| -> Vec<String> {
            let hub = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
            let cfg = || RelayConfig {
                poll_interval: Duration::from_millis(2),
                codec: Codec::Shuffle,
                ..RelayConfig::default()
            };
            let make_relay = |addr: &str| {
                let up: Arc<dyn ExchangeTransport> =
                    Arc::new(SocketTransport::connect_tcp(addr).with_codec(Codec::Shuffle));
                let flaky = Arc::new(Faulty::wrap(
                    up,
                    FaultPlan::new(seed).with_erroring_fetches(0.2),
                ));
                Relay::spawn_tcp(flaky, "127.0.0.1:0", cfg()).unwrap()
            };
            let relay_a = make_relay(hub.addr());
            let relay_b = make_relay(hub.addr());

            let publisher = {
                let addr = hub.addr().to_string();
                std::thread::spawn(move || {
                    let t = SocketTransport::connect_tcp(&addr);
                    for step in 1..=FINAL_STEP {
                        for member in 0..MEMBERS {
                            t.publish(plane(seed, member, step)).unwrap();
                        }
                        std::thread::sleep(Duration::from_millis(5));
                    }
                })
            };

            // readers split across the two relays; injected-fault
            // passthrough retries are tolerated (counted, not asserted)
            let tolerated = Arc::new(AtomicUsize::new(0));
            let half = RELAY_READERS / 2;
            let (log_a, log_b) = (
                run_readers(relay_a.addr(), half, &tolerated),
                run_readers(relay_b.addr(), RELAY_READERS - half, &tolerated),
            );
            publisher.join().unwrap();

            // both relays actually installed planes from upstream
            assert!(relay_a.stats().installs >= 1, "relay A never installed");
            assert!(relay_b.stats().installs >= 1, "relay B never installed");

            let mut log: Vec<String> = log_a
                .iter()
                .map(|l| format!("relay=a {l}"))
                .chain(log_b.iter().map(|l| format!("relay=b {l}")))
                .collect();
            log.sort();
            log
        };

        let first = run(seed);
        assert_eq!(first.len(), RELAY_READERS);
        for line in &first {
            let member: usize = line
                .split("member=")
                .nth(1)
                .and_then(|s| s.split_whitespace().next())
                .and_then(|s| s.parse().ok())
                .unwrap();
            assert!(
                line.ends_with(&expected_suffix(seed, member)),
                "seed {seed}: relayed reader diverged from hub bytes: {line}"
            );
        }
        let second = run(seed);
        assert_eq!(first, second, "seed {seed}: relayed soak logs diverged");
    }
}

//! Wire-level retry semantics: a server that dies mid-`DELTA` reply (or
//! closes before replying at all) must surface a *transient* error, and a
//! `Retry`-wrapped client must recover on its next attempt against the
//! healthy server — with the recovered bytes identical to a clean read.
//!
//! The tear is staged by a byte-level proxy between client and server:
//! it forwards length-prefixed frames verbatim until armed, then either
//! claims the full reply length but sends only half the payload before
//! closing (a torn frame: the client dies in `read_exact` with an
//! `UnexpectedEof`), or closes before any reply byte (a clean close: the
//! client sees "exchange server closed the connection").

use codistill::codistill::transport::{classify_error, ErrorClass};
use codistill::codistill::{
    Checkpoint, ExchangeTransport, Retry, RetryPolicy, SocketServer, SocketTransport,
};
use codistill::runtime::{Tensor, TensorMap};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// `DELTA` request opcode (the one read `SocketTransport::fetch` speaks —
/// see the wire table in `codistill::transport::socket`).
const OP_DELTA: u8 = 8;

fn read_frame(r: &mut impl Read) -> Option<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len).ok()?;
    let mut buf = vec![0u8; u32::from_le_bytes(len) as usize];
    r.read_exact(&mut buf).ok()?;
    Some(buf)
}

fn write_frame(w: &mut impl Write, payload: &[u8]) {
    w.write_all(&(payload.len() as u32).to_le_bytes()).unwrap();
    w.write_all(payload).unwrap();
    w.flush().unwrap();
}

/// Frame-aware TCP proxy: one request/response round trip per inbound
/// connection (the client's connection model), forwarded verbatim to the
/// upstream server unless a tear is armed.
struct TearProxy {
    addr: String,
    /// Tear the next `DELTA` reply mid-payload.
    tear_next_delta: Arc<AtomicBool>,
    /// Close the next connection before any reply byte.
    close_next_request: Arc<AtomicBool>,
    /// Connections torn or closed so far.
    torn: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl TearProxy {
    fn start(upstream: &str) -> Self {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let upstream = upstream.to_string();
        let tear_next_delta = Arc::new(AtomicBool::new(false));
        let close_next_request = Arc::new(AtomicBool::new(false));
        let torn = Arc::new(AtomicUsize::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let (tear, close, count, stopping) = (
            tear_next_delta.clone(),
            close_next_request.clone(),
            torn.clone(),
            stop.clone(),
        );
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stopping.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(mut client) = conn else { break };
                let Some(request) = read_frame(&mut client) else {
                    continue;
                };
                if close.swap(false, Ordering::SeqCst) {
                    // Drop the connection before any reply byte: the
                    // client reads a clean EOF where a frame was due.
                    count.fetch_add(1, Ordering::SeqCst);
                    continue;
                }
                let mut up = TcpStream::connect(&upstream).unwrap();
                write_frame(&mut up, &request);
                let Some(reply) = read_frame(&mut up) else {
                    continue;
                };
                if request.first() == Some(&OP_DELTA) && tear.swap(false, Ordering::SeqCst) {
                    // Claim the full reply, deliver half, close: the
                    // client dies mid-payload in `read_exact`.
                    let _ = client.write_all(&(reply.len() as u32).to_le_bytes());
                    let _ = client.write_all(&reply[..reply.len() / 2]);
                    count.fetch_add(1, Ordering::SeqCst);
                    continue;
                }
                write_frame(&mut client, &reply);
            }
        });
        TearProxy {
            addr,
            tear_next_delta,
            close_next_request,
            torn,
            stop,
            handle: Some(handle),
        }
    }

    fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept so the thread observes the flag.
        let _ = TcpStream::connect(&self.addr);
        self.handle.take().unwrap().join().unwrap();
    }
}

fn ckpt(member: usize, step: u64, val: f32) -> Checkpoint {
    let mut params = TensorMap::new();
    params.insert("params.w", Tensor::f32(&[4], vec![val; 4]).unwrap());
    Checkpoint::new(member, step, params)
}

#[test]
fn torn_mid_delta_reply_is_transient_and_retry_recovers() {
    let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
    let proxy = TearProxy::start(server.addr());
    let client = Arc::new(SocketTransport::connect_tcp(&proxy.addr));

    // Publish rides through the proxy untouched.
    client.publish(ckpt(0, 10, 1.5)).unwrap();

    // Bare client, torn reply: the error is an io UnexpectedEof somewhere
    // in its chain, and classifies transient — retryable, not fatal.
    proxy.tear_next_delta.store(true, Ordering::SeqCst);
    let err = client.latest(0).unwrap_err();
    assert_eq!(classify_error(&err), ErrorClass::Transient, "{err:#}");
    assert!(
        err.chain().any(|c| c
            .downcast_ref::<std::io::Error>()
            .is_some_and(|e| e.kind() == std::io::ErrorKind::UnexpectedEof)),
        "no io error in the chain: {err:#}"
    );

    // Retry-wrapped client, same tear: absorbed on the second attempt
    // against the (healthy) server, one fresh connection per attempt.
    let retry = Arc::new(Retry::wrap(client.clone(), RetryPolicy::immediate(3, 0)));
    proxy.tear_next_delta.store(true, Ordering::SeqCst);
    let ck = retry.latest(0).unwrap().expect("no checkpoint after recovery");
    assert_eq!((ck.member, ck.step), (0, 10));
    let stats = retry.stats();
    assert_eq!(
        (
            stats.ops,
            stats.transient_errors,
            stats.absorbed,
            stats.exhausted,
            stats.permanent_errors,
        ),
        (1, 1, 1, 0, 0),
        "{stats:?}"
    );
    assert_eq!(proxy.torn.load(Ordering::SeqCst), 2);

    // The recovered plane is byte-identical to a direct healthy read.
    let direct = SocketTransport::connect_tcp(server.addr());
    let want = direct.latest(0).unwrap().unwrap();
    assert_eq!((want.member, want.step), (0, 10));
    assert_eq!(
        ck.flat().view("params.w").unwrap(),
        want.flat().view("params.w").unwrap()
    );

    proxy.stop();
    drop(server);
}

#[test]
fn clean_close_before_reply_is_transient_and_recovers_too() {
    let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
    let proxy = TearProxy::start(server.addr());
    let client = Arc::new(SocketTransport::connect_tcp(&proxy.addr));
    client.publish(ckpt(3, 20, 0.25)).unwrap();

    // A close with zero reply bytes is the *clean* EOF shape — no io
    // error in the chain, classified transient by its context text.
    proxy.close_next_request.store(true, Ordering::SeqCst);
    let err = client.latest(3).unwrap_err();
    assert_eq!(classify_error(&err), ErrorClass::Transient, "{err:#}");
    assert!(
        format!("{err:#}").contains("exchange server closed the connection"),
        "{err:#}"
    );

    let retry = Retry::wrap(client.clone(), RetryPolicy::immediate(3, 0));
    proxy.close_next_request.store(true, Ordering::SeqCst);
    let ck = retry.latest(3).unwrap().expect("no checkpoint after recovery");
    assert_eq!((ck.member, ck.step), (3, 20));
    assert_eq!((retry.stats().absorbed, retry.stats().exhausted), (1, 0));

    proxy.stop();
    drop(server);
}

//! Serving-tier acceptance (`make test-serve`): the batching inference
//! server under open-loop load while a publisher lands checkpoint hot
//! swaps mid-traffic through the subscription loop.
//!
//! What is pinned here:
//!
//! * **Zero downtime, zero torn planes**: with >=3 hot swaps landing
//!   under load, every request completes, and every response re-derives
//!   *exactly* (bit-for-bit) from the retained checkpoint of the step it
//!   reports, carrying that plane's content digest — each response is
//!   consistent with exactly one installed plane, never a mix.
//! * **Deterministic churn accounting**: the swap churn log replays
//!   byte-identically across two same-seed runs, and both runs match an
//!   independent offline recomputation from the retained checkpoints
//!   (the pinned churn-across-swaps value, derived rather than
//!   hardcoded so it survives plane-layout changes honestly).
//! * **The reports exist and cohere**: p50/p99 latency quantiles and the
//!   throughput-vs-batch-size table are populated for a loaded run.
//! * The same harness passes over the spool-dir and socket transports
//!   with delta-aware subscription fetches (unchanged windows skipped).

use codistill::codistill::serve::{
    open_loop, InferenceServer, LoadRun, LoadSpec, OpenLoopSpec, ServeConfig,
};
use codistill::codistill::{
    Checkpoint, ExchangeTransport, InProcess, Member, ServeStats, SocketServer, SocketTransport,
    SpoolDir, SubscribeConfig, SubscribeStats, Subscription,
};
use codistill::metrics::{mean_abs_diff, ChurnReport};
use codistill::models::MockForward;
use codistill::runtime::flat::content_digest;
use codistill::testkit::DriftMember;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

const PROBE_LEN: u64 = 32;

fn wait_until(what: &str, cond: impl Fn() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "timed out waiting for {what}"
        );
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Train 5 drift steps, publish the snapshot, retain an identical copy
/// for offline auditing, and wait for the subscription to install it —
/// the gate makes every publication a distinct install, so the swap
/// sequence is deterministic regardless of scheduling.
fn publish_gated(
    t: &dyn ExchangeTransport,
    server: &InferenceServer,
    member: &mut DriftMember,
    retained: &mut BTreeMap<u64, Arc<Checkpoint>>,
) {
    for _ in 0..5 {
        member.train_step(0.0, 0.1).unwrap();
    }
    let keep = Arc::new(member.snapshot().unwrap());
    let step = keep.step;
    t.publish(member.snapshot().unwrap()).unwrap();
    retained.insert(step, keep);
    wait_until("checkpoint install", || server.installed_step() == Some(step));
}

struct Harness {
    load: LoadSpec,
    run: LoadRun,
    /// Publisher-side copies of every published checkpoint, by step.
    retained: BTreeMap<u64, Arc<Checkpoint>>,
    churn: ChurnReport,
    churn_log: String,
    swaps: u64,
    stats: ServeStats,
    sub_stats: SubscribeStats,
}

/// Publisher + subscription + open-loop load over a transport pair
/// (`publish_t` writes, `subscribe_t` reads — the same handle for
/// in-process, distinct handles for real media). The first of
/// `publishes` checkpoints installs before traffic opens; the remaining
/// `publishes - 1` hot-swap mid-traffic.
fn run_harness(
    publish_t: Arc<dyn ExchangeTransport>,
    subscribe_t: Arc<dyn ExchangeTransport>,
    seed: u64,
    requests: u64,
    rps: f64,
    publishes: usize,
) -> Harness {
    let server = Arc::new(InferenceServer::start(
        Arc::new(MockForward::new()),
        ServeConfig {
            max_batch_items: 24,
            max_delay: Duration::from_millis(1),
            workers: 2,
            probe: (0..PROBE_LEN).collect(),
        },
    ));
    let mut sub = Subscription::spawn(
        subscribe_t,
        SubscribeConfig {
            poll_interval: Duration::from_millis(1),
            ..SubscribeConfig::default()
        },
        {
            let server = server.clone();
            move |ck| server.install(ck)
        },
    );

    let mut member = DriftMember::with_frozen(0, 64);
    let mut retained = BTreeMap::new();
    publish_gated(publish_t.as_ref(), &server, &mut member, &mut retained);

    let load = LoadSpec {
        requests,
        seed,
        min_features: 1,
        max_features: 6,
    };
    let lg = std::thread::spawn({
        let server = server.clone();
        let spec = OpenLoopSpec { load, rps };
        move || open_loop(&server, &spec)
    });
    for _ in 1..publishes {
        std::thread::sleep(Duration::from_millis(5));
        publish_gated(publish_t.as_ref(), &server, &mut member, &mut retained);
    }
    let run = lg.join().expect("load generator panicked");

    sub.stop();
    let sub_stats = sub.stats();
    let swaps = server.swaps();
    let (churn, churn_log) = server.churn();
    let stats = server.stats();
    server.shutdown();
    Harness {
        load,
        run,
        retained,
        churn,
        churn_log,
        swaps,
        stats,
        sub_stats,
    }
}

/// The torn-plane audit: regenerate the seeded request sequence offline
/// and re-derive every response from the retained checkpoint of its
/// reported step. An exact match on both the probabilities and the
/// plane content digest means the response came from exactly one
/// installed plane.
fn audit(h: &Harness) {
    assert_eq!(h.run.report.failed, 0, "errors: {:?}", h.run.errors);
    assert_eq!(h.run.report.ok, h.load.requests);
    let requests = h.load.open_loop_requests();
    let fwd = MockForward::new();
    for resp in &h.run.responses {
        let ck = h
            .retained
            .get(&resp.step)
            .unwrap_or_else(|| panic!("response claims never-published step {}", resp.step));
        assert_eq!(
            resp.plane_digest,
            content_digest(ck.flat().data()),
            "torn/corrupt plane digest on request {} (step {})",
            resp.id,
            resp.step
        );
        let expect = fwd.probs(ck, &requests[resp.id as usize]).unwrap();
        assert_eq!(
            resp.probs, expect,
            "request {} diverged from the step-{} plane",
            resp.id, resp.step
        );
    }
}

/// Recompute the entire churn log offline from the retained checkpoints
/// — same probe set, same format string — the value the server's log
/// must pin against.
fn expected_churn_log(retained: &BTreeMap<u64, Arc<Checkpoint>>) -> (String, Vec<f64>) {
    let fwd = MockForward::new();
    let probe: Vec<u64> = (0..PROBE_LEN).collect();
    let planes: Vec<&Arc<Checkpoint>> = retained.values().collect();
    let mut log = String::new();
    let mut samples = Vec::new();
    for (i, pair) in planes.windows(2).enumerate() {
        let (a, b) = (pair[0], pair[1]);
        let churn = mean_abs_diff(
            &fwd.probs(a, &probe).unwrap(),
            &fwd.probs(b, &probe).unwrap(),
        )
        .unwrap();
        log.push_str(&format!(
            "swap {}: step {} -> {} plane {:016x} -> {:016x} churn {:.9e}\n",
            i + 1,
            a.step,
            b.step,
            content_digest(a.flat().data()),
            content_digest(b.flat().data()),
            churn
        ));
        samples.push(churn);
    }
    (log, samples)
}

#[test]
fn hot_swaps_under_open_loop_load_leave_zero_torn_requests() {
    let t: Arc<dyn ExchangeTransport> = Arc::new(InProcess::new(8));
    let h = run_harness(t.clone(), t, 42, 3000, 15_000.0, 5);

    assert!(h.swaps >= 3, "need >=3 mid-traffic hot swaps, got {}", h.swaps);
    assert_eq!(h.sub_stats.installs, 5);
    audit(&h);

    // latency quantiles are populated and ordered for the loaded run
    assert_eq!(h.run.report.latency.count(), 3000);
    let (p50, p99, p999) = (
        h.run.report.latency.p50_s(),
        h.run.report.latency.p99_s(),
        h.run.report.latency.p999_s(),
    );
    assert!(p50 > 0.0 && p50 <= p99 && p99 <= p999, "{p50} {p99} {p999}");
    assert!(h.run.report.goodput() > 0.0);

    // throughput-vs-batch-size table exists and accounts for every request
    assert!(!h.stats.throughput.is_empty());
    let reqs: u64 = h
        .stats
        .throughput
        .iter()
        .map(|b| b.batches * b.batch_requests as u64)
        .sum();
    assert_eq!(reqs, 3000);
    assert_eq!(h.stats.served, 3000);
    assert_eq!(h.stats.failed, 0);
    for line in h.stats.throughput_lines("serve") {
        assert!(line.contains("items/s"), "{line}");
    }
}

#[test]
fn churn_log_replays_byte_identically_and_matches_recomputation() {
    let mk = || {
        let t: Arc<dyn ExchangeTransport> = Arc::new(InProcess::new(8));
        run_harness(t.clone(), t, 7, 600, 10_000.0, 4)
    };
    let (a, b) = (mk(), mk());

    assert_eq!(a.churn_log.lines().count(), 3, "{}", a.churn_log);
    assert_eq!(
        a.churn_log, b.churn_log,
        "same-seed runs must replay the churn log byte-identically"
    );

    // ...and the log pins against an independent offline recomputation
    // from the retained checkpoints: sequence, digests, and churn values.
    let (expect_log, expect_samples) = expected_churn_log(&a.retained);
    assert_eq!(a.churn_log, expect_log);
    assert_eq!(a.churn.samples, expect_samples);
    assert_eq!(b.churn.samples, expect_samples);
    assert!(a.churn.mean() > 0.0, "drifting planes must move predictions");
    assert!(a.churn.half_range() >= 0.0);
    audit(&a);
    audit(&b);
}

#[test]
fn serving_over_a_spool_dir_subscription() {
    let dir = std::env::temp_dir().join(format!("serve_spool_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    // distinct handles: the publisher's in-memory cache cannot serve the
    // subscriber's reads — fetches pay the real file path
    let publisher: Arc<dyn ExchangeTransport> = Arc::new(SpoolDir::open(&dir, 8).unwrap());
    let reader: Arc<dyn ExchangeTransport> = Arc::new(SpoolDir::open(&dir, 8).unwrap());
    let h = run_harness(publisher, reader, 11, 800, 10_000.0, 4);
    assert!(h.swaps >= 3, "got {} swaps", h.swaps);
    audit(&h);
    // the subscription's steady-state fetches were deltas that skipped
    // the frozen (never-changing) windows
    assert!(h.sub_stats.delta.delta_fetches >= 1, "{:?}", h.sub_stats.delta);
    assert!(h.sub_stats.delta.windows_unchanged > 0, "{:?}", h.sub_stats.delta);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serving_over_a_socket_subscription() {
    let hub = SocketServer::bind_tcp_with("127.0.0.1:0", 8, 4).unwrap();
    let publisher: Arc<dyn ExchangeTransport> =
        Arc::new(SocketTransport::connect_tcp(hub.addr()));
    let reader: Arc<dyn ExchangeTransport> = Arc::new(SocketTransport::connect_tcp(hub.addr()));
    let h = run_harness(publisher, reader, 23, 800, 10_000.0, 4);
    assert!(h.swaps >= 3, "got {} swaps", h.swaps);
    audit(&h);
    assert!(h.sub_stats.delta.delta_fetches >= 1, "{:?}", h.sub_stats.delta);
    assert_eq!(h.sub_stats.tolerated_errors, 0);
    drop(hub);
}

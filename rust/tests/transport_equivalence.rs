//! Transport equivalence: the checkpoint exchange is a pluggable medium,
//! so the same orchestrated run (fixed seed, deterministic members) must
//! produce identical results whether checkpoints move through the
//! in-process store, CKPT0003 files in a shared spool directory, or the
//! socket wire protocol — including the sharded (windowed) socket fetch
//! and the incremental (delta) read path, which must install teacher
//! planes byte-identical to full fetches while moving fewer bytes.
//!
//! The members here are mocks whose dynamics *depend on the teacher
//! parameter values* (not just their steps), so any transport that
//! corrupted, reordered, or re-rounded a single plane byte would diverge
//! the eval curves.

use codistill::codistill::transport::spool::spool_file_name;
use codistill::codistill::transport::DeltaCache;
use codistill::codistill::{
    Checkpoint, Codec, DistillSchedule, EvalStats, ExchangeTransport, FaultPlan, Faulty,
    InProcess, LrSchedule, Member, Orchestrator, OrchestratorConfig, RunLog, SocketServer,
    SocketTransport, SpoolDir, StepStats, Topology,
};
use codistill::runtime::flat::{content_digest, FlatBuffer, FlatLayout};
use codistill::runtime::{Tensor, TensorMap};
use std::path::PathBuf;
use std::sync::Arc;

const W: usize = 4;

/// Deterministic member: parameters drift by an id/step-dependent pattern
/// and are pulled toward the mean of the *installed teachers' values*.
struct PullMember {
    id: usize,
    step: u64,
    params: TensorMap,
    teacher_mean: Option<Vec<f32>>,
}

impl PullMember {
    fn new(id: usize) -> Self {
        let init: Vec<f32> = (0..W).map(|k| (id as f32) + 0.25 * k as f32).collect();
        let mut params = TensorMap::new();
        params.insert("params.w", Tensor::f32(&[W], init).unwrap());
        // A window training never touches: its digest is identical across
        // publications, so delta runs must skip it every reload.
        params.insert("params.frozen", Tensor::f32(&[8], vec![3.25; 8]).unwrap());
        PullMember {
            id,
            step: 0,
            params,
            teacher_mean: None,
        }
    }

    fn w(&self) -> Vec<f32> {
        self.params
            .get("params.w")
            .unwrap()
            .as_f32()
            .unwrap()
            .to_vec()
    }
}

impl Member for PullMember {
    fn train_step(&mut self, distill_w: f32, lr: f32) -> anyhow::Result<StepStats> {
        let drift = ((self.step + self.id as u64) % 7) as f32 * 0.125 - 0.375;
        let teacher = self.teacher_mean.clone();
        let w = self.params.get_mut("params.w")?.as_f32_mut()?;
        let mut distill_loss = 0.0f32;
        for (k, v) in w.iter_mut().enumerate() {
            *v += lr * drift * (1.0 + 0.5 * k as f32);
            if distill_w > 0.0 {
                if let Some(t) = &teacher {
                    let pull = t[k] - *v;
                    *v += distill_w * lr * pull;
                    distill_loss += pull * pull;
                }
            }
        }
        self.step += 1;
        let loss = w.iter().map(|v| v.abs()).sum::<f32>() / W as f32;
        Ok(StepStats {
            step: self.step,
            loss,
            distill_loss,
        })
    }

    fn snapshot(&self) -> anyhow::Result<Checkpoint> {
        Ok(Checkpoint::new(self.id, self.step, self.params.clone()))
    }

    fn set_teachers(&mut self, peers: Vec<Arc<Checkpoint>>) -> anyhow::Result<()> {
        let mut mean = vec![0.0f32; W];
        for p in &peers {
            let w = p.flat().view("params.w")?;
            for (m, v) in mean.iter_mut().zip(w) {
                *m += *v;
            }
        }
        for m in &mut mean {
            *m /= peers.len() as f32;
        }
        self.teacher_mean = Some(mean);
        Ok(())
    }

    fn evaluate(&mut self) -> anyhow::Result<EvalStats> {
        let loss = self.w().iter().map(|v| v.abs() as f64).sum::<f64>();
        Ok(EvalStats {
            loss,
            accuracy: None,
        })
    }

    fn steps_done(&self) -> u64 {
        self.step
    }

    fn params(&self) -> &TensorMap {
        &self.params
    }
}

fn cfg() -> OrchestratorConfig {
    OrchestratorConfig {
        total_steps: 40,
        reload_interval: 10,
        extra_staleness: 0,
        eval_every: 10,
        distill: DistillSchedule::new(5, 5, 1.0),
        lr: LrSchedule::Constant(0.25),
        topology: Topology::FullyConnected,
        cluster: None,
        seed: 3,
        delta: false,
        publish_codec: Codec::Raw,
        error_feedback: false,
        verbose: false,
    }
}

fn cfg_delta() -> OrchestratorConfig {
    OrchestratorConfig {
        delta: true,
        ..cfg()
    }
}

fn run_over_cfg(cfg: OrchestratorConfig, transport: Arc<dyn ExchangeTransport>) -> RunLog {
    let mut members: Vec<Box<dyn Member>> = (0..3)
        .map(|i| Box::new(PullMember::new(i)) as Box<dyn Member>)
        .collect();
    Orchestrator::with_transport(cfg, transport)
        .run(&mut members)
        .unwrap()
}

fn run_over(transport: Arc<dyn ExchangeTransport>) -> RunLog {
    run_over_cfg(cfg(), transport)
}

fn tdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("codistill_eqv_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// Exact equality of everything a RunLog records about the exchange.
fn assert_logs_identical(tag: &str, a: &RunLog, b: &RunLog) {
    assert_eq!(a.staleness, b.staleness, "{tag}: staleness diverged");
    assert_eq!(a.eval.len(), b.eval.len(), "{tag}");
    for (i, (ca, cb)) in a.eval.iter().zip(&b.eval).enumerate() {
        assert_eq!(ca.len(), cb.len(), "{tag}: member {i} curve length");
        for (pa, pb) in ca.iter().zip(cb) {
            assert_eq!(pa.step, pb.step, "{tag}: member {i}");
            assert_eq!(pa.loss, pb.loss, "{tag}: member {i} step {}", pa.step);
        }
    }
    assert_eq!(a.train.len(), b.train.len(), "{tag}");
    for (ta, tb) in a.train.iter().zip(&b.train) {
        assert_eq!(ta, tb, "{tag}: train records diverged");
    }
}

#[test]
fn same_run_identical_over_all_transports() {
    let reference = run_over(Arc::new(InProcess::new(8)));
    assert!(
        !reference.staleness.is_empty(),
        "fixture never exchanged teachers"
    );

    // spool directory (fresh tempdir)
    let dir = tdir("spool");
    let spool = run_over(Arc::new(SpoolDir::open(&dir, 8).unwrap()));
    assert_logs_identical("spool", &reference, &spool);
    std::fs::remove_dir_all(&dir).ok();

    // socket, full-plane fetches
    let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
    let socket = run_over(Arc::new(SocketTransport::connect_tcp(server.addr())));
    assert_logs_identical("socket", &reference, &socket);
    drop(server);

    // socket, sharded: reloads reassemble the plane window by window
    let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
    let windowed = run_over(Arc::new(
        SocketTransport::connect_tcp(server.addr()).with_windowed_fetch(1),
    ));
    assert_logs_identical("socket-windowed", &reference, &windowed);
}

#[test]
fn spool_two_endpoints_byte_identical_to_inproc() {
    // Two SpoolDir handles on one directory model two coordinator
    // processes: A publishes, B reads, and the bytes B sees must equal
    // what an in-process exchange of the same checkpoint yields.
    let dir = tdir("two_endpoints");
    let a = SpoolDir::open(&dir, 4).unwrap();
    let b = SpoolDir::open(&dir, 4).unwrap();
    let inproc = InProcess::new(4);

    let member = PullMember::new(1);
    let ck = member.snapshot().unwrap();
    inproc.publish(ck.clone()).unwrap();
    a.publish(ck).unwrap();

    let via_spool = b.latest(1).unwrap().unwrap();
    let via_mem = InProcess::latest(&inproc, 1).unwrap();
    assert_eq!(via_spool.step, via_mem.step);
    assert_eq!(
        via_spool.flat().data(),
        via_mem.flat().data(),
        "spool roundtrip changed plane bytes"
    );
    assert!(via_spool
        .flat()
        .layout()
        .same_plane(via_mem.flat().layout()));

    // the windowed pread path is byte-identical too
    let fetch = b
        .fetch_windows(1, u64::MAX, &["params.w".to_string()])
        .unwrap()
        .unwrap();
    assert_eq!(
        fetch.windows[0].to_f32().unwrap(),
        via_mem.flat().view("params.w").unwrap()
    );

    // and the on-disk artifact is the canonical zero-padded CKPT0002 file
    assert!(dir.join(spool_file_name(1, 0)).exists());
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------- error paths
//
// Corrupt or vanished exchange state must surface `Err` (or a documented
// recovery) — never a panic, never a hang. One table per backend.

fn raw_ckpt(member: usize, step: u64) -> Checkpoint {
    let mut params = TensorMap::new();
    params.insert("params.w", Tensor::f32(&[W], vec![1.5; W]).unwrap());
    Checkpoint::new(member, step, params)
}

#[test]
fn inproc_error_paths_surface_err() {
    let store = InProcess::new(4);
    store.publish(raw_ckpt(0, 10)).unwrap();
    let cases: Vec<(&str, anyhow::Result<()>)> = vec![
        ("step regression", store.publish(raw_ckpt(0, 5))),
        (
            "unknown window",
            ExchangeTransport::fetch_windows(&store, 0, u64::MAX, &["params.nope".to_string()])
                .map(|_| ()),
        ),
    ];
    for (name, result) in cases {
        assert!(result.is_err(), "inproc {name}: expected Err");
    }
    // absent members are a clean None, not an error
    assert!(store.latest(9).is_none());
    assert!(ExchangeTransport::fetch_windows(&store, 9, u64::MAX, &[])
        .unwrap()
        .is_none());
}

#[test]
fn spool_error_paths_surface_err() {
    fn truncate_ckpt(dir: &std::path::Path) {
        let p = dir.join(spool_file_name(0, 5));
        let data = std::fs::read(&p).unwrap();
        std::fs::write(&p, &data[..24]).unwrap();
    }
    fn bad_magic_ckpt(dir: &std::path::Path) {
        std::fs::write(dir.join(spool_file_name(0, 5)), b"XXPT9999 not a checkpoint").unwrap();
    }
    fn scribble_manifest(dir: &std::path::Path) {
        std::fs::write(dir.join("MANIFEST"), "%% not a manifest %%\n\x00\x01").unwrap();
    }

    // (name, corruption, expect Err from a fresh reader)
    let cases: Vec<(&str, fn(&std::path::Path), bool)> = vec![
        ("truncated CKPT0002 payload", truncate_ckpt, true),
        ("bad checkpoint magic", bad_magic_ckpt, true),
        // a corrupt manifest alone is recoverable: readers fall back to
        // the zero-padded directory scan
        ("corrupt MANIFEST only", scribble_manifest, false),
        (
            "corrupt MANIFEST and truncated payload",
            |dir| {
                scribble_manifest(dir);
                truncate_ckpt(dir);
            },
            true,
        ),
    ];
    for (i, (name, corrupt, expect_err)) in cases.into_iter().enumerate() {
        let dir = tdir(&format!("spool_err_{i}"));
        let writer = SpoolDir::open(&dir, 4).unwrap();
        writer.publish(raw_ckpt(0, 5)).unwrap();
        corrupt(&dir);
        // fresh handle: no read cache to mask the corruption
        let reader = SpoolDir::open(&dir, 4).unwrap();
        let latest = reader.latest(0);
        let windows = reader.fetch_windows(0, u64::MAX, &["params.w".to_string()]);
        if expect_err {
            assert!(latest.is_err(), "spool {name}: latest should Err");
            assert!(windows.is_err(), "spool {name}: fetch_windows should Err");
        } else {
            assert_eq!(
                latest.unwrap().expect("recovery lost the checkpoint").step,
                5,
                "spool {name}"
            );
            assert_eq!(
                windows.unwrap().unwrap().windows[0].to_f32().unwrap(),
                vec![1.5; W]
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn socket_error_paths_surface_err_not_hang() {
    use std::io::{Read, Write};
    use std::net::TcpListener;

    // dead server: every operation is a prompt Err, never a hang
    let gone_addr = {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        server.addr().to_string()
    };
    // server mid-DESCRIBE shutdown: accepts, reads the request length,
    // then disappears before answering
    let quitter = TcpListener::bind("127.0.0.1:0").unwrap();
    let quitter_addr = quitter.local_addr().unwrap().to_string();
    let quitter_thread = std::thread::spawn(move || {
        let (mut s, _) = quitter.accept().unwrap();
        let mut len = [0u8; 4];
        s.read_exact(&mut len).ok();
    });
    // protocol-corrupting server: answers any request with a bogus status
    let garbler = TcpListener::bind("127.0.0.1:0").unwrap();
    let garbler_addr = garbler.local_addr().unwrap().to_string();
    let garbler_thread = std::thread::spawn(move || {
        let (mut s, _) = garbler.accept().unwrap();
        let mut len = [0u8; 4];
        s.read_exact(&mut len).unwrap();
        let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
        s.read_exact(&mut body).unwrap();
        s.write_all(&1u32.to_le_bytes()).unwrap();
        s.write_all(&[0xEE]).unwrap();
    });

    let cases: Vec<(&str, anyhow::Result<()>)> = vec![
        (
            "connect to a dead server",
            SocketTransport::connect_tcp(&gone_addr).latest(0).map(|_| ()),
        ),
        (
            "server shutdown mid-DESCRIBE",
            SocketTransport::connect_tcp(&quitter_addr)
                .with_windowed_fetch(2)
                .latest(0)
                .map(|_| ()),
        ),
        (
            "corrupt response status",
            SocketTransport::connect_tcp(&garbler_addr).members().map(|_| ()),
        ),
    ];
    for (name, result) in cases {
        assert!(result.is_err(), "socket {name}: expected Err");
    }
    quitter_thread.join().unwrap();
    garbler_thread.join().unwrap();
}

// ------------------------------------------------------ delta equivalence
//
// Incremental (delta) exchange must be invisible to the run: installed
// teacher planes are byte-identical to full fetches on every backend —
// including through fault injection — while strictly fewer payload bytes
// move whenever part of the plane is unchanged.

/// A two-window checkpoint where `params.hot` changes per step and
/// `params.cold` never does.
fn hot_cold_ckpt(member: usize, step: u64, hot: f32) -> Checkpoint {
    let mut params = TensorMap::new();
    params.insert("params.hot", Tensor::f32(&[W], vec![hot; W]).unwrap());
    params.insert("params.cold", Tensor::f32(&[W], vec![7.5; W]).unwrap());
    Checkpoint::new(member, step, params)
}

#[test]
fn delta_installs_byte_identical_on_all_backends() {
    let dir = tdir("delta_backends");
    let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
    let server_windowed = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
    let backends: Vec<(&str, Arc<dyn ExchangeTransport>)> = vec![
        ("inproc", Arc::new(InProcess::new(8))),
        ("spool", Arc::new(SpoolDir::open(&dir, 8).unwrap())),
        ("socket", Arc::new(SocketTransport::connect_tcp(server.addr()))),
        (
            "socket-windowed",
            Arc::new(SocketTransport::connect_tcp(server_windowed.addr()).with_windowed_fetch(1)),
        ),
    ];
    for (tag, transport) in &backends {
        let mut cache = DeltaCache::new();
        for (i, step) in [1u64, 5, 9].into_iter().enumerate() {
            transport.publish(hot_cold_ckpt(0, step, i as f32)).unwrap();
            let got = cache.latest(transport.as_ref(), 0).unwrap().unwrap();
            let full = transport.latest(0).unwrap().unwrap();
            assert_eq!(got.step, full.step, "{tag}");
            assert_eq!(
                got.flat().data(),
                full.flat().data(),
                "{tag}: delta install diverged from full fetch"
            );
            assert!(got.flat().layout().same_plane(full.flat().layout()), "{tag}");
        }
        let stats = cache.stats();
        assert_eq!(stats.full_fetches, 1, "{tag}");
        assert_eq!(stats.delta_fetches, 2, "{tag}");
        assert_eq!(
            stats.windows_unchanged, 2,
            "{tag}: params.cold not skipped on both deltas"
        );
        // 1 full (2 windows) + 2 deltas (1 window each): strictly fewer
        // payload bytes than three full fetches
        let full_bytes = 3 * (2 * W as u64 * 4);
        assert_eq!(stats.payload_bytes, (2 + 1 + 1) * W as u64 * 4, "{tag}");
        assert!(stats.payload_bytes < full_bytes, "{tag}");
    }
    drop(backends);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn delta_installs_byte_identical_through_faults() {
    // Stale reads on every fetch: the cache installs the *previous*
    // publication each time, and its bytes must equal a direct read of
    // whatever step was served.
    let store = Arc::new(InProcess::new(8));
    let faulty = Faulty::wrap(store.clone(), FaultPlan::new(11).with_stale_reads(1.0));
    let mut cache = DeltaCache::new();
    for (i, step) in [1u64, 5, 9, 13].into_iter().enumerate() {
        faulty.publish(hot_cold_ckpt(0, step, i as f32)).unwrap();
        let got = cache.latest(&faulty, 0).unwrap().unwrap();
        let direct = InProcess::latest_at_most(&store, 0, got.step).unwrap();
        assert_eq!(got.step, direct.step);
        assert_eq!(
            got.flat().data(),
            direct.flat().data(),
            "stale delta install diverged from the served step"
        );
    }
    assert!(cache.stats().delta_fetches >= 2);
    assert!(cache.stats().windows_unchanged >= 2, "cold window moved");

    // Dropped fetches: a drop leaves the installed plane untouched, and
    // the next successful fetch catches it up byte-identically.
    let store = Arc::new(InProcess::new(8));
    let faulty = Faulty::wrap(store.clone(), FaultPlan::new(12).with_dropped_fetches(0.4));
    let mut cache = DeltaCache::new();
    let mut installed = 0usize;
    for (i, step) in (0..24u64).enumerate() {
        faulty.publish(hot_cold_ckpt(0, step, i as f32)).unwrap();
        match cache.latest(&faulty, 0).unwrap() {
            Some(got) => {
                installed += 1;
                let direct = InProcess::latest_at_most(&store, 0, got.step).unwrap();
                assert_eq!(got.flat().data(), direct.flat().data());
            }
            None => {} // dropped: train on with the old teachers
        }
    }
    assert!(installed > 0 && installed < 24, "drop plan degenerate");
}

#[test]
fn delta_install_rejects_corrupt_spool_payload() {
    // A payload byte flipped on disk after publish: a full load fails
    // the CKPT0003 digest verify; the delta pread path must fail the
    // install-side verify instead of silently poisoning the basis (the
    // stored digest table predates the corruption, so a poisoned basis
    // would mark the window "unchanged" forever after).
    let dir = tdir("delta_corrupt");
    let spool = SpoolDir::open(&dir, 8).unwrap();
    spool.publish(hot_cold_ckpt(0, 1, 1.0)).unwrap();
    let mut cache = DeltaCache::new();
    cache.latest(&spool, 0).unwrap().unwrap();
    spool.publish(hot_cold_ckpt(0, 2, 2.0)).unwrap();
    // flip a bit in params.hot's payload — the windows sort as
    // [params.cold, params.hot], so hot's last f32 ends right before the
    // trailing 8-byte residual count
    let path = dir.join(spool_file_name(0, 2));
    let mut raw = std::fs::read(&path).unwrap();
    let n = raw.len();
    raw[n - 8 - 1] ^= 0x40;
    std::fs::write(&path, &raw).unwrap();
    // fresh handle: no read cache; basis from step 1 forces a delta pread
    let reader = SpoolDir::open(&dir, 8).unwrap();
    let err = cache.latest(&reader, 0).unwrap_err();
    assert!(
        format!("{err:#}").contains("corrupt delta payload"),
        "{err:#}"
    );
    // and the full-load path reports the same corruption loudly
    assert!(SpoolDir::open(&dir, 8).unwrap().latest(0).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn equal_step_republish_refreshes_manifest_digests() {
    // A crash-restart can republish the same (member, step) with
    // different bytes; the manifest's digest column must track the new
    // file, not the remembered one.
    let dir = tdir("delta_republish");
    let spool = SpoolDir::open(&dir, 8).unwrap();
    spool.publish(hot_cold_ckpt(0, 5, 1.0)).unwrap();
    let first = spool.latest(0).unwrap().unwrap().window_digests().as_ref().clone();
    let mut republished = TensorMap::new();
    republished.insert("params.hot", Tensor::f32(&[W], vec![9.0; W]).unwrap());
    republished.insert("params.cold", Tensor::f32(&[W], vec![7.5; W]).unwrap());
    spool.publish(Checkpoint::new(0, 5, republished)).unwrap();
    // the MANIFEST digest column must describe the NEW file (write_manifest
    // must not reuse the remembered column for the step it just overwrote)
    let new_digests = spool
        .latest(0)
        .unwrap()
        .unwrap()
        .window_digests()
        .as_ref()
        .clone();
    assert_ne!(new_digests, first, "republished bytes identical?");
    let text = std::fs::read_to_string(dir.join("MANIFEST")).unwrap();
    let line = text.lines().find(|l| l.starts_with("0 5 ")).unwrap();
    let cols: Vec<u64> = line
        .split_whitespace()
        .skip(3)
        .map(|h| u64::from_str_radix(h, 16).unwrap())
        .collect();
    assert_eq!(cols, new_digests, "manifest kept stale digests for the republished step");
    // and a fresh reader's delta fetch against the OLD digests must move
    // the changed window
    let reader = SpoolDir::open(&dir, 8).unwrap();
    let res = reader
        .fetch(
            &codistill::codistill::FetchSpec::full(0, u64::MAX).with_basis(
                codistill::codistill::Basis {
                    step: 5,
                    digests: first,
                },
            ),
        )
        .unwrap()
        .unwrap();
    assert_eq!(res.windows.len(), 1, "republished window not re-fetched");
    assert_eq!(res.windows[0].name, "params.hot");
    assert_eq!(res.windows[0].to_f32().unwrap(), vec![9.0; W]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn delta_orchestrated_runs_identical_over_all_transports() {
    let reference = run_over(Arc::new(InProcess::new(8)));
    assert!(reference.delta.is_none());

    // inproc, delta
    let delta_inproc = run_over_cfg(cfg_delta(), Arc::new(InProcess::new(8)));
    assert_logs_identical("delta-inproc", &reference, &delta_inproc);
    let stats = delta_inproc.delta.expect("delta accounting missing");
    assert!(
        stats.windows_unchanged > 0,
        "frozen window was never skipped: {stats:?}"
    );
    assert!(stats.delta_fetches > 0);

    // spool, delta
    let dir = tdir("delta_spool_run");
    let delta_spool = run_over_cfg(cfg_delta(), Arc::new(SpoolDir::open(&dir, 8).unwrap()));
    assert_logs_identical("delta-spool", &reference, &delta_spool);
    assert!(delta_spool.delta.unwrap().windows_unchanged > 0);
    std::fs::remove_dir_all(&dir).ok();

    // socket, delta
    let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
    let delta_socket = run_over_cfg(
        cfg_delta(),
        Arc::new(SocketTransport::connect_tcp(server.addr())),
    );
    assert_logs_identical("delta-socket", &reference, &delta_socket);
    assert!(delta_socket.delta.unwrap().windows_unchanged > 0);
    drop(server);

    // the same seeded fault plan must fault the delta run identically:
    // one read per (member, teacher) reload in both modes
    let plan = |seed| FaultPlan::new(seed).with_stale_reads(0.5);
    let faulted = run_over(Arc::new(Faulty::wrap(
        Arc::new(InProcess::new(8)),
        plan(21),
    )));
    let faulted_delta = run_over_cfg(
        cfg_delta(),
        Arc::new(Faulty::wrap(Arc::new(InProcess::new(8)), plan(21))),
    );
    assert_logs_identical("delta-faulty", &faulted, &faulted_delta);
}

#[test]
fn digest_equality_iff_byte_equality_on_flat_windows() {
    use codistill::testkit::{forall, in_range};
    // Over random window contents: equal bytes <=> equal digests, and a
    // single-element perturbation (which FNV-1a can never cancel) always
    // flips the digest.
    forall::<(u64, u64, u64)>("digest <=> bytes", 0xD16E57, 128, |&(len_raw, pos_raw, bits)| {
        let len = in_range(len_raw, 1, 64);
        let mut rng_vals: Vec<f32> = (0..len)
            .map(|i| {
                f32::from_bits((bits as u32) ^ (i as u32).wrapping_mul(2_654_435_769))
            })
            .map(|v| if v.is_nan() { 1.25 } else { v })
            .collect();
        let layout = Arc::new(FlatLayout::from_named_shapes(vec![(
            "params.w".to_string(),
            vec![len],
        )]));
        let original = FlatBuffer::from_data(layout.clone(), rng_vals.clone()).unwrap();

        // identical bytes => identical digest
        let copy = FlatBuffer::from_data(layout.clone(), rng_vals.clone()).unwrap();
        if original.window_digests() != copy.window_digests() {
            return false;
        }
        if content_digest(original.view("params.w").unwrap())
            != original.window_digests()[0]
        {
            return false;
        }

        // a one-element bit flip => different bytes => different digest
        let pos = in_range(pos_raw, 0, len - 1);
        let flipped = f32::from_bits(rng_vals[pos].to_bits() ^ 1);
        if flipped.to_bits() == rng_vals[pos].to_bits() {
            return false; // unreachable: xor 1 always changes the bits
        }
        rng_vals[pos] = flipped;
        let changed = FlatBuffer::from_data(layout, rng_vals).unwrap();
        changed.window_digests() != original.window_digests()
    });
}

#[test]
fn socket_windowed_fetch_byte_identical_to_inproc() {
    let inproc = InProcess::new(4);
    let member = PullMember::new(2);
    let ck = member.snapshot().unwrap();
    inproc.publish(ck.clone()).unwrap();

    let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
    let publisher = SocketTransport::connect_tcp(server.addr());
    publisher.publish(ck).unwrap();

    let reader = SocketTransport::connect_tcp(server.addr()).with_windowed_fetch(1);
    let via_socket = reader.latest(2).unwrap().unwrap();
    let via_mem = InProcess::latest(&inproc, 2).unwrap();
    assert_eq!(
        via_socket.flat().data(),
        via_mem.flat().data(),
        "windowed socket reassembly changed plane bytes"
    );
    assert!(via_socket
        .flat()
        .layout()
        .same_plane(via_mem.flat().layout()));

    let fetch = reader
        .fetch_windows(2, u64::MAX, &["params.w".to_string()])
        .unwrap()
        .unwrap();
    assert_eq!(
        fetch.windows[0].to_f32().unwrap(),
        via_mem.flat().view("params.w").unwrap()
    );
    assert_eq!(fetch.payload_bytes(), (W * 4) as u64);
}

// ------------------------------------------------------ codec equivalence
//
// Compressed window payloads must be invisible to the run: a codec-on
// reader installs planes byte-identical to a codec-off reader on every
// backend (including through fault injection), while moving no MORE
// payload bytes — and strictly fewer whenever the encoder pays off.

#[test]
fn codec_on_installs_byte_identical_to_codec_off() {
    // hot windows here are constant-valued, so the shuffle+RLE codec
    // always engages; cold windows are digest-skipped by the delta
    let dir_raw = tdir("codec_off_spool");
    let dir_enc = tdir("codec_on_spool");
    let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();

    // (tag, codec-off pair, codec-on pair). Each pair is (transport,
    // cache): spool encodes at the publisher (CKPT0004 files), socket at
    // the capability-negotiating client, inproc/faulty at the
    // codec-advertising cache (spec-level negotiation).
    struct Case {
        tag: &'static str,
        raw_t: Arc<dyn ExchangeTransport>,
        enc_t: Arc<dyn ExchangeTransport>,
        enc_cache_codec: Option<Codec>,
        shared_store: bool,
    }
    let cases = vec![
        Case {
            tag: "inproc",
            raw_t: Arc::new(InProcess::new(8)),
            enc_t: Arc::new(InProcess::new(8)),
            enc_cache_codec: Some(Codec::Shuffle),
            shared_store: false,
        },
        Case {
            tag: "spool",
            raw_t: Arc::new(SpoolDir::open(&dir_raw, 8).unwrap()),
            enc_t: Arc::new(SpoolDir::open(&dir_enc, 8).unwrap().with_codec(Codec::Shuffle)),
            enc_cache_codec: None,
            shared_store: false,
        },
        Case {
            tag: "socket",
            raw_t: Arc::new(SocketTransport::connect_tcp(server.addr())),
            enc_t: Arc::new(
                SocketTransport::connect_tcp(server.addr()).with_codec(Codec::Shuffle),
            ),
            enc_cache_codec: None,
            shared_store: true,
        },
        Case {
            tag: "faulty",
            raw_t: Arc::new(Faulty::wrap(
                Arc::new(InProcess::new(8)),
                FaultPlan::new(31).with_stale_reads(0.5),
            )),
            enc_t: Arc::new(Faulty::wrap(
                Arc::new(InProcess::new(8)),
                FaultPlan::new(31).with_stale_reads(0.5),
            )),
            enc_cache_codec: Some(Codec::Shuffle),
            shared_store: false,
        },
    ];
    for case in &cases {
        let mut raw_cache = DeltaCache::new();
        let mut enc_cache = match case.enc_cache_codec {
            Some(c) => DeltaCache::new().with_codec(c),
            None => DeltaCache::new(),
        };
        for (i, step) in [1u64, 5, 9, 13].into_iter().enumerate() {
            let ck = hot_cold_ckpt(0, step, i as f32);
            case.raw_t.publish(ck.clone()).unwrap();
            if !case.shared_store {
                case.enc_t.publish(ck).unwrap();
            }
            let a = raw_cache.latest(case.raw_t.as_ref(), 0).unwrap().unwrap();
            let b = enc_cache.latest(case.enc_t.as_ref(), 0).unwrap().unwrap();
            assert_eq!(a.step, b.step, "{}", case.tag);
            assert_eq!(
                a.flat().data(),
                b.flat().data(),
                "{}: codec-on install diverged from codec-off",
                case.tag
            );
            assert!(a.flat().layout().same_plane(b.flat().layout()), "{}", case.tag);
        }
        let (rs, es) = (raw_cache.stats(), enc_cache.stats());
        assert_eq!(rs.windows_moved, es.windows_moved, "{}", case.tag);
        assert_eq!(rs.windows_unchanged, es.windows_unchanged, "{}", case.tag);
        assert_eq!(rs.windows_encoded, 0, "{}", case.tag);
        assert!(
            es.windows_encoded > 0,
            "{}: codec never engaged: {es:?}",
            case.tag
        );
        assert!(
            es.payload_bytes < rs.payload_bytes,
            "{}: encoded deltas moved {} bytes !< raw {}",
            case.tag,
            es.payload_bytes,
            rs.payload_bytes
        );
    }
    drop(cases);
    std::fs::remove_dir_all(&dir_raw).ok();
    std::fs::remove_dir_all(&dir_enc).ok();
}

#[test]
fn codec_orchestrated_runs_identical_to_reference() {
    let reference = run_over(Arc::new(InProcess::new(8)));

    // spool with a codec'd publisher: CKPT0004 files on disk, identical run
    let dir = tdir("codec_run_spool");
    let spool = run_over_cfg(
        cfg_delta(),
        Arc::new(SpoolDir::open(&dir, 8).unwrap().with_codec(Codec::Shuffle)),
    );
    assert_logs_identical("codec-spool", &reference, &spool);
    let stats = spool.delta.expect("delta accounting missing");
    assert!(stats.windows_unchanged > 0);
    // the medium really was compressed: a spool file carries the v4 magic
    let v4 = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .find(|e| e.file_name().to_string_lossy().ends_with(".ckpt"))
        .expect("no spool files written");
    assert_eq!(&std::fs::read(v4.path()).unwrap()[..8], b"CKPT0004");
    std::fs::remove_dir_all(&dir).ok();

    // socket with a codec-negotiating client
    let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
    let socket = run_over_cfg(
        cfg_delta(),
        Arc::new(SocketTransport::connect_tcp(server.addr()).with_codec(Codec::Shuffle)),
    );
    assert_logs_identical("codec-socket", &reference, &socket);
    drop(server);

    // the same seeded fault plan faults a codec run identically to a raw
    // one: one read-gate per reload either way (stale-only — the lockstep
    // orchestrator treats a dropped read as fatal)
    let plan = |seed| FaultPlan::new(seed).with_stale_reads(0.5);
    let dir_a = tdir("codec_faulty_raw");
    let dir_b = tdir("codec_faulty_enc");
    let faulted_raw = run_over_cfg(
        cfg_delta(),
        Arc::new(Faulty::wrap(
            Arc::new(SpoolDir::open(&dir_a, 8).unwrap()),
            plan(37),
        )),
    );
    let faulted_codec = run_over_cfg(
        cfg_delta(),
        Arc::new(Faulty::wrap(
            Arc::new(SpoolDir::open(&dir_b, 8).unwrap().with_codec(Codec::Shuffle)),
            plan(37),
        )),
    );
    assert_logs_identical("codec-faulty", &faulted_raw, &faulted_codec);
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}

// ------------------------------------------------------ gc x delta
//
// Pruning a reader's basis step must never strand the reader: digests
// are content-addressed, so a stale basis still deltas cleanly against
// whatever file survived — and a reshaped survivor triggers the
// DeltaCache full-refetch fallback.

#[test]
fn stale_basis_after_spool_gc_falls_back_cleanly() {
    let dir = tdir("gc_delta");
    let spool = SpoolDir::open(&dir, 1).unwrap(); // history bound of 1
    spool.publish(hot_cold_ckpt(0, 1, 1.0)).unwrap();
    // reader in a second handle (its own read cache, like a second process)
    let reader = SpoolDir::open(&dir, 1).unwrap();
    let mut cache = DeltaCache::new();
    cache.latest(&reader, 0).unwrap().unwrap();
    assert_eq!(cache.installed_step(0), Some(1));

    // two more publications; history=1 prunes the basis step's file
    spool.publish(hot_cold_ckpt(0, 2, 2.0)).unwrap();
    spool.publish(hot_cold_ckpt(0, 3, 3.0)).unwrap();
    spool.gc().unwrap();
    assert!(
        !dir.join(spool_file_name(0, 1)).exists(),
        "basis step survived gc"
    );

    // the stale basis must not error: the content-addressed digest
    // comparison serves a delta against the surviving step-3 file
    let got = cache.latest(&reader, 0).unwrap().unwrap();
    assert_eq!(got.step, 3);
    let direct = SpoolDir::open(&dir, 1).unwrap().latest(0).unwrap().unwrap();
    assert_eq!(got.flat().data(), direct.flat().data());
    let stats = cache.stats();
    assert_eq!(stats.delta_fetches, 1, "pruned basis forced a full refetch");
    assert!(
        stats.windows_unchanged >= 1,
        "cold window moved despite matching digests: {stats:?}"
    );

    // a RESHAPED survivor (extra window) invalidates the basis arity and
    // must route through the full(-refetch) path, still byte-identical
    let mut params = codistill::runtime::TensorMap::new();
    params.insert(
        "params.hot",
        codistill::runtime::Tensor::f32(&[W], vec![9.0; W]).unwrap(),
    );
    params.insert(
        "params.cold",
        codistill::runtime::Tensor::f32(&[W], vec![7.5; W]).unwrap(),
    );
    params.insert(
        "params.new",
        codistill::runtime::Tensor::f32(&[2], vec![1.0, 2.0]).unwrap(),
    );
    spool.publish(Checkpoint::new(0, 4, params)).unwrap();
    spool.gc().unwrap();
    let got = cache.latest(&reader, 0).unwrap().unwrap();
    assert_eq!(got.step, 4);
    let direct = SpoolDir::open(&dir, 1).unwrap().latest(0).unwrap().unwrap();
    assert_eq!(got.flat().data(), direct.flat().data());
    assert_eq!(cache.stats().full_fetches, 2, "reshape did not full-refetch");
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------------ relay equivalence
//
// The relay tier is a read-side cache, so it must be invisible to
// correctness: planes installed through a 2-level relay chain (with
// delta + codec on and a faulty hub link) are byte-identical to a direct
// hub fetch, and every hop re-verifies content digests — the relay's
// DeltaCache checks the hub's payloads, the second relay checks the
// first's, and the leaf reader checks the last relay's.

#[test]
fn relay_chain_installs_byte_identical_to_direct_fetch() {
    use codistill::codistill::{Relay, RelayConfig};
    use std::time::{Duration, Instant};

    let hub = Arc::new(InProcess::new(16));
    // Half the hub-link fetches fail: the relay refresher must absorb
    // the errors and still converge on the exact published bytes.
    let flaky_hub: Arc<dyn ExchangeTransport> = Arc::new(Faulty::wrap(
        hub.clone(),
        FaultPlan::new(11).with_erroring_fetches(0.5),
    ));
    let fast = |codec| RelayConfig {
        poll_interval: Duration::from_millis(1),
        delta: true,
        codec,
        ..RelayConfig::default()
    };
    let relay1 = Relay::spawn_tcp(flaky_hub, "127.0.0.1:0", fast(Codec::Shuffle)).unwrap();
    let mid: Arc<dyn ExchangeTransport> = Arc::new(
        SocketTransport::connect_tcp(relay1.addr()).with_codec(Codec::Shuffle),
    );
    let relay2 = Relay::spawn_tcp(mid, "127.0.0.1:0", fast(Codec::Shuffle)).unwrap();

    let leaf = SocketTransport::connect_tcp(relay2.addr()).with_codec(Codec::Shuffle);
    let mut reader = DeltaCache::new().with_codec(Codec::Shuffle);

    for (i, step) in [1u64, 3, 5, 7, 9, 11, 13, 15].into_iter().enumerate() {
        hub.publish(hot_cold_ckpt(0, step, i as f32)).unwrap();
        // Wait for the publication to ripple down both hops. A cold
        // mirror passes the fetch through to the faulty hub link, so the
        // leaf can see an injected error here — tolerated and retried,
        // exactly like any reader over a flaky exchange.
        let deadline = Instant::now() + Duration::from_secs(30);
        let got = loop {
            if let Ok(Some(ck)) = reader.latest(&leaf, 0) {
                if ck.step >= step {
                    break ck;
                }
            }
            assert!(
                Instant::now() < deadline,
                "step {step} never reached the leaf reader"
            );
            std::thread::sleep(Duration::from_millis(1));
        };
        let direct = InProcess::latest(&hub, 0).unwrap();
        assert_eq!(got.step, direct.step, "leaf lagged the hub");
        assert_eq!(
            got.flat().data(),
            direct.flat().data(),
            "relay-chain install diverged from the direct fetch at step {step}"
        );
        assert!(got.flat().layout().same_plane(direct.flat().layout()));
        // digest re-verification at the last hop matches the source of
        // truth (each inner hop verified the same way when it installed)
        assert_eq!(
            got.window_digests().as_ref(),
            direct.window_digests().as_ref(),
            "digest tables diverged across the chain"
        );
    }

    // the exchange really was incremental + encoded at the leaf ...
    let stats = reader.stats();
    assert!(stats.delta_fetches > 0, "leaf never delta-fetched: {stats:?}");
    assert!(
        stats.windows_unchanged > 0,
        "cold window moved through the chain: {stats:?}"
    );
    assert!(
        stats.windows_encoded > 0,
        "codec never engaged on the leaf hop: {stats:?}"
    );
    // ... and at both relay hops, which digest-verified every install
    for (tag, relay) in [("relay1", &relay1), ("relay2", &relay2)] {
        let rs = relay.stats();
        assert!(rs.installs >= 1, "{tag} installed nothing: {rs:?}");
        assert!(
            rs.delta.full_fetches + rs.delta.delta_fetches >= rs.installs,
            "{tag}: installs bypassed the verifying cache: {rs:?}"
        );
        assert!(
            rs.delta.windows_unchanged > 0,
            "{tag}: cold window moved upstream: {rs:?}"
        );
    }
    // the flaky hub link actually fired (otherwise the fault plan is
    // degenerate and this test proves less than it claims)
    assert!(
        relay1.stats().tolerated_errors > 0,
        "fault plan never errored the hub link"
    );
}

//! Transport equivalence: the checkpoint exchange is a pluggable medium,
//! so the same orchestrated run (fixed seed, deterministic members) must
//! produce identical results whether checkpoints move through the
//! in-process store, CKPT0002 files in a shared spool directory, or the
//! socket wire protocol — including the sharded (windowed) socket fetch.
//!
//! The members here are mocks whose dynamics *depend on the teacher
//! parameter values* (not just their steps), so any transport that
//! corrupted, reordered, or re-rounded a single plane byte would diverge
//! the eval curves.

use codistill::codistill::transport::spool::spool_file_name;
use codistill::codistill::{
    Checkpoint, DistillSchedule, EvalStats, ExchangeTransport, InProcess, LrSchedule, Member,
    Orchestrator, OrchestratorConfig, RunLog, SocketServer, SocketTransport, SpoolDir, StepStats,
    Topology,
};
use codistill::runtime::{Tensor, TensorMap};
use std::path::PathBuf;
use std::sync::Arc;

const W: usize = 4;

/// Deterministic member: parameters drift by an id/step-dependent pattern
/// and are pulled toward the mean of the *installed teachers' values*.
struct PullMember {
    id: usize,
    step: u64,
    params: TensorMap,
    teacher_mean: Option<Vec<f32>>,
}

impl PullMember {
    fn new(id: usize) -> Self {
        let init: Vec<f32> = (0..W).map(|k| (id as f32) + 0.25 * k as f32).collect();
        let mut params = TensorMap::new();
        params.insert("params.w", Tensor::f32(&[W], init).unwrap());
        PullMember {
            id,
            step: 0,
            params,
            teacher_mean: None,
        }
    }

    fn w(&self) -> Vec<f32> {
        self.params
            .get("params.w")
            .unwrap()
            .as_f32()
            .unwrap()
            .to_vec()
    }
}

impl Member for PullMember {
    fn train_step(&mut self, distill_w: f32, lr: f32) -> anyhow::Result<StepStats> {
        let drift = ((self.step + self.id as u64) % 7) as f32 * 0.125 - 0.375;
        let teacher = self.teacher_mean.clone();
        let w = self.params.get_mut("params.w")?.as_f32_mut()?;
        let mut distill_loss = 0.0f32;
        for (k, v) in w.iter_mut().enumerate() {
            *v += lr * drift * (1.0 + 0.5 * k as f32);
            if distill_w > 0.0 {
                if let Some(t) = &teacher {
                    let pull = t[k] - *v;
                    *v += distill_w * lr * pull;
                    distill_loss += pull * pull;
                }
            }
        }
        self.step += 1;
        let loss = w.iter().map(|v| v.abs()).sum::<f32>() / W as f32;
        Ok(StepStats {
            step: self.step,
            loss,
            distill_loss,
        })
    }

    fn snapshot(&self) -> anyhow::Result<Checkpoint> {
        Ok(Checkpoint::new(self.id, self.step, self.params.clone()))
    }

    fn set_teachers(&mut self, peers: Vec<Arc<Checkpoint>>) -> anyhow::Result<()> {
        let mut mean = vec![0.0f32; W];
        for p in &peers {
            let w = p.flat().view("params.w")?;
            for (m, v) in mean.iter_mut().zip(w) {
                *m += *v;
            }
        }
        for m in &mut mean {
            *m /= peers.len() as f32;
        }
        self.teacher_mean = Some(mean);
        Ok(())
    }

    fn evaluate(&mut self) -> anyhow::Result<EvalStats> {
        let loss = self.w().iter().map(|v| v.abs() as f64).sum::<f64>();
        Ok(EvalStats {
            loss,
            accuracy: None,
        })
    }

    fn steps_done(&self) -> u64 {
        self.step
    }

    fn params(&self) -> &TensorMap {
        &self.params
    }
}

fn cfg() -> OrchestratorConfig {
    OrchestratorConfig {
        total_steps: 40,
        reload_interval: 10,
        extra_staleness: 0,
        eval_every: 10,
        distill: DistillSchedule::new(5, 5, 1.0),
        lr: LrSchedule::Constant(0.25),
        topology: Topology::FullyConnected,
        cluster: None,
        seed: 3,
        verbose: false,
    }
}

fn run_over(transport: Arc<dyn ExchangeTransport>) -> RunLog {
    let mut members: Vec<Box<dyn Member>> = (0..3)
        .map(|i| Box::new(PullMember::new(i)) as Box<dyn Member>)
        .collect();
    Orchestrator::with_transport(cfg(), transport)
        .run(&mut members)
        .unwrap()
}

fn tdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("codistill_eqv_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// Exact equality of everything a RunLog records about the exchange.
fn assert_logs_identical(tag: &str, a: &RunLog, b: &RunLog) {
    assert_eq!(a.staleness, b.staleness, "{tag}: staleness diverged");
    assert_eq!(a.eval.len(), b.eval.len(), "{tag}");
    for (i, (ca, cb)) in a.eval.iter().zip(&b.eval).enumerate() {
        assert_eq!(ca.len(), cb.len(), "{tag}: member {i} curve length");
        for (pa, pb) in ca.iter().zip(cb) {
            assert_eq!(pa.step, pb.step, "{tag}: member {i}");
            assert_eq!(pa.loss, pb.loss, "{tag}: member {i} step {}", pa.step);
        }
    }
    assert_eq!(a.train.len(), b.train.len(), "{tag}");
    for (ta, tb) in a.train.iter().zip(&b.train) {
        assert_eq!(ta, tb, "{tag}: train records diverged");
    }
}

#[test]
fn same_run_identical_over_all_transports() {
    let reference = run_over(Arc::new(InProcess::new(8)));
    assert!(
        !reference.staleness.is_empty(),
        "fixture never exchanged teachers"
    );

    // spool directory (fresh tempdir)
    let dir = tdir("spool");
    let spool = run_over(Arc::new(SpoolDir::open(&dir, 8).unwrap()));
    assert_logs_identical("spool", &reference, &spool);
    std::fs::remove_dir_all(&dir).ok();

    // socket, full-plane fetches
    let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
    let socket = run_over(Arc::new(SocketTransport::connect_tcp(server.addr())));
    assert_logs_identical("socket", &reference, &socket);
    drop(server);

    // socket, sharded: reloads reassemble the plane window by window
    let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
    let windowed = run_over(Arc::new(
        SocketTransport::connect_tcp(server.addr()).with_windowed_fetch(1),
    ));
    assert_logs_identical("socket-windowed", &reference, &windowed);
}

#[test]
fn spool_two_endpoints_byte_identical_to_inproc() {
    // Two SpoolDir handles on one directory model two coordinator
    // processes: A publishes, B reads, and the bytes B sees must equal
    // what an in-process exchange of the same checkpoint yields.
    let dir = tdir("two_endpoints");
    let a = SpoolDir::open(&dir, 4).unwrap();
    let b = SpoolDir::open(&dir, 4).unwrap();
    let inproc = InProcess::new(4);

    let member = PullMember::new(1);
    let ck = member.snapshot().unwrap();
    inproc.publish(ck.clone()).unwrap();
    a.publish(ck).unwrap();

    let via_spool = b.latest(1).unwrap().unwrap();
    let via_mem = InProcess::latest(&inproc, 1).unwrap();
    assert_eq!(via_spool.step, via_mem.step);
    assert_eq!(
        via_spool.flat().data(),
        via_mem.flat().data(),
        "spool roundtrip changed plane bytes"
    );
    assert!(via_spool
        .flat()
        .layout()
        .same_plane(via_mem.flat().layout()));

    // the windowed pread path is byte-identical too
    let fetch = b
        .fetch_windows(1, u64::MAX, &["params.w".to_string()])
        .unwrap()
        .unwrap();
    assert_eq!(fetch.windows[0].data, via_mem.flat().view("params.w").unwrap());

    // and the on-disk artifact is the canonical zero-padded CKPT0002 file
    assert!(dir.join(spool_file_name(1, 0)).exists());
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------- error paths
//
// Corrupt or vanished exchange state must surface `Err` (or a documented
// recovery) — never a panic, never a hang. One table per backend.

fn raw_ckpt(member: usize, step: u64) -> Checkpoint {
    let mut params = TensorMap::new();
    params.insert("params.w", Tensor::f32(&[W], vec![1.5; W]).unwrap());
    Checkpoint::new(member, step, params)
}

#[test]
fn inproc_error_paths_surface_err() {
    let store = InProcess::new(4);
    store.publish(raw_ckpt(0, 10)).unwrap();
    let cases: Vec<(&str, anyhow::Result<()>)> = vec![
        ("step regression", store.publish(raw_ckpt(0, 5))),
        (
            "unknown window",
            ExchangeTransport::fetch_windows(&store, 0, u64::MAX, &["params.nope".to_string()])
                .map(|_| ()),
        ),
    ];
    for (name, result) in cases {
        assert!(result.is_err(), "inproc {name}: expected Err");
    }
    // absent members are a clean None, not an error
    assert!(store.latest(9).is_none());
    assert!(ExchangeTransport::fetch_windows(&store, 9, u64::MAX, &[])
        .unwrap()
        .is_none());
}

#[test]
fn spool_error_paths_surface_err() {
    fn truncate_ckpt(dir: &std::path::Path) {
        let p = dir.join(spool_file_name(0, 5));
        let data = std::fs::read(&p).unwrap();
        std::fs::write(&p, &data[..24]).unwrap();
    }
    fn bad_magic_ckpt(dir: &std::path::Path) {
        std::fs::write(dir.join(spool_file_name(0, 5)), b"XXPT9999 not a checkpoint").unwrap();
    }
    fn scribble_manifest(dir: &std::path::Path) {
        std::fs::write(dir.join("MANIFEST"), "%% not a manifest %%\n\x00\x01").unwrap();
    }

    // (name, corruption, expect Err from a fresh reader)
    let cases: Vec<(&str, fn(&std::path::Path), bool)> = vec![
        ("truncated CKPT0002 payload", truncate_ckpt, true),
        ("bad checkpoint magic", bad_magic_ckpt, true),
        // a corrupt manifest alone is recoverable: readers fall back to
        // the zero-padded directory scan
        ("corrupt MANIFEST only", scribble_manifest, false),
        (
            "corrupt MANIFEST and truncated payload",
            |dir| {
                scribble_manifest(dir);
                truncate_ckpt(dir);
            },
            true,
        ),
    ];
    for (i, (name, corrupt, expect_err)) in cases.into_iter().enumerate() {
        let dir = tdir(&format!("spool_err_{i}"));
        let writer = SpoolDir::open(&dir, 4).unwrap();
        writer.publish(raw_ckpt(0, 5)).unwrap();
        corrupt(&dir);
        // fresh handle: no read cache to mask the corruption
        let reader = SpoolDir::open(&dir, 4).unwrap();
        let latest = reader.latest(0);
        let windows = reader.fetch_windows(0, u64::MAX, &["params.w".to_string()]);
        if expect_err {
            assert!(latest.is_err(), "spool {name}: latest should Err");
            assert!(windows.is_err(), "spool {name}: fetch_windows should Err");
        } else {
            assert_eq!(
                latest.unwrap().expect("recovery lost the checkpoint").step,
                5,
                "spool {name}"
            );
            assert_eq!(windows.unwrap().unwrap().windows[0].data, vec![1.5; W]);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn socket_error_paths_surface_err_not_hang() {
    use std::io::{Read, Write};
    use std::net::TcpListener;

    // dead server: every operation is a prompt Err, never a hang
    let gone_addr = {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        server.addr().to_string()
    };
    // server mid-DESCRIBE shutdown: accepts, reads the request length,
    // then disappears before answering
    let quitter = TcpListener::bind("127.0.0.1:0").unwrap();
    let quitter_addr = quitter.local_addr().unwrap().to_string();
    let quitter_thread = std::thread::spawn(move || {
        let (mut s, _) = quitter.accept().unwrap();
        let mut len = [0u8; 4];
        s.read_exact(&mut len).ok();
    });
    // protocol-corrupting server: answers any request with a bogus status
    let garbler = TcpListener::bind("127.0.0.1:0").unwrap();
    let garbler_addr = garbler.local_addr().unwrap().to_string();
    let garbler_thread = std::thread::spawn(move || {
        let (mut s, _) = garbler.accept().unwrap();
        let mut len = [0u8; 4];
        s.read_exact(&mut len).unwrap();
        let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
        s.read_exact(&mut body).unwrap();
        s.write_all(&1u32.to_le_bytes()).unwrap();
        s.write_all(&[0xEE]).unwrap();
    });

    let cases: Vec<(&str, anyhow::Result<()>)> = vec![
        (
            "connect to a dead server",
            SocketTransport::connect_tcp(&gone_addr).latest(0).map(|_| ()),
        ),
        (
            "server shutdown mid-DESCRIBE",
            SocketTransport::connect_tcp(&quitter_addr)
                .with_windowed_fetch(2)
                .latest(0)
                .map(|_| ()),
        ),
        (
            "corrupt response status",
            SocketTransport::connect_tcp(&garbler_addr).members().map(|_| ()),
        ),
    ];
    for (name, result) in cases {
        assert!(result.is_err(), "socket {name}: expected Err");
    }
    quitter_thread.join().unwrap();
    garbler_thread.join().unwrap();
}

#[test]
fn socket_windowed_fetch_byte_identical_to_inproc() {
    let inproc = InProcess::new(4);
    let member = PullMember::new(2);
    let ck = member.snapshot().unwrap();
    inproc.publish(ck.clone()).unwrap();

    let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
    let publisher = SocketTransport::connect_tcp(server.addr());
    publisher.publish(ck).unwrap();

    let reader = SocketTransport::connect_tcp(server.addr()).with_windowed_fetch(1);
    let via_socket = reader.latest(2).unwrap().unwrap();
    let via_mem = InProcess::latest(&inproc, 2).unwrap();
    assert_eq!(
        via_socket.flat().data(),
        via_mem.flat().data(),
        "windowed socket reassembly changed plane bytes"
    );
    assert!(via_socket
        .flat()
        .layout()
        .same_plane(via_mem.flat().layout()));

    let fetch = reader
        .fetch_windows(2, u64::MAX, &["params.w".to_string()])
        .unwrap()
        .unwrap();
    assert_eq!(fetch.windows[0].data, via_mem.flat().view("params.w").unwrap());
    assert_eq!(fetch.payload_bytes(), (W * 4) as u64);
}

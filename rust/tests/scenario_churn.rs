//! The ISSUE 6 acceptance scenario: an O(100)-member coordinator fleet
//! driven through declarative churn scenarios (`codistill::scenario`)
//! over a `Retry`-wrapped `Faulty` socket transport. Under a spot-wave
//! preemption plus a flaky exchange the run must land within 5% of the
//! fault-free in-process reference, the retry layer must absorb >= 90%
//! of the injected transient fetch faults, and the same scenario text +
//! seed must replay byte-identical staleness, fault, and retry logs.
//!
//! `make test-scenarios` runs this suite over the seed list in
//! `CODISTILL_FAULT_SEEDS` (default `11 23 47`).

use codistill::codistill::transport::FaultKind;
use codistill::codistill::{
    Codec, CompiledScenario, Coordinator, CoordinatorConfig, CoordinatorLog, DistillSchedule,
    ExchangeTransport, Faulty, InProcess, LrSchedule, Retry, RetryPolicy, Scenario, SocketServer,
    SocketTransport, Topology,
};
use codistill::testkit::drift_fleet;
use std::sync::Arc;

/// The acceptance scenario: a quarter of the fleet preempted in one
/// correlated wave with staggered rejoins, over an exchange that drops
/// 20% and errors 10% of fetches.
const SPOT_WAVE_100: &str = "\
# spot-preemption wave over a flaky exchange, at O(100) members
seed = 11
members = 100

[spot_wave]
at = 30
fraction = 0.25
down = 25
stagger = 1

[flaky_net]
drop_p = 0.2
error_p = 0.1
";

fn cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        total_steps: 120,
        reload_interval: 20,
        eval_every: 40,
        distill: DistillSchedule::new(20, 10, 1.0),
        lr: LrSchedule::Constant(0.2),
        // Ring keeps the reload fan-in at 2 teachers per member, so the
        // 100-member fleet stays cheap over a real socket.
        topology: Topology::Ring,
        liveness_grace: 25,
        seed: 5,
        delta: false,
        publish_codec: Codec::Raw,
        error_feedback: false,
        verbose: false,
    }
}

/// Run the compiled scenario's fleet (drift members, publish every 10)
/// over `transport`. The scenario schedules are applied; whether its
/// fault plan is active depends on the transport stack passed in.
fn run_fleet(compiled: &CompiledScenario, transport: Arc<dyn ExchangeTransport>) -> CoordinatorLog {
    let mut hosted = drift_fleet(compiled.members, 10);
    compiled.apply(&mut hosted);
    Coordinator::new(cfg(), transport).run(&mut hosted).unwrap()
}

/// Same churn schedules, no injected faults, in-process exchange: the
/// reference the faulty runs must converge to.
fn fault_free_reference(compiled: &CompiledScenario) -> f64 {
    run_fleet(compiled, Arc::new(InProcess::new(8)))
        .final_mean_loss()
        .unwrap()
}

fn assert_within_pct(tag: &str, got: f64, want: f64, pct: f64) {
    let tol = want.abs() * pct / 100.0;
    assert!(
        (got - want).abs() <= tol,
        "{tag}: final mean loss {got:.5} not within {pct}% of fault-free {want:.5}"
    );
}

/// Seeds for the scenario matrix: `CODISTILL_FAULT_SEEDS="a b c"` (the
/// `make test-scenarios` pin) or a fixed default list.
fn fault_seeds() -> Vec<u64> {
    std::env::var("CODISTILL_FAULT_SEEDS")
        .ok()
        .map(|v| v.split_whitespace().filter_map(|t| t.parse().ok()).collect::<Vec<u64>>())
        .filter(|v: &Vec<u64>| !v.is_empty())
        .unwrap_or_else(|| vec![11, 23, 47])
}

/// The full acceptance criterion in one test: 100 members, spot wave +
/// flaky net, `Retry(Faulty(SocketTransport))`, vs the fault-free
/// in-process reference.
#[test]
fn hundred_member_spot_wave_over_retrying_faulty_socket() {
    let scenario = Scenario::parse(SPOT_WAVE_100).unwrap();
    assert_eq!(scenario.fleet_size(2), 100, "file's members must win");
    let compiled = scenario.compile(scenario.fleet_size(2), 0).unwrap();
    let victims: Vec<usize> = compiled
        .schedules
        .iter()
        .filter(|s| !s.downtimes.is_empty())
        .map(|s| s.member)
        .collect();
    assert_eq!(victims.len(), 25, "round(100 * 0.25) members preempted");

    let reference = fault_free_reference(&compiled);

    let run_faulty = || {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 8).unwrap();
        let client: Arc<dyn ExchangeTransport> =
            Arc::new(SocketTransport::connect_tcp(server.addr()));
        let faulty = Arc::new(Faulty::wrap(client, compiled.plan.clone()));
        let retry = Arc::new(Retry::wrap(
            faulty.clone(),
            RetryPolicy::immediate(5, compiled.seed),
        ));
        let log = run_fleet(&compiled, retry.clone());
        let texts = (
            log.staleness_log_text(),
            faulty.fault_log_text(),
            retry.retry_log_text(),
        );
        let faults = faulty.fault_log();
        drop(server);
        (log, texts, faults)
    };

    let (log1, texts1, faults1) = run_faulty();
    let (log2, texts2, _) = run_faulty();

    // Convergence: within 5% of the fault-free in-process reference.
    assert_within_pct(
        "spot wave over retrying faulty socket",
        log1.final_mean_loss().unwrap(),
        reference,
        5.0,
    );

    // Every victim went down and came back: one rejoin record apiece,
    // after the wave started.
    assert_eq!(log1.joins.len(), victims.len(), "{:?}", log1.joins);
    let mut rejoined: Vec<usize> = log1.joins.iter().map(|j| j.member).collect();
    rejoined.sort_unstable();
    assert_eq!(rejoined, victims);
    assert!(log1.joins.iter().all(|j| j.tick > 30), "{:?}", log1.joins);

    // The flaky net really fired: dropped AND errored fetches injected.
    let dropped = faults1.iter().filter(|e| e.kind == FaultKind::DroppedFetch).count();
    let errored = faults1.iter().filter(|e| e.kind == FaultKind::ErroredFetch).count();
    assert!(
        dropped > 0 && errored > 0,
        "fault mix missing a class: {dropped} dropped, {errored} errored"
    );

    // ... and the retry layer absorbed >= 90% of the affected operations.
    let stats = log1.retry.expect("no retry accounting in the coordinator log");
    assert!(stats.transient_errors > 0 && stats.absorbed > 0, "{stats:?}");
    assert!(
        stats.absorption_rate() >= 0.9,
        "retry absorbed only {:.3} of {} affected ops: {stats:?}",
        stats.absorption_rate(),
        stats.affected_ops()
    );

    // Reproducibility: byte-identical staleness + fault + retry logs
    // across two runs with the same scenario text and seed.
    let (stale1, fault1, retry1) = &texts1;
    let (stale2, fault2, retry2) = &texts2;
    assert!(!stale1.is_empty() && !fault1.is_empty() && !retry1.is_empty());
    assert_eq!(stale1.as_bytes(), stale2.as_bytes(), "staleness log not reproducible");
    assert_eq!(fault1.as_bytes(), fault2.as_bytes(), "fault log not reproducible");
    assert_eq!(retry1.as_bytes(), retry2.as_bytes(), "retry log not reproducible");
}

/// The scenario matrix over the pinned seed list: every seed's spot wave
/// + flaky net converges and keeps absorption above the bar (in-process
/// inner transport so the matrix stays fast).
#[test]
fn scenario_matrix_converges_over_every_seed() {
    for seed in fault_seeds() {
        let text = format!(
            "seed = {seed}\nmembers = 24\n\n\
             [spot_wave]\nat = 20\nfraction = 0.25\ndown = 20\nstagger = 2\n\n\
             [flaky_net]\ndrop_p = 0.2\nerror_p = 0.1\n"
        );
        let compiled = Scenario::parse(&text).unwrap().compile(24, 0).unwrap();
        let reference = fault_free_reference(&compiled);

        let faulty = Arc::new(Faulty::wrap(
            Arc::new(InProcess::new(8)),
            compiled.plan.clone(),
        ));
        let retry = Arc::new(Retry::wrap(faulty, RetryPolicy::immediate(5, seed)));
        let log = run_fleet(&compiled, retry);

        assert_within_pct(
            &format!("scenario seed {seed}"),
            log.final_mean_loss().unwrap(),
            reference,
            5.0,
        );
        let stats = log.retry.unwrap();
        assert!(
            stats.absorption_rate() >= 0.9,
            "seed {seed}: absorption {:.3} ({stats:?})",
            stats.absorption_rate()
        );
    }
}

/// Flash-crowd joiners bootstrap from a *live* peer even when the
/// freshest-looking zone is blacked out: the zone members' heartbeats
/// freeze below the crowd's join tick, so every bootstrap source must be
/// a non-zone member with a recent checkpoint.
#[test]
fn flash_crowd_bootstraps_from_live_peers_around_a_zone_outage() {
    const TEXT: &str = "\
seed = 7
members = 30

[zone_outage]
zone = 0..6
from = 40
until = 90

[flash_crowd]
at = 60
joiners = 5
";
    let compiled = Scenario::parse(TEXT).unwrap().compile(30, 0).unwrap();
    assert_eq!(compiled.plan.blackouts.len(), 6);
    assert!(compiled
        .schedules
        .iter()
        .filter(|s| s.join_delay == 60)
        .map(|s| s.member)
        .eq(25..30));

    let faulty = Arc::new(Faulty::wrap(
        Arc::new(InProcess::new(8)),
        compiled.plan.clone(),
    ));
    let log = run_fleet(&compiled, faulty.clone());

    // The zone really went dark: its publishes in [40, 90) were dropped.
    assert!(faulty
        .fault_log()
        .iter()
        .all(|e| e.kind == FaultKind::BlackoutPublish && e.member < 6));
    assert!(!faulty.fault_log().is_empty());

    // All five joiners seeded from a live, non-zone peer with a
    // checkpoint no older than the zone's frozen heartbeat.
    assert_eq!(log.joins.len(), 5, "{:?}", log.joins);
    for j in &log.joins {
        assert!(j.member >= 25 && j.tick == 60, "{j:?}");
        let (peer, step) = j.bootstrapped_from.expect("joiner started cold");
        assert!(peer >= 6, "bootstrapped from blacked-out zone member {peer}");
        assert!(step >= 50, "bootstrap checkpoint stale: step {step}");
    }
}

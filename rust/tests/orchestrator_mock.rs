//! Orchestrator logic tests with a mock member (no XLA): exchange cadence,
//! staleness accounting, burn-in gating, wall-clock accumulation, and the
//! testkit property sweep over coordinator invariants.

use codistill::codistill::{
    Checkpoint, Codec, DistillSchedule, EvalStats, LrSchedule, Member, Orchestrator,
    OrchestratorConfig, StepStats, Topology,
};
use codistill::netsim::ClusterModel;
use codistill::runtime::{Tensor, TensorMap};
use codistill::testkit::{forall, in_range};
use std::sync::Arc;

/// Records every interaction; "loss" decays deterministically.
struct MockMember {
    id: usize,
    step: u64,
    params: TensorMap,
    teachers_seen: Vec<(u64, Vec<u64>)>, // (at step, teacher ckpt steps)
    distill_ws: Vec<f32>,
}

impl MockMember {
    fn new(id: usize) -> Self {
        let mut params = TensorMap::new();
        params.insert("params.w", Tensor::f32(&[2], vec![id as f32, 0.0]).unwrap());
        MockMember {
            id,
            step: 0,
            params,
            teachers_seen: vec![],
            distill_ws: vec![],
        }
    }
}

impl Member for MockMember {
    fn train_step(&mut self, distill_w: f32, _lr: f32) -> anyhow::Result<StepStats> {
        self.step += 1;
        self.distill_ws.push(distill_w);
        Ok(StepStats {
            step: self.step,
            loss: 1.0 / self.step as f32,
            distill_loss: distill_w,
        })
    }

    fn snapshot(&self) -> anyhow::Result<Checkpoint> {
        Ok(Checkpoint::new(self.id, self.step, self.params.clone()))
    }

    fn set_teachers(&mut self, peers: Vec<Arc<Checkpoint>>) -> anyhow::Result<()> {
        self.teachers_seen
            .push((self.step, peers.iter().map(|c| c.step).collect()));
        Ok(())
    }

    fn evaluate(&mut self) -> anyhow::Result<EvalStats> {
        Ok(EvalStats {
            loss: 1.0 / (self.step.max(1)) as f64,
            accuracy: None,
        })
    }

    fn steps_done(&self) -> u64 {
        self.step
    }

    fn params(&self) -> &TensorMap {
        &self.params
    }
}

fn run_mock(n: usize, cfg: OrchestratorConfig) -> (Vec<MockMember>, codistill::codistill::RunLog) {
    let mut members: Vec<Box<dyn Member>> = (0..n)
        .map(|i| Box::new(MockMember::new(i)) as Box<dyn Member>)
        .collect();
    let orch = Orchestrator::new(cfg);
    let log = orch.run(&mut members).unwrap();
    let mocks: Vec<MockMember> = members
        .into_iter()
        .map(|b| {
            // retrieve concrete type back out via raw pointer trick is not
            // possible; instead re-run? We capture what we need from log.
            let _ = b;
            MockMember::new(0)
        })
        .collect();
    (mocks, log)
}

fn base_cfg(steps: u64, reload: u64) -> OrchestratorConfig {
    OrchestratorConfig {
        total_steps: steps,
        reload_interval: reload,
        extra_staleness: 0,
        eval_every: steps,
        distill: DistillSchedule::new(0, 0, 1.0),
        lr: LrSchedule::Constant(0.1),
        topology: Topology::Pair,
        cluster: None,
        seed: 1,
        delta: false,
        publish_codec: Codec::Raw,
        error_feedback: false,
        verbose: false,
    }
}

#[test]
fn staleness_is_bounded_by_reload_interval() {
    let (_m, log) = run_mock(2, base_cfg(100, 10));
    assert!(!log.staleness.is_empty());
    for &(at, _member, staleness) in &log.staleness {
        assert!(
            staleness <= 10,
            "observed staleness {staleness} > reload interval at step {at}"
        );
    }
}

#[test]
fn staleness_grows_with_interval() {
    let (_a, log_small) = run_mock(2, base_cfg(120, 10));
    let (_b, log_large) = run_mock(2, base_cfg(120, 40));
    let mean = |l: &codistill::codistill::RunLog| {
        l.staleness.iter().map(|&(_, _, s)| s as f64).sum::<f64>() / l.staleness.len() as f64
    };
    assert!(mean(&log_large) > mean(&log_small));
}

#[test]
fn train_log_covers_all_members_every_step() {
    let (_m, log) = run_mock(3, base_cfg(50, 10));
    assert_eq!(log.train.len(), 3 * 50);
    for step in 0..50u64 {
        let members: Vec<usize> = log
            .train
            .iter()
            .filter(|&&(s, _, _, _)| s == step)
            .map(|&(_, m, _, _)| m)
            .collect();
        assert_eq!(members.len(), 3, "step {step}");
    }
}

#[test]
fn wall_clock_accumulates_with_cluster_model() {
    let mut cfg = base_cfg(40, 10);
    cfg.cluster = Some(ClusterModel::gpu_cluster(16, 1_000_000));
    let (_m, log) = run_mock(2, cfg);
    assert!(log.wall_s > 0.0);
    // eval points carry increasing wall time
    let walls: Vec<f64> = log.eval[0].iter().map(|p| p.wall_s).collect();
    for w in walls.windows(2) {
        assert!(w[1] >= w[0]);
    }
}

#[test]
fn steps_to_target_and_best_loss() {
    let mut cfg = base_cfg(64, 8);
    cfg.eval_every = 8;
    let (_m, log) = run_mock(1, cfg);
    // mock loss = 1/step: target 0.05 first hit at step >= 20 -> eval 24
    let hit = log.steps_to_target(0, 0.05).unwrap();
    assert_eq!(hit, 24);
    assert!(log.best_loss(0).unwrap() <= 1.0 / 64.0 + 1e-9);
    assert!(log.steps_to_target(0, 1e-9).is_none());
}

/// Pins the staleness-injection fallback: when history pruning leaves no
/// checkpoint old enough for the `extra_staleness` bound, the reload
/// falls back to the paper-semantics freshest read (`latest`) instead of
/// failing — observable as staleness far below the requested bound.
#[test]
fn staleness_fallback_serves_freshest_when_no_old_checkpoint_survives() {
    let mut cfg = base_cfg(40, 5);
    // Demand 1000-step-old teachers that a 1-deep history can never hold.
    cfg.extra_staleness = 1000;
    let transport = Arc::new(codistill::codistill::InProcess::new(1));
    let mut members: Vec<Box<dyn Member>> = (0..2)
        .map(|i| Box::new(MockMember::new(i)) as Box<dyn Member>)
        .collect();
    let log = Orchestrator::with_transport(cfg, transport)
        .run(&mut members)
        .expect("fallback must keep the run alive");
    assert!(!log.staleness.is_empty(), "teachers were never installed");
    for &(at, member, staleness) in &log.staleness {
        assert!(
            staleness <= 5,
            "member {member} at step {at}: fallback should serve the freshest \
             publication (staleness <= reload interval), got {staleness}"
        );
    }
}

#[test]
fn single_member_never_gets_teachers() {
    let (_m, log) = run_mock(1, base_cfg(30, 5));
    assert!(log.staleness.is_empty());
}

#[test]
fn property_topology_teacher_counts() {
    forall::<(u64, u64)>("topology teacher counts", 11, 200, |&(a, b)| {
        let n = in_range(a, 1, 9);
        let i = in_range(b, 0, n - 1);
        let full = Topology::FullyConnected.teachers_of(i, n);
        let ring = Topology::Ring.teachers_of(i, n);
        let pair = Topology::Pair.teachers_of(i, n);
        full.len() == n - 1
            && ring.len() == usize::from(n > 1)
            && pair.len() <= 1
            && !full.contains(&i)
            && !ring.contains(&i)
            && !pair.contains(&i)
            && full.iter().all(|&j| j < n)
            && ring.iter().all(|&j| j < n)
            && pair.iter().all(|&j| j < n)
    });
}

#[test]
fn property_distill_schedule_monotone_ramp() {
    forall::<(u64, u64, u64)>("distill ramp monotone", 13, 200, |&(b, r, q)| {
        let burn = in_range(b, 0, 50) as u64;
        let ramp = in_range(r, 0, 50) as u64;
        let sched = DistillSchedule::new(burn, ramp, 1.0);
        let s1 = in_range(q, 0, 200) as u64;
        let w1 = sched.weight_at(s1);
        let w2 = sched.weight_at(s1 + 1);
        // monotone nondecreasing, bounded, zero during burn-in
        (0.0..=1.0).contains(&w1) && w2 >= w1 && (s1 >= burn || w1 == 0.0)
    });
}

#[test]
fn property_lr_warmup_bounded() {
    forall::<(u64, u64)>("warmup lr bounded by base", 17, 200, |&(a, b)| {
        let warmup = in_range(a, 1, 100) as u64;
        let step = in_range(b, 0, 1000) as u64;
        let s = LrSchedule::WarmupStep {
            base: 0.4,
            warmup,
            milestones: vec![500],
            decay: 0.1,
        };
        let lr = s.at(step);
        lr > 0.0 && lr <= 0.4 + 1e-9
    });
}

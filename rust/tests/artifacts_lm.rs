//! Integration tests over the real LM artifacts (skipped when
//! `artifacts/` is absent; run `make artifacts` first).

use codistill::codistill::{DistillSchedule, Member};
use codistill::config::Settings;
use codistill::data::corpus::Batcher;
use codistill::data::shard::{ShardMode, ShardPlan};
use codistill::experiments::common::{artifacts_dir, corpus_for, lm_member, open_bundle};
use codistill::models::lm::{LmSyncGroup, SmoothingMode};
use codistill::runtime::Tensor;
use std::sync::Arc;

fn have_artifacts() -> bool {
    artifacts_dir(&Settings::new()).join("lm_b32/bundle.txt").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
    };
}

#[test]
fn init_is_seed_deterministic() {
    require_artifacts!();
    let s = Settings::new();
    let bundle = open_bundle(&s, "lm_b32").unwrap();
    let init = bundle.exe("init").unwrap();
    let a = init.run(&[&Tensor::scalar_i32(7)]).unwrap();
    let b = init.run(&[&Tensor::scalar_i32(7)]).unwrap();
    let c = init.run(&[&Tensor::scalar_i32(8)]).unwrap();
    assert_eq!(a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
    assert_ne!(a[0].as_f32().unwrap(), c[0].as_f32().unwrap());
}

#[test]
fn training_reduces_validation_loss() {
    require_artifacts!();
    let s = Settings::new();
    let bundle = open_bundle(&s, "lm_b32").unwrap();
    let plan = ShardPlan::new(1, 32, ShardMode::Disjoint);
    let mut m = lm_member(&bundle, &plan, 0, 3, 1, SmoothingMode::None, 2).unwrap();
    let before = m.evaluate().unwrap().loss;
    for _ in 0..40 {
        let stats = m.train_step(0.0, 0.03).unwrap();
        assert!(stats.loss.is_finite());
    }
    let after = m.evaluate().unwrap().loss;
    assert!(
        after < before - 0.1,
        "loss should drop by >0.1: {before:.4} -> {after:.4}"
    );
}

#[test]
fn distill_weight_zero_matches_plain_step() {
    require_artifacts!();
    // With w=0 the ψ term is multiplied out: a member with teachers set
    // but weight 0 must follow the exact same trajectory as a plain one.
    let s = Settings::new();
    let bundle = open_bundle(&s, "lm_b32").unwrap();
    let plan = ShardPlan::new(1, 32, ShardMode::Disjoint);
    let mut a = lm_member(&bundle, &plan, 0, 5, 1, SmoothingMode::None, 2).unwrap();
    let mut b = lm_member(&bundle, &plan, 0, 5, 1, SmoothingMode::None, 2).unwrap();
    let teacher = Arc::new(a.snapshot().unwrap());
    b.set_fixed_teachers(vec![teacher]).unwrap();
    for _ in 0..5 {
        a.train_step(0.0, 0.03).unwrap();
        b.train_step(0.0, 0.03).unwrap();
    }
    let d = a
        .params()
        .prefix_mean_abs_diff(b.params(), "params.")
        .unwrap();
    assert!(d < 1e-7, "trajectories diverged: mean|Δ|={d}");
}

#[test]
fn teacher_predictions_are_distributions() {
    require_artifacts!();
    let s = Settings::new();
    let bundle = open_bundle(&s, "lm_b32").unwrap();
    let plan = ShardPlan::new(1, 32, ShardMode::Disjoint);
    let m = lm_member(&bundle, &plan, 0, 9, 1, SmoothingMode::None, 2).unwrap();
    let corpus = corpus_for(&bundle).unwrap();
    let streams: Vec<u64> = (700..732).collect();
    let mut batcher = Batcher::new(&corpus, 9, &streams, 16);
    let tokens = batcher.next_batch().unwrap();
    let probs = m.predict_probs(&tokens).unwrap();
    assert_eq!(probs.shape(), &[16 * 32, 512]);
    let data = probs.as_f32().unwrap();
    assert!(data.iter().all(|&p| (0.0..=1.0).contains(&p)));
    // rows sum to 1
    for row in data.chunks(512).take(8) {
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "row sums to {s}");
    }
}

#[test]
fn allreduce_group_matches_fused_large_batch() {
    require_artifacts!();
    // THE sync-SGD equivalence (DESIGN.md §9): 4 workers × batch 8 with
    // mean-reduced grads == one fused batch-32 step, on identical data.
    let s = Settings::new();
    let worker_bundle = open_bundle(&s, "lm_w8").unwrap();
    let fused_bundle = open_bundle(&s, "lm_b32").unwrap();
    let corpus = corpus_for(&fused_bundle).unwrap();
    let streams: Vec<u64> = (0..32).collect();
    let val: Vec<u64> = (2_000_000..2_000_032).collect();
    let mut group = LmSyncGroup::new(
        &worker_bundle,
        &fused_bundle,
        13,
        2,
        4,
        &streams,
        &val,
        &corpus,
        2,
    )
    .unwrap();
    let plan = ShardPlan::new(1, 32, ShardMode::Disjoint);
    let mut fused = lm_member(&fused_bundle, &plan, 0, 13, 2, SmoothingMode::None, 2).unwrap();

    for _ in 0..3 {
        group.train_step(0.0, 0.03).unwrap();
        fused.train_step(0.0, 0.03).unwrap();
    }
    let d = group
        .params()
        .prefix_mean_abs_diff(fused.params(), "params.")
        .unwrap();
    // identical math up to f32 reduction order
    assert!(d < 2e-4, "allreduce vs fused diverged: mean|Δ|={d}");
}

#[test]
fn codistillation_couples_members() {
    require_artifacts!();
    // After codistillation, the two copies' PREDICTIONS on a common probe
    // batch must agree more than two independently trained copies'
    // (predictions are identifiable; weights are not — paper §2.1).
    let s = Settings::new();
    let bundle = open_bundle(&s, "lm_b32").unwrap();
    let corpus = corpus_for(&bundle).unwrap();
    let steps = 60u64;
    let probe = {
        let streams: Vec<u64> = (4_000_000..4_000_032).collect();
        let mut b = Batcher::new(&corpus, 999, &streams, 16);
        b.next_batch().unwrap()
    };

    let run = |codistill: bool| {
        let plan = ShardPlan::new(2, 32, ShardMode::Disjoint);
        let mut a = lm_member(&bundle, &plan, 0, 21, 1, SmoothingMode::None, 2).unwrap();
        let mut b = lm_member(&bundle, &plan, 1, 21, 2, SmoothingMode::None, 2).unwrap();
        let sched = if codistill {
            DistillSchedule::new(10, 5, 2.0)
        } else {
            DistillSchedule::off()
        };
        for step in 0..steps {
            if codistill && step % 10 == 0 {
                let ca = Arc::new(a.snapshot().unwrap());
                let cb = Arc::new(b.snapshot().unwrap());
                a.set_fixed_teachers(vec![cb]).unwrap();
                b.set_fixed_teachers(vec![ca]).unwrap();
            }
            let w = sched.weight_at(step);
            a.train_step(w, 0.03).unwrap();
            b.train_step(w, 0.03).unwrap();
        }
        let pa = a.predict_probs(&probe).unwrap();
        let pb = b.predict_probs(&probe).unwrap();
        pa.mean_abs_diff(&pb).unwrap()
    };
    let d_codist = run(true);
    let d_indep = run(false);
    assert!(
        d_codist < d_indep,
        "codistilled predictions should agree more: codist {d_codist:.6} vs indep {d_indep:.6}"
    );
}

#[test]
fn label_smoothing_modes_train() {
    require_artifacts!();
    let s = Settings::new();
    let bundle = open_bundle(&s, "lm_b32").unwrap();
    let corpus = corpus_for(&bundle).unwrap();
    for mode in [
        SmoothingMode::Uniform,
        SmoothingMode::Unigram(corpus.unigram()),
    ] {
        let plan = ShardPlan::new(1, 32, ShardMode::Disjoint);
        let mut m = lm_member(&bundle, &plan, 0, 31, 1, mode, 2).unwrap();
        for _ in 0..5 {
            let stats = m.train_step(0.3, 0.03).unwrap();
            assert!(stats.loss.is_finite());
            assert!(stats.distill_loss > 0.0, "ψ should be active");
        }
    }
}

// End-to-end smoke: jax/pallas-lowered HLO text loads and runs through the
// runtime with correct numerics. Requires /tmp/smoke built by CI/dev; skipped
// if absent (the real artifact integration tests live in artifacts_*.rs).
use codistill::runtime::{Runtime, Tensor};
use std::path::Path;
use std::sync::Arc;

#[test]
fn smoke_matmul_plus_two() {
    let stem = Path::new("/tmp/smoke/fn");
    if !stem.with_extension("hlo.txt").exists() {
        eprintln!("skipping: /tmp/smoke/fn.hlo.txt not present");
        return;
    }
    let rt = Arc::new(Runtime::cpu().unwrap());
    let exe = rt.load(stem).unwrap();
    let x = Tensor::f32(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
    let y = Tensor::f32(&[2, 2], vec![1., 1., 1., 1.]).unwrap();
    let out = exe.run(&[&x, &y]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].as_f32().unwrap(), &[5., 5., 9., 9.]);
    // cache hit returns the same executable
    let exe2 = rt.load(stem).unwrap();
    assert_eq!(rt.cached_executables(), 1);
    assert_eq!(exe2.name(), "fn");
}

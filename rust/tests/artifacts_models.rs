//! Integration tests for the Criteo and images members over real
//! artifacts (skipped without `make artifacts`).

use codistill::codistill::{DistillSchedule, Member};
use codistill::config::Settings;
use codistill::experiments::common::{artifacts_dir, open_bundle};
use codistill::models::criteo::{CriteoMember, CriteoValSet};
use codistill::models::images::{ImagesMember, ImagesValSet};
use std::sync::Arc;

fn have(bundle: &str) -> bool {
    artifacts_dir(&Settings::new())
        .join(bundle)
        .join("bundle.txt")
        .exists()
}

#[test]
fn criteo_training_reduces_logloss() {
    if !have("criteo") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let s = Settings::new();
    let bundle = open_bundle(&s, "criteo").unwrap();
    let val = CriteoValSet::generate(1, 999, 1000, 256, 4).unwrap();
    let mut m = CriteoMember::new(&bundle, 1, 0, 1, val).unwrap();
    let before = m.evaluate().unwrap().loss;
    for _ in 0..40 {
        m.train_step(0.0, 0.05).unwrap();
    }
    let after = m.evaluate().unwrap().loss;
    assert!(after < before, "logloss {before:.4} -> {after:.4}");
    // predictions are probabilities on the fixed val set
    let preds = m.val_predictions().unwrap();
    assert_eq!(preds.len(), 4 * 256);
    assert!(preds.iter().all(|&p| (0.0..=1.0).contains(&p)));
}

#[test]
fn criteo_retrains_differ_codistilled_pair_couples() {
    if !have("criteo") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let s = Settings::new();
    let bundle = open_bundle(&s, "criteo").unwrap();
    let val = CriteoValSet::generate(1, 999, 1000, 256, 2).unwrap();
    // two retrains differ
    let mut m1 = CriteoMember::new(&bundle, 1, 10, 1, val.clone()).unwrap();
    let mut m2 = CriteoMember::new(&bundle, 1, 20, 2, val.clone()).unwrap();
    for _ in 0..80 {
        m1.train_step(0.0, 0.05).unwrap();
        m2.train_step(0.0, 0.05).unwrap();
    }
    let p1 = m1.val_predictions().unwrap();
    let p2 = m2.val_predictions().unwrap();
    let churn = codistill::metrics::mean_abs_diff(&p1, &p2).unwrap();
    assert!(churn > 1e-4, "independent retrains should disagree: {churn}");

    // Table 1's metric: churn BETWEEN RETRAINS of the codistilled
    // procedure (pick copy A each retrain) drops vs the plain DNN's.
    let sched = DistillSchedule::new(20, 10, 1.0);
    let mut retrain = |seed: i32, stream: u64| {
        let mut a = CriteoMember::new(&bundle, 1, stream, seed, val.clone()).unwrap();
        let mut b = CriteoMember::new(&bundle, 1, stream + 1, seed + 50, val.clone()).unwrap();
        for step in 0..80 {
            if step % 10 == 0 {
                let ca = Arc::new(a.snapshot().unwrap());
                let cb = Arc::new(b.snapshot().unwrap());
                a.set_teachers(vec![cb]).unwrap();
                b.set_teachers(vec![ca]).unwrap();
            }
            let w = sched.weight_at(step);
            a.train_step(w, 0.05).unwrap();
            b.train_step(w, 0.05).unwrap();
        }
        a.val_predictions().unwrap()
    };
    let c1 = retrain(3, 30);
    let c2 = retrain(4, 60);
    let coupled_churn = codistill::metrics::mean_abs_diff(&c1, &c2).unwrap();
    assert!(
        coupled_churn < churn,
        "codistilled retrain churn ({coupled_churn:.4}) should be below plain DNN churn ({churn:.4})"
    );
}

#[test]
fn images_training_improves_accuracy() {
    if !have("images") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let s = Settings::new();
    let bundle = open_bundle(&s, "images").unwrap();
    let val = ImagesValSet::generate(1, 999, 16, 3, 10, 64, 3, 2.0).unwrap();
    let mut m = ImagesMember::new(&bundle, 1, 0, 1, 2.0, val).unwrap();
    let before = m.evaluate().unwrap();
    for _ in 0..60 {
        m.train_step(0.0, 0.02).unwrap();
    }
    let after = m.evaluate().unwrap();
    assert!(
        after.accuracy.unwrap() > before.accuracy.unwrap() + 0.1,
        "accuracy {:?} -> {:?}",
        before.accuracy,
        after.accuracy
    );
    assert!(after.accuracy.unwrap() > 0.3, "should beat 10% chance clearly");
}

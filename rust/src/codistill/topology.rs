//! Codistillation topologies (paper §4: "if pairs are useful then so are
//! other topologies. Fully connected graphs might make the models too
//! similar, too quickly so ring structures might also be interesting").
//!
//! A topology answers: which peers does member `i` distill from? The
//! paper's experiments use [`Topology::Pair`] (two-way); the ring and
//! fully-connected variants back the topology ablation bench.

/// Who teaches whom.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// Everyone distills from everyone else (Algorithm 1 verbatim).
    FullyConnected,
    /// Member i distills from member (i+1) mod n only.
    Ring,
    /// Disjoint pairs: (0,1), (2,3), ... Two-way codistillation when n=2.
    Pair,
}

impl Topology {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "full" | "fully-connected" => Some(Topology::FullyConnected),
            "ring" => Some(Topology::Ring),
            "pair" => Some(Topology::Pair),
            _ => None,
        }
    }

    /// Teacher set for member `i` of `n`.
    pub fn teachers_of(&self, i: usize, n: usize) -> Vec<usize> {
        assert!(i < n);
        match self {
            Topology::FullyConnected => (0..n).filter(|&j| j != i).collect(),
            Topology::Ring => {
                if n <= 1 {
                    vec![]
                } else {
                    vec![(i + 1) % n]
                }
            }
            Topology::Pair => {
                let partner = i ^ 1;
                if partner < n && partner != i {
                    vec![partner]
                } else {
                    vec![]
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fully_connected_excludes_self() {
        let t = Topology::FullyConnected;
        assert_eq!(t.teachers_of(1, 4), vec![0, 2, 3]);
        assert_eq!(t.teachers_of(0, 1), Vec::<usize>::new());
    }

    #[test]
    fn ring_is_single_successor() {
        let t = Topology::Ring;
        assert_eq!(t.teachers_of(0, 3), vec![1]);
        assert_eq!(t.teachers_of(2, 3), vec![0]);
        assert_eq!(t.teachers_of(0, 1), Vec::<usize>::new());
    }

    #[test]
    fn pair_matches_partners() {
        let t = Topology::Pair;
        assert_eq!(t.teachers_of(0, 2), vec![1]);
        assert_eq!(t.teachers_of(1, 2), vec![0]);
        assert_eq!(t.teachers_of(2, 4), vec![3]);
        // odd member count: last member has no partner
        assert_eq!(t.teachers_of(2, 3), Vec::<usize>::new());
    }

    #[test]
    fn every_topology_never_includes_self() {
        for t in [Topology::FullyConnected, Topology::Ring, Topology::Pair] {
            for n in 1..6 {
                for i in 0..n {
                    assert!(!t.teachers_of(i, n).contains(&i), "{t:?} n={n} i={i}");
                }
            }
        }
    }
}

//! Process-local coordinator: the §2.2 fault-tolerance story as a
//! first-class runner.
//!
//! [`Orchestrator::run`](crate::codistill::Orchestrator) drives every
//! member of a run in one lockstep loop over one transport handle — fine
//! for the paper's algorithmic figures, but none of the §2.2 scenarios
//! (stale teachers, slow or dead peers, members joining mid-run) can even
//! occur in it. A [`Coordinator`] instead hosts a *subset* of members in
//! this process (or thread) against a shared
//! [`ExchangeTransport`], with:
//!
//! * **No global lockstep.** Every hosted member advances on its own
//!   local step counter; several coordinators (one per OS process or
//!   thread) share one spool/socket exchange and never synchronize
//!   beyond the checkpoints themselves.
//! * **A liveness table** ([`LivenessTable`]) derived purely from publish
//!   recency: [`ExchangeTransport::last_steps`] heartbeats are polled on
//!   the reload cadence, and a peer whose freshest published step stops
//!   advancing for [`CoordinatorConfig::liveness_grace`] ticks is treated
//!   as dead — dropped from teacher sets instead of stalling the run.
//! * **Mid-run join.** A [`HostedMember`] with `join_delay > 0` sits out
//!   that many coordinator ticks, then bootstraps its parameters from the
//!   freshest peer checkpoint ([`Member::bootstrap`]) and enters the
//!   distillation ramp *at its own local step* — burn-in and ramp are
//!   member-local, exactly like a worker replacing a dead one in §2.2.
//! * **Publish-cadence skew.** Each hosted member has its own
//!   `publish_interval`/`publish_offset`, so exchanges are asynchronous
//!   by construction rather than by accident.
//! * **Fault-tolerant exchange calls.** Every transport operation is
//!   tolerated: a failed publish or teacher fetch is logged
//!   ([`CoordinatorLog::exchange_errors`], `skipped_teachers`) and the
//!   member trains on with whatever teachers it has — the delay-tolerance
//!   argument of §2.1 made executable. Only member-local compute errors
//!   abort a run.
//!
//! Pair a coordinator with a
//! [`Faulty`](crate::codistill::transport::Faulty)-wrapped transport and
//! every failure mode becomes a deterministic test scenario
//! (`tests/coordinator_faults.rs`); with a spool/socket transport and one
//! coordinator per process it is the ROADMAP's "true multi-process
//! orchestration".

use crate::codistill::obs::{render, Event, Recorder};
use crate::codistill::orchestrator::EvalPoint;
use crate::codistill::schedule::{DistillSchedule, LrSchedule};
use crate::codistill::topology::Topology;
use crate::codistill::transport::{
    Codec, DeltaCache, DeltaStats, ErrorFeedback, ExchangeTransport, FeedbackStats, RetryStats,
};
use crate::codistill::Member;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Coordinator parameters. Schedules apply to member-*local* steps.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Local steps each hosted member runs.
    pub total_steps: u64,
    /// Teacher reload cadence, in local steps.
    pub reload_interval: u64,
    pub eval_every: u64,
    pub distill: DistillSchedule,
    pub lr: LrSchedule,
    pub topology: Topology,
    /// Ticks a peer's freshest published step may stand still before the
    /// peer is considered dead (dropped from teacher sets). Should cover
    /// at least one publish interval plus one reload interval.
    pub liveness_grace: u64,
    pub seed: u64,
    /// Incremental (delta) teacher reloads: this coordinator keeps one
    /// installed plane per teacher (`transport::DeltaCache`, shared by
    /// its co-hosted members like the heartbeat polls are) and fetches
    /// only the windows whose content changed. Installed teachers are
    /// byte-identical to full fetches; only the exchange traffic shrinks.
    pub delta: bool,
    /// Codec the published planes are *prepared* under (see
    /// [`OrchestratorConfig::publish_codec`](crate::codistill::OrchestratorConfig::publish_codec)):
    /// lossy codecs quantize once, publisher-side, via [`ErrorFeedback`].
    pub publish_codec: Codec,
    /// Carry quantization residuals into the next publish (lossy
    /// `publish_codec` only).
    pub error_feedback: bool,
    pub verbose: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            total_steps: 400,
            reload_interval: 50,
            eval_every: 25,
            distill: DistillSchedule::new(100, 50, 1.0),
            lr: LrSchedule::Constant(0.1),
            topology: Topology::FullyConnected,
            liveness_grace: 120,
            seed: 0,
            delta: false,
            publish_codec: Codec::Raw,
            error_feedback: false,
            verbose: false,
        }
    }
}

/// One member hosted by this coordinator: a global id, the member itself,
/// and its local publish cadence / join / downtime schedule.
pub struct HostedMember {
    /// Global member id (unique across every coordinator on the exchange).
    pub id: usize,
    pub member: Box<dyn Member>,
    /// Publish every this many local steps (cadence skew: members need
    /// not agree).
    pub publish_interval: u64,
    /// Phase offset of the publish cadence, in local steps.
    pub publish_offset: u64,
    /// Coordinator ticks to sit out before joining the run (0 = from the
    /// start). A late joiner bootstraps from the freshest peer checkpoint.
    pub join_delay: u64,
    /// `[from_tick, until_tick)` windows during which the member is
    /// *gone* (a preemption): no training, no publishing, so its
    /// heartbeat freezes and peers drop it from teacher sets once the
    /// liveness grace runs out. On resume it re-bootstraps from a live
    /// peer and re-enters at its own local step. Scenario compilation
    /// (`codistill::scenario`) fills these for `spot_wave` patterns.
    pub downtimes: Vec<(u64, u64)>,
}

impl HostedMember {
    /// Host `member` as global `id` with the default cadence (publish
    /// every `reload_interval` steps, no skew, joins at the start).
    pub fn new(id: usize, member: Box<dyn Member>, publish_interval: u64) -> Self {
        HostedMember {
            id,
            member,
            publish_interval: publish_interval.max(1),
            publish_offset: 0,
            join_delay: 0,
            downtimes: Vec::new(),
        }
    }

    pub fn with_offset(mut self, offset: u64) -> Self {
        self.publish_offset = offset;
        self
    }

    pub fn with_join_delay(mut self, ticks: u64) -> Self {
        self.join_delay = ticks;
        self
    }

    /// Preempt the member over coordinator ticks `[from, until)`.
    pub fn with_downtime(mut self, from: u64, until: u64) -> Self {
        self.downtimes.push((from, until));
        self
    }

    /// Whether the member is preempted at `tick`.
    fn down_at(&self, tick: u64) -> bool {
        self.downtimes.iter().any(|&(f, u)| tick >= f && tick < u)
    }
}

/// Publish-recency liveness: a member is live while its freshest
/// published step keeps advancing. Built from
/// [`ExchangeTransport::last_steps`] heartbeats; no side channel exists —
/// exactly the information any peer on the exchange can observe.
#[derive(Debug, Default)]
pub struct LivenessTable {
    /// member -> (freshest published step, tick when it last advanced).
    seen: HashMap<usize, (u64, u64)>,
}

impl LivenessTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one round of heartbeats observed at `now` into the table.
    pub fn observe(&mut self, now: u64, heartbeats: &[(usize, u64)]) {
        for &(member, step) in heartbeats {
            match self.seen.get_mut(&member) {
                Some((last_step, last_advance)) => {
                    if step > *last_step {
                        *last_step = step;
                        *last_advance = now;
                    }
                }
                None => {
                    self.seen.insert(member, (step, now));
                }
            }
        }
    }

    /// Freshest published step this table has observed for a member.
    pub fn last_published(&self, member: usize) -> Option<u64> {
        self.seen.get(&member).map(|&(s, _)| s)
    }

    /// Whether a member's publications were still advancing within
    /// `grace` ticks of `now`. Unknown members are not live.
    ///
    /// The grace boundary is **inclusive**: a member whose step last
    /// advanced exactly `grace` ticks ago (`now - advanced == grace`) is
    /// still live; it dies one tick later. `grace = 0` therefore means
    /// "live only if it advanced this very tick", not "never live".
    /// [`LivenessTable::live_members`] uses the same convention, and the
    /// boundary is pinned by a unit test table — off-by-one drift here
    /// silently shrinks teacher sets one reload early.
    pub fn is_live(&self, member: usize, now: u64, grace: u64) -> bool {
        self.seen
            .get(&member)
            .map(|&(_, advanced)| now.saturating_sub(advanced) <= grace)
            .unwrap_or(false)
    }

    /// Every member ever observed, ascending.
    pub fn members(&self) -> Vec<usize> {
        let mut m: Vec<usize> = self.seen.keys().copied().collect();
        m.sort();
        m
    }

    /// Members live at `now`, ascending.
    pub fn live_members(&self, now: u64, grace: u64) -> Vec<usize> {
        let mut m: Vec<usize> = self
            .seen
            .iter()
            .filter(|(_, &(_, advanced))| now.saturating_sub(advanced) <= grace)
            .map(|(&id, _)| id)
            .collect();
        m.sort();
        m
    }
}

/// Teacher ids for `self_id` under `topology`, over the *live* member set
/// (dead peers are simply absent — the ring closes over survivors).
pub fn teachers_from_live(topology: Topology, self_id: usize, live: &[usize]) -> Vec<usize> {
    match topology {
        Topology::FullyConnected => live.iter().copied().filter(|&j| j != self_id).collect(),
        Topology::Ring => {
            let mut all: Vec<usize> = live.to_vec();
            if !all.contains(&self_id) {
                all.push(self_id);
                all.sort();
            }
            let idx = all.iter().position(|&j| j == self_id).unwrap();
            let next = all[(idx + 1) % all.len()];
            if next == self_id {
                vec![]
            } else {
                vec![next]
            }
        }
        Topology::Pair => {
            let partner = self_id ^ 1;
            if partner != self_id && live.contains(&partner) {
                vec![partner]
            } else {
                vec![]
            }
        }
    }
}

/// One member's mid-run join, and where it bootstrapped from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JoinRecord {
    pub tick: u64,
    pub member: usize,
    /// `(peer, peer step)` whose checkpoint seeded the joiner; `None`
    /// when no peer checkpoint was fetchable (cold start).
    pub bootstrapped_from: Option<(usize, u64)>,
}

/// Full record of one coordinator's run.
#[derive(Debug, Default)]
pub struct CoordinatorLog {
    /// Global ids of the hosted members, in hosted order.
    pub ids: Vec<usize>,
    /// Per-hosted-member validation curves (x = local step).
    pub eval: Vec<Vec<EvalPoint>>,
    /// (local step, member id, train loss, distill loss).
    pub train: Vec<(u64, usize, f32, f32)>,
    /// Observed teacher staleness at usage time: (local step, member id,
    /// staleness in local steps) — the byte-comparable reproducibility
    /// log (see [`CoordinatorLog::staleness_log_text`]).
    pub staleness: Vec<(u64, usize, u64)>,
    pub joins: Vec<JoinRecord>,
    /// Teachers skipped at a reload: (local step, member id, teacher id).
    pub skipped_teachers: Vec<(u64, usize, usize)>,
    /// Tolerated exchange failures: (tick, member id, error text).
    pub exchange_errors: Vec<(u64, usize, String)>,
    /// Delta-exchange traffic accounting (`Some` only for delta runs).
    pub delta: Option<DeltaStats>,
    /// Retry accounting (`Some` only when a
    /// [`Retry`](crate::codistill::transport::Retry) decorator is in the
    /// transport stack).
    pub retry: Option<RetryStats>,
    /// Publisher-side quantization accounting, summed over hosted
    /// members (`Some` only when `publish_codec` is lossy).
    pub feedback: Option<FeedbackStats>,
}

impl CoordinatorLog {
    /// Mean final validation loss over hosted members with eval points.
    pub fn final_mean_loss(&self) -> Option<f64> {
        let finals: Vec<f64> = self
            .eval
            .iter()
            .filter_map(|curve| curve.last().map(|p| p.loss))
            .collect();
        if finals.is_empty() {
            None
        } else {
            Some(finals.iter().sum::<f64>() / finals.len() as f64)
        }
    }

    /// Final validation loss of one hosted member by global id.
    pub fn final_loss_of(&self, id: usize) -> Option<f64> {
        let idx = self.ids.iter().position(|&i| i == id)?;
        self.eval[idx].last().map(|p| p.loss)
    }

    /// Canonical staleness log: one `step member staleness` line per
    /// sample, rendered through the shared `codistill::obs` renderer so
    /// the journal's replay of the same events is byte-identical. Two
    /// runs with the same seed, schedule, and fault plan must produce
    /// byte-identical text.
    pub fn staleness_log_text(&self) -> String {
        let mut out = String::new();
        for &(step, member, staleness) in &self.staleness {
            out.push_str(&render::staleness_line(step, member, staleness));
        }
        out
    }
}

/// Per-member progress the coordinator tracks between ticks.
struct MemberState {
    started: bool,
    done: bool,
    /// In a downtime window last tick (controls re-bootstrap on resume).
    gone: bool,
    local_step: u64,
    /// Freshest installed teacher checkpoint step, if any.
    installed: Option<u64>,
}

/// State shared by every hosted member within one coordinator run: the
/// liveness table persists across ticks; the per-tick flags coalesce
/// heartbeat polls and gc so co-hosted members on the same cadence cost
/// one transport round-trip, not one each.
struct RunShared {
    liveness: LivenessTable,
    /// Heartbeats already polled this tick.
    polled_this_tick: bool,
    /// Some(member) when a publish this tick wants a gc afterwards.
    gc_requested: Option<usize>,
    /// Per-teacher installed planes for delta reloads (`Some` only when
    /// `CoordinatorConfig::delta`), shared by co-hosted members.
    delta: Option<DeltaCache>,
    /// Per-hosted-member quantizing accumulators, keyed by global id
    /// (empty map when `publish_codec` is lossless — `prepare` would be
    /// a passthrough anyway, so none are created).
    feedback: HashMap<usize, ErrorFeedback>,
}

/// Drives the hosted members of ONE process/thread against a shared
/// exchange (see module docs). Multiple coordinators cooperate purely
/// through the transport.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    transport: Arc<dyn ExchangeTransport>,
    recorder: Option<Recorder>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig, transport: Arc<dyn ExchangeTransport>) -> Self {
        Coordinator {
            cfg,
            transport,
            recorder: None,
        }
    }

    /// Record the run into a `codistill::obs` journal: publishes,
    /// teacher fetches/installs (via the shared [`DeltaCache`]),
    /// publisher-side quantization, staleness samples, and mid-run
    /// join/rejoin decisions all become typed events. Pass the same
    /// recorder to the decorators in the transport stack to interleave
    /// their events in one trace.
    pub fn with_recorder(mut self, recorder: Recorder) -> Self {
        self.recorder = Some(recorder);
        self
    }

    pub fn transport(&self) -> &Arc<dyn ExchangeTransport> {
        &self.transport
    }

    /// Run every hosted member to `total_steps` local steps. Exchange
    /// failures are tolerated and logged; member compute failures abort.
    pub fn run(&self, hosted: &mut [HostedMember]) -> Result<CoordinatorLog> {
        let mut log = CoordinatorLog {
            ids: hosted.iter().map(|h| h.id).collect(),
            eval: vec![Vec::new(); hosted.len()],
            ..Default::default()
        };
        let mut states: Vec<MemberState> = hosted
            .iter()
            .map(|_| MemberState {
                started: false,
                done: false,
                gone: false,
                local_step: 0,
                installed: None,
            })
            .collect();
        let mut shared = RunShared {
            liveness: LivenessTable::new(),
            polled_this_tick: false,
            gc_requested: None,
            delta: self.cfg.delta.then(|| {
                let mut c = DeltaCache::new();
                if let Some(rec) = &self.recorder {
                    c = c.with_recorder(rec.clone());
                }
                c
            }),
            feedback: HashMap::new(),
        };

        let mut tick: u64 = 0;
        loop {
            let mut all_done = true;
            shared.polled_this_tick = false;
            shared.gc_requested = None;
            for (idx, h) in hosted.iter_mut().enumerate() {
                if states[idx].done {
                    continue;
                }
                all_done = false;
                if tick < h.join_delay {
                    continue;
                }
                if h.down_at(tick) {
                    // Preempted: no training, no publishing — its
                    // heartbeat freezes and peers age it out of teacher
                    // sets once the liveness grace runs out.
                    states[idx].gone = true;
                    continue;
                }
                if !states[idx].started {
                    states[idx].started = true;
                    self.join_member(h, tick, &mut shared, &mut log)?;
                } else if states[idx].gone {
                    // Back from preemption: re-bootstrap from a live peer
                    // (the dead-peer replacement of §2.2) and re-announce
                    // at the current local step.
                    states[idx].gone = false;
                    self.rejoin_member(h, states[idx].local_step, tick, &mut shared, &mut log)?;
                }
                self.drive_one_step(idx, h, &mut states[idx], tick, &mut shared, &mut log)?;
            }
            // One history-bound enforcement per tick, however many
            // members published.
            if let Some(id) = shared.gc_requested.take() {
                if let Err(e) = self.transport.gc() {
                    log.exchange_errors.push((tick, id, format!("{e:#}")));
                }
            }
            if all_done {
                break;
            }
            tick += 1;
        }
        // End-of-run drain: publications a decorator held back past their
        // member's final cadence (e.g. `Faulty`'s delayed publishes) land
        // now, so the final manifest contains every member's last
        // checkpoint. Tolerated like any other exchange call.
        if let Err(e) = self.transport.flush() {
            log.exchange_errors.push((tick, usize::MAX, format!("{e:#}")));
        }
        log.delta = shared.delta.as_ref().map(|c| c.stats());
        log.retry = self.transport.retry_stats();
        if self.cfg.publish_codec.is_lossy() {
            let mut total = FeedbackStats::default();
            for f in shared.feedback.values() {
                total.merge(&f.stats());
            }
            log.feedback = Some(total);
        }
        Ok(log)
    }

    /// Start (or late-join) one member: bootstrap from the freshest
    /// fetchable peer checkpoint when joining mid-run, then publish an
    /// initial snapshot so peers can hear the newcomer.
    fn join_member(
        &self,
        h: &mut HostedMember,
        tick: u64,
        shared: &mut RunShared,
        log: &mut CoordinatorLog,
    ) -> Result<()> {
        if h.join_delay > 0 {
            let bootstrapped_from = self.bootstrap_from_peer(h, tick, shared, log)?;
            log.joins.push(JoinRecord {
                tick,
                member: h.id,
                bootstrapped_from,
            });
            if let Some(rec) = &self.recorder {
                rec.record(Event::Rejoin {
                    tick,
                    member: h.id,
                    bootstrapped_from,
                });
            }
            if self.cfg.verbose {
                eprintln!(
                    "[coord] tick {tick}: member {} joined (bootstrap: {bootstrapped_from:?})",
                    h.id
                );
            }
        }
        // Initial publication (step = local step 0 for true joiners).
        self.publish_member(h, 0, tick, shared, log);
        Ok(())
    }

    /// Resume one member after a downtime window: re-bootstrap from a
    /// peer (its own parameters are a preemption old) and re-announce at
    /// the current local step so the heartbeat advances again.
    fn rejoin_member(
        &self,
        h: &mut HostedMember,
        local_step: u64,
        tick: u64,
        shared: &mut RunShared,
        log: &mut CoordinatorLog,
    ) -> Result<()> {
        let bootstrapped_from = self.bootstrap_from_peer(h, tick, shared, log)?;
        log.joins.push(JoinRecord {
            tick,
            member: h.id,
            bootstrapped_from,
        });
        if let Some(rec) = &self.recorder {
            rec.record(Event::Rejoin {
                tick,
                member: h.id,
                bootstrapped_from,
            });
        }
        if self.cfg.verbose {
            eprintln!(
                "[coord] tick {tick}: member {} resumed at local step {local_step} \
                 (bootstrap: {bootstrapped_from:?})",
                h.id
            );
        }
        self.publish_member(h, local_step, tick, shared, log);
        Ok(())
    }

    /// Fetch a bootstrap checkpoint for a joiner, tolerantly. Candidates
    /// are every heartbeating peer, tried freshest-first (ties to the
    /// lowest id): the freshest peer's payload may be blacked out,
    /// dropped, or gc'd away, and a joiner seeded by the *second*-freshest
    /// peer beats a cold start. Returns the `(peer, step)` that seeded the
    /// member, or `None` when nothing was fetchable (cold start).
    fn bootstrap_from_peer(
        &self,
        h: &mut HostedMember,
        tick: u64,
        shared: &mut RunShared,
        log: &mut CoordinatorLog,
    ) -> Result<Option<(usize, u64)>> {
        /// Payload fetches to try before giving up and starting cold.
        const BOOTSTRAP_CANDIDATES: usize = 3;
        let beats = match self.transport.last_steps() {
            Ok(beats) => {
                shared.polled_this_tick = true;
                shared.liveness.observe(tick, &beats);
                beats
            }
            Err(e) => {
                log.exchange_errors.push((tick, h.id, format!("{e:#}")));
                return Ok(None);
            }
        };
        let mut candidates: Vec<(usize, u64)> =
            beats.into_iter().filter(|&(m, _)| m != h.id).collect();
        candidates.sort_by_key(|&(m, s)| (std::cmp::Reverse(s), m));
        for &(peer, _) in candidates.iter().take(BOOTSTRAP_CANDIDATES) {
            match self.transport.latest(peer) {
                Ok(Some(ck)) => {
                    h.member
                        .bootstrap(&ck)
                        .with_context(|| format!("bootstrapping member {}", h.id))?;
                    return Ok(Some((peer, ck.step)));
                }
                // Nothing fetchable from this peer (blackout, drop, gc):
                // fall through to the next-freshest.
                Ok(None) => {}
                Err(e) => log.exchange_errors.push((tick, h.id, format!("{e:#}"))),
            }
        }
        Ok(None)
    }

    /// One local step of one hosted member: reload teachers on the
    /// cadence, train, publish on the (skewed) cadence, evaluate.
    fn drive_one_step(
        &self,
        idx: usize,
        h: &mut HostedMember,
        st: &mut MemberState,
        tick: u64,
        shared: &mut RunShared,
        log: &mut CoordinatorLog,
    ) -> Result<()> {
        let cfg = &self.cfg;

        if st.local_step % cfg.reload_interval == 0 {
            self.reload_teachers(h, st, tick, shared, log)?;
        }
        if let Some(tstep) = st.installed {
            let staleness = st.local_step.saturating_sub(tstep);
            log.staleness.push((st.local_step, h.id, staleness));
            if let Some(rec) = &self.recorder {
                rec.record(Event::Staleness {
                    step: st.local_step,
                    member: h.id,
                    staleness,
                });
            }
        }

        let w = cfg.distill.weight_at(st.local_step);
        let lr = cfg.lr.at(st.local_step);
        let stats = h
            .member
            .train_step(w, lr)
            .with_context(|| format!("member {} local step {}", h.id, st.local_step))?;
        log.train
            .push((st.local_step, h.id, stats.loss, stats.distill_loss));
        st.local_step += 1;

        if (st.local_step + h.publish_offset) % h.publish_interval == 0 {
            self.publish_member(h, st.local_step, tick, shared, log);
            shared.gc_requested = Some(h.id);
        }

        if st.local_step % cfg.eval_every == 0 || st.local_step == cfg.total_steps {
            let eval = h.member.evaluate()?;
            log.eval[idx].push(EvalPoint {
                step: st.local_step,
                wall_s: 0.0,
                loss: eval.loss,
                accuracy: eval.accuracy,
            });
            if cfg.verbose {
                eprintln!(
                    "[coord] member {} local step {:>6} val_loss={:.4} w={w:.2}",
                    h.id, st.local_step, eval.loss
                );
            }
        }

        if st.local_step >= cfg.total_steps {
            st.done = true;
        }
        Ok(())
    }

    /// Refresh the liveness table and install the live teachers' freshest
    /// checkpoints. Every failure is tolerated: a dead or faulty teacher
    /// is skipped, and the member keeps its previously installed set.
    fn reload_teachers(
        &self,
        h: &mut HostedMember,
        st: &mut MemberState,
        tick: u64,
        shared: &mut RunShared,
        log: &mut CoordinatorLog,
    ) -> Result<()> {
        let cfg = &self.cfg;
        // One heartbeat poll per tick, shared by every co-hosted member
        // reloading on it.
        if !shared.polled_this_tick {
            shared.polled_this_tick = true;
            match self.transport.last_steps() {
                Ok(beats) => shared.liveness.observe(tick, &beats),
                Err(e) => log.exchange_errors.push((tick, h.id, format!("{e:#}"))),
            }
        }
        let live = shared.liveness.live_members(tick, cfg.liveness_grace);
        let teacher_ids = teachers_from_live(cfg.topology, h.id, &live);
        if teacher_ids.is_empty() {
            return Ok(());
        }
        let mut peers = Vec::with_capacity(teacher_ids.len());
        for j in teacher_ids {
            let fetched = match shared.delta.as_mut() {
                Some(cache) => cache.latest(self.transport.as_ref(), j),
                None => self.transport.latest(j),
            };
            match fetched {
                Ok(Some(ck)) => peers.push(ck),
                Ok(None) => log.skipped_teachers.push((st.local_step, h.id, j)),
                Err(e) => {
                    log.skipped_teachers.push((st.local_step, h.id, j));
                    log.exchange_errors.push((tick, h.id, format!("{e:#}")));
                }
            }
        }
        if peers.is_empty() {
            // Nothing fetchable this round: train on with the old set.
            return Ok(());
        }
        st.installed = peers.iter().map(|c| c.step).max();
        h.member.set_teachers(peers)?;
        Ok(())
    }

    /// Publish a member's snapshot, tolerating exchange failures. With a
    /// lossy `publish_codec` the snapshot is quantized (and, with
    /// `error_feedback`, residual-corrected) here, through the member's
    /// own accumulator, before it ever reaches the transport.
    fn publish_member(
        &self,
        h: &HostedMember,
        step: u64,
        tick: u64,
        shared: &mut RunShared,
        log: &mut CoordinatorLog,
    ) {
        let ck = match h.member.snapshot() {
            Ok(mut ck) => {
                ck.member = h.id;
                ck.step = step;
                ck
            }
            Err(e) => {
                log.exchange_errors.push((tick, h.id, format!("{e:#}")));
                return;
            }
        };
        let ck = if self.cfg.publish_codec.is_lossy() {
            let fb = shared.feedback.entry(h.id).or_insert_with(|| {
                let mut f = ErrorFeedback::new(self.cfg.publish_codec, self.cfg.error_feedback);
                if let Some(rec) = &self.recorder {
                    f = f.with_recorder(rec.clone());
                }
                f
            });
            match fb.prepare(ck) {
                Ok(ck) => ck,
                Err(e) => {
                    log.exchange_errors.push((tick, h.id, format!("{e:#}")));
                    return;
                }
            }
        } else {
            ck
        };
        // Journal accounting rides the successful path only: a publish
        // the transport rejected never landed, so it is an exchange
        // error, not a publish event.
        let (member, ck_step) = (ck.member, ck.step);
        let bytes = ck.flat().layout().total_bytes() as u64;
        let t0 = self.recorder.as_ref().map(|r| r.now_us());
        match self.transport.publish(ck) {
            Ok(()) => {
                if let (Some(rec), Some(t0)) = (&self.recorder, t0) {
                    let t1 = rec.now_us();
                    rec.record_at(
                        t0,
                        Event::Publish {
                            member,
                            step: ck_step,
                            bytes,
                            dur_us: t1.saturating_sub(t0),
                        },
                    );
                }
            }
            Err(e) => log.exchange_errors.push((tick, h.id, format!("{e:#}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn liveness_tracks_publish_recency() {
        let mut t = LivenessTable::new();
        t.observe(0, &[(0, 10), (1, 10)]);
        assert!(t.is_live(0, 5, 10));
        assert!(!t.is_live(2, 5, 10), "never-seen member live");
        // member 0 keeps advancing, member 1 goes silent
        t.observe(20, &[(0, 30), (1, 10)]);
        t.observe(40, &[(0, 50), (1, 10)]);
        assert!(t.is_live(0, 45, 10));
        assert!(!t.is_live(1, 45, 10), "silent member still live");
        assert_eq!(t.live_members(45, 10), vec![0]);
        assert_eq!(t.members(), vec![0, 1]);
        assert_eq!(t.last_published(1), Some(10));
        // the silent member publishes again: live again
        t.observe(60, &[(1, 70)]);
        assert!(t.is_live(1, 65, 10));
    }

    #[test]
    fn liveness_grace_boundary_is_inclusive() {
        let mut t = LivenessTable::new();
        t.observe(10, &[(0, 100)]); // advanced at tick 10
        // (now, grace, expected): the documented inclusive convention —
        // live while now - advanced <= grace, dead one tick later.
        let table = [
            (10, 0, true),   // advanced this very tick, zero grace
            (11, 0, false),  // one tick late under zero grace
            (15, 5, true),   // exactly at now - grace: still live
            (16, 5, false),  // one past the boundary: dead
            (9, 5, true),    // observed "in the future" (cross-coordinator
                             // tick skew): saturating_sub keeps it live
            (u64::MAX, u64::MAX, true), // no overflow at the extremes
        ];
        for (now, grace, expect) in table {
            assert_eq!(
                t.is_live(0, now, grace),
                expect,
                "is_live(now={now}, grace={grace})"
            );
            assert_eq!(
                t.live_members(now, grace) == vec![0],
                expect,
                "live_members(now={now}, grace={grace}) disagrees with is_live"
            );
        }
        // never-seen members are dead under any grace
        assert!(!t.is_live(7, 10, u64::MAX));
    }

    #[test]
    fn teachers_from_live_adapts_to_deaths() {
        use Topology::*;
        // fully connected: everyone live except self
        assert_eq!(teachers_from_live(FullyConnected, 1, &[0, 1, 2, 3]), vec![0, 2, 3]);
        assert_eq!(teachers_from_live(FullyConnected, 1, &[1]), Vec::<usize>::new());
        // ring closes over survivors
        assert_eq!(teachers_from_live(Ring, 0, &[0, 1, 2]), vec![1]);
        assert_eq!(teachers_from_live(Ring, 0, &[0, 2]), vec![2]);
        assert_eq!(teachers_from_live(Ring, 2, &[0, 2]), vec![0]);
        assert_eq!(teachers_from_live(Ring, 0, &[0]), Vec::<usize>::new());
        // a ring member whose own publishes are blacked out still teaches
        // from the next live peer
        assert_eq!(teachers_from_live(Ring, 1, &[0, 2]), vec![2]);
        // pairs only teach while the partner is live
        assert_eq!(teachers_from_live(Pair, 0, &[0, 1]), vec![1]);
        assert_eq!(teachers_from_live(Pair, 0, &[0, 2]), Vec::<usize>::new());
        assert_eq!(teachers_from_live(Pair, 3, &[2, 3]), vec![2]);
    }
}

//! `codistill::obs` — one typed event journal for every subsystem.
//!
//! Nine PRs grew nine parallel accounting mechanisms (`RetryStats`,
//! `DeltaStats`, `FeedbackStats`, `RelayStats`, `SubscribeStats`, the
//! `Faulty` fault log, `CoordinatorLog`/`RunLog`, `ServeStats`) — each
//! with its own counters, merge rules, and text renderer, all proving
//! the same paper claim: same seed ⇒ byte-identical replay (§3.5 of
//! Anil et al.). This module unifies them behind a [`Recorder`]:
//!
//! * a typed [`Event`] stream with monotonic timestamps from a
//!   [`Clock`] — [`WallClock`] for real runs (so `netsim::calibrate`
//!   can fit per-byte costs from measured durations), a seeded
//!   [`SimClock`] for tests (so the dumped trace itself is
//!   byte-deterministic);
//! * a string-keyed counter registry (see [`keys`]) for totals that are
//!   not per-event (poll counts, retry op totals) — the legacy `*Stats`
//!   types become thin views folded from the journal;
//! * one shared [`render`] module that re-derives every pinned replay
//!   text (`retry_log_text`, `fault_log_text`, `staleness_log_text`,
//!   the serve swap log) byte-identical to the pre-refactor output.
//!
//! The JSONL dump ([`Recorder::to_jsonl`]) contains **events only** —
//! counters are excluded on purpose, because timing-dependent totals
//! (e.g. subscription poll counts) must not break trace byte-identity.
//! [`EventJournal::from_jsonl`] reads the dump back; unknown `ev` kinds
//! are skipped so traces stay forward-compatible.
//!
//! Every subsystem defaults to a private `Recorder::sim(its seed)` so
//! behavior and replay logs are unchanged when no run-level recorder is
//! injected; the `--trace FILE` CLI flag threads one shared recorder
//! through the whole stack and dumps it on exit.

use crate::codistill::transport::feedback::FeedbackStats;
use crate::codistill::transport::retry::RetryStats;
use crate::codistill::transport::{DeltaStats, FaultEvent, FaultKind};
use crate::prng::Pcg64;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Counter-registry keys used by the refactored subsystems. Collected
/// here so views ([`EventJournal::retry_stats`]) and writers
/// (`transport::Retry`) cannot drift apart.
pub mod keys {
    pub const RETRY_OPS: &str = "retry.ops";
    pub const RETRY_ATTEMPTS: &str = "retry.attempts";
    pub const RETRY_TRANSIENT: &str = "retry.transient_errors";
    pub const RETRY_EMPTY: &str = "retry.empty_retries";
    pub const RETRY_ABSORBED: &str = "retry.absorbed";
    pub const RETRY_EXHAUSTED: &str = "retry.exhausted";
    pub const RETRY_EXHAUSTED_EMPTY: &str = "retry.exhausted_empty";
    pub const RETRY_PERMANENT: &str = "retry.permanent_errors";
    pub const SUB_POLLS: &str = "sub.polls";
    pub const SUB_FETCHES: &str = "sub.fetches";
    pub const SUB_INSTALLS: &str = "sub.installs";
    pub const SUB_TOLERATED: &str = "sub.tolerated_errors";
    pub const RELAY_POLLS: &str = "relay.polls";
    pub const RELAY_INSTALLS: &str = "relay.installs";
    pub const RELAY_TOLERATED: &str = "relay.tolerated_errors";
    pub const RELAY_PASSTHROUGH: &str = "relay.passthrough_fetches";
    pub const RELAY_FORWARDED: &str = "relay.forwarded_publishes";
}

/// Monotonic microsecond clock. `Send + Sync` so one clock can stamp
/// events from every thread of a run.
pub trait Clock: Send + Sync {
    /// Microseconds since some fixed origin; must be non-decreasing.
    fn now_us(&self) -> u64;
}

/// Real time since the clock was created — use for measured runs whose
/// traces feed `netsim::calibrate`.
#[derive(Debug)]
pub struct WallClock {
    t0: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock { t0: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now_us(&self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }
}

/// Deterministic clock: every call advances a seeded PRNG by 1..=128
/// microseconds, so the Nth call always returns the same timestamp for
/// the same seed. Same-seed runs therefore dump byte-identical traces.
pub struct SimClock {
    state: Mutex<(u64, Pcg64)>,
}

/// Stream key separating the sim clock from every other consumer of a
/// run's seed (fault plans, retry backoff, load generators).
const SIM_CLOCK_STREAM: u64 = 0x0b5e_7a11_c10c_0b5e;

impl SimClock {
    pub fn new(seed: u64) -> Self {
        SimClock {
            state: Mutex::new((0, Pcg64::with_stream(seed, SIM_CLOCK_STREAM))),
        }
    }
}

impl Clock for SimClock {
    fn now_us(&self) -> u64 {
        let mut g = self.state.lock().expect("sim clock lock");
        let step = 1 + (g.1.uniform() * 127.0) as u64;
        g.0 += step;
        g.0
    }
}

/// One observation. Fields mirror what the legacy per-subsystem logs
/// recorded, so the shared [`render`] functions can re-derive those
/// texts byte-identically.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A checkpoint left a publisher (`bytes` = plane payload bytes,
    /// `dur_us` = measured wall time of the transport publish; 0 when
    /// the publish is recorded before the call for ordering reasons).
    Publish { member: usize, step: u64, bytes: u64, dur_us: u64 },
    /// A teacher checkpoint was fetched (`bytes` = wire payload moved).
    Fetch { member: usize, step: u64, bytes: u64, dur_us: u64 },
    /// A fetched checkpoint was installed into a `DeltaCache` plane.
    DeltaInstall {
        member: usize,
        step: u64,
        full: bool,
        moved: u64,
        unchanged: u64,
        encoded: u64,
        bytes: u64,
    },
    /// One logged attempt inside `transport::Retry` (`what` ∈
    /// transient | empty | permanent | exhausted | absorbed).
    RetryAttempt { op: u64, member: usize, attempt: u32, what: &'static str },
    /// `transport::Faulty` fired an injected fault.
    FaultDecision { kind: FaultKind, member: usize, salt: u64 },
    /// Lossy publish accounting from `ErrorFeedback::prepare` (deltas
    /// for this one publish, not running totals; `residual_l2` /
    /// `max_abs_bias` are the accumulator state after the publish).
    Quantize {
        member: usize,
        step: u64,
        windows_quantized: u64,
        windows_raw: u64,
        bytes_quantized: u64,
        bytes_raw_equiv: u64,
        residual_l2: f64,
        max_abs_bias: f64,
    },
    /// A serving-tier hot swap (digests are the plane content hashes
    /// the churn log prints).
    Swap {
        index: u64,
        from_step: u64,
        to_step: u64,
        from_digest: u64,
        to_digest: u64,
        churn: f64,
    },
    /// A coordinator member (re)joined mid-run.
    Rejoin { tick: u64, member: usize, bootstrapped_from: Option<(usize, u64)> },
    /// Teacher staleness observed at a training step (the
    /// `staleness_log_text` tuple).
    Staleness { step: u64, member: usize, staleness: u64 },
    /// A relay forwarded a downstream publish to its upstream.
    RelayForward { member: usize, step: u64 },
}

/// An [`Event`] plus its clock stamp.
#[derive(Debug, Clone, PartialEq)]
pub struct TimedEvent {
    pub t_us: u64,
    pub event: Event,
}

/// A snapshot of everything a [`Recorder`] collected: the ordered event
/// stream plus the counter registry.
#[derive(Debug, Clone, Default)]
pub struct EventJournal {
    pub events: Vec<TimedEvent>,
    pub counters: BTreeMap<String, u64>,
}

struct Inner {
    clock: Box<dyn Clock>,
    journal: Mutex<EventJournal>,
}

/// Cloneable handle to one shared journal. Cloning is cheap (one `Arc`
/// bump); every clone records into the same event stream, which is what
/// lets a run-level `--trace` recorder see the whole stack.
#[derive(Clone)]
pub struct Recorder {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for Recorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let j = self.inner.journal.lock().expect("journal lock");
        f.debug_struct("Recorder")
            .field("events", &j.events.len())
            .field("counters", &j.counters.len())
            .finish()
    }
}

impl Recorder {
    /// Recorder over a [`WallClock`] — measured runs, calibration traces.
    pub fn wall() -> Self {
        Self::with_clock(Box::new(WallClock::new()))
    }

    /// Recorder over a seeded [`SimClock`] — deterministic test traces.
    pub fn sim(seed: u64) -> Self {
        Self::with_clock(Box::new(SimClock::new(seed)))
    }

    pub fn with_clock(clock: Box<dyn Clock>) -> Self {
        Recorder {
            inner: Arc::new(Inner {
                clock,
                journal: Mutex::new(EventJournal::default()),
            }),
        }
    }

    /// Read the clock without recording — callers time an operation
    /// with `now_us`, then stamp the event at its start time via
    /// [`Recorder::record_at`].
    pub fn now_us(&self) -> u64 {
        self.inner.clock.now_us()
    }

    /// Record `event` stamped with the current clock reading.
    pub fn record(&self, event: Event) {
        let t_us = self.inner.clock.now_us();
        self.record_at(t_us, event);
    }

    /// Record `event` with an explicit timestamp (from a prior
    /// [`Recorder::now_us`] call). Events keep append order; timestamps
    /// of concurrently recorded events may interleave.
    pub fn record_at(&self, t_us: u64, event: Event) {
        let mut j = self.inner.journal.lock().expect("journal lock");
        j.events.push(TimedEvent { t_us, event });
    }

    /// Bump a registry counter (creating it at zero first).
    pub fn incr(&self, key: &str, by: u64) {
        let mut j = self.inner.journal.lock().expect("journal lock");
        *j.counters.entry(key.to_string()).or_insert(0) += by;
    }

    /// Current value of a registry counter (0 if never bumped).
    pub fn counter(&self, key: &str) -> u64 {
        let j = self.inner.journal.lock().expect("journal lock");
        j.counters.get(key).copied().unwrap_or(0)
    }

    /// Snapshot the whole journal (events + counters).
    pub fn journal(&self) -> EventJournal {
        self.inner.journal.lock().expect("journal lock").clone()
    }

    pub fn len(&self) -> usize {
        self.inner.journal.lock().expect("journal lock").events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serialize the **event stream** as JSONL (counters are excluded —
    /// see the module docs on trace byte-identity).
    pub fn to_jsonl(&self) -> String {
        self.journal().to_jsonl()
    }
}

/// Write a finite f64 in round-trip form; non-finite values (which
/// would be invalid JSON) degrade to 0.0.
fn fmt_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        let _ = write!(out, "{v:?}");
    } else {
        out.push_str("0.0");
    }
}

impl EventJournal {
    /// One JSON object per event, in append order, `\n`-terminated.
    /// Field order is fixed, so same-seed journals serialize to
    /// byte-identical text.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for te in &self.events {
            let t = te.t_us;
            match &te.event {
                Event::Publish { member, step, bytes, dur_us } => {
                    let _ = write!(
                        out,
                        "{{\"t_us\":{t},\"ev\":\"publish\",\"member\":{member},\"step\":{step},\"bytes\":{bytes},\"dur_us\":{dur_us}}}"
                    );
                }
                Event::Fetch { member, step, bytes, dur_us } => {
                    let _ = write!(
                        out,
                        "{{\"t_us\":{t},\"ev\":\"fetch\",\"member\":{member},\"step\":{step},\"bytes\":{bytes},\"dur_us\":{dur_us}}}"
                    );
                }
                Event::DeltaInstall { member, step, full, moved, unchanged, encoded, bytes } => {
                    let _ = write!(
                        out,
                        "{{\"t_us\":{t},\"ev\":\"delta_install\",\"member\":{member},\"step\":{step},\"full\":{full},\"moved\":{moved},\"unchanged\":{unchanged},\"encoded\":{encoded},\"bytes\":{bytes}}}"
                    );
                }
                Event::RetryAttempt { op, member, attempt, what } => {
                    let _ = write!(
                        out,
                        "{{\"t_us\":{t},\"ev\":\"retry\",\"op\":{op},\"member\":{member},\"attempt\":{attempt},\"what\":\"{what}\"}}"
                    );
                }
                Event::FaultDecision { kind, member, salt } => {
                    let _ = write!(
                        out,
                        "{{\"t_us\":{t},\"ev\":\"fault\",\"kind\":\"{}\",\"member\":{member},\"salt\":{salt}}}",
                        kind.name()
                    );
                }
                Event::Quantize {
                    member,
                    step,
                    windows_quantized,
                    windows_raw,
                    bytes_quantized,
                    bytes_raw_equiv,
                    residual_l2,
                    max_abs_bias,
                } => {
                    let _ = write!(
                        out,
                        "{{\"t_us\":{t},\"ev\":\"quantize\",\"member\":{member},\"step\":{step},\"windows_quantized\":{windows_quantized},\"windows_raw\":{windows_raw},\"bytes_quantized\":{bytes_quantized},\"bytes_raw_equiv\":{bytes_raw_equiv},\"residual_l2\":"
                    );
                    fmt_f64(&mut out, *residual_l2);
                    out.push_str(",\"max_abs_bias\":");
                    fmt_f64(&mut out, *max_abs_bias);
                    out.push('}');
                }
                Event::Swap { index, from_step, to_step, from_digest, to_digest, churn } => {
                    let _ = write!(
                        out,
                        "{{\"t_us\":{t},\"ev\":\"swap\",\"index\":{index},\"from_step\":{from_step},\"to_step\":{to_step},\"from_digest\":\"{from_digest:016x}\",\"to_digest\":\"{to_digest:016x}\",\"churn\":"
                    );
                    fmt_f64(&mut out, *churn);
                    out.push('}');
                }
                Event::Rejoin { tick, member, bootstrapped_from } => {
                    match bootstrapped_from {
                        Some((peer, step)) => {
                            let _ = write!(
                                out,
                                "{{\"t_us\":{t},\"ev\":\"rejoin\",\"tick\":{tick},\"member\":{member},\"from_peer\":{peer},\"from_step\":{step}}}"
                            );
                        }
                        None => {
                            let _ = write!(
                                out,
                                "{{\"t_us\":{t},\"ev\":\"rejoin\",\"tick\":{tick},\"member\":{member},\"from_peer\":null}}"
                            );
                        }
                    }
                }
                Event::Staleness { step, member, staleness } => {
                    let _ = write!(
                        out,
                        "{{\"t_us\":{t},\"ev\":\"staleness\",\"step\":{step},\"member\":{member},\"staleness\":{staleness}}}"
                    );
                }
                Event::RelayForward { member, step } => {
                    let _ = write!(
                        out,
                        "{{\"t_us\":{t},\"ev\":\"relay_forward\",\"member\":{member},\"step\":{step}}}"
                    );
                }
            }
            out.push('\n');
        }
        out
    }

    /// Parse a JSONL trace back into a journal (events only — counters
    /// are never serialized). Blank lines and unknown `ev` kinds are
    /// skipped; structurally broken lines error.
    pub fn from_jsonl(text: &str) -> Result<EventJournal> {
        let mut journal = EventJournal::default();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let parse = |j: &mut EventJournal| -> Result<()> {
                let t_us = u64_field(line, "t_us")?;
                let ev = str_field(line, "ev")?;
                let event = match ev {
                    "publish" => Event::Publish {
                        member: usize_field(line, "member")?,
                        step: u64_field(line, "step")?,
                        bytes: u64_field(line, "bytes")?,
                        dur_us: u64_field(line, "dur_us")?,
                    },
                    "fetch" => Event::Fetch {
                        member: usize_field(line, "member")?,
                        step: u64_field(line, "step")?,
                        bytes: u64_field(line, "bytes")?,
                        dur_us: u64_field(line, "dur_us")?,
                    },
                    "delta_install" => Event::DeltaInstall {
                        member: usize_field(line, "member")?,
                        step: u64_field(line, "step")?,
                        full: bool_field(line, "full")?,
                        moved: u64_field(line, "moved")?,
                        unchanged: u64_field(line, "unchanged")?,
                        encoded: u64_field(line, "encoded")?,
                        bytes: u64_field(line, "bytes")?,
                    },
                    "retry" => Event::RetryAttempt {
                        op: u64_field(line, "op")?,
                        member: usize_field(line, "member")?,
                        attempt: u64_field(line, "attempt")? as u32,
                        what: retry_what(str_field(line, "what")?)?,
                    },
                    "fault" => Event::FaultDecision {
                        kind: fault_kind(str_field(line, "kind")?)?,
                        member: usize_field(line, "member")?,
                        salt: u64_field(line, "salt")?,
                    },
                    "quantize" => Event::Quantize {
                        member: usize_field(line, "member")?,
                        step: u64_field(line, "step")?,
                        windows_quantized: u64_field(line, "windows_quantized")?,
                        windows_raw: u64_field(line, "windows_raw")?,
                        bytes_quantized: u64_field(line, "bytes_quantized")?,
                        bytes_raw_equiv: u64_field(line, "bytes_raw_equiv")?,
                        residual_l2: f64_field(line, "residual_l2")?,
                        max_abs_bias: f64_field(line, "max_abs_bias")?,
                    },
                    "swap" => Event::Swap {
                        index: u64_field(line, "index")?,
                        from_step: u64_field(line, "from_step")?,
                        to_step: u64_field(line, "to_step")?,
                        from_digest: hex_field(line, "from_digest")?,
                        to_digest: hex_field(line, "to_digest")?,
                        churn: f64_field(line, "churn")?,
                    },
                    "rejoin" => {
                        let peer = opt_usize_field(line, "from_peer")?;
                        let bootstrapped_from = match peer {
                            Some(p) => Some((p, u64_field(line, "from_step")?)),
                            None => None,
                        };
                        Event::Rejoin {
                            tick: u64_field(line, "tick")?,
                            member: usize_field(line, "member")?,
                            bootstrapped_from,
                        }
                    }
                    "staleness" => Event::Staleness {
                        step: u64_field(line, "step")?,
                        member: usize_field(line, "member")?,
                        staleness: u64_field(line, "staleness")?,
                    },
                    "relay_forward" => Event::RelayForward {
                        member: usize_field(line, "member")?,
                        step: u64_field(line, "step")?,
                    },
                    // Forward compatibility: unknown event kinds skip.
                    _ => return Ok(()),
                };
                j.events.push(TimedEvent { t_us, event });
                Ok(())
            };
            parse(&mut journal).with_context(|| format!("trace line {}", ln + 1))?;
        }
        Ok(journal)
    }

    /// The retry replay log, byte-identical to the pre-refactor
    /// `Retry::retry_log_text` (one `"{op} {member} {attempt} {what}"`
    /// line per logged attempt).
    pub fn retry_log_text(&self) -> String {
        let mut out = String::new();
        for te in &self.events {
            if let Event::RetryAttempt { op, member, attempt, what } = &te.event {
                out.push_str(&render::retry_line(*op, *member, *attempt, what));
            }
        }
        out
    }

    /// Injected faults in decision order, as `transport::FaultEvent`s.
    pub fn fault_events(&self) -> Vec<FaultEvent> {
        self.events
            .iter()
            .filter_map(|te| match &te.event {
                Event::FaultDecision { kind, member, salt } => Some(FaultEvent {
                    kind: *kind,
                    member: *member,
                    salt: *salt,
                }),
                _ => None,
            })
            .collect()
    }

    /// The fault replay log, byte-identical to the pre-refactor
    /// `Faulty::fault_log_text`.
    pub fn fault_log_text(&self) -> String {
        let mut out = String::new();
        for te in &self.events {
            if let Event::FaultDecision { kind, member, salt } = &te.event {
                out.push_str(&render::fault_line(kind.name(), *member, *salt));
            }
        }
        out
    }

    /// Staleness replay text, byte-identical to
    /// `CoordinatorLog::staleness_log_text`.
    pub fn staleness_log_text(&self) -> String {
        let mut out = String::new();
        for te in &self.events {
            if let Event::Staleness { step, member, staleness } = &te.event {
                out.push_str(&render::staleness_line(*step, *member, *staleness));
            }
        }
        out
    }

    /// The serve churn log, byte-identical to the text
    /// `InferenceServer` accumulates across hot swaps.
    pub fn swap_log_text(&self) -> String {
        let mut out = String::new();
        for te in &self.events {
            if let Event::Swap { index, from_step, to_step, from_digest, to_digest, churn } =
                &te.event
            {
                out.push_str(&render::swap_line(
                    *index,
                    *from_step,
                    *to_step,
                    *from_digest,
                    *to_digest,
                    *churn,
                ));
            }
        }
        out
    }

    /// `RetryStats` view over the counter registry (zeros for a journal
    /// parsed from JSONL, which carries no counters).
    pub fn retry_stats(&self) -> RetryStats {
        let c = |k: &str| self.counters.get(k).copied().unwrap_or(0);
        RetryStats {
            ops: c(keys::RETRY_OPS),
            attempts: c(keys::RETRY_ATTEMPTS),
            transient_errors: c(keys::RETRY_TRANSIENT),
            empty_retries: c(keys::RETRY_EMPTY),
            absorbed: c(keys::RETRY_ABSORBED),
            exhausted: c(keys::RETRY_EXHAUSTED),
            exhausted_empty: c(keys::RETRY_EXHAUSTED_EMPTY),
            permanent_errors: c(keys::RETRY_PERMANENT),
        }
    }

    /// `DeltaStats` view folded from the delta-install events.
    pub fn delta_stats(&self) -> DeltaStats {
        let mut d = DeltaStats::default();
        for te in &self.events {
            if let Event::DeltaInstall { full, moved, unchanged, encoded, bytes, .. } = &te.event {
                if *full {
                    d.full_fetches += 1;
                } else {
                    d.delta_fetches += 1;
                }
                d.windows_moved += *moved;
                d.windows_unchanged += *unchanged;
                d.windows_encoded += *encoded;
                d.payload_bytes += *bytes;
            }
        }
        d
    }

    /// `FeedbackStats` view folded from the quantize events (matches
    /// `FeedbackStats::merge` semantics: sums for totals, last residual
    /// per member then max across members, max bias).
    pub fn feedback_stats(&self) -> FeedbackStats {
        let mut s = FeedbackStats::default();
        let mut last_residual: BTreeMap<usize, f64> = BTreeMap::new();
        for te in &self.events {
            if let Event::Quantize {
                member,
                windows_quantized,
                windows_raw,
                bytes_quantized,
                bytes_raw_equiv,
                residual_l2,
                max_abs_bias,
                ..
            } = &te.event
            {
                s.publishes += 1;
                s.windows_quantized += *windows_quantized;
                s.windows_raw += *windows_raw;
                s.bytes_quantized += *bytes_quantized;
                s.bytes_raw_equiv += *bytes_raw_equiv;
                s.max_abs_bias = s.max_abs_bias.max(*max_abs_bias);
                last_residual.insert(*member, *residual_l2);
            }
        }
        s.last_residual_l2 = last_residual.values().fold(0.0, |a, &b| a.max(b));
        s
    }
}

/// The one renderer for every pinned replay-text format. The byte
/// layouts here are load-bearing: `tests/scenario_churn.rs`,
/// `tests/coordinator_faults.rs`, and the serve hot-swap suite compare
/// these strings across same-seed runs.
pub mod render {
    /// `"{op} {member} {attempt} {what}\n"` — the retry log line.
    pub fn retry_line(op: u64, member: usize, attempt: u32, what: &str) -> String {
        format!("{op} {member} {attempt} {what}\n")
    }

    /// `"{kind} {member} {salt}\n"` — the fault log line.
    pub fn fault_line(kind: &str, member: usize, salt: u64) -> String {
        format!("{kind} {member} {salt}\n")
    }

    /// `"{step} {member} {staleness}\n"` — the staleness log line.
    pub fn staleness_line(step: u64, member: usize, staleness: u64) -> String {
        format!("{step} {member} {staleness}\n")
    }

    /// The serve churn-log swap line.
    pub fn swap_line(
        index: u64,
        from_step: u64,
        to_step: u64,
        from_digest: u64,
        to_digest: u64,
        churn: f64,
    ) -> String {
        format!(
            "swap {index}: step {from_step} -> {to_step} plane {from_digest:016x} -> {to_digest:016x} churn {churn:.9e}\n"
        )
    }
}

/// Map a retry `what` string back to the static the writer used.
fn retry_what(s: &str) -> Result<&'static str> {
    Ok(match s {
        "transient" => "transient",
        "empty" => "empty",
        "permanent" => "permanent",
        "exhausted" => "exhausted",
        "absorbed" => "absorbed",
        other => bail!("unknown retry what {other:?}"),
    })
}

/// Map a fault-kind name (as printed by `FaultKind::name`) back to the
/// enum.
fn fault_kind(s: &str) -> Result<FaultKind> {
    for kind in [
        FaultKind::DelayedPublish,
        FaultKind::BlackoutPublish,
        FaultKind::DroppedFetch,
        FaultKind::ErroredFetch,
        FaultKind::StaleRead,
    ] {
        if kind.name() == s {
            return Ok(kind);
        }
    }
    bail!("unknown fault kind {s:?}")
}

/// Scan a flat one-line JSON object for `"key":` and return the raw
/// value text (quoted strings unwrapped). Our writer emits no nested
/// objects and no commas inside strings, so scanning to the next `,` /
/// `}` is exact; input with extra whitespace still parses.
fn raw_field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = line[start..].trim_start();
    if let Some(stripped) = rest.strip_prefix('"') {
        stripped.find('"').map(|end| &stripped[..end])
    } else {
        let end = rest.find([',', '}']).unwrap_or(rest.len());
        Some(rest[..end].trim_end())
    }
}

fn str_field<'a>(line: &'a str, key: &str) -> Result<&'a str> {
    raw_field(line, key).with_context(|| format!("missing field {key:?}"))
}

fn u64_field(line: &str, key: &str) -> Result<u64> {
    str_field(line, key)?
        .parse::<u64>()
        .with_context(|| format!("field {key:?} is not a u64"))
}

fn usize_field(line: &str, key: &str) -> Result<usize> {
    Ok(u64_field(line, key)? as usize)
}

fn f64_field(line: &str, key: &str) -> Result<f64> {
    str_field(line, key)?
        .parse::<f64>()
        .with_context(|| format!("field {key:?} is not an f64"))
}

fn bool_field(line: &str, key: &str) -> Result<bool> {
    match str_field(line, key)? {
        "true" => Ok(true),
        "false" => Ok(false),
        other => bail!("field {key:?} is not a bool: {other:?}"),
    }
}

fn hex_field(line: &str, key: &str) -> Result<u64> {
    u64::from_str_radix(str_field(line, key)?, 16)
        .with_context(|| format!("field {key:?} is not a hex digest"))
}

fn opt_usize_field(line: &str, key: &str) -> Result<Option<usize>> {
    match raw_field(line, key) {
        None => Ok(None),
        Some("null") => Ok(None),
        Some(v) => Ok(Some(
            v.parse::<usize>()
                .with_context(|| format!("field {key:?} is not a usize"))?,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events(rec: &Recorder) {
        rec.record(Event::Publish { member: 0, step: 5, bytes: 4096, dur_us: 120 });
        rec.record(Event::Fetch { member: 1, step: 5, bytes: 1024, dur_us: 80 });
        rec.record(Event::DeltaInstall {
            member: 1,
            step: 5,
            full: false,
            moved: 2,
            unchanged: 6,
            encoded: 2,
            bytes: 1024,
        });
        rec.record(Event::RetryAttempt { op: 0, member: 1, attempt: 1, what: "transient" });
        rec.record(Event::FaultDecision {
            kind: FaultKind::DroppedFetch,
            member: 1,
            salt: 3,
        });
        rec.record(Event::Quantize {
            member: 0,
            step: 5,
            windows_quantized: 7,
            windows_raw: 1,
            bytes_quantized: 900,
            bytes_raw_equiv: 3600,
            residual_l2: 0.125,
            max_abs_bias: 1.5e-4,
        });
        rec.record(Event::Swap {
            index: 1,
            from_step: 2,
            to_step: 6,
            from_digest: 0xdead_beef,
            to_digest: 0xfeed_f00d,
            churn: 3.25e-2,
        });
        rec.record(Event::Rejoin { tick: 9, member: 2, bootstrapped_from: Some((0, 40)) });
        rec.record(Event::Rejoin { tick: 1, member: 3, bootstrapped_from: None });
        rec.record(Event::Staleness { step: 10, member: 0, staleness: 5 });
        rec.record(Event::RelayForward { member: 4, step: 15 });
    }

    #[test]
    fn sim_clock_is_deterministic_and_monotonic() {
        let a = SimClock::new(7);
        let b = SimClock::new(7);
        let mut prev = 0;
        for _ in 0..100 {
            let ta = a.now_us();
            assert_eq!(ta, b.now_us());
            assert!(ta > prev, "sim clock must strictly advance");
            prev = ta;
        }
        let c = SimClock::new(8);
        let seq_a: Vec<u64> = (0..8).map(|_| SimClock::new(7).now_us()).collect();
        let seq_c: Vec<u64> = (0..8).map(|_| c.now_us()).collect();
        assert_ne!(seq_a, seq_c, "different seeds should diverge");
    }

    #[test]
    fn same_seed_recorders_dump_identical_jsonl() {
        let a = Recorder::sim(42);
        let b = Recorder::sim(42);
        sample_events(&a);
        sample_events(&b);
        assert!(!a.to_jsonl().is_empty());
        assert_eq!(a.to_jsonl(), b.to_jsonl());
    }

    #[test]
    fn jsonl_round_trips_every_event_kind() {
        let rec = Recorder::sim(1);
        sample_events(&rec);
        let text = rec.to_jsonl();
        let parsed = EventJournal::from_jsonl(&text).expect("parse back");
        assert_eq!(parsed.events, rec.journal().events);
        // Re-serializing the parsed journal is byte-identical.
        assert_eq!(parsed.to_jsonl(), text);
    }

    #[test]
    fn parser_skips_unknown_events_and_blank_lines() {
        let text = "\n{\"t_us\":1,\"ev\":\"warp_core\",\"dilithium\":9}\n{\"t_us\":2,\"ev\":\"staleness\",\"step\":3,\"member\":0,\"staleness\":1}\n";
        let j = EventJournal::from_jsonl(text).expect("tolerant parse");
        assert_eq!(j.events.len(), 1);
        assert_eq!(j.staleness_log_text(), "3 0 1\n");
    }

    #[test]
    fn renderers_pin_the_legacy_byte_formats() {
        assert_eq!(render::retry_line(0, 0, 3, "absorbed"), "0 0 3 absorbed\n");
        assert_eq!(render::fault_line("blackout-publish", 2, 10), "blackout-publish 2 10\n");
        assert_eq!(render::staleness_line(12, 3, 4), "12 3 4\n");
        assert_eq!(
            render::swap_line(1, 2, 6, 0x1, 0x2, 0.015625),
            "swap 1: step 2 -> 6 plane 0000000000000001 -> 0000000000000002 churn 1.562500000e-2\n"
        );
    }

    #[test]
    fn retry_stats_view_reads_the_counter_registry() {
        let rec = Recorder::sim(0);
        rec.incr(keys::RETRY_OPS, 2);
        rec.incr(keys::RETRY_ATTEMPTS, 5);
        rec.incr(keys::RETRY_TRANSIENT, 3);
        rec.incr(keys::RETRY_ABSORBED, 2);
        let s = rec.journal().retry_stats();
        assert_eq!((s.ops, s.attempts, s.transient_errors, s.absorbed), (2, 5, 3, 2));
        assert_eq!(s.permanent_errors, 0);
    }

    #[test]
    fn stats_views_fold_the_event_stream() {
        let rec = Recorder::sim(3);
        sample_events(&rec);
        let j = rec.journal();
        let d = j.delta_stats();
        assert_eq!(
            (d.full_fetches, d.delta_fetches, d.windows_moved, d.windows_unchanged),
            (0, 1, 2, 6)
        );
        assert_eq!(d.payload_bytes, 1024);
        let f = j.feedback_stats();
        assert_eq!((f.publishes, f.windows_quantized, f.windows_raw), (1, 7, 1));
        assert_eq!(f.bytes_quantized, 900);
        assert!((f.last_residual_l2 - 0.125).abs() < 1e-12);
        assert_eq!(j.fault_events().len(), 1);
        assert_eq!(j.fault_log_text(), "dropped-fetch 1 3\n");
        assert_eq!(j.retry_log_text(), "0 1 1 transient\n");
        assert_eq!(j.staleness_log_text(), "10 0 5\n");
        assert!(j.swap_log_text().starts_with("swap 1: step 2 -> 6 plane 00000000deadbeef"));
    }

    #[test]
    fn recorder_clones_share_one_journal() {
        let rec = Recorder::sim(11);
        let clone = rec.clone();
        clone.record(Event::RelayForward { member: 0, step: 1 });
        rec.incr(keys::SUB_POLLS, 4);
        assert_eq!(rec.len(), 1);
        assert_eq!(clone.counter(keys::SUB_POLLS), 4);
    }
}

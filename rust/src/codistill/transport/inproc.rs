//! The in-process backend: the zero-copy `Arc<FlatBuffer>` store.
//!
//! Publications and reads share `Arc<Checkpoint>` (and through it the flat
//! plane), so the in-memory exchange never copies parameters. This is the
//! reference backend the spool-dir and socket transports must match
//! byte-for-byte, and the store a [`SocketServer`] serves from.
//!
//! An optional disk spool additionally writes every publication as a
//! `CKPT0003` file (zero-padded, temp+rename — the same naming scheme
//! [`SpoolDir`] reads), and the history bound is enforced on those files
//! too: publishing past `history` deletes the member's oldest spool file.
//!
//! [`SocketServer`]: crate::codistill::transport::SocketServer
//! [`SpoolDir`]: crate::codistill::transport::SpoolDir

use crate::codistill::store::Checkpoint;
use crate::codistill::transport::{
    fetch_from_checkpoint, ExchangeTransport, FetchResult, FetchSpec, TransportKind,
};
use crate::codistill::transport::spool::{spool_file_name, spool_temp_name};
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Bounded per-member checkpoint history with freshest-available reads.
pub struct InProcess {
    inner: Mutex<HashMap<usize, Vec<Arc<Checkpoint>>>>,
    history: usize,
    spool: Option<PathBuf>,
}

impl InProcess {
    pub fn new(history: usize) -> Self {
        InProcess {
            inner: Mutex::new(HashMap::new()),
            history: history.max(1),
            spool: None,
        }
    }

    /// Also write every published checkpoint to `dir` (cross-process
    /// mode): another process can read the same exchange through a
    /// [`SpoolDir`](crate::codistill::transport::SpoolDir) on `dir`.
    pub fn with_spool(mut self, dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)?;
        self.spool = Some(dir.to_path_buf());
        Ok(self)
    }

    /// Retention bound (publications kept per member).
    pub fn history(&self) -> usize {
        self.history
    }

    /// Publish a member's checkpoint.
    pub fn publish(&self, ckpt: Checkpoint) -> Result<()> {
        if let Some(dir) = &self.spool {
            // temp+rename so a concurrent SpoolDir reader never sees a
            // torn file, then drop this member's files past the bound and
            // refresh the manifest SpoolDir readers prefer over a scan.
            let tmp = dir.join(spool_temp_name(ckpt.member, ckpt.step));
            ckpt.save(&tmp)?;
            std::fs::rename(&tmp, dir.join(spool_file_name(ckpt.member, ckpt.step)))?;
            crate::codistill::transport::spool::prune_spool(dir, self.history)?;
            crate::codistill::transport::spool::write_manifest(
                dir,
                Some((ckpt.member, ckpt.step, ckpt.window_digests().as_slice())),
            )?;
        }
        let mut inner = self.inner.lock().unwrap();
        let hist = inner.entry(ckpt.member).or_default();
        if let Some(last) = hist.last() {
            if ckpt.step < last.step {
                bail!(
                    "member {} published step {} after step {}",
                    ckpt.member,
                    ckpt.step,
                    last.step
                );
            }
        }
        hist.push(Arc::new(ckpt));
        let len = hist.len();
        if len > self.history {
            hist.drain(0..len - self.history);
        }
        Ok(())
    }

    /// Freshest available checkpoint from a member (paper semantics).
    pub fn latest(&self, member: usize) -> Option<Arc<Checkpoint>> {
        self.inner
            .lock()
            .unwrap()
            .get(&member)
            .and_then(|h| h.last().cloned())
    }

    /// Freshest checkpoint from a member with `step <= max_step`
    /// (explicit staleness injection).
    pub fn latest_at_most(&self, member: usize, max_step: u64) -> Option<Arc<Checkpoint>> {
        self.inner
            .lock()
            .unwrap()
            .get(&member)
            .and_then(|h| h.iter().rev().find(|c| c.step <= max_step).cloned())
    }

    /// Staleness (in steps) a reader at `now` would observe for a member.
    pub fn staleness(&self, member: usize, now: u64) -> Option<u64> {
        self.latest(member).map(|c| now.saturating_sub(c.step))
    }

    pub fn members(&self) -> Vec<usize> {
        let mut m: Vec<usize> = self.inner.lock().unwrap().keys().copied().collect();
        m.sort();
        m
    }

    /// `(member, freshest published step)` heartbeats, ascending by member
    /// — one lock scan, no checkpoint payloads touched.
    pub fn last_steps(&self) -> Vec<(usize, u64)> {
        let inner = self.inner.lock().unwrap();
        let mut out: Vec<(usize, u64)> = inner
            .iter()
            .filter_map(|(&m, h)| h.last().map(|c| (m, c.step)))
            .collect();
        out.sort();
        out
    }
}

impl ExchangeTransport for InProcess {
    fn kind(&self) -> TransportKind {
        TransportKind::InProcess
    }

    fn publish(&self, ckpt: Checkpoint) -> Result<()> {
        InProcess::publish(self, ckpt)
    }

    /// The one native read: resolve in-memory history, then answer the
    /// spec from the shared snapshot — a no-basis full fetch hands the
    /// `Arc<Checkpoint>` over zero-copy, a delta fetch compares digest
    /// tables against the shared buffer and copies only changed windows.
    fn fetch(&self, spec: &FetchSpec) -> Result<Option<FetchResult>> {
        match InProcess::latest_at_most(self, spec.member, spec.max_step) {
            Some(ckpt) => Ok(Some(fetch_from_checkpoint(&ckpt, spec)?)),
            None => Ok(None),
        }
    }

    fn members(&self) -> Result<Vec<usize>> {
        Ok(InProcess::members(self))
    }

    fn last_steps(&self) -> Result<Vec<(usize, u64)>> {
        Ok(InProcess::last_steps(self))
    }

    fn gc(&self) -> Result<()> {
        // In-memory history is bounded on publish; only spool files can
        // outlive the bound. Rewrite the shared manifest when the prune
        // removed something — or when it still lists files a concurrent
        // pruner removed (same stale-row recovery as `SpoolDir::gc`).
        if let Some(dir) = &self.spool {
            let pruned = crate::codistill::transport::spool::prune_spool(dir, self.history)?;
            if pruned > 0 || crate::codistill::transport::spool::manifest_needs_rewrite(dir) {
                crate::codistill::transport::spool::write_manifest(dir, None)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Tensor, TensorMap};

    fn ckpt(member: usize, step: u64, val: f32) -> Checkpoint {
        let mut params = TensorMap::new();
        params.insert("params.w", Tensor::f32(&[2], vec![val, val]).unwrap());
        Checkpoint::new(member, step, params)
    }

    #[test]
    fn latest_returns_freshest() {
        let store = InProcess::new(4);
        store.publish(ckpt(0, 10, 1.0)).unwrap();
        store.publish(ckpt(0, 20, 2.0)).unwrap();
        let c = store.latest(0).unwrap();
        assert_eq!(c.step, 20);
        assert_eq!(store.latest(1).map(|c| c.step), None);
    }

    #[test]
    fn reads_share_the_flat_plane_zero_copy() {
        let store = InProcess::new(4);
        let c = ckpt(0, 1, 3.0);
        let plane = c.flat().clone();
        store.publish(c).unwrap();
        let a = store.latest(0).unwrap();
        let b = store.latest(0).unwrap();
        assert!(Arc::ptr_eq(a.flat(), &plane), "publish copied the plane");
        assert!(Arc::ptr_eq(a.flat(), b.flat()), "reads copied the plane");
        assert_eq!(a.flat().view("params.w").unwrap(), &[3.0, 3.0]);
    }

    #[test]
    fn latest_at_most_respects_bound() {
        let store = InProcess::new(8);
        for s in [5u64, 10, 15, 20] {
            store.publish(ckpt(1, s, s as f32)).unwrap();
        }
        assert_eq!(store.latest_at_most(1, 12).unwrap().step, 10);
        assert!(store.latest_at_most(1, 4).is_none());
        assert_eq!(store.latest_at_most(1, 100).unwrap().step, 20);
    }

    #[test]
    fn history_is_bounded() {
        let store = InProcess::new(2);
        for s in 0..10u64 {
            store.publish(ckpt(0, s, 0.0)).unwrap();
        }
        // only the last 2 checkpoints (steps 8, 9) survive
        assert_eq!(store.latest(0).unwrap().step, 9);
        assert_eq!(store.latest_at_most(0, 8).unwrap().step, 8);
        assert!(store.latest_at_most(0, 7).is_none(), "old history retained");
    }

    #[test]
    fn last_steps_reports_heartbeats() {
        let store = InProcess::new(4);
        assert!(store.last_steps().is_empty());
        store.publish(ckpt(2, 7, 0.0)).unwrap();
        store.publish(ckpt(0, 3, 0.0)).unwrap();
        store.publish(ckpt(0, 9, 0.0)).unwrap();
        assert_eq!(store.last_steps(), vec![(0, 9), (2, 7)]);
    }

    #[test]
    fn rejects_step_regression() {
        let store = InProcess::new(4);
        store.publish(ckpt(0, 10, 0.0)).unwrap();
        assert!(store.publish(ckpt(0, 5, 0.0)).is_err());
    }

    #[test]
    fn staleness_accounting() {
        let store = InProcess::new(4);
        store.publish(ckpt(2, 100, 0.0)).unwrap();
        assert_eq!(store.staleness(2, 150), Some(50));
        assert_eq!(store.staleness(2, 50), Some(0)); // saturating
        assert_eq!(store.staleness(3, 10), None);
    }

    #[test]
    fn spool_writes_files_and_prunes_past_history() {
        let dir =
            std::env::temp_dir().join(format!("codistill_spool_gc_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let store = InProcess::new(2).with_spool(&dir).unwrap();
        for s in 0..5u64 {
            store.publish(ckpt(0, s, s as f32)).unwrap();
        }
        // history=2: only steps 3 and 4 survive on disk (the old unpadded,
        // unbounded spool kept all five forever).
        let mut names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".ckpt"))
            .collect();
        names.sort();
        assert_eq!(names, vec![spool_file_name(0, 3), spool_file_name(0, 4)]);
        // and they load back through the magic-dispatched reader
        let l = Checkpoint::load(&dir.join(spool_file_name(0, 4))).unwrap();
        assert_eq!(l.flat().view("params.w").unwrap(), &[4.0, 4.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fetch_windows_slices_the_plane() {
        let store = InProcess::new(4);
        let mut params = TensorMap::new();
        params.insert("params.a", Tensor::f32(&[2], vec![1.0, 2.0]).unwrap());
        params.insert("params.b", Tensor::f32(&[3], vec![3.0, 4.0, 5.0]).unwrap());
        store.publish(Checkpoint::new(0, 7, params)).unwrap();

        let t: &dyn ExchangeTransport = &store;
        let f = t
            .fetch_windows(0, u64::MAX, &["params.b".to_string()])
            .unwrap()
            .unwrap();
        assert_eq!(f.step, 7);
        assert_eq!(f.windows.len(), 1);
        assert_eq!(f.windows[0].to_f32().unwrap(), vec![3.0, 4.0, 5.0]);
        assert_eq!(f.payload_bytes(), 12);
        // unknown window is an error, absent member is None
        assert!(t.fetch_windows(0, u64::MAX, &["params.z".to_string()]).is_err());
        assert!(t.fetch_windows(9, u64::MAX, &[]).unwrap().is_none());
    }
}

//! Pluggable checkpoint-exchange transports.
//!
//! The paper's systems argument (§2.1) is that codistillation scales
//! because teachers only need **rarely transmitted** parameter snapshots —
//! which makes the transmission medium swappable, and makes each
//! transmission worth shrinking. This module fixes one API,
//! [`ExchangeTransport`], and ships interchangeable backends that move the
//! identical flat-plane bytes:
//!
//! * [`InProcess`] — the zero-copy `Arc<FlatBuffer>` store: publisher,
//!   history, and every reader share one buffer. The default for
//!   single-process runs and the reference implementation the other
//!   backends must match byte-for-byte.
//! * [`SpoolDir`] — checkpoints as `CKPT0003` files in a shared directory
//!   (one file per publication, written temp+rename so readers never see
//!   a torn file) plus an atomic `MANIFEST` that also persists each
//!   checkpoint's per-window digest table. Separate coordinator processes
//!   exchange by pointing at the same directory; reads `pread` only the
//!   windows they need out of the contiguous payload.
//! * [`Socket`](SocketTransport) — a length-prefixed request/response
//!   protocol over TCP or Unix sockets against a [`SocketServer`].
//! * [`Faulty`] — a decorator over any backend: a seeded [`FaultPlan`]
//!   deterministically injects delayed publishes, dropped/erroring
//!   fetches, stale reads, and scripted member blackouts, so every §2.2
//!   failure mode is a reproducible `cargo test` scenario
//!   (`tests/coordinator_faults.rs`) instead of a hope about real
//!   networks.
//!
//! ## One read path: [`ExchangeTransport::fetch`]
//!
//! Every read is one operation: a [`FetchSpec`] names the member, a
//! staleness bound, an optional delta [`Basis`] (the step and per-window
//! digest table of the reader's installed copy), and a window scope
//! ([`WindowSel::All`] or [`WindowSel::Named`]). The [`FetchResult`]
//! carries the source plane's window table and digest table, the payload
//! of every window whose content **differs** from the basis, and the
//! names of the windows skipped as `unchanged` — enough metadata to prove
//! the reader's patched plane is byte-identical to a full fetch. With no
//! basis, a fetch degenerates to the classic full read (and in-memory
//! backends hand the whole checkpoint over zero-copy via
//! [`FetchResult::full`]).
//!
//! The historical reads are thin shims over `fetch`:
//! [`ExchangeTransport::latest`] / [`ExchangeTransport::latest_at_most`]
//! are a no-basis full-plane spec, [`ExchangeTransport::fetch_windows`] a
//! no-basis named-window spec — so each backend implements exactly one
//! read natively.
//!
//! ## Incremental (delta) exchange
//!
//! [`DeltaCache`] is the reader side: it keeps one installed plane (and
//! digest basis) per teacher, sends the basis with every fetch, patches
//! changed windows in place via
//! [`FlatBuffer::write_window`](crate::runtime::flat::FlatBuffer), and
//! hands out ordinary `Arc<Checkpoint>`s whose bytes are identical to a
//! full fetch (`tests/transport_equivalence.rs` pins this on every
//! backend). Steady-state exchanges move only what changed —
//! `netsim::ClusterModel::delta_exchange_time` prices exactly this
//! against the full-plane pull. Backends serve deltas natively:
//! `InProcess` compares digest tables against the shared buffer,
//! `SpoolDir` `pread`s only changed byte ranges, and the socket protocol
//! has a dedicated `DELTA` opcode (basis digests up, changed windows
//! down).
//!
//! ## Compressed window payloads
//!
//! [`codec`] layers lossless per-window encoding under the delta fetch:
//! [`FetchSpec::codec`] advertises what a reader accepts, every
//! [`FetchedWindow`] carries a per-window codec tag, and the install side
//! ([`DeltaCache`], [`FetchResult::into_checkpoint`]) decodes and
//! digest-verifies before any byte lands — so compression can shrink an
//! exchange but never weaken the corrupt-payload guarantee or change the
//! installed bytes. `SpoolDir` publishers opt in with
//! [`SpoolDir::with_codec`] (`CKPT0004` files whose window table records
//! codec + encoded length; readers `pread` the encoded ranges), socket
//! clients with [`SocketTransport::with_codec`] (a capability byte on the
//! `DELTA`/`FETCH` requests — old servers reject it cleanly and the
//! client falls back to raw frames, old clients never send it), and
//! `netsim::ClusterModel::compressed_exchange_time` prices the saving.
//!
//! ## Liveness heartbeats
//!
//! [`ExchangeTransport::last_steps`] returns `(member, freshest step)`
//! pairs without moving checkpoint payloads — an in-memory scan for
//! [`InProcess`], a manifest parse for [`SpoolDir`], a dedicated opcode
//! for the socket protocol. The coordinator's liveness table and the
//! default [`ExchangeTransport::staleness`] probe are built from these
//! heartbeats.
//!
//! ## Garbage collection
//!
//! Every backend bounds its history to `history` publications per member;
//! [`ExchangeTransport::gc`] forces the bound onto durable state too
//! (spool files past the bound are deleted). The orchestrator calls it on
//! the publish cadence.

pub mod codec;
pub mod faulty;
pub mod feedback;
pub mod inproc;
pub mod relay;
pub mod retry;
pub mod socket;
pub mod spool;
pub mod subscribe;

pub use codec::{Codec, WindowCodec};
pub use faulty::{Blackout, FaultEvent, FaultKind, FaultPlan, Faulty};
pub use feedback::{ErrorFeedback, FeedbackStats};
pub use inproc::InProcess;
pub use relay::{Relay, RelayConfig, RelayStats};
pub use retry::{classify_error, ErrorClass, Retry, RetryPolicy, RetryStats};
pub use socket::{SocketServer, SocketTransport};
pub use spool::SpoolDir;
pub use subscribe::{SubscribeConfig, SubscribeStats, Subscription};

use crate::codistill::obs::{Event, Recorder};
use crate::codistill::store::Checkpoint;
use crate::runtime::flat::{content_digest, FlatBuffer, FlatLayout};
use crate::runtime::TensorMap;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// `max_step` value meaning "no staleness bound: freshest available".
pub const ANY_STEP: u64 = u64::MAX;

/// Which backend a transport is (CLI parsing, logging, bench labels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    InProcess,
    SpoolDir,
    Socket,
}

impl TransportKind {
    /// Parse a `--transport {inproc,spool,socket}` value.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "inproc" | "inprocess" | "mem" => Ok(TransportKind::InProcess),
            "spool" | "spooldir" | "dir" => Ok(TransportKind::SpoolDir),
            "socket" | "tcp" | "unix" => Ok(TransportKind::Socket),
            other => bail!("unknown transport {other:?} (want inproc|spool|socket)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProcess => "inproc",
            TransportKind::SpoolDir => "spool",
            TransportKind::Socket => "socket",
        }
    }
}

/// One window pulled by a fetch: the name, its shape, and the payload —
/// either already-decoded f32s (in-memory backends, legacy wire frames)
/// or the still-encoded bytes exactly as they moved over the medium
/// (compressed spool preads, capability-negotiated socket frames). The
/// install side ([`DeltaCache`], [`FetchResult::into_checkpoint`])
/// decodes and digest-verifies encoded payloads, so a corrupt encoded
/// window fails exactly as loudly as a corrupt raw one.
#[derive(Debug, Clone)]
pub struct FetchedWindow {
    pub name: String,
    pub shape: Vec<usize>,
    pub payload: WindowPayload,
}

/// A fetched window's payload representation (see [`FetchedWindow`]).
#[derive(Debug, Clone)]
pub enum WindowPayload {
    /// Decoded f32 elements.
    Raw(Vec<f32>),
    /// Bytes as they moved over the medium, still in `codec` encoding.
    Encoded { codec: Codec, bytes: Vec<u8> },
}

impl FetchedWindow {
    /// A window carrying decoded elements.
    pub fn raw(name: String, shape: Vec<usize>, data: Vec<f32>) -> Self {
        FetchedWindow {
            name,
            shape,
            payload: WindowPayload::Raw(data),
        }
    }

    /// A window carrying a still-encoded payload.
    pub fn encoded(name: String, shape: Vec<usize>, codec: Codec, bytes: Vec<u8>) -> Self {
        FetchedWindow {
            name,
            shape,
            payload: WindowPayload::Encoded { codec, bytes },
        }
    }

    /// Element count this window decodes to (from its shape).
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    /// Codec tag the payload travels in ([`Codec::Raw`] for decoded
    /// payloads).
    pub fn codec(&self) -> Codec {
        match &self.payload {
            WindowPayload::Raw(_) => Codec::Raw,
            WindowPayload::Encoded { codec, .. } => *codec,
        }
    }

    /// Bytes this window actually moved over the medium: the encoded
    /// length for encoded payloads, 4 per element otherwise — the
    /// quantity the delta/compression bench records and `netsim` prices.
    pub fn wire_bytes(&self) -> u64 {
        match &self.payload {
            WindowPayload::Raw(data) => data.len() as u64 * 4,
            WindowPayload::Encoded { bytes, .. } => bytes.len() as u64,
        }
    }

    /// Decode into f32 elements, consuming the window (decoded payloads
    /// move without a copy).
    pub fn into_f32(self) -> Result<Vec<f32>> {
        let elems = self.elems();
        match self.payload {
            WindowPayload::Raw(data) => {
                if data.len() != elems {
                    bail!(
                        "window {:?}: payload has {} elems, shape wants {elems}",
                        self.name,
                        data.len()
                    );
                }
                Ok(data)
            }
            WindowPayload::Encoded { codec, bytes } => codec
                .decode(&bytes, elems)
                .with_context(|| format!("decoding window {:?} ({})", self.name, codec.name())),
        }
    }

    /// Decode into f32 elements, cloning decoded payloads (tests,
    /// diagnostics — hot paths use [`FetchedWindow::into_f32`]).
    pub fn to_f32(&self) -> Result<Vec<f32>> {
        self.clone().into_f32()
    }
}

/// Result of [`ExchangeTransport::fetch_windows`]: which checkpoint the
/// windows came from, plus the windows themselves in request order.
#[derive(Debug, Clone)]
pub struct WindowedFetch {
    pub member: usize,
    pub step: u64,
    pub windows: Vec<FetchedWindow>,
}

impl WindowedFetch {
    /// Parameter payload bytes this fetch actually moved — the quantity
    /// `netsim` prices for sharded exchange.
    pub fn payload_bytes(&self) -> u64 {
        self.windows.iter().map(|w| w.wire_bytes()).sum()
    }
}

/// Which windows a fetch addresses.
#[derive(Debug, Clone)]
pub enum WindowSel {
    /// The whole plane (the teacher-reload path).
    All,
    /// Only these named windows, answered in request order (the sharded
    /// path). Unknown names are an error: the caller's layout disagrees
    /// with the publisher's plane.
    Named(Vec<String>),
}

/// A reader's installed copy of a member's plane, as a delta basis: the
/// step it was installed at and its per-window content digests **in the
/// publisher's plane order** (the order `FetchResult::parts` lists).
/// A basis whose digest count disagrees with the source plane's window
/// count is ignored (the plane was reshaped) and the fetch degenerates to
/// a full read.
#[derive(Debug, Clone)]
pub struct Basis {
    pub step: u64,
    pub digests: Vec<u64>,
}

/// One read request (see [`ExchangeTransport::fetch`]).
#[derive(Debug, Clone)]
pub struct FetchSpec {
    pub member: usize,
    /// Staleness bound: freshest checkpoint with `step <= max_step`
    /// ([`ANY_STEP`] = freshest available, the paper semantics).
    pub max_step: u64,
    /// Installed basis for delta fetch; `None` = full read.
    pub basis: Option<Basis>,
    pub windows: WindowSel,
    /// Codec negotiation: the encoding the reader accepts for window
    /// payloads ([`Codec::Raw`] = classic uncompressed frames). Backends
    /// MAY answer any window in this codec or raw (the per-window tag on
    /// each [`FetchedWindow`] is authoritative); readers always decode by
    /// tag, so a backend serving pre-encoded state (a `CKPT0004` spool
    /// file) may return encoded windows regardless.
    pub codec: Codec,
}

impl FetchSpec {
    /// Full-plane, no-basis read of the freshest checkpoint with
    /// `step <= max_step` — the [`ExchangeTransport::latest_at_most`]
    /// shim's spec.
    pub fn full(member: usize, max_step: u64) -> Self {
        FetchSpec {
            member,
            max_step,
            basis: None,
            windows: WindowSel::All,
            codec: Codec::Raw,
        }
    }

    /// Named-window, no-basis read — the
    /// [`ExchangeTransport::fetch_windows`] shim's spec.
    pub fn named(member: usize, max_step: u64, names: Vec<String>) -> Self {
        FetchSpec {
            member,
            max_step,
            basis: None,
            windows: WindowSel::Named(names),
            codec: Codec::Raw,
        }
    }

    /// Attach a delta basis.
    pub fn with_basis(mut self, basis: Basis) -> Self {
        self.basis = Some(basis);
        self
    }

    /// Accept window payloads in `codec` encoding.
    pub fn with_codec(mut self, codec: Codec) -> Self {
        self.codec = codec;
        self
    }
}

/// Result of [`ExchangeTransport::fetch`]: everything a reader needs to
/// make its installed plane byte-identical to the source checkpoint, and
/// to prove it (the digest table covers every window, fetched or
/// skipped).
#[derive(Debug, Clone)]
pub struct FetchResult {
    pub member: usize,
    /// Step of the checkpoint this fetch was answered from.
    pub step: u64,
    /// Window table `(name, shape)` of the source plane, in plane order.
    pub parts: Vec<(String, Vec<usize>)>,
    /// Per-window content digests aligned with `parts`.
    pub digests: Vec<u64>,
    /// Payloads of the requested windows whose content differs from the
    /// basis (all requested windows when there is no applicable basis).
    /// Request order for [`WindowSel::Named`], plane order for
    /// [`WindowSel::All`].
    pub windows: Vec<FetchedWindow>,
    /// Requested windows skipped because the basis digest matched.
    pub unchanged: Vec<String>,
    /// Non-f32 leaves of the checkpoint (usually empty).
    pub residual: TensorMap,
    /// Zero-copy whole-checkpoint hand-off, set when the backend can
    /// share its in-memory snapshot for a no-basis full-plane fetch
    /// (`InProcess`, the spool read cache, a reassembled windowed socket
    /// pull). `windows` is empty when this is set.
    pub full: Option<Arc<Checkpoint>>,
}

impl FetchResult {
    /// Parameter payload bytes this fetch moved: the whole plane for a
    /// zero-copy full hand-off, otherwise the fetched windows only (at
    /// their encoded size when a codec was in play) — the quantity the
    /// delta/compression bench records and `netsim` prices.
    pub fn payload_bytes(&self) -> u64 {
        match &self.full {
            Some(ck) => ck.flat().layout().total_bytes() as u64,
            None => self.windows.iter().map(|w| w.wire_bytes()).sum(),
        }
    }

    /// Total bytes of the source plane (what a full fetch would move).
    pub fn total_bytes(&self) -> u64 {
        self.parts
            .iter()
            .map(|(_, shape)| shape.iter().product::<usize>() as u64 * 4)
            .sum()
    }

    /// Materialize a whole checkpoint. Only a full result qualifies: a
    /// delta (some windows unchanged-and-absent) cannot stand alone.
    pub fn into_checkpoint(self) -> Result<Arc<Checkpoint>> {
        if let Some(full) = self.full {
            return Ok(full);
        }
        if !self.unchanged.is_empty() || self.windows.len() != self.parts.len() {
            bail!(
                "fetch result carries {} of {} windows ({} unchanged): \
                 a delta cannot materialize a checkpoint without its basis",
                self.windows.len(),
                self.parts.len(),
                self.unchanged.len()
            );
        }
        let decoded = decode_and_verify(self.windows, &self.parts, &self.digests)?;
        let layout = Arc::new(FlatLayout::from_named_shapes(self.parts));
        let mut buf = FlatBuffer::zeros(layout);
        for (name, data) in &decoded {
            buf.write_window(name, data)?;
        }
        Ok(Arc::new(Checkpoint::from_flat(
            self.member,
            self.step,
            Arc::new(buf),
            self.residual,
        )))
    }

    /// View as the historical [`WindowedFetch`] (the
    /// [`ExchangeTransport::fetch_windows`] shim). Windows are handed
    /// over decoded: the legacy API predates the codec layer.
    pub fn into_windowed(self) -> Result<WindowedFetch> {
        if !self.unchanged.is_empty() {
            bail!(
                "fetch result skipped {} unchanged windows: not a full windowed fetch",
                self.unchanged.len()
            );
        }
        let windows = match &self.full {
            Some(ck) => {
                let flat = ck.flat();
                flat.layout()
                    .entries()
                    .iter()
                    .map(|e| {
                        FetchedWindow::raw(
                            e.name.clone(),
                            e.shape.clone(),
                            flat.data()[e.range()].to_vec(),
                        )
                    })
                    .collect()
            }
            None => self
                .windows
                .into_iter()
                .map(|w| {
                    let (name, shape) = (w.name.clone(), w.shape.clone());
                    Ok(FetchedWindow::raw(name, shape, w.into_f32()?))
                })
                .collect::<Result<Vec<_>>>()?,
        };
        Ok(WindowedFetch {
            member: self.member,
            step: self.step,
            windows,
        })
    }
}

/// One checkpoint-exchange medium. All methods take `&self`: transports
/// are shared (`Arc<dyn ExchangeTransport>`) between the orchestrator and
/// any number of members/threads.
///
/// Reads are racy by design (the paper's exchange is asynchronous): a
/// fetch observed now may be superseded a step later. The only ordering
/// guarantee is per-member step monotonicity of publications.
///
/// [`ExchangeTransport::fetch`] is the one read every backend implements
/// natively; `latest`/`latest_at_most`/`fetch_windows` are provided shims
/// over it.
pub trait ExchangeTransport: Send + Sync {
    /// Which backend this is.
    fn kind(&self) -> TransportKind;

    /// Publish a member's checkpoint. Steps must be non-decreasing per
    /// member.
    fn publish(&self, ckpt: Checkpoint) -> Result<()>;

    /// The unified, delta-aware read (module docs): resolve the freshest
    /// checkpoint within `spec.max_step`, answer the requested windows,
    /// and — when `spec.basis` applies — skip the ones whose content
    /// digest matches the basis. `Ok(None)` while no checkpoint matches;
    /// unknown window names are an error.
    fn fetch(&self, spec: &FetchSpec) -> Result<Option<FetchResult>>;

    /// Freshest available checkpoint from a member (paper semantics);
    /// `None` while the member has never published. Shim over
    /// [`ExchangeTransport::fetch`].
    fn latest(&self, member: usize) -> Result<Option<Arc<Checkpoint>>> {
        self.latest_at_most(member, ANY_STEP)
    }

    /// Freshest checkpoint from a member with `step <= max_step`
    /// (explicit staleness injection). Shim over
    /// [`ExchangeTransport::fetch`]: a full-plane, no-basis spec.
    fn latest_at_most(&self, member: usize, max_step: u64) -> Result<Option<Arc<Checkpoint>>> {
        match self.fetch(&FetchSpec::full(member, max_step))? {
            Some(r) => Ok(Some(r.into_checkpoint()?)),
            None => Ok(None),
        }
    }

    /// Sharded fetch: only the named windows of the freshest checkpoint
    /// from `member` with `step <= max_step`. Shim over
    /// [`ExchangeTransport::fetch`]: a named-window, no-basis spec.
    fn fetch_windows(
        &self,
        member: usize,
        max_step: u64,
        names: &[String],
    ) -> Result<Option<WindowedFetch>> {
        match self.fetch(&FetchSpec::named(member, max_step, names.to_vec()))? {
            Some(r) => Ok(Some(r.into_windowed()?)),
            None => Ok(None),
        }
    }

    /// Members that have published at least once, ascending.
    fn members(&self) -> Result<Vec<usize>>;

    /// `(member, freshest published step)` heartbeats for every member
    /// that has published, ascending by member — the liveness probe the
    /// coordinator polls on its reload cadence. Backends override this
    /// with a metadata-only read (in-memory scan, manifest parse, a
    /// dedicated wire opcode); the default pulls whole checkpoints and is
    /// only acceptable for tests.
    fn last_steps(&self) -> Result<Vec<(usize, u64)>> {
        let mut out = Vec::new();
        for m in self.members()? {
            if let Some(c) = self.latest(m)? {
                out.push((m, c.step));
            }
        }
        Ok(out)
    }

    /// Enforce the history bound on durable state (delete spool files /
    /// server history past the bound). In-memory history is already
    /// bounded on publish, so for [`InProcess`] this is a no-op.
    fn gc(&self) -> Result<()>;

    /// Staleness (in steps) a reader at `now` would observe for a member.
    /// Routed through the metadata-only [`ExchangeTransport::last_steps`]
    /// heartbeat: a staleness probe must never pull a checkpoint payload
    /// over a spool or socket just to read a step number.
    fn staleness(&self, member: usize, now: u64) -> Result<Option<u64>> {
        Ok(self
            .last_steps()?
            .into_iter()
            .find(|&(m, _)| m == member)
            .map(|(_, step)| now.saturating_sub(step)))
    }

    /// Deliver any state a decorator is still holding back (e.g. the
    /// publications [`Faulty`] delayed past their member's final cadence).
    /// The coordinator calls this once at end of run; plain backends have
    /// nothing held, so the default is a no-op. Decorators forward to
    /// their inner transport after draining their own state, so the call
    /// reaches every layer of a stacked transport.
    fn flush(&self) -> Result<()> {
        Ok(())
    }

    /// Retry accounting, when a [`Retry`] decorator is anywhere in the
    /// stack. Plain backends answer `None`; decorators forward to their
    /// inner transport so the stats surface through however many layers
    /// wrap the retrier.
    fn retry_stats(&self) -> Option<RetryStats> {
        None
    }
}

/// Slice a checkpoint held in memory into a [`WindowedFetch`] — the
/// legacy window read shared by the socket server's `FETCH` opcode and
/// the spool's v1-file fallback.
pub(crate) fn windows_from_checkpoint(
    ckpt: &Checkpoint,
    names: &[String],
) -> Result<WindowedFetch> {
    let flat = ckpt.flat();
    let mut windows = Vec::with_capacity(names.len());
    for name in names {
        let entry = match flat.layout().entry(name) {
            Some(e) => e,
            None => bail!(
                "member {} step {}: plane has no window {name:?}",
                ckpt.member,
                ckpt.step
            ),
        };
        windows.push(FetchedWindow::raw(
            name.clone(),
            entry.shape.clone(),
            flat.view(name)?.to_vec(),
        ));
    }
    Ok(WindowedFetch {
        member: ckpt.member,
        step: ckpt.step,
        windows,
    })
}

/// Materialize one window for a fetch answer in the spec's negotiated
/// codec: a straight slice copy for [`Codec::Raw`], an encode (with the
/// never-larger fallback) otherwise.
pub(crate) fn encode_window(
    codec: Codec,
    name: &str,
    shape: &[usize],
    data: &[f32],
) -> FetchedWindow {
    match codec {
        Codec::Raw => FetchedWindow::raw(name.to_string(), shape.to_vec(), data.to_vec()),
        other => {
            let (tag, bytes) = other.encode(data);
            FetchedWindow::encoded(name.to_string(), shape.to_vec(), tag, bytes)
        }
    }
}

/// Partition a plane's requested windows into (indices to fetch,
/// unchanged names) — the window-selection / basis-validity / digest-skip
/// core shared by every backend's native read (in-memory slice or spool
/// pread; only the IO differs, so the semantics cannot diverge). Unknown
/// names in a [`WindowSel::Named`] scope are an error.
pub(crate) fn partition_windows(
    layout: &FlatLayout,
    digests: &[u64],
    spec: &FetchSpec,
) -> Result<(Vec<usize>, Vec<String>)> {
    let requested: Vec<usize> = match &spec.windows {
        WindowSel::All => (0..layout.len()).collect(),
        WindowSel::Named(names) => names
            .iter()
            .map(|n| {
                layout
                    .position(n)
                    .ok_or_else(|| anyhow::anyhow!("plane has no window {n:?}"))
            })
            .collect::<Result<_>>()?,
    };
    // A basis only applies when it describes a plane of the same window
    // count; anything else means the plane was reshaped — full read.
    let basis = spec
        .basis
        .as_ref()
        .filter(|b| b.digests.len() == layout.len());
    let mut fetch = Vec::new();
    let mut unchanged = Vec::new();
    for idx in requested {
        match basis {
            Some(b) if b.digests[idx] == digests[idx] => {
                unchanged.push(layout.entries()[idx].name.clone())
            }
            _ => fetch.push(idx),
        }
    }
    Ok((fetch, unchanged))
}

/// Answer a [`FetchSpec`] from a checkpoint held in memory — the shared
/// native read for [`InProcess`] (and through it the socket server) and
/// the spool's cached/v1 paths. Digest comparison, basis-validity, and
/// the zero-copy full hand-off live here once.
pub(crate) fn fetch_from_checkpoint(
    ckpt: &Arc<Checkpoint>,
    spec: &FetchSpec,
) -> Result<FetchResult> {
    let flat = ckpt.flat();
    let layout = flat.layout();
    // Every result carries the window+digest tables — the metadata that
    // lets a reader prove (and seed) a delta basis. That costs one small
    // name/shape clone per window even on the zero-copy full path; the
    // payload itself is never copied there, and the tables are a few KB
    // on a reload cadence of dozens of steps, so the uniform contract
    // wins over shaving the last allocation.
    let parts: Vec<(String, Vec<usize>)> = layout
        .entries()
        .iter()
        .map(|e| (e.name.clone(), e.shape.clone()))
        .collect();
    let digests: Vec<u64> = ckpt.window_digests().as_ref().clone();
    let basis_applies = spec
        .basis
        .as_ref()
        .map(|b| b.digests.len() == parts.len())
        .unwrap_or(false);

    if !basis_applies {
        if let WindowSel::All = spec.windows {
            // Zero-copy: hand the whole in-memory snapshot over.
            return Ok(FetchResult {
                member: ckpt.member,
                step: ckpt.step,
                parts,
                digests,
                windows: Vec::new(),
                unchanged: Vec::new(),
                residual: ckpt.residual().clone(),
                full: Some(ckpt.clone()),
            });
        }
    }

    let (fetch_idx, unchanged) = partition_windows(layout, &digests, spec)
        .with_context(|| format!("member {} step {}", ckpt.member, ckpt.step))?;
    let mut windows = Vec::with_capacity(fetch_idx.len());
    for idx in fetch_idx {
        let e = &layout.entries()[idx];
        windows.push(encode_window(
            spec.codec,
            &e.name,
            &e.shape,
            &flat.data()[e.range()],
        ));
    }
    Ok(FetchResult {
        member: ckpt.member,
        step: ckpt.step,
        parts,
        digests,
        windows,
        unchanged,
        residual: ckpt.residual().clone(),
        full: None,
    })
}

/// Decode every fetched window and check its bytes against the digest
/// table it rode in with — the install-side half of the "corrupt
/// payloads fail loudly instead of poisoning a delta basis" guarantee
/// (the publish-side half is the `CKPT0003`/`CKPT0004` verify-on-load).
/// Without this, a flipped byte in a spool payload would be installed
/// AND its pre-corruption digest adopted as the basis, so every later
/// fetch would skip the window as "unchanged" and the corruption would
/// persist silently. An encoded payload that fails to decode — or
/// decodes to bytes that miss the digest — dies here too, so the codec
/// layer cannot weaken the guarantee. For in-memory backends the hash is
/// redundant (windows are copied out of the buffer the table was
/// computed from) but it only touches the changed bytes.
pub(crate) fn decode_and_verify(
    windows: Vec<FetchedWindow>,
    parts: &[(String, Vec<usize>)],
    digests: &[u64],
) -> Result<Vec<(String, Vec<f32>)>> {
    let mut out = Vec::with_capacity(windows.len());
    for w in windows {
        let idx = match parts.iter().position(|(n, _)| n == &w.name) {
            Some(i) => i,
            None => bail!("fetched window {:?} is not in the plane's window table", w.name),
        };
        let name = w.name.clone();
        let data = w.into_f32()?;
        let got = content_digest(&data);
        if got != digests[idx] {
            bail!(
                "window {name:?}: fetched payload hashes to {got:#018x}, digest table says \
                 {:#018x} — corrupt delta payload",
                digests[idx]
            );
        }
        out.push((name, data));
    }
    Ok(out)
}

// -------------------------------------------------------- delta reader

/// Accumulated accounting of a [`DeltaCache`] reader's traffic.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DeltaStats {
    /// Fetches that moved (or zero-copy shared) the whole plane.
    pub full_fetches: u64,
    /// Fetches answered as a delta against an installed basis.
    pub delta_fetches: u64,
    /// Windows whose payload was actually moved/installed.
    pub windows_moved: u64,
    /// Windows skipped because their digest matched the basis.
    pub windows_unchanged: u64,
    /// Moved windows that arrived codec-encoded (non-raw tag).
    pub windows_encoded: u64,
    /// Parameter payload bytes moved over the medium (full planes count
    /// whole; encoded windows count their encoded size).
    pub payload_bytes: u64,
}

impl DeltaStats {
    /// Fold another reader's accounting into this one (the single point
    /// of truth for aggregating per-reader caches into a run total).
    pub fn merge(&mut self, other: DeltaStats) {
        self.full_fetches += other.full_fetches;
        self.delta_fetches += other.delta_fetches;
        self.windows_moved += other.windows_moved;
        self.windows_unchanged += other.windows_unchanged;
        self.windows_encoded += other.windows_encoded;
        self.payload_bytes += other.payload_bytes;
    }
}

/// One teacher's installed plane: the buffer delta fetches patch, plus
/// the digest basis sent with the next fetch.
struct InstalledPlane {
    step: u64,
    flat: Arc<FlatBuffer>,
    digests: Vec<u64>,
    residual: TensorMap,
}

impl InstalledPlane {
    /// Whether the source plane still has our exact window set (names +
    /// shapes, in order) — the precondition for applying a delta.
    fn matches(&self, parts: &[(String, Vec<usize>)]) -> bool {
        let entries = self.flat.layout().entries();
        entries.len() == parts.len()
            && entries
                .iter()
                .zip(parts)
                .all(|(e, (name, shape))| e.name == *name && e.shape == *shape)
    }
}

/// The reader side of incremental exchange: a per-teacher cache of
/// installed planes. Each read sends the installed digest [`Basis`],
/// applies the returned delta in place via
/// [`FlatBuffer::write_window`](crate::runtime::flat::FlatBuffer::write_window)
/// (copy-on-write when a previously handed-out checkpoint still shares
/// the buffer), and returns an ordinary `Arc<Checkpoint>` byte-identical
/// to a full fetch. Falls back to a full read whenever the publisher's
/// plane no longer matches the basis.
///
/// Not thread-safe by itself (`&mut self`): each coordinator/orchestrator
/// run owns one.
#[derive(Default)]
pub struct DeltaCache {
    planes: HashMap<usize, InstalledPlane>,
    stats: DeltaStats,
    /// Codec this reader advertises on every fetch ([`Codec::Raw`] =
    /// classic uncompressed frames). Installed planes are byte-identical
    /// either way — the codec only changes how moved windows are framed.
    codec: Codec,
    /// When present, every successful read emits `Event::Fetch` +
    /// `Event::DeltaInstall` into the journal (the local [`DeltaStats`]
    /// stays authoritative for per-cache merges either way).
    recorder: Option<Recorder>,
}

impl DeltaCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Advertise `codec` on every fetch this cache issues (compressed
    /// window payloads where the backend supports them).
    pub fn with_codec(mut self, codec: Codec) -> Self {
        self.codec = codec;
        self
    }

    /// Emit fetch/install events into `recorder` in addition to the
    /// local accounting.
    pub fn with_recorder(mut self, recorder: Recorder) -> Self {
        self.recorder = Some(recorder);
        self
    }

    /// Traffic accounting so far.
    pub fn stats(&self) -> DeltaStats {
        self.stats
    }

    /// Step of the installed plane for a member, if any.
    pub fn installed_step(&self, member: usize) -> Option<u64> {
        self.planes.get(&member).map(|p| p.step)
    }

    /// Delta-aware `latest`: freshest available checkpoint, moving only
    /// changed windows when a basis is installed.
    pub fn latest(
        &mut self,
        transport: &dyn ExchangeTransport,
        member: usize,
    ) -> Result<Option<Arc<Checkpoint>>> {
        self.latest_at_most(transport, member, ANY_STEP)
    }

    /// Delta-aware `latest_at_most` (see [`DeltaCache::latest`]).
    pub fn latest_at_most(
        &mut self,
        transport: &dyn ExchangeTransport,
        member: usize,
        max_step: u64,
    ) -> Result<Option<Arc<Checkpoint>>> {
        let recorder = self.recorder.clone();
        let t0 = recorder.as_ref().map(|r| r.now_us());
        let before = self.stats;
        let basis = self.planes.get(&member).map(|p| Basis {
            step: p.step,
            digests: p.digests.clone(),
        });
        let spec = FetchSpec {
            member,
            max_step,
            basis,
            windows: WindowSel::All,
            codec: self.codec,
        };
        let out = match transport.fetch(&spec)? {
            Some(res) => self.install(transport, max_step, res, true)?,
            None => None,
        };
        if let (Some(rec), Some(t0), Some(ck)) = (recorder.as_ref(), t0, out.as_ref()) {
            // Event payloads are the per-read diff of the authoritative
            // local stats, so the journal and the struct cannot drift.
            let d = {
                let after = self.stats;
                DeltaStats {
                    full_fetches: after.full_fetches - before.full_fetches,
                    delta_fetches: after.delta_fetches - before.delta_fetches,
                    windows_moved: after.windows_moved - before.windows_moved,
                    windows_unchanged: after.windows_unchanged - before.windows_unchanged,
                    windows_encoded: after.windows_encoded - before.windows_encoded,
                    payload_bytes: after.payload_bytes - before.payload_bytes,
                }
            };
            let t1 = rec.now_us();
            rec.record_at(
                t0,
                Event::Fetch {
                    member,
                    step: ck.step,
                    bytes: d.payload_bytes,
                    dur_us: t1.saturating_sub(t0),
                },
            );
            rec.record_at(
                t1,
                Event::DeltaInstall {
                    member,
                    step: ck.step,
                    full: d.full_fetches > 0,
                    moved: d.windows_moved,
                    unchanged: d.windows_unchanged,
                    encoded: d.windows_encoded,
                    bytes: d.payload_bytes,
                },
            );
        }
        Ok(out)
    }

    /// Install one fetch result and hand out the resulting checkpoint.
    fn install(
        &mut self,
        transport: &dyn ExchangeTransport,
        max_step: u64,
        res: FetchResult,
        allow_refetch: bool,
    ) -> Result<Option<Arc<Checkpoint>>> {
        let FetchResult {
            member,
            step,
            parts,
            digests,
            windows,
            unchanged,
            residual,
            full,
        } = res;

        // Zero-copy full hand-off (first fetch, in-memory backends).
        if let Some(full) = full {
            self.stats.full_fetches += 1;
            self.stats.windows_moved += parts.len() as u64;
            self.stats.payload_bytes += full.flat().layout().total_bytes() as u64;
            self.planes.insert(
                member,
                InstalledPlane {
                    step,
                    flat: full.flat().clone(),
                    digests,
                    residual: full.residual().clone(),
                },
            );
            return Ok(Some(full));
        }

        // Wire accounting happens before the decode: encoded windows are
        // charged at the size they actually moved.
        let moved_windows = windows.len() as u64;
        let moved_bytes: u64 = windows.iter().map(|w| w.wire_bytes()).sum();
        let moved_encoded = windows.iter().filter(|w| w.codec() != Codec::Raw).count() as u64;

        // Every installed byte must decode cleanly and hash to the digest
        // it will be remembered by — see `decode_and_verify`.
        let decoded = decode_and_verify(windows, &parts, &digests)?;

        let complete = unchanged.is_empty() && decoded.len() == parts.len();
        let matches = self
            .planes
            .get(&member)
            .map(|p| p.matches(&parts))
            .unwrap_or(false);

        if !matches {
            if !complete {
                // The publisher's plane no longer matches the basis we
                // sent, yet the answer is still a delta (a positional
                // digest coincidence across a reshaped plane). Drop the
                // basis and fetch fresh once.
                if !allow_refetch {
                    bail!(
                        "member {member}: basis-free fetch still returned a partial plane \
                         ({} of {} windows)",
                        decoded.len(),
                        parts.len()
                    );
                }
                self.planes.remove(&member);
                return match transport.fetch(&FetchSpec::full(member, max_step))? {
                    Some(r) => self.install(transport, max_step, r, false),
                    None => Ok(None),
                };
            }
            // Full rebuild from a complete window set.
            let layout = Arc::new(FlatLayout::from_named_shapes(parts));
            let mut buf = FlatBuffer::zeros(layout);
            for (name, data) in &decoded {
                buf.write_window(name, data)?;
            }
            self.stats.full_fetches += 1;
            self.stats.windows_moved += moved_windows;
            self.stats.windows_encoded += moved_encoded;
            self.stats.payload_bytes += moved_bytes;
            let flat = Arc::new(buf);
            self.planes.insert(
                member,
                InstalledPlane {
                    step,
                    flat: flat.clone(),
                    digests,
                    residual: residual.clone(),
                },
            );
            return Ok(Some(Arc::new(Checkpoint::from_flat(
                member, step, flat, residual,
            ))));
        }

        // Delta apply: patch changed windows into the installed plane.
        // Arc::make_mut is copy-on-write: in place when no handed-out
        // checkpoint still shares the buffer, one local clone otherwise —
        // either way the transport moved only the changed bytes. An
        // all-unchanged fetch touches nothing at all.
        let plane = self.planes.get_mut(&member).expect("matches checked");
        if !decoded.is_empty() {
            let buf = Arc::make_mut(&mut plane.flat);
            for (name, data) in &decoded {
                buf.write_window(name, data)?;
            }
        }
        plane.step = step;
        plane.digests = digests;
        plane.residual = residual;
        self.stats.delta_fetches += 1;
        self.stats.windows_moved += moved_windows;
        self.stats.windows_unchanged += unchanged.len() as u64;
        self.stats.windows_encoded += moved_encoded;
        self.stats.payload_bytes += moved_bytes;
        Ok(Some(Arc::new(Checkpoint::from_flat(
            member,
            plane.step,
            plane.flat.clone(),
            plane.residual.clone(),
        ))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Tensor, TensorMap};

    #[test]
    fn kind_parse_roundtrip() {
        for (s, k) in [
            ("inproc", TransportKind::InProcess),
            ("spool", TransportKind::SpoolDir),
            ("socket", TransportKind::Socket),
        ] {
            assert_eq!(TransportKind::parse(s).unwrap(), k);
            assert_eq!(TransportKind::parse(k.name()).unwrap(), k);
        }
        assert!(TransportKind::parse("carrier-pigeon").is_err());
    }

    #[test]
    fn windowed_fetch_counts_payload_bytes() {
        let f = WindowedFetch {
            member: 0,
            step: 1,
            windows: vec![
                FetchedWindow::raw("a".into(), vec![3], vec![0.0; 3]),
                FetchedWindow::raw("b".into(), vec![2, 2], vec![0.0; 4]),
            ],
        };
        assert_eq!(f.payload_bytes(), (3 + 4) * 4);
        // encoded windows count the bytes that actually moved
        let (tag, bytes) = Codec::Shuffle.encode(&[0.0; 16]);
        let enc = FetchedWindow::encoded("c".into(), vec![16], tag, bytes.clone());
        assert_eq!(enc.wire_bytes(), bytes.len() as u64);
        assert!(enc.wire_bytes() < 16 * 4);
        assert_eq!(enc.to_f32().unwrap(), vec![0.0; 16]);
    }

    fn two_window_ckpt(member: usize, step: u64, a: f32, b: f32) -> Arc<Checkpoint> {
        let mut params = TensorMap::new();
        params.insert("params.a", Tensor::f32(&[2], vec![a, a]).unwrap());
        params.insert("params.b", Tensor::f32(&[3], vec![b, b, b]).unwrap());
        Arc::new(Checkpoint::new(member, step, params))
    }

    #[test]
    fn fetch_from_checkpoint_full_is_zero_copy() {
        let ck = two_window_ckpt(0, 5, 1.0, 2.0);
        let res = fetch_from_checkpoint(&ck, &FetchSpec::full(0, ANY_STEP)).unwrap();
        assert_eq!(res.step, 5);
        assert_eq!(res.parts.len(), 2);
        assert_eq!(res.digests.len(), 2);
        assert!(res.windows.is_empty() && res.unchanged.is_empty());
        let full = res.full.as_ref().expect("full hand-off");
        assert!(Arc::ptr_eq(full, &ck), "full fetch copied the checkpoint");
        assert_eq!(res.payload_bytes(), (2 + 3) * 4);
        assert_eq!(res.total_bytes(), (2 + 3) * 4);
    }

    #[test]
    fn fetch_from_checkpoint_delta_skips_unchanged() {
        let v1 = two_window_ckpt(0, 5, 1.0, 2.0);
        let v2 = two_window_ckpt(0, 9, 1.0, 3.0); // params.a unchanged
        let basis = Basis {
            step: 5,
            digests: v1.window_digests().as_ref().clone(),
        };
        let res =
            fetch_from_checkpoint(&v2, &FetchSpec::full(0, ANY_STEP).with_basis(basis)).unwrap();
        assert!(res.full.is_none());
        assert_eq!(res.unchanged, vec!["params.a".to_string()]);
        assert_eq!(res.windows.len(), 1);
        assert_eq!(res.windows[0].name, "params.b");
        assert_eq!(res.windows[0].to_f32().unwrap(), vec![3.0; 3]);
        assert_eq!(res.payload_bytes(), 3 * 4);
        // a basis of the wrong arity is ignored: full read
        let bad = Basis {
            step: 5,
            digests: vec![0; 7],
        };
        let res =
            fetch_from_checkpoint(&v2, &FetchSpec::full(0, ANY_STEP).with_basis(bad)).unwrap();
        assert!(res.full.is_some(), "invalid basis should degrade to full");
    }

    #[test]
    fn fetch_from_checkpoint_honors_codec_negotiation() {
        let v1 = two_window_ckpt(0, 5, 1.0, 2.0);
        let v2 = two_window_ckpt(0, 9, 1.0, 3.0); // params.a unchanged
        let basis = Basis {
            step: 5,
            digests: v1.window_digests().as_ref().clone(),
        };
        let spec = FetchSpec::full(0, ANY_STEP)
            .with_basis(basis)
            .with_codec(Codec::Shuffle);
        let res = fetch_from_checkpoint(&v2, &spec).unwrap();
        assert_eq!(res.windows.len(), 1);
        // constant-valued window: the encoder pays off and the tag says so
        assert_eq!(res.windows[0].codec(), Codec::Shuffle);
        assert!(res.payload_bytes() < 3 * 4, "{}", res.payload_bytes());
        // decode + digest verify reproduces the publisher's bytes
        assert_eq!(res.windows[0].to_f32().unwrap(), vec![3.0; 3]);
        let decoded = decode_and_verify(res.windows.clone(), &res.parts, &res.digests).unwrap();
        assert_eq!(decoded[0].1, vec![3.0; 3]);
        // a corrupt encoded payload fails loudly at the install boundary
        let mut bad = res.windows.clone();
        if let WindowPayload::Encoded { bytes, .. } = &mut bad[0].payload {
            bytes[0] ^= 0x01;
        }
        assert!(decode_and_verify(bad, &res.parts, &res.digests).is_err());
    }

    #[test]
    fn delta_cache_with_codec_installs_byte_identical_planes() {
        let store = InProcess::new(8);
        let t: &dyn ExchangeTransport = &store;
        let mut plain = DeltaCache::new();
        let mut coded = DeltaCache::new().with_codec(Codec::Shuffle);

        for (step, b) in [(1u64, 2.0f32), (5, 3.0), (9, 4.0)] {
            store.publish((*two_window_ckpt(0, step, 1.0, b)).clone()).unwrap();
            let a = plain.latest(t, 0).unwrap().unwrap();
            let c = coded.latest(t, 0).unwrap().unwrap();
            assert_eq!(a.flat().data(), c.flat().data(), "codec changed bytes");
            assert_eq!(a.step, c.step);
        }
        let (ps, cs) = (plain.stats(), coded.stats());
        assert_eq!(ps.windows_moved, cs.windows_moved);
        assert_eq!(ps.windows_unchanged, cs.windows_unchanged);
        assert_eq!(ps.windows_encoded, 0);
        assert!(cs.windows_encoded > 0, "codec never engaged: {cs:?}");
        assert!(
            cs.payload_bytes < ps.payload_bytes,
            "encoded deltas should move fewer bytes: {} !< {}",
            cs.payload_bytes,
            ps.payload_bytes
        );
    }

    #[test]
    fn fetch_result_into_checkpoint_rejects_partial() {
        let v1 = two_window_ckpt(0, 5, 1.0, 2.0);
        let v2 = two_window_ckpt(0, 9, 1.0, 3.0);
        let basis = Basis {
            step: 5,
            digests: v1.window_digests().as_ref().clone(),
        };
        let res =
            fetch_from_checkpoint(&v2, &FetchSpec::full(0, ANY_STEP).with_basis(basis)).unwrap();
        assert!(res.into_checkpoint().is_err(), "delta materialized alone");
    }

    #[test]
    fn delta_cache_installs_byte_identical_planes() {
        let store = InProcess::new(8);
        let t: &dyn ExchangeTransport = &store;
        let mut cache = DeltaCache::new();

        store.publish((*two_window_ckpt(0, 5, 1.0, 2.0)).clone()).unwrap();
        let first = cache.latest(t, 0).unwrap().unwrap();
        assert_eq!(first.step, 5);
        assert_eq!(cache.stats().full_fetches, 1);
        assert_eq!(cache.installed_step(0), Some(5));

        // only params.b changes: the second fetch is a delta
        store.publish((*two_window_ckpt(0, 9, 1.0, 3.0)).clone()).unwrap();
        let second = cache.latest(t, 0).unwrap().unwrap();
        let direct = InProcess::latest(&store, 0).unwrap();
        assert_eq!(second.step, 9);
        assert_eq!(second.flat().data(), direct.flat().data());
        let stats = cache.stats();
        assert_eq!(stats.delta_fetches, 1);
        assert_eq!(stats.windows_unchanged, 1);
        assert_eq!(stats.windows_moved, 2 + 1); // full(2) + delta(1)
        // the first handed-out checkpoint kept its pre-delta bytes
        assert_eq!(first.flat().view("params.b").unwrap(), &[2.0; 3]);

        // nothing changed: a re-fetch moves zero windows
        let third = cache.latest(t, 0).unwrap().unwrap();
        assert_eq!(third.flat().data(), direct.flat().data());
        assert_eq!(cache.stats().windows_moved, 3);
        assert_eq!(cache.stats().windows_unchanged, 1 + 2);
        assert!(cache.latest(t, 7).unwrap().is_none(), "absent member");
    }

    #[test]
    fn delta_cache_rebuilds_on_reshaped_plane() {
        let store = InProcess::new(8);
        let t: &dyn ExchangeTransport = &store;
        let mut cache = DeltaCache::new();
        store.publish((*two_window_ckpt(0, 1, 1.0, 2.0)).clone()).unwrap();
        cache.latest(t, 0).unwrap().unwrap();

        // the member's plane grows a window: basis arity no longer fits
        let mut params = TensorMap::new();
        params.insert("params.a", Tensor::f32(&[2], vec![4.0, 4.0]).unwrap());
        params.insert("params.b", Tensor::f32(&[3], vec![5.0; 3]).unwrap());
        params.insert("params.c", Tensor::f32(&[1], vec![6.0]).unwrap());
        store.publish(Checkpoint::new(0, 2, params)).unwrap();

        let got = cache.latest(t, 0).unwrap().unwrap();
        let direct = InProcess::latest(&store, 0).unwrap();
        assert_eq!(got.flat().data(), direct.flat().data());
        assert!(got.flat().layout().same_plane(direct.flat().layout()));
        assert_eq!(cache.stats().full_fetches, 2);
    }
}

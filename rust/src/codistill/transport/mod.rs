//! Pluggable checkpoint-exchange transports.
//!
//! The paper's systems argument (§2.1) is that codistillation scales
//! because teachers only need **rarely transmitted** parameter snapshots —
//! which makes the transmission medium swappable. This module fixes one
//! API, [`ExchangeTransport`], and ships three interchangeable backends
//! that move the identical `CKPT0002` flat-plane bytes:
//!
//! * [`InProcess`] — the zero-copy `Arc<FlatBuffer>` store: publisher,
//!   history, and every reader share one buffer. The default for
//!   single-process runs and the reference implementation the other
//!   backends must match byte-for-byte.
//! * [`SpoolDir`] — checkpoints as `CKPT0002` files in a shared directory
//!   (one file per publication, written temp+rename so readers never see
//!   a torn file) plus an atomic `MANIFEST`. Separate coordinator
//!   processes exchange by pointing at the same directory; reads can
//!   `pread` just the windows they need out of the contiguous payload.
//! * [`Socket`](SocketTransport) — a length-prefixed request/response
//!   protocol over TCP or Unix sockets against a [`SocketServer`]. A
//!   member can pull a teacher's full plane in one response or *shard*
//!   the fetch: ask for the window table first, then request only the
//!   named [`FlatLayout`](crate::runtime::flat::FlatLayout) windows it
//!   needs, in batches.
//!
//! ## Sharded (windowed) fetch
//!
//! [`ExchangeTransport::fetch_windows`] is the window-addressed read: give
//! it a member, a staleness bound, and window names, and it returns just
//! those slices of the freshest matching plane plus enough metadata to
//! place them ([`WindowedFetch`]). `InProcess` slices the shared buffer,
//! `SpoolDir` `pread`s byte ranges out of the checkpoint file, and the
//! socket client turns it into a wire request the server answers from its
//! own in-process store. `netsim::ClusterModel::sharded_exchange_time`
//! prices exactly this path against the full-plane pull.
//!
//! ## Fault injection
//!
//! [`Faulty`] is a decorator over any backend: a seeded [`FaultPlan`]
//! deterministically injects delayed publishes, dropped/erroring fetches,
//! stale-window reads, and scripted member blackouts, so every §2.2
//! failure mode is a reproducible `cargo test` scenario
//! (`tests/coordinator_faults.rs`) instead of a hope about real networks.
//!
//! ## Liveness heartbeats
//!
//! [`ExchangeTransport::last_steps`] returns `(member, freshest step)`
//! pairs without moving checkpoint payloads — an in-memory scan for
//! [`InProcess`], a manifest parse for [`SpoolDir`], a dedicated opcode
//! for the socket protocol. The coordinator's liveness table is built
//! from these heartbeats.
//!
//! ## Garbage collection
//!
//! Every backend bounds its history to `history` publications per member;
//! [`ExchangeTransport::gc`] forces the bound onto durable state too
//! (spool files past the bound are deleted). The orchestrator calls it on
//! the publish cadence.

pub mod faulty;
pub mod inproc;
pub mod socket;
pub mod spool;

pub use faulty::{Blackout, FaultEvent, FaultKind, FaultPlan, Faulty};
pub use inproc::InProcess;
pub use socket::{SocketServer, SocketTransport};
pub use spool::SpoolDir;

use crate::codistill::store::Checkpoint;
use anyhow::{bail, Result};
use std::sync::Arc;

/// `max_step` value meaning "no staleness bound: freshest available".
pub const ANY_STEP: u64 = u64::MAX;

/// Which backend a transport is (CLI parsing, logging, bench labels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    InProcess,
    SpoolDir,
    Socket,
}

impl TransportKind {
    /// Parse a `--transport {inproc,spool,socket}` value.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "inproc" | "inprocess" | "mem" => Ok(TransportKind::InProcess),
            "spool" | "spooldir" | "dir" => Ok(TransportKind::SpoolDir),
            "socket" | "tcp" | "unix" => Ok(TransportKind::Socket),
            other => bail!("unknown transport {other:?} (want inproc|spool|socket)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProcess => "inproc",
            TransportKind::SpoolDir => "spool",
            TransportKind::Socket => "socket",
        }
    }
}

/// One window pulled by a sharded fetch: the name, its shape, and the
/// contiguous slice of the publisher's plane.
#[derive(Debug, Clone)]
pub struct FetchedWindow {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Result of [`ExchangeTransport::fetch_windows`]: which checkpoint the
/// windows came from, plus the windows themselves in request order.
#[derive(Debug, Clone)]
pub struct WindowedFetch {
    pub member: usize,
    pub step: u64,
    pub windows: Vec<FetchedWindow>,
}

impl WindowedFetch {
    /// Parameter payload bytes this fetch actually moved (4 bytes per f32
    /// element) — the quantity `netsim` prices for sharded exchange.
    pub fn payload_bytes(&self) -> u64 {
        self.windows.iter().map(|w| w.data.len() as u64 * 4).sum()
    }
}

/// One checkpoint-exchange medium. All methods take `&self`: transports
/// are shared (`Arc<dyn ExchangeTransport>`) between the orchestrator and
/// any number of members/threads.
///
/// Reads are racy by design (the paper's exchange is asynchronous): a
/// `latest` observed now may be superseded a step later. The only ordering
/// guarantee is per-member step monotonicity of publications.
pub trait ExchangeTransport: Send + Sync {
    /// Which backend this is.
    fn kind(&self) -> TransportKind;

    /// Publish a member's checkpoint. Steps must be non-decreasing per
    /// member.
    fn publish(&self, ckpt: Checkpoint) -> Result<()>;

    /// Freshest available checkpoint from a member (paper semantics);
    /// `None` while the member has never published.
    fn latest(&self, member: usize) -> Result<Option<Arc<Checkpoint>>>;

    /// Freshest checkpoint from a member with `step <= max_step`
    /// (explicit staleness injection). `max_step == ANY_STEP` is
    /// equivalent to [`ExchangeTransport::latest`].
    fn latest_at_most(&self, member: usize, max_step: u64) -> Result<Option<Arc<Checkpoint>>>;

    /// Sharded fetch: only the named windows of the freshest checkpoint
    /// from `member` with `step <= max_step`. Unknown window names are an
    /// error (the caller's layout disagrees with the publisher's plane);
    /// an absent checkpoint is `Ok(None)`.
    fn fetch_windows(
        &self,
        member: usize,
        max_step: u64,
        names: &[String],
    ) -> Result<Option<WindowedFetch>>;

    /// Members that have published at least once, ascending.
    fn members(&self) -> Result<Vec<usize>>;

    /// `(member, freshest published step)` heartbeats for every member
    /// that has published, ascending by member — the liveness probe the
    /// coordinator polls on its reload cadence. Backends override this
    /// with a metadata-only read (in-memory scan, manifest parse, a
    /// dedicated wire opcode); the default pulls whole checkpoints and is
    /// only acceptable for tests.
    fn last_steps(&self) -> Result<Vec<(usize, u64)>> {
        let mut out = Vec::new();
        for m in self.members()? {
            if let Some(c) = self.latest(m)? {
                out.push((m, c.step));
            }
        }
        Ok(out)
    }

    /// Enforce the history bound on durable state (delete spool files /
    /// server history past the bound). In-memory history is already
    /// bounded on publish, so for [`InProcess`] this is a no-op.
    fn gc(&self) -> Result<()>;

    /// Staleness (in steps) a reader at `now` would observe for a member.
    fn staleness(&self, member: usize, now: u64) -> Result<Option<u64>> {
        Ok(self.latest(member)?.map(|c| now.saturating_sub(c.step)))
    }
}

/// Slice a checkpoint held in memory into a [`WindowedFetch`] — the
/// shared read path for [`InProcess`] and the socket server.
pub(crate) fn windows_from_checkpoint(
    ckpt: &Checkpoint,
    names: &[String],
) -> Result<WindowedFetch> {
    let flat = ckpt.flat();
    let mut windows = Vec::with_capacity(names.len());
    for name in names {
        let entry = match flat.layout().entry(name) {
            Some(e) => e,
            None => bail!(
                "member {} step {}: plane has no window {name:?}",
                ckpt.member,
                ckpt.step
            ),
        };
        windows.push(FetchedWindow {
            name: name.clone(),
            shape: entry.shape.clone(),
            data: flat.view(name)?.to_vec(),
        });
    }
    Ok(WindowedFetch {
        member: ckpt.member,
        step: ckpt.step,
        windows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for (s, k) in [
            ("inproc", TransportKind::InProcess),
            ("spool", TransportKind::SpoolDir),
            ("socket", TransportKind::Socket),
        ] {
            assert_eq!(TransportKind::parse(s).unwrap(), k);
            assert_eq!(TransportKind::parse(k.name()).unwrap(), k);
        }
        assert!(TransportKind::parse("carrier-pigeon").is_err());
    }

    #[test]
    fn windowed_fetch_counts_payload_bytes() {
        let f = WindowedFetch {
            member: 0,
            step: 1,
            windows: vec![
                FetchedWindow {
                    name: "a".into(),
                    shape: vec![3],
                    data: vec![0.0; 3],
                },
                FetchedWindow {
                    name: "b".into(),
                    shape: vec![2, 2],
                    data: vec![0.0; 4],
                },
            ],
        };
        assert_eq!(f.payload_bytes(), (3 + 4) * 4);
    }
}

//! The spool-directory backend: checkpoint exchange through a shared
//! filesystem — the medium the paper actually describes (§2.1: workers
//! checkpoint to a distributed filesystem; others load the freshest
//! available file).
//!
//! ## Layout of a spool directory
//!
//! * `memberNNNN_stepNNNNNNNNNNNNNNNNNNNN.ckpt` — one `CKPT0003` file per
//!   publication, or `CKPT0004` with per-window codec-encoded payloads
//!   when the publisher opted in via [`SpoolDir::with_codec`], or
//!   `CKPT0005` (the `CKPT0004` table plus a per-window scale column
//!   surfacing int8 quantization metadata) when that codec is lossy
//!   (older `CKPT0002`/`CKPT0001` files still read; handles with
//!   different codecs interoperate on one directory because reads are
//!   driven by each file's own window table). Member
//!   and step are zero-padded so lexicographic directory order equals
//!   (member, step) order: manifest recovery after a crash is a plain
//!   sorted scan. Files are written to a hidden `.tmp_*` name and
//!   atomically renamed into place, so a concurrent reader (this process
//!   or another) never observes a torn checkpoint.
//! * `MANIFEST` — an atomic (write-temp+rename) text snapshot of the
//!   published set: a header line, then
//!   `member step filename [digest...]` per checkpoint, the trailing hex
//!   fields being the checkpoint's per-window content digests (read out
//!   of its `CKPT0003` header). Rewritten from a full directory scan on
//!   every publish and gc, so concurrent publishers converge; readers
//!   fall back to the directory scan whenever the manifest is missing or
//!   unparsable, and to the file's own header whenever a digest column is
//!   absent.
//!
//! ## Reads
//!
//! [`ExchangeTransport::fetch`] is the one native read. A no-basis
//! full-plane spec loads the whole file through the read cache (one
//! contiguous payload read, repeat reads of one step served from memory).
//! Anything else — named windows, or a delta [`Basis`] — parses only the
//! checkpoint header, compares the basis against the file's digest table
//! (or the manifest's, for digest-free `CKPT0002` files published by a
//! digest-aware writer), then `pread`s (seek + exact read) exactly the
//! byte ranges of the windows whose content changed: an exchange over a
//! shared file system where each reader moves only the bytes it needs.
//!
//! Two processes exchange by constructing `SpoolDir::open` on the same
//! directory (or one side may be an
//! [`InProcess`](crate::codistill::transport::InProcess) store with
//! `.with_spool(dir)` — it writes the identical files).
//!
//! [`FlatLayout`]: crate::runtime::flat::FlatLayout
//! [`Basis`]: crate::codistill::transport::Basis

use crate::codistill::store::{
    read_framed_tensor, read_name, read_shape, read_u32, read_u64, Checkpoint, MAGIC_V1, MAGIC_V2,
    MAGIC_V3, MAGIC_V4, MAGIC_V5,
};
use crate::codistill::transport::{
    fetch_from_checkpoint, partition_windows, Codec, ExchangeTransport, FetchResult, FetchSpec,
    FetchedWindow, TransportKind, WindowSel,
};
use crate::runtime::flat::FlatLayout;
use crate::runtime::TensorMap;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Seek, SeekFrom};
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

const MANIFEST: &str = "MANIFEST";
/// Current manifest header (v2: digest columns after the filename).
const MANIFEST_HEADER: &str = "SPOOLMANIFEST v2";
/// Digest-free manifests from older builds still parse.
const MANIFEST_HEADER_V1: &str = "SPOOLMANIFEST v1";

/// Canonical spool file name: zero-padded so lexicographic order equals
/// (member, step) order — 4 digits cover the paper's member counts, 20
/// digits cover all of u64.
pub fn spool_file_name(member: usize, step: u64) -> String {
    format!("member{member:04}_step{step:020}.ckpt")
}

/// Hidden temp name a publisher writes before the atomic rename (dotted,
/// pid-tagged: skipped by scans, unique across publisher processes).
pub fn spool_temp_name(member: usize, step: u64) -> String {
    format!(
        ".tmp_{}_member{member:04}_step{step:020}.ckpt",
        std::process::id()
    )
}

/// Parse `memberN..N_stepN..N.ckpt` (padding optional on read, so spools
/// from older builds still scan).
pub fn parse_spool_name(name: &str) -> Option<(usize, u64)> {
    let rest = name.strip_prefix("member")?.strip_suffix(".ckpt")?;
    let (member, step) = rest.split_once("_step")?;
    Some((member.parse().ok()?, step.parse().ok()?))
}

/// All published (member, step) pairs in `dir`, ascending per member.
fn scan_dir(dir: &Path) -> Result<BTreeMap<usize, Vec<u64>>> {
    let mut out: BTreeMap<usize, Vec<u64>> = BTreeMap::new();
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("scanning spool {}", dir.display()))?
    {
        let name = entry?.file_name().to_string_lossy().into_owned();
        if let Some((member, step)) = parse_spool_name(&name) {
            out.entry(member).or_default().push(step);
        }
    }
    for steps in out.values_mut() {
        steps.sort_unstable();
        steps.dedup();
    }
    Ok(out)
}

/// Atomically rewrite `dir/MANIFEST` from a directory scan. Every
/// publisher into a spool directory must call this after adding/pruning
/// files ([`SpoolDir::publish`] and `InProcess::with_spool` both do), so
/// readers that prefer the manifest converge on the true published set.
/// Each line also persists the checkpoint's per-window digest table so
/// delta readers can price and verify an exchange from manifest metadata
/// alone. A publisher passes its fresh checkpoint's digests as
/// `fresh = (member, step, digests)` — authoritative for that file even
/// when it overwrote an equal-step publication, and saving the header
/// read for it.
pub(crate) fn write_manifest(dir: &Path, fresh: Option<(usize, u64, &[u64])>) -> Result<()> {
    let scan = scan_dir(dir)?;
    // Remaining digest columns: reuse the previous manifest's (files
    // other than `fresh` are immutable while listed) and header-read only
    // files covered by neither, keeping the publish path at O(1) file
    // opens instead of O(members × history).
    let prior = read_manifest_digests(dir).unwrap_or_default();
    let mut text = String::from(MANIFEST_HEADER);
    text.push('\n');
    for (member, steps) in &scan {
        for step in steps {
            let file = spool_file_name(*member, *step);
            let is_fresh = matches!(fresh, Some((fm, fs, _)) if fm == *member && fs == *step);
            // A file pruned between the directory scan and this row (a
            // concurrent publisher's gc) must not be resurrected into the
            // manifest — a manifest-preferring reader would resolve a
            // (member, step) whose payload is gone and only recover
            // through the scan fallback. The prior-digest reuse below
            // makes this trap easy to spring (no file open needed), so
            // re-check existence per row; the freshly renamed file is
            // exempt.
            if !is_fresh && !dir.join(&file).exists() {
                continue;
            }
            text.push_str(&format!("{member} {step} {file}"));
            // Best-effort: v1/v2 files simply get no column and readers
            // fall back to the file header.
            let digests = match fresh {
                Some((_, _, fd)) if is_fresh => Some(fd.to_vec()),
                _ => prior
                    .get(&(*member, *step))
                    .cloned()
                    .or_else(|| read_file_digests(&dir.join(&file))),
            };
            if let Some(digests) = digests {
                for d in digests {
                    text.push_str(&format!(" {d:016x}"));
                }
            }
            text.push('\n');
        }
    }
    let tmp = dir.join(format!(".tmp_{}_{MANIFEST}", std::process::id()));
    std::fs::write(&tmp, text)?;
    std::fs::rename(&tmp, dir.join(MANIFEST))?;
    Ok(())
}

/// The digest table in a spool file's `CKPT0003` header; `None` for
/// older formats or any parse failure.
fn read_file_digests(path: &Path) -> Option<Vec<u64>> {
    let file = std::fs::File::open(path).ok()?;
    parse_plane_header(std::io::BufReader::new(file))
        .ok()
        .flatten()
        .and_then(|h| h.digests)
}

/// Manifest lines split into the published set; `None` when the manifest
/// is missing or unparsable.
fn manifest_lines(dir: &Path) -> Option<String> {
    let text = std::fs::read_to_string(dir.join(MANIFEST)).ok()?;
    let header = text.lines().next()?;
    if header != MANIFEST_HEADER && header != MANIFEST_HEADER_V1 {
        return None;
    }
    Some(text)
}

/// Read the published set from the manifest; `None` when it is missing or
/// unparsable (callers fall back to a directory scan).
fn read_manifest(dir: &Path) -> Option<BTreeMap<usize, Vec<u64>>> {
    let text = manifest_lines(dir)?;
    let mut out: BTreeMap<usize, Vec<u64>> = BTreeMap::new();
    for line in text.lines().skip(1) {
        if line.trim().is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let member: usize = parts.next()?.parse().ok()?;
        let step: u64 = parts.next()?.parse().ok()?;
        out.entry(member).or_default().push(step);
    }
    for steps in out.values_mut() {
        steps.sort_unstable();
        steps.dedup();
    }
    Some(out)
}

/// The digest columns the manifest persists, keyed by (member, step);
/// `None` when the manifest is missing or unparsable. Entries without
/// digest columns are simply absent.
pub(crate) fn read_manifest_digests(dir: &Path) -> Option<HashMap<(usize, u64), Vec<u64>>> {
    let text = manifest_lines(dir)?;
    let mut out = HashMap::new();
    for line in text.lines().skip(1) {
        if line.trim().is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let member: usize = parts.next()?.parse().ok()?;
        let step: u64 = parts.next()?.parse().ok()?;
        let _file = parts.next()?;
        let digests: Vec<u64> = parts
            .map(|p| u64::from_str_radix(p, 16))
            .collect::<Result<_, _>>()
            .ok()?;
        if !digests.is_empty() {
            out.insert((member, step), digests);
        }
    }
    Some(out)
}

/// Whether `gc` must rewrite the manifest: it is missing/unparsable
/// (recovery), or it references a checkpoint file that no longer exists
/// — the signature of a manifest write that lost a race with a
/// concurrent prune. One manifest parse answers both questions.
pub(crate) fn manifest_needs_rewrite(dir: &Path) -> bool {
    match read_manifest(dir) {
        None => true,
        Some(m) => m.iter().any(|(member, steps)| {
            steps
                .iter()
                .any(|&s| !dir.join(spool_file_name(*member, s)).exists())
        }),
    }
}

/// Delete every member's spool files past the last `history` steps (the
/// spool-side history bound — the in-memory bound's durable twin).
/// Returns how many files were removed so callers can skip manifest
/// rewrites when nothing changed.
pub(crate) fn prune_spool(dir: &Path, history: usize) -> Result<usize> {
    let history = history.max(1);
    let mut pruned = 0usize;
    for (member, steps) in scan_dir(dir)? {
        if steps.len() > history {
            for &step in &steps[..steps.len() - history] {
                if std::fs::remove_file(dir.join(spool_file_name(member, step))).is_ok() {
                    pruned += 1;
                }
            }
        }
    }
    Ok(pruned)
}

/// `CKPT0002`/`CKPT0003`/`CKPT0004` header: everything before the
/// payload, plus where the payload starts — enough to address any
/// window's bytes in the file, and (v3/v4) the digest table a delta
/// fetch compares against.
struct PlaneHeader {
    member: usize,
    step: u64,
    layout: FlatLayout,
    /// Per-window content digests in plane order (`CKPT0003`/`CKPT0004`).
    digests: Option<Vec<u64>>,
    /// `CKPT0004` only: per-window codec tag and encoded byte range
    /// relative to `payload_start`, in plane order.
    enc_windows: Option<Vec<(Codec, Range<u64>)>>,
    /// Total payload bytes on disk (raw plane bytes for v2/v3, summed
    /// encoded lengths for v4) — the residual section starts right after.
    payload_len: u64,
    /// Absolute file offset of the first payload byte.
    payload_start: u64,
}

/// Reader adapter that tracks the absolute stream position.
struct CountingReader<R> {
    inner: R,
    pos: u64,
}

impl<R: Read> Read for CountingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.pos += n as u64;
        Ok(n)
    }
}

/// Parse a v2/v3/v4 header from the start of `r`. Returns `None` for a
/// v1 file (no contiguous payload to address — callers load it whole).
fn parse_plane_header(r: impl Read) -> Result<Option<PlaneHeader>> {
    let mut f = CountingReader { inner: r, pos: 0 };
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic == MAGIC_V1 {
        return Ok(None);
    }
    let (with_digests, with_codecs, with_scales) = match &magic {
        m if m == MAGIC_V5 => (true, true, true),
        m if m == MAGIC_V4 => (true, true, false),
        m if m == MAGIC_V3 => (true, false, false),
        m if m == MAGIC_V2 => (false, false, false),
        _ => bail!("bad checkpoint magic"),
    };
    let member = read_u64(&mut f)? as usize;
    let step = read_u64(&mut f)?;
    let n_windows = read_u64(&mut f)? as usize;
    let mut parts = Vec::with_capacity(n_windows);
    let mut digests = Vec::with_capacity(if with_digests { n_windows } else { 0 });
    let mut encodings = Vec::with_capacity(if with_codecs { n_windows } else { 0 });
    for _ in 0..n_windows {
        let name = read_name(&mut f)?;
        let shape = read_shape(&mut f)?;
        parts.push((name, shape));
        if with_digests {
            digests.push(read_u64(&mut f)?);
        }
        if with_codecs {
            let mut tag = [0u8; 1];
            f.read_exact(&mut tag)?;
            let codec = Codec::from_id(tag[0])?;
            if with_scales {
                // v5 scale column: metadata only at this layer (the
                // payload carries its own authoritative header, which
                // decode validates), but an int8 row with a nonsense
                // scale means a corrupt table — fail here, not at read.
                let scale = f32::from_bits(read_u32(&mut f)?);
                if codec == Codec::Int8 && !(scale.is_finite() && scale > 0.0) {
                    bail!(
                        "window {:?}: int8 table scale {scale} is not a positive finite value",
                        parts.last().unwrap().0
                    );
                }
            }
            let enc_len = read_u64(&mut f)?;
            encodings.push((codec, enc_len));
        }
    }
    let layout = FlatLayout::from_named_shapes(parts);
    let (enc_windows, payload_len) = if with_codecs {
        // Encoded ranges by prefix sum; the payload-total field must
        // agree with the table.
        let mut ranges = Vec::with_capacity(encodings.len());
        let mut off = 0u64;
        for (i, (codec, enc_len)) in encodings.iter().enumerate() {
            if !codec.wire_len_ok(*enc_len, layout.entries()[i].len) {
                bail!(
                    "window {:?}: {} encoding of {enc_len} bytes is inconsistent with \
                     {} elems",
                    layout.entries()[i].name,
                    codec.name(),
                    layout.entries()[i].len
                );
            }
            ranges.push((*codec, off..off + enc_len));
            off += enc_len;
        }
        let total = read_u64(&mut f)?;
        if total != off {
            bail!("encoded payload claims {total} bytes, window table wants {off}");
        }
        (Some(ranges), total)
    } else {
        let payload_elems = read_u64(&mut f)? as usize;
        if payload_elems != layout.total_len() {
            bail!(
                "flat payload has {} elems, window table wants {}",
                payload_elems,
                layout.total_len()
            );
        }
        (None, layout.total_bytes() as u64)
    };
    Ok(Some(PlaneHeader {
        member,
        step,
        layout,
        digests: with_digests.then_some(digests),
        enc_windows,
        payload_len,
        payload_start: f.pos,
    }))
}

/// Shared-directory checkpoint exchange (see module docs).
pub struct SpoolDir {
    dir: PathBuf,
    history: usize,
    /// Codec this handle's publications are written under:
    /// [`Codec::Raw`] = `CKPT0003` files, lossless codecs = `CKPT0004`
    /// files with per-window encoded payloads, lossy codecs = `CKPT0005`
    /// files that additionally surface quantization scales in the
    /// window table. Read paths are codec-agnostic (the file's own
    /// table drives decoding), so handles with different codecs
    /// interoperate on one directory.
    codec: Codec,
    /// Loaded checkpoints keyed by (member, step): repeated `latest`
    /// reads on the reload cadence hit memory, not the filesystem.
    cache: Mutex<HashMap<(usize, u64), Arc<Checkpoint>>>,
}

impl SpoolDir {
    /// Open (creating if needed) a spool directory with a per-member
    /// retention bound of `history` publications.
    pub fn open(dir: &Path, history: usize) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating spool {}", dir.display()))?;
        Ok(SpoolDir {
            dir: dir.to_path_buf(),
            history: history.max(1),
            codec: Codec::Raw,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Publish through `codec`: checkpoints land as `CKPT0004` (or, for
    /// lossy codecs, `CKPT0005`) files whose windows are individually
    /// encoded (raw-tagged when the codec does not shrink them or, for
    /// lossy tags, when the window does not round-trip bit-exactly), so
    /// delta readers `pread` fewer bytes.
    pub fn with_codec(mut self, codec: Codec) -> Self {
        self.codec = codec;
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Published set: manifest when readable, directory scan otherwise
    /// (recovery path — zero-padded names make the scan order correct).
    fn published(&self) -> Result<BTreeMap<usize, Vec<u64>>> {
        match read_manifest(&self.dir) {
            Some(m) => Ok(m),
            None => scan_dir(&self.dir),
        }
    }

    /// Freshest step for `member` with `step <= max_step`.
    fn resolve(&self, member: usize, max_step: u64) -> Result<Option<u64>> {
        Ok(self
            .published()?
            .get(&member)
            .and_then(|steps| steps.iter().rev().find(|&&s| s <= max_step).copied()))
    }

    /// Like [`SpoolDir::resolve`] but always from a fresh directory scan —
    /// the fallback when a manifest-resolved file turns out to be gone
    /// (stale manifest, or a concurrent publisher pruned it mid-read).
    fn resolve_scan(&self, member: usize, max_step: u64) -> Result<Option<u64>> {
        Ok(scan_dir(&self.dir)?
            .get(&member)
            .and_then(|steps| steps.iter().rev().find(|&&s| s <= max_step).copied()))
    }

    /// Load (or fetch from cache) the checkpoint file for (member, step);
    /// `Ok(None)` when the file has vanished (concurrent prune / stale
    /// manifest) so callers can re-resolve instead of aborting the run.
    fn try_load_at(&self, member: usize, step: u64) -> Result<Option<Arc<Checkpoint>>> {
        if let Some(c) = self.cache.lock().unwrap().get(&(member, step)) {
            return Ok(Some(c.clone()));
        }
        let path = self.dir.join(spool_file_name(member, step));
        if !path.exists() {
            return Ok(None);
        }
        let ckpt = Arc::new(Checkpoint::load(&path)?);
        self.cache_insert(member, step, ckpt.clone());
        Ok(Some(ckpt))
    }

    /// Insert into the read cache, keeping at most `history` cached
    /// publications per member (count-based, mirroring the spool bound —
    /// steps advance by reload intervals, not by 1).
    fn cache_insert(&self, member: usize, step: u64, ckpt: Arc<Checkpoint>) {
        let mut cache = self.cache.lock().unwrap();
        cache.insert((member, step), ckpt);
        let mut steps: Vec<u64> = cache
            .keys()
            .filter(|&&(m, _)| m == member)
            .map(|&(_, s)| s)
            .collect();
        if steps.len() > self.history {
            steps.sort_unstable();
            let cutoff = steps[steps.len() - self.history];
            cache.retain(|&(m, s), _| m != member || s >= cutoff);
        }
    }

    /// Answer one fetch from the checkpoint file at (member, step).
    /// `Ok(None)` when the file has vanished (callers re-resolve).
    fn try_fetch_at(&self, spec: &FetchSpec, step: u64) -> Result<Option<FetchResult>> {
        // The classic full read: whole-file load through the read cache,
        // answered zero-copy from memory on repeat reads of one step.
        if spec.basis.is_none() && matches!(spec.windows, WindowSel::All) {
            return match self.try_load_at(spec.member, step)? {
                Some(ckpt) => Ok(Some(fetch_from_checkpoint(&ckpt, spec)?)),
                None => Ok(None),
            };
        }
        self.try_pread_fetch(spec, step)
    }

    /// Windowed/delta `pread` of one checkpoint file: parse the header,
    /// drop every requested window whose digest matches the basis, then
    /// seek + read exactly the remaining windows' byte ranges (plus the
    /// small residual section after the payload). `Ok(None)` when the
    /// file has vanished (callers re-resolve).
    fn try_pread_fetch(&self, spec: &FetchSpec, step: u64) -> Result<Option<FetchResult>> {
        let member = spec.member;
        let path = self.dir.join(spool_file_name(member, step));
        let file = match std::fs::File::open(&path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(e).with_context(|| format!("opening {}", path.display()))
            }
        };
        let mut reader = std::io::BufReader::new(file);
        let header = parse_plane_header(&mut reader)
            .with_context(|| format!("reading {}", path.display()))?;
        let header = match header {
            Some(h) => h,
            None => {
                // v1 spool file: no contiguous payload; load it whole
                // (cached) and answer from memory.
                return match self.try_load_at(member, step)? {
                    Some(ckpt) => Ok(Some(fetch_from_checkpoint(&ckpt, spec)?)),
                    None => Ok(None),
                };
            }
        };
        // Digest table: the file's own (v3), else the manifest's column
        // (a digest-aware publisher over a v2 file), else fall back to a
        // whole-file read — without digests there is nothing to compare
        // a basis against.
        let digests = match &header.digests {
            Some(d) => d.clone(),
            None => {
                let from_manifest = read_manifest_digests(&self.dir)
                    .and_then(|m| m.get(&(member, step)).cloned())
                    .filter(|d| d.len() == header.layout.len());
                match from_manifest {
                    Some(d) => d,
                    None => {
                        return match self.try_load_at(member, step)? {
                            Some(ckpt) => Ok(Some(fetch_from_checkpoint(&ckpt, spec)?)),
                            None => Ok(None),
                        };
                    }
                }
            }
        };
        let layout = &header.layout;
        // The selection/basis semantics are the shared transport core;
        // only the pread IO below is spool-specific.
        let (fetch_idx, unchanged) = partition_windows(layout, &digests, spec)
            .with_context(|| format!("member {member} step {step}"))?;
        let mut file = reader.into_inner();
        let mut windows = Vec::with_capacity(fetch_idx.len());
        for idx in fetch_idx {
            let entry = &layout.entries()[idx];
            match &header.enc_windows {
                // CKPT0004: pread exactly the window's encoded bytes and
                // hand them over still encoded — the install side
                // (DeltaCache / into_checkpoint) decodes and
                // digest-verifies, so a reader moves the compressed size
                // off disk and over any downstream accounting.
                Some(enc) => {
                    let (codec, range) = &enc[idx];
                    file.seek(SeekFrom::Start(header.payload_start + range.start))?;
                    let mut bytes = vec![0u8; (range.end - range.start) as usize];
                    file.read_exact(&mut bytes)?;
                    windows.push(FetchedWindow::encoded(
                        entry.name.clone(),
                        entry.shape.clone(),
                        *codec,
                        bytes,
                    ));
                }
                None => {
                    file.seek(SeekFrom::Start(
                        header.payload_start + entry.byte_range().start as u64,
                    ))?;
                    let mut data = vec![0f32; entry.len];
                    crate::codistill::store::read_f32s(&mut file, &mut data)?;
                    windows.push(FetchedWindow::raw(
                        entry.name.clone(),
                        entry.shape.clone(),
                        data,
                    ));
                }
            }
        }
        // The residual section sits right after the contiguous payload.
        file.seek(SeekFrom::Start(header.payload_start + header.payload_len))?;
        let mut tail = std::io::BufReader::new(file);
        let n_residual = read_u64(&mut tail)? as usize;
        let mut residual = TensorMap::new();
        for _ in 0..n_residual {
            let (name, t) = read_framed_tensor(&mut tail)?;
            residual.insert(name, t);
        }
        let parts = layout
            .entries()
            .iter()
            .map(|e| (e.name.clone(), e.shape.clone()))
            .collect();
        Ok(Some(FetchResult {
            member: header.member,
            step: header.step,
            parts,
            digests,
            windows,
            unchanged,
            residual,
            full: None,
        }))
    }
}

impl ExchangeTransport for SpoolDir {
    fn kind(&self) -> TransportKind {
        TransportKind::SpoolDir
    }

    fn publish(&self, ckpt: Checkpoint) -> Result<()> {
        if let Some(last) = self.resolve(ckpt.member, u64::MAX)? {
            if ckpt.step < last {
                bail!(
                    "member {} published step {} after step {}",
                    ckpt.member,
                    ckpt.step,
                    last
                );
            }
        }
        let member = ckpt.member;
        let step = ckpt.step;
        let tmp = self.dir.join(spool_temp_name(member, step));
        match self.codec {
            Codec::Raw => ckpt.save(&tmp)?,
            codec if codec.is_lossy() => ckpt.save_v5(&tmp, codec)?,
            codec => ckpt.save_v4(&tmp, codec)?,
        }
        std::fs::rename(&tmp, self.dir.join(spool_file_name(member, step)))?;
        prune_spool(&self.dir, self.history)?;
        // save() already computed (and cached) the digest table; hand it
        // to the manifest as the authority for this file.
        write_manifest(
            &self.dir,
            Some((member, step, ckpt.window_digests().as_slice())),
        )?;
        // Publisher keeps the Arc'd plane hot for its own readers.
        self.cache_insert(member, step, Arc::new(ckpt));
        Ok(())
    }

    /// The one native read (see the module's Reads section).
    fn fetch(&self, spec: &FetchSpec) -> Result<Option<FetchResult>> {
        if let Some(step) = self.resolve(spec.member, spec.max_step)? {
            if let Some(r) = self.try_fetch_at(spec, step)? {
                return Ok(Some(r));
            }
            // The resolved file vanished (stale manifest / concurrent
            // prune): fall back to a direct directory scan. A second
            // vanish is a hard error — something is deleting fresh files.
            if let Some(step) = self.resolve_scan(spec.member, spec.max_step)? {
                return match self.try_fetch_at(spec, step)? {
                    Some(r) => Ok(Some(r)),
                    None => bail!(
                        "spool file for member {} step {step} vanished during read",
                        spec.member
                    ),
                };
            }
        }
        Ok(None)
    }

    fn members(&self) -> Result<Vec<usize>> {
        Ok(self.published()?.keys().copied().collect())
    }

    fn last_steps(&self) -> Result<Vec<(usize, u64)>> {
        // Manifest (or scan) only — a liveness probe never opens a
        // checkpoint file.
        Ok(self
            .published()?
            .iter()
            .filter_map(|(&m, steps)| steps.last().map(|&s| (m, s)))
            .collect())
    }

    fn gc(&self) -> Result<()> {
        // Publish already prunes + rewrites the manifest; this pass only
        // touches the manifest when something actually changed, when it
        // is missing/unreadable, or when it still lists files a
        // concurrent pruner removed (a manifest write that lost the race
        // — gc actively drops the pruned rows instead of leaving every
        // reader on the directory-scan fallback).
        let pruned = prune_spool(&self.dir, self.history)?;
        let stale = manifest_needs_rewrite(&self.dir);
        if pruned > 0 || stale {
            write_manifest(&self.dir, None)?;
        }
        if pruned > 0 || stale {
            let published = self.published()?;
            self.cache.lock().unwrap().retain(|&(m, s), _| {
                published
                    .get(&m)
                    .map(|steps| steps.contains(&s))
                    .unwrap_or(false)
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Tensor, TensorMap};

    fn tdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("codistill_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn ckpt(member: usize, step: u64, vals: &[f32]) -> Checkpoint {
        let mut params = TensorMap::new();
        params.insert("params.a", Tensor::f32(&[2], vec![vals[0], vals[1]]).unwrap());
        params.insert("params.b", Tensor::f32(&[3], vec![vals[2], vals[3], vals[4]]).unwrap());
        Checkpoint::new(member, step, params)
    }

    #[test]
    fn names_zero_pad_and_parse() {
        assert_eq!(spool_file_name(3, 7), "member0003_step00000000000000000007.ckpt");
        assert_eq!(parse_spool_name(&spool_file_name(12, 1_000_000)), Some((12, 1_000_000)));
        // padding-free legacy names still parse
        assert_eq!(parse_spool_name("member0_step7.ckpt"), Some((0, 7)));
        assert_eq!(parse_spool_name("MANIFEST"), None);
        assert_eq!(parse_spool_name(".tmp_1_member0000_step00.ckpt"), None);
        // lexicographic order now equals step order (the seed's unpadded
        // names sorted step10 before step9)
        assert!(spool_file_name(0, 9) < spool_file_name(0, 10));
    }

    #[test]
    fn publish_read_roundtrip_and_manifest() {
        let dir = tdir("spooldir_rt");
        let spool = SpoolDir::open(&dir, 4).unwrap();
        spool.publish(ckpt(0, 5, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();
        spool.publish(ckpt(1, 6, &[9.0, 9.0, 9.0, 9.0, 9.0])).unwrap();

        assert_eq!(spool.members().unwrap(), vec![0, 1]);
        let c = spool.latest(0).unwrap().unwrap();
        assert_eq!(c.step, 5);
        assert_eq!(c.flat().view("params.a").unwrap(), &[1.0, 2.0]);

        // manifest exists, is atomic-format, and matches the scan
        let text = std::fs::read_to_string(dir.join(MANIFEST)).unwrap();
        assert!(text.starts_with(MANIFEST_HEADER));
        assert!(text.contains(&spool_file_name(1, 6)));

        // a fresh SpoolDir on the same dir (second process) sees the same
        let other = SpoolDir::open(&dir, 4).unwrap();
        let c2 = other.latest(0).unwrap().unwrap();
        assert_eq!(c2.flat().data(), c.flat().data());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_recovery_from_scan() {
        let dir = tdir("spooldir_recover");
        let spool = SpoolDir::open(&dir, 4).unwrap();
        spool.publish(ckpt(2, 10, &[1.0; 5])).unwrap();
        std::fs::remove_file(dir.join(MANIFEST)).unwrap();
        // reads fall back to the zero-padded directory scan
        assert_eq!(spool.latest(2).unwrap().unwrap().step, 10);
        assert_eq!(spool.members().unwrap(), vec![2]);
        // gc rebuilds the manifest
        spool.gc().unwrap();
        assert!(dir.join(MANIFEST).exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn history_bound_prunes_files() {
        let dir = tdir("spooldir_gc");
        let spool = SpoolDir::open(&dir, 2).unwrap();
        for s in 0..6u64 {
            spool.publish(ckpt(0, s, &[s as f32; 5])).unwrap();
        }
        let mut files: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".ckpt"))
            .collect();
        files.sort();
        assert_eq!(files, vec![spool_file_name(0, 4), spool_file_name(0, 5)]);
        assert!(spool.latest_at_most(0, 3).unwrap().is_none(), "pruned step readable");
        assert_eq!(spool.latest(0).unwrap().unwrap().step, 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn windowed_pread_matches_full_load() {
        let dir = tdir("spooldir_pread");
        let spool = SpoolDir::open(&dir, 4).unwrap();
        spool.publish(ckpt(0, 3, &[1.5, -2.5, 3.5, 4.5, 5.5])).unwrap();

        let fetch = spool
            .fetch_windows(0, u64::MAX, &["params.b".to_string(), "params.a".to_string()])
            .unwrap()
            .unwrap();
        assert_eq!(fetch.member, 0);
        assert_eq!(fetch.step, 3);
        assert_eq!(fetch.windows[0].name, "params.b");
        assert_eq!(fetch.windows[0].to_f32().unwrap(), vec![3.5, 4.5, 5.5]);
        assert_eq!(fetch.windows[1].to_f32().unwrap(), vec![1.5, -2.5]);
        assert_eq!(fetch.payload_bytes(), 5 * 4);
        // staleness bound applies to windowed fetches too
        assert!(spool.fetch_windows(0, 2, &[]).unwrap().is_none());
        // unknown window rejected
        assert!(spool
            .fetch_windows(0, u64::MAX, &["params.zzz".to_string()])
            .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn delta_pread_moves_only_changed_windows() {
        use crate::codistill::transport::Basis;
        let dir = tdir("spooldir_delta");
        let spool = SpoolDir::open(&dir, 4).unwrap();
        spool.publish(ckpt(0, 1, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();
        let v1 = spool.latest(0).unwrap().unwrap();
        let basis = Basis {
            step: 1,
            digests: v1.window_digests().as_ref().clone(),
        };
        // params.a changes, params.b does not
        spool.publish(ckpt(0, 2, &[9.0, 9.0, 3.0, 4.0, 5.0])).unwrap();
        // fresh handle: no read cache — the delta must come off the file
        let reader = SpoolDir::open(&dir, 4).unwrap();
        let res = reader
            .fetch(&FetchSpec::full(0, u64::MAX).with_basis(basis))
            .unwrap()
            .unwrap();
        assert_eq!(res.step, 2);
        assert!(res.full.is_none());
        assert_eq!(res.unchanged, vec!["params.b".to_string()]);
        assert_eq!(res.windows.len(), 1);
        assert_eq!(res.windows[0].name, "params.a");
        assert_eq!(res.windows[0].to_f32().unwrap(), vec![9.0, 9.0]);
        assert_eq!(res.payload_bytes(), 2 * 4);
        assert_eq!(res.digests.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn codec_spool_preads_encoded_windows() {
        use crate::codistill::transport::{Basis, DeltaCache};
        let dir = tdir("spooldir_codec");
        let spool = SpoolDir::open(&dir, 4).unwrap().with_codec(Codec::Shuffle);
        // constant-valued windows: the shuffle+RLE codec pays off
        spool.publish(ckpt(0, 1, &[1.0, 1.0, 2.0, 2.0, 2.0])).unwrap();
        // the file on disk is CKPT0004
        let raw = std::fs::read(dir.join(spool_file_name(0, 1))).unwrap();
        assert_eq!(&raw[..8], MAGIC_V4);

        // full load (fresh handle) round-trips through the v4 reader
        let reader = SpoolDir::open(&dir, 4).unwrap();
        let v1 = reader.latest(0).unwrap().unwrap();
        assert_eq!(v1.flat().view("params.a").unwrap(), &[1.0, 1.0]);

        // delta pread returns STILL-ENCODED windows that move fewer
        // bytes; DeltaCache decodes + verifies + installs byte-identical
        let basis = Basis {
            step: 1,
            digests: v1.window_digests().as_ref().clone(),
        };
        spool.publish(ckpt(0, 2, &[3.0, 3.0, 2.0, 2.0, 2.0])).unwrap();
        let fresh = SpoolDir::open(&dir, 4).unwrap();
        let res = fresh
            .fetch(&FetchSpec::full(0, u64::MAX).with_basis(basis))
            .unwrap()
            .unwrap();
        assert_eq!(res.unchanged, vec!["params.b".to_string()]);
        assert_eq!(res.windows.len(), 1);
        assert_eq!(res.windows[0].codec(), Codec::Shuffle);
        assert!(res.payload_bytes() < 2 * 4, "{}", res.payload_bytes());
        assert_eq!(res.windows[0].to_f32().unwrap(), vec![3.0, 3.0]);

        let mut cache = DeltaCache::new();
        let reader2 = SpoolDir::open(&dir, 4).unwrap();
        let got = cache.latest(&reader2, 0).unwrap().unwrap();
        let direct = reader2.latest(0).unwrap().unwrap();
        assert_eq!(got.flat().data(), direct.flat().data());

        // a corrupt encoded payload fails the install, never poisons.
        // Install the step-2 basis FIRST, then publish a step 3 where
        // both windows change and flip a byte in its encoded payload: the
        // delta pread must move the corrupted bytes and the install-side
        // decode + digest verify must reject them.
        let mut cache = DeltaCache::new();
        cache.latest(&reader2, 0).unwrap().unwrap(); // installs step 2
        spool.publish(ckpt(0, 3, &[4.0, 4.0, 5.0, 5.0, 5.0])).unwrap();
        let path = dir.join(spool_file_name(0, 3));
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 8 - 1] ^= 0x20; // last payload byte, before the residual count
        std::fs::write(&path, &bytes).unwrap();
        let basis2 = Basis {
            step: 2,
            digests: direct.window_digests().as_ref().clone(),
        };
        let res = SpoolDir::open(&dir, 4)
            .unwrap()
            .fetch(&FetchSpec::full(0, u64::MAX).with_basis(basis2))
            .unwrap()
            .unwrap();
        assert_eq!(res.windows.len(), 2, "corruption fixture drifted");
        assert!(
            cache.latest(&SpoolDir::open(&dir, 4).unwrap(), 0).is_err(),
            "corrupt encoded payload installed silently"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lossy_spool_writes_v5_and_preads_int8_windows() {
        use crate::codistill::transport::{Basis, DeltaCache};
        let dir = tdir("spooldir_lossy");
        let spool = SpoolDir::open(&dir, 4).unwrap().with_codec(Codec::Int8);
        // values exactly on the int8 power-of-two grid, as a prepared
        // (already-dequantized) plane from ErrorFeedback::prepare is
        spool.publish(ckpt(0, 1, &[0.5, 0.5, 1.0, 1.0, 1.0])).unwrap();
        let raw = std::fs::read(dir.join(spool_file_name(0, 1))).unwrap();
        assert_eq!(&raw[..8], MAGIC_V5);

        // full load (fresh handle) round-trips through the v5 reader
        let reader = SpoolDir::open(&dir, 4).unwrap();
        let v1 = reader.latest(0).unwrap().unwrap();
        assert_eq!(v1.flat().view("params.a").unwrap(), &[0.5, 0.5]);

        // delta pread ships the still-encoded int8 window (4-byte scale
        // header + one code byte per elem); install decodes + verifies
        let basis = Basis {
            step: 1,
            digests: v1.window_digests().as_ref().clone(),
        };
        spool.publish(ckpt(0, 2, &[0.75, 0.75, 1.0, 1.0, 1.0])).unwrap();
        let fresh = SpoolDir::open(&dir, 4).unwrap();
        let res = fresh
            .fetch(&FetchSpec::full(0, u64::MAX).with_basis(basis))
            .unwrap()
            .unwrap();
        assert_eq!(res.unchanged, vec!["params.b".to_string()]);
        assert_eq!(res.windows.len(), 1);
        assert_eq!(res.windows[0].codec(), Codec::Int8);
        assert_eq!(res.payload_bytes(), 4 + 2, "int8 wire layout drifted");
        assert_eq!(res.windows[0].to_f32().unwrap(), vec![0.75, 0.75]);

        let mut cache = DeltaCache::new();
        let reader2 = SpoolDir::open(&dir, 4).unwrap();
        let got = cache.latest(&reader2, 0).unwrap().unwrap();
        let direct = reader2.latest(0).unwrap().unwrap();
        assert_eq!(got.flat().data(), direct.flat().data());

        // a flipped int8 code still decodes, but to the wrong values:
        // the install-side digest verify must reject it loudly
        let mut cache = DeltaCache::new();
        cache.latest(&reader2, 0).unwrap().unwrap(); // installs step 2
        spool.publish(ckpt(0, 3, &[0.25, 0.25, 2.0, 2.0, 2.0])).unwrap();
        let path = dir.join(spool_file_name(0, 3));
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 8 - 1] ^= 0x20; // last payload byte, before the residual count
        std::fs::write(&path, &bytes).unwrap();
        assert!(
            cache.latest(&SpoolDir::open(&dir, 4).unwrap(), 0).is_err(),
            "corrupt int8 payload installed silently"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_drops_rows_for_vanished_files_from_manifest() {
        let dir = tdir("spooldir_stale_manifest");
        let spool = SpoolDir::open(&dir, 8).unwrap();
        spool.publish(ckpt(0, 1, &[1.0; 5])).unwrap();
        spool.publish(ckpt(0, 2, &[2.0; 5])).unwrap();
        // Simulate a concurrent pruner whose manifest rewrite lost the
        // race: the file vanishes while the manifest still lists it.
        std::fs::remove_file(dir.join(spool_file_name(0, 1))).unwrap();
        let text = std::fs::read_to_string(dir.join(MANIFEST)).unwrap();
        assert!(text.contains(&spool_file_name(0, 1)), "fixture broken");

        // A manifest-preferring reader resolves the gone file and must
        // recover through the scan fallback — documented behavior.
        let reader = SpoolDir::open(&dir, 8).unwrap();
        assert!(reader.latest_at_most(0, 1).unwrap().is_none());
        assert_eq!(reader.latest(0).unwrap().unwrap().step, 2);

        // gc (nothing left to prune) must still drop the stale row so
        // later readers stop tripping over it.
        spool.gc().unwrap();
        let text = std::fs::read_to_string(dir.join(MANIFEST)).unwrap();
        assert!(
            !text.contains(&spool_file_name(0, 1)),
            "gc kept a manifest row for a pruned file"
        );
        assert!(text.contains(&spool_file_name(0, 2)));
        // and the fetch path is clean again on a fresh reader
        let fresh = SpoolDir::open(&dir, 8).unwrap();
        assert_eq!(fresh.latest(0).unwrap().unwrap().step, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_persists_digests_and_v2_files_still_delta() {
        use crate::codistill::transport::Basis;
        let dir = tdir("spooldir_mdigest");
        let spool = SpoolDir::open(&dir, 4).unwrap();
        spool.publish(ckpt(1, 3, &[1.0; 5])).unwrap();
        let c3 = spool.latest(1).unwrap().unwrap();
        // the manifest's digest column equals the checkpoint's table
        let m = read_manifest_digests(&dir).unwrap();
        assert_eq!(m.get(&(1, 3)).unwrap(), c3.window_digests().as_ref());

        // a digest-free CKPT0002 file from an older writer: no column,
        // and a delta fetch over it falls back to a whole-file read
        let c9 = ckpt(1, 9, &[2.0; 5]);
        c9.save_v2(&dir.join(spool_file_name(1, 9))).unwrap();
        write_manifest(&dir, None).unwrap();
        assert!(read_manifest_digests(&dir).unwrap().get(&(1, 9)).is_none());
        let reader = SpoolDir::open(&dir, 4).unwrap();
        let basis = Basis {
            step: 3,
            digests: c3.window_digests().as_ref().clone(),
        };
        let res = reader
            .fetch(&FetchSpec::full(1, u64::MAX).with_basis(basis))
            .unwrap()
            .unwrap();
        assert_eq!(res.step, 9);
        assert_eq!(res.windows.len(), 2, "both windows changed 1.0 -> 2.0");

        // a hand-added manifest digest column over the v2 file serves the
        // pread delta path: identical content => zero windows moved
        let line = format!("1 9 {}", spool_file_name(1, 9));
        let col: String = c9
            .window_digests()
            .iter()
            .map(|d| format!(" {d:016x}"))
            .collect();
        let text = std::fs::read_to_string(dir.join(MANIFEST)).unwrap();
        std::fs::write(dir.join(MANIFEST), text.replace(&line, &format!("{line}{col}")))
            .unwrap();
        let basis9 = Basis {
            step: 9,
            digests: c9.window_digests().as_ref().clone(),
        };
        let res = SpoolDir::open(&dir, 4)
            .unwrap()
            .fetch(&FetchSpec::full(1, u64::MAX).with_basis(basis9))
            .unwrap()
            .unwrap();
        assert_eq!(res.windows.len(), 0);
        assert_eq!(res.unchanged.len(), 2);
        assert_eq!(res.payload_bytes(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn last_steps_is_metadata_only() {
        let dir = tdir("spooldir_heartbeat");
        let spool = SpoolDir::open(&dir, 4).unwrap();
        spool.publish(ckpt(1, 5, &[0.0; 5])).unwrap();
        spool.publish(ckpt(1, 9, &[0.0; 5])).unwrap();
        spool.publish(ckpt(3, 2, &[0.0; 5])).unwrap();
        // corrupt every checkpoint file: the heartbeat probe must not
        // open payloads, so it still answers from the manifest
        for e in std::fs::read_dir(&dir).unwrap().filter_map(|e| e.ok()) {
            if e.file_name().to_string_lossy().ends_with(".ckpt") {
                std::fs::write(e.path(), b"garbage").unwrap();
            }
        }
        let reader = SpoolDir::open(&dir, 4).unwrap();
        assert_eq!(reader.last_steps().unwrap(), vec![(1, 9), (3, 2)]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_step_regression_like_inproc() {
        let dir = tdir("spooldir_regress");
        let spool = SpoolDir::open(&dir, 4).unwrap();
        spool.publish(ckpt(0, 10, &[0.0; 5])).unwrap();
        assert!(spool.publish(ckpt(0, 5, &[0.0; 5])).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Window codecs for the checkpoint exchange.
//!
//! The paper's systems budget (§2.1) is exchange bandwidth: PR 4's delta
//! fetch cut *which* windows move (digest-matched windows are skipped);
//! this layer cuts *how many bytes* each moved window costs. The codecs
//! in this file are **lossless on the f32 bit patterns** — the decoded
//! window is byte-identical to the publisher's plane; the [`lossy`]
//! submodule adds quantizing codecs ([`Codec::Fp16`], [`Codec::Int8`])
//! whose precision loss is applied ONCE, publisher-side, by
//! `transport::feedback::ErrorFeedback` — by the time a plane reaches
//! any transport it is already dequantized, its digests are digests of
//! the dequantized values, and every wire/file hop is exact (enforced
//! by [`Codec::encode`]'s exact-or-raw rule below). Digest verification
//! and the transport-equivalence matrix therefore hold for every codec
//! id.
//!
//! Two lossless codecs ship behind the [`WindowCodec`] trait:
//!
//! * [`RawCodec`] (wire id 0) — passthrough: the window's f32s as LE
//!   bytes, exactly what moved before this layer existed. Also the
//!   per-window fallback whenever an encoding fails to shrink a window.
//! * [`ShuffleRleCodec`] (wire id 1) — byteshuffle + RLE with varint run
//!   lengths, tuned for f32 parameter planes: the four bytes of each f32
//!   are transposed into four contiguous byte planes (all byte-0s, then
//!   all byte-1s, ...), so the highly repetitive sign/exponent bytes of
//!   same-magnitude parameters line up into long runs that RLE collapses.
//!   A delta window's bytes are near-identical in structure to its basis
//!   (training nudges mantissas, rarely exponents), which is exactly the
//!   shape this transform exploits.
//!
//! [`Codec`] is the wire-facing registry: a `Copy` tag that travels in
//! `CKPT0004`/`CKPT0005` window tables, socket capability bytes, and
//! `FetchedWindow` payloads, dispatching to the trait impls. Encoding
//! through [`Codec::encode`] applies the **never-larger rule**: if the
//! preferred codec does not shrink a window, the window ships raw (tagged
//! [`Codec::Raw`]), so an encoded payload is never bigger than the
//! passthrough and decoders size-check against that bound
//! ([`Codec::wire_len_ok`]). Lossy tags additionally apply the
//! **exact-or-raw rule**: [`Codec::encode`] round-trips the encoding and
//! ships raw unless the decode is bit-identical to the input — transports
//! re-encoding an already-dequantized plane stay lossless in effect,
//! while a plane that was never quantized is never silently degraded by
//! a transport hop.
//!
//! Decode failures (truncated stream, bad varint, length mismatch) are
//! hard errors; the install side additionally digest-verifies every
//! decoded window (`transport::decode_and_verify`), so a corrupt encoded
//! payload fails exactly as loudly as a corrupt raw one.

use anyhow::{bail, Context, Result};

pub mod lossy;

use lossy::{Fp16Codec, Int8Codec};

/// One lossless window encoding: f32 slice in, bytes out, and back.
/// Implementations must be pure functions of the bits — a publisher and
/// any reader (another process, behind a socket, reading a spool file)
/// must produce identical bytes for identical input.
pub trait WindowCodec {
    /// Wire id recorded in `CKPT0004` tables and socket frames.
    fn id(&self) -> u8;

    /// Human name (CLI parsing, bench labels).
    fn name(&self) -> &'static str;

    /// Encode one window's elements.
    fn encode(&self, data: &[f32]) -> Vec<u8>;

    /// Decode one window of exactly `elems` f32s; any mismatch between
    /// `bytes` and `elems` is an error, never a short or padded window.
    fn decode(&self, bytes: &[u8], elems: usize) -> Result<Vec<f32>>;
}

/// Wire-facing codec tag: the registry of known [`WindowCodec`]s.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Codec {
    /// Passthrough LE f32 bytes (wire id 0).
    #[default]
    Raw,
    /// Byteshuffle + RLE/varint (wire id 1).
    Shuffle,
    /// Lossy binary16 quantization (wire id 2, [`lossy::Fp16Codec`]).
    Fp16,
    /// Lossy per-window symmetric i8 quantization (wire id 3,
    /// [`lossy::Int8Codec`]; the 4-byte scale header travels inside the
    /// encoded payload).
    Int8,
}

static RAW_CODEC: RawCodec = RawCodec;
static SHUFFLE_CODEC: ShuffleRleCodec = ShuffleRleCodec;
static FP16_CODEC: Fp16Codec = Fp16Codec;
static INT8_CODEC: Int8Codec = Int8Codec;

impl Codec {
    /// The codec implementation behind this tag.
    pub fn imp(self) -> &'static dyn WindowCodec {
        match self {
            Codec::Raw => &RAW_CODEC,
            Codec::Shuffle => &SHUFFLE_CODEC,
            Codec::Fp16 => &FP16_CODEC,
            Codec::Int8 => &INT8_CODEC,
        }
    }

    /// Whether this tag quantizes (drops precision) on encode. Lossy
    /// tags route publishes through `save_v5`/`CKPT0005` on the spool
    /// and are only safe to apply publisher-side (see
    /// `transport::feedback`).
    pub fn is_lossy(self) -> bool {
        matches!(self, Codec::Fp16 | Codec::Int8)
    }

    /// Size sanity for a wire/file-claimed encoded length: each codec
    /// has a known (or bounded) encoded size for `elems` elements, so a
    /// hostile length claim becomes an error before it becomes an
    /// allocation or a misdecode.
    pub fn wire_len_ok(self, enc_len: u64, elems: usize) -> bool {
        let raw = elems as u64 * 4;
        match self {
            Codec::Raw => enc_len == raw,
            Codec::Shuffle => enc_len <= raw,
            Codec::Fp16 => enc_len == elems as u64 * 2,
            Codec::Int8 => enc_len == 4 + elems as u64,
        }
    }

    /// Wire id (`CKPT0004` window tables, socket capability bytes).
    pub fn id(self) -> u8 {
        self.imp().id()
    }

    /// Inverse of [`Codec::id`]; unknown ids are an error (a frame from a
    /// newer build — fail loudly rather than misdecode).
    pub fn from_id(id: u8) -> Result<Self> {
        match id {
            0 => Ok(Codec::Raw),
            1 => Ok(Codec::Shuffle),
            2 => Ok(Codec::Fp16),
            3 => Ok(Codec::Int8),
            other => bail!("unknown window codec id {other}"),
        }
    }

    /// Parse a CLI/codec setting value.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "raw" | "none" => Ok(Codec::Raw),
            "shuffle" | "byteshuffle" | "shuffle-rle" => Ok(Codec::Shuffle),
            "fp16" | "f16" | "half" => Ok(Codec::Fp16),
            "int8" | "i8" => Ok(Codec::Int8),
            other => bail!("unknown codec {other:?} (want raw|shuffle|fp16|int8)"),
        }
    }

    pub fn name(self) -> &'static str {
        self.imp().name()
    }

    /// Encode one window under the never-larger rule: try this codec,
    /// fall back to [`Codec::Raw`] when the encoding does not shrink the
    /// window. Lossy tags additionally fall back unless the round trip
    /// is bit-exact (the exact-or-raw rule: transports re-encode already
    /// -dequantized planes losslessly, and never quantize a plane the
    /// publisher didn't). Returns the tag actually used alongside the
    /// bytes — the per-window codec tag every transport carries.
    pub fn encode(self, data: &[f32]) -> (Codec, Vec<u8>) {
        match self {
            Codec::Raw => (Codec::Raw, RAW_CODEC.encode(data)),
            other => {
                let enc = other.imp().encode(data);
                let fits = enc.len() < data.len() * 4;
                let exact = !other.is_lossy()
                    || matches!(other.imp().decode(&enc, data.len()), Ok(back)
                        if back.iter().zip(data).all(|(a, b)| a.to_bits() == b.to_bits()));
                if fits && exact {
                    (other, enc)
                } else {
                    (Codec::Raw, RAW_CODEC.encode(data))
                }
            }
        }
    }

    /// Decode one window of `elems` f32s encoded under this tag.
    pub fn decode(self, bytes: &[u8], elems: usize) -> Result<Vec<f32>> {
        self.imp().decode(bytes, elems)
    }
}

// ------------------------------------------------------------------ raw

/// Passthrough: the window's f32s as little-endian bytes.
pub struct RawCodec;

impl WindowCodec for RawCodec {
    fn id(&self) -> u8 {
        0
    }

    fn name(&self) -> &'static str {
        "raw"
    }

    fn encode(&self, data: &[f32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(data.len() * 4);
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    fn decode(&self, bytes: &[u8], elems: usize) -> Result<Vec<f32>> {
        if bytes.len() != elems * 4 {
            bail!(
                "raw window payload has {} bytes, {elems} elems need {}",
                bytes.len(),
                elems * 4
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

// ------------------------------------------------- byteshuffle + RLE

/// Byteshuffle + run-length encoding with varint lengths (module docs).
///
/// Token stream after the shuffle: each token is a LEB128 varint `v`;
/// `v & 1 == 1` means a run of `v >> 1` copies of the single byte that
/// follows, `v & 1 == 0` a literal stretch of `v >> 1` bytes that follow.
/// Runs shorter than [`MIN_RUN`] stay literal (a run token would not pay
/// for itself), so worst-case expansion is one varint per maximal literal
/// stretch — and [`Codec::encode`]'s never-larger rule ships such windows
/// raw anyway.
pub struct ShuffleRleCodec;

/// Shortest byte run worth a run token (varint + byte ≤ 3 bytes < 4).
const MIN_RUN: usize = 4;

/// Largest window a decode will materialize (1 GiB — the socket frame
/// cap; real plane windows are megabytes). Decodes run on untrusted
/// input where a few bytes can *claim* terabytes (an absurd shape in a
/// reply table, a huge RLE run token), so the claim must become an
/// error before it becomes an allocation.
const MAX_DECODED_BYTES: usize = 1 << 30;

impl WindowCodec for ShuffleRleCodec {
    fn id(&self) -> u8 {
        1
    }

    fn name(&self) -> &'static str {
        "shuffle"
    }

    fn encode(&self, data: &[f32]) -> Vec<u8> {
        rle_encode(&shuffle(data))
    }

    fn decode(&self, bytes: &[u8], elems: usize) -> Result<Vec<f32>> {
        if elems.saturating_mul(4) > MAX_DECODED_BYTES {
            bail!("window claims {elems} elems — over the {MAX_DECODED_BYTES}-byte decode cap");
        }
        let planes = rle_decode(bytes, elems * 4)?;
        Ok(unshuffle(&planes, elems))
    }
}

/// Transpose f32s into four contiguous byte planes: byte 0 of every
/// element, then byte 1, etc. (LE, so plane 3 holds sign + high exponent
/// bits — the most repetitive plane on a trained parameter window).
fn shuffle(data: &[f32]) -> Vec<u8> {
    let n = data.len();
    let mut out = vec![0u8; n * 4];
    for (i, v) in data.iter().enumerate() {
        let b = v.to_le_bytes();
        out[i] = b[0];
        out[n + i] = b[1];
        out[2 * n + i] = b[2];
        out[3 * n + i] = b[3];
    }
    out
}

fn unshuffle(bytes: &[u8], n: usize) -> Vec<f32> {
    debug_assert_eq!(bytes.len(), n * 4);
    (0..n)
        .map(|i| f32::from_le_bytes([bytes[i], bytes[n + i], bytes[2 * n + i], bytes[3 * n + i]]))
        .collect()
}

/// LEB128 unsigned varint.
fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn read_varint(bytes: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *bytes.get(*pos).context("varint truncated")?;
        *pos += 1;
        if shift >= 64 || (shift == 63 && b > 1) {
            bail!("varint overflows u64");
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

fn flush_literals(out: &mut Vec<u8>, lits: &[u8]) {
    if !lits.is_empty() {
        write_varint(out, (lits.len() as u64) << 1);
        out.extend_from_slice(lits);
    }
}

fn rle_encode(input: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(input.len() / 4 + 16);
    let mut i = 0usize;
    let mut lit_start = 0usize;
    while i < input.len() {
        let b = input[i];
        let mut j = i + 1;
        while j < input.len() && input[j] == b {
            j += 1;
        }
        if j - i >= MIN_RUN {
            flush_literals(&mut out, &input[lit_start..i]);
            write_varint(&mut out, (((j - i) as u64) << 1) | 1);
            out.push(b);
            lit_start = j;
        }
        i = j;
    }
    flush_literals(&mut out, &input[lit_start..]);
    out
}

fn rle_decode(input: &[u8], expect: usize) -> Result<Vec<u8>> {
    // Capacity hint only (capped): `expect` is wire-derived, and the
    // output-exceeds check below bounds real growth to it.
    let mut out = Vec::with_capacity(expect.min(1 << 20));
    let mut pos = 0usize;
    while pos < input.len() {
        let tok = read_varint(input, &mut pos)?;
        let n = (tok >> 1) as usize;
        if n == 0 {
            bail!("rle token with zero length");
        }
        if out.len() + n > expect {
            bail!("rle output exceeds the window's {expect} bytes");
        }
        if tok & 1 == 1 {
            let b = *input.get(pos).context("rle run byte truncated")?;
            pos += 1;
            out.resize(out.len() + n, b);
        } else {
            let lits = input
                .get(pos..pos + n)
                .context("rle literal stretch truncated")?;
            pos += n;
            out.extend_from_slice(lits);
        }
    }
    if out.len() != expect {
        bail!("rle decoded {} bytes, window wants {expect}", out.len());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(codec: Codec, data: &[f32]) {
        let (tag, bytes) = codec.encode(data);
        let back = tag.decode(&bytes, data.len()).unwrap();
        // bit-exact, not just value-equal (−0.0, NaN payloads)
        let a: Vec<u32> = data.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = back.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "codec {} not lossless", codec.name());
    }

    #[test]
    fn ids_and_parse_roundtrip() {
        for c in [Codec::Raw, Codec::Shuffle, Codec::Fp16, Codec::Int8] {
            assert_eq!(Codec::from_id(c.id()).unwrap(), c);
            assert_eq!(Codec::parse(c.name()).unwrap(), c);
        }
        assert!(Codec::from_id(99).is_err());
        assert!(Codec::parse("gzip").is_err());
        assert_eq!(Codec::parse("byteshuffle").unwrap(), Codec::Shuffle);
        assert_eq!(Codec::parse("half").unwrap(), Codec::Fp16);
        assert_eq!(Codec::parse("i8").unwrap(), Codec::Int8);
        assert!(Codec::Fp16.is_lossy() && Codec::Int8.is_lossy());
        assert!(!Codec::Raw.is_lossy() && !Codec::Shuffle.is_lossy());
    }

    #[test]
    fn wire_len_bounds_per_codec() {
        assert!(Codec::Raw.wire_len_ok(40, 10));
        assert!(!Codec::Raw.wire_len_ok(39, 10));
        assert!(Codec::Shuffle.wire_len_ok(3, 10));
        assert!(!Codec::Shuffle.wire_len_ok(41, 10));
        assert!(Codec::Fp16.wire_len_ok(20, 10));
        assert!(!Codec::Fp16.wire_len_ok(40, 10));
        assert!(Codec::Int8.wire_len_ok(14, 10));
        assert!(!Codec::Int8.wire_len_ok(10, 10));
    }

    #[test]
    fn lossy_tags_ship_raw_unless_exact() {
        // a plane that is NOT on the quantization grid: exact-or-raw
        // falls back so no transport hop ever degrades it
        let unquantized = vec![0.1f32, 0.2, 0.3, 0.4, 1.0 / 3.0];
        for c in [Codec::Fp16, Codec::Int8] {
            let (tag, bytes) = c.encode(&unquantized);
            assert_eq!(tag, Codec::Raw, "{} quantized an unprepared plane", c.name());
            assert_eq!(bytes.len(), unquantized.len() * 4);
        }
        // the same plane after one publisher-side round trip re-ships
        // under the lossy tag (value idempotence)
        for c in [Codec::Fp16, Codec::Int8] {
            let enc = c.imp().encode(&unquantized);
            let prepared = c.imp().decode(&enc, unquantized.len()).unwrap();
            let (tag, bytes) = c.encode(&prepared);
            assert_eq!(tag, c);
            assert!(bytes.len() < prepared.len() * 4);
            roundtrip(c, &prepared); // and that wire hop is bit-exact
        }
        // single-element int8 windows never fit (5 > 4 bytes): raw
        let one = Int8Codec.decode(&Int8Codec.encode(&[0.5f32]), 1).unwrap();
        let (tag, _) = Codec::Int8.encode(&one);
        assert_eq!(tag, Codec::Raw);
    }

    #[test]
    fn both_codecs_are_lossless_on_awkward_bits() {
        let data = vec![
            0.0,
            -0.0,
            1.0,
            -1.5,
            f32::MIN_POSITIVE,
            f32::MAX,
            f32::from_bits(0x7fc0_1234), // NaN with payload
            3.25,
            3.25,
            3.25,
            3.25,
            3.25,
        ];
        roundtrip(Codec::Raw, &data);
        roundtrip(Codec::Shuffle, &data);
        roundtrip(Codec::Shuffle, &[]);
        roundtrip(Codec::Raw, &[]);
    }

    #[test]
    fn constant_windows_compress_hard() {
        let data = vec![0.125f32; 4096];
        let (tag, bytes) = Codec::Shuffle.encode(&data);
        assert_eq!(tag, Codec::Shuffle);
        assert!(
            bytes.len() < data.len(), // well under 1 byte per element
            "constant window encoded to {} bytes",
            bytes.len()
        );
        roundtrip(Codec::Shuffle, &data);
        // same-magnitude parameters share exponent bytes: still shrinks
        let ramp: Vec<f32> = (0..1024).map(|i| 1.0 + i as f32 * 1e-6).collect();
        let (tag, bytes) = Codec::Shuffle.encode(&ramp);
        assert_eq!(tag, Codec::Shuffle);
        assert!(bytes.len() < ramp.len() * 4);
        roundtrip(Codec::Shuffle, &ramp);
    }

    #[test]
    fn incompressible_windows_fall_back_to_raw() {
        // pseudo-random bits: byteshuffle finds no runs, so the
        // never-larger rule ships the window raw
        let noise: Vec<f32> = (0..256u32)
            .map(|i| f32::from_bits(i.wrapping_mul(2_654_435_769) | 1))
            .map(|v| if v.is_nan() { 1.0 } else { v })
            .collect();
        let (tag, bytes) = Codec::Shuffle.encode(&noise);
        assert_eq!(tag, Codec::Raw, "noise should fall back to raw");
        assert_eq!(bytes.len(), noise.len() * 4);
        roundtrip(Codec::Shuffle, &noise);
    }

    #[test]
    fn corrupt_streams_fail_loudly() {
        let data = vec![2.5f32; 64];
        let (tag, bytes) = Codec::Shuffle.encode(&data);
        assert_eq!(tag, Codec::Shuffle);
        // truncated
        assert!(tag.decode(&bytes[..bytes.len() - 1], 64).is_err());
        // wrong element count
        assert!(tag.decode(&bytes, 63).is_err());
        assert!(tag.decode(&bytes, 65).is_err());
        // raw length mismatch
        assert!(Codec::Raw.decode(&[0u8; 7], 2).is_err());
        // zero-length token is malformed, not an infinite loop
        assert!(Codec::Shuffle.decode(&[0u8], 1).is_err());
        // truncated varint
        assert!(Codec::Shuffle.decode(&[0x80], 1).is_err());
        // an absurd claimed element count is an error before it is an
        // allocation (hostile reply tables claim, decoders refuse)
        assert!(Codec::Shuffle.decode(&[0u8], usize::MAX / 2).is_err());
    }

    #[test]
    fn rle_respects_min_run_and_literals() {
        // runs below MIN_RUN stay literal; above, they tokenize
        let short = [1u8, 1, 1, 2, 3];
        let enc = rle_encode(&short);
        assert_eq!(rle_decode(&enc, short.len()).unwrap(), short);
        let long = [7u8; 100];
        let enc = rle_encode(&long);
        assert!(enc.len() <= 3, "run of 100 should be one token: {enc:?}");
        assert_eq!(rle_decode(&enc, 100).unwrap(), long.to_vec());
        // mixed
        let mut mixed = vec![9u8; 10];
        mixed.extend_from_slice(&[1, 2, 3, 4, 5]);
        mixed.extend_from_slice(&[0u8; 8]);
        let enc = rle_encode(&mixed);
        assert_eq!(rle_decode(&enc, mixed.len()).unwrap(), mixed);
    }

    #[test]
    fn varint_roundtrip() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            write_varint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(read_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }
}

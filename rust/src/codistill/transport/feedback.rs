//! Publisher-side quantization with error feedback.
//!
//! The lossy codecs (`codec::lossy`) drop precision; where that loss
//! happens matters. If every transport hop re-quantized independently,
//! digests could not verify payloads and readers behind different media
//! would install different planes. [`ErrorFeedback::prepare`] therefore
//! applies the loss exactly ONCE, before `ExchangeTransport::publish`:
//! it quantizes each window through the configured lossy codec and
//! replaces the plane with the **dequantized** values. From then on the
//! published checkpoint is an ordinary exact plane — its digest table
//! *is* the round-trip digest table, delta detection compares
//! dequantized bases on both sides, and every backend re-encodes it
//! losslessly (the codecs are value-idempotent and `Codec::encode`
//! enforces exact-or-raw), so installs stay byte-identical across
//! inproc/spool/socket/relay and corruption still fails loudly.
//!
//! **Error feedback** (the `feedback` flag) keeps a per-window residual
//! `r = intended − published` in f64 and adds it into the next publish
//! before quantizing. The per-publish error then telescopes: after `T`
//! publishes the *accumulated* error of the published sequence is just
//! the current residual (bounded by half a quantization step), instead
//! of growing like `T ×` the per-publish rounding bias. The
//! quality-gate tests pin exactly this: with feedback ON the
//! accumulated per-window bias stays under one step; OFF, a window
//! whose value the grid cannot represent drifts by a fixed bias every
//! publish. This is the standard error-feedback/EF-SGD construction
//! from the gradient-compression literature applied to the paper's
//! checkpoint exchange.
//!
//! One [`ErrorFeedback`] instance belongs to one publishing member —
//! residuals are keyed by window name and reset whenever a window's
//! shape changes (or its residual turns non-finite). [`FeedbackStats`]
//! aggregates into `RunLog`/`CoordinatorLog`.

use crate::codistill::obs::{Event, Recorder};
use crate::codistill::store::Checkpoint;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

use super::codec::Codec;

/// Accounting for quantized publishes, merged into the run logs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FeedbackStats {
    /// Publishes that went through [`ErrorFeedback::prepare`].
    pub publishes: u64,
    /// Windows quantized (per publish per window).
    pub windows_quantized: u64,
    /// Windows left exact because quantization would not shrink them.
    pub windows_raw: u64,
    /// Encoded bytes of every quantized window (what the wire moves in
    /// the steady state).
    pub bytes_quantized: u64,
    /// Raw bytes the same windows would have cost (4 × elems).
    pub bytes_raw_equiv: u64,
    /// L2 norm of the residual carried after the most recent publish.
    pub last_residual_l2: f64,
    /// Largest accumulated per-window mean signed error vs the
    /// publisher's true plane, over all windows and publishes so far —
    /// the bias the quality gate pins (feedback keeps it under one
    /// quantization step; without feedback it grows with every
    /// publish).
    pub max_abs_bias: f64,
}

impl FeedbackStats {
    pub fn merge(&mut self, other: &FeedbackStats) {
        self.publishes += other.publishes;
        self.windows_quantized += other.windows_quantized;
        self.windows_raw += other.windows_raw;
        self.bytes_quantized += other.bytes_quantized;
        self.bytes_raw_equiv += other.bytes_raw_equiv;
        self.last_residual_l2 = self.last_residual_l2.max(other.last_residual_l2);
        self.max_abs_bias = self.max_abs_bias.max(other.max_abs_bias);
    }

    /// Encoded bytes / raw bytes over the quantized windows (1.0 when
    /// nothing quantized).
    pub fn compression_ratio(&self) -> f64 {
        if self.bytes_raw_equiv == 0 {
            return 1.0;
        }
        self.bytes_quantized as f64 / self.bytes_raw_equiv as f64
    }
}

/// Per-member publisher-side quantizer (module docs). `prepare` a
/// checkpoint right before handing it to `ExchangeTransport::publish`.
pub struct ErrorFeedback {
    codec: Codec,
    feedback: bool,
    /// Per-window carried residual (intended − published), f64 so tiny
    /// errors survive accumulation across many publishes.
    residuals: HashMap<String, Vec<f64>>,
    /// Per-window accumulated mean signed error vs the true plane.
    bias: HashMap<String, f64>,
    stats: FeedbackStats,
    /// When present, every lossy `prepare` emits an `Event::Quantize`
    /// with that publish's deltas into the journal.
    recorder: Option<Recorder>,
}

impl ErrorFeedback {
    /// A quantizer for `codec` (a no-op for lossless tags) with the
    /// residual carry on or off.
    pub fn new(codec: Codec, feedback: bool) -> Self {
        ErrorFeedback {
            codec,
            feedback,
            residuals: HashMap::new(),
            bias: HashMap::new(),
            stats: FeedbackStats::default(),
            recorder: None,
        }
    }

    /// Emit quantize events into `recorder` in addition to the local
    /// accounting.
    pub fn with_recorder(mut self, recorder: Recorder) -> Self {
        self.recorder = Some(recorder);
        self
    }

    /// Quantize `ckpt`'s plane through the codec (round trip:
    /// quantize → dequantize) and return the checkpoint that should
    /// actually be published. Lossless codecs pass through untouched.
    /// The returned checkpoint's digests are computed fresh over the
    /// dequantized values.
    pub fn prepare(&mut self, ckpt: Checkpoint) -> Result<Checkpoint> {
        if !self.codec.is_lossy() {
            return Ok(ckpt);
        }
        let before = self.stats.clone();
        self.stats.publishes += 1;
        let imp = self.codec.imp();
        let mut buf = (**ckpt.flat()).clone();
        let layout = buf.layout().clone();
        let mut residual_sq = 0f64;
        for e in layout.entries() {
            let window = &mut buf.data_mut()[e.range()];
            let r = self.residuals.entry(e.name.clone()).or_default();
            if r.len() != window.len() || r.iter().any(|v| !v.is_finite()) {
                // fresh window, reshaped window, or a poisoned carry
                // (non-finite values in the plane): restart the carry
                r.clear();
                r.resize(window.len(), 0.0);
            }
            // quantize the carry-adjusted window; publish the decode
            let adjusted: Vec<f32> = if self.feedback {
                window.iter().zip(r.iter()).map(|(x, c)| (*x as f64 + c) as f32).collect()
            } else {
                window.to_vec()
            };
            let enc = imp.encode(&adjusted);
            if enc.len() >= adjusted.len() * 4 {
                // never-larger: this window ships exact, no error to carry
                self.stats.windows_raw += 1;
                for c in r.iter_mut() {
                    *c = 0.0;
                }
                continue;
            }
            let published = imp.decode(&enc, adjusted.len())?;
            self.stats.windows_quantized += 1;
            self.stats.bytes_quantized += enc.len() as u64;
            self.stats.bytes_raw_equiv += adjusted.len() as u64 * 4;
            let mut err_sum = 0f64;
            for k in 0..window.len() {
                let intended = window[k] as f64 + if self.feedback { r[k] } else { 0.0 };
                let out = published[k] as f64;
                let carry = intended - out;
                // a non-finite input (or a clamped ±inf) has no
                // meaningful residual to carry or bias to account
                let carry = if carry.is_finite() { carry } else { 0.0 };
                r[k] = carry;
                residual_sq += carry * carry;
                if (out - window[k] as f64).is_finite() {
                    err_sum += out - window[k] as f64;
                }
                window[k] = published[k];
            }
            if !window.is_empty() {
                let b = self.bias.entry(e.name.clone()).or_insert(0.0);
                *b += err_sum / window.len() as f64;
                let mag = b.abs();
                if mag > self.stats.max_abs_bias {
                    self.stats.max_abs_bias = mag;
                }
            }
        }
        self.stats.last_residual_l2 = residual_sq.sqrt();
        if let Some(rec) = &self.recorder {
            // Per-publish deltas of the authoritative local stats, plus
            // the accumulator state after this publish.
            rec.record(Event::Quantize {
                member: ckpt.member,
                step: ckpt.step,
                windows_quantized: self.stats.windows_quantized - before.windows_quantized,
                windows_raw: self.stats.windows_raw - before.windows_raw,
                bytes_quantized: self.stats.bytes_quantized - before.bytes_quantized,
                bytes_raw_equiv: self.stats.bytes_raw_equiv - before.bytes_raw_equiv,
                residual_l2: self.stats.last_residual_l2,
                max_abs_bias: self.stats.max_abs_bias,
            });
        }
        Ok(Checkpoint::from_flat(
            ckpt.member,
            ckpt.step,
            Arc::new(buf),
            ckpt.residual().clone(),
        ))
    }

    /// Accounting so far (cloned; merging into run logs).
    pub fn stats(&self) -> FeedbackStats {
        self.stats.clone()
    }

    /// Whether `prepare` actually rewrites planes.
    pub fn active(&self) -> bool {
        self.codec.is_lossy()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::flat::{FlatBuffer, FlatLayout};
    use crate::runtime::TensorMap;

    fn ckpt_with(values: &[(&str, Vec<f32>)], step: u64) -> Checkpoint {
        let layout = Arc::new(FlatLayout::from_named_shapes(
            values
                .iter()
                .map(|(n, v)| (n.to_string(), vec![v.len()]))
                .collect::<Vec<_>>(),
        ));
        let mut buf = FlatBuffer::zeros(layout);
        for (n, v) in values {
            let r = buf.layout().window_range(n).unwrap();
            buf.data_mut()[r].copy_from_slice(v);
        }
        Checkpoint::from_flat(0, step, Arc::new(buf), TensorMap::new())
    }

    /// 0.1 is not on int8's power-of-two grid (scale 2^-10, code 102
    /// dequantizes to 0.099609375): the canonical biased window.
    const OFF_GRID: f32 = 0.1;

    #[test]
    fn lossless_codecs_pass_through_untouched() {
        for codec in [Codec::Raw, Codec::Shuffle] {
            let mut fb = ErrorFeedback::new(codec, true);
            let ck = ckpt_with(&[("w", vec![OFF_GRID; 8])], 1);
            let before: Vec<u32> = ck.flat().data().iter().map(|v| v.to_bits()).collect();
            let out = fb.prepare(ck).unwrap();
            let after: Vec<u32> = out.flat().data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(before, after);
            assert_eq!(fb.stats(), FeedbackStats::default());
            assert!(!fb.active());
        }
    }

    #[test]
    fn published_plane_is_the_dequantized_roundtrip() {
        let mut fb = ErrorFeedback::new(Codec::Int8, false);
        let ck = ckpt_with(&[("w", vec![OFF_GRID; 16])], 1);
        let out = fb.prepare(ck).unwrap();
        for v in out.flat().data() {
            assert_eq!(*v, 0.099_609_375, "int8 code 102 × 2^-10");
        }
        // re-encoding the published plane under the lossy tag is exact:
        // any transport hop after prepare is lossless in effect
        let (tag, bytes) = Codec::Int8.encode(out.flat().data());
        assert_eq!(tag, Codec::Int8);
        let back = Codec::Int8.decode(&bytes, out.flat().data().len()).unwrap();
        assert_eq!(back, out.flat().data());
        let s = fb.stats();
        assert_eq!(s.publishes, 1);
        assert_eq!(s.windows_quantized, 1);
        assert_eq!(s.bytes_quantized, 4 + 16);
        assert_eq!(s.bytes_raw_equiv, 64);
        assert!(s.compression_ratio() < 0.5);
    }

    #[test]
    fn feedback_telescopes_the_accumulated_bias() {
        // A constant off-grid window published T times. Without
        // feedback every publish lands the same rounding bias
        // (~3.9e-4); with feedback the carried residual alternates the
        // rounding so the accumulated bias stays under one step.
        let publishes = 8;
        let run = |feedback: bool| {
            let mut fb = ErrorFeedback::new(Codec::Int8, feedback);
            let mut sum = vec![0f64; 16];
            for t in 0..publishes {
                let out = fb.prepare(ckpt_with(&[("w", vec![OFF_GRID; 16])], t)).unwrap();
                for (a, v) in sum.iter_mut().zip(out.flat().data()) {
                    *a += *v as f64 - OFF_GRID as f64;
                }
            }
            (fb.stats().max_abs_bias, sum[0] / publishes as f64)
        };
        let (bias_on, mean_err_on) = run(true);
        let (bias_off, mean_err_off) = run(false);
        let step = (2f64).powi(-10); // int8 scale for amax 0.1
        assert!(
            bias_on <= step,
            "feedback-ON accumulated bias {bias_on} exceeds one step {step}"
        );
        assert!(
            bias_off > 3.0 * bias_on.max(1e-12),
            "feedback-OFF bias {bias_off} not measurably worse than ON {bias_on}"
        );
        // the mean published value itself tells the same story
        assert!(mean_err_on.abs() < mean_err_off.abs());
        assert!(mean_err_off.abs() > 3e-4, "0.1 should bias by ~3.9e-4/publish");
    }

    #[test]
    fn residuals_reset_on_reshape_and_nonfinite_planes() {
        let mut fb = ErrorFeedback::new(Codec::Int8, true);
        fb.prepare(ckpt_with(&[("w", vec![OFF_GRID; 8])], 1)).unwrap();
        assert!(fb.residuals["w"].iter().any(|r| *r != 0.0));
        // reshape: the carry restarts instead of misaligning
        fb.prepare(ckpt_with(&[("w", vec![OFF_GRID; 4])], 2)).unwrap();
        assert_eq!(fb.residuals["w"].len(), 4);
        // a non-finite plane value cannot poison the carry
        let out = fb
            .prepare(ckpt_with(&[("w", vec![f32::NAN, 0.5, -0.5, 0.25])], 3))
            .unwrap();
        assert_eq!(out.flat().data()[0], 0.0, "NaN quantizes to 0");
        assert!(fb.residuals["w"].iter().all(|r| r.is_finite()));
        let out = fb.prepare(ckpt_with(&[("w", vec![OFF_GRID; 4])], 4)).unwrap();
        assert!(out.flat().data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn stats_merge_accumulates() {
        let mut a = FeedbackStats {
            publishes: 1,
            windows_quantized: 2,
            windows_raw: 1,
            bytes_quantized: 10,
            bytes_raw_equiv: 40,
            last_residual_l2: 0.5,
            max_abs_bias: 1e-4,
        };
        let b = FeedbackStats {
            publishes: 2,
            windows_quantized: 1,
            windows_raw: 0,
            bytes_quantized: 5,
            bytes_raw_equiv: 20,
            last_residual_l2: 0.25,
            max_abs_bias: 2e-4,
        };
        a.merge(&b);
        assert_eq!(a.publishes, 3);
        assert_eq!(a.windows_quantized, 3);
        assert_eq!(a.bytes_quantized, 15);
        assert_eq!(a.bytes_raw_equiv, 60);
        assert_eq!(a.last_residual_l2, 0.5);
        assert_eq!(a.max_abs_bias, 2e-4);
    }
}

//! Background checkpoint subscription over any [`ExchangeTransport`].
//!
//! [`Subscription::spawn`] starts a thread that polls the exchange's
//! metadata-only [`last_steps`](ExchangeTransport::last_steps)
//! heartbeat and, whenever the watched member has published a fresher
//! step than the last install, pulls the checkpoint and hands it to the
//! caller's `on_install` callback — the feed behind the serving tier's
//! hot swap (`codistill::serve`).
//!
//! Two properties the serving path depends on:
//!
//! * **Delta-aware**: with `delta` on, fetches go through a private
//!   [`DeltaCache`], so steady-state updates move only the windows
//!   whose content digests changed — digest-verified installs, byte-
//!   identical to a full fetch (`stats().delta` carries the traffic
//!   accounting). `codec` rides along exactly as it does for training
//!   readers.
//! * **Error-tolerant**: a failed poll, fetch, or `on_install` is
//!   counted (`tolerated_errors`) and retried on the next tick; the
//!   loop never dies. Wrap the transport in
//!   [`Retry`](crate::codistill::Retry) *underneath* the subscription
//!   for per-operation backoff on lossy media — the loop itself only
//!   provides the outer poll cadence.
//!
//! Drop (or [`Subscription::stop`]) signals the thread and joins it.

use super::{Codec, DeltaCache, DeltaStats, ExchangeTransport};
use crate::codistill::obs::{keys, Recorder};
use crate::codistill::Checkpoint;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Subscription knobs.
#[derive(Debug, Clone, Copy)]
pub struct SubscribeConfig {
    /// Member whose publications to follow.
    pub member: usize,
    /// Heartbeat poll cadence.
    pub poll_interval: Duration,
    /// Fetch through a [`DeltaCache`] (changed windows only) instead of
    /// whole-plane reads.
    pub delta: bool,
    /// Window codec advertised on delta fetches ([`Codec::Raw`] = none).
    pub codec: Codec,
}

impl Default for SubscribeConfig {
    fn default() -> Self {
        SubscribeConfig {
            member: 0,
            poll_interval: Duration::from_millis(5),
            delta: true,
            codec: Codec::Raw,
        }
    }
}

/// Counters the loop maintains (snapshot via [`Subscription::stats`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct SubscribeStats {
    /// Heartbeat polls issued.
    pub polls: u64,
    /// Checkpoint fetches attempted (a poll that saw a fresher step).
    pub fetches: u64,
    /// Successful installs handed to `on_install`.
    pub installs: u64,
    /// Errors absorbed (poll, fetch, or callback); the loop continued.
    pub tolerated_errors: u64,
    /// Delta traffic accounting (zeroed when `delta` is off).
    pub delta: DeltaStats,
}

/// Handle to the background subscription thread.
pub struct Subscription {
    stop: Arc<AtomicBool>,
    stats: Arc<Mutex<SubscribeStats>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Subscription {
    /// Spawn the loop. `on_install` receives each freshly fetched
    /// checkpoint exactly once, in step order; if it errors, the step
    /// is not marked installed and is retried on the next poll.
    pub fn spawn<F>(
        transport: Arc<dyn ExchangeTransport>,
        cfg: SubscribeConfig,
        on_install: F,
    ) -> Self
    where
        F: FnMut(Arc<Checkpoint>) -> Result<()> + Send + 'static,
    {
        Self::spawn_recorded(transport, cfg, None, on_install)
    }

    /// [`Subscription::spawn`] with an optional `codistill::obs`
    /// recorder: the private delta cache emits fetch/install journal
    /// events and the loop mirrors its counters into the `sub.*`
    /// registry keys. Per-poll counters are intentionally *not* journal
    /// events — poll counts are timing-dependent and would break trace
    /// byte-identity.
    pub fn spawn_recorded<F>(
        transport: Arc<dyn ExchangeTransport>,
        cfg: SubscribeConfig,
        recorder: Option<Recorder>,
        mut on_install: F,
    ) -> Self
    where
        F: FnMut(Arc<Checkpoint>) -> Result<()> + Send + 'static,
    {
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(Mutex::new(SubscribeStats::default()));
        let (t_stop, t_stats) = (stop.clone(), stats.clone());
        let handle = std::thread::Builder::new()
            .name(format!("ckpt-subscribe-m{}", cfg.member))
            .spawn(move || {
                let mut cache = cfg.delta.then(|| {
                    let mut c = DeltaCache::new().with_codec(cfg.codec);
                    if let Some(rec) = &recorder {
                        c = c.with_recorder(rec.clone());
                    }
                    c
                });
                let mut installed: Option<u64> = None;
                while !t_stop.load(Ordering::SeqCst) {
                    let outcome = poll_once(
                        transport.as_ref(),
                        cfg.member,
                        &mut cache,
                        &mut installed,
                        &mut on_install,
                    );
                    {
                        let mut s = t_stats.lock().unwrap();
                        s.polls += 1;
                        let mut fetched_now = 0u64;
                        let mut installed_now = 0u64;
                        let mut tolerated_now = 0u64;
                        match outcome {
                            Ok(PollOutcome::Installed) => {
                                fetched_now = 1;
                                installed_now = 1;
                            }
                            Ok(PollOutcome::Fresh) => {}
                            Err(fetched) => {
                                if fetched {
                                    fetched_now = 1;
                                }
                                tolerated_now = 1;
                            }
                        }
                        s.fetches += fetched_now;
                        s.installs += installed_now;
                        s.tolerated_errors += tolerated_now;
                        if let Some(c) = &cache {
                            s.delta = c.stats();
                        }
                        if let Some(rec) = &recorder {
                            rec.incr(keys::SUB_POLLS, 1);
                            rec.incr(keys::SUB_FETCHES, fetched_now);
                            rec.incr(keys::SUB_INSTALLS, installed_now);
                            rec.incr(keys::SUB_TOLERATED, tolerated_now);
                        }
                    }
                    std::thread::sleep(cfg.poll_interval);
                }
            })
            .expect("spawning subscription thread");
        Subscription {
            stop,
            stats,
            handle: Some(handle),
        }
    }

    /// Snapshot the loop's counters.
    pub fn stats(&self) -> SubscribeStats {
        *self.stats.lock().unwrap()
    }

    /// Signal the loop and join it. Idempotent; also runs on drop.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

impl Drop for Subscription {
    fn drop(&mut self) {
        self.stop();
    }
}

enum PollOutcome {
    /// Nothing fresher than the installed step.
    Fresh,
    /// A fresher checkpoint was fetched and handed to `on_install`.
    Installed,
}

/// One poll tick. `Err(fetched)` reports whether the failure happened
/// at/after the fetch (for the `fetches` counter).
fn poll_once(
    transport: &dyn ExchangeTransport,
    member: usize,
    cache: &mut Option<DeltaCache>,
    installed: &mut Option<u64>,
    on_install: &mut impl FnMut(Arc<Checkpoint>) -> Result<()>,
) -> std::result::Result<PollOutcome, bool> {
    let steps = transport.last_steps().map_err(|_| false)?;
    let fresh = steps.iter().find(|&&(m, _)| m == member).map(|&(_, s)| s);
    let Some(step) = fresh else {
        return Ok(PollOutcome::Fresh); // member has never published
    };
    if installed.is_some_and(|i| step <= i) {
        return Ok(PollOutcome::Fresh);
    }
    let ck = match cache {
        Some(c) => c.latest(transport, member).map_err(|_| true)?,
        None => transport.latest(member).map_err(|_| true)?,
    };
    let Some(ck) = ck else {
        // heartbeat raced a gc; try again next tick
        return Ok(PollOutcome::Fresh);
    };
    let got = ck.step;
    on_install(ck).map_err(|_| true)?;
    *installed = Some(got);
    Ok(PollOutcome::Installed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codistill::transport::InProcess;
    use crate::codistill::Member;
    use crate::testkit::DriftMember;
    use std::sync::mpsc;

    fn publish(t: &dyn ExchangeTransport, m: &mut DriftMember, steps: u64) {
        for _ in 0..steps {
            m.train_step(0.0, 0.1).unwrap();
        }
        t.publish(m.snapshot().unwrap()).unwrap();
    }

    fn wait_for<const N: usize>(rx: &mpsc::Receiver<u64>) -> [u64; N] {
        let mut out = [0u64; N];
        for slot in &mut out {
            *slot = rx
                .recv_timeout(Duration::from_secs(10))
                .expect("install did not arrive");
        }
        out
    }

    #[test]
    fn installs_each_fresh_step_in_order() {
        let t: Arc<dyn ExchangeTransport> = Arc::new(InProcess::new(4));
        let mut m = DriftMember::new(0);
        publish(t.as_ref(), &mut m, 2);

        let (tx, rx) = mpsc::channel();
        let mut sub = Subscription::spawn(
            t.clone(),
            SubscribeConfig {
                poll_interval: Duration::from_millis(1),
                ..SubscribeConfig::default()
            },
            move |ck| {
                tx.send(ck.step).unwrap();
                Ok(())
            },
        );
        let [first] = wait_for::<1>(&rx);
        assert_eq!(first, 2);
        // gate each publish on the previous install so no step coalesces
        publish(t.as_ref(), &mut m, 3);
        let [a] = wait_for::<1>(&rx);
        assert_eq!(a, 5);
        publish(t.as_ref(), &mut m, 3);
        let [b] = wait_for::<1>(&rx);
        assert_eq!(b, 8);

        sub.stop();
        let stats = sub.stats();
        assert!(stats.installs >= 2);
        assert!(stats.polls >= stats.installs);
        assert_eq!(stats.tolerated_errors, 0);
        // delta accounting rode along (first fetch counts as full)
        assert!(stats.delta.full_fetches >= 1);
    }

    #[test]
    fn callback_errors_are_tolerated_and_retried() {
        let t: Arc<dyn ExchangeTransport> = Arc::new(InProcess::new(4));
        let mut m = DriftMember::new(0);
        publish(t.as_ref(), &mut m, 1);

        let (tx, rx) = mpsc::channel();
        let mut failed_once = false;
        let mut sub = Subscription::spawn(
            t.clone(),
            SubscribeConfig {
                poll_interval: Duration::from_millis(1),
                delta: false,
                ..SubscribeConfig::default()
            },
            move |ck| {
                if !failed_once {
                    failed_once = true;
                    anyhow::bail!("transient install failure");
                }
                tx.send(ck.step).unwrap();
                Ok(())
            },
        );
        // the step still arrives (second attempt), exactly once
        let [step] = wait_for::<1>(&rx);
        assert_eq!(step, 1);
        sub.stop();
        let stats = sub.stats();
        assert!(stats.tolerated_errors >= 1);
        assert_eq!(stats.installs, 1);
        assert_eq!(stats.delta.full_fetches, 0, "delta off ⇒ no cache accounting");
    }

    #[test]
    fn never_published_member_is_quietly_fresh() {
        let t: Arc<dyn ExchangeTransport> = Arc::new(InProcess::new(4));
        let (tx, rx) = mpsc::channel::<u64>();
        let mut sub = Subscription::spawn(
            t,
            SubscribeConfig {
                member: 9,
                poll_interval: Duration::from_millis(1),
                ..SubscribeConfig::default()
            },
            move |ck| {
                tx.send(ck.step).unwrap();
                Ok(())
            },
        );
        std::thread::sleep(Duration::from_millis(30));
        sub.stop();
        assert!(rx.try_recv().is_err());
        let stats = sub.stats();
        assert!(stats.polls > 0);
        assert_eq!(stats.installs, 0);
        assert_eq!(stats.tolerated_errors, 0);
    }
}

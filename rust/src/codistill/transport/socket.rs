//! The socket backend: checkpoint exchange over a length-prefixed
//! request/response protocol (TCP or Unix domain sockets).
//!
//! A [`SocketServer`] answers requests from any number of
//! [`SocketTransport`] clients — the server process is the paper's
//! "parameter checkpoint service", clients are coordinator processes
//! hosting members. By default the server owns an [`InProcess`] store;
//! [`SocketServer::bind_tcp_over`] / [`SocketServer::bind_unix_over`]
//! instead serve any [`ExchangeTransport`] backend — a `SpoolDir` turns
//! the server into a spool gateway whose `DELTA` replies stream encoded
//! window ranges straight from their `pread`s, and
//! [`Relay`](crate::codistill::transport::Relay) serves its mirrored
//! planes through one to form checkpoint fan-out trees.
//!
//! ## Wire format
//!
//! Every message is one frame: `u32 LE payload length` + payload. A
//! request payload is `opcode u8` + body; a response payload is
//! `status u8` (0 = ok, 1 = not found, 2 = error + utf8 message) + body.
//! Integers are LE; names/shapes/tensors reuse the `CKPT0002` encodings
//! from `codistill::store`, and a full checkpoint travels as the exact
//! bytes [`Checkpoint::write_to`] produces.
//!
//! | op | request body | ok-response body |
//! |----|--------------|------------------|
//! | 1 `PUBLISH`  | checkpoint stream | — |
//! | 2 `LATEST`   | member u64, max_step u64 | checkpoint stream |
//! | 3 `FETCH`    | member u64, max_step u64, n u32 (bit 31 = capability), names, [codec u8] | member, step, windows (raw frames, or tagged frames under capability) |
//! | 4 `DESCRIBE` | member u64, max_step u64 | member, step, window table, residual tensors |
//! | 5 `MEMBERS`  | — | n u64, member u64s |
//! | 6 `GC`       | — | — |
//! | 7 `STEPS`    | — | n u64, (member u64, step u64) pairs |
//! | 8 `DELTA`    | member u64, max_step u64, flags u8 (bit 0 = basis, bit 1 = capability) [step u64, n u64, digests u64s], sel u8 [n u32, names], [codec u8] | member, step, window+digest table (n u64; name, shape, digest u64), changed windows (n u32; raw or tagged frames), unchanged names (n u32; names), residual tensors (n u64; frames) |
//!
//! A raw window frame is `name, shape, elems u64, f32 data`; a tagged
//! frame (capability negotiated) is `name, shape, codec u8, len u64,
//! encoded bytes` — see `transport::codec`.
//!
//! `STEPS` is the liveness heartbeat: the freshest published step per
//! member with no checkpoint payload attached, so a coordinator can poll
//! it on every reload without moving planes.
//!
//! `DELTA` is the one read the client's [`ExchangeTransport::fetch`]
//! speaks: the request carries an optional delta basis (flags bit 0 ⇒
//! installed step + per-window digest vector) and a window selection
//! (`sel u8` = 0 ⇒ whole plane, 1 ⇒ named windows), and the response
//! returns only the windows whose content digest differs from the basis,
//! plus the full window+digest table and the names skipped as unchanged —
//! the server-side twin of `transport::fetch_from_checkpoint`. `LATEST` /
//! `FETCH` / `DESCRIBE` remain for older readers and for the windowed
//! reassembly mode below.
//!
//! ## Codec capability (compressed window payloads)
//!
//! A client built [`SocketTransport::with_codec`] asks for encoded window
//! frames by setting a **capability bit** on the request — bit 1 of the
//! `DELTA` flags byte, bit 31 of the `FETCH` name count — and appending
//! one codec-id byte after the request body. Interop is deliberately
//! asymmetric-safe in both directions: an old client never sets the bit
//! and keeps receiving raw frames byte-identical to before; an old server
//! rejects the unknown bit with a clean `STATUS_ERR` ("bad basis flag" /
//! the `checked_count` guard on the absurd name count), and a
//! capability-aware server that predates a codec id (the lossy `fp16` /
//! `int8` tags postdate `shuffle`) rejects it with "unknown window codec
//! id" — either way the new client detects, remembers, and transparently
//! retries raw. Replies to a
//! capability request frame every changed window as `codec u8, len u64,
//! bytes` with a **per-window tag**: windows the codec cannot shrink ride
//! raw-tagged, and the client hands encoded payloads to the install side
//! (`DeltaCache` / `into_checkpoint`), which decodes and digest-verifies
//! before any byte lands.
//!
//! ## The readiness loop (server concurrency)
//!
//! The server is one event-driven thread (`ckpt-exchange-loop`): the
//! listener and every registered connection are nonblocking, a `poll(2)`
//! readiness wait picks the sockets with work each tick, and each
//! connection advances a small state machine:
//!
//! ```text
//!            bytes readable                frame complete
//!   [READ] ───────────────▶ inbox buffer ────────────────▶ [DISPATCH]
//!     ▲                     (partial frames wait here)          │
//!     │                                                         ▼
//!     │   outbox drained            WouldBlock             response as
//!     └──────────────── [WRITE] ◀──────────────▶ POLLOUT   byte segments
//!                        vectored writes        (parked)
//! ```
//!
//! * **READ** — available bytes append to the connection's `inbox`; a
//!   complete `u32 LE length + payload` frame is split off and
//!   dispatched. Partial frames simply wait for the next readiness
//!   event, so a slow *writer* costs a buffer, not a thread.
//! * **DISPATCH** — `PUBLISH`/`LATEST`/`FETCH`/`DESCRIBE`/`DELTA`/
//!   `STEPS`/… run inline on the loop thread against the backend
//!   (window digest compares + memcpy at exchange cadence — cheap), and
//!   every failure becomes a `STATUS_ERR` reply isolated to that
//!   connection.
//! * **WRITE** — the response is a list of byte segments ([`Segments`])
//!   flushed with vectored writes; on `WouldBlock` the connection parks
//!   on `POLLOUT` with its segment cursor intact, so a slow *reader*
//!   costs a parked state machine while every other socket keeps being
//!   served. Large payloads (a full `LATEST` stream, encoded `DELTA`
//!   windows `pread` from a spool file) are **adopted** as their own
//!   segments instead of concatenated — the bytes the backend produced
//!   are the bytes handed to the kernel.
//!
//! Up to the connection cap ([`MAX_CONNECTIONS`] by default) register at
//! once; further accepts wait in the listen backlog until a slot frees.
//! Connections idle past [`READ_TIMEOUT`] are swept. Shutdown flips a
//! flag and wakes the poll with a loopback connect: the loop exits
//! promptly, dropping any pending connections mid-state.
//!
//! ## Sharded (windowed) fetch
//!
//! `FETCH` moves only the named windows of the publisher's plane. A
//! client built `with_windowed_fetch(batch)` reloads teachers without
//! ever pulling the whole plane in one response: `DESCRIBE` returns the
//! window table (names + shapes, no payload), then the client issues
//! `FETCH`es of `batch` windows at a time — **pinned to the described
//! step** so a concurrent publish can never produce a torn plane — and
//! reassembles the checkpoint locally. The reassembled bytes are
//! identical to the full-plane pull; only the fetch granularity changes.

use crate::codistill::store::{
    read_framed_tensor, read_name, read_shape, read_u32, read_u64, write_f32s, write_i32s,
    write_name, write_shape, Checkpoint,
};
use crate::codistill::transport::{
    fetch_from_checkpoint, windows_from_checkpoint, Basis, Codec, ExchangeTransport, FetchResult,
    FetchSpec, FetchedWindow, InProcess, TransportKind, WindowPayload, WindowSel, WindowedFetch,
};
use crate::runtime::flat::{FlatBuffer, FlatLayout};
use crate::runtime::{Tensor, TensorMap};
use anyhow::{bail, Context, Result};
use std::io::{IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const OP_PUBLISH: u8 = 1;
const OP_LATEST: u8 = 2;
const OP_FETCH: u8 = 3;
const OP_DESCRIBE: u8 = 4;
const OP_MEMBERS: u8 = 5;
const OP_GC: u8 = 6;
const OP_STEPS: u8 = 7;
const OP_DELTA: u8 = 8;

/// `DELTA` flags byte: bit 0 = a delta basis follows, bit 1 = a codec
/// capability byte follows the window selection (module docs). Old
/// servers reject any flags value above 1 with "bad basis flag".
const DELTA_FLAG_BASIS: u8 = 1;
const DELTA_FLAG_CODEC: u8 = 2;

/// `FETCH` capability bit on the u32 name count: a codec byte follows
/// the names. Old servers see an absurd count and reject it through
/// `checked_count` — a clean error the client falls back on.
const FETCH_CAP_BIT: u32 = 0x8000_0000;

/// Default bound on concurrently *registered* connections: accepts past
/// the cap wait in the listen backlog until a slot frees. A registered
/// connection is a parked state machine (a buffer + a pollfd), not a
/// thread, so the default is sized for O(1000)-reader fan-out rather
/// than a worker pool. Per-server override via
/// [`SocketServer::bind_tcp_with`] / [`SocketServer::bind_unix_with`]
/// (`socket_pool=N` from the CLI).
pub const MAX_CONNECTIONS: usize = 1024;

const STATUS_OK: u8 = 0;
const STATUS_NONE: u8 = 1;
const STATUS_ERR: u8 = 2;

/// Largest accepted frame (1 GiB): a cap on corrupt length prefixes, far
/// above any real checkpoint in this repo.
const MAX_FRAME: usize = 1 << 30;

/// Inactivity bound on both sides of the wire: the server's readiness
/// loop sweeps connections idle past this (a wedged client cannot hold
/// a registration slot forever), and a client read timeout turns a dead
/// server into an error instead of a hang.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Upper bound on one readiness wait: the loop re-checks the shutdown
/// flag at least this often even with no socket activity (the shutdown
/// wakeup usually makes it immediate).
const POLL_TICK: Duration = Duration::from_millis(50);

// ------------------------------------------------------------------- frames

fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    // Enforce the cap on the send side too: a u32 prefix cannot frame a
    // larger payload, and a silent truncation would desync the protocol.
    if payload.len() > MAX_FRAME {
        bail!(
            "frame of {} bytes exceeds the {MAX_FRAME}-byte cap (checkpoint too large for one frame)",
            payload.len()
        );
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame; `Ok(None)` on a clean EOF before any length byte.
fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    if let Err(e) = r.read_exact(&mut len) {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            return Ok(None);
        }
        return Err(e.into());
    }
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME {
        bail!("frame of {n} bytes exceeds the {MAX_FRAME}-byte cap");
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(Some(buf))
}

/// Guard a wire-supplied element count against the bytes actually left
/// in the frame (each element needs at least `min_bytes` of encoding): a
/// malformed count becomes a protocol error on this connection, never a
/// huge `Vec::with_capacity` that could panic the worker or abort the
/// process.
fn checked_count(n: usize, remaining: usize, min_bytes: usize, what: &str) -> Result<usize> {
    if n > remaining / min_bytes.max(1) {
        bail!("frame claims {n} {what} but only {remaining} bytes remain");
    }
    Ok(n)
}

/// Split one complete `u32 LE length + payload` frame off the front of
/// an accumulation buffer. `Ok(None)` when the buffer holds only a
/// partial frame; `Err` when the length prefix exceeds [`MAX_FRAME`]
/// (a protocol error that ends the connection).
fn take_frame(buf: &mut Vec<u8>) -> Result<Option<Vec<u8>>> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let n = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if n > MAX_FRAME {
        bail!("frame of {n} bytes exceeds the {MAX_FRAME}-byte cap");
    }
    if buf.len() < 4 + n {
        return Ok(None);
    }
    let rest = buf.split_off(4 + n);
    let mut frame = std::mem::replace(buf, rest);
    frame.drain(..4);
    Ok(Some(frame))
}

/// A response assembled as a list of byte segments for the readiness
/// loop's vectored writes. Small header fields append to the trailing
/// segment (`Write` impl); large payloads the backend already owns —
/// encoded window bytes `pread` from a spool file, codec output — are
/// **adopted** as their own segment ([`Segments::adopt`]), so they reach
/// the kernel without an intermediate concatenation copy.
pub(crate) struct Segments {
    parts: Vec<Vec<u8>>,
}

impl Segments {
    fn new() -> Self {
        Segments {
            parts: vec![Vec::new()],
        }
    }

    /// A one-byte status-only response.
    fn status(status: u8) -> Self {
        let mut s = Self::new();
        s.push(status);
        s
    }

    fn push(&mut self, b: u8) {
        self.parts.last_mut().unwrap().push(b);
    }

    fn extend_from_slice(&mut self, bytes: &[u8]) {
        self.parts.last_mut().unwrap().extend_from_slice(bytes);
    }

    /// Take ownership of a payload as its own wire segment (no copy); a
    /// fresh tail segment is opened so later appends land after it.
    fn adopt(&mut self, payload: Vec<u8>) {
        self.parts.push(payload);
        self.parts.push(Vec::new());
    }

    fn len(&self) -> usize {
        self.parts.iter().map(|p| p.len()).sum()
    }

    /// Flatten to one contiguous buffer (the blocking-write path and the
    /// tests; the readiness loop writes the segments directly).
    fn concat(self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len());
        for p in &self.parts {
            out.extend_from_slice(p);
        }
        out
    }
}

impl Write for Segments {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn write_framed_tensor(w: &mut impl Write, name: &str, t: &Tensor) -> Result<()> {
    write_name(w, name)?;
    write_shape(w, t.shape())?;
    match t {
        Tensor::F32 { data, .. } => {
            w.write_all(&[0u8])?;
            write_f32s(w, data)?;
        }
        Tensor::I32 { data, .. } => {
            w.write_all(&[1u8])?;
            write_i32s(w, data)?;
        }
    }
    Ok(())
}

/// Legacy window frame: `name, shape, elems u64, f32 data`. Windows that
/// arrive encoded are decoded first — a pre-capability reader never sees
/// codec bytes.
fn write_window_frame_raw(out: &mut Segments, w: &FetchedWindow) -> Result<()> {
    write_name(out, &w.name)?;
    write_shape(out, &w.shape)?;
    match &w.payload {
        WindowPayload::Raw(data) => {
            out.extend_from_slice(&(data.len() as u64).to_le_bytes());
            write_f32s(out, data)?;
        }
        WindowPayload::Encoded { .. } => {
            let data = w.to_f32()?;
            out.extend_from_slice(&(data.len() as u64).to_le_bytes());
            write_f32s(out, &data)?;
        }
    }
    Ok(())
}

/// Capability window frame: `name, shape, codec u8, len u64, bytes` —
/// the per-window tag records what the payload is actually encoded as.
/// Consumes the window so an encoded payload (`pread` bytes from a spool
/// backend, codec output) is adopted as a wire segment, not copied.
fn write_window_frame_tagged(out: &mut Segments, w: FetchedWindow) -> Result<()> {
    write_name(out, &w.name)?;
    write_shape(out, &w.shape)?;
    match w.payload {
        WindowPayload::Raw(data) => {
            out.push(Codec::Raw.id());
            out.extend_from_slice(&((data.len() * 4) as u64).to_le_bytes());
            write_f32s(out, &data)?;
        }
        WindowPayload::Encoded { codec, bytes } => {
            out.push(codec.id());
            out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
            out.adopt(bytes);
        }
    }
    Ok(())
}

/// Parse one capability window frame (the inverse of
/// [`write_window_frame_tagged`]); the payload stays encoded for the
/// install side to decode + digest-verify.
fn read_window_frame_tagged(r: &mut &[u8]) -> Result<FetchedWindow> {
    let name = read_name(r)?;
    let shape = read_shape(r)?;
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag)?;
    let codec = Codec::from_id(tag[0])?;
    let len = checked_count(read_u64(r)? as usize, r.len(), 1, "payload bytes")?;
    let mut bytes = vec![0u8; len];
    r.read_exact(&mut bytes)?;
    Ok(FetchedWindow::encoded(name, shape, codec, bytes))
}

/// Parse one legacy window frame.
fn read_window_frame_raw(r: &mut &[u8]) -> Result<FetchedWindow> {
    let name = read_name(r)?;
    let shape = read_shape(r)?;
    let elems = checked_count(read_u64(r)? as usize, r.len(), 4, "f32s")?;
    let mut data = vec![0f32; elems];
    crate::codistill::store::read_f32s(r, &mut data)?;
    Ok(FetchedWindow::raw(name, shape, data))
}

// ------------------------------------------------------------------- server

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn set_nonblocking(&self, v: bool) -> std::io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(v),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(v),
        }
    }

    fn accept(&self) -> std::io::Result<Conn> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Conn::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().map(|(s, _)| Conn::Unix(s)),
        }
    }

    #[cfg(unix)]
    fn raw_fd(&self) -> std::os::unix::io::RawFd {
        use std::os::unix::io::AsRawFd;
        match self {
            Listener::Tcp(l) => l.as_raw_fd(),
            Listener::Unix(l) => l.as_raw_fd(),
        }
    }
}

enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    fn set_nonblocking(&self, v: bool) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_nonblocking(v),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_nonblocking(v),
        }
    }

    #[cfg(unix)]
    fn raw_fd(&self) -> std::os::unix::io::RawFd {
        use std::os::unix::io::AsRawFd;
        match self {
            Conn::Tcp(s) => s.as_raw_fd(),
            Conn::Unix(s) => s.as_raw_fd(),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn write_vectored(&mut self, bufs: &[IoSlice<'_>]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write_vectored(bufs),
            #[cfg(unix)]
            Conn::Unix(s) => s.write_vectored(bufs),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

// --------------------------------------------------------------- readiness
//
// The readiness primitive behind the event loop. On unix it is a
// minimal binding to `poll(2)` — std already links libc, so the symbol
// resolves without adding a dependency; this is the crate's only
// `unsafe` block and it hands the kernel nothing but a stack slice of
// repr(C) pollfd structs. Elsewhere a short-sleep fallback reports
// every socket as possibly-ready: the sockets are nonblocking, so a
// not-actually-ready socket costs one `WouldBlock` per tick.

#[cfg(unix)]
mod readiness {
    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;

    /// `struct pollfd` from `poll.h`.
    #[repr(C)]
    pub struct PollFd {
        pub fd: std::os::unix::io::RawFd,
        pub events: i16,
        pub revents: i16,
    }

    extern "C" {
        fn poll(
            fds: *mut PollFd,
            nfds: std::os::raw::c_ulong,
            timeout: std::os::raw::c_int,
        ) -> std::os::raw::c_int;
    }

    /// Wait until any registered fd is ready, at most `timeout_ms`.
    /// Readiness (including errors/hangups) lands in each entry's
    /// `revents`; a timeout or `EINTR` leaves them all zero.
    pub fn wait(fds: &mut [PollFd], timeout_ms: i32) {
        if fds.is_empty() {
            std::thread::sleep(std::time::Duration::from_millis(timeout_ms.max(0) as u64));
            return;
        }
        // SAFETY: `fds` is a valid exclusively-borrowed slice of repr(C)
        // pollfd structs for the whole call; poll(2) only writes the
        // `revents` fields within it.
        unsafe {
            poll(fds.as_mut_ptr(), fds.len() as std::os::raw::c_ulong, timeout_ms);
        }
    }
}

/// One registered connection in the readiness loop: the nonblocking
/// socket plus its state-machine buffers (module docs — READ accumulates
/// into `inbox`, WRITE drains `outbox` with vectored writes).
struct Connection {
    conn: Conn,
    /// Received bytes not yet consumed; complete frames are split off
    /// the front, partial frames wait for more readable bytes.
    inbox: Vec<u8>,
    /// In-flight response, if any: no further request is dispatched on
    /// this connection until it drains (per-connection ordering — and
    /// natural backpressure for pipelined clients).
    outbox: Option<PendingWrite>,
    /// Last byte moved in either direction (idle sweep).
    last_activity: Instant,
}

impl Connection {
    fn new(conn: Conn) -> Self {
        Connection {
            conn,
            inbox: Vec::new(),
            outbox: None,
            last_activity: Instant::now(),
        }
    }
}

/// A partially written response frame: the length-prefix segment plus
/// the body segments, with a cursor (`seg`, `off`) marking how far the
/// kernel has taken it.
struct PendingWrite {
    segments: Vec<Vec<u8>>,
    seg: usize,
    off: usize,
}

impl PendingWrite {
    /// Frame a [`Segments`] response: the `u32 LE` length prefix becomes
    /// its own leading segment, the body segments follow untouched.
    fn frame(body: Segments) -> Result<Self> {
        let len = body.len();
        if len > MAX_FRAME {
            bail!("frame of {len} bytes exceeds the {MAX_FRAME}-byte cap (checkpoint too large for one frame)");
        }
        let mut segments = Vec::with_capacity(body.parts.len() + 1);
        segments.push((len as u32).to_le_bytes().to_vec());
        segments.extend(body.parts);
        Ok(PendingWrite {
            segments,
            seg: 0,
            off: 0,
        })
    }

    /// Advance the cursor past `n` written bytes.
    fn advance(&mut self, mut n: usize) {
        while n > 0 && self.seg < self.segments.len() {
            let left = self.segments[self.seg].len() - self.off;
            if n < left {
                self.off += n;
                return;
            }
            n -= left;
            self.seg += 1;
            self.off = 0;
        }
    }

    /// Bytes not yet taken by the kernel.
    fn remaining(&self) -> usize {
        self.segments[self.seg.min(self.segments.len())..]
            .iter()
            .map(|s| s.len())
            .sum::<usize>()
            - self.off
    }
}

/// Serves an [`ExchangeTransport`] backend over the wire protocol from
/// one event-driven readiness loop (see the module's readiness-loop
/// section). The default binds own an [`InProcess`] store; the `_over`
/// binds serve any backend — a spool gateway, a relay mirror. Dropping
/// the server shuts the loop down, closing every registered connection.
pub struct SocketServer {
    addr: String,
    /// `Some` for the default binds that own their store; `None` when
    /// bound over an external backend.
    store: Option<Arc<InProcess>>,
    shutdown: Arc<AtomicBool>,
    /// Connections currently registered in the loop (observability).
    active: Arc<AtomicUsize>,
    cap: usize,
    handle: Option<std::thread::JoinHandle<()>>,
    /// Unix-socket path to unlink on shutdown.
    unlink: Option<PathBuf>,
}

impl SocketServer {
    /// Bind a TCP endpoint (`"127.0.0.1:0"` picks a free port; the
    /// resolved address is [`SocketServer::addr`]) over a server-owned
    /// [`InProcess`] store, with the default [`MAX_CONNECTIONS`] cap.
    pub fn bind_tcp(addr: &str, history: usize) -> Result<Self> {
        Self::bind_tcp_with(addr, history, MAX_CONNECTIONS)
    }

    /// [`SocketServer::bind_tcp`] with an explicit bound on registered
    /// connections (clamped to at least 1).
    pub fn bind_tcp_with(addr: &str, history: usize, max_connections: usize) -> Result<Self> {
        let store = Arc::new(InProcess::new(history));
        let mut server = Self::bind_tcp_over(addr, store.clone(), max_connections)?;
        server.store = Some(store);
        Ok(server)
    }

    /// Bind a TCP endpoint serving an arbitrary backend: every wire
    /// request dispatches to `backend`'s trait ops. Serving a
    /// [`SpoolDir`](crate::codistill::transport::SpoolDir) makes the
    /// server a spool gateway (encoded `DELTA` windows stream straight
    /// from their `pread` ranges); serving a relay mirror makes it a
    /// fan-out node.
    pub fn bind_tcp_over(
        addr: &str,
        backend: Arc<dyn ExchangeTransport>,
        max_connections: usize,
    ) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding tcp {addr}"))?;
        let resolved = listener.local_addr()?.to_string();
        Self::spawn(Listener::Tcp(listener), resolved, backend, None, max_connections)
    }

    /// Bind a Unix-domain socket at `path` (any stale socket file is
    /// replaced) over a server-owned [`InProcess`] store, with the
    /// default [`MAX_CONNECTIONS`] cap.
    #[cfg(unix)]
    pub fn bind_unix(path: &Path, history: usize) -> Result<Self> {
        Self::bind_unix_with(path, history, MAX_CONNECTIONS)
    }

    /// [`SocketServer::bind_unix`] with an explicit bound on registered
    /// connections (clamped to at least 1).
    #[cfg(unix)]
    pub fn bind_unix_with(path: &Path, history: usize, max_connections: usize) -> Result<Self> {
        let store = Arc::new(InProcess::new(history));
        let mut server = Self::bind_unix_over(path, store.clone(), max_connections)?;
        server.store = Some(store);
        Ok(server)
    }

    /// [`SocketServer::bind_tcp_over`] on a Unix-domain socket.
    #[cfg(unix)]
    pub fn bind_unix_over(
        path: &Path,
        backend: Arc<dyn ExchangeTransport>,
        max_connections: usize,
    ) -> Result<Self> {
        std::fs::remove_file(path).ok();
        let listener = UnixListener::bind(path)
            .with_context(|| format!("binding unix socket {}", path.display()))?;
        Self::spawn(
            Listener::Unix(listener),
            path.display().to_string(),
            backend,
            Some(path.to_path_buf()),
            max_connections,
        )
    }

    fn spawn(
        listener: Listener,
        addr: String,
        backend: Arc<dyn ExchangeTransport>,
        unlink: Option<PathBuf>,
        max_connections: usize,
    ) -> Result<Self> {
        let cap = max_connections.max(1);
        let shutdown = Arc::new(AtomicBool::new(false));
        let active = Arc::new(AtomicUsize::new(0));
        let thread_shutdown = shutdown.clone();
        let thread_active = active.clone();
        let handle = std::thread::Builder::new()
            .name("ckpt-exchange-loop".into())
            .spawn(move || event_loop(listener, backend, thread_shutdown, thread_active, cap))?;
        Ok(SocketServer {
            addr,
            store: None,
            shutdown,
            active,
            cap,
            handle: Some(handle),
            unlink,
        })
    }

    /// The resolved endpoint: `host:port` for TCP, the path for Unix.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Connections currently registered in the readiness loop
    /// (observability for the concurrency tests; racy by nature).
    pub fn active_connections(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// This server's bound on concurrently registered connections.
    pub fn max_connections(&self) -> usize {
        self.cap
    }

    /// The store behind a default-bound endpoint (the server process's
    /// own members can exchange through it zero-copy while remote
    /// members use the wire). Panics for a server bound `_over` an
    /// external backend, which has no server-owned store.
    pub fn store(&self) -> &Arc<InProcess> {
        self.store
            .as_ref()
            .expect("server bound over an external backend has no local store")
    }

    /// Wake the readiness wait so it observes the shutdown flag
    /// immediately instead of at the next [`POLL_TICK`].
    fn wake_accept(&self) {
        match &self.unlink {
            #[cfg(unix)]
            Some(path) => {
                UnixStream::connect(path).ok();
            }
            #[cfg(not(unix))]
            Some(_) => {}
            None => {
                TcpStream::connect(&self.addr).ok();
            }
        }
    }
}

impl Drop for SocketServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.wake_accept();
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
        if let Some(p) = &self.unlink {
            std::fs::remove_file(p).ok();
        }
    }
}

/// The readiness loop: nonblocking accept + per-connection state
/// machines, one thread for the whole server (module docs). Exits when
/// the shutdown flag flips; every registered connection drops with it.
fn event_loop(
    listener: Listener,
    backend: Arc<dyn ExchangeTransport>,
    shutdown: Arc<AtomicBool>,
    active: Arc<AtomicUsize>,
    cap: usize,
) {
    let _ = listener.set_nonblocking(true);
    let mut conns: Vec<Connection> = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        let accept_open = conns.len() < cap;
        let ready = wait_for_readiness(&listener, &conns, accept_open);
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        // Drain the accept queue up to the cap. Past the cap the
        // listener is simply not polled, so pending connects wait in the
        // kernel backlog instead of being accepted-then-starved.
        if accept_open && ready.accept {
            while conns.len() < cap {
                match listener.accept() {
                    Ok(conn) => {
                        let _ = conn.set_nonblocking(true);
                        conns.push(Connection::new(conn));
                    }
                    // WouldBlock = queue drained; any other accept error
                    // (EMFILE, aborted handshake) is transient — retry
                    // next tick rather than spinning here.
                    Err(_) => break,
                }
            }
        }
        // Advance every connection the wait flagged (the non-unix
        // fallback flags all of them). `retain_mut` visits in order, so
        // the readiness flags line up with the connection indices.
        let now = Instant::now();
        let mut idx = 0;
        conns.retain_mut(|c| {
            let flagged = ready.conns.get(idx).copied().unwrap_or(true);
            idx += 1;
            let alive = !flagged || progress(c, backend.as_ref());
            alive && now.duration_since(c.last_activity) <= READ_TIMEOUT
        });
        active.store(conns.len(), Ordering::SeqCst);
    }
    active.store(0, Ordering::SeqCst);
}

/// Which sockets have work: the listener plus one flag per connection.
struct Ready {
    accept: bool,
    conns: Vec<bool>,
}

/// Readiness wait over the listener and every registered connection: a
/// connection with a pending response waits on writability, an idle one
/// on readability. Bounded by [`POLL_TICK`] so the shutdown flag is
/// re-checked even with no socket activity.
#[cfg(unix)]
fn wait_for_readiness(listener: &Listener, conns: &[Connection], accept_open: bool) -> Ready {
    use readiness::{PollFd, POLLIN, POLLOUT};
    let mut fds = Vec::with_capacity(conns.len() + 1);
    fds.push(PollFd {
        fd: listener.raw_fd(),
        // With the cap reached, events=0 still surfaces listener errors
        // but suppresses accept readiness.
        events: if accept_open { POLLIN } else { 0 },
        revents: 0,
    });
    for c in conns {
        fds.push(PollFd {
            fd: c.conn.raw_fd(),
            events: if c.outbox.is_some() { POLLOUT } else { POLLIN },
            revents: 0,
        });
    }
    readiness::wait(&mut fds, POLL_TICK.as_millis() as i32);
    Ready {
        accept: fds[0].revents != 0,
        conns: fds[1..].iter().map(|f| f.revents != 0).collect(),
    }
}

/// Non-unix fallback: a short sleep, then everything reported ready.
/// The sockets are nonblocking, so a not-actually-ready socket costs a
/// single `WouldBlock` per tick — correct, just not as idle-cheap.
#[cfg(not(unix))]
fn wait_for_readiness(_listener: &Listener, conns: &[Connection], _accept_open: bool) -> Ready {
    std::thread::sleep(Duration::from_millis(2));
    Ready {
        accept: true,
        conns: vec![true; conns.len()],
    }
}

/// Advance one connection's state machine as far as its socket allows:
/// drain the outbox, split complete request frames off the inbox,
/// dispatch, repeat. Returns `false` when the connection is finished
/// (EOF, error, torn or oversized frame) and should be dropped — errors
/// are isolated here; they end this connection and nothing else.
fn progress(c: &mut Connection, backend: &dyn ExchangeTransport) -> bool {
    let mut scratch = [0u8; 64 * 1024];
    loop {
        // WRITE: an in-flight response drains before anything else —
        // no new request is dispatched past a pending reply.
        while let Some(pending) = c.outbox.as_mut() {
            if pending.remaining() == 0 {
                c.outbox = None;
                break;
            }
            let slices: Vec<IoSlice<'_>> = pending.segments[pending.seg..]
                .iter()
                .enumerate()
                .map(|(i, s)| IoSlice::new(if i == 0 { &s[pending.off..] } else { s }))
                .filter(|s| !s.is_empty())
                .collect();
            match c.conn.write_vectored(&slices) {
                Ok(0) => return false,
                Ok(n) => {
                    pending.advance(n);
                    c.last_activity = Instant::now();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => return false,
            }
        }
        // DISPATCH: a complete buffered frame becomes the next outbox.
        match take_frame(&mut c.inbox) {
            Ok(Some(request)) => {
                c.last_activity = Instant::now();
                match PendingWrite::frame(respond(backend, &request)) {
                    Ok(pending) => c.outbox = Some(pending),
                    // A response too large to frame: protocol error on
                    // this connection (same as the blocking write path).
                    Err(_) => return false,
                }
                continue;
            }
            Ok(None) => {}
            Err(_) => return false,
        }
        // READ: pull whatever the socket has into the inbox.
        match c.conn.read(&mut scratch) {
            // EOF — clean between frames or torn mid-frame, either way
            // this connection is done.
            Ok(0) => return false,
            Ok(n) => {
                c.inbox.extend_from_slice(&scratch[..n]);
                c.last_activity = Instant::now();
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
}

/// Dispatch one request payload; never panics the loop thread — every
/// failure becomes a `STATUS_ERR` response.
fn respond(backend: &dyn ExchangeTransport, payload: &[u8]) -> Segments {
    match try_handle(backend, payload) {
        Ok(response) => response,
        Err(e) => {
            let mut out = Segments::status(STATUS_ERR);
            out.extend_from_slice(format!("{e:#}").as_bytes());
            out
        }
    }
}

/// [`respond`] flattened to one buffer (tests and legacy-server
/// simulations that still speak blocking `write_frame`).
#[cfg(test)]
fn handle_request(backend: &dyn ExchangeTransport, payload: &[u8]) -> Vec<u8> {
    respond(backend, payload).concat()
}

fn try_handle(backend: &dyn ExchangeTransport, payload: &[u8]) -> Result<Segments> {
    let mut r = payload;
    let mut op = [0u8; 1];
    r.read_exact(&mut op)?;
    match op[0] {
        OP_PUBLISH => {
            let ckpt = Checkpoint::read_from(&mut r)?;
            backend.publish(ckpt)?;
            Ok(Segments::status(STATUS_OK))
        }
        OP_LATEST => {
            let member = read_u64(&mut r)? as usize;
            let max_step = read_u64(&mut r)?;
            match backend.latest_at_most(member, max_step)? {
                Some(ckpt) => {
                    let mut out = Segments::status(STATUS_OK);
                    ckpt.write_to(&mut out)?;
                    Ok(out)
                }
                None => Ok(Segments::status(STATUS_NONE)),
            }
        }
        OP_FETCH => {
            let member = read_u64(&mut r)? as usize;
            let max_step = read_u64(&mut r)?;
            let raw_count = read_u32(&mut r)?;
            // Capability bit: a codec byte follows the names and the
            // reply uses tagged frames. An old server never gets here —
            // the masked-off count fails its checked_count guard.
            let cap = raw_count & FETCH_CAP_BIT != 0;
            let n = checked_count((raw_count & !FETCH_CAP_BIT) as usize, r.len(), 4, "names")?;
            let mut names = Vec::with_capacity(n);
            for _ in 0..n {
                names.push(read_name(&mut r)?);
            }
            let codec = if cap {
                let mut tag = [0u8; 1];
                r.read_exact(&mut tag)?;
                Codec::from_id(tag[0])?
            } else {
                Codec::Raw
            };
            match backend.latest_at_most(member, max_step)? {
                Some(ckpt) => {
                    let fetch = windows_from_checkpoint(&ckpt, &names)?;
                    let mut out = Segments::status(STATUS_OK);
                    out.extend_from_slice(&(fetch.member as u64).to_le_bytes());
                    out.extend_from_slice(&fetch.step.to_le_bytes());
                    out.extend_from_slice(&(fetch.windows.len() as u32).to_le_bytes());
                    for w in fetch.windows {
                        if cap {
                            // Encode straight off the window's payload —
                            // windows_from_checkpoint hands over decoded
                            // data, so no second copy before the encode —
                            // and adopt the encoder's output as the wire
                            // segment.
                            let (tag, bytes) = match &w.payload {
                                WindowPayload::Raw(data) => codec.encode(data),
                                WindowPayload::Encoded { .. } => codec.encode(&w.to_f32()?),
                            };
                            write_window_frame_tagged(
                                &mut out,
                                FetchedWindow::encoded(w.name, w.shape, tag, bytes),
                            )?;
                        } else {
                            write_window_frame_raw(&mut out, &w)?;
                        }
                    }
                    Ok(out)
                }
                None => Ok(Segments::status(STATUS_NONE)),
            }
        }
        OP_DESCRIBE => {
            let member = read_u64(&mut r)? as usize;
            let max_step = read_u64(&mut r)?;
            match backend.latest_at_most(member, max_step)? {
                Some(ckpt) => {
                    let mut out = Segments::status(STATUS_OK);
                    out.extend_from_slice(&(ckpt.member as u64).to_le_bytes());
                    out.extend_from_slice(&ckpt.step.to_le_bytes());
                    let layout = ckpt.flat().layout();
                    out.extend_from_slice(&(layout.len() as u64).to_le_bytes());
                    for e in layout.entries() {
                        write_name(&mut out, &e.name)?;
                        write_shape(&mut out, &e.shape)?;
                    }
                    let residual = ckpt.residual().prefix_entries("");
                    out.extend_from_slice(&(residual.len() as u64).to_le_bytes());
                    for (name, t) in residual {
                        write_framed_tensor(&mut out, name, t)?;
                    }
                    Ok(out)
                }
                None => Ok(Segments::status(STATUS_NONE)),
            }
        }
        OP_MEMBERS => {
            let members = backend.members()?;
            let mut out = Segments::status(STATUS_OK);
            out.extend_from_slice(&(members.len() as u64).to_le_bytes());
            for m in members {
                out.extend_from_slice(&(m as u64).to_le_bytes());
            }
            Ok(out)
        }
        OP_GC => {
            backend.gc()?;
            Ok(Segments::status(STATUS_OK))
        }
        OP_STEPS => {
            let steps = backend.last_steps()?;
            let mut out = Segments::status(STATUS_OK);
            out.extend_from_slice(&(steps.len() as u64).to_le_bytes());
            for (m, s) in steps {
                out.extend_from_slice(&(m as u64).to_le_bytes());
                out.extend_from_slice(&s.to_le_bytes());
            }
            Ok(out)
        }
        OP_DELTA => {
            let member = read_u64(&mut r)? as usize;
            let max_step = read_u64(&mut r)?;
            let mut flag = [0u8; 1];
            r.read_exact(&mut flag)?;
            let flags = flag[0];
            // The pre-capability protocol used this byte as a pure 0/1
            // basis marker; keeping the error string stable ("bad basis
            // flag") is what lets a new client recognize an old server.
            if flags > (DELTA_FLAG_BASIS | DELTA_FLAG_CODEC) {
                bail!("bad basis flag {flags}");
            }
            let basis = if flags & DELTA_FLAG_BASIS != 0 {
                let step = read_u64(&mut r)?;
                let n = checked_count(read_u64(&mut r)? as usize, r.len(), 8, "digests")?;
                let mut digests = Vec::with_capacity(n);
                for _ in 0..n {
                    digests.push(read_u64(&mut r)?);
                }
                Some(Basis { step, digests })
            } else {
                None
            };
            r.read_exact(&mut flag)?;
            let windows = match flag[0] {
                0 => WindowSel::All,
                1 => {
                    let n = checked_count(read_u32(&mut r)? as usize, r.len(), 4, "names")?;
                    let mut names = Vec::with_capacity(n);
                    for _ in 0..n {
                        names.push(read_name(&mut r)?);
                    }
                    WindowSel::Named(names)
                }
                other => bail!("bad window selection flag {other}"),
            };
            let cap = flags & DELTA_FLAG_CODEC != 0;
            let codec = if cap {
                r.read_exact(&mut flag)?;
                Codec::from_id(flag[0])?
            } else {
                Codec::Raw
            };
            let spec = FetchSpec {
                member,
                max_step,
                basis,
                windows,
                codec,
            };
            // Answer with the backend's native fetch so this path can
            // never diverge from serving the backend directly: an
            // InProcess store compares digests against the shared plane,
            // a SpoolDir `pread`s exactly the changed encoded ranges —
            // which the tagged writer below adopts as wire segments
            // untouched — and a relay mirror serves its installed plane.
            match backend.fetch(&spec)? {
                Some(res) => {
                    let mut out = Segments::status(STATUS_OK);
                    out.extend_from_slice(&(res.member as u64).to_le_bytes());
                    out.extend_from_slice(&res.step.to_le_bytes());
                    out.extend_from_slice(&(res.parts.len() as u64).to_le_bytes());
                    for ((name, shape), d) in res.parts.iter().zip(&res.digests) {
                        write_name(&mut out, name)?;
                        write_shape(&mut out, shape)?;
                        out.extend_from_slice(&d.to_le_bytes());
                    }
                    // A zero-copy full hand-off has no wire analogue:
                    // expand it into windows straight off the shared plane.
                    match &res.full {
                        Some(ck) => {
                            let flat = ck.flat();
                            let entries = flat.layout().entries();
                            out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
                            for e in entries {
                                if cap {
                                    let (tag, bytes) = codec.encode(&flat.data()[e.range()]);
                                    write_window_frame_tagged(
                                        &mut out,
                                        FetchedWindow::encoded(
                                            e.name.clone(),
                                            e.shape.clone(),
                                            tag,
                                            bytes,
                                        ),
                                    )?;
                                } else {
                                    write_name(&mut out, &e.name)?;
                                    write_shape(&mut out, &e.shape)?;
                                    out.extend_from_slice(&(e.len as u64).to_le_bytes());
                                    write_f32s(&mut out, &flat.data()[e.range()])?;
                                }
                            }
                        }
                        None => {
                            out.extend_from_slice(&(res.windows.len() as u32).to_le_bytes());
                            for w in res.windows {
                                if cap {
                                    write_window_frame_tagged(&mut out, w)?;
                                } else {
                                    write_window_frame_raw(&mut out, &w)?;
                                }
                            }
                        }
                    }
                    out.extend_from_slice(&(res.unchanged.len() as u32).to_le_bytes());
                    for name in &res.unchanged {
                        write_name(&mut out, name)?;
                    }
                    let residual = res.residual.prefix_entries("");
                    out.extend_from_slice(&(residual.len() as u64).to_le_bytes());
                    for (name, t) in residual {
                        write_framed_tensor(&mut out, name, t)?;
                    }
                    Ok(out)
                }
                None => Ok(Segments::status(STATUS_NONE)),
            }
        }
        other => bail!("unknown opcode {other}"),
    }
}

// ------------------------------------------------------------------- client

enum Target {
    Tcp(String),
    #[cfg(unix)]
    Unix(PathBuf),
}

/// Window table + residual of a published checkpoint, as returned by
/// `DESCRIBE` — the metadata a sharded reload needs before fetching.
struct Description {
    member: usize,
    step: u64,
    parts: Vec<(String, Vec<usize>)>,
    residual: TensorMap,
}

/// Client endpoint of the wire protocol (one request/response connection
/// per operation — the exchange cadence is seconds, not microseconds).
pub struct SocketTransport {
    target: Target,
    /// `Some(batch)`: `latest`/`latest_at_most` reassemble the plane from
    /// windowed fetches of `batch` windows each instead of one full-plane
    /// response.
    windowed: Option<usize>,
    /// Codec advertised through the capability bit on `DELTA`/`FETCH`
    /// requests ([`Codec::Raw`] = classic raw frames, no capability).
    codec: Codec,
    /// Sticky fallback: set once a capability request is rejected by a
    /// pre-capability server, so later requests skip the doomed attempt.
    legacy_peer: AtomicBool,
    /// Per-connection response timeout (defaults to [`READ_TIMEOUT`]).
    read_timeout: Duration,
    requests: AtomicU64,
    bytes_tx: AtomicU64,
    bytes_rx: AtomicU64,
}

impl SocketTransport {
    fn new(target: Target) -> Self {
        SocketTransport {
            target,
            windowed: None,
            codec: Codec::Raw,
            legacy_peer: AtomicBool::new(false),
            read_timeout: READ_TIMEOUT,
            requests: AtomicU64::new(0),
            bytes_tx: AtomicU64::new(0),
            bytes_rx: AtomicU64::new(0),
        }
    }

    /// Connect to a [`SocketServer::bind_tcp`] endpoint (`host:port`).
    pub fn connect_tcp(addr: &str) -> Self {
        Self::new(Target::Tcp(addr.to_string()))
    }

    /// Connect to a [`SocketServer::bind_unix`] endpoint.
    #[cfg(unix)]
    pub fn connect_unix(path: &Path) -> Self {
        Self::new(Target::Unix(path.to_path_buf()))
    }

    /// Parse an endpoint spec: `unix:/path/to.sock` or `host:port`.
    pub fn connect(spec: &str) -> Result<Self> {
        #[cfg(unix)]
        if let Some(path) = spec.strip_prefix("unix:") {
            return Ok(Self::connect_unix(Path::new(path)));
        }
        if spec.contains(':') {
            Ok(Self::connect_tcp(spec))
        } else {
            bail!("socket endpoint {spec:?} (want host:port or unix:/path)")
        }
    }

    /// Reload teachers by sharded fetch, `batch` windows per request.
    pub fn with_windowed_fetch(mut self, batch: usize) -> Self {
        self.windowed = Some(batch.max(1));
        self
    }

    /// Ask the server for codec-encoded window frames (the capability
    /// bit on `DELTA`/`FETCH` requests). Falls back to raw frames —
    /// transparently and stickily — against a pre-capability server.
    pub fn with_codec(mut self, codec: Codec) -> Self {
        self.codec = codec;
        self
    }

    /// Bound every response read to `timeout` instead of the default
    /// [`READ_TIMEOUT`]. A timed-out read surfaces as an `io::Error` of
    /// kind `TimedOut`/`WouldBlock` — transient under
    /// [`classify_error`](crate::codistill::transport::classify_error),
    /// so a [`Retry`](crate::codistill::transport::Retry)-wrapped client
    /// re-attempts a hung operation instead of blocking the run on it.
    pub fn with_read_timeout(mut self, timeout: Duration) -> Self {
        self.read_timeout = timeout;
        self
    }

    /// The codec to advertise for one spec: an explicit spec codec wins,
    /// the client default otherwise — and neither once the peer proved
    /// pre-capability.
    fn effective_codec(&self, spec_codec: Codec) -> Codec {
        if self.legacy_peer.load(Ordering::Relaxed) {
            return Codec::Raw;
        }
        if spec_codec != Codec::Raw {
            spec_codec
        } else {
            self.codec
        }
    }

    /// Whether `err` is a peer rejecting a capability request: a
    /// pre-capability server (old `DELTA` flag validation / old `FETCH`
    /// count guard), or a capability-aware-but-older server that knows
    /// the codec byte yet not this codec id (lossy tags postdate the
    /// lossless ones).
    fn is_capability_rejection(err: &anyhow::Error) -> bool {
        let text = format!("{err:#}");
        text.contains("bad basis flag")
            || text.contains("names but only")
            || text.contains("unknown window codec id")
    }

    /// (requests, bytes sent, bytes received) so far — the numbers the
    /// bench reports and `netsim` prices.
    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.requests.load(Ordering::Relaxed),
            self.bytes_tx.load(Ordering::Relaxed),
            self.bytes_rx.load(Ordering::Relaxed),
        )
    }

    fn open(&self) -> Result<Conn> {
        // A response timeout bounds every operation: a dead server is an
        // error, never a hang.
        match &self.target {
            Target::Tcp(addr) => {
                let s =
                    TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
                s.set_read_timeout(Some(self.read_timeout))?;
                Ok(Conn::Tcp(s))
            }
            #[cfg(unix)]
            Target::Unix(path) => {
                let s = UnixStream::connect(path)
                    .with_context(|| format!("connecting {}", path.display()))?;
                s.set_read_timeout(Some(self.read_timeout))?;
                Ok(Conn::Unix(s))
            }
        }
    }

    /// One request/response round trip. Returns the response body after
    /// the status byte, or `None` for `STATUS_NONE`.
    fn roundtrip(&self, request: &[u8]) -> Result<Option<Vec<u8>>> {
        let mut conn = self.open()?;
        write_frame(&mut conn, request)?;
        let mut response =
            read_frame(&mut conn)?.context("exchange server closed the connection")?;
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.bytes_tx
            .fetch_add(request.len() as u64, Ordering::Relaxed);
        self.bytes_rx
            .fetch_add(response.len() as u64, Ordering::Relaxed);
        if response.is_empty() {
            bail!("empty response frame");
        }
        let status = response.remove(0);
        match status {
            STATUS_OK => Ok(Some(response)),
            STATUS_NONE => Ok(None),
            STATUS_ERR => bail!(
                "exchange server error: {}",
                String::from_utf8_lossy(&response)
            ),
            other => bail!("bad response status {other}"),
        }
    }

    fn describe(&self, member: usize, max_step: u64) -> Result<Option<Description>> {
        let mut req = vec![OP_DESCRIBE];
        req.extend_from_slice(&(member as u64).to_le_bytes());
        req.extend_from_slice(&max_step.to_le_bytes());
        let body = match self.roundtrip(&req)? {
            Some(b) => b,
            None => return Ok(None),
        };
        let mut r = body.as_slice();
        let member = read_u64(&mut r)? as usize;
        let step = read_u64(&mut r)?;
        // Reply counts come off the wire: bound them against the bytes
        // actually present (like every other count parser here) so a
        // truncated or malicious frame is a protocol error, never a huge
        // allocation.
        let n_windows = checked_count(read_u64(&mut r)? as usize, r.len(), 8, "windows")?;
        let mut parts = Vec::with_capacity(n_windows);
        for _ in 0..n_windows {
            let name = read_name(&mut r)?;
            let shape = read_shape(&mut r)?;
            parts.push((name, shape));
        }
        let n_residual = checked_count(read_u64(&mut r)? as usize, r.len(), 9, "residuals")?;
        let mut residual = TensorMap::new();
        for _ in 0..n_residual {
            let (name, t) = read_framed_tensor(&mut r)?;
            residual.insert(name, t);
        }
        Ok(Some(Description {
            member,
            step,
            parts,
            residual,
        }))
    }

    /// Full plane via sharded reassembly: describe, then pull windows in
    /// `batch`-sized `FETCH` requests pinned to the described step, then
    /// hand the reassembled checkpoint over as a zero-copy full result
    /// (digests computed locally — a pure function of the bytes, so they
    /// equal the server's).
    fn windowed_full_fetch(
        &self,
        member: usize,
        max_step: u64,
        batch: usize,
    ) -> Result<Option<FetchResult>> {
        let desc = match self.describe(member, max_step)? {
            Some(d) => d,
            None => return Ok(None),
        };
        let layout = Arc::new(FlatLayout::from_named_shapes(desc.parts.clone()));
        let mut buf = FlatBuffer::zeros(layout.clone());
        let names: Vec<String> = layout.names().map(|s| s.to_string()).collect();
        for chunk in names.chunks(batch) {
            let fetch = self
                .wire_fetch_windows(member, desc.step, chunk, self.effective_codec(Codec::Raw))?
                .context("checkpoint pruned between describe and fetch")?;
            if fetch.step != desc.step {
                bail!(
                    "exchange moved from step {} to {} mid-fetch",
                    desc.step,
                    fetch.step
                );
            }
            for w in fetch.windows {
                let name = w.name.clone();
                buf.write_window(&name, &w.into_f32()?)?;
            }
        }
        let digests = buf.window_digests();
        let ckpt = Arc::new(Checkpoint::from_flat(
            desc.member,
            desc.step,
            Arc::new(buf),
            desc.residual.clone(),
        ));
        Ok(Some(FetchResult {
            member: desc.member,
            step: desc.step,
            parts: desc.parts,
            digests,
            windows: Vec::new(),
            unchanged: Vec::new(),
            residual: desc.residual,
            full: Some(ckpt),
        }))
    }

    /// The `FETCH` wire op: named windows of the freshest checkpoint
    /// within `max_step`, in request order. A non-raw `codec` sets the
    /// capability bit (tagged reply frames); a pre-capability server's
    /// rejection flips the sticky fallback and the request retries raw.
    fn wire_fetch_windows(
        &self,
        member: usize,
        max_step: u64,
        names: &[String],
        codec: Codec,
    ) -> Result<Option<WindowedFetch>> {
        let cap = codec != Codec::Raw;
        let mut req = vec![OP_FETCH];
        req.extend_from_slice(&(member as u64).to_le_bytes());
        req.extend_from_slice(&max_step.to_le_bytes());
        let count = names.len() as u32 | if cap { FETCH_CAP_BIT } else { 0 };
        req.extend_from_slice(&count.to_le_bytes());
        for name in names {
            write_name(&mut req, name)?;
        }
        if cap {
            req.push(codec.id());
        }
        let body = match self.roundtrip(&req) {
            Err(e) if cap && Self::is_capability_rejection(&e) => {
                self.legacy_peer.store(true, Ordering::Relaxed);
                return self.wire_fetch_windows(member, max_step, names, Codec::Raw);
            }
            other => match other? {
                Some(b) => b,
                None => return Ok(None),
            },
        };
        let mut r = body.as_slice();
        let member = read_u64(&mut r)? as usize;
        let step = read_u64(&mut r)?;
        let n = checked_count(read_u32(&mut r)? as usize, r.len(), 16, "windows")?;
        let mut windows = Vec::with_capacity(n);
        for _ in 0..n {
            windows.push(if cap {
                read_window_frame_tagged(&mut r)?
            } else {
                read_window_frame_raw(&mut r)?
            });
        }
        Ok(Some(WindowedFetch {
            member,
            step,
            windows,
        }))
    }
}

impl ExchangeTransport for SocketTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::Socket
    }

    fn publish(&self, ckpt: Checkpoint) -> Result<()> {
        let mut req = vec![OP_PUBLISH];
        ckpt.write_to(&mut req)?;
        self.roundtrip(&req)?
            .context("publish returned not-found")?;
        Ok(())
    }

    /// The one native read: a full no-basis fetch pulls the whole
    /// checkpoint in one `LATEST` stream (or reassembles it window by
    /// window in windowed mode); anything else — a delta basis or a named
    /// scope — is one `DELTA` round trip moving only changed windows,
    /// codec-encoded when the capability negotiated (module docs).
    fn fetch(&self, spec: &FetchSpec) -> Result<Option<FetchResult>> {
        if spec.basis.is_none() {
            if let WindowSel::All = spec.windows {
                if let Some(batch) = self.windowed {
                    return self.windowed_full_fetch(spec.member, spec.max_step, batch);
                }
                // Whole checkpoint as one CKPT0003 stream: the digest
                // table rides the header, verified on read.
                let mut req = vec![OP_LATEST];
                req.extend_from_slice(&(spec.member as u64).to_le_bytes());
                req.extend_from_slice(&spec.max_step.to_le_bytes());
                let ckpt = match self.roundtrip(&req)? {
                    Some(body) => Arc::new(Checkpoint::read_from(&mut body.as_slice())?),
                    None => return Ok(None),
                };
                return Ok(Some(fetch_from_checkpoint(
                    &ckpt,
                    &FetchSpec::full(spec.member, spec.max_step),
                )?));
            }
        }
        let codec = self.effective_codec(spec.codec);
        let cap = codec != Codec::Raw;
        let mut req = vec![OP_DELTA];
        req.extend_from_slice(&(spec.member as u64).to_le_bytes());
        req.extend_from_slice(&spec.max_step.to_le_bytes());
        let mut flags = 0u8;
        if spec.basis.is_some() {
            flags |= DELTA_FLAG_BASIS;
        }
        if cap {
            flags |= DELTA_FLAG_CODEC;
        }
        req.push(flags);
        if let Some(b) = &spec.basis {
            req.extend_from_slice(&b.step.to_le_bytes());
            req.extend_from_slice(&(b.digests.len() as u64).to_le_bytes());
            for d in &b.digests {
                req.extend_from_slice(&d.to_le_bytes());
            }
        }
        match &spec.windows {
            WindowSel::All => req.push(0),
            WindowSel::Named(names) => {
                req.push(1);
                req.extend_from_slice(&(names.len() as u32).to_le_bytes());
                for name in names {
                    write_name(&mut req, name)?;
                }
            }
        }
        if cap {
            req.push(codec.id());
        }
        let body = match self.roundtrip(&req) {
            // A pre-capability server rejects the flags byte; remember
            // and retry the identical spec with raw frames.
            Err(e) if cap && Self::is_capability_rejection(&e) => {
                self.legacy_peer.store(true, Ordering::Relaxed);
                return self.fetch(spec);
            }
            other => match other? {
                Some(b) => b,
                None => return Ok(None),
            },
        };
        let mut r = body.as_slice();
        let member = read_u64(&mut r)? as usize;
        let step = read_u64(&mut r)?;
        // The counts below come off the wire too: bound them against the
        // bytes actually present so a garbled response is an error, not
        // an absurd allocation.
        let n_parts = checked_count(read_u64(&mut r)? as usize, r.len(), 16, "windows")?;
        let mut parts = Vec::with_capacity(n_parts);
        let mut digests = Vec::with_capacity(n_parts);
        for _ in 0..n_parts {
            let name = read_name(&mut r)?;
            let shape = read_shape(&mut r)?;
            parts.push((name, shape));
            digests.push(read_u64(&mut r)?);
        }
        let n_changed = checked_count(read_u32(&mut r)? as usize, r.len(), 16, "windows")?;
        let mut windows = Vec::with_capacity(n_changed);
        for _ in 0..n_changed {
            windows.push(if cap {
                read_window_frame_tagged(&mut r)?
            } else {
                read_window_frame_raw(&mut r)?
            });
        }
        let n_unchanged = checked_count(read_u32(&mut r)? as usize, r.len(), 4, "names")?;
        let mut unchanged = Vec::with_capacity(n_unchanged);
        for _ in 0..n_unchanged {
            unchanged.push(read_name(&mut r)?);
        }
        let n_residual = checked_count(read_u64(&mut r)? as usize, r.len(), 9, "residuals")?;
        let mut residual = TensorMap::new();
        for _ in 0..n_residual {
            let (name, t) = read_framed_tensor(&mut r)?;
            residual.insert(name, t);
        }
        Ok(Some(FetchResult {
            member,
            step,
            parts,
            digests,
            windows,
            unchanged,
            residual,
            full: None,
        }))
    }

    fn members(&self) -> Result<Vec<usize>> {
        let body = self
            .roundtrip(&[OP_MEMBERS])?
            .context("members returned not-found")?;
        let mut r = body.as_slice();
        let n = checked_count(read_u64(&mut r)? as usize, r.len(), 8, "members")?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(read_u64(&mut r)? as usize);
        }
        Ok(out)
    }

    fn last_steps(&self) -> Result<Vec<(usize, u64)>> {
        let body = self
            .roundtrip(&[OP_STEPS])?
            .context("steps returned not-found")?;
        let mut r = body.as_slice();
        let n = checked_count(read_u64(&mut r)? as usize, r.len(), 16, "heartbeats")?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let m = read_u64(&mut r)? as usize;
            let s = read_u64(&mut r)?;
            out.push((m, s));
        }
        Ok(out)
    }

    fn gc(&self) -> Result<()> {
        self.roundtrip(&[OP_GC])?.context("gc returned not-found")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ckpt(member: usize, step: u64, vals: &[f32]) -> Checkpoint {
        let mut params = TensorMap::new();
        params.insert("params.a", Tensor::f32(&[2], vals[..2].to_vec()).unwrap());
        params.insert("params.b", Tensor::f32(&[3], vals[2..5].to_vec()).unwrap());
        params.insert("params.ids", Tensor::i32(&[2], vec![4, 2]).unwrap());
        Checkpoint::new(member, step, params)
    }

    #[test]
    fn configurable_connection_pool() {
        // default bind uses the crate-wide cap
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        assert_eq!(server.max_connections(), MAX_CONNECTIONS);
        drop(server);

        // explicit cap is honored and serves traffic; zero clamps to 1
        let server = SocketServer::bind_tcp_with("127.0.0.1:0", 4, 2).unwrap();
        assert_eq!(server.max_connections(), 2);
        let client = SocketTransport::connect_tcp(server.addr());
        client.publish(ckpt(0, 1, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();
        assert_eq!(client.latest(0).unwrap().unwrap().step, 1);
        drop(server);

        let server = SocketServer::bind_tcp_with("127.0.0.1:0", 4, 0).unwrap();
        assert_eq!(server.max_connections(), 1);
        // a 1-slot pool still serves sequential clients
        let a = SocketTransport::connect_tcp(server.addr());
        a.publish(ckpt(0, 2, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();
        drop(a);
        let b = SocketTransport::connect_tcp(server.addr());
        assert_eq!(b.latest(0).unwrap().unwrap().step, 2);
    }

    #[test]
    fn tcp_roundtrip_full_plane() {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let client = SocketTransport::connect_tcp(server.addr());

        assert!(client.latest(0).unwrap().is_none());
        client.publish(ckpt(0, 5, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();
        client.publish(ckpt(0, 9, &[6.0, 7.0, 8.0, 9.0, 10.0])).unwrap();

        let c = client.latest(0).unwrap().unwrap();
        assert_eq!(c.step, 9);
        assert_eq!(c.flat().view("params.a").unwrap(), &[6.0, 7.0]);
        // residual (i32) leaves survive the wire
        assert_eq!(
            c.params().get("params.ids").unwrap().as_i32().unwrap(),
            &[4, 2]
        );
        // staleness bound
        assert_eq!(client.latest_at_most(0, 5).unwrap().unwrap().step, 5);
        assert!(client.latest_at_most(0, 4).unwrap().is_none());
        assert_eq!(client.members().unwrap(), vec![0]);
        client.gc().unwrap();

        // server-side store saw the same bytes (no re-encode drift)
        let direct = server.store().latest(0).unwrap();
        assert_eq!(direct.flat().data(), c.flat().data());
    }

    #[test]
    fn tcp_windowed_fetch_and_reassembly() {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let publisher = SocketTransport::connect_tcp(server.addr());
        publisher.publish(ckpt(1, 3, &[1.5, 2.5, 3.5, 4.5, 5.5])).unwrap();

        // raw sharded fetch: one window only
        let reader = SocketTransport::connect_tcp(server.addr());
        let f = reader
            .fetch_windows(1, u64::MAX, &["params.b".to_string()])
            .unwrap()
            .unwrap();
        assert_eq!(f.step, 3);
        assert_eq!(f.windows[0].to_f32().unwrap(), vec![3.5, 4.5, 5.5]);
        assert_eq!(f.payload_bytes(), 12);

        // windowed reload reassembles the identical checkpoint
        let windowed = SocketTransport::connect_tcp(server.addr()).with_windowed_fetch(1);
        let via_windows = windowed.latest(1).unwrap().unwrap();
        let via_plane = reader.latest(1).unwrap().unwrap();
        assert_eq!(via_windows.step, via_plane.step);
        assert_eq!(via_windows.flat().data(), via_plane.flat().data());
        assert!(via_windows
            .flat()
            .layout()
            .same_plane(via_plane.flat().layout()));
        assert_eq!(
            via_windows.params().get("params.ids").unwrap().as_i32().unwrap(),
            &[4, 2]
        );

        // the windowed client paid per-window requests, never one big pull
        let (reqs, _tx, rx) = windowed.stats();
        assert!(reqs >= 3, "describe + >=2 window fetches, got {reqs}");
        assert!(rx > 0);
    }

    #[test]
    fn server_reports_errors_not_hangs() {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let client = SocketTransport::connect_tcp(server.addr());
        client.publish(ckpt(0, 10, &[0.0; 5])).unwrap();
        // step regression is rejected through the wire with the store's
        // message, and the connection/server stay healthy
        let err = client.publish(ckpt(0, 4, &[0.0; 5])).unwrap_err();
        assert!(format!("{err:#}").contains("published step"), "{err:#}");
        assert_eq!(client.members().unwrap(), vec![0]);
        // unknown window error round-trips too
        let err = client
            .fetch_windows(0, u64::MAX, &["params.nope".to_string()])
            .unwrap_err();
        assert!(format!("{err:#}").contains("no window"), "{err:#}");
    }

    #[test]
    fn delta_opcode_moves_only_changed_windows() {
        use crate::codistill::transport::Basis;
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let client = SocketTransport::connect_tcp(server.addr());
        client.publish(ckpt(0, 1, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();
        let v1 = client.latest(0).unwrap().unwrap();
        let basis = Basis {
            step: 1,
            digests: v1.window_digests().as_ref().clone(),
        };
        // params.b changes, params.a does not
        client.publish(ckpt(0, 2, &[1.0, 2.0, 9.0, 9.0, 9.0])).unwrap();
        let res = client
            .fetch(&FetchSpec::full(0, u64::MAX).with_basis(basis.clone()))
            .unwrap()
            .unwrap();
        assert_eq!(res.step, 2);
        assert!(res.full.is_none());
        assert_eq!(res.unchanged, vec!["params.a".to_string()]);
        assert_eq!(res.windows.len(), 1);
        assert_eq!(res.windows[0].name, "params.b");
        assert_eq!(res.windows[0].to_f32().unwrap(), vec![9.0, 9.0, 9.0]);
        assert_eq!(res.payload_bytes(), 3 * 4);
        assert_eq!(res.parts.len(), 2);
        assert_eq!(res.digests.len(), 2);
        // residual (i32) leaves ride the delta wire too
        assert_eq!(
            res.residual.get("params.ids").unwrap().as_i32().unwrap(),
            &[4, 2]
        );
        // named scope + basis over the wire
        let res = client
            .fetch(
                &FetchSpec::named(0, u64::MAX, vec!["params.a".into(), "params.b".into()])
                    .with_basis(basis),
            )
            .unwrap()
            .unwrap();
        assert_eq!(res.unchanged, vec!["params.a".to_string()]);
        assert_eq!(res.windows.len(), 1);
        // absent member stays a clean None through DELTA
        assert!(client
            .fetch(&FetchSpec::full(9, u64::MAX))
            .unwrap()
            .is_none());
    }

    /// Satellite regression: a hostile or corrupt server replying
    /// `STATUS_OK` with absurd element counts must produce a protocol
    /// error on the client — never a multi-gigabyte `Vec::with_capacity`.
    /// Before the `checked_count` guards on the reply parsers, the
    /// DESCRIBE `n_windows` and the DESCRIBE/DELTA `n_residual` counts
    /// were trusted verbatim.
    #[test]
    fn malformed_reply_counts_error_instead_of_allocating() {
        use std::net::TcpListener;

        // One-shot fake server: answers every connection's first frame
        // with the canned STATUS_OK body.
        fn fake_server(reply: Vec<u8>) -> (String, std::thread::JoinHandle<()>) {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            let handle = std::thread::spawn(move || {
                if let Ok((mut s, _)) = listener.accept() {
                    if read_frame(&mut s).is_ok() {
                        write_frame(&mut s, &reply).ok();
                    }
                }
            });
            (addr, handle)
        }

        // DESCRIBE reply claiming u64::MAX windows
        let mut body = vec![STATUS_OK];
        body.extend_from_slice(&0u64.to_le_bytes()); // member
        body.extend_from_slice(&1u64.to_le_bytes()); // step
        body.extend_from_slice(&u64::MAX.to_le_bytes()); // n_windows
        let (addr, h) = fake_server(body);
        let err = SocketTransport::connect_tcp(&addr)
            .with_windowed_fetch(2)
            .latest(0)
            .unwrap_err();
        assert!(format!("{err:#}").contains("frame claims"), "{err:#}");
        h.join().unwrap();

        // DELTA reply with an empty table but u64::MAX residual tensors
        let mut body = vec![STATUS_OK];
        body.extend_from_slice(&0u64.to_le_bytes()); // member
        body.extend_from_slice(&1u64.to_le_bytes()); // step
        body.extend_from_slice(&0u64.to_le_bytes()); // n_parts
        body.extend_from_slice(&0u32.to_le_bytes()); // n_changed
        body.extend_from_slice(&0u32.to_le_bytes()); // n_unchanged
        body.extend_from_slice(&u64::MAX.to_le_bytes()); // n_residual
        let (addr, h) = fake_server(body);
        let err = SocketTransport::connect_tcp(&addr)
            .fetch(
                &crate::codistill::transport::FetchSpec::full(0, u64::MAX).with_basis(Basis {
                    step: 0,
                    digests: vec![0],
                }),
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("frame claims"), "{err:#}");
        h.join().unwrap();

        // MEMBERS reply claiming u64::MAX members
        let mut body = vec![STATUS_OK];
        body.extend_from_slice(&u64::MAX.to_le_bytes());
        let (addr, h) = fake_server(body);
        let err = SocketTransport::connect_tcp(&addr).members().unwrap_err();
        assert!(format!("{err:#}").contains("frame claims"), "{err:#}");
        h.join().unwrap();
    }

    #[test]
    fn delta_capability_moves_encoded_frames() {
        use crate::codistill::transport::DeltaCache;
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let publisher = SocketTransport::connect_tcp(server.addr());
        // constant-valued windows so the shuffle codec pays off
        let big = |member: usize, step: u64, v: f32| {
            let mut params = TensorMap::new();
            params.insert("params.hot", Tensor::f32(&[256], vec![v; 256]).unwrap());
            params.insert("params.cold", Tensor::f32(&[256], vec![0.5; 256]).unwrap());
            Checkpoint::new(member, step, params)
        };
        publisher.publish(big(0, 1, 1.0)).unwrap();
        publisher.publish(big(0, 2, 2.0)).unwrap();
        let v1 = publisher.latest_at_most(0, 1).unwrap().unwrap();
        let basis = Basis {
            step: 1,
            digests: v1.window_digests().as_ref().clone(),
        };

        let raw = SocketTransport::connect_tcp(server.addr());
        let coded = SocketTransport::connect_tcp(server.addr()).with_codec(Codec::Shuffle);
        let spec = crate::codistill::transport::FetchSpec::full(0, u64::MAX).with_basis(basis);
        let res_raw = raw.fetch(&spec).unwrap().unwrap();
        let res_enc = coded.fetch(&spec).unwrap().unwrap();
        assert_eq!(res_raw.unchanged, res_enc.unchanged);
        assert_eq!(res_enc.windows.len(), 1);
        assert_eq!(res_enc.windows[0].codec(), Codec::Shuffle);
        assert!(
            res_enc.payload_bytes() < res_raw.payload_bytes(),
            "{} !< {}",
            res_enc.payload_bytes(),
            res_raw.payload_bytes()
        );
        // decoded bytes identical to the raw frames
        assert_eq!(
            res_enc.windows[0].to_f32().unwrap(),
            res_raw.windows[0].to_f32().unwrap()
        );

        // DeltaCache over the codec client installs byte-identically
        let mut cache = DeltaCache::new();
        let a = cache.latest(&coded, 0).unwrap().unwrap();
        let b = raw.latest(0).unwrap().unwrap();
        assert_eq!(a.flat().data(), b.flat().data());

        // windowed reassembly with codec: identical plane, fewer bytes
        let w_raw = SocketTransport::connect_tcp(server.addr()).with_windowed_fetch(1);
        let w_enc = SocketTransport::connect_tcp(server.addr())
            .with_windowed_fetch(1)
            .with_codec(Codec::Shuffle);
        let via_raw = w_raw.latest(0).unwrap().unwrap();
        let via_enc = w_enc.latest(0).unwrap().unwrap();
        assert_eq!(via_raw.flat().data(), via_enc.flat().data());
        let (_, _, rx_raw) = w_raw.stats();
        let (_, _, rx_enc) = w_enc.stats();
        assert!(rx_enc < rx_raw, "windowed codec moved {rx_enc} !< {rx_raw}");
    }

    /// A new client against a pre-capability server: the capability
    /// request is rejected with the old "bad basis flag" error, and the
    /// client transparently (and stickily) falls back to raw frames.
    #[test]
    fn capability_falls_back_against_legacy_server() {
        use std::net::TcpListener;

        let store = Arc::new(InProcess::new(4));
        store.publish(ckpt(0, 1, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();
        store.publish(ckpt(0, 2, &[1.0, 2.0, 9.0, 9.0, 9.0])).unwrap();
        let v1 = InProcess::latest_at_most(&store, 0, 1).unwrap();
        let basis = Basis {
            step: 1,
            digests: v1.window_digests().as_ref().clone(),
        };

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let thread_store = store.clone();
        let legacy = std::thread::spawn(move || {
            // serve three connections: capability attempt, raw retry,
            // and the later already-fallen-back request
            for _ in 0..3 {
                let (mut s, _) = listener.accept().unwrap();
                let req = match read_frame(&mut s).unwrap() {
                    Some(r) => r,
                    None => continue,
                };
                // a legacy server knows only flag values 0 and 1
                let reply = if req[0] == OP_DELTA && req[17] > 1 {
                    let mut out = vec![STATUS_ERR];
                    out.extend_from_slice(format!("bad basis flag {}", req[17]).as_bytes());
                    out
                } else {
                    handle_request(thread_store.as_ref(), &req)
                };
                write_frame(&mut s, &reply).ok();
            }
        });

        let client = SocketTransport::connect_tcp(&addr).with_codec(Codec::Shuffle);
        let spec =
            crate::codistill::transport::FetchSpec::full(0, u64::MAX).with_basis(basis.clone());
        let res = client.fetch(&spec).unwrap().unwrap();
        assert_eq!(res.step, 2);
        assert_eq!(res.unchanged, vec!["params.a".to_string()]);
        assert_eq!(res.windows[0].to_f32().unwrap(), vec![9.0, 9.0, 9.0]);
        assert_eq!(res.windows[0].codec(), Codec::Raw, "fallback still encoded?");
        // the fallback is sticky: the next request goes raw immediately
        // (the legacy thread serves exactly one more connection)
        let res = client.fetch(&spec).unwrap().unwrap();
        assert_eq!(res.windows[0].to_f32().unwrap(), vec![9.0, 9.0, 9.0]);
        legacy.join().unwrap();
    }

    /// A lossy-codec client against a capability-aware server that
    /// predates the lossy ids: the server understands the codec byte but
    /// rejects id 3 with "unknown window codec id", and the client falls
    /// back (stickily) to raw frames exactly like against a
    /// pre-capability server.
    #[test]
    fn lossy_capability_falls_back_against_shuffle_era_server() {
        use std::net::TcpListener;

        let store = Arc::new(InProcess::new(4));
        store.publish(ckpt(0, 1, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();
        store.publish(ckpt(0, 2, &[1.0, 2.0, 9.0, 9.0, 9.0])).unwrap();
        let v1 = InProcess::latest_at_most(&store, 0, 1).unwrap();
        let basis = Basis {
            step: 1,
            digests: v1.window_digests().as_ref().clone(),
        };

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let thread_store = store.clone();
        let older = std::thread::spawn(move || {
            for _ in 0..3 {
                let (mut s, _) = listener.accept().unwrap();
                let req = match read_frame(&mut s).unwrap() {
                    Some(r) => r,
                    None => continue,
                };
                // a shuffle-era server accepts the capability bit but its
                // Codec::from_id knows only ids 0 and 1 (the codec byte
                // rides last on a DELTA request)
                let reply = if req[0] == OP_DELTA
                    && req[17] & DELTA_FLAG_CODEC != 0
                    && *req.last().unwrap() > 1
                {
                    let mut out = vec![STATUS_ERR];
                    out.extend_from_slice(
                        format!("unknown window codec id {}", req.last().unwrap()).as_bytes(),
                    );
                    out
                } else {
                    handle_request(thread_store.as_ref(), &req)
                };
                write_frame(&mut s, &reply).ok();
            }
        });

        let client = SocketTransport::connect_tcp(&addr).with_codec(Codec::Int8);
        let spec =
            crate::codistill::transport::FetchSpec::full(0, u64::MAX).with_basis(basis.clone());
        let res = client.fetch(&spec).unwrap().unwrap();
        assert_eq!(res.step, 2);
        assert_eq!(res.unchanged, vec!["params.a".to_string()]);
        assert_eq!(res.windows[0].to_f32().unwrap(), vec![9.0, 9.0, 9.0]);
        assert_eq!(res.windows[0].codec(), Codec::Raw, "fallback still encoded?");
        let res = client.fetch(&spec).unwrap().unwrap();
        assert_eq!(res.windows[0].to_f32().unwrap(), vec![9.0, 9.0, 9.0]);
        older.join().unwrap();
    }

    #[test]
    fn steps_heartbeat_roundtrip() {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let client = SocketTransport::connect_tcp(server.addr());
        assert!(client.last_steps().unwrap().is_empty());
        client.publish(ckpt(3, 5, &[0.0; 5])).unwrap();
        client.publish(ckpt(1, 9, &[0.0; 5])).unwrap();
        client.publish(ckpt(3, 8, &[0.0; 5])).unwrap();
        assert_eq!(client.last_steps().unwrap(), vec![(1, 9), (3, 8)]);
    }

    /// Regression for the serial accept loop: two clients fetching
    /// concurrently must both complete while a third connection sits on
    /// the wire sending nothing (the old poll-one-connection server
    /// served that idle connection to EOF before accepting anyone else).
    #[test]
    fn concurrent_fetches_complete_despite_slow_connection() {
        use std::sync::mpsc;

        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let publisher = SocketTransport::connect_tcp(server.addr());
        publisher.publish(ckpt(0, 7, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();

        // A slow client: connects, sends half a length prefix, stalls.
        let mut slow = TcpStream::connect(server.addr()).unwrap();
        slow.write_all(&[9u8, 0]).unwrap();
        // Give the server time to hand the slow connection to a worker.
        std::thread::sleep(Duration::from_millis(50));

        let (tx, rx) = mpsc::channel();
        let addr = server.addr().to_string();
        let mut handles = Vec::new();
        for i in 0..2 {
            let tx = tx.clone();
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let c = SocketTransport::connect_tcp(&addr);
                let got = c.latest(0).unwrap().unwrap();
                tx.send((i, got.step)).unwrap();
            }));
        }
        drop(tx);
        let mut done = Vec::new();
        for _ in 0..2 {
            // A serial server would sit on the slow connection until its
            // 30 s read timeout; the concurrent server answers promptly.
            let (i, step) = rx
                .recv_timeout(Duration::from_secs(5))
                .expect("fetch blocked behind the slow connection");
            assert_eq!(step, 7);
            done.push(i);
        }
        done.sort();
        assert_eq!(done, vec![0, 1]);
        for h in handles {
            h.join().unwrap();
        }
        // The slow connection is still being served (held by its worker).
        assert!(server.active_connections() >= 1);
        drop(slow);
    }

    /// Dropping the server must not wait out the accept poll or any read
    /// timeout: the shutdown wakeup unblocks the accept immediately.
    #[test]
    fn shutdown_is_prompt() {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let t0 = std::time::Instant::now();
        drop(server);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "server drop took {:?}",
            t0.elapsed()
        );
    }

    #[cfg(unix)]
    #[test]
    fn unix_socket_roundtrip() {
        let path = std::env::temp_dir().join(format!(
            "codistill_uds_{}.sock",
            std::process::id()
        ));
        let server = SocketServer::bind_unix(&path, 4).unwrap();
        let client = SocketTransport::connect(&format!("unix:{}", path.display())).unwrap();
        client.publish(ckpt(7, 1, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();
        let c = client.latest(7).unwrap().unwrap();
        assert_eq!(c.flat().view("params.b").unwrap(), &[3.0, 4.0, 5.0]);
        drop(client);
        drop(server);
        assert!(!path.exists(), "socket file not unlinked on shutdown");
    }

    // -------------------------------------- readiness-loop edge cases
    //
    // Regressions for the event-driven rewrite: partial writes parked on
    // POLLOUT, torn frames, shutdown with live state machines, and
    // byte-compatibility with thread-pool-era blocking clients.

    /// Raw LATEST request frame for `member`, unbounded staleness.
    fn latest_request(member: u64) -> Vec<u8> {
        let mut req = vec![OP_LATEST];
        req.extend_from_slice(&member.to_le_bytes());
        req.extend_from_slice(&u64::MAX.to_le_bytes());
        req
    }

    /// A plane large enough that its reply cannot fit any kernel socket
    /// buffer, forcing the server's vectored write to park on POLLOUT.
    fn big_ckpt(member: usize, step: u64) -> Checkpoint {
        let elems = 2 * 1024 * 1024; // 8 MB of f32 payload
        let vals: Vec<f32> = (0..elems).map(|i| i as f32 * 0.5).collect();
        let mut params = TensorMap::new();
        params.insert("params.big", Tensor::f32(&[elems], vals).unwrap());
        Checkpoint::new(member, step, params)
    }

    /// A reader that drains an 8 MB reply in dribs while other clients
    /// fetch: the partial-write path must resume exactly where it parked
    /// and deliver a byte-identical frame, without stalling the loop.
    #[test]
    fn slow_reader_partial_writes_resume_byte_identical() {
        use std::io::Read as _;

        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let publisher = SocketTransport::connect_tcp(server.addr());
        publisher.publish(big_ckpt(0, 3)).unwrap();
        let req = latest_request(0);
        let expected = handle_request(server.store().as_ref(), &req);

        // The slow reader sends its request and then reads NOTHING: the
        // server fills the socket buffers and parks the rest on POLLOUT.
        let mut slow = TcpStream::connect(server.addr()).unwrap();
        write_frame(&mut slow, &req).unwrap();
        std::thread::sleep(Duration::from_millis(100));

        // Parked writer must not block anyone else.
        let fast = SocketTransport::connect_tcp(server.addr());
        let got = fast.latest(0).unwrap().unwrap();
        assert_eq!(got.step, 3);

        // Now drain the reply in 64 KB sips and compare every byte.
        let mut len = [0u8; 4];
        slow.read_exact(&mut len).unwrap();
        let total = u32::from_le_bytes(len) as usize;
        assert_eq!(total, expected.len());
        let mut reply = vec![0u8; total];
        let mut off = 0;
        while off < total {
            let end = (off + 64 * 1024).min(total);
            slow.read_exact(&mut reply[off..end]).unwrap();
            off = end;
            if off % (1024 * 1024) < 64 * 1024 {
                // stall every megabyte to re-exercise the park/resume path
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        assert_eq!(reply, expected, "partial-write resume corrupted the frame");
        // the drained connection is idle again and the server healthy
        assert_eq!(fast.latest(0).unwrap().unwrap().step, 3);
    }

    /// Clients vanishing mid-frame — half a length prefix, or a length
    /// prefix promising bytes that never come — must cost exactly their
    /// own connection: the state machine sees EOF, drops it, and the
    /// loop keeps serving.
    #[test]
    fn mid_request_disconnect_leaves_server_healthy() {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let publisher = SocketTransport::connect_tcp(server.addr());
        publisher.publish(ckpt(0, 2, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();
        let baseline = server.active_connections();

        // half a length prefix, then gone
        let mut torn_prefix = TcpStream::connect(server.addr()).unwrap();
        torn_prefix.write_all(&[17u8, 0]).unwrap();
        // a full prefix + the DESCRIBE opcode, but none of its body
        let mut torn_body = TcpStream::connect(server.addr()).unwrap();
        torn_body.write_all(&17u32.to_le_bytes()).unwrap();
        torn_body.write_all(&[OP_DESCRIBE]).unwrap();
        std::thread::sleep(Duration::from_millis(60));
        drop(torn_prefix);
        drop(torn_body);

        // both EOFs are noticed within a poll tick or two
        let t0 = std::time::Instant::now();
        loop {
            // only the publisher's connections are left registered
            if server.active_connections() <= baseline {
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "torn connections never reaped: {} still active",
                server.active_connections()
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        // and the server answers new traffic as if nothing happened
        let fresh = SocketTransport::connect_tcp(server.addr());
        assert_eq!(fresh.latest(0).unwrap().unwrap().step, 2);
    }

    /// Dropping the server with registered connections in every state —
    /// idle, mid-frame, reply pending — must still be prompt: the loop
    /// notices the shutdown flag on the next tick and exits without
    /// waiting out any timeout.
    #[test]
    fn shutdown_with_pending_connections_is_prompt() {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let publisher = SocketTransport::connect_tcp(server.addr());
        publisher.publish(big_ckpt(0, 1)).unwrap();

        // idle registered connection
        let idle = TcpStream::connect(server.addr()).unwrap();
        // torn mid-frame
        let mut torn = TcpStream::connect(server.addr()).unwrap();
        torn.write_all(&[9u8, 0]).unwrap();
        // reply parked on POLLOUT (8 MB response, reader never drains)
        let mut parked = TcpStream::connect(server.addr()).unwrap();
        write_frame(&mut parked, &latest_request(0)).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        assert!(server.active_connections() >= 3);

        let t0 = std::time::Instant::now();
        drop(server);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "shutdown with pending connections took {:?}",
            t0.elapsed()
        );
        drop((idle, torn, parked));
    }

    /// A thread-pool-era client — blocking `write_frame`/`read_frame`,
    /// several sequential requests on ONE connection, then a pipelined
    /// burst — must interoperate unchanged, byte-for-byte.
    #[test]
    fn legacy_blocking_client_interops_unchanged() {
        use std::io::Read as _;

        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let publisher = SocketTransport::connect_tcp(server.addr());
        publisher.publish(ckpt(2, 4, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();
        let store = server.store().clone();

        let mut legacy = TcpStream::connect(server.addr()).unwrap();
        let members_req = vec![OP_MEMBERS];
        let steps_req = vec![OP_STEPS];
        // sequential request/response, exactly like the old pool client
        for req in [&members_req, &steps_req, &latest_request(2)] {
            write_frame(&mut legacy, req).unwrap();
            let reply = read_frame(&mut legacy).unwrap().expect("server hung up");
            assert_eq!(
                reply,
                handle_request(store.as_ref(), req),
                "legacy blocking roundtrip diverged"
            );
        }

        // pipelined burst: both requests on the wire before any read;
        // replies come back complete and in order
        write_frame(&mut legacy, &members_req).unwrap();
        write_frame(&mut legacy, &steps_req).unwrap();
        let first = read_frame(&mut legacy).unwrap().unwrap();
        let second = read_frame(&mut legacy).unwrap().unwrap();
        assert_eq!(first, handle_request(store.as_ref(), &members_req));
        assert_eq!(second, handle_request(store.as_ref(), &steps_req));

        // and a torn pipelined tail (half a frame, then EOF) costs only
        // this connection
        write_frame(&mut legacy, &members_req).unwrap();
        legacy.write_all(&[44u8, 0]).unwrap();
        let reply = read_frame(&mut legacy).unwrap().unwrap();
        assert_eq!(reply, handle_request(store.as_ref(), &members_req));
        drop(legacy);
        assert_eq!(
            SocketTransport::connect_tcp(server.addr()).members().unwrap(),
            vec![2]
        );
    }

    /// `bind_tcp_over` a codec'd spool: DELTA windows stream from their
    /// encoded pread ranges (tagged frames on the wire) and a delta
    /// reader installs byte-identically to a direct spool read.
    #[test]
    fn server_over_spool_serves_encoded_windows() {
        use crate::codistill::transport::{DeltaCache, SpoolDir};

        let dir = std::env::temp_dir().join(format!(
            "codistill_spool_gateway_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let spool =
            Arc::new(SpoolDir::open(&dir, 4).unwrap().with_codec(Codec::Shuffle));
        let server =
            SocketServer::bind_tcp_over("127.0.0.1:0", spool.clone(), 8).unwrap();

        // constant-valued hot window so the shuffle codec engages
        let gateway_ckpt = |step: u64, v: f32| {
            let mut params = TensorMap::new();
            params.insert("params.hot", Tensor::f32(&[256], vec![v; 256]).unwrap());
            params.insert("params.cold", Tensor::f32(&[256], vec![0.5; 256]).unwrap());
            Checkpoint::new(0, step, params)
        };
        let publisher = SocketTransport::connect_tcp(server.addr());
        publisher.publish(gateway_ckpt(1, 1.0)).unwrap();

        let coded = SocketTransport::connect_tcp(server.addr()).with_codec(Codec::Shuffle);
        let mut cache = DeltaCache::new().with_codec(Codec::Shuffle);
        let a = cache.latest(&coded, 0).unwrap().unwrap();
        let direct = spool.latest(0).unwrap().unwrap();
        assert_eq!(a.flat().data(), direct.flat().data());

        // second publication: the delta reply's moved window arrives
        // encoded (streamed off the CKPT0004 pread range, never decoded
        // server-side)
        publisher.publish(gateway_ckpt(2, 2.0)).unwrap();
        let basis = Basis {
            step: 1,
            digests: a.window_digests().as_ref().clone(),
        };
        let res = coded
            .fetch(&crate::codistill::transport::FetchSpec::full(0, u64::MAX).with_basis(basis))
            .unwrap()
            .unwrap();
        assert_eq!(res.unchanged, vec!["params.cold".to_string()]);
        assert_eq!(res.windows.len(), 1);
        assert_eq!(
            res.windows[0].codec(),
            Codec::Shuffle,
            "gateway decoded the spool's encoded range instead of streaming it"
        );
        assert_eq!(res.windows[0].to_f32().unwrap(), vec![2.0; 256]);

        // the delta cache over the gateway stays byte-identical too
        let b = cache.latest(&coded, 0).unwrap().unwrap();
        assert_eq!(b.flat().data(), spool.latest(0).unwrap().unwrap().flat().data());
        drop(server);
        std::fs::remove_dir_all(&dir).ok();
    }
}

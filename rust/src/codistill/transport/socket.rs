//! The socket backend: checkpoint exchange over a length-prefixed
//! request/response protocol (TCP or Unix domain sockets).
//!
//! A [`SocketServer`] owns an [`InProcess`] store and answers requests
//! from any number of [`SocketTransport`] clients — the server process is
//! the paper's "parameter checkpoint service", clients are coordinator
//! processes hosting members.
//!
//! ## Wire format
//!
//! Every message is one frame: `u32 LE payload length` + payload. A
//! request payload is `opcode u8` + body; a response payload is
//! `status u8` (0 = ok, 1 = not found, 2 = error + utf8 message) + body.
//! Integers are LE; names/shapes/tensors reuse the `CKPT0002` encodings
//! from `codistill::store`, and a full checkpoint travels as the exact
//! bytes [`Checkpoint::write_to`] produces.
//!
//! | op | request body | ok-response body |
//! |----|--------------|------------------|
//! | 1 `PUBLISH`  | checkpoint stream | — |
//! | 2 `LATEST`   | member u64, max_step u64 | checkpoint stream |
//! | 3 `FETCH`    | member u64, max_step u64, n u32, names | member, step, windows (name, shape, elems u64, f32 data) |
//! | 4 `DESCRIBE` | member u64, max_step u64 | member, step, window table, residual tensors |
//! | 5 `MEMBERS`  | — | n u64, member u64s |
//! | 6 `GC`       | — | — |
//! | 7 `STEPS`    | — | n u64, (member u64, step u64) pairs |
//! | 8 `DELTA`    | member u64, max_step u64, basis u8 [step u64, n u64, digests u64s], sel u8 [n u32, names] | member, step, window+digest table (n u64; name, shape, digest u64), changed windows (n u32; name, shape, elems u64, f32 data), unchanged names (n u32; names), residual tensors (n u64; frames) |
//!
//! `STEPS` is the liveness heartbeat: the freshest published step per
//! member with no checkpoint payload attached, so a coordinator can poll
//! it on every reload without moving planes.
//!
//! `DELTA` is the one read the client's [`ExchangeTransport::fetch`]
//! speaks: the request carries an optional delta basis (`basis u8` = 1 ⇒
//! installed step + per-window digest vector) and a window selection
//! (`sel u8` = 0 ⇒ whole plane, 1 ⇒ named windows), and the response
//! returns only the windows whose content digest differs from the basis,
//! plus the full window+digest table and the names skipped as unchanged —
//! the server-side twin of `transport::fetch_from_checkpoint`. `LATEST` /
//! `FETCH` / `DESCRIBE` remain for older readers and for the windowed
//! reassembly mode below.
//!
//! ## Concurrency
//!
//! The server is thread-per-connection behind a blocking accept: each
//! accepted connection is served on its own worker thread (bounded by
//! [`MAX_CONNECTIONS`]; further accepts wait for a free slot), so a slow
//! or wedged client stalls only its own connection while other clients
//! keep publishing and fetching. An idle server burns no CPU — the accept
//! blocks in the kernel, and shutdown wakes it with a loopback connect
//! instead of a poll loop. Request handling errors are isolated per
//! connection: a malformed frame ends that connection, never the server.
//!
//! ## Sharded (windowed) fetch
//!
//! `FETCH` moves only the named windows of the publisher's plane. A
//! client built `with_windowed_fetch(batch)` reloads teachers without
//! ever pulling the whole plane in one response: `DESCRIBE` returns the
//! window table (names + shapes, no payload), then the client issues
//! `FETCH`es of `batch` windows at a time — **pinned to the described
//! step** so a concurrent publish can never produce a torn plane — and
//! reassembles the checkpoint locally. The reassembled bytes are
//! identical to the full-plane pull; only the fetch granularity changes.

use crate::codistill::store::{
    read_framed_tensor, read_name, read_shape, read_u32, read_u64, write_f32s, write_i32s,
    write_name, write_shape, Checkpoint,
};
use crate::codistill::transport::{
    fetch_from_checkpoint, windows_from_checkpoint, Basis, ExchangeTransport, FetchResult,
    FetchSpec, FetchedWindow, InProcess, TransportKind, WindowSel, WindowedFetch,
};
use crate::runtime::flat::{FlatBuffer, FlatLayout};
use crate::runtime::{Tensor, TensorMap};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

const OP_PUBLISH: u8 = 1;
const OP_LATEST: u8 = 2;
const OP_FETCH: u8 = 3;
const OP_DESCRIBE: u8 = 4;
const OP_MEMBERS: u8 = 5;
const OP_GC: u8 = 6;
const OP_STEPS: u8 = 7;
const OP_DELTA: u8 = 8;

/// Bound on concurrently served connections: accepts past the cap wait
/// for a worker slot to free instead of spawning unboundedly.
pub const MAX_CONNECTIONS: usize = 64;

const STATUS_OK: u8 = 0;
const STATUS_NONE: u8 = 1;
const STATUS_ERR: u8 = 2;

/// Largest accepted frame (1 GiB): a cap on corrupt length prefixes, far
/// above any real checkpoint in this repo.
const MAX_FRAME: usize = 1 << 30;

/// Read timeout on both sides of the wire: a wedged client cannot stall
/// the server's accept loop, and a dead server turns a client operation
/// into an error instead of a hang.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

// ------------------------------------------------------------------- frames

fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    // Enforce the cap on the send side too: a u32 prefix cannot frame a
    // larger payload, and a silent truncation would desync the protocol.
    if payload.len() > MAX_FRAME {
        bail!(
            "frame of {} bytes exceeds the {MAX_FRAME}-byte cap (checkpoint too large for one frame)",
            payload.len()
        );
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame; `Ok(None)` on a clean EOF before any length byte.
fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    if let Err(e) = r.read_exact(&mut len) {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            return Ok(None);
        }
        return Err(e.into());
    }
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME {
        bail!("frame of {n} bytes exceeds the {MAX_FRAME}-byte cap");
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(Some(buf))
}

/// Guard a wire-supplied element count against the bytes actually left
/// in the frame (each element needs at least `min_bytes` of encoding): a
/// malformed count becomes a protocol error on this connection, never a
/// huge `Vec::with_capacity` that could panic the worker or abort the
/// process.
fn checked_count(n: usize, remaining: usize, min_bytes: usize, what: &str) -> Result<usize> {
    if n > remaining / min_bytes.max(1) {
        bail!("frame claims {n} {what} but only {remaining} bytes remain");
    }
    Ok(n)
}

fn write_framed_tensor(w: &mut impl Write, name: &str, t: &Tensor) -> Result<()> {
    write_name(w, name)?;
    write_shape(w, t.shape())?;
    match t {
        Tensor::F32 { data, .. } => {
            w.write_all(&[0u8])?;
            write_f32s(w, data)?;
        }
        Tensor::I32 { data, .. } => {
            w.write_all(&[1u8])?;
            write_i32s(w, data)?;
        }
    }
    Ok(())
}

// ------------------------------------------------------------------- server

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

/// Counting semaphore over connection-worker slots (bounded accept pool).
struct ConnPool {
    active: std::sync::Mutex<usize>,
    freed: std::sync::Condvar,
}

impl ConnPool {
    fn new() -> Self {
        ConnPool {
            active: std::sync::Mutex::new(0),
            freed: std::sync::Condvar::new(),
        }
    }

    /// Block until a worker slot is free, then claim it; `None` once
    /// shutdown is requested (a full pool must not wedge the accept
    /// thread past shutdown — the loopback wakeup cannot reach a loop
    /// that is waiting here, so the wait polls the flag). The returned
    /// guard releases the slot on drop (worker exit — or the spawn
    /// failing, which drops the closure holding the guard).
    fn acquire(pool: &Arc<ConnPool>, shutdown: &AtomicBool) -> Option<ConnSlot> {
        let mut n = pool.active.lock().unwrap();
        while *n >= MAX_CONNECTIONS {
            if shutdown.load(Ordering::SeqCst) {
                return None;
            }
            let (guard, _timed_out) = pool
                .freed
                .wait_timeout(n, Duration::from_millis(100))
                .unwrap();
            n = guard;
        }
        *n += 1;
        Some(ConnSlot(pool.clone()))
    }

    fn active(&self) -> usize {
        *self.active.lock().unwrap()
    }
}

struct ConnSlot(Arc<ConnPool>);

impl Drop for ConnSlot {
    fn drop(&mut self) {
        let mut n = self.0.active.lock().unwrap();
        *n -= 1;
        drop(n);
        self.0.freed.notify_one();
    }
}

/// Serves an [`InProcess`] store over the wire protocol: a blocking
/// accept loop on a background thread hands each connection to its own
/// worker thread (see the module's Concurrency section). Dropping the
/// server shuts the accept loop down; lingering connection workers exit
/// at their next frame boundary (or read timeout).
pub struct SocketServer {
    addr: String,
    store: Arc<InProcess>,
    shutdown: Arc<AtomicBool>,
    pool: Arc<ConnPool>,
    handle: Option<std::thread::JoinHandle<()>>,
    /// Unix-socket path to unlink on shutdown.
    unlink: Option<PathBuf>,
}

impl SocketServer {
    /// Bind a TCP endpoint (`"127.0.0.1:0"` picks a free port; the
    /// resolved address is [`SocketServer::addr`]).
    pub fn bind_tcp(addr: &str, history: usize) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding tcp {addr}"))?;
        let resolved = listener.local_addr()?.to_string();
        Self::spawn(Listener::Tcp(listener), resolved, history, None)
    }

    /// Bind a Unix-domain socket at `path` (any stale socket file is
    /// replaced).
    #[cfg(unix)]
    pub fn bind_unix(path: &Path, history: usize) -> Result<Self> {
        std::fs::remove_file(path).ok();
        let listener = UnixListener::bind(path)
            .with_context(|| format!("binding unix socket {}", path.display()))?;
        Self::spawn(
            Listener::Unix(listener),
            path.display().to_string(),
            history,
            Some(path.to_path_buf()),
        )
    }

    fn spawn(
        listener: Listener,
        addr: String,
        history: usize,
        unlink: Option<PathBuf>,
    ) -> Result<Self> {
        let store = Arc::new(InProcess::new(history));
        let shutdown = Arc::new(AtomicBool::new(false));
        let pool = Arc::new(ConnPool::new());
        let thread_store = store.clone();
        let thread_shutdown = shutdown.clone();
        let thread_pool = pool.clone();
        let handle = std::thread::Builder::new()
            .name("ckpt-exchange-accept".into())
            .spawn(move || accept_loop(listener, thread_store, thread_shutdown, thread_pool))?;
        Ok(SocketServer {
            addr,
            store,
            shutdown,
            pool,
            handle: Some(handle),
            unlink,
        })
    }

    /// The resolved endpoint: `host:port` for TCP, the path for Unix.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Connections currently held by worker threads (observability for
    /// the concurrency tests; racy by nature).
    pub fn active_connections(&self) -> usize {
        self.pool.active()
    }

    /// The store behind the endpoint (the server process's own members
    /// can exchange through it zero-copy while remote members use the
    /// wire).
    pub fn store(&self) -> &Arc<InProcess> {
        &self.store
    }

    /// Wake the blocking accept so it can observe the shutdown flag.
    fn wake_accept(&self) {
        match &self.unlink {
            #[cfg(unix)]
            Some(path) => {
                UnixStream::connect(path).ok();
            }
            #[cfg(not(unix))]
            Some(_) => {}
            None => {
                TcpStream::connect(&self.addr).ok();
            }
        }
    }
}

impl Drop for SocketServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.wake_accept();
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
        if let Some(p) = &self.unlink {
            std::fs::remove_file(p).ok();
        }
    }
}

/// Blocking accept loop: claim a worker slot (bounded pool), accept, hand
/// the connection to a worker thread. No polling — an idle server sits in
/// the kernel's accept until a client (or the shutdown wakeup) connects.
fn accept_loop(
    listener: Listener,
    store: Arc<InProcess>,
    shutdown: Arc<AtomicBool>,
    pool: Arc<ConnPool>,
) {
    loop {
        // Claim the slot before accepting so the pool bound also bounds
        // accepted-but-unserved sockets.
        let slot = match ConnPool::acquire(&pool, &shutdown) {
            Some(slot) => slot,
            None => return,
        };
        let conn = match &listener {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Conn::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().map(|(s, _)| Conn::Unix(s)),
        };
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match conn {
            Ok(conn) => {
                let store = store.clone();
                let shutdown = shutdown.clone();
                // Spawn failure drops the closure (and with it the slot
                // guard and the connection) — the server itself survives.
                std::thread::Builder::new()
                    .name("ckpt-exchange-conn".into())
                    .spawn(move || {
                        let _slot = slot;
                        serve_connection(conn, &store, &shutdown);
                    })
                    .ok();
            }
            Err(_) => {
                // Transient accept failure (EMFILE, aborted handshake):
                // release the slot and retry without spinning hot. The
                // shutdown check above still runs each iteration, so a
                // persistently failing accept cannot outlive the server.
                drop(slot);
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

/// Serve one connection until EOF, timeout, error, or shutdown. Errors
/// are isolated here: they end this connection and nothing else.
fn serve_connection(mut conn: Conn, store: &InProcess, shutdown: &AtomicBool) {
    let _ = match &mut conn {
        Conn::Tcp(s) => s.set_read_timeout(Some(READ_TIMEOUT)),
        #[cfg(unix)]
        Conn::Unix(s) => s.set_read_timeout(Some(READ_TIMEOUT)),
    };
    while !shutdown.load(Ordering::SeqCst) {
        match read_frame(&mut conn) {
            Ok(Some(request)) => {
                let response = handle_request(store, &request);
                if write_frame(&mut conn, &response).is_err() {
                    return;
                }
            }
            // Clean EOF, read timeout, or a torn frame: drop the
            // connection, keep the server.
            Ok(None) | Err(_) => return,
        }
    }
}

/// Dispatch one request payload; never panics the server thread — every
/// failure becomes a `STATUS_ERR` response.
fn handle_request(store: &InProcess, payload: &[u8]) -> Vec<u8> {
    match try_handle(store, payload) {
        Ok(response) => response,
        Err(e) => {
            let mut out = vec![STATUS_ERR];
            out.extend_from_slice(format!("{e:#}").as_bytes());
            out
        }
    }
}

fn try_handle(store: &InProcess, payload: &[u8]) -> Result<Vec<u8>> {
    let mut r = payload;
    let mut op = [0u8; 1];
    r.read_exact(&mut op)?;
    match op[0] {
        OP_PUBLISH => {
            let ckpt = Checkpoint::read_from(&mut r)?;
            store.publish(ckpt)?;
            Ok(vec![STATUS_OK])
        }
        OP_LATEST => {
            let member = read_u64(&mut r)? as usize;
            let max_step = read_u64(&mut r)?;
            match store.latest_at_most(member, max_step) {
                Some(ckpt) => {
                    let mut out = vec![STATUS_OK];
                    ckpt.write_to(&mut out)?;
                    Ok(out)
                }
                None => Ok(vec![STATUS_NONE]),
            }
        }
        OP_FETCH => {
            let member = read_u64(&mut r)? as usize;
            let max_step = read_u64(&mut r)?;
            let n = checked_count(read_u32(&mut r)? as usize, r.len(), 4, "names")?;
            let mut names = Vec::with_capacity(n);
            for _ in 0..n {
                names.push(read_name(&mut r)?);
            }
            match store.latest_at_most(member, max_step) {
                Some(ckpt) => {
                    let fetch = windows_from_checkpoint(&ckpt, &names)?;
                    let mut out = vec![STATUS_OK];
                    out.extend_from_slice(&(fetch.member as u64).to_le_bytes());
                    out.extend_from_slice(&fetch.step.to_le_bytes());
                    out.extend_from_slice(&(fetch.windows.len() as u32).to_le_bytes());
                    for w in &fetch.windows {
                        write_name(&mut out, &w.name)?;
                        write_shape(&mut out, &w.shape)?;
                        out.extend_from_slice(&(w.data.len() as u64).to_le_bytes());
                        write_f32s(&mut out, &w.data)?;
                    }
                    Ok(out)
                }
                None => Ok(vec![STATUS_NONE]),
            }
        }
        OP_DESCRIBE => {
            let member = read_u64(&mut r)? as usize;
            let max_step = read_u64(&mut r)?;
            match store.latest_at_most(member, max_step) {
                Some(ckpt) => {
                    let mut out = vec![STATUS_OK];
                    out.extend_from_slice(&(ckpt.member as u64).to_le_bytes());
                    out.extend_from_slice(&ckpt.step.to_le_bytes());
                    let layout = ckpt.flat().layout();
                    out.extend_from_slice(&(layout.len() as u64).to_le_bytes());
                    for e in layout.entries() {
                        write_name(&mut out, &e.name)?;
                        write_shape(&mut out, &e.shape)?;
                    }
                    let residual = ckpt.residual().prefix_entries("");
                    out.extend_from_slice(&(residual.len() as u64).to_le_bytes());
                    for (name, t) in residual {
                        write_framed_tensor(&mut out, name, t)?;
                    }
                    Ok(out)
                }
                None => Ok(vec![STATUS_NONE]),
            }
        }
        OP_MEMBERS => {
            let members = store.members();
            let mut out = vec![STATUS_OK];
            out.extend_from_slice(&(members.len() as u64).to_le_bytes());
            for m in members {
                out.extend_from_slice(&(m as u64).to_le_bytes());
            }
            Ok(out)
        }
        OP_GC => {
            ExchangeTransport::gc(store)?;
            Ok(vec![STATUS_OK])
        }
        OP_STEPS => {
            let steps = store.last_steps();
            let mut out = vec![STATUS_OK];
            out.extend_from_slice(&(steps.len() as u64).to_le_bytes());
            for (m, s) in steps {
                out.extend_from_slice(&(m as u64).to_le_bytes());
                out.extend_from_slice(&s.to_le_bytes());
            }
            Ok(out)
        }
        OP_DELTA => {
            let member = read_u64(&mut r)? as usize;
            let max_step = read_u64(&mut r)?;
            let mut flag = [0u8; 1];
            r.read_exact(&mut flag)?;
            let basis = match flag[0] {
                0 => None,
                1 => {
                    let step = read_u64(&mut r)?;
                    let n = checked_count(read_u64(&mut r)? as usize, r.len(), 8, "digests")?;
                    let mut digests = Vec::with_capacity(n);
                    for _ in 0..n {
                        digests.push(read_u64(&mut r)?);
                    }
                    Some(Basis { step, digests })
                }
                other => bail!("bad basis flag {other}"),
            };
            r.read_exact(&mut flag)?;
            let windows = match flag[0] {
                0 => WindowSel::All,
                1 => {
                    let n = checked_count(read_u32(&mut r)? as usize, r.len(), 4, "names")?;
                    let mut names = Vec::with_capacity(n);
                    for _ in 0..n {
                        names.push(read_name(&mut r)?);
                    }
                    WindowSel::Named(names)
                }
                other => bail!("bad window selection flag {other}"),
            };
            let spec = FetchSpec {
                member,
                max_step,
                basis,
                windows,
            };
            // The server IS an InProcess store: answer with its native
            // fetch so this path can never diverge from the reference
            // backend.
            match ExchangeTransport::fetch(store, &spec)? {
                Some(res) => {
                    let mut out = vec![STATUS_OK];
                    out.extend_from_slice(&(res.member as u64).to_le_bytes());
                    out.extend_from_slice(&res.step.to_le_bytes());
                    out.extend_from_slice(&(res.parts.len() as u64).to_le_bytes());
                    for ((name, shape), d) in res.parts.iter().zip(&res.digests) {
                        write_name(&mut out, name)?;
                        write_shape(&mut out, shape)?;
                        out.extend_from_slice(&d.to_le_bytes());
                    }
                    // A zero-copy full hand-off has no wire analogue:
                    // expand it into windows straight off the shared plane.
                    match &res.full {
                        Some(ck) => {
                            let flat = ck.flat();
                            let entries = flat.layout().entries();
                            out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
                            for e in entries {
                                write_name(&mut out, &e.name)?;
                                write_shape(&mut out, &e.shape)?;
                                out.extend_from_slice(&(e.len as u64).to_le_bytes());
                                write_f32s(&mut out, &flat.data()[e.range()])?;
                            }
                        }
                        None => {
                            out.extend_from_slice(&(res.windows.len() as u32).to_le_bytes());
                            for w in &res.windows {
                                write_name(&mut out, &w.name)?;
                                write_shape(&mut out, &w.shape)?;
                                out.extend_from_slice(&(w.data.len() as u64).to_le_bytes());
                                write_f32s(&mut out, &w.data)?;
                            }
                        }
                    }
                    out.extend_from_slice(&(res.unchanged.len() as u32).to_le_bytes());
                    for name in &res.unchanged {
                        write_name(&mut out, name)?;
                    }
                    let residual = res.residual.prefix_entries("");
                    out.extend_from_slice(&(residual.len() as u64).to_le_bytes());
                    for (name, t) in residual {
                        write_framed_tensor(&mut out, name, t)?;
                    }
                    Ok(out)
                }
                None => Ok(vec![STATUS_NONE]),
            }
        }
        other => bail!("unknown opcode {other}"),
    }
}

// ------------------------------------------------------------------- client

enum Target {
    Tcp(String),
    #[cfg(unix)]
    Unix(PathBuf),
}

/// Window table + residual of a published checkpoint, as returned by
/// `DESCRIBE` — the metadata a sharded reload needs before fetching.
struct Description {
    member: usize,
    step: u64,
    parts: Vec<(String, Vec<usize>)>,
    residual: TensorMap,
}

/// Client endpoint of the wire protocol (one request/response connection
/// per operation — the exchange cadence is seconds, not microseconds).
pub struct SocketTransport {
    target: Target,
    /// `Some(batch)`: `latest`/`latest_at_most` reassemble the plane from
    /// windowed fetches of `batch` windows each instead of one full-plane
    /// response.
    windowed: Option<usize>,
    requests: AtomicU64,
    bytes_tx: AtomicU64,
    bytes_rx: AtomicU64,
}

impl SocketTransport {
    /// Connect to a [`SocketServer::bind_tcp`] endpoint (`host:port`).
    pub fn connect_tcp(addr: &str) -> Self {
        SocketTransport {
            target: Target::Tcp(addr.to_string()),
            windowed: None,
            requests: AtomicU64::new(0),
            bytes_tx: AtomicU64::new(0),
            bytes_rx: AtomicU64::new(0),
        }
    }

    /// Connect to a [`SocketServer::bind_unix`] endpoint.
    #[cfg(unix)]
    pub fn connect_unix(path: &Path) -> Self {
        SocketTransport {
            target: Target::Unix(path.to_path_buf()),
            windowed: None,
            requests: AtomicU64::new(0),
            bytes_tx: AtomicU64::new(0),
            bytes_rx: AtomicU64::new(0),
        }
    }

    /// Parse an endpoint spec: `unix:/path/to.sock` or `host:port`.
    pub fn connect(spec: &str) -> Result<Self> {
        #[cfg(unix)]
        if let Some(path) = spec.strip_prefix("unix:") {
            return Ok(Self::connect_unix(Path::new(path)));
        }
        if spec.contains(':') {
            Ok(Self::connect_tcp(spec))
        } else {
            bail!("socket endpoint {spec:?} (want host:port or unix:/path)")
        }
    }

    /// Reload teachers by sharded fetch, `batch` windows per request.
    pub fn with_windowed_fetch(mut self, batch: usize) -> Self {
        self.windowed = Some(batch.max(1));
        self
    }

    /// (requests, bytes sent, bytes received) so far — the numbers the
    /// bench reports and `netsim` prices.
    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.requests.load(Ordering::Relaxed),
            self.bytes_tx.load(Ordering::Relaxed),
            self.bytes_rx.load(Ordering::Relaxed),
        )
    }

    fn open(&self) -> Result<Conn> {
        // A response timeout bounds every operation: a dead server is an
        // error, never a hang.
        match &self.target {
            Target::Tcp(addr) => {
                let s =
                    TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
                s.set_read_timeout(Some(READ_TIMEOUT))?;
                Ok(Conn::Tcp(s))
            }
            #[cfg(unix)]
            Target::Unix(path) => {
                let s = UnixStream::connect(path)
                    .with_context(|| format!("connecting {}", path.display()))?;
                s.set_read_timeout(Some(READ_TIMEOUT))?;
                Ok(Conn::Unix(s))
            }
        }
    }

    /// One request/response round trip. Returns the response body after
    /// the status byte, or `None` for `STATUS_NONE`.
    fn roundtrip(&self, request: &[u8]) -> Result<Option<Vec<u8>>> {
        let mut conn = self.open()?;
        write_frame(&mut conn, request)?;
        let mut response =
            read_frame(&mut conn)?.context("exchange server closed the connection")?;
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.bytes_tx
            .fetch_add(request.len() as u64, Ordering::Relaxed);
        self.bytes_rx
            .fetch_add(response.len() as u64, Ordering::Relaxed);
        if response.is_empty() {
            bail!("empty response frame");
        }
        let status = response.remove(0);
        match status {
            STATUS_OK => Ok(Some(response)),
            STATUS_NONE => Ok(None),
            STATUS_ERR => bail!(
                "exchange server error: {}",
                String::from_utf8_lossy(&response)
            ),
            other => bail!("bad response status {other}"),
        }
    }

    fn describe(&self, member: usize, max_step: u64) -> Result<Option<Description>> {
        let mut req = vec![OP_DESCRIBE];
        req.extend_from_slice(&(member as u64).to_le_bytes());
        req.extend_from_slice(&max_step.to_le_bytes());
        let body = match self.roundtrip(&req)? {
            Some(b) => b,
            None => return Ok(None),
        };
        let mut r = body.as_slice();
        let member = read_u64(&mut r)? as usize;
        let step = read_u64(&mut r)?;
        let n_windows = read_u64(&mut r)? as usize;
        let mut parts = Vec::with_capacity(n_windows);
        for _ in 0..n_windows {
            let name = read_name(&mut r)?;
            let shape = read_shape(&mut r)?;
            parts.push((name, shape));
        }
        let n_residual = read_u64(&mut r)? as usize;
        let mut residual = TensorMap::new();
        for _ in 0..n_residual {
            let (name, t) = read_framed_tensor(&mut r)?;
            residual.insert(name, t);
        }
        Ok(Some(Description {
            member,
            step,
            parts,
            residual,
        }))
    }

    /// Full plane via sharded reassembly: describe, then pull windows in
    /// `batch`-sized `FETCH` requests pinned to the described step, then
    /// hand the reassembled checkpoint over as a zero-copy full result
    /// (digests computed locally — a pure function of the bytes, so they
    /// equal the server's).
    fn windowed_full_fetch(
        &self,
        member: usize,
        max_step: u64,
        batch: usize,
    ) -> Result<Option<FetchResult>> {
        let desc = match self.describe(member, max_step)? {
            Some(d) => d,
            None => return Ok(None),
        };
        let layout = Arc::new(FlatLayout::from_named_shapes(desc.parts.clone()));
        let mut buf = FlatBuffer::zeros(layout.clone());
        let names: Vec<String> = layout.names().map(|s| s.to_string()).collect();
        for chunk in names.chunks(batch) {
            let fetch = self
                .wire_fetch_windows(member, desc.step, chunk)?
                .context("checkpoint pruned between describe and fetch")?;
            if fetch.step != desc.step {
                bail!(
                    "exchange moved from step {} to {} mid-fetch",
                    desc.step,
                    fetch.step
                );
            }
            for w in &fetch.windows {
                buf.write_window(&w.name, &w.data)?;
            }
        }
        let digests = buf.window_digests();
        let ckpt = Arc::new(Checkpoint::from_flat(
            desc.member,
            desc.step,
            Arc::new(buf),
            desc.residual.clone(),
        ));
        Ok(Some(FetchResult {
            member: desc.member,
            step: desc.step,
            parts: desc.parts,
            digests,
            windows: Vec::new(),
            unchanged: Vec::new(),
            residual: desc.residual,
            full: Some(ckpt),
        }))
    }

    /// The raw `FETCH` wire op: named windows of the freshest checkpoint
    /// within `max_step`, in request order.
    fn wire_fetch_windows(
        &self,
        member: usize,
        max_step: u64,
        names: &[String],
    ) -> Result<Option<WindowedFetch>> {
        let mut req = vec![OP_FETCH];
        req.extend_from_slice(&(member as u64).to_le_bytes());
        req.extend_from_slice(&max_step.to_le_bytes());
        req.extend_from_slice(&(names.len() as u32).to_le_bytes());
        for name in names {
            write_name(&mut req, name)?;
        }
        let body = match self.roundtrip(&req)? {
            Some(b) => b,
            None => return Ok(None),
        };
        let mut r = body.as_slice();
        let member = read_u64(&mut r)? as usize;
        let step = read_u64(&mut r)?;
        let n = checked_count(read_u32(&mut r)? as usize, r.len(), 16, "windows")?;
        let mut windows = Vec::with_capacity(n);
        for _ in 0..n {
            let name = read_name(&mut r)?;
            let shape = read_shape(&mut r)?;
            let elems = checked_count(read_u64(&mut r)? as usize, r.len(), 4, "f32s")?;
            let mut data = vec![0f32; elems];
            crate::codistill::store::read_f32s(&mut r, &mut data)?;
            windows.push(FetchedWindow { name, shape, data });
        }
        Ok(Some(WindowedFetch {
            member,
            step,
            windows,
        }))
    }
}

impl ExchangeTransport for SocketTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::Socket
    }

    fn publish(&self, ckpt: Checkpoint) -> Result<()> {
        let mut req = vec![OP_PUBLISH];
        ckpt.write_to(&mut req)?;
        self.roundtrip(&req)?
            .context("publish returned not-found")?;
        Ok(())
    }

    /// The one native read: a full no-basis fetch pulls the whole
    /// checkpoint in one `LATEST` stream (or reassembles it window by
    /// window in windowed mode); anything else — a delta basis or a named
    /// scope — is one `DELTA` round trip moving only changed windows.
    fn fetch(&self, spec: &FetchSpec) -> Result<Option<FetchResult>> {
        if spec.basis.is_none() {
            if let WindowSel::All = spec.windows {
                if let Some(batch) = self.windowed {
                    return self.windowed_full_fetch(spec.member, spec.max_step, batch);
                }
                // Whole checkpoint as one CKPT0003 stream: the digest
                // table rides the header, verified on read.
                let mut req = vec![OP_LATEST];
                req.extend_from_slice(&(spec.member as u64).to_le_bytes());
                req.extend_from_slice(&spec.max_step.to_le_bytes());
                let ckpt = match self.roundtrip(&req)? {
                    Some(body) => Arc::new(Checkpoint::read_from(&mut body.as_slice())?),
                    None => return Ok(None),
                };
                return Ok(Some(fetch_from_checkpoint(
                    &ckpt,
                    &FetchSpec::full(spec.member, spec.max_step),
                )?));
            }
        }
        let mut req = vec![OP_DELTA];
        req.extend_from_slice(&(spec.member as u64).to_le_bytes());
        req.extend_from_slice(&spec.max_step.to_le_bytes());
        match &spec.basis {
            Some(b) => {
                req.push(1);
                req.extend_from_slice(&b.step.to_le_bytes());
                req.extend_from_slice(&(b.digests.len() as u64).to_le_bytes());
                for d in &b.digests {
                    req.extend_from_slice(&d.to_le_bytes());
                }
            }
            None => req.push(0),
        }
        match &spec.windows {
            WindowSel::All => req.push(0),
            WindowSel::Named(names) => {
                req.push(1);
                req.extend_from_slice(&(names.len() as u32).to_le_bytes());
                for name in names {
                    write_name(&mut req, name)?;
                }
            }
        }
        let body = match self.roundtrip(&req)? {
            Some(b) => b,
            None => return Ok(None),
        };
        let mut r = body.as_slice();
        let member = read_u64(&mut r)? as usize;
        let step = read_u64(&mut r)?;
        // The counts below come off the wire too: bound them against the
        // bytes actually present so a garbled response is an error, not
        // an absurd allocation.
        let n_parts = checked_count(read_u64(&mut r)? as usize, r.len(), 16, "windows")?;
        let mut parts = Vec::with_capacity(n_parts);
        let mut digests = Vec::with_capacity(n_parts);
        for _ in 0..n_parts {
            let name = read_name(&mut r)?;
            let shape = read_shape(&mut r)?;
            parts.push((name, shape));
            digests.push(read_u64(&mut r)?);
        }
        let n_changed = checked_count(read_u32(&mut r)? as usize, r.len(), 16, "windows")?;
        let mut windows = Vec::with_capacity(n_changed);
        for _ in 0..n_changed {
            let name = read_name(&mut r)?;
            let shape = read_shape(&mut r)?;
            let elems = checked_count(read_u64(&mut r)? as usize, r.len(), 4, "f32s")?;
            let mut data = vec![0f32; elems];
            crate::codistill::store::read_f32s(&mut r, &mut data)?;
            windows.push(FetchedWindow { name, shape, data });
        }
        let n_unchanged = checked_count(read_u32(&mut r)? as usize, r.len(), 4, "names")?;
        let mut unchanged = Vec::with_capacity(n_unchanged);
        for _ in 0..n_unchanged {
            unchanged.push(read_name(&mut r)?);
        }
        let n_residual = read_u64(&mut r)? as usize;
        let mut residual = TensorMap::new();
        for _ in 0..n_residual {
            let (name, t) = read_framed_tensor(&mut r)?;
            residual.insert(name, t);
        }
        Ok(Some(FetchResult {
            member,
            step,
            parts,
            digests,
            windows,
            unchanged,
            residual,
            full: None,
        }))
    }

    fn members(&self) -> Result<Vec<usize>> {
        let body = self
            .roundtrip(&[OP_MEMBERS])?
            .context("members returned not-found")?;
        let mut r = body.as_slice();
        let n = read_u64(&mut r)? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(read_u64(&mut r)? as usize);
        }
        Ok(out)
    }

    fn last_steps(&self) -> Result<Vec<(usize, u64)>> {
        let body = self
            .roundtrip(&[OP_STEPS])?
            .context("steps returned not-found")?;
        let mut r = body.as_slice();
        let n = read_u64(&mut r)? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let m = read_u64(&mut r)? as usize;
            let s = read_u64(&mut r)?;
            out.push((m, s));
        }
        Ok(out)
    }

    fn gc(&self) -> Result<()> {
        self.roundtrip(&[OP_GC])?.context("gc returned not-found")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ckpt(member: usize, step: u64, vals: &[f32]) -> Checkpoint {
        let mut params = TensorMap::new();
        params.insert("params.a", Tensor::f32(&[2], vals[..2].to_vec()).unwrap());
        params.insert("params.b", Tensor::f32(&[3], vals[2..5].to_vec()).unwrap());
        params.insert("params.ids", Tensor::i32(&[2], vec![4, 2]).unwrap());
        Checkpoint::new(member, step, params)
    }

    #[test]
    fn tcp_roundtrip_full_plane() {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let client = SocketTransport::connect_tcp(server.addr());

        assert!(client.latest(0).unwrap().is_none());
        client.publish(ckpt(0, 5, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();
        client.publish(ckpt(0, 9, &[6.0, 7.0, 8.0, 9.0, 10.0])).unwrap();

        let c = client.latest(0).unwrap().unwrap();
        assert_eq!(c.step, 9);
        assert_eq!(c.flat().view("params.a").unwrap(), &[6.0, 7.0]);
        // residual (i32) leaves survive the wire
        assert_eq!(
            c.params().get("params.ids").unwrap().as_i32().unwrap(),
            &[4, 2]
        );
        // staleness bound
        assert_eq!(client.latest_at_most(0, 5).unwrap().unwrap().step, 5);
        assert!(client.latest_at_most(0, 4).unwrap().is_none());
        assert_eq!(client.members().unwrap(), vec![0]);
        client.gc().unwrap();

        // server-side store saw the same bytes (no re-encode drift)
        let direct = server.store().latest(0).unwrap();
        assert_eq!(direct.flat().data(), c.flat().data());
    }

    #[test]
    fn tcp_windowed_fetch_and_reassembly() {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let publisher = SocketTransport::connect_tcp(server.addr());
        publisher.publish(ckpt(1, 3, &[1.5, 2.5, 3.5, 4.5, 5.5])).unwrap();

        // raw sharded fetch: one window only
        let reader = SocketTransport::connect_tcp(server.addr());
        let f = reader
            .fetch_windows(1, u64::MAX, &["params.b".to_string()])
            .unwrap()
            .unwrap();
        assert_eq!(f.step, 3);
        assert_eq!(f.windows[0].data, vec![3.5, 4.5, 5.5]);
        assert_eq!(f.payload_bytes(), 12);

        // windowed reload reassembles the identical checkpoint
        let windowed = SocketTransport::connect_tcp(server.addr()).with_windowed_fetch(1);
        let via_windows = windowed.latest(1).unwrap().unwrap();
        let via_plane = reader.latest(1).unwrap().unwrap();
        assert_eq!(via_windows.step, via_plane.step);
        assert_eq!(via_windows.flat().data(), via_plane.flat().data());
        assert!(via_windows
            .flat()
            .layout()
            .same_plane(via_plane.flat().layout()));
        assert_eq!(
            via_windows.params().get("params.ids").unwrap().as_i32().unwrap(),
            &[4, 2]
        );

        // the windowed client paid per-window requests, never one big pull
        let (reqs, _tx, rx) = windowed.stats();
        assert!(reqs >= 3, "describe + >=2 window fetches, got {reqs}");
        assert!(rx > 0);
    }

    #[test]
    fn server_reports_errors_not_hangs() {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let client = SocketTransport::connect_tcp(server.addr());
        client.publish(ckpt(0, 10, &[0.0; 5])).unwrap();
        // step regression is rejected through the wire with the store's
        // message, and the connection/server stay healthy
        let err = client.publish(ckpt(0, 4, &[0.0; 5])).unwrap_err();
        assert!(format!("{err:#}").contains("published step"), "{err:#}");
        assert_eq!(client.members().unwrap(), vec![0]);
        // unknown window error round-trips too
        let err = client
            .fetch_windows(0, u64::MAX, &["params.nope".to_string()])
            .unwrap_err();
        assert!(format!("{err:#}").contains("no window"), "{err:#}");
    }

    #[test]
    fn delta_opcode_moves_only_changed_windows() {
        use crate::codistill::transport::Basis;
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let client = SocketTransport::connect_tcp(server.addr());
        client.publish(ckpt(0, 1, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();
        let v1 = client.latest(0).unwrap().unwrap();
        let basis = Basis {
            step: 1,
            digests: v1.window_digests().as_ref().clone(),
        };
        // params.b changes, params.a does not
        client.publish(ckpt(0, 2, &[1.0, 2.0, 9.0, 9.0, 9.0])).unwrap();
        let res = client
            .fetch(&FetchSpec::full(0, u64::MAX).with_basis(basis.clone()))
            .unwrap()
            .unwrap();
        assert_eq!(res.step, 2);
        assert!(res.full.is_none());
        assert_eq!(res.unchanged, vec!["params.a".to_string()]);
        assert_eq!(res.windows.len(), 1);
        assert_eq!(res.windows[0].name, "params.b");
        assert_eq!(res.windows[0].data, vec![9.0, 9.0, 9.0]);
        assert_eq!(res.payload_bytes(), 3 * 4);
        assert_eq!(res.parts.len(), 2);
        assert_eq!(res.digests.len(), 2);
        // residual (i32) leaves ride the delta wire too
        assert_eq!(
            res.residual.get("params.ids").unwrap().as_i32().unwrap(),
            &[4, 2]
        );
        // named scope + basis over the wire
        let res = client
            .fetch(
                &FetchSpec::named(0, u64::MAX, vec!["params.a".into(), "params.b".into()])
                    .with_basis(basis),
            )
            .unwrap()
            .unwrap();
        assert_eq!(res.unchanged, vec!["params.a".to_string()]);
        assert_eq!(res.windows.len(), 1);
        // absent member stays a clean None through DELTA
        assert!(client
            .fetch(&FetchSpec::full(9, u64::MAX))
            .unwrap()
            .is_none());
    }

    #[test]
    fn steps_heartbeat_roundtrip() {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let client = SocketTransport::connect_tcp(server.addr());
        assert!(client.last_steps().unwrap().is_empty());
        client.publish(ckpt(3, 5, &[0.0; 5])).unwrap();
        client.publish(ckpt(1, 9, &[0.0; 5])).unwrap();
        client.publish(ckpt(3, 8, &[0.0; 5])).unwrap();
        assert_eq!(client.last_steps().unwrap(), vec![(1, 9), (3, 8)]);
    }

    /// Regression for the serial accept loop: two clients fetching
    /// concurrently must both complete while a third connection sits on
    /// the wire sending nothing (the old poll-one-connection server
    /// served that idle connection to EOF before accepting anyone else).
    #[test]
    fn concurrent_fetches_complete_despite_slow_connection() {
        use std::sync::mpsc;

        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let publisher = SocketTransport::connect_tcp(server.addr());
        publisher.publish(ckpt(0, 7, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();

        // A slow client: connects, sends half a length prefix, stalls.
        let mut slow = TcpStream::connect(server.addr()).unwrap();
        slow.write_all(&[9u8, 0]).unwrap();
        // Give the server time to hand the slow connection to a worker.
        std::thread::sleep(Duration::from_millis(50));

        let (tx, rx) = mpsc::channel();
        let addr = server.addr().to_string();
        let mut handles = Vec::new();
        for i in 0..2 {
            let tx = tx.clone();
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let c = SocketTransport::connect_tcp(&addr);
                let got = c.latest(0).unwrap().unwrap();
                tx.send((i, got.step)).unwrap();
            }));
        }
        drop(tx);
        let mut done = Vec::new();
        for _ in 0..2 {
            // A serial server would sit on the slow connection until its
            // 30 s read timeout; the concurrent server answers promptly.
            let (i, step) = rx
                .recv_timeout(Duration::from_secs(5))
                .expect("fetch blocked behind the slow connection");
            assert_eq!(step, 7);
            done.push(i);
        }
        done.sort();
        assert_eq!(done, vec![0, 1]);
        for h in handles {
            h.join().unwrap();
        }
        // The slow connection is still being served (held by its worker).
        assert!(server.active_connections() >= 1);
        drop(slow);
    }

    /// Dropping the server must not wait out the accept poll or any read
    /// timeout: the shutdown wakeup unblocks the accept immediately.
    #[test]
    fn shutdown_is_prompt() {
        let server = SocketServer::bind_tcp("127.0.0.1:0", 4).unwrap();
        let t0 = std::time::Instant::now();
        drop(server);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "server drop took {:?}",
            t0.elapsed()
        );
    }

    #[cfg(unix)]
    #[test]
    fn unix_socket_roundtrip() {
        let path = std::env::temp_dir().join(format!(
            "codistill_uds_{}.sock",
            std::process::id()
        ));
        let server = SocketServer::bind_unix(&path, 4).unwrap();
        let client = SocketTransport::connect(&format!("unix:{}", path.display())).unwrap();
        client.publish(ckpt(7, 1, &[1.0, 2.0, 3.0, 4.0, 5.0])).unwrap();
        let c = client.latest(7).unwrap().unwrap();
        assert_eq!(c.flat().view("params.b").unwrap(), &[3.0, 4.0, 5.0]);
        drop(client);
        drop(server);
        assert!(!path.exists(), "socket file not unlinked on shutdown");
    }
}

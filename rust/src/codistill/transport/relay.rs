//! Relay tier: CDN-style checkpoint fan-out nodes.
//!
//! A [`Relay`] sits between an upstream publisher hub and a crowd of
//! downstream readers. It polls the upstream exactly like a reader
//! ([`ExchangeTransport::last_steps`] to spot fresh publications, then a
//! delta-aware [`DeltaCache`] fetch that moves only changed windows and
//! digest-verifies every install), mirrors the resulting planes into a
//! local [`InProcess`] store, and serves downstream `DESCRIBE` / `FETCH`
//! / `DELTA` / `STEPS` requests from that mirror through the
//! event-driven [`SocketServer`]. One upstream connection amortizes over
//! arbitrarily many downstream readers; relays stack, so `R` readers
//! fan out as a tree of depth `ceil(log_f R)` instead of a flat hub with
//! `R` sockets (priced against the flat hub in `netsim`).
//!
//! ```text
//!                        publisher hub
//!                             │  (1 delta subscription per relay)
//!                ┌────────────┴────────────┐
//!             Relay A                   Relay B
//!         ┌──────┼──────┐            ┌──────┼──────┐
//!      reader reader  Relay C     reader reader  reader
//!                    ┌───┴───┐
//!                 reader   reader
//! ```
//!
//! ## Semantics
//!
//! - **Reads are served from the mirror.** `members`/`last_steps`/
//!   `fetch` reflect what the relay has *installed*, not what the
//!   upstream currently holds: a relay hop adds at most one
//!   `poll_interval` of staleness per level — exactly the bounded
//!   staleness the codistillation paper says the algorithm tolerates.
//!   Readers digest-verify installs against the relay, and the relay
//!   digest-verified them against *its* upstream, so corruption cannot
//!   propagate silently down the tree.
//! - **`fetch` falls through on a mirror miss.** A request for a member
//!   the mirror has not yet installed is forwarded upstream verbatim
//!   (counted in [`RelayStats::passthrough_fetches`]), so a freshly
//!   started relay is correct immediately and merely warms up to cheap.
//! - **`publish` forwards upstream.** A relay is a read-side cache, not
//!   a coordinator: writes go to the root hub (counted in
//!   [`RelayStats::forwarded_publishes`]) and come back down through the
//!   normal refresh path like any other publication.
//! - **`gc` is local-only.** The mirror bounds its own history per
//!   member; relays never garbage-collect the upstream on behalf of
//!   readers — only the orchestrator owning the root hub does that.

use super::socket::{SocketServer, MAX_CONNECTIONS};
use super::{Codec, DeltaCache, DeltaStats, ExchangeTransport, InProcess, SubscribeStats};
use crate::codistill::obs::{keys, Event, Recorder};
use crate::codistill::store::Checkpoint;
use crate::codistill::transport::{FetchResult, FetchSpec, RetryStats, TransportKind};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

/// Knobs for one relay node.
#[derive(Debug, Clone)]
pub struct RelayConfig {
    /// Upstream poll cadence — the staleness this hop adds.
    pub poll_interval: Duration,
    /// Fetch from the upstream through a [`DeltaCache`] (moving only
    /// changed windows) instead of full planes.
    pub delta: bool,
    /// Codec advertised on upstream fetches (downstream framing is
    /// negotiated per-connection by the server as usual).
    pub codec: Codec,
    /// Publications retained per member in the mirror.
    pub history: usize,
    /// Downstream connection bound (registered readiness-loop state
    /// machines, not threads).
    pub max_connections: usize,
}

impl Default for RelayConfig {
    fn default() -> Self {
        Self {
            poll_interval: Duration::from_millis(5),
            delta: true,
            codec: Codec::Raw,
            history: 4,
            max_connections: MAX_CONNECTIONS,
        }
    }
}

/// Counters for one relay node (cheap copies; see [`Relay::stats`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct RelayStats {
    /// Upstream refresh sweeps.
    pub polls: u64,
    /// Planes installed into the mirror (fresh steps seen upstream).
    pub installs: u64,
    /// Upstream errors absorbed by the refresher (retried next sweep).
    pub tolerated_errors: u64,
    /// Downstream fetches forwarded upstream on a mirror miss.
    pub passthrough_fetches: u64,
    /// Downstream publishes forwarded to the upstream hub.
    pub forwarded_publishes: u64,
    /// Upstream delta-fetch accounting (zeros when `delta` is off).
    pub delta: DeltaStats,
}

/// The backend the relay's socket server dispatches to: a local
/// [`InProcess`] mirror for reads, with writes and mirror-miss fetches
/// forwarded to the upstream transport.
struct RelayStore {
    upstream: Arc<dyn ExchangeTransport>,
    mirror: InProcess,
    passthrough_fetches: AtomicU64,
    forwarded_publishes: AtomicU64,
    recorder: Option<Recorder>,
}

impl ExchangeTransport for RelayStore {
    fn kind(&self) -> TransportKind {
        // A relay is transparent: it reports the upstream's kind so
        // logs/bench labels show what the tree is ultimately made of.
        self.upstream.kind()
    }

    fn publish(&self, ckpt: Checkpoint) -> Result<()> {
        self.forwarded_publishes.fetch_add(1, Ordering::Relaxed);
        if let Some(rec) = &self.recorder {
            rec.record(Event::RelayForward {
                member: ckpt.member,
                step: ckpt.step,
            });
            rec.incr(keys::RELAY_FORWARDED, 1);
        }
        self.upstream.publish(ckpt)
    }

    fn fetch(&self, spec: &FetchSpec) -> Result<Option<FetchResult>> {
        if let Some(res) = self.mirror.fetch(spec)? {
            return Ok(Some(res));
        }
        // Mirror miss (member not yet refreshed, or a staleness bound
        // older than anything installed): forward verbatim so a cold
        // relay is correct immediately.
        self.passthrough_fetches.fetch_add(1, Ordering::Relaxed);
        if let Some(rec) = &self.recorder {
            rec.incr(keys::RELAY_PASSTHROUGH, 1);
        }
        self.upstream.fetch(spec)
    }

    fn members(&self) -> Result<Vec<usize>> {
        Ok(self.mirror.members())
    }

    fn last_steps(&self) -> Result<Vec<(usize, u64)>> {
        Ok(self.mirror.last_steps())
    }

    fn gc(&self) -> Result<()> {
        // Local-only: the mirror already bounds history on publish, and
        // relays must not gc the upstream out from under other readers.
        Ok(())
    }

    fn retry_stats(&self) -> Option<RetryStats> {
        self.upstream.retry_stats()
    }
}

/// A running fan-out node: background upstream refresher + event-driven
/// downstream socket server over the mirror. See the module docs for
/// semantics; stacking relays (each one's upstream a
/// [`SocketTransport`](super::SocketTransport) pointed at the previous
/// relay's [`Relay::addr`]) builds the tree.
pub struct Relay {
    server: SocketServer,
    store: Arc<RelayStore>,
    stats: Arc<Mutex<RelayStats>>,
    stop: Arc<AtomicBool>,
    refresher: Option<JoinHandle<()>>,
}

impl Relay {
    /// Bind a TCP relay on `addr` (use port 0 for an ephemeral port,
    /// then [`Relay::addr`]) over `upstream`, and start refreshing.
    pub fn spawn_tcp(
        upstream: Arc<dyn ExchangeTransport>,
        addr: &str,
        cfg: RelayConfig,
    ) -> Result<Relay> {
        Self::spawn_tcp_recorded(upstream, addr, cfg, None)
    }

    /// [`Relay::spawn_tcp`] with an optional `codistill::obs` recorder:
    /// forwarded publishes become journal events, the refresher's delta
    /// cache emits fetch/install events, and the loop mirrors its
    /// counters into the `relay.*` registry keys. Per-sweep counters are
    /// intentionally *not* journal events — poll counts are timing-
    /// dependent and would break trace byte-identity.
    pub fn spawn_tcp_recorded(
        upstream: Arc<dyn ExchangeTransport>,
        addr: &str,
        cfg: RelayConfig,
        recorder: Option<Recorder>,
    ) -> Result<Relay> {
        let store = Arc::new(RelayStore {
            upstream,
            mirror: InProcess::new(cfg.history),
            passthrough_fetches: AtomicU64::new(0),
            forwarded_publishes: AtomicU64::new(0),
            recorder: recorder.clone(),
        });
        let backend: Arc<dyn ExchangeTransport> = store.clone();
        let server = SocketServer::bind_tcp_over(addr, backend, cfg.max_connections)?;

        let stats = Arc::new(Mutex::new(RelayStats::default()));
        let stop = Arc::new(AtomicBool::new(false));
        let refresher = {
            let store = store.clone();
            let stats = stats.clone();
            let stop = stop.clone();
            thread::Builder::new()
                .name("ckpt-relay-refresh".into())
                .spawn(move || refresh_loop(&store, &cfg, &stats, &stop, recorder))
                .expect("spawning relay refresher thread")
        };
        Ok(Relay {
            server,
            store,
            stats,
            stop,
            refresher: Some(refresher),
        })
    }

    /// Resolved downstream listen address (`host:port`).
    pub fn addr(&self) -> &str {
        self.server.addr()
    }

    /// Downstream connections currently registered with the server.
    pub fn active_connections(&self) -> usize {
        self.server.active_connections()
    }

    /// Counters so far (refresher progress + forwarding traffic).
    pub fn stats(&self) -> RelayStats {
        let mut s = *self.stats.lock().expect("relay stats lock");
        s.passthrough_fetches = self.store.passthrough_fetches.load(Ordering::Relaxed);
        s.forwarded_publishes = self.store.forwarded_publishes.load(Ordering::Relaxed);
        s
    }

    /// The refresher viewed as a subscription: the relay's upstream loop
    /// is the same poll/fetch/install shape as a
    /// [`Subscription`](super::Subscription), so its counters project
    /// onto [`SubscribeStats`] (fetches = full + delta upstream pulls).
    /// Lets `codistill relay` print both summaries from one node.
    pub fn subscribe_stats(&self) -> SubscribeStats {
        let s = self.stats();
        SubscribeStats {
            polls: s.polls,
            fetches: s.delta.full_fetches + s.delta.delta_fetches,
            installs: s.installs,
            tolerated_errors: s.tolerated_errors,
            delta: s.delta,
        }
    }

    /// Stop refreshing and join the refresher thread. The downstream
    /// server keeps answering from the (now frozen) mirror until the
    /// relay is dropped.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.refresher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Relay {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One refresh sweep per `poll_interval`: list upstream steps, pull any
/// member whose freshest step the mirror has not installed yet, publish
/// the verified plane into the mirror. Upstream errors are tolerated
/// and retried on the next sweep (the mirror just stays one beat
/// staler), mirroring the [`Subscription`](super::Subscription) loop.
fn refresh_loop(
    store: &RelayStore,
    cfg: &RelayConfig,
    stats: &Arc<Mutex<RelayStats>>,
    stop: &AtomicBool,
    recorder: Option<Recorder>,
) {
    let mut cache = DeltaCache::new().with_codec(cfg.codec);
    if let Some(rec) = &recorder {
        cache = cache.with_recorder(rec.clone());
    }
    // Installed step per member, tracked locally so the delta-off path
    // does not have to re-list the mirror every sweep.
    let mut installed: HashMap<usize, u64> = HashMap::new();
    while !stop.load(Ordering::SeqCst) {
        let mut sweep_installs = 0u64;
        let mut sweep_errors = 0u64;
        match store.upstream.last_steps() {
            Ok(steps) => {
                for (member, step) in steps {
                    if installed.get(&member).is_some_and(|&got| got >= step) {
                        continue;
                    }
                    let fetched = if cfg.delta {
                        cache.latest(store.upstream.as_ref(), member)
                    } else {
                        store.upstream.latest(member)
                    };
                    match fetched {
                        Ok(Some(ck)) => {
                            let got = ck.step;
                            // Checkpoint clones are cheap: the flat plane
                            // is Arc-shared, so the mirror and the cache
                            // reference the same verified bytes.
                            if store.mirror.publish((*ck).clone()).is_ok() {
                                installed.insert(member, got);
                                sweep_installs += 1;
                            } else {
                                sweep_errors += 1;
                            }
                        }
                        Ok(None) => {}
                        Err(_) => sweep_errors += 1,
                    }
                }
            }
            Err(_) => sweep_errors += 1,
        }
        {
            let mut s = stats.lock().expect("relay stats lock");
            s.polls += 1;
            s.installs += sweep_installs;
            s.tolerated_errors += sweep_errors;
            s.delta = cache.stats();
        }
        if let Some(rec) = &recorder {
            rec.incr(keys::RELAY_POLLS, 1);
            rec.incr(keys::RELAY_INSTALLS, sweep_installs);
            rec.incr(keys::RELAY_TOLERATED, sweep_errors);
        }
        thread::sleep(cfg.poll_interval);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codistill::transport::{SocketTransport, ANY_STEP};
    use crate::testkit::DriftMember;
    use std::time::Instant;

    fn publish(t: &dyn ExchangeTransport, m: &mut DriftMember, steps: u64) {
        for _ in 0..steps {
            m.train_step(0.0, 0.1).unwrap();
        }
        t.publish(m.snapshot().unwrap()).unwrap();
    }

    fn wait_for_step(t: &dyn ExchangeTransport, member: usize, step: u64) -> Arc<Checkpoint> {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            if let Some(ck) = t.latest_at_most(member, ANY_STEP).unwrap() {
                if ck.step >= step {
                    return ck;
                }
            }
            assert!(Instant::now() < deadline, "relay never installed step {step}");
            thread::sleep(Duration::from_millis(1));
        }
    }

    fn fast() -> RelayConfig {
        RelayConfig {
            poll_interval: Duration::from_millis(1),
            ..RelayConfig::default()
        }
    }

    #[test]
    fn relay_mirrors_publisher_byte_identically() {
        let hub: Arc<dyn ExchangeTransport> = Arc::new(InProcess::new(4));
        let mut m = DriftMember::new(0);
        publish(hub.as_ref(), &mut m, 3);

        let mut relay = Relay::spawn_tcp(hub.clone(), "127.0.0.1:0", fast()).unwrap();
        let reader = SocketTransport::connect_tcp(relay.addr());
        let via_relay = wait_for_step(&reader, 0, 3);
        let direct = hub.latest(0).unwrap().unwrap();
        assert_eq!(via_relay.step, direct.step);
        assert_eq!(via_relay.flat().data(), direct.flat().data());

        // a fresh publication propagates without re-moving old planes
        publish(hub.as_ref(), &mut m, 2);
        let via_relay = wait_for_step(&reader, 0, 5);
        assert_eq!(via_relay.flat().data(), hub.latest(0).unwrap().unwrap().flat().data());

        relay.stop();
        let stats = relay.stats();
        assert!(stats.installs >= 2);
        assert!(stats.polls >= stats.installs);
        assert_eq!(stats.tolerated_errors, 0);
        assert!(stats.delta.full_fetches >= 1, "first upstream pull is full");
    }

    #[test]
    fn two_level_chain_serves_the_same_plane() {
        let hub: Arc<dyn ExchangeTransport> = Arc::new(InProcess::new(4));
        let mut m = DriftMember::new(2);
        publish(hub.as_ref(), &mut m, 4);

        let relay1 = Relay::spawn_tcp(hub.clone(), "127.0.0.1:0", fast()).unwrap();
        let up1: Arc<dyn ExchangeTransport> =
            Arc::new(SocketTransport::connect_tcp(relay1.addr()));
        let relay2 = Relay::spawn_tcp(up1, "127.0.0.1:0", fast()).unwrap();

        let leaf = SocketTransport::connect_tcp(relay2.addr());
        let got = wait_for_step(&leaf, 2, 4);
        let direct = hub.latest(2).unwrap().unwrap();
        assert_eq!(got.step, direct.step);
        assert_eq!(got.flat().data(), direct.flat().data());
        assert_eq!(got.residual().len(), direct.residual().len());
    }

    #[test]
    fn publish_through_relay_lands_on_the_hub() {
        let hub = Arc::new(InProcess::new(4));
        let upstream: Arc<dyn ExchangeTransport> = hub.clone();
        let relay = Relay::spawn_tcp(upstream, "127.0.0.1:0", fast()).unwrap();

        let writer = SocketTransport::connect_tcp(relay.addr());
        let mut m = DriftMember::new(7);
        publish(&writer, &mut m, 1);

        let direct = hub.latest_at_most(7, ANY_STEP).expect("hub saw the forwarded publish");
        assert_eq!(direct.step, 1);
        assert_eq!(relay.stats().forwarded_publishes, 1);
        // ...and the refresher pulls it back down to the mirror.
        let reader = SocketTransport::connect_tcp(relay.addr());
        let got = wait_for_step(&reader, 7, 1);
        assert_eq!(got.flat().data(), direct.flat().data());
    }

    #[test]
    fn cold_mirror_miss_passes_through_upstream() {
        let hub: Arc<dyn ExchangeTransport> = Arc::new(InProcess::new(4));
        let mut m = DriftMember::new(1);
        publish(hub.as_ref(), &mut m, 2);

        // Huge poll interval: the mirror stays cold for the duration of
        // the test, so the first downstream fetch must fall through.
        let cfg = RelayConfig {
            poll_interval: Duration::from_secs(3600),
            ..RelayConfig::default()
        };
        let relay = Relay::spawn_tcp(hub.clone(), "127.0.0.1:0", cfg).unwrap();
        // let the first (cold) sweep finish before probing
        let deadline = Instant::now() + Duration::from_secs(10);
        while relay.stats().polls == 0 && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(1));
        }

        let reader = SocketTransport::connect_tcp(relay.addr());
        let got = reader.latest_at_most(1, ANY_STEP).unwrap();
        // the cold sweep may already have mirrored member 1; either way
        // the bytes are the hub's, and a fetch for an unknown member
        // counts a passthrough instead of erroring
        let direct = hub.latest(1).unwrap().unwrap();
        assert_eq!(got.unwrap().flat().data(), direct.flat().data());
        assert!(reader.latest_at_most(99, ANY_STEP).unwrap().is_none());
        assert!(relay.stats().passthrough_fetches >= 1);
    }
}

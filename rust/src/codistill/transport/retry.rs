//! Retrying decorator over any checkpoint-exchange transport.
//!
//! The coordinator tolerates exchange failures by *skipping* them: a
//! dropped teacher fetch is logged and the member trains on with its old
//! set. That is the right last resort, but most real failures — a torn
//! connection, a preempted peer mid-reply, an injected
//! [`Faulty`](crate::codistill::transport::Faulty) fetch fault — are
//! transient, and a single retry absorbs them before the coordinator ever
//! has to degrade. [`Retry`] wraps any [`ExchangeTransport`] with a
//! per-operation retry loop:
//!
//! * **Transient vs permanent classification** ([`classify_error`]).
//!   Connection-shaped failures (refused/reset/torn frame/timeout — any
//!   `std::io::Error` of those kinds in the chain), a server that closed
//!   the connection cleanly mid-operation, and `Faulty`'s injected fetch
//!   errors are transient: the operation is retried with backoff.
//!   Protocol violations and corruption (digest mismatch, malformed or
//!   oversized frames, bad opcodes/status bytes) are permanent: retrying
//!   cannot help and might mask a real bug, so they surface immediately.
//! * **Deterministic seeded backoff.** The delay before attempt `k` of
//!   operation `op` is a pure function of `(policy.seed, op, k)` —
//!   exponential with jitter, but jittered from a
//!   [`Pcg64`] stream rather than a wall clock, so two runs with the same
//!   seed replay byte-identical [`Retry::retry_log_text`] output.
//! * **Empty-read retries.** A fetch answered `Ok(None)` may mean "never
//!   published" or a dropped read (that is exactly how `Faulty` models a
//!   drop). With [`RetryPolicy::retry_none`] (default on) empty fetch
//!   answers are retried like transient errors and surface as `None`
//!   only after the attempt budget is spent.
//! * **Per-attempt deadline.** [`RetryPolicy::attempt_deadline`] marks an
//!   attempt that failed after running past the deadline as transient
//!   regardless of its error class: an operation slow enough to trip the
//!   deadline is timeout-shaped even when its error text is not. (The
//!   blocking socket client's own read timeout —
//!   [`SocketTransport::with_read_timeout`](crate::codistill::transport::SocketTransport::with_read_timeout)
//!   — is what actually bounds a hung read; set it at or below this
//!   deadline.)
//!
//! Accounting lands in [`RetryStats`] — total operations, attempts,
//! transient failures absorbed vs surfaced — which the coordinator and
//! orchestrator thread into their run logs so the fault matrix can assert
//! "N injected transient faults, M absorbed by retry, K surfaced".
//!
//! Since the `codistill::obs` refactor both the counters and the replay
//! log live in an [`obs::Recorder`](crate::codistill::obs::Recorder):
//! [`Retry::stats`] is a view over the recorder's counter registry and
//! [`Retry::retry_log_text`] re-renders the journal's retry events
//! through the shared renderer — byte-identical to the pre-refactor
//! output. By default each `Retry` owns a private
//! `Recorder::sim(policy.seed)`; [`Retry::with_recorder`] injects a
//! run-level recorder instead (note a *shared* recorder pools counters
//! and log lines across everything recording into it).

use crate::codistill::obs::{keys, Event, Recorder};
use crate::codistill::store::Checkpoint;
use crate::codistill::transport::{ExchangeTransport, FetchResult, FetchSpec, TransportKind};
use crate::prng::Pcg64;
use anyhow::Result;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Whether a failed exchange operation is worth retrying.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorClass {
    /// Connection-shaped / injected-fault failure: retry may succeed.
    Transient,
    /// Protocol violation or corruption: retrying cannot help.
    Permanent,
}

/// Classify an exchange error as transient (retryable) or permanent.
///
/// The decision walks the error chain: any connection-shaped
/// `std::io::Error` makes the failure transient, any corruption-shaped
/// one permanent. Failing that, known error texts from the transport
/// stack decide; unknown errors default to **permanent** — an
/// unclassified failure is surfaced loudly rather than silently retried.
pub fn classify_error(err: &anyhow::Error) -> ErrorClass {
    for cause in err.chain() {
        if let Some(io) = cause.downcast_ref::<std::io::Error>() {
            use std::io::ErrorKind::*;
            return match io.kind() {
                UnexpectedEof | ConnectionRefused | ConnectionReset | ConnectionAborted
                | BrokenPipe | TimedOut | WouldBlock | Interrupted | NotConnected
                | AddrNotAvailable => ErrorClass::Transient,
                _ => ErrorClass::Permanent,
            };
        }
    }
    let text = format!("{err:#}");
    // Transient markers: injected fetch faults (`Faulty`), a server that
    // closed the connection between frames, a connect that failed before
    // an io::Error made it into the chain.
    const TRANSIENT: &[&str] = &[
        "injected fetch error",
        "exchange server closed the connection",
        "connecting ",
    ];
    // Permanent markers: corruption and protocol violations from the
    // wire/install guards.
    const PERMANENT: &[&str] = &[
        "corrupt delta payload",
        "frame claims",
        "frame of",
        "bad response status",
        "bad basis flag",
        "bad window selection flag",
        "unknown opcode",
        "empty response frame",
    ];
    if PERMANENT.iter().any(|m| text.contains(m)) {
        return ErrorClass::Permanent;
    }
    if TRANSIENT.iter().any(|m| text.contains(m)) {
        return ErrorClass::Transient;
    }
    ErrorClass::Permanent
}

/// Per-operation retry policy (see module docs). The defaults — 5
/// attempts, 1 ms base backoff doubling to 50 ms with 50% jitter — absorb
/// the overwhelming majority of independent per-attempt faults: at a 30%
/// transient-failure rate per attempt, fewer than 0.3% of operations
/// exhaust the budget.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts per operation (>= 1; 1 = no retries).
    pub max_attempts: u32,
    /// Backoff before the second attempt; doubles per further attempt.
    pub base_delay: Duration,
    /// Backoff cap.
    pub max_delay: Duration,
    /// Jitter fraction in [0, 1]: the drawn delay is
    /// `delay * (1 - jitter + jitter * u)` for a seeded uniform `u`.
    pub jitter: f64,
    /// Retry fetches answered `Ok(None)` (dropped reads look identical
    /// to never-published members; see module docs).
    pub retry_none: bool,
    /// An attempt that *failed* after running at least this long is
    /// treated as transient regardless of its error class.
    pub attempt_deadline: Option<Duration>,
    /// Seed of the deterministic jitter stream.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 5,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(50),
            jitter: 0.5,
            retry_none: true,
            attempt_deadline: None,
            seed: 0,
        }
    }
}

impl RetryPolicy {
    /// A policy for deterministic tests: `attempts` tries, no sleeping.
    pub fn immediate(attempts: u32, seed: u64) -> Self {
        RetryPolicy {
            max_attempts: attempts.max(1),
            base_delay: Duration::ZERO,
            max_delay: Duration::ZERO,
            seed,
            ..Default::default()
        }
    }

    /// Deterministic backoff before attempt `attempt` (2-based: no delay
    /// precedes the first attempt) of operation `op`.
    fn backoff(&self, op: u64, attempt: u32) -> Duration {
        if self.base_delay.is_zero() || attempt < 2 {
            return Duration::ZERO;
        }
        let exp = self.base_delay.as_secs_f64() * f64::from(2u32.saturating_pow(attempt - 2));
        let capped = exp.min(self.max_delay.as_secs_f64());
        let j = self.jitter.clamp(0.0, 1.0);
        let stream = op
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add(u64::from(attempt).wrapping_mul(0xbf58476d1ce4e5b9));
        let u = Pcg64::with_stream(self.seed, stream).uniform();
        Duration::from_secs_f64(capped * (1.0 - j + j * u))
    }
}

/// Retry accounting: enough to assert "N injected transient faults, M
/// absorbed by retry, K surfaced" from a run log.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RetryStats {
    /// Operations gated through the retry loop.
    pub ops: u64,
    /// Individual attempts (>= ops).
    pub attempts: u64,
    /// Transient errors observed (each was retried unless it exhausted
    /// the budget).
    pub transient_errors: u64,
    /// Empty fetch answers retried under [`RetryPolicy::retry_none`].
    pub empty_retries: u64,
    /// Operations that failed transiently at least once and then
    /// succeeded — the faults the retry layer absorbed.
    pub absorbed: u64,
    /// Operations whose final attempt still failed transiently (the
    /// error surfaced to the caller).
    pub exhausted: u64,
    /// Operations that still answered `Ok(None)` after the budget.
    pub exhausted_empty: u64,
    /// Permanent errors surfaced without retry.
    pub permanent_errors: u64,
}

impl RetryStats {
    /// Operations that saw at least one transient failure.
    pub fn affected_ops(&self) -> u64 {
        self.absorbed + self.exhausted + self.exhausted_empty
    }

    /// Fraction of transient-failure-affected operations the retry layer
    /// rescued (1.0 when nothing failed).
    pub fn absorption_rate(&self) -> f64 {
        let affected = self.affected_ops();
        if affected == 0 {
            1.0
        } else {
            self.absorbed as f64 / affected as f64
        }
    }
}

/// Retrying decorator over any exchange transport (see module docs).
/// Stack it *outside* fault injection — `Retry::wrap(Faulty::wrap(...))`
/// — so injected faults exercise the retry loop.
pub struct Retry {
    inner: Arc<dyn ExchangeTransport>,
    policy: RetryPolicy,
    /// Next journal op id. Ids number *logged* operations only (see
    /// [`Retry::run_op`]), so they are deterministic even when
    /// timing-dependent heartbeat polling drives extra silent ops.
    next_op: Mutex<u64>,
    recorder: Recorder,
}

/// Outcome of one gated operation, before stats bookkeeping.
enum OpOutcome<T> {
    Done(Result<T>),
    TransientErr(anyhow::Error),
    Empty(T),
}

impl Retry {
    pub fn wrap(inner: Arc<dyn ExchangeTransport>, policy: RetryPolicy) -> Self {
        let recorder = Recorder::sim(policy.seed);
        Retry {
            inner,
            policy: RetryPolicy {
                max_attempts: policy.max_attempts.max(1),
                ..policy
            },
            next_op: Mutex::new(0),
            recorder,
        }
    }

    /// Record into a shared (e.g. run-level `--trace`) recorder instead
    /// of the private seeded default.
    pub fn with_recorder(mut self, recorder: Recorder) -> Self {
        self.recorder = recorder;
        self
    }

    pub fn policy(&self) -> &RetryPolicy {
        &self.policy
    }

    /// Retry accounting so far — a view over the recorder's counter
    /// registry (pooled across writers when the recorder is shared).
    pub fn stats(&self) -> RetryStats {
        RetryStats {
            ops: self.recorder.counter(keys::RETRY_OPS),
            attempts: self.recorder.counter(keys::RETRY_ATTEMPTS),
            transient_errors: self.recorder.counter(keys::RETRY_TRANSIENT),
            empty_retries: self.recorder.counter(keys::RETRY_EMPTY),
            absorbed: self.recorder.counter(keys::RETRY_ABSORBED),
            exhausted: self.recorder.counter(keys::RETRY_EXHAUSTED),
            exhausted_empty: self.recorder.counter(keys::RETRY_EXHAUSTED_EMPTY),
            permanent_errors: self.recorder.counter(keys::RETRY_PERMANENT),
        }
    }

    /// Canonical text rendering of the retry log: one
    /// `op member attempt what` line per retry-relevant event, in
    /// operation order — byte-comparable across runs with the same seed,
    /// fault plan, and schedule (single writer assumed, like the fault
    /// log). Re-derived from the journal through the shared renderer.
    pub fn retry_log_text(&self) -> String {
        self.recorder.journal().retry_log_text()
    }

    /// Record one attempt into the journal, allocating the op id at the
    /// first logged attempt of the operation.
    fn record(&self, op_id: &mut Option<u64>, member: usize, attempt: u32, what: &'static str) {
        let id = match *op_id {
            Some(id) => id,
            None => {
                let mut next = self.next_op.lock().unwrap();
                let id = *next;
                *next += 1;
                *op_id = Some(id);
                id
            }
        };
        self.recorder.record(Event::RetryAttempt {
            op: id,
            member,
            attempt,
            what,
        });
    }

    /// Drive one operation through the retry loop. `member` is only used
    /// for the log (coordinator-level ops like `gc` pass [`COORD_OP`]).
    /// `empty` marks results that should be retried under `retry_none`.
    ///
    /// Journal op ids are assigned lazily, at an operation's first
    /// logged attempt: the (common) clean first-attempt success never
    /// consumes an id, so op numbering is a pure function of the fault
    /// sequence — not of how many silent heartbeat polls happened to run.
    fn run_op<T>(
        &self,
        member: usize,
        mut op: impl FnMut() -> Result<T>,
        empty: impl Fn(&T) -> bool,
    ) -> Result<T> {
        self.recorder.incr(keys::RETRY_OPS, 1);
        let mut op_id: Option<u64> = None;
        let mut failed_before = false;
        for attempt in 1..=self.policy.max_attempts {
            // Backoff only ever precedes attempt >= 2, by which point the
            // failed first attempt has already allocated the op id.
            let backoff = self.policy.backoff(op_id.unwrap_or(0), attempt);
            if !backoff.is_zero() {
                std::thread::sleep(backoff);
            }
            self.recorder.incr(keys::RETRY_ATTEMPTS, 1);
            let started = Instant::now();
            let outcome = match op() {
                Ok(v) if self.policy.retry_none && empty(&v) => OpOutcome::Empty(v),
                Ok(v) => OpOutcome::Done(Ok(v)),
                Err(e) => {
                    let over_deadline = self
                        .policy
                        .attempt_deadline
                        .is_some_and(|d| started.elapsed() >= d);
                    if over_deadline || classify_error(&e) == ErrorClass::Transient {
                        OpOutcome::TransientErr(e)
                    } else {
                        OpOutcome::Done(Err(e))
                    }
                }
            };
            match outcome {
                OpOutcome::Done(Ok(v)) => {
                    if failed_before {
                        self.recorder.incr(keys::RETRY_ABSORBED, 1);
                        self.record(&mut op_id, member, attempt, "absorbed");
                    }
                    return Ok(v);
                }
                OpOutcome::Done(Err(e)) => {
                    self.recorder.incr(keys::RETRY_PERMANENT, 1);
                    self.record(&mut op_id, member, attempt, "permanent");
                    return Err(e);
                }
                OpOutcome::TransientErr(e) => {
                    failed_before = true;
                    self.recorder.incr(keys::RETRY_TRANSIENT, 1);
                    self.record(&mut op_id, member, attempt, "transient");
                    if attempt == self.policy.max_attempts {
                        self.recorder.incr(keys::RETRY_EXHAUSTED, 1);
                        self.record(&mut op_id, member, attempt, "exhausted");
                        return Err(e);
                    }
                }
                OpOutcome::Empty(v) => {
                    failed_before = true;
                    self.recorder.incr(keys::RETRY_EMPTY, 1);
                    self.record(&mut op_id, member, attempt, "empty");
                    if attempt == self.policy.max_attempts {
                        self.recorder.incr(keys::RETRY_EXHAUSTED_EMPTY, 1);
                        self.record(&mut op_id, member, attempt, "exhausted");
                        return Ok(v);
                    }
                }
            }
        }
        unreachable!("retry loop returns within max_attempts");
    }
}

/// Member id the retry log uses for coordinator-level operations
/// (`members`/`last_steps`/`gc`) that are not about one member.
pub const COORD_OP: usize = usize::MAX;

impl ExchangeTransport for Retry {
    fn kind(&self) -> TransportKind {
        self.inner.kind()
    }

    fn publish(&self, ckpt: Checkpoint) -> Result<()> {
        let member = ckpt.member;
        // Publish is idempotent on the exchange (per-member step
        // monotonicity: re-publishing the same step overwrites the same
        // slot), so a transient publish failure is retried like a read.
        let mut held = Some(ckpt);
        self.run_op(
            member,
            move || {
                let ck = held.take().expect("publish retried after success");
                match self.inner.publish(ck.clone()) {
                    Ok(()) => Ok(()),
                    Err(e) => {
                        held = Some(ck);
                        Err(e)
                    }
                }
            },
            |_| false,
        )
    }

    fn fetch(&self, spec: &FetchSpec) -> Result<Option<FetchResult>> {
        self.run_op(spec.member, || self.inner.fetch(spec), Option::is_none)
    }

    fn members(&self) -> Result<Vec<usize>> {
        self.run_op(COORD_OP, || self.inner.members(), |_| false)
    }

    fn last_steps(&self) -> Result<Vec<(usize, u64)>> {
        self.run_op(COORD_OP, || self.inner.last_steps(), |_| false)
    }

    fn gc(&self) -> Result<()> {
        self.run_op(COORD_OP, || self.inner.gc(), |_| false)
    }

    fn flush(&self) -> Result<()> {
        self.inner.flush()
    }

    fn retry_stats(&self) -> Option<RetryStats> {
        Some(self.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codistill::transport::{FaultPlan, Faulty, InProcess};
    use crate::runtime::{Tensor, TensorMap};
    use anyhow::{anyhow, bail};

    fn ckpt(member: usize, step: u64, val: f32) -> Checkpoint {
        let mut params = TensorMap::new();
        params.insert("params.w", Tensor::f32(&[2], vec![val, val]).unwrap());
        Checkpoint::new(member, step, params)
    }

    /// Scripted transport: fails the first `fail_reads` reads with the
    /// given error builder, then behaves like its inner store.
    struct Scripted {
        inner: InProcess,
        fail_reads: Mutex<u32>,
        make_err: fn() -> anyhow::Error,
    }

    impl Scripted {
        fn new(fail_reads: u32, make_err: fn() -> anyhow::Error) -> Self {
            Scripted {
                inner: InProcess::new(4),
                fail_reads: Mutex::new(fail_reads),
                make_err,
            }
        }
    }

    impl ExchangeTransport for Scripted {
        fn kind(&self) -> TransportKind {
            self.inner.kind()
        }
        fn publish(&self, ckpt: Checkpoint) -> Result<()> {
            self.inner.publish(ckpt)
        }
        fn fetch(&self, spec: &FetchSpec) -> Result<Option<FetchResult>> {
            let mut left = self.fail_reads.lock().unwrap();
            if *left > 0 {
                *left -= 1;
                return Err((self.make_err)());
            }
            self.inner.fetch(spec)
        }
        fn members(&self) -> Result<Vec<usize>> {
            self.inner.members()
        }
        fn gc(&self) -> Result<()> {
            self.inner.gc()
        }
    }

    #[test]
    fn classifies_known_error_shapes() {
        use std::io::{Error as IoError, ErrorKind};
        let torn = anyhow::Error::from(IoError::new(ErrorKind::UnexpectedEof, "torn"))
            .context("reading DELTA reply");
        assert_eq!(classify_error(&torn), ErrorClass::Transient);
        let refused =
            anyhow::Error::from(IoError::new(ErrorKind::ConnectionRefused, "refused"))
                .context("connecting 127.0.0.1:1");
        assert_eq!(classify_error(&refused), ErrorClass::Transient);
        let injected = anyhow!("injected fetch error for member 3 (read op 7)");
        assert_eq!(classify_error(&injected), ErrorClass::Transient);
        let closed = anyhow!("exchange server closed the connection");
        assert_eq!(classify_error(&closed), ErrorClass::Transient);
        let corrupt = anyhow!(
            "window \"params.w\" digest 0x01 does not match table digest 0x02 — corrupt delta payload"
        );
        assert_eq!(classify_error(&corrupt), ErrorClass::Permanent);
        let malformed = anyhow!("frame claims 10 windows but only 3 bytes remain");
        assert_eq!(classify_error(&malformed), ErrorClass::Permanent);
        let unknown = anyhow!("some novel failure");
        assert_eq!(classify_error(&unknown), ErrorClass::Permanent);
        // io beats text: a permanent marker riding an io::Error chain is
        // still connection-shaped
        let io_wins = anyhow::Error::from(IoError::new(ErrorKind::ConnectionReset, "reset"))
            .context("bad response status said the peer");
        assert_eq!(classify_error(&io_wins), ErrorClass::Transient);
    }

    #[test]
    fn transient_errors_are_absorbed_and_accounted() {
        let scripted = Arc::new(Scripted::new(2, || {
            anyhow!("injected fetch error for member 0 (read op 0)")
        }));
        scripted.publish(ckpt(0, 5, 1.0)).unwrap();
        let retry = Retry::wrap(scripted, RetryPolicy::immediate(5, 1));
        let got = retry.latest(0).unwrap().unwrap();
        assert_eq!(got.step, 5);
        let s = retry.stats();
        assert_eq!((s.ops, s.attempts), (1, 3));
        assert_eq!((s.transient_errors, s.absorbed, s.exhausted), (2, 1, 0));
        assert_eq!(s.permanent_errors, 0);
        assert_eq!(
            retry.retry_log_text(),
            "0 0 1 transient\n0 0 2 transient\n0 0 3 absorbed\n"
        );
    }

    #[test]
    fn permanent_errors_surface_without_retry() {
        let scripted = Arc::new(Scripted::new(99, || anyhow!("corrupt delta payload")));
        scripted.publish(ckpt(0, 5, 1.0)).unwrap();
        let retry = Retry::wrap(scripted.clone(), RetryPolicy::immediate(5, 1));
        let err = retry.latest(0).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt delta payload"));
        let s = retry.stats();
        assert_eq!((s.ops, s.attempts, s.permanent_errors), (1, 1, 1));
        assert_eq!(s.transient_errors, 0);
        // only one scripted failure consumed: no second attempt happened
        assert_eq!(*scripted.fail_reads.lock().unwrap(), 98);
    }

    #[test]
    fn exhausted_budget_surfaces_the_transient_error() {
        let scripted = Arc::new(Scripted::new(99, || {
            anyhow!("injected fetch error for member 0 (read op 0)")
        }));
        scripted.publish(ckpt(0, 5, 1.0)).unwrap();
        let retry = Retry::wrap(scripted, RetryPolicy::immediate(3, 1));
        assert!(retry.latest(0).is_err());
        let s = retry.stats();
        assert_eq!((s.ops, s.attempts), (1, 3));
        assert_eq!((s.transient_errors, s.absorbed, s.exhausted), (3, 0, 1));
    }

    #[test]
    fn empty_reads_retry_under_the_policy_and_give_up_clean() {
        let store = Arc::new(InProcess::new(4));
        let retry = Retry::wrap(store.clone(), RetryPolicy::immediate(3, 1));
        // never-published member: retried, then surfaces as None
        assert!(retry.latest(0).unwrap().is_none());
        let s = retry.stats();
        assert_eq!((s.ops, s.attempts), (1, 3));
        assert_eq!((s.empty_retries, s.exhausted_empty), (3, 1));
        // retry_none off: one attempt, straight None
        let no_retry = Retry::wrap(
            store,
            RetryPolicy {
                retry_none: false,
                ..RetryPolicy::immediate(3, 1)
            },
        );
        assert!(no_retry.latest(0).unwrap().is_none());
        assert_eq!(no_retry.stats().attempts, 1);
    }

    #[test]
    fn absorbs_faulty_drops_and_errors_deterministically() {
        let run = |seed: u64| {
            let faulty = Arc::new(Faulty::wrap(
                Arc::new(InProcess::new(4)),
                FaultPlan::new(seed)
                    .with_dropped_fetches(0.25)
                    .with_erroring_fetches(0.15),
            ));
            faulty.publish(ckpt(0, 7, 1.0)).unwrap();
            let retry = Retry::wrap(faulty.clone(), RetryPolicy::immediate(5, seed));
            let mut ok = 0;
            for _ in 0..64 {
                if retry.latest(0).unwrap().is_some() {
                    ok += 1;
                }
            }
            (ok, retry.stats(), retry.retry_log_text(), faulty.fault_log_text())
        };
        let (ok1, s1, rlog1, flog1) = run(9);
        let (ok2, s2, rlog2, flog2) = run(9);
        assert_eq!(ok1, 64, "retry failed to absorb independent faults");
        assert!(s1.transient_errors + s1.empty_retries > 0, "no faults fired");
        assert!(s1.absorption_rate() >= 0.9, "absorption {}", s1.absorption_rate());
        // byte-identical replay of both logs
        assert_eq!((s1, rlog1.as_bytes(), flog1.as_bytes()), (s2, rlog2.as_bytes(), flog2.as_bytes()));
    }

    #[test]
    fn backoff_is_deterministic_and_bounded() {
        let p = RetryPolicy {
            base_delay: Duration::from_millis(4),
            max_delay: Duration::from_millis(10),
            jitter: 0.5,
            seed: 3,
            ..RetryPolicy::default()
        };
        // no delay before the first attempt
        assert_eq!(p.backoff(0, 1), Duration::ZERO);
        for op in 0..8u64 {
            for attempt in 2..=6u32 {
                let a = p.backoff(op, attempt);
                let b = p.backoff(op, attempt);
                assert_eq!(a, b, "backoff not deterministic");
                assert!(a <= p.max_delay, "backoff {a:?} over cap");
                // jitter 0.5 keeps at least half the exponential delay
                let floor = Duration::from_secs_f64(
                    (p.base_delay.as_secs_f64() * f64::from(2u32.pow(attempt - 2)))
                        .min(p.max_delay.as_secs_f64())
                        * 0.5,
                );
                assert!(a >= floor, "backoff {a:?} under jitter floor {floor:?}");
            }
        }
        // different ops jitter differently
        assert_ne!(p.backoff(0, 3), p.backoff(1, 3));
    }

    #[test]
    fn slow_failed_attempts_count_transient_past_the_deadline() {
        struct Slow;
        impl ExchangeTransport for Slow {
            fn kind(&self) -> TransportKind {
                TransportKind::InProcess
            }
            fn publish(&self, _: Checkpoint) -> Result<()> {
                Ok(())
            }
            fn fetch(&self, _: &FetchSpec) -> Result<Option<FetchResult>> {
                std::thread::sleep(Duration::from_millis(5));
                bail!("some novel failure"); // would classify permanent
            }
            fn members(&self) -> Result<Vec<usize>> {
                Ok(vec![])
            }
            fn gc(&self) -> Result<()> {
                Ok(())
            }
        }
        let retry = Retry::wrap(
            Arc::new(Slow),
            RetryPolicy {
                attempt_deadline: Some(Duration::from_millis(1)),
                ..RetryPolicy::immediate(2, 1)
            },
        );
        assert!(retry.latest(0).is_err());
        let s = retry.stats();
        // both attempts ran: the deadline reclassified the failure
        assert_eq!((s.attempts, s.transient_errors, s.permanent_errors), (2, 2, 0));
    }

    #[test]
    fn flush_and_stats_thread_through_the_stack() {
        let store = Arc::new(InProcess::new(4));
        let faulty = Arc::new(Faulty::wrap(
            store.clone(),
            FaultPlan::new(2).with_delayed_publishes(1.0),
        ));
        let retry = Retry::wrap(faulty, RetryPolicy::immediate(3, 0));
        retry.publish(ckpt(0, 10, 1.0)).unwrap();
        assert!(store.latest(0).is_none(), "delayed publish leaked");
        // flush() reaches Faulty::flush_delayed through the Retry layer
        retry.flush().unwrap();
        assert_eq!(store.latest(0).unwrap().step, 10);
        assert_eq!(retry.retry_stats().unwrap(), retry.stats());
    }
}

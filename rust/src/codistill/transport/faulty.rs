//! Deterministic fault injection for any checkpoint-exchange transport.
//!
//! The paper's §2.2 claim is that codistillation tolerates exactly the
//! failures that break synchronous SGD: stale checkpoint propagation,
//! slow or dead peers, members joining mid-run. None of those scenarios
//! can be *tested* by hoping a real network misbehaves on cue, so
//! [`Faulty`] wraps any [`ExchangeTransport`] and injects faults from a
//! seeded, fully deterministic [`FaultPlan`]:
//!
//! * **Delayed publishes** — with probability `delay_publish_p` (decided
//!   per `(member, step)`) a publication is held back and delivered just
//!   before that member's *next* publish, so readers see one extra
//!   cadence of staleness.
//! * **Dropped / erroring fetches** — a read (any [`FetchSpec`] through
//!   [`ExchangeTransport::fetch`], which `latest`/`latest_at_most`/
//!   `fetch_windows` shim onto) returns `Ok(None)` or `Err` with
//!   probabilities `drop_fetch_p` / `error_fetch_p`, decided per
//!   (member, read-op counter).
//! * **Stale-window reads** — with probability `stale_read_p` a read is
//!   served the publication *before* the freshest one, modelling slow
//!   checkpoint propagation.
//! * **Member blackouts** — scripted `[from_step, until_step)` windows
//!   during which every publication from a member is silently dropped:
//!   the member trains on, but the exchange (and so every peer, and the
//!   liveness table) stops hearing from it.
//!
//! Every decision is a pure function of `(seed, op kind, member, salt)`
//! where the salt is the publish step or a per-member read counter — so a
//! single-threaded run over a `Faulty` transport replays **byte-identical**
//! fault sequences for a given seed, and `tests/coordinator_faults.rs`
//! asserts convergence under each fault class as an ordinary `cargo test`.
//!
//! Metadata heartbeats ([`ExchangeTransport::last_steps`]) pass through
//! un-faulted: faults target checkpoint *payload* movement, while a
//! blackout is still observable through the heartbeat because the dropped
//! publications never advance the member's published step.

use crate::codistill::obs::{Event, Recorder};
use crate::codistill::store::Checkpoint;
use crate::codistill::transport::{
    ExchangeTransport, FetchResult, FetchSpec, TransportKind, ANY_STEP,
};
use crate::prng::Pcg64;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// One scripted blackout: publications from `member` with
/// `from_step <= step < until_step` are dropped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Blackout {
    pub member: usize,
    pub from_step: u64,
    pub until_step: u64,
}

impl Blackout {
    fn covers(&self, member: usize, step: u64) -> bool {
        member == self.member && step >= self.from_step && step < self.until_step
    }
}

/// Seeded fault schedule (see module docs). All probabilities default to
/// 0 and the blackout list to empty, so `FaultPlan::new(seed)` is a
/// transparent plan until faults are switched on.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub seed: u64,
    pub delay_publish_p: f64,
    pub drop_fetch_p: f64,
    pub error_fetch_p: f64,
    pub stale_read_p: f64,
    pub blackouts: Vec<Blackout>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        FaultPlan {
            seed,
            delay_publish_p: 0.0,
            drop_fetch_p: 0.0,
            error_fetch_p: 0.0,
            stale_read_p: 0.0,
            blackouts: Vec::new(),
        }
    }

    pub fn with_delayed_publishes(mut self, p: f64) -> Self {
        self.delay_publish_p = p;
        self
    }

    pub fn with_dropped_fetches(mut self, p: f64) -> Self {
        self.drop_fetch_p = p;
        self
    }

    pub fn with_erroring_fetches(mut self, p: f64) -> Self {
        self.error_fetch_p = p;
        self
    }

    pub fn with_stale_reads(mut self, p: f64) -> Self {
        self.stale_read_p = p;
        self
    }

    pub fn with_blackout(mut self, member: usize, from_step: u64, until_step: u64) -> Self {
        self.blackouts.push(Blackout {
            member,
            from_step,
            until_step,
        });
        self
    }

    /// Deterministic Bernoulli draw keyed on `(seed, kind, member, salt)`.
    fn decide(&self, kind: u64, member: usize, salt: u64, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        if p >= 1.0 {
            return true;
        }
        let stream = kind
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add((member as u64).wrapping_mul(0xbf58476d1ce4e5b9))
            .wrapping_add(salt.wrapping_mul(0x94d049bb133111eb));
        Pcg64::with_stream(self.seed, stream).bernoulli(p)
    }

    fn blackout_at(&self, member: usize, step: u64) -> bool {
        self.blackouts.iter().any(|b| b.covers(member, step))
    }
}

const KIND_DELAY: u64 = 1;
const KIND_DROP: u64 = 2;
const KIND_ERROR: u64 = 3;
const KIND_STALE: u64 = 4;

/// What [`Faulty`] did to one operation (the reproducibility log).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Publication held until the member's next publish.
    DelayedPublish,
    /// Publication silently dropped (scripted blackout).
    BlackoutPublish,
    /// Read answered `Ok(None)`.
    DroppedFetch,
    /// Read answered `Err`.
    ErroredFetch,
    /// Read served the publication before the freshest one.
    StaleRead,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::DelayedPublish => "delayed-publish",
            FaultKind::BlackoutPublish => "blackout-publish",
            FaultKind::DroppedFetch => "dropped-fetch",
            FaultKind::ErroredFetch => "errored-fetch",
            FaultKind::StaleRead => "stale-read",
        }
    }
}

/// One injected fault: what happened, to which member, at which salt
/// (publish step for publish faults, read-op counter for fetch faults).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub kind: FaultKind,
    pub member: usize,
    pub salt: u64,
}

/// Fault-injecting decorator over any exchange transport (see module
/// docs). Construct with [`Faulty::wrap`]; share as
/// `Arc<dyn ExchangeTransport>` like any other backend.
pub struct Faulty {
    inner: Arc<dyn ExchangeTransport>,
    plan: FaultPlan,
    /// Publications held by the delay fault, per member, in publish order.
    delayed: Mutex<HashMap<usize, Vec<Checkpoint>>>,
    /// Per-member read-operation counters (the fetch-fault salt).
    read_ops: Mutex<HashMap<usize, u64>>,
    /// Fault decisions land here as `Event::FaultDecision` journal
    /// entries; defaults to a private `Recorder::sim(plan.seed)`.
    recorder: Recorder,
}

impl Faulty {
    pub fn wrap(inner: Arc<dyn ExchangeTransport>, plan: FaultPlan) -> Self {
        let recorder = Recorder::sim(plan.seed);
        Faulty {
            inner,
            plan,
            delayed: Mutex::new(HashMap::new()),
            read_ops: Mutex::new(HashMap::new()),
            recorder,
        }
    }

    /// Record into a shared (e.g. run-level `--trace`) recorder instead
    /// of the private seeded default.
    pub fn with_recorder(mut self, recorder: Recorder) -> Self {
        self.recorder = recorder;
        self
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Every fault injected so far, in injection order — a view folded
    /// from the journal's fault-decision events.
    pub fn fault_log(&self) -> Vec<FaultEvent> {
        self.recorder.journal().fault_events()
    }

    /// Canonical text rendering of the fault log (one `kind member salt`
    /// line per event) — byte-comparable across runs of the same seed.
    /// Re-derived from the journal through the shared renderer.
    pub fn fault_log_text(&self) -> String {
        self.recorder.journal().fault_log_text()
    }

    /// Deliver every held (delayed) publication to the inner transport.
    /// Runs happily at end-of-run; the coordinator never calls it on the
    /// exchange cadence, so a delayed publish really is late.
    pub fn flush_delayed(&self) -> Result<()> {
        let held: Vec<Checkpoint> = {
            let mut delayed = self.delayed.lock().unwrap();
            let mut all: Vec<Checkpoint> = delayed.drain().flat_map(|(_, v)| v).collect();
            all.sort_by_key(|c| (c.member, c.step));
            all
        };
        for ck in held {
            self.inner.publish(ck)?;
        }
        Ok(())
    }

    fn record(&self, kind: FaultKind, member: usize, salt: u64) {
        self.recorder.record(Event::FaultDecision { kind, member, salt });
    }

    fn next_read_op(&self, member: usize) -> u64 {
        let mut ops = self.read_ops.lock().unwrap();
        let n = ops.entry(member).or_insert(0);
        let salt = *n;
        *n += 1;
        salt
    }

    /// Apply the fetch fault classes shared by every read op. Returns the
    /// read salt when the read should proceed; short-circuits with
    /// `Err`/`Ok(None)` decisions via the returned enum.
    fn read_gate(&self, member: usize) -> Result<ReadGate> {
        let salt = self.next_read_op(member);
        if self.plan.decide(KIND_ERROR, member, salt, self.plan.error_fetch_p) {
            self.record(FaultKind::ErroredFetch, member, salt);
            bail!("injected fetch error for member {member} (read op {salt})");
        }
        if self.plan.decide(KIND_DROP, member, salt, self.plan.drop_fetch_p) {
            self.record(FaultKind::DroppedFetch, member, salt);
            return Ok(ReadGate::Dropped);
        }
        let stale = self.plan.decide(KIND_STALE, member, salt, self.plan.stale_read_p);
        Ok(ReadGate::Proceed { salt, stale })
    }
}

enum ReadGate {
    Dropped,
    Proceed { salt: u64, stale: bool },
}

impl ExchangeTransport for Faulty {
    fn kind(&self) -> TransportKind {
        self.inner.kind()
    }

    fn publish(&self, ckpt: Checkpoint) -> Result<()> {
        let member = ckpt.member;
        let step = ckpt.step;
        if self.plan.blackout_at(member, step) {
            // The member believes it published; the exchange never hears.
            self.record(FaultKind::BlackoutPublish, member, step);
            return Ok(());
        }
        // Anything held from earlier delays lands first (step order is
        // preserved: held steps precede the current one).
        let held: Vec<Checkpoint> = self
            .delayed
            .lock()
            .unwrap()
            .remove(&member)
            .unwrap_or_default();
        for h in held {
            self.inner.publish(h)?;
        }
        if self
            .plan
            .decide(KIND_DELAY, member, step, self.plan.delay_publish_p)
        {
            self.record(FaultKind::DelayedPublish, member, step);
            self.delayed.lock().unwrap().entry(member).or_default().push(ckpt);
            return Ok(());
        }
        self.inner.publish(ckpt)
    }

    /// The one native read: gate it through the fetch fault classes, then
    /// delegate to the wrapped backend — with the staleness bound pulled
    /// one publication behind the freshest on a stale-read fault. Delta
    /// bases pass through untouched: a stale delta is still answered
    /// relative to the reader's basis, so an installed plane stays
    /// byte-identical to a full fetch of whatever (stale) step was
    /// served.
    fn fetch(&self, spec: &FetchSpec) -> Result<Option<FetchResult>> {
        let member = spec.member;
        let (salt, stale) = match self.read_gate(member)? {
            ReadGate::Dropped => return Ok(None),
            ReadGate::Proceed { salt, stale } => (salt, stale),
        };
        if stale {
            // Resolve the freshest step WITHIN the caller's bound with a
            // metadata-only probe — the heartbeat for unbounded reads, a
            // zero-window named fetch (step + tables, no payload) for
            // bounded ones — then serve the one payload read a
            // publication behind it. The fault is only recorded when
            // something older really is served: a degrade-to-clean read
            // must not skew the reproducibility log.
            let fresh_step = if spec.max_step == ANY_STEP {
                self.inner
                    .last_steps()?
                    .into_iter()
                    .find(|&(m, _)| m == member)
                    .map(|(_, s)| s)
            } else {
                self.inner
                    .fetch(&FetchSpec::named(member, spec.max_step, Vec::new()))?
                    .map(|r| r.step)
            };
            if let Some(s) = fresh_step {
                if s > 0 {
                    let mut stale_spec = spec.clone();
                    stale_spec.max_step = s - 1;
                    if let Some(r) = self.inner.fetch(&stale_spec)? {
                        self.record(FaultKind::StaleRead, member, salt);
                        return Ok(Some(r));
                    }
                    // Nothing older retained: degrade to a clean read.
                }
            }
        }
        self.inner.fetch(spec)
    }

    fn members(&self) -> Result<Vec<usize>> {
        self.inner.members()
    }

    fn last_steps(&self) -> Result<Vec<(usize, u64)>> {
        // Heartbeats ride the metadata path un-faulted (module docs).
        self.inner.last_steps()
    }

    fn gc(&self) -> Result<()> {
        self.inner.gc()
    }

    /// End-of-run drain: deliver every held (delayed) publication, then
    /// let the inner transport flush whatever it holds.
    fn flush(&self) -> Result<()> {
        self.flush_delayed()?;
        self.inner.flush()
    }

    fn retry_stats(&self) -> Option<crate::codistill::transport::RetryStats> {
        self.inner.retry_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codistill::transport::InProcess;
    use crate::runtime::{Tensor, TensorMap};

    fn ckpt(member: usize, step: u64, val: f32) -> Checkpoint {
        let mut params = TensorMap::new();
        params.insert("params.w", Tensor::f32(&[2], vec![val, val]).unwrap());
        Checkpoint::new(member, step, params)
    }

    #[test]
    fn transparent_plan_changes_nothing() {
        let faulty = Faulty::wrap(Arc::new(InProcess::new(4)), FaultPlan::new(1));
        faulty.publish(ckpt(0, 5, 1.0)).unwrap();
        faulty.publish(ckpt(0, 9, 2.0)).unwrap();
        assert_eq!(faulty.latest(0).unwrap().unwrap().step, 9);
        assert_eq!(faulty.latest_at_most(0, 5).unwrap().unwrap().step, 5);
        assert_eq!(faulty.members().unwrap(), vec![0]);
        assert_eq!(faulty.last_steps().unwrap(), vec![(0, 9)]);
        assert!(faulty.fault_log().is_empty());
    }

    #[test]
    fn blackout_drops_publishes_in_window_only() {
        let store = Arc::new(InProcess::new(8));
        let faulty = Faulty::wrap(store.clone(), FaultPlan::new(2).with_blackout(1, 10, 20));
        faulty.publish(ckpt(1, 5, 1.0)).unwrap();
        faulty.publish(ckpt(1, 10, 2.0)).unwrap(); // dropped
        faulty.publish(ckpt(1, 19, 3.0)).unwrap(); // dropped
        faulty.publish(ckpt(1, 20, 4.0)).unwrap(); // lands
        assert_eq!(store.latest(1).unwrap().step, 20);
        assert!(InProcess::latest_at_most(&store, 1, 19).unwrap().step == 5);
        let kinds: Vec<FaultKind> = faulty.fault_log().iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![FaultKind::BlackoutPublish, FaultKind::BlackoutPublish]
        );
        // heartbeat froze during the blackout window
        assert_eq!(faulty.last_steps().unwrap(), vec![(1, 20)]);
    }

    #[test]
    fn delayed_publish_lands_before_next_publish() {
        let store = Arc::new(InProcess::new(8));
        // p=1: every publish is delayed one cadence.
        let faulty = Faulty::wrap(store.clone(), FaultPlan::new(3).with_delayed_publishes(1.0));
        faulty.publish(ckpt(0, 10, 1.0)).unwrap();
        assert!(store.latest(0).is_none(), "delayed publish leaked through");
        faulty.publish(ckpt(0, 20, 2.0)).unwrap();
        // the held step-10 checkpoint landed; step 20 is now held
        assert_eq!(store.latest(0).unwrap().step, 10);
        faulty.flush_delayed().unwrap();
        assert_eq!(store.latest(0).unwrap().step, 20);
    }

    #[test]
    fn stale_reads_serve_the_previous_publication() {
        let store = Arc::new(InProcess::new(8));
        let faulty = Faulty::wrap(store.clone(), FaultPlan::new(4).with_stale_reads(1.0));
        faulty.publish(ckpt(0, 10, 1.0)).unwrap();
        // only one publication retained: fault degrades to a clean read
        assert_eq!(faulty.latest(0).unwrap().unwrap().step, 10);
        faulty.publish(ckpt(0, 20, 2.0)).unwrap();
        assert_eq!(faulty.latest(0).unwrap().unwrap().step, 10);
        let f = faulty
            .fetch_windows(0, u64::MAX, &["params.w".to_string()])
            .unwrap()
            .unwrap();
        assert_eq!(f.step, 10);
        assert!(faulty
            .fault_log()
            .iter()
            .any(|e| e.kind == FaultKind::StaleRead));
    }

    #[test]
    fn stale_faults_apply_to_bounded_reads() {
        let store = Arc::new(InProcess::new(8));
        let faulty = Faulty::wrap(store, FaultPlan::new(5).with_stale_reads(1.0));
        for s in [10u64, 20, 30] {
            faulty.publish(ckpt(0, s, s as f32)).unwrap();
        }
        // bounded read: freshest within 20 is step 20, stale serves 10 —
        // the bound-relative semantics, not "bound already excludes the
        // absolute freshest, so no fault"
        assert_eq!(faulty.latest_at_most(0, 20).unwrap().unwrap().step, 10);
        assert!(faulty
            .fault_log()
            .iter()
            .any(|e| e.kind == FaultKind::StaleRead));
        // nothing older than the bounded-freshest retained: degrade to a
        // clean bounded read, and don't log a fault for it
        let before = faulty.fault_log().len();
        assert_eq!(faulty.latest_at_most(0, 10).unwrap().unwrap().step, 10);
        assert_eq!(faulty.fault_log().len(), before);
    }

    #[test]
    fn delta_reads_through_faults_stay_byte_identical() {
        use crate::codistill::transport::DeltaCache;
        let store = Arc::new(InProcess::new(8));
        let faulty = Faulty::wrap(store.clone(), FaultPlan::new(6).with_stale_reads(1.0));
        let mut cache = DeltaCache::new();
        faulty.publish(ckpt(0, 10, 1.0)).unwrap();
        faulty.publish(ckpt(0, 20, 2.0)).unwrap();
        // stale fault: the cache installs step 10, not 20 — and its bytes
        // equal a direct read of step 10
        let got = cache.latest(&faulty, 0).unwrap().unwrap();
        assert_eq!(got.step, 10);
        let direct = InProcess::latest_at_most(&store, 0, 10).unwrap();
        assert_eq!(got.flat().data(), direct.flat().data());
        // the next read sends the installed step-10 basis; the fault
        // serves step 20, still byte-identical to a full fetch of it
        faulty.publish(ckpt(0, 30, 3.0)).unwrap();
        let got = cache.latest(&faulty, 0).unwrap().unwrap();
        assert_eq!(got.step, 20);
        let direct = InProcess::latest_at_most(&store, 0, 20).unwrap();
        assert_eq!(got.flat().data(), direct.flat().data());
        assert!(cache.stats().delta_fetches >= 1);
        assert!(faulty
            .fault_log()
            .iter()
            .any(|e| e.kind == FaultKind::StaleRead));
    }

    #[test]
    fn drop_and_error_fetch_rates_are_deterministic() {
        let run = |seed: u64| -> (Vec<bool>, Vec<bool>) {
            let faulty = Faulty::wrap(
                Arc::new(InProcess::new(4)),
                FaultPlan::new(seed)
                    .with_dropped_fetches(0.4)
                    .with_erroring_fetches(0.2),
            );
            faulty.publish(ckpt(0, 1, 1.0)).unwrap();
            let mut dropped = Vec::new();
            let mut errored = Vec::new();
            for _ in 0..64 {
                match faulty.latest(0) {
                    Ok(Some(_)) => {
                        dropped.push(false);
                        errored.push(false);
                    }
                    Ok(None) => {
                        dropped.push(true);
                        errored.push(false);
                    }
                    Err(_) => {
                        dropped.push(false);
                        errored.push(true);
                    }
                }
            }
            (dropped, errored)
        };
        let (d1, e1) = run(7);
        let (d2, e2) = run(7);
        assert_eq!(d1, d2, "same seed must replay the same drops");
        assert_eq!(e1, e2, "same seed must replay the same errors");
        let drops = d1.iter().filter(|&&b| b).count();
        let errs = e1.iter().filter(|&&b| b).count();
        assert!(drops > 0 && drops < 64, "drop rate degenerate: {drops}/64");
        assert!(errs > 0 && errs < 64, "error rate degenerate: {errs}/64");
        let (d3, _) = run(8);
        assert_ne!(d1, d3, "different seeds must differ");
    }

    #[test]
    fn fault_log_text_is_canonical() {
        let faulty = Faulty::wrap(
            Arc::new(InProcess::new(4)),
            FaultPlan::new(5).with_blackout(2, 0, 100),
        );
        faulty.publish(ckpt(2, 10, 1.0)).unwrap();
        faulty.publish(ckpt(2, 20, 2.0)).unwrap();
        assert_eq!(
            faulty.fault_log_text(),
            "blackout-publish 2 10\nblackout-publish 2 20\n"
        );
    }
}

//! Lossy quantizing window codecs for the checkpoint exchange.
//!
//! The paper's core observation is that online distillation tolerates
//! stale, *imprecise* teacher weights — checkpoints "only rarely get
//! transmitted" and runs still converge — so the exchange can drop
//! precision, not just pack bytes. These codecs quantize a window's f32s
//! down to 16 or 8 bits per element; the dequantized window the reader
//! installs is *not* bit-identical to the training job's plane.
//!
//! Two codecs:
//!
//! * [`Fp16Codec`] (wire id 2) — IEEE-754 binary16 with round-to-nearest
//!   -even. 2 bytes/elem, no header. Worst-case relative error is
//!   2^-11 (~4.9e-4) for normal values; values outside f16 range clamp
//!   to ±inf, NaNs collapse to the canonical quiet NaN.
//! * [`Int8Codec`] (wire id 3) — per-window symmetric linear
//!   quantization to i8 in [-127, 127] with one power-of-two scale
//!   stored as an f32 header. 4 + n bytes for n elems. Absolute error
//!   per element is bounded by `scale / 2` where
//!   `scale = 2^ceil(log2(amax / 127))` and `amax` is the window's
//!   largest finite magnitude; non-finite inputs map to 0 (NaN) or ±127
//!   (±inf).
//!
//! **Decode is exact.** Both codecs dequantize deterministically —
//! f16→f32 widening is exact, `i8 * 2^e` is exact — so any two readers
//! decode identical bytes to identical f32s and digest verification over
//! the *decoded* payload still fails loudly on corruption.
//!
//! **Encode is value-idempotent on dequantized planes.** Feeding a
//! codec's own output back through `encode` reproduces it bit-for-bit:
//! every f16 value is its own nearest f16, and with power-of-two scales
//! every `q * 2^e` re-quantizes exactly even if the second pass picks a
//! smaller scale. This is what lets the publisher quantize ONCE (see
//! `transport::feedback::ErrorFeedback`) and publish the dequantized
//! plane: every transport hop after that — spool files, socket frames,
//! relays re-encoding for downstream readers — is lossless in effect,
//! enforced mechanically by [`super::Codec::encode`]'s exact-or-raw
//! check.

use anyhow::{bail, Result};

use super::WindowCodec;

// ------------------------------------------------------------- fp16

/// IEEE-754 binary16 quantizer (wire id 2): 2 bytes/elem, RNE rounding.
pub struct Fp16Codec;

impl WindowCodec for Fp16Codec {
    fn id(&self) -> u8 {
        2
    }

    fn name(&self) -> &'static str {
        "fp16"
    }

    fn encode(&self, data: &[f32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(data.len() * 2);
        for v in data {
            out.extend_from_slice(&f32_to_f16_bits(*v).to_le_bytes());
        }
        out
    }

    fn decode(&self, bytes: &[u8], elems: usize) -> Result<Vec<f32>> {
        if bytes.len() != elems * 2 {
            bail!(
                "fp16 window payload has {} bytes, {elems} elems need {}",
                bytes.len(),
                elems * 2
            );
        }
        Ok(bytes
            .chunks_exact(2)
            .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
            .collect())
    }
}

/// f32 → binary16 bits with round-to-nearest-even. Overflow → ±inf,
/// underflow past the smallest subnormal → ±0, NaN → canonical quiet
/// NaN (payload dropped — a lossy codec keeps values, not diagnostics).
pub(crate) fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        return if man != 0 { sign | 0x7e00 } else { sign | 0x7c00 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow to inf
    }
    if e <= 0 {
        // f16 subnormal (or underflow to zero): shift the full 24-bit
        // significand down so the implicit bit lands at its subnormal
        // position, rounding to nearest even on the dropped bits.
        if e < -10 {
            return sign; // below half the smallest subnormal
        }
        let man = man | 0x0080_0000;
        let shift = (14 - e) as u32; // 14..=24
        let half = (man >> shift) as u16;
        let round = 1u32 << (shift - 1);
        if man & round != 0 && (man & (round - 1) != 0 || half & 1 != 0) {
            return sign | (half + 1); // may carry into the normal range: correct
        }
        return sign | half;
    }
    let half = ((e as u16) << 10) | (man >> 13) as u16;
    let round = 1u32 << 12;
    if man & round != 0 && (man & (round - 1) != 0 || half & 1 != 0) {
        return sign | (half + 1); // mantissa carry rolls the exponent: correct (incl. → inf)
    }
    sign | half
}

/// binary16 bits → f32. Exact: every f16 value is representable in f32.
pub(crate) fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
    }
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign);
        }
        // subnormal: man * 2^-24, exact in f32
        let v = man as f32 * f32::from_bits(0x3380_0000);
        return if sign != 0 { -v } else { v };
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

// ------------------------------------------------------------- int8

/// Per-window symmetric i8 quantizer (wire id 3): a 4-byte LE f32 scale
/// header, then one i8 per element. `x → round(x / scale)` clamped to
/// [-127, 127]; `q → q * scale` back.
pub struct Int8Codec;

impl WindowCodec for Int8Codec {
    fn id(&self) -> u8 {
        3
    }

    fn name(&self) -> &'static str {
        "int8"
    }

    fn encode(&self, data: &[f32]) -> Vec<u8> {
        let scale = int8_scale(data);
        let mut out = Vec::with_capacity(4 + data.len());
        out.extend_from_slice(&scale.to_le_bytes());
        let s = scale as f64;
        for &x in data {
            // clamp BEFORE the cast: the saturating f64→i8 cast would
            // send -inf to -128, outside the symmetric range (NaN →
            // clamp keeps NaN → cast gives 0, which is what we want)
            let q = (x as f64 / s).round().clamp(-127.0, 127.0) as i8;
            out.push(q as u8);
        }
        out
    }

    fn decode(&self, bytes: &[u8], elems: usize) -> Result<Vec<f32>> {
        if bytes.len() != 4 + elems {
            bail!(
                "int8 window payload has {} bytes, {elems} elems need {}",
                bytes.len(),
                4 + elems
            );
        }
        let scale = f32::from_le_bytes(bytes[..4].try_into().unwrap());
        if !scale.is_finite() || scale <= 0.0 {
            bail!("int8 window header carries invalid scale {scale}");
        }
        Ok(bytes[4..].iter().map(|&b| b as i8 as f32 * scale).collect())
    }
}

/// The window's quantization step: the smallest power of two `2^e ≥
/// amax / 127` (so every finite magnitude fits in [-127, 127]), with
/// `e` clamped to f32's representable range. Power-of-two scales make
/// dequantization (`q * 2^e`) and re-quantization exact — the
/// value-idempotence the module docs rely on. An all-zero (or
/// all-non-finite) window gets scale 1.0.
fn int8_scale(data: &[f32]) -> f32 {
    let mut amax = 0f32;
    for &x in data {
        if x.is_finite() {
            amax = amax.max(x.abs());
        }
    }
    if amax == 0.0 {
        return 1.0;
    }
    let target = amax as f64 / 127.0;
    let mut e = target.log2().ceil() as i32;
    while e > -149 && ((e - 1) as f64).exp2() >= target {
        e -= 1;
    }
    while e < 127 && (e as f64).exp2() < target {
        e += 1;
    }
    (e.clamp(-149, 127) as f64).exp2() as f32
}

#[cfg(test)]
mod tests {
    use super::super::Codec;
    use super::*;

    #[test]
    fn f16_conversion_hits_the_known_landmarks() {
        // (f32 input, expected f16 bits)
        let cases: &[(f32, u16)] = &[
            (0.0, 0x0000),
            (-0.0, 0x8000),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (65504.0, 0x7bff),       // f16 max
            (65536.0, 0x7c00),       // overflow → inf
            (f32::INFINITY, 0x7c00),
            (f32::NEG_INFINITY, 0xfc00),
            (6.103_515_6e-5, 0x0400), // smallest f16 normal, 2^-14
            (5.960_464_5e-8, 0x0001), // smallest f16 subnormal, 2^-24
            (2.980_232_2e-8, 0x0000), // exactly half the smallest: RNE → even (0)
            (1e-10, 0x0000),          // deep underflow → 0
            (0.1, 0x2e66),            // RNE on a repeating fraction
        ];
        for &(x, want) in cases {
            assert_eq!(f32_to_f16_bits(x), want, "converting {x}");
        }
        assert_eq!(f32_to_f16_bits(f32::NAN) & 0x7e00, 0x7e00);
        // widening every f16 bit pattern and re-narrowing is identity
        // (NaNs collapse to canonical but stay NaN)
        for h in 0..=u16::MAX {
            let back = f32_to_f16_bits(f16_bits_to_f32(h));
            let exp = (h >> 10) & 0x1f;
            let man = h & 0x3ff;
            if exp == 0x1f && man != 0 {
                assert_eq!(back & 0x7e00, 0x7e00, "NaN {h:#x} must stay NaN");
                assert_eq!(back & 0x8000, h & 0x8000, "NaN {h:#x} keeps its sign");
            } else {
                assert_eq!(back, h, "f16 {h:#x} not a fixed point");
            }
        }
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 1.0 + 2^-11 sits exactly between 1.0 and the next f16
        // (1.0 + 2^-10): RNE picks the even mantissa (1.0)
        assert_eq!(f32_to_f16_bits(1.0 + 0.000_488_281_25), 0x3c00);
        // one ulp above the midpoint rounds up
        assert_eq!(
            f32_to_f16_bits(f32::from_bits((1.0f32 + 0.000_488_281_25).to_bits() + 1)),
            0x3c01
        );
        // next midpoint (between 0x3c01 and 0x3c02) rounds UP to even
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 0.000_488_281_25), 0x3c02);
    }

    #[test]
    fn int8_scale_is_a_power_of_two_covering_amax() {
        for amax in [1.0f32, 0.1, 127.0, 1e-30, 3.4e38, 0.5, 126.9] {
            let s = int8_scale(&[amax, -amax / 2.0]);
            // power of two: one mantissa bit
            let m = s.to_bits() & 0x007f_ffff;
            let e = (s.to_bits() >> 23) & 0xff;
            assert!(
                (e > 0 && m == 0) || (e == 0 && m.count_ones() == 1),
                "scale {s} for amax {amax} is not a power of two"
            );
            assert!(s as f64 * 127.0 >= amax as f64, "amax {amax} overflows scale {s}");
            // not gratuitously coarse: half the scale would not cover
            if s > f32::MIN_POSITIVE {
                assert!(
                    (s as f64 / 2.0) * 127.0 < amax as f64,
                    "scale {s} for amax {amax} is coarser than needed"
                );
            }
        }
        assert_eq!(int8_scale(&[0.0, -0.0]), 1.0);
        assert_eq!(int8_scale(&[f32::NAN, f32::INFINITY]), 1.0);
        assert_eq!(int8_scale(&[]), 1.0);
    }

    #[test]
    fn int8_error_is_within_half_a_scale() {
        let data: Vec<f32> = (0..512).map(|i| (i as f32 - 256.0) * 0.003).collect();
        let enc = Int8Codec.encode(&data);
        let scale = f32::from_le_bytes(enc[..4].try_into().unwrap());
        let back = Int8Codec.decode(&enc, data.len()).unwrap();
        for (x, y) in data.iter().zip(&back) {
            assert!(
                (x - y).abs() as f64 <= scale as f64 / 2.0 + 1e-12,
                "|{x} - {y}| > scale/2 ({scale})"
            );
        }
    }

    #[test]
    fn int8_nonfinite_inputs_quantize_cleanly() {
        let data = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.0, -1.0];
        let enc = Int8Codec.encode(&data);
        let back = Int8Codec.decode(&enc, data.len()).unwrap();
        let scale = f32::from_le_bytes(enc[..4].try_into().unwrap());
        assert_eq!(back[0], 0.0); // NaN → 0
        assert_eq!(back[1], 127.0 * scale); // +inf clamps to the top code
        assert_eq!(back[2], -127.0 * scale); // -inf to the bottom (NOT -128)
        assert_eq!(back[3], 1.0);
        assert_eq!(back[4], -1.0);
    }

    #[test]
    fn lossy_codecs_are_idempotent_on_their_own_output() {
        let data: Vec<f32> = (0..300)
            .map(|i| ((i * 37 % 101) as f32 - 50.0) * 0.013 + 0.1)
            .collect();
        for codec in [Codec::Fp16, Codec::Int8] {
            let first = codec.imp().decode(&codec.imp().encode(&data), data.len()).unwrap();
            let again = codec
                .imp()
                .decode(&codec.imp().encode(&first), first.len())
                .unwrap();
            let a: Vec<u32> = first.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = again.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "{} not idempotent", codec.name());
            // and the registry-level encode agrees it is exact: the
            // dequantized plane re-ships under the lossy tag
            let (tag, _) = codec.encode(&first);
            assert_eq!(tag, codec, "{} exact-or-raw rejected its own output", codec.name());
        }
    }

    #[test]
    fn int8_rescale_of_own_output_stays_exact() {
        // A dequantized window whose max |q| < 64 makes the second
        // encode pick a smaller power-of-two scale; values must still
        // re-quantize exactly (q * 2^m with the finer scale).
        let enc = Int8Codec.encode(&[0.1f32; 16]); // q = 102 everywhere
        let once = Int8Codec.decode(&enc, 16).unwrap();
        let small: Vec<f32> = once.iter().map(|v| v / 4.0).collect(); // exact: /2^2
        let (tag, bytes) = Codec::Int8.encode(&small);
        assert_eq!(tag, Codec::Int8);
        let back = Codec::Int8.decode(&bytes, 16).unwrap();
        assert_eq!(
            small.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            back.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn wire_layout_and_length_checks() {
        let data = [0.5f32, -0.25, 0.125];
        let f = Fp16Codec.encode(&data);
        assert_eq!(f.len(), 6);
        let i = Int8Codec.encode(&data);
        assert_eq!(i.len(), 7);
        assert!(Fp16Codec.decode(&f, 2).is_err());
        assert!(Fp16Codec.decode(&f[..5], 3).is_err());
        assert!(Int8Codec.decode(&i, 2).is_err());
        assert!(Int8Codec.decode(&i[..6], 3).is_err());
        // invalid scale headers are protocol errors, not NaN planes
        for bad in [0.0f32, -1.0, f32::NAN, f32::INFINITY] {
            let mut c = i.clone();
            c[..4].copy_from_slice(&bad.to_le_bytes());
            assert!(Int8Codec.decode(&c, 3).is_err(), "scale {bad} accepted");
        }
    }
}

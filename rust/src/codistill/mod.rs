//! The paper's contribution: codistillation as a distributed training
//! algorithm (Algorithm 1 + §2.1).
//!
//! `n` members (each a model copy, or a whole sync-SGD worker group) train
//! in parallel; after a burn-in period each member adds
//! `ψ(mean_{j≠i} F(θ_j, x), F(θ_i, x))` to its loss, where the `θ_j` are
//! **stale** copies read from a checkpoint exchange on a configurable
//! reload interval. Prediction staleness is the delay-tolerant
//! communication channel that lets the algorithm scale past sync-SGD's
//! limits.
//!
//! ## The checkpoint exchange
//!
//! The exchange is split into a value type and a medium:
//!
//! * [`store`] defines [`Checkpoint`] — an immutable `Arc<FlatBuffer>`
//!   parameter snapshot — and its `CKPT0003` encoding (a window table
//!   with per-window content digests, then the whole flat plane as one
//!   contiguous byte slice; `CKPT0002`/`CKPT0001` still read). The same
//!   bytes serve as the disk format and the socket wire format.
//! * [`transport`] defines [`ExchangeTransport`] around one unified,
//!   delta-aware read — `fetch(FetchSpec) -> FetchResult` — plus
//!   `publish` / `members` / `gc` / `last_steps`; `latest` /
//!   `latest_at_most` / `fetch_windows` are shims over `fetch`. Three
//!   interchangeable backends implement it natively: [`InProcess`]
//!   (zero-copy shared buffers), [`SpoolDir`] (`CKPT0003` files + atomic
//!   digest-carrying `MANIFEST` in a shared directory; readers `pread`
//!   only changed windows), and [`SocketTransport`]/[`SocketServer`]
//!   (length-prefixed TCP/Unix protocol with a `DELTA` opcode: basis
//!   digests up, changed windows down). [`DeltaCache`] is the reader
//!   side: per-teacher installed planes patched in place, byte-identical
//!   to full fetches while moving only what changed. On top of the delta
//!   sits the lossless [`transport::codec`] layer ([`Codec`] /
//!   [`WindowCodec`]): per-window byteshuffle+RLE encoding negotiated
//!   end-to-end (`CKPT0004` spool files, a capability byte on the socket
//!   `DELTA`/`FETCH` requests, `--compress` from the CLI), decoded and
//!   digest-verified at install so compression can never change the
//!   installed bytes or mask corruption.
//!
//! The [`Orchestrator`] is constructed from any `Arc<dyn
//! ExchangeTransport>` ([`Orchestrator::with_transport`]) and feeds
//! [`Member::set_teachers`] exclusively from transport reads, so the same
//! run rides any medium; `codistill --transport {inproc,spool,socket}`
//! selects one from the CLI.
//!
//! Exchange payloads can ride a lossless codec (`--compress`, byte
//! shuffle + RLE) or a lossy quantizer (`codec=fp16|int8`) whose
//! quantization error is applied **once, publisher-side** by
//! [`ErrorFeedback`]: the published plane already holds the dequantized
//! values, every digest is a round-trip digest, and `--error-feedback`
//! carries each window's residual into the next publish so the
//! quantization bias telescopes instead of accumulating (see
//! [`transport::feedback`]).
//!
//! ## Orchestrator vs Coordinator
//!
//! [`Orchestrator`] is the paper's Algorithm 1 in lockstep: every member
//! steps, reloads, and publishes together — right for the algorithmic
//! figures. [`Coordinator`] is the §2.2 systems story: each coordinator
//! (one per process or thread) hosts a *subset* of members with
//! per-member publish cadences, a publish-recency [`LivenessTable`],
//! mid-run joins ([`Member::bootstrap`]), and fault-tolerant exchange
//! calls — run it over a [`transport::Faulty`]-wrapped backend to make
//! every failure mode a deterministic test (`codistill coordinate` from
//! the CLI; `tests/coordinator_faults.rs` in the suite).
//!
//! ## The serving tier
//!
//! [`serve`] closes the loop from training to traffic: an
//! [`InferenceServer`] batches requests over the distilled model's
//! installed plane behind an atomic [`SwapHandle`], while a
//! [`transport::subscribe`] loop follows the run's publications over
//! any transport (delta-aware, digest-verified) and hot-swaps fresh
//! planes in mid-traffic — zero downtime, no request ever sees a torn
//! plane. `codistill serve` drives it from the CLI.
//!
//! ### A two-process spool-dir exchange
//!
//! ```sh
//! # terminal 1: member group 0 publishes into / reads from ./exchange
//! codistill codistill --transport spool --set spool_dir=./exchange
//! # terminal 2: a second coordinator on the same directory
//! codistill codistill --transport spool --set spool_dir=./exchange
//! ```
//!
//! Both processes write `memberNNNN_stepNNN...N.ckpt` files (zero-padded
//! so directory order equals step order, temp+rename so never torn) and
//! converge on the atomic `MANIFEST`; `gc` bounds the files each member
//! keeps.

pub mod coordinator;
pub mod obs;
pub mod orchestrator;
pub mod scenario;
pub mod schedule;
pub mod serve;
pub mod store;
pub mod topology;
pub mod transport;

pub use coordinator::{
    Coordinator, CoordinatorConfig, CoordinatorLog, HostedMember, JoinRecord, LivenessTable,
};
pub use obs::{Clock, Event, EventJournal, Recorder, SimClock, TimedEvent, WallClock};
pub use orchestrator::{Orchestrator, OrchestratorConfig, RunLog};
pub use scenario::{CompiledScenario, MemberSchedule, Scenario, ScenarioEvent};
pub use schedule::{DistillSchedule, LrSchedule};
pub use serve::{
    BatchPolicy, InferRequest, InferResponse, InferenceServer, ServeConfig, ServeStats,
    ServingModel, ServingPlane, SwapHandle,
};
pub use store::Checkpoint;
pub use topology::Topology;
pub use transport::{
    Basis, Codec, DeltaCache, DeltaStats, ErrorFeedback, ExchangeTransport, FaultPlan, Faulty,
    FeedbackStats, FetchResult, FetchSpec, InProcess, Relay, RelayConfig, RelayStats, Retry,
    RetryPolicy, RetryStats, SocketServer, SocketTransport, SpoolDir, SubscribeConfig,
    SubscribeStats, Subscription, TransportKind, WindowCodec, WindowSel, WindowedFetch,
};

/// The zero-copy in-process store under its historical name (it was the
/// only exchange before the transport split).
pub use transport::InProcess as CheckpointStore;

use crate::runtime::TensorMap;
use anyhow::Result;

/// Per-step statistics reported by a member.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    /// Member-local step index (1-based after the step completes).
    pub step: u64,
    /// Hard-label loss φ (mean over the batch).
    pub loss: f32,
    /// Distillation loss ψ (mean over the batch; 0 when disabled).
    pub distill_loss: f32,
}

/// Validation statistics.
#[derive(Debug, Clone, Copy)]
pub struct EvalStats {
    /// Mean per-example (or per-token) validation loss.
    pub loss: f64,
    /// Top-1 accuracy where defined (images), else None.
    pub accuracy: Option<f64>,
}

/// One codistilling participant: a model copy plus its data shard,
/// optimizer state, and locally-held stale teacher copies.
pub trait Member {
    /// Run one training step. `distill_w` is the ψ weight for this step
    /// (0 during burn-in); `lr` comes from the orchestrator's schedule.
    fn train_step(&mut self, distill_w: f32, lr: f32) -> Result<StepStats>;

    /// Snapshot current parameters for publication to the store.
    fn snapshot(&self) -> Result<Checkpoint>;

    /// Install stale peer checkpoints as this member's teachers. The
    /// member averages the teachers' predictions when computing ψ
    /// (Algorithm 1's `1/(N-1) Σ_{j≠i}`).
    fn set_teachers(&mut self, peers: Vec<std::sync::Arc<Checkpoint>>) -> Result<()>;

    /// Adopt a peer checkpoint's parameters as this member's own — the
    /// §2.2 mid-run join: a member added to (or replaced in) a running
    /// job seeds itself from the freshest available peer snapshot instead
    /// of a cold init. Default: keep the cold init (snapshot ignored).
    fn bootstrap(&mut self, ck: &Checkpoint) -> Result<()> {
        let _ = ck;
        Ok(())
    }

    /// Evaluate on the member's validation stream.
    fn evaluate(&mut self) -> Result<EvalStats>;

    /// Steps taken so far.
    fn steps_done(&self) -> u64;

    /// Current parameters (for churn measurement and tests).
    fn params(&self) -> &TensorMap;
}

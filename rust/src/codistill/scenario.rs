//! Declarative churn scenarios: named failure patterns compiled into a
//! [`FaultPlan`] plus per-member join/leave/cadence schedules for the
//! [`Coordinator`](crate::codistill::Coordinator).
//!
//! Hand-rolling churn for three members is fine (`join_delays=0,0,60`,
//! `fault_blackout=1:45:56`); at a hundred members it is not. A scenario
//! file names the *pattern* and the compiler expands it over the fleet:
//!
//! ```text
//! # preempt a quarter of the fleet at tick 30, staggered rejoins
//! seed = 11
//! members = 100
//!
//! [spot_wave]
//! at = 30          # tick the wave hits
//! fraction = 0.25  # fraction of the fleet preempted
//! down = 25        # ticks each victim stays gone
//! stagger = 1      # extra down ticks per victim rank (staggered rejoin)
//!
//! [flaky_net]
//! drop_p = 0.2     # per-read dropped-fetch probability
//! error_p = 0.1    # per-read erroring-fetch probability
//! ```
//!
//! The grammar is a deliberately tiny TOML subset, parsed with no
//! dependencies: `#` comments, top-level `key = value` lines (`seed`,
//! `members`), and repeatable `[section]` blocks, one per event. Values
//! are integers, floats, or `lo..hi` half-open ranges. Sections:
//!
//! * `[spot_wave]` — correlated preemption: a seeded-random `fraction` of
//!   the fleet goes down at tick `at` for `down` ticks, rejoining
//!   staggered by `stagger` ticks per victim rank. Victims stop training
//!   and publishing entirely (their liveness heartbeat freezes) and
//!   re-bootstrap from a live peer on return.
//! * `[zone_outage]` — `zone = lo..hi` members keep training but every
//!   publication with step in `from..until` is blacked out (a
//!   [`FaultPlan`] blackout per zone member): the exchange — and every
//!   peer — stops hearing from the zone.
//! * `[flash_crowd]` — the `joiners` highest-indexed members all join at
//!   tick `at` and bootstrap at once.
//! * `[diurnal]` — publish-cadence oscillation across the fleet: member
//!   `i`'s publish interval follows an integer triangle wave from `base`
//!   to `base + amplitude` with period `period` members, phase-offset by
//!   its index.
//! * `[flaky_net]` — elevated random fault probabilities (`drop_p`,
//!   `error_p`, `stale_p`, `delay_p`) folded into the [`FaultPlan`]
//!   (max-combined when repeated).
//!
//! **Determinism.** Compilation is a pure function of (scenario text,
//! seed, member count): victim selection draws from a
//! [`Pcg64`] stream keyed on the seed and event index, cadences are
//! integer arithmetic, and the compiled [`FaultPlan`] inherits the
//! scenario seed — so the same scenario file + seed replays byte-identical
//! staleness, fault, and retry logs
//! (`CoordinatorLog::staleness_log_text`, `Faulty::fault_log_text`,
//! `Retry::retry_log_text`). `tests/scenario_churn.rs` pins exactly that
//! at 100 members.

use crate::codistill::coordinator::HostedMember;
use crate::codistill::transport::FaultPlan;
use crate::netsim::ClusterModel;
use crate::prng::Pcg64;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// One named churn pattern (see module docs for file syntax).
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioEvent {
    /// Correlated preemption of a seeded-random member subset.
    SpotWave {
        at: u64,
        fraction: f64,
        down: u64,
        stagger: u64,
    },
    /// Publication blackout of a contiguous member range `[zone.0, zone.1)`
    /// over the published-step window `[from, until)`.
    ZoneOutage {
        zone: (usize, usize),
        from: u64,
        until: u64,
    },
    /// Burst of mid-run joins: the `joiners` highest-indexed members all
    /// join at tick `at`.
    FlashCrowd { at: u64, joiners: usize },
    /// Publish-cadence oscillation over member index.
    Diurnal { base: u64, amplitude: u64, period: u64 },
    /// Elevated random fault probabilities.
    FlakyNet {
        drop_p: f64,
        error_p: f64,
        stale_p: f64,
        delay_p: f64,
    },
}

impl ScenarioEvent {
    /// Section name this event parses from / prices as.
    pub fn name(&self) -> &'static str {
        match self {
            ScenarioEvent::SpotWave { .. } => "spot_wave",
            ScenarioEvent::ZoneOutage { .. } => "zone_outage",
            ScenarioEvent::FlashCrowd { .. } => "flash_crowd",
            ScenarioEvent::Diurnal { .. } => "diurnal",
            ScenarioEvent::FlakyNet { .. } => "flaky_net",
        }
    }
}

/// A parsed scenario: seed, fleet size, and the event list in file order.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub seed: u64,
    /// Fleet size the file declares; 0 = inherit the caller's count.
    pub members: usize,
    pub events: Vec<ScenarioEvent>,
}

/// Per-member schedule produced by compilation, applied onto a
/// [`HostedMember`] with [`MemberSchedule::apply_to`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MemberSchedule {
    /// Global member id this schedule is for.
    pub member: usize,
    /// Coordinator ticks to sit out before joining (0 = from the start).
    pub join_delay: u64,
    /// `[from_tick, until_tick)` windows during which the member is gone
    /// (preempted): no training, no publishing, re-bootstrap on return.
    pub downtimes: Vec<(u64, u64)>,
    /// Publish cadence override, when an event (diurnal) sets one.
    pub publish_interval: Option<u64>,
    pub publish_offset: u64,
}

impl MemberSchedule {
    /// Overlay this schedule onto a hosted member.
    pub fn apply_to(&self, h: &mut HostedMember) {
        h.join_delay = self.join_delay;
        h.downtimes.extend(self.downtimes.iter().copied());
        if let Some(p) = self.publish_interval {
            h.publish_interval = p.max(1);
            h.publish_offset = self.publish_offset;
        }
    }
}

/// A scenario expanded over a concrete fleet: the fault plan for the
/// transport and one schedule per member, ids `base..base + members`.
#[derive(Debug, Clone)]
pub struct CompiledScenario {
    pub seed: u64,
    pub members: usize,
    pub plan: FaultPlan,
    pub schedules: Vec<MemberSchedule>,
}

impl CompiledScenario {
    /// Overlay the schedules onto a hosted fleet, in order: `hosted[i]`
    /// gets the schedule of scenario member `i`. Fleets larger than the
    /// scenario keep their existing settings past the end.
    pub fn apply(&self, hosted: &mut [HostedMember]) {
        for (h, s) in hosted.iter_mut().zip(&self.schedules) {
            s.apply_to(h);
        }
    }

    /// Whether any random fault probability or blackout is active (i.e.
    /// whether wrapping the transport in `Faulty` is worthwhile).
    pub fn has_faults(&self) -> bool {
        !self.plan.blackouts.is_empty()
            || self.plan.drop_fetch_p > 0.0
            || self.plan.error_fetch_p > 0.0
            || self.plan.stale_read_p > 0.0
            || self.plan.delay_publish_p > 0.0
    }
}

impl Scenario {
    /// Parse a scenario from text (see module docs for the grammar).
    pub fn parse(text: &str) -> Result<Scenario> {
        let mut scenario = Scenario {
            seed: 0,
            members: 0,
            events: Vec::new(),
        };
        let mut section: Option<(String, HashMap<String, String>, usize)> = None;
        let mut finish =
            |sec: Option<(String, HashMap<String, String>, usize)>, out: &mut Vec<ScenarioEvent>| {
                match sec {
                    None => Ok(()),
                    Some((name, keys, line_no)) => {
                        let ev = build_event(&name, &keys)
                            .with_context(|| format!("scenario section [{name}] (line {line_no})"))?;
                        out.push(ev);
                        Ok(())
                    }
                }
            };
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = match raw.find('#') {
                Some(p) => &raw[..p],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                finish(section.take(), &mut scenario.events)?;
                section = Some((name.trim().to_string(), HashMap::new(), line_no));
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("scenario line {line_no}: {line:?} (want key = value)"))?;
            let (key, value) = (key.trim(), value.trim());
            match &mut section {
                Some((_, keys, _)) => {
                    if keys.insert(key.to_string(), value.to_string()).is_some() {
                        bail!("scenario line {line_no}: duplicate key {key:?} in section");
                    }
                }
                None => match key {
                    "seed" => {
                        scenario.seed = value
                            .parse()
                            .with_context(|| format!("scenario line {line_no}: seed {value:?}"))?
                    }
                    "members" => {
                        scenario.members = value
                            .parse()
                            .with_context(|| format!("scenario line {line_no}: members {value:?}"))?
                    }
                    other => bail!(
                        "scenario line {line_no}: unknown top-level key {other:?} (want seed|members)"
                    ),
                },
            }
        }
        finish(section.take(), &mut scenario.events)?;
        Ok(scenario)
    }

    /// Parse a scenario file.
    pub fn from_file(path: &Path) -> Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario {}", path.display()))?;
        Scenario::parse(&text).with_context(|| format!("parsing scenario {}", path.display()))
    }

    /// Fleet size for a caller hosting `caller_members`: the file's
    /// `members` wins when declared.
    pub fn fleet_size(&self, caller_members: usize) -> usize {
        if self.members > 0 {
            self.members
        } else {
            caller_members
        }
    }

    /// Expand the scenario over `n` members with global ids
    /// `base..base + n`. Pure function of (self, n, base): compiling twice
    /// yields identical plans and schedules.
    pub fn compile(&self, n: usize, base: usize) -> Result<CompiledScenario> {
        if n == 0 {
            bail!("scenario compiled for an empty fleet");
        }
        let mut plan = FaultPlan::new(self.seed);
        let mut schedules: Vec<MemberSchedule> = (0..n)
            .map(|i| MemberSchedule {
                member: base + i,
                ..Default::default()
            })
            .collect();
        for (ei, ev) in self.events.iter().enumerate() {
            match *ev {
                ScenarioEvent::SpotWave {
                    at,
                    fraction,
                    down,
                    stagger,
                } => {
                    let victims = ((n as f64) * fraction.clamp(0.0, 1.0)).round() as usize;
                    let victims = victims.min(n);
                    // Seeded victim pick, keyed on the event index so two
                    // waves preempt different subsets.
                    let mut ids: Vec<usize> = (0..n).collect();
                    let stream = 0x7a7e_0001u64.wrapping_add(ei as u64);
                    Pcg64::with_stream(self.seed, stream).shuffle(&mut ids);
                    for (rank, &i) in ids[..victims].iter().enumerate() {
                        let until = at + down + stagger * rank as u64;
                        schedules[i].downtimes.push((at, until.max(at + 1)));
                    }
                }
                ScenarioEvent::ZoneOutage { zone, from, until } => {
                    if zone.0 >= zone.1 {
                        bail!("zone_outage zone {}..{} is empty", zone.0, zone.1);
                    }
                    for i in zone.0..zone.1.min(n) {
                        plan = plan.with_blackout(base + i, from, until);
                    }
                }
                ScenarioEvent::FlashCrowd { at, joiners } => {
                    let j = joiners.min(n);
                    for s in schedules.iter_mut().skip(n - j) {
                        s.join_delay = at;
                    }
                }
                ScenarioEvent::Diurnal {
                    base: lo,
                    amplitude,
                    period,
                } => {
                    let p = period.max(2);
                    let half = (p / 2).max(1);
                    for (i, s) in schedules.iter_mut().enumerate() {
                        // Integer triangle wave over member index: 0 at
                        // phase 0, `amplitude` at phase `period/2`.
                        let pos = i as u64 % p;
                        let tri = if pos <= half { pos } else { p - pos };
                        let interval = (lo + amplitude * tri / half).max(1);
                        s.publish_interval = Some(interval);
                        s.publish_offset = i as u64 % interval;
                    }
                }
                ScenarioEvent::FlakyNet {
                    drop_p,
                    error_p,
                    stale_p,
                    delay_p,
                } => {
                    plan.drop_fetch_p = plan.drop_fetch_p.max(drop_p);
                    plan.error_fetch_p = plan.error_fetch_p.max(error_p);
                    plan.stale_read_p = plan.stale_read_p.max(stale_p);
                    plan.delay_publish_p = plan.delay_publish_p.max(delay_p);
                }
            }
        }
        Ok(CompiledScenario {
            seed: self.seed,
            members: n,
            plan,
            schedules,
        })
    }

    /// Analytic wall-clock price of each event over a fleet of `n`
    /// members running `total_steps` (see the [`ClusterModel`] scenario
    /// primitives): `(event name, seconds)` rows in file order.
    pub fn price(
        &self,
        m: &ClusterModel,
        n: usize,
        total_steps: u64,
    ) -> Vec<(&'static str, f64)> {
        self.events
            .iter()
            .map(|ev| {
                let cost = match *ev {
                    ScenarioEvent::SpotWave {
                        fraction,
                        down,
                        stagger,
                        ..
                    } => {
                        let victims = ((n as f64) * fraction.clamp(0.0, 1.0)).round() as usize;
                        // mean downtime includes the staggered tail
                        let mean_down =
                            down as f64 + stagger as f64 * victims.saturating_sub(1) as f64 / 2.0;
                        m.preemption_wave_cost(victims.min(n), mean_down)
                    }
                    ScenarioEvent::ZoneOutage { zone, from, until } => {
                        let size = zone.1.saturating_sub(zone.0).min(n);
                        m.zone_outage_cost(size, until.saturating_sub(from))
                    }
                    ScenarioEvent::FlashCrowd { joiners, .. } => {
                        m.flash_crowd_cost(joiners.min(n))
                    }
                    ScenarioEvent::Diurnal {
                        base,
                        amplitude,
                        period,
                    } => {
                        // price the whole fleet's skewed publish traffic
                        let p = period.max(2);
                        let half = (p / 2).max(1);
                        let intervals: Vec<u64> = (0..n as u64)
                            .map(|i| {
                                let pos = i % p;
                                let tri = if pos <= half { pos } else { p - pos };
                                (base + amplitude * tri / half).max(1)
                            })
                            .collect();
                        total_steps as f64 * n as f64 * m.skewed_bytes_per_step(&intervals)
                            / m.bandwidth_bps
                    }
                    ScenarioEvent::FlakyNet { drop_p, error_p, .. } => {
                        // every member's reload reads pay the retry tax
                        let reads =
                            n as u64 * (total_steps / m.reload_interval.max(1)).max(1);
                        m.flaky_net_cost(reads, drop_p + error_p, 5)
                    }
                };
                (ev.name(), cost)
            })
            .collect()
    }
}

/// Build one event from a finished `[section]` block, rejecting unknown
/// keys so typos fail at parse time.
fn build_event(name: &str, keys: &HashMap<String, String>) -> Result<ScenarioEvent> {
    let known: &[&str] = match name {
        "spot_wave" => &["at", "fraction", "down", "stagger"],
        "zone_outage" => &["zone", "from", "until"],
        "flash_crowd" => &["at", "joiners"],
        "diurnal" => &["base", "amplitude", "period"],
        "flaky_net" => &["drop_p", "error_p", "stale_p", "delay_p"],
        other => bail!(
            "unknown section {other:?} (want spot_wave|zone_outage|flash_crowd|diurnal|flaky_net)"
        ),
    };
    for k in keys.keys() {
        if !known.contains(&k.as_str()) {
            bail!("unknown key {k:?} (known: {})", known.join(", "));
        }
    }
    let u64_of = |k: &str, default: Option<u64>| -> Result<u64> {
        match keys.get(k) {
            Some(v) => v.parse().with_context(|| format!("key {k} = {v:?}")),
            None => default.with_context(|| format!("missing required key {k:?}")),
        }
    };
    let f64_of = |k: &str, default: Option<f64>| -> Result<f64> {
        match keys.get(k) {
            Some(v) => {
                let p: f64 = v.parse().with_context(|| format!("key {k} = {v:?}"))?;
                if !p.is_finite() || p < 0.0 {
                    bail!("key {k} = {v:?} must be finite and >= 0");
                }
                Ok(p)
            }
            None => default.with_context(|| format!("missing required key {k:?}")),
        }
    };
    Ok(match name {
        "spot_wave" => {
            let fraction = f64_of("fraction", None)?;
            if fraction > 1.0 {
                bail!("fraction {fraction} > 1");
            }
            ScenarioEvent::SpotWave {
                at: u64_of("at", None)?,
                fraction,
                down: u64_of("down", None)?.max(1),
                stagger: u64_of("stagger", Some(0))?,
            }
        }
        "zone_outage" => {
            let spec = keys.get("zone").context("missing required key \"zone\"")?;
            let (lo, hi) = spec
                .split_once("..")
                .with_context(|| format!("zone {spec:?} (want lo..hi)"))?;
            let zone: (usize, usize) = (
                lo.trim().parse().with_context(|| format!("zone lo {lo:?}"))?,
                hi.trim().parse().with_context(|| format!("zone hi {hi:?}"))?,
            );
            ScenarioEvent::ZoneOutage {
                zone,
                from: u64_of("from", None)?,
                until: u64_of("until", None)?,
            }
        }
        "flash_crowd" => ScenarioEvent::FlashCrowd {
            at: u64_of("at", None)?,
            joiners: u64_of("joiners", None)? as usize,
        },
        "diurnal" => ScenarioEvent::Diurnal {
            base: u64_of("base", None)?.max(1),
            amplitude: u64_of("amplitude", None)?,
            period: u64_of("period", Some(16))?,
        },
        "flaky_net" => ScenarioEvent::FlakyNet {
            drop_p: f64_of("drop_p", Some(0.0))?,
            error_p: f64_of("error_p", Some(0.0))?,
            stale_p: f64_of("stale_p", Some(0.0))?,
            delay_p: f64_of("delay_p", Some(0.0))?,
        },
        _ => unreachable!("validated above"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = "\
# every pattern at once
seed = 11
members = 100

[spot_wave]
at = 30
fraction = 0.25
down = 25
stagger = 1

[zone_outage]
zone = 10..30
from = 50
until = 90

[flash_crowd]
at = 60
joiners = 20

[diurnal]
base = 10
amplitude = 6
period = 32

[flaky_net]
drop_p = 0.2
error_p = 0.1
";

    #[test]
    fn parses_every_section() {
        let s = Scenario::parse(FULL).unwrap();
        assert_eq!((s.seed, s.members, s.events.len()), (11, 100, 5));
        assert_eq!(
            s.events[0],
            ScenarioEvent::SpotWave {
                at: 30,
                fraction: 0.25,
                down: 25,
                stagger: 1
            }
        );
        assert_eq!(
            s.events[1],
            ScenarioEvent::ZoneOutage {
                zone: (10, 30),
                from: 50,
                until: 90
            }
        );
        assert_eq!(s.events[2], ScenarioEvent::FlashCrowd { at: 60, joiners: 20 });
        assert_eq!(
            s.events[4],
            ScenarioEvent::FlakyNet {
                drop_p: 0.2,
                error_p: 0.1,
                stale_p: 0.0,
                delay_p: 0.0
            }
        );
    }

    #[test]
    fn parse_rejects_malformed_input() {
        for bad in [
            "bogus = 1",                               // unknown top-level key
            "[nope]\nx = 1",                           // unknown section
            "[spot_wave]\nat = 1\nfraction = 0.5",     // missing `down`
            "[spot_wave]\nat = 1\nfraction = 2.0\ndown = 5", // fraction > 1
            "[spot_wave]\nat = 1\nat = 2\nfraction = 0.5\ndown = 5", // dup key
            "[zone_outage]\nzone = 5\nfrom = 1\nuntil = 2", // bad range
            "[zone_outage]\nzone = 9..3\nfrom = 1\nuntil = 2", // empty range
            "[flaky_net]\ndrop_p = -0.5",              // negative probability
            "[flash_crowd]\nat",                       // no `=`
            "[spot_wave]\nat = 1\nfraction = 0.5\ndown = 5\nbanana = 1", // unknown key
        ] {
            assert!(Scenario::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn compile_is_deterministic_and_covers_the_fleet() {
        let s = Scenario::parse(FULL).unwrap();
        let a = s.compile(100, 0).unwrap();
        let b = s.compile(100, 0).unwrap();
        assert_eq!(a.schedules, b.schedules, "victim pick not deterministic");
        assert_eq!(a.plan.blackouts, b.plan.blackouts);
        // spot wave: exactly 25 members have a downtime starting at 30
        let victims: Vec<&MemberSchedule> =
            a.schedules.iter().filter(|m| !m.downtimes.is_empty()).collect();
        assert_eq!(victims.len(), 25);
        assert!(victims.iter().all(|m| m.downtimes[0].0 == 30));
        // staggered rejoins: not all downtimes end together
        let ends: std::collections::BTreeSet<u64> =
            victims.iter().map(|m| m.downtimes[0].1).collect();
        assert!(ends.len() > 1, "rejoins not staggered: {ends:?}");
        // zone outage: 20 blackouts covering members 10..30
        assert_eq!(a.plan.blackouts.len(), 20);
        assert!(a.plan.blackouts.iter().all(|b| (10..30).contains(&b.member)
            && b.from_step == 50
            && b.until_step == 90));
        // flash crowd: the 20 highest ids join at 60
        assert!(a.schedules[80..].iter().all(|m| m.join_delay == 60));
        assert!(a.schedules[..80].iter().all(|m| m.join_delay == 0));
        // diurnal: cadence oscillates within [base, base+amplitude]
        let intervals: Vec<u64> =
            a.schedules.iter().map(|m| m.publish_interval.unwrap()).collect();
        assert!(intervals.iter().all(|&i| (10..=16).contains(&i)));
        assert!(intervals.iter().any(|&i| i == 10) && intervals.iter().any(|&i| i == 16));
        // flaky net folded into the plan
        assert_eq!((a.plan.drop_fetch_p, a.plan.error_fetch_p), (0.2, 0.1));
        assert!(a.has_faults());
        // different seeds preempt different subsets
        let mut other = s.clone();
        other.seed = 12;
        let c = other.compile(100, 0).unwrap();
        assert_ne!(a.schedules, c.schedules);
    }

    #[test]
    fn compile_respects_member_base() {
        let s = Scenario::parse("seed = 1\n[zone_outage]\nzone = 0..2\nfrom = 5\nuntil = 9\n")
            .unwrap();
        let c = s.compile(4, 100).unwrap();
        assert_eq!(c.schedules[0].member, 100);
        assert!(c.plan.blackouts.iter().all(|b| b.member >= 100 && b.member < 102));
    }

    #[test]
    fn apply_overlays_schedules_onto_hosted_members() {
        use crate::codistill::Member;
        use crate::testkit::DriftMember;
        let s = Scenario::parse(
            "seed = 3\nmembers = 4\n[flash_crowd]\nat = 7\njoiners = 2\n\
             [diurnal]\nbase = 5\namplitude = 4\nperiod = 4\n",
        )
        .unwrap();
        let c = s.compile(4, 0).unwrap();
        let mut hosted: Vec<HostedMember> = (0..4)
            .map(|i| HostedMember::new(i, Box::new(DriftMember::new(i)) as Box<dyn Member>, 10))
            .collect();
        c.apply(&mut hosted);
        assert_eq!(hosted[3].join_delay, 7);
        assert_eq!(hosted[0].join_delay, 0);
        assert!(hosted.iter().all(|h| h.publish_interval >= 5));
        assert!(!c.has_faults());
    }

    #[test]
    fn fleet_size_prefers_the_file() {
        let with = Scenario::parse("members = 10\n").unwrap();
        let without = Scenario::parse("seed = 1\n").unwrap();
        assert_eq!(with.fleet_size(3), 10);
        assert_eq!(without.fleet_size(3), 3);
        assert!(with.compile(0, 0).is_err(), "empty fleet must be rejected");
    }

    #[test]
    fn prices_every_event_positively() {
        let s = Scenario::parse(FULL).unwrap();
        let m = ClusterModel::gpu_cluster(8, 40_000_000);
        let rows = s.price(&m, 100, 200);
        assert_eq!(rows.len(), 5);
        for (name, cost) in &rows {
            assert!(*cost > 0.0, "{name} priced {cost}");
        }
        // a bigger wave costs more
        let small = Scenario::parse(
            "seed = 11\n[spot_wave]\nat = 30\nfraction = 0.05\ndown = 25\nstagger = 1\n",
        )
        .unwrap();
        let wave_full = rows[0].1;
        let wave_small = small.price(&m, 100, 200)[0].1;
        assert!(wave_small < wave_full, "{wave_small} !< {wave_full}");
    }
}

//! The batching inference server: workers over a [`BatchQueue`], one
//! plane snapshot per batch, swap-aware churn accounting.

use super::batcher::{BatchPolicy, BatchQueue, Pending};
use super::swap::SwapHandle;
use super::{InferRequest, InferResponse, ServingModel};
use crate::codistill::obs::{render, Event, Recorder};
use crate::codistill::Checkpoint;
use crate::metrics::{mean_abs_diff, ChurnReport, LatencyHistogram};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Server construction knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Batch closes at this summed feature count.
    pub max_batch_items: usize,
    /// …or when its oldest request has waited this long.
    pub max_delay: Duration,
    /// Inference worker threads.
    pub workers: usize,
    /// Fixed feature set evaluated on both planes at every hot swap to
    /// measure prediction churn (the serving-side Table 1). Empty
    /// disables churn tracking.
    pub probe: Vec<u64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch_items: 64,
            max_delay: Duration::from_millis(2),
            workers: 1,
            probe: (0..32).collect(),
        }
    }
}

/// Throughput accounting for one batch-size class.
#[derive(Debug, Clone, Copy)]
pub struct BatchBucket {
    /// Requests per batch in this class.
    pub batch_requests: usize,
    /// Batches served at this size.
    pub batches: u64,
    /// Total feature items across them.
    pub items: u64,
    /// Worker-busy seconds spent on them.
    pub busy_s: f64,
}

impl BatchBucket {
    /// Items per worker-busy second at this batch size.
    pub fn throughput(&self) -> f64 {
        if self.busy_s > 0.0 {
            self.items as f64 / self.busy_s
        } else {
            f64::NAN
        }
    }
}

/// Snapshot of the server's serving-side counters.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Successfully served requests.
    pub served: u64,
    /// Requests that failed (no plane installed, model error).
    pub failed: u64,
    /// Micro-batches executed.
    pub batches: u64,
    /// Per-request submit→response latency.
    pub latency: LatencyHistogram,
    /// Throughput by requests-per-batch class, ascending.
    pub throughput: Vec<BatchBucket>,
}

impl ServeStats {
    /// `throughput vs batch size` table lines (the CLI/report format).
    pub fn throughput_lines(&self, tag: &str) -> Vec<String> {
        self.throughput
            .iter()
            .map(|b| {
                format!(
                    "[{tag}] batch={:>3} req: batches={} items={} throughput={:.0} items/s",
                    b.batch_requests,
                    b.batches,
                    b.items,
                    b.throughput()
                )
            })
            .collect()
    }
}

#[derive(Default)]
struct StatsInner {
    served: u64,
    failed: u64,
    batches: u64,
    latency: LatencyHistogram,
    buckets: BTreeMap<usize, BatchBucket>,
}

#[derive(Default)]
struct ChurnState {
    report: ChurnReport,
    /// Fixed-format, deterministic-given-the-swap-sequence log: one
    /// line per hot swap. Replays byte-identically across same-seed
    /// runs (the §3.5 reproducibility check, applied to serving).
    log: String,
}

/// The batching inference server (module docs for the architecture).
///
/// All methods take `&self`; wrap in an `Arc` to share with loadgen
/// client threads. Dropping the server closes the queue and joins the
/// workers; in-flight requests drain first.
pub struct InferenceServer {
    model: Arc<dyn ServingModel>,
    swap: Arc<SwapHandle>,
    queue: Arc<BatchQueue>,
    cfg: ServeConfig,
    stats: Arc<Mutex<StatsInner>>,
    churn: Mutex<ChurnState>,
    recorder: Mutex<Option<Recorder>>,
    next_id: AtomicU64,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl InferenceServer {
    /// Spawn the worker threads and return the (not yet installed)
    /// server. Requests submitted before the first
    /// [`InferenceServer::install`] fail cleanly with "no plane".
    pub fn start(model: Arc<dyn ServingModel>, cfg: ServeConfig) -> Self {
        let swap = Arc::new(SwapHandle::new());
        let queue = Arc::new(BatchQueue::new());
        let stats = Arc::new(Mutex::new(StatsInner::default()));
        let policy = BatchPolicy {
            max_batch_items: cfg.max_batch_items,
            max_delay: cfg.max_delay,
        };
        let mut handles = Vec::new();
        for w in 0..cfg.workers.max(1) {
            let (model, swap, queue, stats) =
                (model.clone(), swap.clone(), queue.clone(), stats.clone());
            let h = std::thread::Builder::new()
                .name(format!("serve-worker-{w}"))
                .spawn(move || worker_loop(&*model, &swap, &queue, &stats, policy))
                .expect("spawning inference worker");
            handles.push(h);
        }
        InferenceServer {
            model,
            swap,
            queue,
            cfg,
            stats,
            churn: Mutex::new(ChurnState::default()),
            recorder: Mutex::new(None),
            next_id: AtomicU64::new(0),
            workers: Mutex::new(handles),
        }
    }

    /// Record hot swaps into a `codistill::obs` journal: each swap
    /// becomes a typed [`Event::Swap`] carrying the same fields as the
    /// churn log line (which is re-derived from the journal's shared
    /// renderer). Takes `&self` so it composes with the `Arc`-shared
    /// server the subscription callback holds.
    pub fn set_recorder(&self, recorder: Recorder) {
        *self.recorder.lock().unwrap() = Some(recorder);
    }

    /// Verify and hot-swap `ckpt` in as the serving plane, recording
    /// prediction churn against the replaced plane over the probe set.
    /// Traffic never pauses: in-flight batches finish on the old plane,
    /// later batches snapshot the new one.
    pub fn install(&self, ckpt: Arc<Checkpoint>) -> Result<()> {
        let (old, new) = self.swap.install(ckpt)?;
        if let Some(old) = old {
            let probe = &self.cfg.probe;
            if !probe.is_empty() {
                let a = self.model.predict(&old.ckpt, probe)?;
                let b = self.model.predict(&new.ckpt, probe)?;
                let churn = mean_abs_diff(&a, &b)?;
                let mut c = self.churn.lock().unwrap();
                let idx = (c.report.samples.len() + 1) as u64;
                c.log.push_str(&render::swap_line(
                    idx,
                    old.ckpt.step,
                    new.ckpt.step,
                    old.digest,
                    new.digest,
                    churn,
                ));
                c.report.push(churn);
                // Record inside the churn critical section so the
                // journal's swap order matches the log's.
                if let Some(rec) = self.recorder.lock().unwrap().as_ref() {
                    rec.record(Event::Swap {
                        index: idx,
                        from_step: old.ckpt.step,
                        to_step: new.ckpt.step,
                        from_digest: old.digest,
                        to_digest: new.digest,
                        churn,
                    });
                }
            }
        }
        Ok(())
    }

    /// Enqueue a request; returns its id and the response channel. The
    /// id is the dense submission index (0-based, in submit order), so
    /// a seeded load generator's requests can be re-derived offline.
    pub fn submit(&self, features: Vec<u64>) -> (u64, mpsc::Receiver<Result<InferResponse>>) {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = mpsc::channel();
        let p = Pending {
            req: InferRequest { id, features },
            enqueued: Instant::now(),
            tx,
        };
        if let Err(p) = self.queue.push(p) {
            self.stats.lock().unwrap().failed += 1;
            p.tx.send(Err(anyhow!("server shut down"))).ok();
        }
        (id, rx)
    }

    /// Synchronous submit + wait.
    pub fn infer(&self, features: Vec<u64>) -> Result<InferResponse> {
        let (_, rx) = self.submit(features);
        rx.recv()
            .map_err(|_| anyhow!("server dropped the request channel"))?
    }

    /// Completed hot swaps so far.
    pub fn swaps(&self) -> u64 {
        self.swap.swaps()
    }

    /// Step of the plane currently serving; `None` before first install.
    pub fn installed_step(&self) -> Option<u64> {
        self.swap.installed_step()
    }

    /// The swap handle (for tests that race installs against traffic).
    pub fn swap_handle(&self) -> &Arc<SwapHandle> {
        &self.swap
    }

    /// Requests queued right now.
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Snapshot the serving counters.
    pub fn stats(&self) -> ServeStats {
        let s = self.stats.lock().unwrap();
        ServeStats {
            served: s.served,
            failed: s.failed,
            batches: s.batches,
            latency: s.latency.clone(),
            throughput: s.buckets.values().copied().collect(),
        }
    }

    /// The churn-across-swaps aggregate and its replayable log text.
    pub fn churn(&self) -> (ChurnReport, String) {
        let c = self.churn.lock().unwrap();
        (c.report.clone(), c.log.clone())
    }

    /// Stop accepting requests, drain the queue, join the workers.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&self) {
        self.queue.close();
        let mut ws = self.workers.lock().unwrap();
        for h in ws.drain(..) {
            h.join().ok();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(
    model: &dyn ServingModel,
    swap: &SwapHandle,
    queue: &BatchQueue,
    stats: &Mutex<StatsInner>,
    policy: BatchPolicy,
) {
    while let Some(batch) = queue.next_batch(&policy) {
        // ONE plane snapshot per batch: every response in this batch is
        // consistent with exactly this plane, no matter how many swaps
        // land while it computes.
        let plane = swap.current();
        let nreq = batch.len();
        let items: usize = batch.iter().map(|p| p.items()).sum();
        let t0 = Instant::now();
        let mut ok = 0u64;
        let mut failed = 0u64;
        let mut latencies: Vec<Duration> = Vec::with_capacity(nreq);
        for p in batch {
            let res = match &plane {
                None => Err(anyhow!("no plane installed yet")),
                Some(pl) => model.predict(&pl.ckpt, &p.req.features).map(|probs| {
                    let latency = p.enqueued.elapsed();
                    InferResponse {
                        id: p.req.id,
                        probs,
                        step: pl.ckpt.step,
                        plane_digest: pl.digest,
                        batch_requests: nreq,
                        latency,
                    }
                }),
            };
            match &res {
                Ok(r) => {
                    ok += 1;
                    latencies.push(r.latency);
                }
                Err(_) => failed += 1,
            }
            // A dropped receiver (caller gave up) is not a serve failure.
            p.tx.send(res).ok();
        }
        let busy = t0.elapsed().as_secs_f64();
        let mut s = stats.lock().unwrap();
        s.served += ok;
        s.failed += failed;
        s.batches += 1;
        for l in latencies {
            s.latency.record(l);
        }
        let b = s.buckets.entry(nreq).or_insert(BatchBucket {
            batch_requests: nreq,
            batches: 0,
            items: 0,
            busy_s: 0.0,
        });
        b.batches += 1;
        b.items += items as u64;
        b.busy_s += busy;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codistill::Member;
    use crate::models::MockForward;
    use crate::testkit::DriftMember;

    fn snap(steps: u64) -> Arc<Checkpoint> {
        let mut m = DriftMember::new(0);
        for _ in 0..steps {
            m.train_step(0.0, 0.1).unwrap();
        }
        Arc::new(m.snapshot().unwrap())
    }

    fn server() -> InferenceServer {
        InferenceServer::start(
            Arc::new(MockForward::new()),
            ServeConfig {
                max_batch_items: 8,
                max_delay: Duration::from_millis(1),
                workers: 2,
                probe: (0..16).collect(),
            },
        )
    }

    #[test]
    fn serves_and_reports_provenance() {
        let srv = server();
        srv.install(snap(3)).unwrap();
        let resp = srv.infer(vec![1, 2, 3]).unwrap();
        assert_eq!(resp.probs.len(), 3);
        assert_eq!(resp.step, 3);
        assert!(resp.batch_requests >= 1);
        // the response re-derives exactly from the same plane
        let expect = MockForward::new()
            .probs(&srv.swap_handle().current().unwrap().ckpt, &[1, 2, 3])
            .unwrap();
        assert_eq!(resp.probs, expect);
        let stats = srv.stats();
        assert_eq!(stats.served, 1);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.latency.count(), 1);
        assert!(!stats.throughput.is_empty());
    }

    #[test]
    fn requests_before_install_fail_cleanly() {
        let srv = server();
        let err = srv.infer(vec![1]).unwrap_err();
        assert!(format!("{err:#}").contains("no plane"), "{err:#}");
        assert_eq!(srv.stats().failed, 1);
        assert_eq!(srv.stats().served, 0);
    }

    #[test]
    fn swap_records_churn_and_log_line() {
        let srv = server();
        srv.install(snap(2)).unwrap();
        srv.install(snap(6)).unwrap();
        assert_eq!(srv.swaps(), 1);
        let (report, log) = srv.churn();
        assert_eq!(report.samples.len(), 1);
        assert!(report.samples[0] > 0.0, "drift between steps must move predictions");
        assert!(log.starts_with("swap 1: step 2 -> 6 plane "), "{log}");
        assert!(log.contains("churn"), "{log}");
    }

    #[test]
    fn shutdown_fails_late_submits() {
        let srv = server();
        srv.install(snap(1)).unwrap();
        srv.shutdown();
        let err = srv.infer(vec![1]).unwrap_err();
        assert!(format!("{err:#}").contains("shut down"), "{err:#}");
    }
}

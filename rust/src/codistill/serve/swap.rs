//! The atomic plane-swap handle: install verified, read torn-free.

use crate::codistill::Checkpoint;
use crate::runtime::flat::content_digest;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// One installed plane plus its identity: the whole-plane content
/// digest recomputed at install time. Responses carry `(step, digest)`
/// so any response can be re-derived offline from the retained
/// checkpoint and compared exactly.
#[derive(Debug, Clone)]
pub struct ServingPlane {
    pub ckpt: Arc<Checkpoint>,
    /// `content_digest` over the full flat plane, recomputed (not
    /// adopted) when the plane was installed.
    pub digest: u64,
}

/// Swap point between the subscription loop (writer) and the inference
/// workers (readers).
///
/// Readers call [`SwapHandle::current`] once per micro-batch and hold
/// the returned `Arc` for the batch's lifetime: the swap is a pointer
/// flip under a briefly-held lock, so a swap concurrent with a batch
/// leaves the batch on the old plane — consistent, never torn. Installs
/// re-hash every window of the incoming plane against the checkpoint's
/// remembered digest table before the flip, so bytes corrupted anywhere
/// between the publisher and this process are rejected here and the
/// previous plane keeps serving.
pub struct SwapHandle {
    current: RwLock<Option<Arc<ServingPlane>>>,
    /// Installs that replaced an existing plane (completed hot swaps).
    swaps: AtomicU64,
    /// All successful installs (first install included).
    installs: AtomicU64,
}

impl SwapHandle {
    pub fn new() -> Self {
        SwapHandle {
            current: RwLock::new(None),
            swaps: AtomicU64::new(0),
            installs: AtomicU64::new(0),
        }
    }

    /// Verify `ckpt`'s plane bytes and swap it in. Returns the replaced
    /// plane (if any) and the newly installed one, so the caller can
    /// measure prediction churn across the swap. On verification
    /// failure the handle is untouched and keeps serving the old plane.
    pub fn install(
        &self,
        ckpt: Arc<Checkpoint>,
    ) -> Result<(Option<Arc<ServingPlane>>, Arc<ServingPlane>)> {
        // Re-hash every window from the actual bytes and compare with
        // the digest table the checkpoint was exchanged under. The
        // delta path already verified moved windows at decode time;
        // this is the last line of defense for the serving tier —
        // whatever the medium did, the plane we point requests at
        // hashes to what the publisher published.
        let fresh = ckpt.flat().window_digests();
        let remembered = ckpt.window_digests();
        if fresh != **remembered {
            bail!(
                "member {} step {}: plane bytes do not match their digest table \
                 (torn or corrupt checkpoint refused at install)",
                ckpt.member,
                ckpt.step
            );
        }
        let digest = content_digest(ckpt.flat().data());
        let plane = Arc::new(ServingPlane { ckpt, digest });
        let old = {
            let mut cur = self.current.write().unwrap();
            std::mem::replace(&mut *cur, Some(plane.clone()))
        };
        self.installs.fetch_add(1, Ordering::SeqCst);
        if old.is_some() {
            self.swaps.fetch_add(1, Ordering::SeqCst);
        }
        Ok((old, plane))
    }

    /// The plane requests should be served against right now; `None`
    /// before the first install. O(1): clones the `Arc` under a read
    /// lock held for the duration of the clone only.
    pub fn current(&self) -> Option<Arc<ServingPlane>> {
        self.current.read().unwrap().clone()
    }

    /// Completed hot swaps (installs beyond the first).
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::SeqCst)
    }

    /// All successful installs.
    pub fn installs(&self) -> u64 {
        self.installs.load(Ordering::SeqCst)
    }

    /// Step of the currently installed plane.
    pub fn installed_step(&self) -> Option<u64> {
        self.current().map(|p| p.ckpt.step)
    }
}

impl Default for SwapHandle {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codistill::Member;
    use crate::testkit::DriftMember;

    fn snap(steps: u64) -> Arc<Checkpoint> {
        let mut m = DriftMember::new(0);
        for _ in 0..steps {
            m.train_step(0.0, 0.1).unwrap();
        }
        Arc::new(m.snapshot().unwrap())
    }

    #[test]
    fn install_then_swap_counts_and_identity() {
        let h = SwapHandle::new();
        assert!(h.current().is_none());
        assert_eq!(h.installed_step(), None);

        let (old, first) = h.install(snap(2)).unwrap();
        assert!(old.is_none());
        assert_eq!(h.swaps(), 0);
        assert_eq!(h.installs(), 1);
        assert_eq!(h.installed_step(), Some(2));
        assert_eq!(first.digest, content_digest(first.ckpt.flat().data()));

        let (old, second) = h.install(snap(5)).unwrap();
        assert_eq!(old.unwrap().ckpt.step, 2);
        assert_eq!(h.swaps(), 1);
        assert_eq!(h.installs(), 2);
        assert_eq!(h.installed_step(), Some(5));
        assert_ne!(first.digest, second.digest);
    }

    #[test]
    fn readers_hold_old_plane_across_a_swap() {
        let h = SwapHandle::new();
        h.install(snap(1)).unwrap();
        let held = h.current().unwrap();
        h.install(snap(4)).unwrap();
        // the held Arc still reads the old plane, byte-for-byte
        assert_eq!(held.ckpt.step, 1);
        assert_eq!(held.digest, content_digest(held.ckpt.flat().data()));
        assert_eq!(h.current().unwrap().ckpt.step, 4);
    }

    #[test]
    fn corrupt_plane_refused_and_old_keeps_serving() {
        let h = SwapHandle::new();
        h.install(snap(3)).unwrap();
        let before = h.current().unwrap().digest;

        // A checkpoint whose remembered digest table was adopted from a
        // medium that lied: honest bytes, stale table (one parameter
        // flipped after hashing).
        let good = snap(6);
        let honest = good.window_digests().as_ref().clone();
        let mut flat = (**good.flat()).clone();
        flat.data_mut()[0] += 1.0;
        let torn = Arc::new(Checkpoint::from_flat_with_digests(
            good.member,
            good.step,
            Arc::new(flat),
            good.residual().clone(),
            honest,
        ));
        let err = h.install(torn).unwrap_err();
        assert!(format!("{err:#}").contains("torn or corrupt"), "{err:#}");
        // the handle is untouched: old plane still serving, no swap counted
        assert_eq!(h.installed_step(), Some(3));
        assert_eq!(h.current().unwrap().digest, before);
        assert_eq!(h.swaps(), 0);
        assert_eq!(h.installs(), 1);
    }
}

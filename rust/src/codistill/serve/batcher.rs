//! Size- and deadline-triggered micro-batching over mixed request sizes.

use super::{InferRequest, InferResponse};
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// When a waiting batch closes: when its summed feature count reaches
/// `max_batch_items`, or its oldest request has waited `max_delay`,
/// whichever comes first. A request larger than `max_batch_items` forms
/// a batch of one rather than wedging the queue.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch_items: usize,
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch_items: 64,
            max_delay: Duration::from_millis(2),
        }
    }
}

/// A queued request plus its response channel and arrival time.
pub struct Pending {
    pub req: InferRequest,
    pub enqueued: Instant,
    pub tx: mpsc::Sender<Result<InferResponse>>,
}

impl Pending {
    /// Batch-item weight of this request (at least 1 so empty feature
    /// lists still occupy a slot).
    pub fn items(&self) -> usize {
        self.req.features.len().max(1)
    }
}

struct Inner {
    q: VecDeque<Pending>,
    closed: bool,
}

/// MPMC queue between request submitters and batch workers. Submitters
/// push; each worker blocks in [`BatchQueue::next_batch`] until a batch
/// is ready under the policy.
pub struct BatchQueue {
    inner: Mutex<Inner>,
    /// Signaled on push and close.
    changed: Condvar,
}

impl BatchQueue {
    pub fn new() -> Self {
        BatchQueue {
            inner: Mutex::new(Inner {
                q: VecDeque::new(),
                closed: false,
            }),
            changed: Condvar::new(),
        }
    }

    /// Enqueue a request; hands the request back when the queue is
    /// already closed so the caller can fail it on its own channel.
    pub fn push(&self, p: Pending) -> std::result::Result<(), Pending> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(p);
        }
        inner.q.push_back(p);
        drop(inner);
        self.changed.notify_one();
        Ok(())
    }

    /// No further pushes; blocked workers drain what is queued and then
    /// observe `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.changed.notify_all();
    }

    /// Requests currently queued (observability; racy by nature).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    /// Block until a batch is ready under `policy` and return it;
    /// `None` once the queue is closed and drained. A batch is the
    /// longest queue prefix whose item sum stays within
    /// `max_batch_items` (always at least one request).
    pub fn next_batch(&self, policy: &BatchPolicy) -> Option<Vec<Pending>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.q.is_empty() {
                if inner.closed {
                    return None;
                }
                inner = self.changed.wait(inner).unwrap();
                continue;
            }
            // Size up the prefix that fits.
            let mut items = 0usize;
            let mut take = 0usize;
            for p in &inner.q {
                let n = p.items();
                if take > 0 && items + n > policy.max_batch_items {
                    break;
                }
                items += n;
                take += 1;
                if items >= policy.max_batch_items {
                    break;
                }
            }
            let age = inner.q.front().map(|p| p.enqueued.elapsed()).unwrap_or_default();
            if items >= policy.max_batch_items || age >= policy.max_delay || inner.closed {
                return Some(inner.q.drain(..take).collect());
            }
            // Deadline-triggered: sleep until the oldest request's
            // deadline (a push meanwhile wakes us to re-check size).
            let remaining = policy.max_delay - age;
            let (guard, _timeout) = self.changed.wait_timeout(inner, remaining).unwrap();
            inner = guard;
        }
    }
}

impl Default for BatchQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(id: u64, nfeat: usize) -> (Pending, mpsc::Receiver<Result<InferResponse>>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                req: InferRequest {
                    id,
                    features: (0..nfeat as u64).collect(),
                },
                enqueued: Instant::now(),
                tx,
            },
            rx,
        )
    }

    #[test]
    fn size_trigger_fills_to_cap_over_mixed_sizes() {
        let q = BatchQueue::new();
        // 3+3+3+3 items against a cap of 8: first batch takes 2 whole
        // requests (6 items; a third would overflow)
        for i in 0..4 {
            q.push(pending(i, 3).0).unwrap();
        }
        let policy = BatchPolicy {
            max_batch_items: 8,
            max_delay: Duration::from_secs(10), // size-trigger only
        };
        let b = q.next_batch(&policy).unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(b.iter().map(|p| p.items()).sum::<usize>(), 6);
        let b = q.next_batch(&policy).unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn oversized_request_forms_a_batch_of_one() {
        let q = BatchQueue::new();
        q.push(pending(0, 100).0).unwrap();
        q.push(pending(1, 1).0).unwrap();
        let policy = BatchPolicy {
            max_batch_items: 8,
            max_delay: Duration::from_secs(10),
        };
        let b = q.next_batch(&policy).unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].req.id, 0);
    }

    #[test]
    fn deadline_trigger_flushes_a_partial_batch() {
        let q = BatchQueue::new();
        q.push(pending(0, 1).0).unwrap();
        let policy = BatchPolicy {
            max_batch_items: 1_000_000,
            max_delay: Duration::from_millis(20),
        };
        let t0 = Instant::now();
        let b = q.next_batch(&policy).unwrap();
        let waited = t0.elapsed();
        assert_eq!(b.len(), 1);
        assert!(waited >= Duration::from_millis(10), "flushed too early: {waited:?}");
    }

    #[test]
    fn close_drains_then_ends() {
        let q = BatchQueue::new();
        q.push(pending(0, 1).0).unwrap();
        q.close();
        let policy = BatchPolicy::default();
        assert_eq!(q.next_batch(&policy).unwrap().len(), 1);
        assert!(q.next_batch(&policy).is_none());
        // pushes after close hand the request back
        assert!(q.push(pending(1, 1).0).is_err());
    }

    #[test]
    fn push_wakes_a_waiting_worker_to_fill_the_batch() {
        use std::sync::Arc;
        let q = Arc::new(BatchQueue::new());
        let policy = BatchPolicy {
            max_batch_items: 2,
            max_delay: Duration::from_secs(5),
        };
        let qt = q.clone();
        let worker = std::thread::spawn(move || qt.next_batch(&policy));
        q.push(pending(0, 1).0).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        q.push(pending(1, 1).0).unwrap(); // completes the size trigger
        let b = worker.join().unwrap().unwrap();
        assert_eq!(b.len(), 2);
    }
}

//! Seeded open- and closed-loop load generators over mixed request
//! sizes.
//!
//! Both generators derive every request's feature list from a
//! [`Pcg64`] stream, so request *content* is a pure function of the
//! seed — the hot-swap acceptance test regenerates the exact request
//! sequence offline to verify every response against the retained
//! checkpoints. Only arrival *timing* (and therefore batching and
//! latency) varies between runs.
//!
//! * [`open_loop`]: requests arrive on a fixed schedule (`rps`),
//!   regardless of completions — queue depth grows when the server
//!   falls behind, the configuration that actually exercises deep
//!   batches and tail latency.
//! * [`closed_loop`]: `clients` synchronous callers, each waiting for
//!   its response before sending the next — concurrency is bounded by
//!   the client count, the configuration that measures server-paced
//!   throughput.

use super::server::InferenceServer;
use super::InferResponse;
use crate::metrics::LatencyHistogram;
use crate::prng::Pcg64;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Request-content shape shared by both generators.
#[derive(Debug, Clone, Copy)]
pub struct LoadSpec {
    /// Total requests to issue.
    pub requests: u64,
    /// Seed for the feature streams.
    pub seed: u64,
    /// Features per request, drawn uniformly in
    /// `min_features..=max_features` (mixed request sizes).
    pub min_features: usize,
    pub max_features: usize,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec {
            requests: 1000,
            seed: 42,
            min_features: 1,
            max_features: 8,
        }
    }
}

impl LoadSpec {
    /// The feature list of request `i` *for a given stream*: requests
    /// are drawn in order from one generator, so the whole sequence is
    /// re-derivable offline.
    fn next_features(&self, rng: &mut Pcg64) -> Vec<u64> {
        let span = (self.max_features.max(self.min_features) - self.min_features + 1) as u64;
        let n = self.min_features + rng.below(span) as usize;
        (0..n).map(|_| rng.next_u64()).collect()
    }

    /// Regenerate the full open-loop request sequence (request `i` ↔
    /// submission id `i` when the generator is the only submitter).
    pub fn open_loop_requests(&self) -> Vec<Vec<u64>> {
        let mut rng = Pcg64::new(self.seed);
        (0..self.requests).map(|_| self.next_features(&mut rng)).collect()
    }
}

/// Open-loop arrival schedule.
#[derive(Debug, Clone, Copy)]
pub struct OpenLoopSpec {
    pub load: LoadSpec,
    /// Target arrival rate, requests/second.
    pub rps: f64,
}

/// Aggregate counters from one generator run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub sent: u64,
    pub ok: u64,
    pub failed: u64,
    /// Client-observed submit→response latency.
    pub latency: LatencyHistogram,
    /// Wall seconds from first submit to last response.
    pub wall_s: f64,
}

impl LoadReport {
    /// Completed requests per wall second.
    pub fn goodput(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.ok as f64 / self.wall_s
        } else {
            f64::NAN
        }
    }
}

/// A run's report plus every response and error (the acceptance test
/// audits each response against the retained checkpoints).
pub struct LoadRun {
    pub report: LoadReport,
    pub responses: Vec<InferResponse>,
    pub errors: Vec<String>,
}

/// Issue `spec.load.requests` on a fixed `spec.rps` schedule without
/// waiting for responses, then drain them all.
pub fn open_loop(server: &InferenceServer, spec: &OpenLoopSpec) -> LoadRun {
    let mut rng = Pcg64::new(spec.load.seed);
    let interval = if spec.rps > 0.0 {
        Duration::from_secs_f64(1.0 / spec.rps)
    } else {
        Duration::ZERO
    };
    let start = Instant::now();
    let mut rxs = Vec::with_capacity(spec.load.requests as usize);
    for i in 0..spec.load.requests {
        let due = start + interval.mul_f64(i as f64);
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        let feats = spec.load.next_features(&mut rng);
        rxs.push(server.submit(feats).1);
    }
    let mut report = LoadReport {
        sent: spec.load.requests,
        ok: 0,
        failed: 0,
        latency: LatencyHistogram::new(),
        wall_s: 0.0,
    };
    let mut responses = Vec::new();
    let mut errors = Vec::new();
    for rx in rxs {
        match rx.recv() {
            Ok(Ok(resp)) => {
                report.ok += 1;
                report.latency.record(resp.latency);
                responses.push(resp);
            }
            Ok(Err(e)) => {
                report.failed += 1;
                errors.push(format!("{e:#}"));
            }
            Err(_) => {
                report.failed += 1;
                errors.push("response channel dropped".to_string());
            }
        }
    }
    report.wall_s = start.elapsed().as_secs_f64();
    LoadRun {
        report,
        responses,
        errors,
    }
}

/// `clients` synchronous callers splitting `spec.requests` as evenly as
/// possible; client `c` draws its features from stream `c` of the seed.
pub fn closed_loop(server: &Arc<InferenceServer>, clients: usize, spec: &LoadSpec) -> LoadRun {
    let clients = clients.max(1);
    let start = Instant::now();
    let mut handles = Vec::with_capacity(clients);
    for c in 0..clients {
        let per = spec.requests / clients as u64
            + u64::from((c as u64) < spec.requests % clients as u64);
        let server = server.clone();
        let spec = *spec;
        handles.push(std::thread::spawn(move || {
            let mut rng = Pcg64::with_stream(spec.seed, c as u64 + 1);
            let mut latency = LatencyHistogram::new();
            let mut responses = Vec::new();
            let mut errors = Vec::new();
            for _ in 0..per {
                let feats = spec.next_features(&mut rng);
                match server.infer(feats) {
                    Ok(resp) => {
                        latency.record(resp.latency);
                        responses.push(resp);
                    }
                    Err(e) => errors.push(format!("{e:#}")),
                }
            }
            (per, latency, responses, errors)
        }));
    }
    let mut report = LoadReport {
        sent: 0,
        ok: 0,
        failed: 0,
        latency: LatencyHistogram::new(),
        wall_s: 0.0,
    };
    let mut responses = Vec::new();
    let mut errors = Vec::new();
    for h in handles {
        let (sent, lat, resp, errs) = h.join().expect("loadgen client panicked");
        report.sent += sent;
        report.ok += resp.len() as u64;
        report.failed += errs.len() as u64;
        report.latency.merge(&lat);
        responses.extend(resp);
        errors.extend(errs);
    }
    report.wall_s = start.elapsed().as_secs_f64();
    LoadRun {
        report,
        responses,
        errors,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codistill::serve::ServeConfig;
    use crate::codistill::{Checkpoint, Member};
    use crate::models::MockForward;
    use crate::testkit::DriftMember;

    fn installed_server() -> Arc<InferenceServer> {
        let srv = InferenceServer::start(
            Arc::new(MockForward::new()),
            ServeConfig {
                max_batch_items: 16,
                max_delay: Duration::from_millis(1),
                workers: 2,
                probe: vec![],
            },
        );
        let mut m = DriftMember::new(0);
        for _ in 0..3 {
            m.train_step(0.0, 0.1).unwrap();
        }
        srv.install(std::sync::Arc::new(m.snapshot().unwrap())).unwrap();
        Arc::new(srv)
    }

    fn snap_of(srv: &InferenceServer) -> Arc<Checkpoint> {
        srv.swap_handle().current().unwrap().ckpt.clone()
    }

    #[test]
    fn open_loop_serves_everything_and_replays_content() {
        let srv = installed_server();
        let spec = OpenLoopSpec {
            load: LoadSpec {
                requests: 200,
                seed: 7,
                min_features: 1,
                max_features: 6,
            },
            rps: 50_000.0,
        };
        let run = open_loop(&srv, &spec);
        assert_eq!(run.report.sent, 200);
        assert_eq!(run.report.ok, 200, "errors: {:?}", run.errors);
        assert_eq!(run.report.failed, 0);
        assert_eq!(run.report.latency.count(), 200);
        assert!(run.report.goodput() > 0.0);

        // every response re-derives exactly from the regenerated request
        let requests = spec.load.open_loop_requests();
        let ck = snap_of(&srv);
        let fwd = MockForward::new();
        for resp in &run.responses {
            let feats = &requests[resp.id as usize];
            assert_eq!(resp.probs, fwd.probs(&ck, feats).unwrap());
        }
    }

    #[test]
    fn closed_loop_splits_requests_across_clients() {
        let srv = installed_server();
        let run = closed_loop(
            &srv,
            3,
            &LoadSpec {
                requests: 100,
                seed: 11,
                min_features: 2,
                max_features: 4,
            },
        );
        assert_eq!(run.report.sent, 100);
        assert_eq!(run.report.ok, 100, "errors: {:?}", run.errors);
        assert_eq!(run.responses.len(), 100);
        // mixed sizes honored
        assert!(run
            .responses
            .iter()
            .all(|r| (2..=4).contains(&r.probs.len())));
    }
}

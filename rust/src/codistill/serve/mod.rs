//! The serving tier: batched inference over installed planes with
//! zero-downtime checkpoint hot-swap.
//!
//! The paper's §3.5 argument — online distillation makes the *exact
//! predictions* of a model dramatically more reproducible — only
//! matters once something serves predictions. This module is that
//! something: it takes the checkpoints a codistillation run publishes
//! through any [`ExchangeTransport`](crate::codistill::ExchangeTransport)
//! and turns them into a live prediction endpoint that follows the run.
//!
//! ## Architecture
//!
//! ```text
//!  publisher(s)                         serving process
//!  ───────────                          ──────────────────────────────
//!  train → publish ──► transport ──►  Subscription (poll last_steps,
//!                      (spool/socket/     │         DeltaCache fetch)
//!                       inproc, ±Retry)   ▼ install (digest-verified)
//!                                      SwapHandle ── Arc<ServingPlane>
//!                                          │ atomic swap, never torn
//!        clients ──► submit ──► BatchQueue ┴► worker: snapshot plane,
//!                    (open/closed loadgen)     predict micro-batch,
//!                                              respond {probs, step,
//!                                               plane_digest, latency}
//! ```
//!
//! * [`SwapHandle`] (in [`swap`]) owns the current
//!   [`ServingPlane`] — an `Arc<Checkpoint>` plus its recomputed plane
//!   digest. `install` re-hashes every window before the pointer flip,
//!   so a corrupt or torn plane is rejected *before* any request can
//!   observe it; readers clone the `Arc` in O(1) and are immune to
//!   concurrent swaps.
//! * [`BatchQueue`] (in [`batcher`]) forms size- and deadline-triggered
//!   micro-batches over mixed request sizes: a batch closes when its
//!   summed feature count reaches `max_batch_items` or its oldest
//!   request has waited `max_delay`, whichever is first.
//! * [`InferenceServer`] (in [`server`]) drives worker threads that
//!   snapshot the plane **once per batch** — every response in a batch
//!   is consistent with exactly one installed plane, and each response
//!   carries the `(step, plane_digest)` it was computed against so the
//!   property is externally checkable.
//! * [`loadgen`] provides seeded open-loop (fixed arrival schedule,
//!   unbounded concurrency) and closed-loop (N synchronous clients)
//!   generators over mixed request sizes.
//! * Swap-to-swap prediction movement is measured against a fixed probe
//!   set and aggregated in a
//!   [`ChurnReport`](crate::metrics::ChurnReport) — the serving-side
//!   Table 1: how much did the endpoint's answers move when the model
//!   under it changed?
//!
//! The subscription loop that feeds `install` lives with the other
//! transport machinery as
//! [`transport::subscribe`](crate::codistill::transport::subscribe); it
//! reuses [`DeltaCache`](crate::codistill::DeltaCache) so steady-state
//! updates move only changed windows, and composes with
//! [`Retry`](crate::codistill::Retry) for lossy media.
//!
//! ## Mock mode
//!
//! [`ServingModel`] abstracts the forward pass.
//! [`MockForward`](crate::models::MockForward) implements it as a
//! deterministic hash-tap function of the plane bytes, so the whole
//! tier runs without artifacts or XLA — `codistill serve` from the CLI
//! and `tests/serve_hotswap.rs` both drive a `DriftMember` publisher
//! against it.

pub mod batcher;
pub mod loadgen;
pub mod server;
pub mod swap;

pub use batcher::{BatchPolicy, BatchQueue};
pub use loadgen::{closed_loop, open_loop, LoadReport, LoadRun, LoadSpec, OpenLoopSpec};
pub use server::{BatchBucket, InferenceServer, ServeConfig, ServeStats};
pub use swap::{ServingPlane, SwapHandle};

use crate::codistill::Checkpoint;
use anyhow::Result;
use std::time::Duration;

/// A forward pass the serving tier can run against any installed plane.
///
/// Implementations must be pure in the plane: same `(ckpt, features)`
/// must yield bit-identical probabilities, because the hot-swap tests
/// re-derive responses offline from retained checkpoints and compare
/// exactly. `&self` methods run concurrently from worker threads.
pub trait ServingModel: Send + Sync + 'static {
    /// One probability per feature id, computed against `ckpt`'s plane.
    fn predict(&self, ckpt: &Checkpoint, features: &[u64]) -> Result<Vec<f32>>;
}

/// One inference request: a batch-mergeable bag of feature ids.
#[derive(Debug, Clone)]
pub struct InferRequest {
    /// Server-assigned submission index (dense, in submit order).
    pub id: u64,
    /// Feature ids to score (mixed sizes across requests are expected).
    pub features: Vec<u64>,
}

/// One served response, carrying enough provenance to audit it.
#[derive(Debug, Clone)]
pub struct InferResponse {
    /// Echo of [`InferRequest::id`].
    pub id: u64,
    /// One probability per requested feature.
    pub probs: Vec<f32>,
    /// Publisher step of the plane that served this request.
    pub step: u64,
    /// Content digest of that plane — with `step`, pins the response to
    /// exactly one installed plane (the torn-request check).
    pub plane_digest: u64,
    /// Requests that shared this micro-batch (≥ 1).
    pub batch_requests: usize,
    /// Queue + compute time from submit to response.
    pub latency: Duration,
}

//! Training schedules.
//!
//! * [`DistillSchedule`]: the paper enables ψ "once training has gotten off
//!   the ground" (§2) — weight 0 for `burn_in` steps, then a linear ramp to
//!   the target weight over `ramp` steps (avoiding the "complicated loss
//!   function schedule" the paper warns about: two numbers, not a curve).
//! * [`LrSchedule`]: constant, or the Goyal et al. warmup + step-decay used
//!   by the ImageNet experiments.

/// Distillation-weight schedule: burn-in, then linear ramp to `weight`.
#[derive(Debug, Clone, Copy)]
pub struct DistillSchedule {
    pub burn_in: u64,
    pub ramp: u64,
    pub weight: f32,
}

impl DistillSchedule {
    pub fn new(burn_in: u64, ramp: u64, weight: f32) -> Self {
        DistillSchedule {
            burn_in,
            ramp,
            weight,
        }
    }

    /// A schedule that never enables distillation (baselines).
    pub fn off() -> Self {
        DistillSchedule {
            burn_in: u64::MAX,
            ramp: 0,
            weight: 0.0,
        }
    }

    /// ψ weight at a given step.
    pub fn weight_at(&self, step: u64) -> f32 {
        if step < self.burn_in {
            return 0.0;
        }
        if self.ramp == 0 {
            return self.weight;
        }
        let into = (step - self.burn_in).min(self.ramp) as f32;
        self.weight * into / self.ramp as f32
    }

    pub fn enabled_at(&self, step: u64) -> bool {
        self.weight_at(step) > 0.0
    }
}

/// Learning-rate schedule.
#[derive(Debug, Clone)]
pub enum LrSchedule {
    Constant(f32),
    /// Goyal et al.: linear warmup from `base/warmup` to `base` over
    /// `warmup` steps, then ×`decay` at each milestone.
    WarmupStep {
        base: f32,
        warmup: u64,
        milestones: Vec<u64>,
        decay: f32,
    },
}

impl LrSchedule {
    pub fn at(&self, step: u64) -> f32 {
        match self {
            LrSchedule::Constant(lr) => *lr,
            LrSchedule::WarmupStep {
                base,
                warmup,
                milestones,
                decay,
            } => {
                if step < *warmup {
                    return base * (step + 1) as f32 / *warmup as f32;
                }
                let hits = milestones.iter().filter(|&&m| step >= m).count() as i32;
                base * decay.powi(hits)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distill_burn_in_then_ramp() {
        let s = DistillSchedule::new(100, 50, 1.0);
        assert_eq!(s.weight_at(0), 0.0);
        assert_eq!(s.weight_at(99), 0.0);
        assert_eq!(s.weight_at(100), 0.0); // ramp starts at 0
        assert!((s.weight_at(125) - 0.5).abs() < 1e-6);
        assert_eq!(s.weight_at(150), 1.0);
        assert_eq!(s.weight_at(10_000), 1.0);
        assert!(!s.enabled_at(50));
        assert!(s.enabled_at(150));
    }

    #[test]
    fn distill_no_ramp_is_step_function() {
        let s = DistillSchedule::new(10, 0, 0.7);
        assert_eq!(s.weight_at(9), 0.0);
        assert_eq!(s.weight_at(10), 0.7);
    }

    #[test]
    fn distill_off_never_enables() {
        let s = DistillSchedule::off();
        assert_eq!(s.weight_at(u64::MAX - 1), 0.0);
    }

    #[test]
    fn lr_constant() {
        assert_eq!(LrSchedule::Constant(0.1).at(12345), 0.1);
    }

    #[test]
    fn lr_warmup_and_decay() {
        let s = LrSchedule::WarmupStep {
            base: 1.0,
            warmup: 10,
            milestones: vec![100, 200],
            decay: 0.1,
        };
        assert!((s.at(0) - 0.1).abs() < 1e-6);
        assert!((s.at(4) - 0.5).abs() < 1e-6);
        assert!((s.at(10) - 1.0).abs() < 1e-6);
        assert!((s.at(99) - 1.0).abs() < 1e-6);
        assert!((s.at(100) - 0.1).abs() < 1e-6);
        assert!((s.at(250) - 0.01).abs() < 1e-6);
    }
}

//! Checkpoint store: the codistillation communication substrate.
//!
//! Stands in for the paper's shared filesystem (§2.1: "workers checkpoint
//! their parameters; other workers load the freshest available checkpoints").
//! Checkpoints are immutable parameter snapshots tagged with the publishing
//! member and step; the store keeps a bounded history per member so the
//! orchestrator can both read "freshest available" and deliberately fetch
//! older snapshots (staleness injection for the Fig 4-style ablations).
//!
//! An optional disk spool writes every published checkpoint through the
//! same text-free binary format used by the CLI's `--save` flag, proving
//! the exchange also works across processes.

use crate::runtime::{Tensor, TensorMap};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Immutable parameter snapshot.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Publishing member id.
    pub member: usize,
    /// Member-local step at publication.
    pub step: u64,
    /// `params.*` entries only.
    pub params: TensorMap,
}

impl Checkpoint {
    pub fn new(member: usize, step: u64, params: TensorMap) -> Self {
        Checkpoint {
            member,
            step,
            params,
        }
    }

    /// Serialize to a simple length-prefixed binary format.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating {}", path.display()))?,
        );
        f.write_all(b"CKPT0001")?;
        f.write_all(&(self.member as u64).to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        let entries = self.params.prefix_entries("");
        f.write_all(&(entries.len() as u64).to_le_bytes())?;
        for (name, t) in entries {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u32).to_le_bytes())?;
            f.write_all(nb)?;
            let shape = t.shape();
            f.write_all(&(shape.len() as u32).to_le_bytes())?;
            for &d in shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            match t {
                Tensor::F32 { data, .. } => {
                    f.write_all(&[0u8])?;
                    for v in data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
                Tensor::I32 { data, .. } => {
                    f.write_all(&[1u8])?;
                    for v in data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Load a checkpoint written by [`Checkpoint::save`].
    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"CKPT0001" {
            bail!("{}: bad checkpoint magic", path.display());
        }
        let member = read_u64(&mut f)? as usize;
        let step = read_u64(&mut f)?;
        let n = read_u64(&mut f)? as usize;
        let mut params = TensorMap::new();
        for _ in 0..n {
            let name_len = read_u32(&mut f)? as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("checkpoint name not utf8")?;
            let rank = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u64(&mut f)? as usize);
            }
            let numel: usize = shape.iter().product();
            let mut tag = [0u8; 1];
            f.read_exact(&mut tag)?;
            let t = match tag[0] {
                0 => {
                    let mut data = vec![0f32; numel];
                    let mut buf = [0u8; 4];
                    for v in data.iter_mut() {
                        f.read_exact(&mut buf)?;
                        *v = f32::from_le_bytes(buf);
                    }
                    Tensor::f32(&shape, data)?
                }
                1 => {
                    let mut data = vec![0i32; numel];
                    let mut buf = [0u8; 4];
                    for v in data.iter_mut() {
                        f.read_exact(&mut buf)?;
                        *v = i32::from_le_bytes(buf);
                    }
                    Tensor::i32(&shape, data)?
                }
                other => bail!("bad dtype tag {other}"),
            };
            params.insert(name, t);
        }
        Ok(Checkpoint {
            member,
            step,
            params,
        })
    }
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Bounded per-member checkpoint history with freshest-available reads.
pub struct CheckpointStore {
    inner: Mutex<HashMap<usize, Vec<Arc<Checkpoint>>>>,
    history: usize,
    spool: Option<PathBuf>,
}

impl CheckpointStore {
    pub fn new(history: usize) -> Self {
        CheckpointStore {
            inner: Mutex::new(HashMap::new()),
            history: history.max(1),
            spool: None,
        }
    }

    /// Also write every published checkpoint to `dir` (cross-process mode).
    pub fn with_spool(mut self, dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)?;
        self.spool = Some(dir.to_path_buf());
        Ok(self)
    }

    /// Publish a member's checkpoint.
    pub fn publish(&self, ckpt: Checkpoint) -> Result<()> {
        if let Some(dir) = &self.spool {
            let path = dir.join(format!("member{}_step{}.ckpt", ckpt.member, ckpt.step));
            ckpt.save(&path)?;
        }
        let mut inner = self.inner.lock().unwrap();
        let hist = inner.entry(ckpt.member).or_default();
        if let Some(last) = hist.last() {
            if ckpt.step < last.step {
                bail!(
                    "member {} published step {} after step {}",
                    ckpt.member,
                    ckpt.step,
                    last.step
                );
            }
        }
        hist.push(Arc::new(ckpt));
        let len = hist.len();
        if len > self.history {
            hist.drain(0..len - self.history);
        }
        Ok(())
    }

    /// Freshest available checkpoint from a member (paper semantics).
    pub fn latest(&self, member: usize) -> Option<Arc<Checkpoint>> {
        self.inner
            .lock()
            .unwrap()
            .get(&member)
            .and_then(|h| h.last().cloned())
    }

    /// Freshest checkpoint from a member with `step <= max_step`
    /// (explicit staleness injection).
    pub fn latest_at_most(&self, member: usize, max_step: u64) -> Option<Arc<Checkpoint>> {
        self.inner
            .lock()
            .unwrap()
            .get(&member)
            .and_then(|h| h.iter().rev().find(|c| c.step <= max_step).cloned())
    }

    /// Staleness (in steps) a reader at `now` would observe for a member.
    pub fn staleness(&self, member: usize, now: u64) -> Option<u64> {
        self.latest(member).map(|c| now.saturating_sub(c.step))
    }

    pub fn members(&self) -> Vec<usize> {
        let mut m: Vec<usize> = self.inner.lock().unwrap().keys().copied().collect();
        m.sort();
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ckpt(member: usize, step: u64, val: f32) -> Checkpoint {
        let mut params = TensorMap::new();
        params.insert("params.w", Tensor::f32(&[2], vec![val, val]).unwrap());
        Checkpoint::new(member, step, params)
    }

    #[test]
    fn latest_returns_freshest() {
        let store = CheckpointStore::new(4);
        store.publish(ckpt(0, 10, 1.0)).unwrap();
        store.publish(ckpt(0, 20, 2.0)).unwrap();
        let c = store.latest(0).unwrap();
        assert_eq!(c.step, 20);
        assert_eq!(store.latest(1).map(|c| c.step), None);
    }

    #[test]
    fn latest_at_most_respects_bound() {
        let store = CheckpointStore::new(8);
        for s in [5u64, 10, 15, 20] {
            store.publish(ckpt(1, s, s as f32)).unwrap();
        }
        assert_eq!(store.latest_at_most(1, 12).unwrap().step, 10);
        assert!(store.latest_at_most(1, 4).is_none());
        assert_eq!(store.latest_at_most(1, 100).unwrap().step, 20);
    }

    #[test]
    fn history_is_bounded() {
        let store = CheckpointStore::new(2);
        for s in 0..10u64 {
            store.publish(ckpt(0, s, 0.0)).unwrap();
        }
        // only the last 2 checkpoints (steps 8, 9) survive
        assert_eq!(store.latest(0).unwrap().step, 9);
        assert_eq!(store.latest_at_most(0, 8).unwrap().step, 8);
        assert!(store.latest_at_most(0, 7).is_none(), "old history retained");
    }

    #[test]
    fn rejects_step_regression() {
        let store = CheckpointStore::new(4);
        store.publish(ckpt(0, 10, 0.0)).unwrap();
        assert!(store.publish(ckpt(0, 5, 0.0)).is_err());
    }

    #[test]
    fn staleness_accounting() {
        let store = CheckpointStore::new(4);
        store.publish(ckpt(2, 100, 0.0)).unwrap();
        assert_eq!(store.staleness(2, 150), Some(50));
        assert_eq!(store.staleness(2, 50), Some(0)); // saturating
        assert_eq!(store.staleness(3, 10), None);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("codistill_test_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        let mut params = TensorMap::new();
        params.insert("params.w", Tensor::f32(&[2, 2], vec![1.0, -2.0, 3.5, 0.0]).unwrap());
        params.insert("params.ids", Tensor::i32(&[3], vec![7, 8, 9]).unwrap());
        let c = Checkpoint::new(3, 42, params);
        c.save(&path).unwrap();
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(l.member, 3);
        assert_eq!(l.step, 42);
        assert_eq!(
            l.params.get("params.w").unwrap().as_f32().unwrap(),
            &[1.0, -2.0, 3.5, 0.0]
        );
        assert_eq!(l.params.get("params.ids").unwrap().as_i32().unwrap(), &[7, 8, 9]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn spool_writes_files() {
        let dir = std::env::temp_dir().join(format!("codistill_spool_{}", std::process::id()));
        let store = CheckpointStore::new(2).with_spool(&dir).unwrap();
        store.publish(ckpt(0, 7, 1.0)).unwrap();
        assert!(dir.join("member0_step7.ckpt").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Checkpoint store: the codistillation communication substrate.
//!
//! Stands in for the paper's shared filesystem (§2.1: "workers checkpoint
//! their parameters; other workers load the freshest available checkpoints").
//! Checkpoints are immutable parameter snapshots tagged with the publishing
//! member and step; the store keeps a bounded history per member so the
//! orchestrator can both read "freshest available" and deliberately fetch
//! older snapshots (staleness injection for the Fig 4-style ablations).
//!
//! Snapshots live on the flat parameter plane: a [`Checkpoint`] is an
//! `Arc<FlatBuffer>` (all f32 leaves, one contiguous buffer, shared layout)
//! plus a small residual map for non-f32 leaves. Publishing and reading are
//! therefore **zero-copy** — the store and every reader share the same
//! buffer — and teacher reloads scatter the plane into existing tensor
//! storage instead of rebuilding named maps.
//!
//! On disk there are two formats, both understood by [`Checkpoint::load`]:
//!
//! * `CKPT0002` (written by [`Checkpoint::save`]): a window table followed
//!   by the whole flat plane as one contiguous byte slice — no per-tensor
//!   framing on the payload.
//! * `CKPT0001` (written by [`Checkpoint::save_v1`]): the original
//!   per-tensor framing, kept for spools produced by older builds.
//!
//! An optional disk spool writes every published checkpoint through the
//! same binary format used by the CLI's `--save` flag, proving the
//! exchange also works across processes.

use crate::runtime::flat::{FlatBuffer, FlatLayout};
use crate::runtime::{Tensor, TensorMap};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

const MAGIC_V1: &[u8; 8] = b"CKPT0001";
const MAGIC_V2: &[u8; 8] = b"CKPT0002";

/// Immutable parameter snapshot on the flat plane.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Publishing member id.
    pub member: usize,
    /// Member-local step at publication.
    pub step: u64,
    /// All f32 `params.*` leaves, fused. Shared zero-copy between the
    /// publisher, the store's history, and every reader.
    flat: Arc<FlatBuffer>,
    /// Non-f32 leaves (embedding id tables etc.) — usually empty.
    residual: TensorMap,
}

impl Checkpoint {
    /// Snapshot a named parameter map (layout derived from the map itself).
    pub fn new(member: usize, step: u64, params: TensorMap) -> Self {
        let layout = Arc::new(FlatLayout::from_map(&params, ""));
        Self::gather_from(member, step, layout, &params, "")
            .expect("gathering a layout derived from its own source map")
    }

    /// Snapshot the `prefix` leaves of a live variable map onto a
    /// pre-computed plane — the members' hot path: the layout is computed
    /// once per member and reused by every publication, so a snapshot is
    /// one contiguous gather (plus a clone per rare non-f32 leaf).
    pub fn gather_from(
        member: usize,
        step: u64,
        layout: Arc<FlatLayout>,
        vars: &TensorMap,
        prefix: &str,
    ) -> Result<Self> {
        let flat = FlatBuffer::gather(layout, vars)?;
        let mut residual = TensorMap::new();
        for (k, t) in vars.prefix_iter(prefix) {
            if t.as_f32().is_err() {
                residual.insert(k, t.clone());
            }
        }
        Ok(Checkpoint {
            member,
            step,
            flat: Arc::new(flat),
            residual,
        })
    }

    /// Snapshot from a pre-gathered plane (the members' hot path: layout is
    /// computed once per member and reused for every publication).
    pub fn from_flat(
        member: usize,
        step: u64,
        flat: Arc<FlatBuffer>,
        residual: TensorMap,
    ) -> Self {
        Checkpoint {
            member,
            step,
            flat,
            residual,
        }
    }

    /// The fused f32 plane (zero-copy view shared with the store).
    pub fn flat(&self) -> &Arc<FlatBuffer> {
        &self.flat
    }

    /// Non-f32 leaves.
    pub fn residual(&self) -> &TensorMap {
        &self.residual
    }

    /// Materialize the snapshot as a named map (allocates; prefer
    /// [`Checkpoint::scatter_params_into`] on reload paths).
    pub fn params(&self) -> TensorMap {
        let mut m = self
            .flat
            .to_map()
            .expect("materializing a self-consistent flat plane");
        m.merge(self.residual.clone());
        m
    }

    /// Scatter the snapshot into existing storage: same-shape tensors are
    /// overwritten in place (no allocation), anything else is inserted.
    /// Entries of `dst` outside the snapshot are left untouched — callers
    /// refreshing a whole teacher map should use
    /// [`Checkpoint::refresh_params`], which guards against that.
    pub fn scatter_params_into(&self, dst: &mut TensorMap) -> Result<()> {
        self.flat.scatter_into(dst)?;
        for (k, t) in self.residual.prefix_iter("") {
            dst.insert(k, t.clone());
        }
        Ok(())
    }

    /// Whether `m` holds exactly this snapshot's entries (names + shapes),
    /// i.e. an in-place scatter fully overwrites it with nothing stale
    /// left behind.
    fn plane_matches(&self, m: &TensorMap) -> bool {
        m.len() == self.flat.layout().len() + self.residual.len()
            && self.flat.layout().entries().iter().all(|e| {
                m.get(&e.name)
                    .map(|t| t.shape() == e.shape.as_slice() && t.as_f32().is_ok())
                    .unwrap_or(false)
            })
            && self.residual.prefix_iter("").all(|(k, t)| {
                m.get(k).map(|p| p.shape() == t.shape()).unwrap_or(false)
            })
    }

    /// Refresh a teacher map previously materialized from a checkpoint:
    /// in place (no allocation) when the entry sets line up, a full
    /// rebuild when they don't — never a silent mix of old and new
    /// windows.
    pub fn refresh_params(&self, prev: TensorMap) -> Result<TensorMap> {
        if self.plane_matches(&prev) {
            let mut m = prev;
            self.scatter_params_into(&mut m)?;
            Ok(m)
        } else {
            Ok(self.params())
        }
    }

    /// Total parameter elements in the snapshot.
    pub fn numel(&self) -> usize {
        self.flat.layout().total_len() + self.residual.prefix_numel("")
    }

    /// Serialize (format `CKPT0002`): window table + the flat plane as one
    /// contiguous byte slice + residual entries.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating {}", path.display()))?,
        );
        f.write_all(MAGIC_V2)?;
        f.write_all(&(self.member as u64).to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;

        let layout = self.flat.layout();
        f.write_all(&(layout.len() as u64).to_le_bytes())?;
        for e in layout.entries() {
            write_name(&mut f, &e.name)?;
            write_shape(&mut f, &e.shape)?;
        }
        // The whole plane, unframed.
        f.write_all(&(self.flat.data().len() as u64).to_le_bytes())?;
        write_f32s(&mut f, self.flat.data())?;

        let residual = self.residual.prefix_entries("");
        f.write_all(&(residual.len() as u64).to_le_bytes())?;
        for (name, t) in residual {
            write_name(&mut f, name)?;
            write_shape(&mut f, t.shape())?;
            match t {
                Tensor::F32 { data, .. } => {
                    f.write_all(&[0u8])?;
                    write_f32s(&mut f, data)?;
                }
                Tensor::I32 { data, .. } => {
                    f.write_all(&[1u8])?;
                    write_i32s(&mut f, data)?;
                }
            }
        }
        Ok(())
    }

    /// Serialize in the original `CKPT0001` per-tensor framing (compat
    /// writer for consumers of older spools).
    pub fn save_v1(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating {}", path.display()))?,
        );
        f.write_all(MAGIC_V1)?;
        f.write_all(&(self.member as u64).to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        let params = self.params();
        let entries = params.prefix_entries("");
        f.write_all(&(entries.len() as u64).to_le_bytes())?;
        for (name, t) in entries {
            write_name(&mut f, name)?;
            write_shape(&mut f, t.shape())?;
            match t {
                Tensor::F32 { data, .. } => {
                    f.write_all(&[0u8])?;
                    write_f32s(&mut f, data)?;
                }
                Tensor::I32 { data, .. } => {
                    f.write_all(&[1u8])?;
                    write_i32s(&mut f, data)?;
                }
            }
        }
        Ok(())
    }

    /// Load a checkpoint written by [`Checkpoint::save`] (either format).
    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        match &magic {
            m if m == MAGIC_V2 => {
                Self::load_v2(&mut f).with_context(|| format!("reading {}", path.display()))
            }
            m if m == MAGIC_V1 => {
                Self::load_v1(&mut f).with_context(|| format!("reading {}", path.display()))
            }
            _ => bail!("{}: bad checkpoint magic", path.display()),
        }
    }

    fn load_v2(f: &mut impl Read) -> Result<Self> {
        let member = read_u64(f)? as usize;
        let step = read_u64(f)?;

        let n_windows = read_u64(f)? as usize;
        let mut parts = Vec::with_capacity(n_windows);
        for _ in 0..n_windows {
            let name = read_name(f)?;
            let shape = read_shape(f)?;
            parts.push((name, shape));
        }
        let layout = Arc::new(FlatLayout::from_named_shapes(parts));

        let payload = read_u64(f)? as usize;
        if payload != layout.total_len() {
            bail!(
                "flat payload has {} elems, window table wants {}",
                payload,
                layout.total_len()
            );
        }
        let mut data = vec![0f32; payload];
        read_f32s(f, &mut data)?;
        let flat = FlatBuffer::from_data(layout, data)?;

        let n_residual = read_u64(f)? as usize;
        let mut residual = TensorMap::new();
        for _ in 0..n_residual {
            let (name, t) = read_framed_tensor(f)?;
            residual.insert(name, t);
        }
        Ok(Checkpoint {
            member,
            step,
            flat: Arc::new(flat),
            residual,
        })
    }

    fn load_v1(f: &mut impl Read) -> Result<Self> {
        let member = read_u64(f)? as usize;
        let step = read_u64(f)?;
        let n = read_u64(f)? as usize;
        let mut params = TensorMap::new();
        for _ in 0..n {
            let (name, t) = read_framed_tensor(f)?;
            params.insert(name, t);
        }
        Ok(Checkpoint::new(member, step, params))
    }
}

// ------------------------------------------------------------ binary plumbing

fn write_name(f: &mut impl Write, name: &str) -> Result<()> {
    let nb = name.as_bytes();
    f.write_all(&(nb.len() as u32).to_le_bytes())?;
    f.write_all(nb)?;
    Ok(())
}

fn read_name(f: &mut impl Read) -> Result<String> {
    let len = read_u32(f)? as usize;
    let mut buf = vec![0u8; len];
    f.read_exact(&mut buf)?;
    String::from_utf8(buf).context("checkpoint name not utf8")
}

fn write_shape(f: &mut impl Write, shape: &[usize]) -> Result<()> {
    f.write_all(&(shape.len() as u32).to_le_bytes())?;
    for &d in shape {
        f.write_all(&(d as u64).to_le_bytes())?;
    }
    Ok(())
}

fn read_shape(f: &mut impl Read) -> Result<Vec<usize>> {
    let rank = read_u32(f)? as usize;
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        shape.push(read_u64(f)? as usize);
    }
    Ok(shape)
}

/// One `CKPT0001`-framed tensor: name, shape, dtype tag, payload.
fn read_framed_tensor(f: &mut impl Read) -> Result<(String, Tensor)> {
    let name = read_name(f)?;
    let shape = read_shape(f)?;
    let numel: usize = shape.iter().product();
    let mut tag = [0u8; 1];
    f.read_exact(&mut tag)?;
    let t = match tag[0] {
        0 => {
            let mut data = vec![0f32; numel];
            read_f32s(f, &mut data)?;
            Tensor::f32(&shape, data)?
        }
        1 => {
            let mut data = vec![0i32; numel];
            read_i32s(f, &mut data)?;
            Tensor::i32(&shape, data)?
        }
        other => bail!("bad dtype tag {other}"),
    };
    Ok((name, t))
}

/// Staging buffer: 16 KiB of LE bytes per syscall-sized write/read, instead
/// of the seed's 4-bytes-per-call loop. Both payload types are 4 bytes.
const IO_CHUNK_ELEMS: usize = 4096;

/// Chunked little-endian slice IO over any 4-byte element type.
macro_rules! le_slice_io {
    ($write:ident, $read:ident, $t:ty) => {
        fn $write(f: &mut impl Write, data: &[$t]) -> Result<()> {
            let mut buf = [0u8; IO_CHUNK_ELEMS * 4];
            for chunk in data.chunks(IO_CHUNK_ELEMS) {
                for (i, v) in chunk.iter().enumerate() {
                    buf[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
                }
                f.write_all(&buf[..chunk.len() * 4])?;
            }
            Ok(())
        }

        fn $read(f: &mut impl Read, out: &mut [$t]) -> Result<()> {
            let mut buf = [0u8; IO_CHUNK_ELEMS * 4];
            for chunk in out.chunks_mut(IO_CHUNK_ELEMS) {
                let bytes = &mut buf[..chunk.len() * 4];
                f.read_exact(bytes)?;
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = <$t>::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap());
                }
            }
            Ok(())
        }
    };
}

le_slice_io!(write_f32s, read_f32s, f32);
le_slice_io!(write_i32s, read_i32s, i32);

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Bounded per-member checkpoint history with freshest-available reads.
/// Publications and reads share `Arc<Checkpoint>` (and through it the flat
/// plane), so the in-memory exchange never copies parameters.
pub struct CheckpointStore {
    inner: Mutex<HashMap<usize, Vec<Arc<Checkpoint>>>>,
    history: usize,
    spool: Option<PathBuf>,
}

impl CheckpointStore {
    pub fn new(history: usize) -> Self {
        CheckpointStore {
            inner: Mutex::new(HashMap::new()),
            history: history.max(1),
            spool: None,
        }
    }

    /// Also write every published checkpoint to `dir` (cross-process mode).
    pub fn with_spool(mut self, dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)?;
        self.spool = Some(dir.to_path_buf());
        Ok(self)
    }

    /// Publish a member's checkpoint.
    pub fn publish(&self, ckpt: Checkpoint) -> Result<()> {
        if let Some(dir) = &self.spool {
            let path = dir.join(format!("member{}_step{}.ckpt", ckpt.member, ckpt.step));
            ckpt.save(&path)?;
        }
        let mut inner = self.inner.lock().unwrap();
        let hist = inner.entry(ckpt.member).or_default();
        if let Some(last) = hist.last() {
            if ckpt.step < last.step {
                bail!(
                    "member {} published step {} after step {}",
                    ckpt.member,
                    ckpt.step,
                    last.step
                );
            }
        }
        hist.push(Arc::new(ckpt));
        let len = hist.len();
        if len > self.history {
            hist.drain(0..len - self.history);
        }
        Ok(())
    }

    /// Freshest available checkpoint from a member (paper semantics).
    pub fn latest(&self, member: usize) -> Option<Arc<Checkpoint>> {
        self.inner
            .lock()
            .unwrap()
            .get(&member)
            .and_then(|h| h.last().cloned())
    }

    /// Freshest checkpoint from a member with `step <= max_step`
    /// (explicit staleness injection).
    pub fn latest_at_most(&self, member: usize, max_step: u64) -> Option<Arc<Checkpoint>> {
        self.inner
            .lock()
            .unwrap()
            .get(&member)
            .and_then(|h| h.iter().rev().find(|c| c.step <= max_step).cloned())
    }

    /// Staleness (in steps) a reader at `now` would observe for a member.
    pub fn staleness(&self, member: usize, now: u64) -> Option<u64> {
        self.latest(member).map(|c| now.saturating_sub(c.step))
    }

    pub fn members(&self) -> Vec<usize> {
        let mut m: Vec<usize> = self.inner.lock().unwrap().keys().copied().collect();
        m.sort();
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ckpt(member: usize, step: u64, val: f32) -> Checkpoint {
        let mut params = TensorMap::new();
        params.insert("params.w", Tensor::f32(&[2], vec![val, val]).unwrap());
        Checkpoint::new(member, step, params)
    }

    #[test]
    fn latest_returns_freshest() {
        let store = CheckpointStore::new(4);
        store.publish(ckpt(0, 10, 1.0)).unwrap();
        store.publish(ckpt(0, 20, 2.0)).unwrap();
        let c = store.latest(0).unwrap();
        assert_eq!(c.step, 20);
        assert_eq!(store.latest(1).map(|c| c.step), None);
    }

    #[test]
    fn reads_share_the_flat_plane_zero_copy() {
        let store = CheckpointStore::new(4);
        let c = ckpt(0, 1, 3.0);
        let plane = c.flat().clone();
        store.publish(c).unwrap();
        let a = store.latest(0).unwrap();
        let b = store.latest(0).unwrap();
        assert!(Arc::ptr_eq(a.flat(), &plane), "publish copied the plane");
        assert!(Arc::ptr_eq(a.flat(), b.flat()), "reads copied the plane");
        assert_eq!(a.flat().view("params.w").unwrap(), &[3.0, 3.0]);
    }

    #[test]
    fn latest_at_most_respects_bound() {
        let store = CheckpointStore::new(8);
        for s in [5u64, 10, 15, 20] {
            store.publish(ckpt(1, s, s as f32)).unwrap();
        }
        assert_eq!(store.latest_at_most(1, 12).unwrap().step, 10);
        assert!(store.latest_at_most(1, 4).is_none());
        assert_eq!(store.latest_at_most(1, 100).unwrap().step, 20);
    }

    #[test]
    fn history_is_bounded() {
        let store = CheckpointStore::new(2);
        for s in 0..10u64 {
            store.publish(ckpt(0, s, 0.0)).unwrap();
        }
        // only the last 2 checkpoints (steps 8, 9) survive
        assert_eq!(store.latest(0).unwrap().step, 9);
        assert_eq!(store.latest_at_most(0, 8).unwrap().step, 8);
        assert!(store.latest_at_most(0, 7).is_none(), "old history retained");
    }

    #[test]
    fn rejects_step_regression() {
        let store = CheckpointStore::new(4);
        store.publish(ckpt(0, 10, 0.0)).unwrap();
        assert!(store.publish(ckpt(0, 5, 0.0)).is_err());
    }

    #[test]
    fn staleness_accounting() {
        let store = CheckpointStore::new(4);
        store.publish(ckpt(2, 100, 0.0)).unwrap();
        assert_eq!(store.staleness(2, 150), Some(50));
        assert_eq!(store.staleness(2, 50), Some(0)); // saturating
        assert_eq!(store.staleness(3, 10), None);
    }

    fn mixed_params() -> TensorMap {
        let mut params = TensorMap::new();
        params.insert("params.w", Tensor::f32(&[2, 2], vec![1.0, -2.0, 3.5, 0.0]).unwrap());
        params.insert("params.ids", Tensor::i32(&[3], vec![7, 8, 9]).unwrap());
        params
    }

    #[test]
    fn save_load_roundtrip_v2() {
        let dir = std::env::temp_dir().join(format!("codistill_ckpt_v2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        let c = Checkpoint::new(3, 42, mixed_params());
        c.save(&path).unwrap();
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(l.member, 3);
        assert_eq!(l.step, 42);
        let p = l.params();
        assert_eq!(
            p.get("params.w").unwrap().as_f32().unwrap(),
            &[1.0, -2.0, 3.5, 0.0]
        );
        assert_eq!(p.get("params.w").unwrap().shape(), &[2, 2]);
        assert_eq!(p.get("params.ids").unwrap().as_i32().unwrap(), &[7, 8, 9]);
        assert!(l.flat().layout().same_plane(c.flat().layout()));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_writer_and_reader_stay_compatible() {
        let dir = std::env::temp_dir().join(format!("codistill_ckpt_v1_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c1.ckpt");
        let c = Checkpoint::new(1, 7, mixed_params());
        c.save_v1(&path).unwrap();
        // sanity: it really is the old format on disk
        let raw = std::fs::read(&path).unwrap();
        assert_eq!(&raw[..8], MAGIC_V1);
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(l.member, 1);
        assert_eq!(l.step, 7);
        assert_eq!(
            l.params().get("params.w").unwrap().as_f32().unwrap(),
            c.params().get("params.w").unwrap().as_f32().unwrap()
        );
        assert_eq!(
            l.params().get("params.ids").unwrap().as_i32().unwrap(),
            &[7, 8, 9]
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn refresh_params_rebuilds_on_plane_mismatch() {
        let a = Checkpoint::new(0, 1, mixed_params());
        let mut bigger = mixed_params();
        bigger.insert("params.extra", Tensor::f32(&[2], vec![7.0, 7.0]).unwrap());
        let b = Checkpoint::new(0, 2, bigger);
        // Teacher storage materialized from b has a window a lacks: a
        // refresh from a must rebuild, not leave params.extra stale.
        let refreshed = a.refresh_params(b.params()).unwrap();
        assert!(refreshed.get("params.extra").is_err(), "stale window survived");
        assert_eq!(refreshed.len(), a.params().len());
        // Matching planes refresh in place and carry the new values.
        let again = a.refresh_params(refreshed).unwrap();
        assert_eq!(
            again.get("params.w").unwrap().as_f32().unwrap(),
            &[1.0, -2.0, 3.5, 0.0]
        );
        assert_eq!(again.get("params.ids").unwrap().as_i32().unwrap(), &[7, 8, 9]);
    }

    #[test]
    fn scatter_params_into_reuses_storage() {
        let c = Checkpoint::new(0, 1, mixed_params());
        let mut dst = TensorMap::new();
        dst.insert("params.w", Tensor::f32(&[2, 2], vec![0.0; 4]).unwrap());
        c.scatter_params_into(&mut dst).unwrap();
        assert_eq!(
            dst.get("params.w").unwrap().as_f32().unwrap(),
            &[1.0, -2.0, 3.5, 0.0]
        );
        assert_eq!(dst.get("params.ids").unwrap().as_i32().unwrap(), &[7, 8, 9]);
        assert_eq!(c.numel(), 4 + 3);
    }

    #[test]
    fn spool_writes_files() {
        let dir = std::env::temp_dir().join(format!("codistill_spool_{}", std::process::id()));
        let store = CheckpointStore::new(2).with_spool(&dir).unwrap();
        store.publish(ckpt(0, 7, 1.0)).unwrap();
        let path = dir.join("member0_step7.ckpt");
        assert!(path.exists());
        // and they load back through the v2 reader
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(l.flat().view("params.w").unwrap(), &[1.0, 1.0]);
        std::fs::remove_dir_all(&dir).ok();
    }
}

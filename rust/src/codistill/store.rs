//! Checkpoint snapshots and their wire/disk encodings.
//!
//! Stands in for the paper's shared filesystem (§2.1: "workers checkpoint
//! their parameters; other workers load the freshest available checkpoints").
//! Checkpoints are immutable parameter snapshots tagged with the publishing
//! member and step; the exchange keeps a bounded history per member so the
//! orchestrator can both read "freshest available" and deliberately fetch
//! older snapshots (staleness injection for the Fig 4-style ablations).
//!
//! Snapshots live on the flat parameter plane: a [`Checkpoint`] is an
//! `Arc<FlatBuffer>` (all f32 leaves, one contiguous buffer, shared layout)
//! plus a small residual map for non-f32 leaves. Publishing and reading are
//! therefore **zero-copy** — the store and every reader share the same
//! buffer — and teacher reloads scatter the plane into existing tensor
//! storage instead of rebuilding named maps.
//!
//! On disk there are four formats, all understood by [`Checkpoint::load`]:
//!
//! * `CKPT0004` (written by [`Checkpoint::save_v4`]): the compressed
//!   variant — each window-table entry carries `name, shape, digest,
//!   codec u8, encoded length u64`, and the payload is the concatenation
//!   of the per-window **encoded** byte ranges (see
//!   `codistill::transport::codec`; windows the codec cannot shrink are
//!   stored raw, tagged as such). Loading decodes every window and
//!   verifies its digest, so corruption of an encoded payload fails as
//!   loudly as the `CKPT0003` case. Spool publishers opt in via
//!   `SpoolDir::with_codec`; readers `pread` exactly the encoded ranges.
//! * `CKPT0003` (written by [`Checkpoint::save`]): the `CKPT0002` layout
//!   with a per-window [`content_digest`] added to each window-table
//!   entry. The digest table is what makes incremental (delta) exchange
//!   possible: a reader compares it against the digests of its installed
//!   copy and pulls only the windows whose bytes changed. Loading
//!   recomputes and verifies every digest, so a corrupt payload fails
//!   loudly instead of poisoning a delta basis.
//! * `CKPT0002` (written by [`Checkpoint::save_v2`]): a window table
//!   followed by the whole flat plane as one contiguous byte slice — no
//!   per-tensor framing on the payload, no digests.
//! * `CKPT0001` (written by [`Checkpoint::save_v1`]): the original
//!   per-tensor framing, kept for spools produced by older builds.
//!
//! [`content_digest`]: crate::runtime::flat::content_digest
//!
//! The exchange itself — who holds published checkpoints and how readers
//! get them — lives behind `codistill::transport::ExchangeTransport`; this
//! module only defines the snapshot value type and its wire/disk encoding.
//! [`Checkpoint::write_to`] / [`Checkpoint::read_from`] stream the same
//! `CKPT0003` bytes over any `Write`/`Read` (socket frames, spool files),
//! so every transport speaks one format.

use crate::codistill::transport::codec::Codec;
use crate::runtime::flat::{content_digest, FlatBuffer, FlatLayout};
use crate::runtime::{Tensor, TensorMap};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;
use std::sync::{Arc, OnceLock};

pub(crate) const MAGIC_V1: &[u8; 8] = b"CKPT0001";
pub(crate) const MAGIC_V2: &[u8; 8] = b"CKPT0002";
pub(crate) const MAGIC_V3: &[u8; 8] = b"CKPT0003";
pub(crate) const MAGIC_V4: &[u8; 8] = b"CKPT0004";
pub(crate) const MAGIC_V5: &[u8; 8] = b"CKPT0005";

/// Largest single window a checkpoint stream may claim (1 GiB — the
/// socket layer's frame cap; any real plane window here is megabytes).
/// Checkpoint streams are parsed off untrusted bytes, so a lying shape
/// must become an error before it becomes an allocation.
const MAX_WINDOW_BYTES: usize = 1 << 30;

/// Cap on `Vec::with_capacity` *hints* taken from wire-supplied counts:
/// the vectors still grow to any honest size, but a `u64::MAX` count in
/// a corrupt stream cannot reserve memory up front — it just runs out of
/// bytes to parse.
const TABLE_CAPACITY_HINT: usize = 4096;

/// Immutable parameter snapshot on the flat plane.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Publishing member id.
    pub member: usize,
    /// Member-local step at publication.
    pub step: u64,
    /// All f32 `params.*` leaves, fused. Shared zero-copy between the
    /// publisher, the store's history, and every reader.
    flat: Arc<FlatBuffer>,
    /// Non-f32 leaves (embedding id tables etc.) — usually empty.
    residual: TensorMap,
    /// Per-window content digests in plane order, computed once (at the
    /// first publish/save/fetch that needs them, or adopted verified from
    /// a `CKPT0003` load) and shared by every reader of this snapshot.
    digests: OnceLock<Arc<Vec<u64>>>,
}

impl Checkpoint {
    /// Snapshot a named parameter map (layout derived from the map itself).
    pub fn new(member: usize, step: u64, params: TensorMap) -> Self {
        let layout = Arc::new(FlatLayout::from_map(&params, ""));
        Self::gather_from(member, step, layout, &params, "")
            .expect("gathering a layout derived from its own source map")
    }

    /// Snapshot the `prefix` leaves of a live variable map onto a
    /// pre-computed plane — the members' hot path: the layout is computed
    /// once per member and reused by every publication, so a snapshot is
    /// one contiguous gather (plus a clone per rare non-f32 leaf).
    pub fn gather_from(
        member: usize,
        step: u64,
        layout: Arc<FlatLayout>,
        vars: &TensorMap,
        prefix: &str,
    ) -> Result<Self> {
        let flat = FlatBuffer::gather(layout, vars)?;
        let mut residual = TensorMap::new();
        for (k, t) in vars.prefix_iter(prefix) {
            if t.as_f32().is_err() {
                residual.insert(k, t.clone());
            }
        }
        Ok(Checkpoint {
            member,
            step,
            flat: Arc::new(flat),
            residual,
            digests: OnceLock::new(),
        })
    }

    /// Snapshot from a pre-gathered plane (the members' hot path: layout is
    /// computed once per member and reused for every publication).
    pub fn from_flat(
        member: usize,
        step: u64,
        flat: Arc<FlatBuffer>,
        residual: TensorMap,
    ) -> Self {
        Checkpoint {
            member,
            step,
            flat,
            residual,
            digests: OnceLock::new(),
        }
    }

    /// Test-only: adopt `digests` as the remembered window table without
    /// verifying it against the bytes — models a checkpoint whose table
    /// came from a medium that lied. The serving tier's install check
    /// (`serve::SwapHandle::install`) must refuse such a plane.
    #[cfg(test)]
    pub fn from_flat_with_digests(
        member: usize,
        step: u64,
        flat: Arc<FlatBuffer>,
        residual: TensorMap,
        digests: Vec<u64>,
    ) -> Self {
        let ck = Self::from_flat(member, step, flat, residual);
        let _ = ck.digests.set(Arc::new(digests));
        ck
    }

    /// The fused f32 plane (zero-copy view shared with the store).
    pub fn flat(&self) -> &Arc<FlatBuffer> {
        &self.flat
    }

    /// Per-window content digests in plane order. Computed once per
    /// snapshot (a checkpoint is immutable) and cached, so the publish
    /// path, the `CKPT0003` writer, and every delta-serving fetch share
    /// one hashing pass over the plane.
    pub fn window_digests(&self) -> &Arc<Vec<u64>> {
        self.digests
            .get_or_init(|| Arc::new(self.flat.window_digests()))
    }

    /// Non-f32 leaves.
    pub fn residual(&self) -> &TensorMap {
        &self.residual
    }

    /// Materialize the snapshot as a named map (allocates; prefer
    /// [`Checkpoint::scatter_params_into`] on reload paths).
    pub fn params(&self) -> TensorMap {
        let mut m = self
            .flat
            .to_map()
            .expect("materializing a self-consistent flat plane");
        m.merge(self.residual.clone());
        m
    }

    /// Scatter the snapshot into existing storage: same-shape tensors are
    /// overwritten in place (no allocation), anything else is inserted.
    /// Entries of `dst` outside the snapshot are left untouched — callers
    /// refreshing a whole teacher map should use
    /// [`Checkpoint::refresh_params`], which guards against that.
    pub fn scatter_params_into(&self, dst: &mut TensorMap) -> Result<()> {
        self.flat.scatter_into(dst)?;
        for (k, t) in self.residual.prefix_iter("") {
            dst.insert(k, t.clone());
        }
        Ok(())
    }

    /// Whether `m` holds exactly this snapshot's entries (names + shapes),
    /// i.e. an in-place scatter fully overwrites it with nothing stale
    /// left behind.
    fn plane_matches(&self, m: &TensorMap) -> bool {
        m.len() == self.flat.layout().len() + self.residual.len()
            && self.flat.layout().entries().iter().all(|e| {
                m.get(&e.name)
                    .map(|t| t.shape() == e.shape.as_slice() && t.as_f32().is_ok())
                    .unwrap_or(false)
            })
            && self.residual.prefix_iter("").all(|(k, t)| {
                m.get(k).map(|p| p.shape() == t.shape()).unwrap_or(false)
            })
    }

    /// Refresh a teacher map previously materialized from a checkpoint:
    /// in place (no allocation) when the entry sets line up, a full
    /// rebuild when they don't — never a silent mix of old and new
    /// windows.
    pub fn refresh_params(&self, prev: TensorMap) -> Result<TensorMap> {
        if self.plane_matches(&prev) {
            let mut m = prev;
            self.scatter_params_into(&mut m)?;
            Ok(m)
        } else {
            Ok(self.params())
        }
    }

    /// Total parameter elements in the snapshot.
    pub fn numel(&self) -> usize {
        self.flat.layout().total_len() + self.residual.prefix_numel("")
    }

    /// Serialize (format `CKPT0003`): window table with per-window
    /// digests + the flat plane as one contiguous byte slice + residual
    /// entries.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating {}", path.display()))?,
        );
        self.write_to(&mut f)?;
        // Explicit flush: BufWriter's Drop swallows errors, and a spool
        // publish renames this file into place assuming it is complete.
        f.flush().with_context(|| format!("flushing {}", path.display()))
    }

    /// Serialize in the `CKPT0002` format (no digest table) — compat
    /// writer for consumers of older spools, like [`Checkpoint::save_v1`].
    pub fn save_v2(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating {}", path.display()))?,
        );
        self.write_to_v2(&mut f)?;
        f.flush().with_context(|| format!("flushing {}", path.display()))
    }

    /// Stream the `CKPT0003` encoding (the same bytes [`Checkpoint::save`]
    /// puts on disk) into any writer — socket frames, spool temp files.
    pub fn write_to(&self, f: &mut impl Write) -> Result<()> {
        f.write_all(MAGIC_V3)?;
        f.write_all(&(self.member as u64).to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;

        let layout = self.flat.layout();
        let digests = self.window_digests();
        f.write_all(&(layout.len() as u64).to_le_bytes())?;
        for (e, d) in layout.entries().iter().zip(digests.iter()) {
            write_name(&mut f, &e.name)?;
            write_shape(&mut f, &e.shape)?;
            f.write_all(&d.to_le_bytes())?;
        }
        self.write_payload_and_residual(f)
    }

    /// Stream the `CKPT0002` encoding — the digest-free window table.
    pub fn write_to_v2(&self, f: &mut impl Write) -> Result<()> {
        f.write_all(MAGIC_V2)?;
        f.write_all(&(self.member as u64).to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;

        let layout = self.flat.layout();
        f.write_all(&(layout.len() as u64).to_le_bytes())?;
        for e in layout.entries() {
            write_name(&mut f, &e.name)?;
            write_shape(&mut f, &e.shape)?;
        }
        self.write_payload_and_residual(f)
    }

    /// Serialize in the compressed `CKPT0004` format: each window is
    /// encoded under `codec` (with the per-window raw fallback), the
    /// window table records the tag + encoded length actually used, and
    /// the payload is the concatenation of the encoded ranges — so a
    /// spool reader can `pread` exactly one window's encoded bytes.
    pub fn save_v4(&self, path: &Path, codec: Codec) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating {}", path.display()))?,
        );
        self.write_to_v4(&mut f, codec)?;
        f.flush().with_context(|| format!("flushing {}", path.display()))
    }

    /// Stream the `CKPT0004` encoding (see [`Checkpoint::save_v4`]).
    pub fn write_to_v4(&self, f: &mut impl Write, codec: Codec) -> Result<()> {
        f.write_all(MAGIC_V4)?;
        f.write_all(&(self.member as u64).to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;

        let layout = self.flat.layout();
        let digests = self.window_digests().clone();
        // Encode first: the table must record each window's actual tag
        // and encoded length before any payload byte is written.
        let encoded: Vec<(Codec, Vec<u8>)> = layout
            .entries()
            .iter()
            .map(|e| codec.encode(&self.flat.data()[e.range()]))
            .collect();
        f.write_all(&(layout.len() as u64).to_le_bytes())?;
        for ((e, d), (tag, bytes)) in
            layout.entries().iter().zip(digests.iter()).zip(&encoded)
        {
            write_name(&mut f, &e.name)?;
            write_shape(&mut f, &e.shape)?;
            f.write_all(&d.to_le_bytes())?;
            f.write_all(&[tag.id()])?;
            f.write_all(&(bytes.len() as u64).to_le_bytes())?;
        }
        let total: u64 = encoded.iter().map(|(_, b)| b.len() as u64).sum();
        f.write_all(&total.to_le_bytes())?;
        for (_, bytes) in &encoded {
            f.write_all(bytes)?;
        }
        self.write_residual(f)
    }

    /// Serialize in the lossy-aware `CKPT0005` format: `CKPT0004` plus a
    /// per-window quantization-scale f32 column in the table (the int8
    /// scale surfaced as metadata; 0.0 for windows that carry no scale).
    /// Spool publishers route here whenever the publish codec
    /// [`Codec::is_lossy`] — note the *plane being written is already
    /// dequantized* (`transport::feedback::ErrorFeedback::prepare` ran
    /// before publish), so the stored digests verify the decoded payload
    /// exactly as in v4.
    pub fn save_v5(&self, path: &Path, codec: Codec) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating {}", path.display()))?,
        );
        self.write_to_v5(&mut f, codec)?;
        f.flush().with_context(|| format!("flushing {}", path.display()))
    }

    /// Stream the `CKPT0005` encoding (see [`Checkpoint::save_v5`]).
    pub fn write_to_v5(&self, f: &mut impl Write, codec: Codec) -> Result<()> {
        f.write_all(MAGIC_V5)?;
        f.write_all(&(self.member as u64).to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;

        let layout = self.flat.layout();
        let digests = self.window_digests().clone();
        let encoded: Vec<(Codec, Vec<u8>)> = layout
            .entries()
            .iter()
            .map(|e| codec.encode(&self.flat.data()[e.range()]))
            .collect();
        f.write_all(&(layout.len() as u64).to_le_bytes())?;
        for ((e, d), (tag, bytes)) in
            layout.entries().iter().zip(digests.iter()).zip(&encoded)
        {
            write_name(&mut f, &e.name)?;
            write_shape(&mut f, &e.shape)?;
            f.write_all(&d.to_le_bytes())?;
            f.write_all(&[tag.id()])?;
            // scale column: the int8 header scale surfaced into the
            // table (tools can read quantization metadata without
            // touching payload bytes); 0.0 for every other tag
            let scale = match tag {
                Codec::Int8 if bytes.len() >= 4 => {
                    f32::from_le_bytes(bytes[..4].try_into().unwrap())
                }
                _ => 0.0,
            };
            f.write_all(&scale.to_le_bytes())?;
            f.write_all(&(bytes.len() as u64).to_le_bytes())?;
        }
        let total: u64 = encoded.iter().map(|(_, b)| b.len() as u64).sum();
        f.write_all(&total.to_le_bytes())?;
        for (_, bytes) in &encoded {
            f.write_all(bytes)?;
        }
        self.write_residual(f)
    }

    /// The part of the v2/v3 encodings after the window table: the whole
    /// plane as one unframed slice, then the framed residual entries.
    fn write_payload_and_residual(&self, f: &mut impl Write) -> Result<()> {
        f.write_all(&(self.flat.data().len() as u64).to_le_bytes())?;
        write_f32s(&mut f, self.flat.data())?;
        self.write_residual(f)
    }

    /// The framed residual section shared by every contiguous format.
    fn write_residual(&self, f: &mut impl Write) -> Result<()> {
        let residual = self.residual.prefix_entries("");
        f.write_all(&(residual.len() as u64).to_le_bytes())?;
        for (name, t) in residual {
            write_name(&mut f, name)?;
            write_shape(&mut f, t.shape())?;
            match t {
                Tensor::F32 { data, .. } => {
                    f.write_all(&[0u8])?;
                    write_f32s(&mut f, data)?;
                }
                Tensor::I32 { data, .. } => {
                    f.write_all(&[1u8])?;
                    write_i32s(&mut f, data)?;
                }
            }
        }
        Ok(())
    }

    /// Serialize in the original `CKPT0001` per-tensor framing (compat
    /// writer for consumers of older spools).
    pub fn save_v1(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating {}", path.display()))?,
        );
        f.write_all(MAGIC_V1)?;
        f.write_all(&(self.member as u64).to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        let params = self.params();
        let entries = params.prefix_entries("");
        f.write_all(&(entries.len() as u64).to_le_bytes())?;
        for (name, t) in entries {
            write_name(&mut f, name)?;
            write_shape(&mut f, t.shape())?;
            match t {
                Tensor::F32 { data, .. } => {
                    f.write_all(&[0u8])?;
                    write_f32s(&mut f, data)?;
                }
                Tensor::I32 { data, .. } => {
                    f.write_all(&[1u8])?;
                    write_i32s(&mut f, data)?;
                }
            }
        }
        f.flush()
            .with_context(|| format!("flushing {}", path.display()))
    }

    /// Load a checkpoint written by [`Checkpoint::save`] (either format).
    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        Self::read_from(&mut f).with_context(|| format!("reading {}", path.display()))
    }

    /// Read any checkpoint format (magic-dispatched) from any reader —
    /// the inverse of [`Checkpoint::write_to`].
    pub fn read_from(f: &mut impl Read) -> Result<Self> {
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        match &magic {
            m if m == MAGIC_V5 => Self::load_encoded(f, true),
            m if m == MAGIC_V4 => Self::load_encoded(f, false),
            m if m == MAGIC_V3 => Self::load_contiguous(f, true),
            m if m == MAGIC_V2 => Self::load_contiguous(f, false),
            m if m == MAGIC_V1 => Self::load_v1(f),
            _ => bail!("bad checkpoint magic"),
        }
    }

    /// `CKPT0004`/`CKPT0005` reader (`with_scales` = v5's extra
    /// quantization-scale table column): decode every window under its
    /// recorded codec, then verify the decoded bytes against the stored
    /// digest — a corrupt encoded payload (or a lying table) is a load
    /// error here, never a silently-wrong plane. For lossy tags the
    /// stored digests are digests of the dequantized values (the plane
    /// was quantized once, publisher-side), so this check is exactly as
    /// strong as for lossless windows.
    ///
    /// This stream is parsed off untrusted bytes (socket `LATEST`
    /// replies, `PUBLISH` bodies), so wire-supplied sizes never drive an
    /// upfront allocation: counts are capacity *hints* capped at
    /// [`TABLE_CAPACITY_HINT`], per-window sizes are bounded by
    /// [`MAX_WINDOW_BYTES`], and encoded payloads are read through
    /// `take(..)` so a lying length fails at EOF instead of reserving
    /// the claimed size.
    fn load_encoded(f: &mut impl Read, with_scales: bool) -> Result<Self> {
        let member = read_u64(f)? as usize;
        let step = read_u64(f)?;

        let n_windows = read_u64(f)? as usize;
        let mut parts = Vec::with_capacity(n_windows.min(TABLE_CAPACITY_HINT));
        let mut stored_digests = Vec::with_capacity(n_windows.min(TABLE_CAPACITY_HINT));
        let mut encodings = Vec::with_capacity(n_windows.min(TABLE_CAPACITY_HINT));
        for _ in 0..n_windows {
            let name = read_name(f)?;
            let shape = read_shape(f)?;
            let numel: usize = shape.iter().product();
            if numel.saturating_mul(4) > MAX_WINDOW_BYTES {
                bail!(
                    "window {name:?} claims {numel} elems — over the {MAX_WINDOW_BYTES}-byte \
                     window cap, corrupt table"
                );
            }
            parts.push((name, shape));
            stored_digests.push(read_u64(f)?);
            let mut tag = [0u8; 1];
            f.read_exact(&mut tag)?;
            let codec = Codec::from_id(tag[0])?;
            let scale = if with_scales {
                Some(f32::from_bits(read_u32(f)?))
            } else {
                None
            };
            let enc_len = read_u64(f)? as usize;
            // Every codec has a known (or never-larger-bounded) encoded
            // size for this window. Checking up front turns a corrupt
            // table into an error instead of a huge read.
            if !codec.wire_len_ok(enc_len as u64, numel) {
                bail!(
                    "window {:?}: {} encoding of {enc_len} bytes is inconsistent with \
                     {numel} elems",
                    parts.last().unwrap().0,
                    codec.name()
                );
            }
            encodings.push((codec, enc_len, scale));
        }
        let layout = Arc::new(FlatLayout::from_named_shapes(parts));

        let payload_total = read_u64(f)?;
        let expect: u64 = encodings.iter().map(|&(_, n, _)| n as u64).sum();
        if payload_total != expect {
            bail!("encoded payload claims {payload_total} bytes, window table wants {expect}");
        }
        // Read + decode every window BEFORE allocating the plane: memory
        // growth tracks bytes the peer actually delivered, not what the
        // table claims.
        let mut decoded_windows = Vec::with_capacity(encodings.len());
        let mut bytes = Vec::new();
        for (i, (codec, enc_len, scale)) in encodings.iter().enumerate() {
            let e = &layout.entries()[i];
            bytes.clear();
            let took = f.by_ref().take(*enc_len as u64).read_to_end(&mut bytes)?;
            if took != *enc_len {
                bail!(
                    "window {:?}: encoded payload truncated ({took} of {enc_len} bytes)",
                    e.name
                );
            }
            // v5 surfaces the int8 scale as table metadata; it must
            // agree bit-for-bit with the payload's own header or the
            // file is corrupt
            if let (Codec::Int8, Some(s)) = (codec, scale) {
                if bytes.len() >= 4
                    && f32::from_le_bytes(bytes[..4].try_into().unwrap()).to_bits()
                        != s.to_bits()
                {
                    bail!(
                        "window {:?}: table scale {s} disagrees with the int8 payload header",
                        e.name
                    );
                }
            }
            let decoded = codec
                .decode(&bytes, e.len)
                .with_context(|| format!("decoding checkpoint window {:?}", e.name))?;
            let got = content_digest(&decoded);
            if got != stored_digests[i] {
                bail!(
                    "checkpoint window {:?} digest mismatch \
                     (stored {:#018x}, payload decodes to {got:#018x}): \
                     corrupt payload or digest table",
                    e.name,
                    stored_digests[i]
                );
            }
            decoded_windows.push(decoded);
        }
        let mut data = vec![0f32; layout.total_len()];
        for (e, decoded) in layout.entries().iter().zip(&decoded_windows) {
            data[e.range()].copy_from_slice(decoded);
        }
        drop(decoded_windows);
        let flat = FlatBuffer::from_data(layout, data)?;
        let digests = OnceLock::new();
        let _ = digests.set(Arc::new(stored_digests));

        let n_residual = read_u64(f)? as usize;
        let mut residual = TensorMap::new();
        for _ in 0..n_residual {
            let (name, t) = read_framed_tensor(f)?;
            residual.insert(name, t);
        }
        Ok(Checkpoint {
            member,
            step,
            flat: Arc::new(flat),
            residual,
            digests,
        })
    }

    /// Shared v2/v3 reader (`with_digests` selects the v3 window table).
    /// A v3 load recomputes every window digest from the payload and
    /// verifies it against the stored table: a flipped payload byte is a
    /// load error here, not a silently-wrong delta basis later.
    fn load_contiguous(f: &mut impl Read, with_digests: bool) -> Result<Self> {
        let member = read_u64(f)? as usize;
        let step = read_u64(f)?;

        let n_windows = read_u64(f)? as usize;
        let mut parts = Vec::with_capacity(n_windows.min(TABLE_CAPACITY_HINT));
        let mut stored_digests =
            Vec::with_capacity(if with_digests { n_windows.min(TABLE_CAPACITY_HINT) } else { 0 });
        for _ in 0..n_windows {
            let name = read_name(f)?;
            let shape = read_shape(f)?;
            parts.push((name, shape));
            if with_digests {
                stored_digests.push(read_u64(f)?);
            }
        }
        let layout = Arc::new(FlatLayout::from_named_shapes(parts));

        let payload = read_u64(f)? as usize;
        if payload != layout.total_len() {
            bail!(
                "flat payload has {} elems, window table wants {}",
                payload,
                layout.total_len()
            );
        }
        let mut data = vec![0f32; payload];
        read_f32s(f, &mut data)?;
        let flat = FlatBuffer::from_data(layout, data)?;

        let digests = OnceLock::new();
        if with_digests {
            let computed = flat.window_digests();
            for (i, (stored, computed)) in
                stored_digests.iter().zip(&computed).enumerate()
            {
                if stored != computed {
                    bail!(
                        "checkpoint window {:?} digest mismatch \
                         (stored {stored:#018x}, payload hashes to {computed:#018x}): \
                         corrupt payload or digest table",
                        flat.layout().entries()[i].name
                    );
                }
            }
            let _ = digests.set(Arc::new(computed));
        }

        let n_residual = read_u64(f)? as usize;
        let mut residual = TensorMap::new();
        for _ in 0..n_residual {
            let (name, t) = read_framed_tensor(f)?;
            residual.insert(name, t);
        }
        Ok(Checkpoint {
            member,
            step,
            flat: Arc::new(flat),
            residual,
            digests,
        })
    }

    fn load_v1(f: &mut impl Read) -> Result<Self> {
        let member = read_u64(f)? as usize;
        let step = read_u64(f)?;
        let n = read_u64(f)? as usize;
        let mut params = TensorMap::new();
        for _ in 0..n {
            let (name, t) = read_framed_tensor(f)?;
            params.insert(name, t);
        }
        Ok(Checkpoint::new(member, step, params))
    }
}

// ------------------------------------------------------------ binary plumbing

pub(crate) fn write_name(f: &mut impl Write, name: &str) -> Result<()> {
    let nb = name.as_bytes();
    f.write_all(&(nb.len() as u32).to_le_bytes())?;
    f.write_all(nb)?;
    Ok(())
}

pub(crate) fn read_name(f: &mut impl Read) -> Result<String> {
    let len = read_u32(f)? as usize;
    let mut buf = vec![0u8; len];
    f.read_exact(&mut buf)?;
    String::from_utf8(buf).context("checkpoint name not utf8")
}

pub(crate) fn write_shape(f: &mut impl Write, shape: &[usize]) -> Result<()> {
    f.write_all(&(shape.len() as u32).to_le_bytes())?;
    for &d in shape {
        f.write_all(&(d as u64).to_le_bytes())?;
    }
    Ok(())
}

pub(crate) fn read_shape(f: &mut impl Read) -> Result<Vec<usize>> {
    let rank = read_u32(f)? as usize;
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        shape.push(read_u64(f)? as usize);
    }
    Ok(shape)
}

/// One `CKPT0001`-framed tensor: name, shape, dtype tag, payload.
pub(crate) fn read_framed_tensor(f: &mut impl Read) -> Result<(String, Tensor)> {
    let name = read_name(f)?;
    let shape = read_shape(f)?;
    let numel: usize = shape.iter().product();
    let mut tag = [0u8; 1];
    f.read_exact(&mut tag)?;
    let t = match tag[0] {
        0 => {
            let mut data = vec![0f32; numel];
            read_f32s(f, &mut data)?;
            Tensor::f32(&shape, data)?
        }
        1 => {
            let mut data = vec![0i32; numel];
            read_i32s(f, &mut data)?;
            Tensor::i32(&shape, data)?
        }
        other => bail!("bad dtype tag {other}"),
    };
    Ok((name, t))
}

/// Staging buffer: 16 KiB of LE bytes per syscall-sized write/read, instead
/// of the seed's 4-bytes-per-call loop. Both payload types are 4 bytes.
const IO_CHUNK_ELEMS: usize = 4096;

/// Chunked little-endian slice IO over any 4-byte element type.
macro_rules! le_slice_io {
    ($write:ident, $read:ident, $t:ty) => {
        pub(crate) fn $write(f: &mut impl Write, data: &[$t]) -> Result<()> {
            let mut buf = [0u8; IO_CHUNK_ELEMS * 4];
            for chunk in data.chunks(IO_CHUNK_ELEMS) {
                for (i, v) in chunk.iter().enumerate() {
                    buf[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
                }
                f.write_all(&buf[..chunk.len() * 4])?;
            }
            Ok(())
        }

        pub(crate) fn $read(f: &mut impl Read, out: &mut [$t]) -> Result<()> {
            let mut buf = [0u8; IO_CHUNK_ELEMS * 4];
            for chunk in out.chunks_mut(IO_CHUNK_ELEMS) {
                let bytes = &mut buf[..chunk.len() * 4];
                f.read_exact(bytes)?;
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = <$t>::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap());
                }
            }
            Ok(())
        }
    };
}

le_slice_io!(write_f32s, read_f32s, f32);
le_slice_io!(write_i32s, read_i32s, i32);

pub(crate) fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub(crate) fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mixed_params() -> TensorMap {
        let mut params = TensorMap::new();
        params.insert("params.w", Tensor::f32(&[2, 2], vec![1.0, -2.0, 3.5, 0.0]).unwrap());
        params.insert("params.ids", Tensor::i32(&[3], vec![7, 8, 9]).unwrap());
        params
    }

    #[test]
    fn save_load_roundtrip_v3() {
        let dir = std::env::temp_dir().join(format!("codistill_ckpt_v3_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        let c = Checkpoint::new(3, 42, mixed_params());
        c.save(&path).unwrap();
        let raw = std::fs::read(&path).unwrap();
        assert_eq!(&raw[..8], MAGIC_V3);
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(l.member, 3);
        assert_eq!(l.step, 42);
        let p = l.params();
        assert_eq!(
            p.get("params.w").unwrap().as_f32().unwrap(),
            &[1.0, -2.0, 3.5, 0.0]
        );
        assert_eq!(p.get("params.w").unwrap().shape(), &[2, 2]);
        assert_eq!(p.get("params.ids").unwrap().as_i32().unwrap(), &[7, 8, 9]);
        assert!(l.flat().layout().same_plane(c.flat().layout()));
        // the digest table survives the round trip (adopted, not recomputed
        // lazily: load verified it against the payload)
        assert_eq!(l.window_digests(), c.window_digests());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_writer_and_reader_stay_compatible() {
        let dir =
            std::env::temp_dir().join(format!("codistill_ckpt_v2c_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c2.ckpt");
        let c = Checkpoint::new(2, 11, mixed_params());
        c.save_v2(&path).unwrap();
        let raw = std::fs::read(&path).unwrap();
        assert_eq!(&raw[..8], MAGIC_V2);
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!((l.member, l.step), (2, 11));
        assert_eq!(l.flat().data(), c.flat().data());
        // no digest table on disk: digests come from a lazy recompute and
        // still agree with the publisher's
        assert_eq!(l.window_digests(), c.window_digests());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v3_load_rejects_corrupt_payload() {
        let dir =
            std::env::temp_dir().join(format!("codistill_ckpt_v3corrupt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c3.ckpt");
        let c = Checkpoint::new(0, 1, mixed_params());
        c.save(&path).unwrap();
        // flip one byte of the last payload f32 of params.w: the window
        // table (incl. digests) stays valid, only the content lies
        let mut raw = std::fs::read(&path).unwrap();
        let payload_end_of_w = raw.len()
            - (8 /* n_residual */ + {
                // params.ids residual frame: name + shape + tag + 3 i32s
                4 + "params.ids".len() + 4 + 8 + 1 + 3 * 4
            });
        raw[payload_end_of_w - 1] ^= 0x40;
        std::fs::write(&path, &raw).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("digest mismatch"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v4_roundtrip_compresses_and_verifies() {
        let dir = std::env::temp_dir().join(format!("codistill_ckpt_v4_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c4.ckpt");
        // a constant window (compresses) next to the mixed fixture
        let mut params = mixed_params();
        params.insert("params.big", Tensor::f32(&[512], vec![0.5; 512]).unwrap());
        let c = Checkpoint::new(4, 77, params);
        c.save_v4(&path, Codec::Shuffle).unwrap();
        let raw = std::fs::read(&path).unwrap();
        assert_eq!(&raw[..8], MAGIC_V4);
        // the constant 512-element window alone is 2 KiB raw; the v4 file
        // must come in well under the v3 file
        let v3_path = dir.join("c4_ref.ckpt");
        c.save(&v3_path).unwrap();
        let v3_len = std::fs::metadata(&v3_path).unwrap().len();
        assert!(
            (raw.len() as u64) < v3_len,
            "v4 {} bytes !< v3 {v3_len} bytes",
            raw.len()
        );

        let l = Checkpoint::load(&path).unwrap();
        assert_eq!((l.member, l.step), (4, 77));
        assert_eq!(l.flat().data(), c.flat().data());
        assert!(l.flat().layout().same_plane(c.flat().layout()));
        assert_eq!(l.window_digests(), c.window_digests());
        assert_eq!(
            l.params().get("params.ids").unwrap().as_i32().unwrap(),
            &[7, 8, 9]
        );
        // a Raw-codec v4 file round-trips too (every window tagged raw)
        c.save_v4(&path, Codec::Raw).unwrap();
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(l.flat().data(), c.flat().data());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v4_load_rejects_corrupt_encoded_payload() {
        let dir =
            std::env::temp_dir().join(format!("codistill_ckpt_v4c_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c4bad.ckpt");
        let mut params = TensorMap::new();
        params.insert("params.w", Tensor::f32(&[256], vec![1.25; 256]).unwrap());
        let c = Checkpoint::new(0, 1, params);
        c.save_v4(&path, Codec::Shuffle).unwrap();
        // flip a byte inside the encoded payload (right before the
        // trailing 8-byte residual count): the table stays valid, the
        // decoded window no longer hashes to its digest (or fails to
        // decode) — either way the load errs
        let mut raw = std::fs::read(&path).unwrap();
        let n = raw.len();
        raw[n - 8 - 1] ^= 0x40;
        std::fs::write(&path, &raw).unwrap();
        assert!(Checkpoint::load(&path).is_err(), "corrupt v4 loaded");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v5_roundtrip_stores_lossy_windows_with_scales() {
        let dir = std::env::temp_dir().join(format!("codistill_ckpt_v5_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c5.ckpt");
        // values exactly on the int8 power-of-two grid: a prepared
        // (already-dequantized) plane, as ErrorFeedback::prepare would
        // hand to publish — the exact-or-raw rule keeps the int8 tag
        let mut params = mixed_params();
        params.insert("params.big", Tensor::f32(&[512], vec![0.5; 512]).unwrap());
        let c = Checkpoint::new(5, 99, params);
        c.save_v5(&path, Codec::Int8).unwrap();
        let raw = std::fs::read(&path).unwrap();
        assert_eq!(&raw[..8], MAGIC_V5);
        // int8 moves ~1 byte/elem: the 512-elem window alone saves ~1.5 KiB
        let v3_path = dir.join("c5_ref.ckpt");
        c.save(&v3_path).unwrap();
        let v3_len = std::fs::metadata(&v3_path).unwrap().len() as usize;
        assert!(raw.len() + 1024 < v3_len, "v5 {} !<< v3 {v3_len}", raw.len());

        let l = Checkpoint::load(&path).unwrap();
        assert_eq!((l.member, l.step), (5, 99));
        assert_eq!(l.flat().data(), c.flat().data(), "on-grid plane loads bit-identical");
        assert_eq!(l.window_digests(), c.window_digests());
        assert_eq!(
            l.params().get("params.ids").unwrap().as_i32().unwrap(),
            &[7, 8, 9]
        );
        // lossless tags write v5 fine too (scale column all zeros)
        c.save_v5(&path, Codec::Shuffle).unwrap();
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(l.flat().data(), c.flat().data());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v5_load_rejects_corrupt_payload_and_lying_scale() {
        let dir = std::env::temp_dir().join(format!("codistill_ckpt_v5c_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c5bad.ckpt");
        let mut params = TensorMap::new();
        params.insert("params.w", Tensor::f32(&[256], vec![0.5; 256]).unwrap());
        let c = Checkpoint::new(0, 1, params);
        c.save_v5(&path, Codec::Int8).unwrap();
        let good = std::fs::read(&path).unwrap();

        // flip an i8 code inside the encoded payload: decode succeeds
        // but the digest over the dequantized values no longer matches
        let mut raw = good.clone();
        let n = raw.len();
        raw[n - 8 - 1] ^= 0x40;
        std::fs::write(&path, &raw).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("digest mismatch"), "{err:#}");

        // corrupt the table's scale column so it disagrees with the
        // payload header. The preamble is magic(8) member(8) step(8)
        // count(8) = 32 bytes; the single row is then name(4+8)
        // shape(4+8) digest(8) tag(1) scale(4) len(8).
        let mut raw = good.clone();
        let scale_off = 32 + (4 + "params.w".len()) + (4 + 8) + 8 + 1;
        raw[scale_off] ^= 0x01;
        std::fs::write(&path, &raw).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("disagrees"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v1_writer_and_reader_stay_compatible() {
        let dir = std::env::temp_dir().join(format!("codistill_ckpt_v1_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c1.ckpt");
        let c = Checkpoint::new(1, 7, mixed_params());
        c.save_v1(&path).unwrap();
        // sanity: it really is the old format on disk
        let raw = std::fs::read(&path).unwrap();
        assert_eq!(&raw[..8], MAGIC_V1);
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(l.member, 1);
        assert_eq!(l.step, 7);
        assert_eq!(
            l.params().get("params.w").unwrap().as_f32().unwrap(),
            c.params().get("params.w").unwrap().as_f32().unwrap()
        );
        assert_eq!(
            l.params().get("params.ids").unwrap().as_i32().unwrap(),
            &[7, 8, 9]
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stream_roundtrip_matches_disk_format() {
        // write_to/read_from (the socket wire path) must produce exactly
        // the bytes save() puts on disk.
        let c = Checkpoint::new(5, 99, mixed_params());
        let mut wire: Vec<u8> = Vec::new();
        c.write_to(&mut wire).unwrap();
        assert_eq!(&wire[..8], MAGIC_V3);

        let dir = std::env::temp_dir().join(format!("codistill_ckpt_wire_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.ckpt");
        c.save(&path).unwrap();
        let disk = std::fs::read(&path).unwrap();
        assert_eq!(wire, disk, "stream and disk encodings diverged");

        let l = Checkpoint::read_from(&mut wire.as_slice()).unwrap();
        assert_eq!(l.member, 5);
        assert_eq!(l.step, 99);
        assert_eq!(l.flat().data(), c.flat().data());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn refresh_params_rebuilds_on_plane_mismatch() {
        let a = Checkpoint::new(0, 1, mixed_params());
        let mut bigger = mixed_params();
        bigger.insert("params.extra", Tensor::f32(&[2], vec![7.0, 7.0]).unwrap());
        let b = Checkpoint::new(0, 2, bigger);
        // Teacher storage materialized from b has a window a lacks: a
        // refresh from a must rebuild, not leave params.extra stale.
        let refreshed = a.refresh_params(b.params()).unwrap();
        assert!(refreshed.get("params.extra").is_err(), "stale window survived");
        assert_eq!(refreshed.len(), a.params().len());
        // Matching planes refresh in place and carry the new values.
        let again = a.refresh_params(refreshed).unwrap();
        assert_eq!(
            again.get("params.w").unwrap().as_f32().unwrap(),
            &[1.0, -2.0, 3.5, 0.0]
        );
        assert_eq!(again.get("params.ids").unwrap().as_i32().unwrap(), &[7, 8, 9]);
    }

    #[test]
    fn scatter_params_into_reuses_storage() {
        let c = Checkpoint::new(0, 1, mixed_params());
        let mut dst = TensorMap::new();
        dst.insert("params.w", Tensor::f32(&[2, 2], vec![0.0; 4]).unwrap());
        c.scatter_params_into(&mut dst).unwrap();
        assert_eq!(
            dst.get("params.w").unwrap().as_f32().unwrap(),
            &[1.0, -2.0, 3.5, 0.0]
        );
        assert_eq!(dst.get("params.ids").unwrap().as_i32().unwrap(), &[7, 8, 9]);
        assert_eq!(c.numel(), 4 + 3);
    }

}

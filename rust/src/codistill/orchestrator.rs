//! The codistillation orchestrator: drives N members, the checkpoint
//! exchange, the burn-in/ramp schedule, validation, and the simulated wall
//! clock. This is Algorithm 1 at system scale — each "member" is a whole
//! synchronous-SGD worker group in the scalability experiments.
//!
//! The exchange itself is a pluggable [`ExchangeTransport`]: members
//! publish `Arc<FlatBuffer>`-backed checkpoints (one contiguous gather
//! per publication) and teachers are installed exclusively from transport
//! reads, so the same orchestrated run rides the in-process zero-copy
//! store, a spool directory shared between processes, or a socket server
//! — see `codistill::transport` and `runtime::flat`. The orchestrator
//! never names a concrete backend.

use crate::codistill::obs::{render, Event, Recorder};
use crate::codistill::schedule::{DistillSchedule, LrSchedule};
use crate::codistill::topology::Topology;
use crate::codistill::transport::{
    Codec, DeltaCache, DeltaStats, ErrorFeedback, ExchangeTransport, FeedbackStats, InProcess,
    RetryStats,
};
use crate::codistill::{Checkpoint, EvalStats, Member};
use crate::netsim::ClusterModel;
use crate::prng::Pcg64;
use anyhow::{Context, Result};
use std::sync::Arc;

/// Orchestration parameters.
#[derive(Debug, Clone)]
pub struct OrchestratorConfig {
    pub total_steps: u64,
    /// Checkpoint publish + reload interval in steps (paper Fig 4: 50 is
    /// safe; larger degrades mildly).
    pub reload_interval: u64,
    /// Extra staleness injected on reads, in steps (0 = freshest
    /// available). Models slow checkpoint propagation.
    pub extra_staleness: u64,
    pub eval_every: u64,
    pub distill: DistillSchedule,
    pub lr: LrSchedule,
    pub topology: Topology,
    /// Wall-clock model for the cluster hosting ONE member (each member is
    /// a worker group; groups run concurrently, so the run's wall time is
    /// the max over members — here: identical models, so one clock).
    pub cluster: Option<ClusterModel>,
    /// Seed for the straggler-sampling stream.
    pub seed: u64,
    /// Incremental (delta) teacher reloads: keep a per-teacher installed
    /// plane and fetch only the windows whose content changed since it
    /// (`transport::DeltaCache`). Installed teachers are byte-identical
    /// to full fetches; only the exchange traffic shrinks.
    pub delta: bool,
    /// Codec the published planes are *prepared* under. Lossless codecs
    /// pass through untouched (the transport encodes on the wire as
    /// usual); a lossy codec ([`Codec::is_lossy`]) quantizes every
    /// window once, publisher-side, so the published plane already holds
    /// the dequantized values and every digest is a round-trip digest —
    /// see [`ErrorFeedback`].
    pub publish_codec: Codec,
    /// Carry each window's quantization residual into the next publish
    /// (only meaningful with a lossy `publish_codec`): the bias
    /// telescopes instead of accumulating across publishes.
    pub error_feedback: bool,
    /// Print progress lines.
    pub verbose: bool,
}

impl Default for OrchestratorConfig {
    fn default() -> Self {
        OrchestratorConfig {
            total_steps: 400,
            reload_interval: 50,
            extra_staleness: 0,
            eval_every: 25,
            distill: DistillSchedule::new(100, 50, 1.0),
            lr: LrSchedule::Constant(0.1),
            topology: Topology::Pair,
            cluster: None,
            seed: 0,
            delta: false,
            publish_codec: Codec::Raw,
            error_feedback: false,
            verbose: false,
        }
    }
}

/// One point on a member's validation curve.
#[derive(Debug, Clone, Copy)]
pub struct EvalPoint {
    pub step: u64,
    pub wall_s: f64,
    pub loss: f64,
    pub accuracy: Option<f64>,
}

/// Full record of an orchestrated run.
#[derive(Debug, Clone, Default)]
pub struct RunLog {
    /// Per-member validation curves.
    pub eval: Vec<Vec<EvalPoint>>,
    /// (step, member, train loss, distill loss).
    pub train: Vec<(u64, usize, f32, f32)>,
    /// Total simulated wall seconds (0 when no cluster model).
    pub wall_s: f64,
    /// Observed teacher staleness at *usage* time: one sample per member
    /// per step while teachers are installed (step, member, staleness).
    pub staleness: Vec<(u64, usize, u64)>,
    /// Delta-exchange traffic accounting (`Some` only for delta runs).
    pub delta: Option<DeltaStats>,
    /// Retry accounting (`Some` only when a
    /// [`Retry`](crate::codistill::transport::Retry) decorator is in the
    /// transport stack).
    pub retry: Option<RetryStats>,
    /// Publisher-side quantization accounting, summed over members
    /// (`Some` only when `publish_codec` is lossy).
    pub feedback: Option<FeedbackStats>,
}

impl RunLog {
    /// First step at which a member's validation loss reaches `target`.
    pub fn steps_to_target(&self, member: usize, target: f64) -> Option<u64> {
        self.eval
            .get(member)?
            .iter()
            .find(|p| p.loss <= target)
            .map(|p| p.step)
    }

    /// Best (minimum) validation loss for a member.
    pub fn best_loss(&self, member: usize) -> Option<f64> {
        self.eval
            .get(member)?
            .iter()
            .map(|p| p.loss)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Mean final validation loss over members.
    pub fn final_mean_loss(&self) -> Option<f64> {
        let finals: Vec<f64> = self
            .eval
            .iter()
            .filter_map(|curve| curve.last().map(|p| p.loss))
            .collect();
        if finals.is_empty() {
            None
        } else {
            Some(finals.iter().sum::<f64>() / finals.len() as f64)
        }
    }

    /// Staleness samples rendered one per line (`step member staleness`)
    /// through the shared `codistill::obs` renderer — byte-identical to
    /// [`CoordinatorLog::staleness_log_text`]
    /// (crate::codistill::CoordinatorLog::staleness_log_text) and to the
    /// journal's replay of the same events.
    pub fn staleness_log_text(&self) -> String {
        let mut out = String::new();
        for &(step, member, staleness) in &self.staleness {
            out.push_str(&render::staleness_line(step, member, staleness));
        }
        out
    }
}

/// Drives members in lockstep. Members run their steps sequentially in
/// process but model *concurrent* groups: the wall clock advances by the
/// max step time over members, not the sum.
pub struct Orchestrator {
    cfg: OrchestratorConfig,
    transport: Arc<dyn ExchangeTransport>,
    recorder: Option<Recorder>,
}

impl Orchestrator {
    /// Default exchange: the in-process zero-copy store with an 8-deep
    /// history.
    pub fn new(cfg: OrchestratorConfig) -> Self {
        Self::with_transport(cfg, Arc::new(InProcess::new(8)))
    }

    /// Run over any checkpoint-exchange medium.
    pub fn with_transport(cfg: OrchestratorConfig, transport: Arc<dyn ExchangeTransport>) -> Self {
        Orchestrator {
            cfg,
            transport,
            recorder: None,
        }
    }

    /// Record the run into a `codistill::obs` journal: publishes,
    /// teacher fetches/installs (via each reader's [`DeltaCache`]),
    /// publisher-side quantization, and per-step staleness samples all
    /// become typed events. Pass the same recorder to the decorators in
    /// the transport stack to interleave their events in one trace.
    pub fn with_recorder(mut self, recorder: Recorder) -> Self {
        self.recorder = Some(recorder);
        self
    }

    pub fn transport(&self) -> &Arc<dyn ExchangeTransport> {
        &self.transport
    }

    /// Publish with journal accounting when a recorder is attached: the
    /// event carries the plane size and the publish wall duration.
    fn publish_recorded(&self, ck: Checkpoint) -> Result<()> {
        match &self.recorder {
            Some(rec) => {
                let (member, step) = (ck.member, ck.step);
                let bytes = ck.flat().layout().total_bytes() as u64;
                let t0 = rec.now_us();
                self.transport.publish(ck)?;
                let t1 = rec.now_us();
                rec.record_at(
                    t0,
                    Event::Publish {
                        member,
                        step,
                        bytes,
                        dur_us: t1.saturating_sub(t0),
                    },
                );
                Ok(())
            }
            None => self.transport.publish(ck),
        }
    }

    /// Run the full schedule over the given members.
    pub fn run(&self, members: &mut [Box<dyn Member>]) -> Result<RunLog> {
        let n = members.len();
        let cfg = &self.cfg;
        let mut log = RunLog {
            eval: vec![Vec::new(); n],
            ..Default::default()
        };
        let mut rng = Pcg64::new(cfg.seed ^ 0xc0d15711);
        let mut wall = 0.0f64;
        // freshest installed teacher checkpoint step, per member
        let mut installed: Vec<Option<u64>> = vec![None; n];
        // one installed-plane cache per reader when delta exchange is on
        let mut delta_caches: Vec<DeltaCache> = if cfg.delta {
            (0..n)
                .map(|_| {
                    let mut c = DeltaCache::new();
                    if let Some(rec) = &self.recorder {
                        c = c.with_recorder(rec.clone());
                    }
                    c
                })
                .collect()
        } else {
            Vec::new()
        };

        // One quantizing accumulator per member (no-op for lossless
        // codecs): loss is applied HERE, once, so whatever the transport
        // ships decodes back to exactly the plane being published.
        let mut feedback: Vec<ErrorFeedback> = (0..n)
            .map(|_| {
                let mut f = ErrorFeedback::new(cfg.publish_codec, cfg.error_feedback);
                if let Some(rec) = &self.recorder {
                    f = f.with_recorder(rec.clone());
                }
                f
            })
            .collect();

        // Initial publication so teachers exist from the first reload.
        for (i, m) in members.iter().enumerate() {
            let mut ck = m.snapshot()?;
            ck.member = i;
            let ck = feedback[i].prepare(ck)?;
            self.publish_recorded(ck)?;
        }

        for step in 0..cfg.total_steps {
            let distill_w = cfg.distill.weight_at(step);
            let lr = cfg.lr.at(step);

            // Reload teachers on the exchange cadence, right before the ψ
            // term first becomes active and every interval thereafter.
            if step % cfg.reload_interval == 0 && n > 1 {
                for i in 0..n {
                    let teacher_ids = cfg.topology.teachers_of(i, n);
                    let mut peers = Vec::with_capacity(teacher_ids.len());
                    for j in teacher_ids {
                        // One bounded read, delta-aware when enabled.
                        let mut read = |max_step: u64| {
                            if cfg.delta {
                                delta_caches[i].latest_at_most(
                                    self.transport.as_ref(),
                                    j,
                                    max_step,
                                )
                            } else {
                                self.transport.latest_at_most(j, max_step)
                            }
                        };
                        let ck = if cfg.extra_staleness > 0 {
                            let bound = step.saturating_sub(cfg.extra_staleness);
                            match read(bound)? {
                                some @ Some(_) => some,
                                // No checkpoint old enough (history pruned
                                // past the bound): fall back to the paper's
                                // freshest-available read.
                                None => read(crate::codistill::transport::ANY_STEP)?,
                            }
                        } else {
                            read(crate::codistill::transport::ANY_STEP)?
                        };
                        let ck = ck.with_context(|| format!("no checkpoint for member {j}"))?;
                        peers.push(ck);
                    }
                    installed[i] = peers.iter().map(|c| c.step).max();
                    members[i].set_teachers(peers)?;
                }
            }

            // One step per member (modelled as concurrent groups).
            let mut max_step_time = 0.0f64;
            for (i, m) in members.iter_mut().enumerate() {
                if let Some(tstep) = installed[i] {
                    let staleness = step.saturating_sub(tstep);
                    log.staleness.push((step, i, staleness));
                    if let Some(rec) = &self.recorder {
                        rec.record(Event::Staleness {
                            step,
                            member: i,
                            staleness,
                        });
                    }
                }
                let stats = m.train_step(distill_w, lr)?;
                log.train.push((step, i, stats.loss, stats.distill_loss));
                if let Some(cluster) = &cfg.cluster {
                    max_step_time = max_step_time.max(cluster.step_time(&mut rng));
                }
            }
            wall += max_step_time;

            // Publish on the same cadence (offset so a publish at step k is
            // visible to reloads at step k+interval, i.e. one-interval
            // staleness floor, like the paper's asynchronous exchange).
            if (step + 1) % cfg.reload_interval == 0 {
                for (i, m) in members.iter().enumerate() {
                    let mut ck = m.snapshot()?;
                    ck.member = i;
                    ck.step = step + 1;
                    let ck = feedback[i].prepare(ck)?;
                    self.publish_recorded(ck)?;
                }
                // Enforce the history bound on durable backend state
                // (spool files, server history) on the publish cadence.
                self.transport.gc()?;
                if let Some(cluster) = &cfg.cluster {
                    // Checkpoint write+read amortized over the interval.
                    wall += cluster.checkpoint_exchange_time();
                }
            }

            if (step + 1) % cfg.eval_every == 0 || step + 1 == cfg.total_steps {
                for (i, m) in members.iter_mut().enumerate() {
                    let EvalStats { loss, accuracy } = m.evaluate()?;
                    log.eval[i].push(EvalPoint {
                        step: step + 1,
                        wall_s: wall,
                        loss,
                        accuracy,
                    });
                    if cfg.verbose {
                        let acc = accuracy
                            .map(|a| format!(" acc={a:.4}"))
                            .unwrap_or_default();
                        eprintln!(
                            "[orch] step {:>6} member {} val_loss={loss:.4}{acc} w={distill_w:.2}",
                            step + 1,
                            i
                        );
                    }
                }
            }
        }
        log.wall_s = wall;
        if cfg.delta {
            // Aggregate every reader's exchange accounting.
            let mut total = DeltaStats::default();
            for c in &delta_caches {
                total.merge(c.stats());
            }
            log.delta = Some(total);
        }
        if cfg.publish_codec.is_lossy() {
            let mut total = FeedbackStats::default();
            for f in &feedback {
                total.merge(&f.stats());
            }
            log.feedback = Some(total);
        }
        // Drain anything a decorator held back, then pick up its retry
        // accounting (both no-ops on plain backends).
        self.transport.flush()?;
        log.retry = self.transport.retry_stats();
        Ok(log)
    }
}

//! Experiment harness: one module per paper table/figure (DESIGN.md §6).
//!
//! Each module exposes a `run(&Settings) -> Result<Summary>` that trains
//! the relevant configurations, writes `results/<id>.csv` with the same
//! series the paper plots, and prints a human-readable table. The cargo
//! bench targets under `rust/benches/` are thin wrappers over these.

pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod relay;
pub mod serve;
pub mod table1;
pub mod two_phase;

//! Fig 2a/2b: two-way codistillation vs baselines on the LM.
//!
//! Arms (paper Fig 2a, all at the best single-group configuration):
//!   * `baseline`   — one sync-SGD group, plain loss;
//!   * `uniform`    — ψ against the uniform distribution (label smoothing);
//!   * `unigram`    — ψ against the corpus unigram distribution;
//!   * `codistill`  — two groups, disjoint shards, stale-teacher ψ;
//!   * `ensemble`   — two independent baselines scored as an averaged-
//!     probability ensemble (the "would be better but unservable" arm).
//!
//! Fig 2b control: `codistill_same` forces both groups onto identical
//! data; the paper shows it barely beats the baseline while disjoint
//! codistillation is much better — the gains are information about unseen
//! data flowing through teacher predictions.
//!
//! Emits `results/fig2a.csv` and `results/fig2b.csv` (arm, step, loss).

use crate::codistill::{DistillSchedule, EvalStats, Member, Orchestrator};
use crate::config::Settings;
use crate::data::corpus::Batcher;
use crate::data::shard::{ShardMode, ShardPlan};
use crate::experiments::common::{
    corpus_for, lm_defaults, lm_member, open_bundle, orch_config, results_dir, LmExpDefaults,
};
use crate::metrics::{lm_ensemble_eval, CsvWriter};
use crate::models::lm::{LmMember, SmoothingMode};
use crate::runtime::Tensor;
use anyhow::Result;
use std::collections::BTreeMap;

/// Validation curve: (step, loss).
pub type Curve = Vec<(u64, f64)>;

pub struct Fig2Summary {
    pub curves: BTreeMap<String, Curve>,
    /// steps to reach the baseline's best loss, per arm.
    pub steps_to_baseline_best: BTreeMap<String, Option<u64>>,
}

fn orch_curve(
    s: &Settings,
    d: &LmExpDefaults,
    arms: Vec<(String, SmoothingMode)>,
    n_members_per_arm: usize,
    mode: ShardMode,
    distill: DistillSchedule,
) -> Result<BTreeMap<String, Curve>> {
    let bundle = open_bundle(s, s.str_or("bundle", "lm_b64"))?;
    let mut out = BTreeMap::new();
    for (arm, smoothing) in arms {
        let plan = ShardPlan::new(n_members_per_arm, bundle.meta_usize("batch")?, mode);
        let mut members: Vec<Box<dyn Member>> = Vec::new();
        for g in 0..n_members_per_arm {
            members.push(Box::new(lm_member(
                &bundle,
                &plan,
                g,
                d.seed,
                (g + 1) as i32,
                smoothing.clone(),
                d.val_batches,
            )?));
        }
        let cfg = orch_config(d, distill, None);
        let orch = Orchestrator::new(cfg);
        let log = orch.run(&mut members)?;
        // Report member 0's curve (members are symmetric).
        let curve: Curve = log.eval[0].iter().map(|p| (p.step, p.loss)).collect();
        println!(
            "[fig2] arm {arm}: final {:.4}",
            curve.last().map(|c| c.1).unwrap_or(f64::NAN)
        );
        out.insert(arm, curve);
    }
    Ok(out)
}

/// Train two independent baselines, tracking individual and ensemble loss.
fn ensemble_curve(s: &Settings, d: &LmExpDefaults) -> Result<Curve> {
    let bundle = open_bundle(s, s.str_or("bundle", "lm_b64"))?;
    let corpus = corpus_for(&bundle)?;
    let batch = bundle.meta_usize("batch")?;
    let unroll = bundle.meta_usize("unroll")?;
    let plan = ShardPlan::new(2, batch, ShardMode::Disjoint);
    let mut a = lm_member(&bundle, &plan, 0, d.seed, 1, SmoothingMode::None, d.val_batches)?;
    let mut b = lm_member(&bundle, &plan, 1, d.seed, 2, SmoothingMode::None, d.val_batches)?;
    // Fixed validation token batches for the ensemble scoring.
    let val_streams = plan.validation_streams(batch);
    let mut vb = Batcher::new(&corpus, d.seed ^ 0xe5e, &val_streams, unroll);
    let val_tokens: Vec<Tensor> = (0..d.val_batches)
        .map(|_| vb.next_batch())
        .collect::<Result<_>>()?;

    let mut curve = Curve::new();
    for step in 0..d.steps {
        a.train_step(0.0, d.lr)?;
        b.train_step(0.0, d.lr)?;
        if (step + 1) % d.eval_every == 0 || step + 1 == d.steps {
            let mut total = 0.0;
            for t in &val_tokens {
                let pa = a.predict_probs(t)?;
                let pb = b.predict_probs(t)?;
                total += lm_ensemble_eval(&[pa, pb], t)?;
            }
            curve.push((step + 1, total / val_tokens.len() as f64));
        }
    }
    println!(
        "[fig2] arm ensemble: final {:.4}",
        curve.last().map(|c| c.1).unwrap_or(f64::NAN)
    );
    let _ = <LmMember as Member>::evaluate(&mut a)?; // keep the member-eval
    let _: EvalStats = <LmMember as Member>::evaluate(&mut b)?; // path exercised
    Ok(curve)
}

pub fn run(s: &Settings) -> Result<Fig2Summary> {
    let mut d = lm_defaults(s)?;
    d.steps = s.u64_or("steps", 240)?;
    d.eval_every = s.u64_or("eval_every", 20)?;
    d.burn_in = s.u64_or("burn_in", 60)?;
    d.ramp = s.u64_or("ramp", 30)?;
    let results = results_dir(s);
    let bundle = open_bundle(s, s.str_or("bundle", "lm_b64"))?;
    let unigram = corpus_for(&bundle)?.unigram();

    let mut curves = BTreeMap::new();
    // Baseline + label-smoothing arms (single member each).
    let smooth_w = s.f32_or("smooth_weight", 0.3)?;
    curves.extend(orch_curve(
        s,
        &d,
        vec![("baseline".into(), SmoothingMode::None)],
        1,
        ShardMode::Disjoint,
        DistillSchedule::off(),
    )?);
    let smooth_sched = DistillSchedule::new(d.burn_in, d.ramp, smooth_w);
    curves.extend(orch_curve(
        s,
        &d,
        vec![
            ("uniform_smooth".into(), SmoothingMode::Uniform),
            ("unigram_smooth".into(), SmoothingMode::Unigram(unigram)),
        ],
        1,
        ShardMode::Disjoint,
        smooth_sched,
    )?);
    // Codistillation arms.
    let codist_sched = DistillSchedule::new(d.burn_in, d.ramp, d.weight);
    let disjoint = orch_curve(
        s,
        &d,
        vec![("codistill".into(), SmoothingMode::None)],
        2,
        ShardMode::Disjoint,
        codist_sched,
    )?;
    curves.extend(disjoint);
    let same = orch_curve(
        s,
        &d,
        vec![("codistill_same_data".into(), SmoothingMode::None)],
        2,
        ShardMode::SameData,
        codist_sched,
    )?;
    curves.extend(same);
    // Ensemble arm.
    curves.insert("ensemble".into(), ensemble_curve(s, &d)?);

    // CSVs: fig2a = baseline/smoothing/codistill/ensemble; fig2b =
    // baseline/codistill/codistill_same_data.
    let mut csv_a = CsvWriter::create(&results.join("fig2a.csv"), &["arm", "step", "val_loss"])?;
    let mut csv_b = CsvWriter::create(&results.join("fig2b.csv"), &["arm", "step", "val_loss"])?;
    for (arm, curve) in &curves {
        for (step, loss) in curve {
            let row = [arm.clone(), step.to_string(), format!("{loss:.5}")];
            if arm != "codistill_same_data" {
                csv_a.row(&row)?;
            }
            if matches!(arm.as_str(), "baseline" | "codistill" | "codistill_same_data") {
                csv_b.row(&row)?;
            }
        }
    }
    csv_a.finish()?;
    csv_b.finish()?;

    // The paper's headline: codistillation reaches the baseline's best
    // validation error in ~2× fewer steps.
    let baseline_best = curves["baseline"]
        .iter()
        .map(|&(_, l)| l)
        .fold(f64::INFINITY, f64::min);
    let mut steps_to = BTreeMap::new();
    for (arm, curve) in &curves {
        let hit = curve.iter().find(|&&(_, l)| l <= baseline_best).map(|&(s, _)| s);
        steps_to.insert(arm.clone(), hit);
        println!(
            "[fig2] steps to baseline-best ({baseline_best:.4}): {arm} -> {:?}",
            hit
        );
    }
    Ok(Fig2Summary {
        curves,
        steps_to_baseline_best: steps_to,
    })
}

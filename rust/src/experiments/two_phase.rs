//! §3.4.1: codistillation vs multi-phase (offline) distillation.
//!
//! Paper: a two-model ensemble trained for 18K steps, then a student
//! distilled from it for 9K steps, reaches CE 4.0 after 27K total steps;
//! two-way codistillation reaches the same error after only 10K steps.
//!
//! Phases here (step counts scaled, ratios preserved):
//!   1. Train two independent baselines for `phase1_steps` (the teachers).
//!   2. Train a fresh student with ψ against the *frozen* two-model
//!      ensemble (teacher predictions averaged) for up to `phase2_steps`,
//!      recording when it reaches the target loss.
//!   3. Train a two-way codistilling pair from scratch, recording when it
//!      reaches the same target.
//!
//! Emits `results/sec341.csv` (arm, step, total_step_cost, val_loss) where
//! total_step_cost for the offline student includes the teacher phase
//! (the paper's 18K + 9K accounting).

use crate::codistill::{DistillSchedule, Member, Orchestrator};
use crate::config::Settings;
use crate::data::shard::{ShardMode, ShardPlan};
use crate::experiments::common::{lm_defaults, lm_member, open_bundle, orch_config, results_dir};
use crate::metrics::CsvWriter;
use crate::models::lm::SmoothingMode;
use anyhow::Result;
use std::sync::Arc;

pub struct TwoPhaseSummary {
    /// total step cost (incl. teacher training) for offline distillation
    /// to reach the target, if reached.
    pub offline_total_cost: Option<u64>,
    /// steps for codistillation to reach the target, if reached.
    pub codistill_cost: Option<u64>,
    pub target: f64,
}

pub fn run(s: &Settings) -> Result<TwoPhaseSummary> {
    let mut d = lm_defaults(s)?;
    let phase1 = s.u64_or("phase1_steps", 240)?; // paper: 18K
    let phase2 = s.u64_or("phase2_steps", 120)?; // paper: 9K
    let codist_steps = s.u64_or("codist_steps", 360)?; // paper cap
    d.eval_every = s.u64_or("eval_every", 20)?;
    let bundle = open_bundle(s, s.str_or("bundle", "lm_b64"))?;
    let results = results_dir(s);
    let mut csv = CsvWriter::create(
        &results.join("sec341.csv"),
        &["arm", "step", "total_step_cost", "val_loss"],
    )?;

    // ---- Phase 1: the ensemble (two independent baselines).
    let plan = ShardPlan::new(2, bundle.meta_usize("batch")?, ShardMode::Disjoint);
    let mut t0 = lm_member(&bundle, &plan, 0, d.seed, 1, SmoothingMode::None, d.val_batches)?;
    let mut t1 = lm_member(&bundle, &plan, 1, d.seed, 2, SmoothingMode::None, d.val_batches)?;
    for _step in 0..phase1 {
        t0.train_step(0.0, d.lr)?;
        t1.train_step(0.0, d.lr)?;
    }
    let teachers = vec![Arc::new(t0.snapshot()?), Arc::new(t1.snapshot()?)];
    println!("[sec341] phase 1 done: 2 teachers x {phase1} steps");

    // Target: what the offline student should reach (default: measure the
    // student's final loss and use it as the common bar, like the paper's
    // CE 4.0 operating point).
    // ---- Phase 2: offline distillation into a fresh student.
    let plan3 = ShardPlan::new(3, bundle.meta_usize("batch")?, ShardMode::Disjoint);
    let mut student = lm_member(&bundle, &plan3, 2, d.seed, 3, SmoothingMode::None, d.val_batches)?;
    student.set_fixed_teachers(teachers)?;
    let sched = DistillSchedule::new(0, 10, d.weight); // ψ on from the start
    let mut student_curve = Vec::new();
    for step in 0..phase2 {
        let w = sched.weight_at(step);
        student.train_step(w, d.lr)?;
        if (step + 1) % d.eval_every == 0 || step + 1 == phase2 {
            let loss = Member::evaluate(&mut student)?.loss;
            student_curve.push((step + 1, loss));
            // cost accounting: teachers used 2*phase1 steps of compute but
            // the paper counts pipeline *steps*: 18K + 9K -> phase1+step.
            csv.row(&[
                "offline_distill".into(),
                (step + 1).to_string(),
                (phase1 + step + 1).to_string(),
                format!("{loss:.5}"),
            ])?;
        }
    }
    let target = s
        .f64_or("target", student_curve.last().map(|c| c.1).unwrap_or(4.0))?;
    let offline_hit = student_curve
        .iter()
        .find(|&&(_, l)| l <= target)
        .map(|&(st, _)| phase1 + st);
    println!(
        "[sec341] phase 2 done: offline student reaches {target:.4} at total cost {:?}",
        offline_hit
    );

    // ---- Codistillation from scratch.
    let mut members: Vec<Box<dyn Member>> = Vec::new();
    for g in 0..2 {
        members.push(Box::new(lm_member(
            &bundle,
            &plan,
            g,
            d.seed ^ 0xc0d,
            (g + 10) as i32,
            SmoothingMode::None,
            d.val_batches,
        )?));
    }
    let mut cfg = orch_config(&d, DistillSchedule::new(d.burn_in, d.ramp, d.weight), None);
    cfg.total_steps = codist_steps;
    let orch = Orchestrator::new(cfg);
    let log = orch.run(&mut members)?;
    for p in &log.eval[0] {
        csv.row(&[
            "codistill".into(),
            p.step.to_string(),
            p.step.to_string(),
            format!("{:.5}", p.loss),
        ])?;
    }
    csv.finish()?;
    let codist_hit = log.steps_to_target(0, target);
    println!(
        "[sec341] codistillation reaches {target:.4} at step {:?} \
         (offline total: {:?}; paper: 10K vs 27K)",
        codist_hit, offline_hit
    );
    Ok(TwoPhaseSummary {
        offline_total_cost: offline_hit,
        codistill_cost: codist_hit,
        target,
    })
}

//! Fig 3: codistillation on the image task (ImageNet stand-in).
//!
//! Paper: two-way codistillation enabled after 3000 steps reaches the
//! baseline's 75% accuracy at 5250 vs 7250 steps, and ends slightly higher
//! (75.6%). Setup follows Goyal et al.: momentum SGD, warmup + step decay.
//!
//! Here: the synthetic prototype-image task (DESIGN.md §4) with the same
//! schedule structure, scaled step counts, and a noise level that puts the
//! baseline plateau near the paper's 75% operating point.
//!
//! Emits `results/fig3.csv` (arm, step, accuracy, val_loss).

use crate::codistill::{
    Codec, DistillSchedule, LrSchedule, Member, Orchestrator, OrchestratorConfig, Topology,
};
use crate::config::Settings;
use crate::experiments::common::{open_bundle, results_dir};
use crate::metrics::CsvWriter;
use crate::models::images::{ImagesMember, ImagesValSet};
use anyhow::Result;
use std::collections::BTreeMap;

pub struct Fig3Summary {
    /// arm -> (step, accuracy) curve
    pub curves: BTreeMap<String, Vec<(u64, f64)>>,
    /// steps for codistill to reach the baseline's final accuracy
    pub codistill_steps_to_baseline_final: Option<u64>,
}

pub fn run(s: &Settings) -> Result<Fig3Summary> {
    let steps = s.u64_or("steps", 400)?;
    let eval_every = s.u64_or("eval_every", 25)?;
    let burn_in = s.u64_or("burn_in", 120)?; // paper: 3000 of ~7250
    let seed = s.u64_or("seed", 42)?;
    let noise = s.f64_or("noise", 2.0)?;
    let base_lr = s.f32_or("lr", 0.02)?;
    let val_batches = s.usize_or("val_batches", 4)?;
    let bundle = open_bundle(s, "images")?;
    let batch = bundle.meta_usize("batch")?;
    let size = bundle.meta_usize("size")?;
    let channels = bundle.meta_usize("channels")?;
    let classes = bundle.meta_usize("classes")?;

    let val = ImagesValSet::generate(
        seed, 1_000_000, size, channels, classes, batch, val_batches, noise,
    )?;

    // Goyal-style schedule scaled to our step count.
    let lr = LrSchedule::WarmupStep {
        base: base_lr,
        warmup: steps / 20,
        milestones: vec![steps / 2, (3 * steps) / 4],
        decay: 0.1,
    };

    let mut curves = BTreeMap::new();
    for (arm, n_members, distill) in [
        ("baseline", 1usize, DistillSchedule::off()),
        (
            "codistill",
            2,
            DistillSchedule::new(burn_in, burn_in / 4, s.f32_or("weight", 1.0)?),
        ),
    ] {
        let mut members: Vec<Box<dyn Member>> = Vec::new();
        for g in 0..n_members {
            members.push(Box::new(ImagesMember::new(
                &bundle,
                seed,
                g as u64, // disjoint data streams per member
                (g + 1) as i32,
                noise,
                val.clone(),
            )?));
        }
        let cfg = OrchestratorConfig {
            total_steps: steps,
            reload_interval: s.u64_or("reload", 50)?,
            extra_staleness: 0,
            eval_every,
            distill,
            lr: lr.clone(),
            topology: Topology::Pair,
            cluster: None,
            seed,
            delta: false,
            publish_codec: Codec::Raw,
            error_feedback: false,
            verbose: s.bool_or("verbose", false)?,
        };
        let orch = Orchestrator::new(cfg);
        let log = orch.run(&mut members)?;
        let curve: Vec<(u64, f64)> = log.eval[0]
            .iter()
            .map(|p| (p.step, p.accuracy.unwrap_or(f64::NAN)))
            .collect();
        println!(
            "[fig3] arm {arm}: final acc {:.4}",
            curve.last().map(|c| c.1).unwrap_or(f64::NAN)
        );
        curves.insert(arm.to_string(), curve);
    }

    let results = results_dir(s);
    let mut csv = CsvWriter::create(&results.join("fig3.csv"), &["arm", "step", "accuracy"])?;
    for (arm, curve) in &curves {
        for (step, acc) in curve {
            csv.row(&[arm.clone(), step.to_string(), format!("{acc:.5}")])?;
        }
    }
    csv.finish()?;

    let baseline_final = curves["baseline"].last().map(|c| c.1).unwrap_or(1.0);
    let hit = curves["codistill"]
        .iter()
        .find(|&&(_, a)| a >= baseline_final)
        .map(|&(s, _)| s);
    println!(
        "[fig3] codistill reaches baseline final acc {baseline_final:.4} at step {:?} (baseline: {steps})",
        hit
    );
    Ok(Fig3Summary {
        curves,
        codistill_steps_to_baseline_final: hit,
    })
}

//! Fig 4: sensitivity to the checkpoint reload interval.
//!
//! Paper: two-way synchronous codistillation on Common Crawl with
//! exchange delays of 50/100/250 steps — beyond 50 steps (819,200
//! examples) the learning curve degrades only slightly, demonstrating the
//! staleness tolerance that makes the algorithm communication-cheap.
//!
//! Emits `results/fig4.csv` (reload_interval, step, val_loss) plus a
//! summary of observed teacher staleness per interval.

use crate::codistill::{DistillSchedule, Member, Orchestrator};
use crate::config::Settings;
use crate::data::shard::{ShardMode, ShardPlan};
use crate::experiments::common::{lm_defaults, lm_member, open_bundle, orch_config, results_dir};
use crate::metrics::CsvWriter;
use crate::models::lm::SmoothingMode;
use anyhow::Result;
use std::collections::BTreeMap;

pub struct Fig4Summary {
    /// interval -> final val loss
    pub finals: BTreeMap<u64, f64>,
    /// interval -> mean observed staleness (steps)
    pub staleness: BTreeMap<u64, f64>,
}

pub fn run(s: &Settings) -> Result<Fig4Summary> {
    let mut d = lm_defaults(s)?;
    d.steps = s.u64_or("steps", 240)?;
    d.eval_every = s.u64_or("eval_every", 20)?;
    d.burn_in = s.u64_or("burn_in", 60)?;
    d.ramp = s.u64_or("ramp", 30)?;
    let intervals: Vec<u64> = s
        .str_or("intervals", "25,50,100")
        .split(',')
        .map(|v| v.trim().parse().unwrap())
        .collect();
    let bundle = open_bundle(s, s.str_or("bundle", "lm_b64"))?;
    let results = results_dir(s);
    let mut csv = CsvWriter::create(
        &results.join("fig4.csv"),
        &["reload_interval", "step", "val_loss"],
    )?;

    let mut finals = BTreeMap::new();
    let mut staleness = BTreeMap::new();
    for &interval in &intervals {
        let plan = ShardPlan::new(2, bundle.meta_usize("batch")?, ShardMode::Disjoint);
        let mut members: Vec<Box<dyn Member>> = Vec::new();
        for g in 0..2 {
            members.push(Box::new(lm_member(
                &bundle,
                &plan,
                g,
                d.seed,
                (g + 1) as i32,
                SmoothingMode::None,
                d.val_batches,
            )?));
        }
        let mut cfg = orch_config(&d, DistillSchedule::new(d.burn_in, d.ramp, d.weight), None);
        cfg.reload_interval = interval;
        let orch = Orchestrator::new(cfg);
        let log = orch.run(&mut members)?;
        for p in &log.eval[0] {
            csv.row(&[
                interval.to_string(),
                p.step.to_string(),
                format!("{:.5}", p.loss),
            ])?;
        }
        let fin = log.final_mean_loss().unwrap_or(f64::NAN);
        let mean_stale = if log.staleness.is_empty() {
            0.0
        } else {
            log.staleness.iter().map(|&(_, _, st)| st as f64).sum::<f64>()
                / log.staleness.len() as f64
        };
        println!(
            "[fig4] reload={interval}: final={fin:.4} mean_observed_staleness={mean_stale:.1} steps"
        );
        finals.insert(interval, fin);
        staleness.insert(interval, mean_stale);
    }
    csv.finish()?;
    println!("[fig4] paper shape: mild monotone degradation as interval grows");
    Ok(Fig4Summary { finals, staleness })
}

//! Fig 1a/1b: reaching the limits of distributed sync SGD.
//!
//! Paper: validation error vs steps (1a) and vs wall time (1b) for fully
//! synchronous SGD with 32/64/128/256 workers (effective batch 4096–32768,
//! per-worker batch 128). Finding: steps-to-target improves up to 128
//! workers then plateaus; at 256 workers step-time degradation makes more
//! workers counterproductive.
//!
//! Here (1:8 scale, DESIGN.md §4): worker counts {4, 8, 16, 32} × per-
//! worker batch 8 → fused effective batches {32, 64, 128, 256} (bundles
//! `lm_b32..lm_b256`), and the step-time model prices the paper-scale
//! cluster (32·8=256 workers at the top end) for the wall-time axis.
//!
//! Emits `results/fig1a.csv` (worker count, step, val loss) and
//! `results/fig1b.csv` (worker count, wall seconds, val loss).

use crate::codistill::{DistillSchedule, Member, Orchestrator};
use crate::config::Settings;
use crate::data::shard::{ShardMode, ShardPlan};
use crate::experiments::common::{
    lm_defaults, lm_member, open_bundle, orch_config, print_runlog, results_dir, WORKER_SCALE,
};
use crate::metrics::CsvWriter;
use crate::models::lm::SmoothingMode;
use crate::netsim::ClusterModel;
use anyhow::Result;

/// Per-worker batch in our scaled setup (paper: 128).
pub const WORKER_BATCH: usize = 8;

/// Simulated worker counts (paper: ×8 of these).
pub const WORKERS: [usize; 4] = [4, 8, 16, 32];

pub struct Fig1Summary {
    /// (workers, steps_to_target or u64::MAX, final loss, mean step time s)
    pub rows: Vec<(usize, u64, f64, f64)>,
}

pub fn run(s: &Settings) -> Result<Fig1Summary> {
    let mut d = lm_defaults(s)?;
    d.steps = s.u64_or("steps", 240)?;
    d.eval_every = s.u64_or("eval_every", 20)?;
    let target = s.f64_or("target", 4.95)?;
    let results = results_dir(s);
    let mut csv_a = CsvWriter::create(&results.join("fig1a.csv"), &["workers", "step", "val_loss"])?;
    let mut csv_b = CsvWriter::create(
        &results.join("fig1b.csv"),
        &["workers", "wall_s", "val_loss"],
    )?;

    // LM f32 params ≈ 0.26 MB at this scale; the netsim prices the paper's
    // model (2×LSTM-1024 ≈ 40 MB of gradients) for realistic wall times.
    let paper_model_bytes: u64 = 40_000_000;

    let mut rows = Vec::new();
    for &w in &WORKERS {
        let eff = w * WORKER_BATCH;
        let bundle = open_bundle(s, &format!("lm_b{eff}"))?;
        let plan = ShardPlan::new(1, eff, ShardMode::Disjoint);
        let member = lm_member(&bundle, &plan, 0, d.seed, 1, SmoothingMode::None, d.val_batches)?;
        let cluster = ClusterModel::gpu_cluster(w * WORKER_SCALE, paper_model_bytes);
        let mean_step = cluster.mean_step_time(200, d.seed ^ w as u64);
        let cfg = orch_config(&d, DistillSchedule::off(), Some(cluster));
        let orch = Orchestrator::new(cfg);
        let mut members: Vec<Box<dyn Member>> = vec![Box::new(member)];
        let log = orch.run(&mut members)?;
        for p in &log.eval[0] {
            csv_a.row(&[w.to_string(), p.step.to_string(), format!("{:.5}", p.loss)])?;
            csv_b.row(&[
                w.to_string(),
                format!("{:.2}", p.wall_s),
                format!("{:.5}", p.loss),
            ])?;
        }
        let stt = log.steps_to_target(0, target).unwrap_or(u64::MAX);
        let fin = log.final_mean_loss().unwrap_or(f64::NAN);
        println!(
            "[fig1] workers={w} (paper ~{}) eff_batch={eff}: steps_to_{target}={} final={fin:.4} mean_step_time={mean_step:.3}s",
            w * WORKER_SCALE,
            if stt == u64::MAX { "n/a".into() } else { stt.to_string() },
        );
        print_runlog(&format!("fig1 w={w}"), &log);
        rows.push((w, stt, fin, mean_step));
    }
    csv_a.finish()?;
    csv_b.finish()?;

    println!("\n[fig1] paper shape checks:");
    println!("  - steps-to-target should improve with workers, then plateau");
    println!("  - mean step time should degrade at the largest worker count");
    Ok(Fig1Summary { rows })
}

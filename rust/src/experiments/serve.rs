//! `codistill serve`: the batching inference tier end-to-end.
//!
//! One publisher (a deterministic [`DriftMember`] standing in for the
//! distilled model's training job) publishes checkpoints over the
//! selected `--transport`; a [`Subscription`] follows them (delta-aware
//! with `--delta`, compressed with `--compress`, retrying with
//! `--retry`) and hot-swaps each fresh plane into an
//! [`InferenceServer`] while a seeded load generator drives traffic.
//! Each publish is gated on the previous install landing, so every
//! publication becomes a distinct mid-traffic hot swap.
//!
//! Knobs (all `--set key=value` unless a dedicated flag exists):
//!
//! * `publishes=N` (4), `publish_steps=N` (5), `mock_frozen=N` (256) —
//!   the publisher's checkpoint cadence and plane size
//! * `requests=N` (2000), `rps=R` (5000), `clients=N` (0 = open loop;
//!   >0 runs that many closed-loop callers instead)
//! * `batch=N` (64), `batch_delay_ms=MS` (2), `workers=N` (2),
//!   `probe=N` (32) — server batching and churn-probe knobs
//! * `poll_ms=MS` (2) — subscription heartbeat cadence
//! * `upstream=relay:ADDR` — subscribe through a checkpoint relay
//!   instead of straight off the transport: `relay:auto` (or the
//!   shorthand `upstream=auto`) spawns an in-process [`Relay`] over the
//!   built transport (the one-process publisher → relay → serve demo),
//!   any other `ADDR` connects the subscription to an already-running
//!   relay tier (`codistill relay`) at that address — the publisher
//!   keeps publishing to the base transport the relay mirrors
//!
//! With `--trace FILE` the run records publish/fetch/install/swap (and,
//! via `upstream=auto`, relay-forward) events into a shared
//! [`codistill::obs`](crate::codistill::obs) journal and dumps it as
//! JSONL on exit.
//!
//! The run prints the load report (p50/p99/p999 latency, goodput), the
//! server's throughput-vs-batch-size table, the churn-across-swaps
//! aggregate (mean ± half-range, the paper's Table 1 convention applied
//! to serving), and the subscription's delta-exchange accounting.

use crate::codistill::{
    Codec, ExchangeTransport, Member, Relay, RelayConfig, SocketTransport, SubscribeConfig,
    Subscription,
};
use crate::codistill::serve::{
    closed_loop, open_loop, InferenceServer, LoadSpec, OpenLoopSpec, ServeConfig,
};
use crate::codistill::obs::Event;
use crate::config::Settings;
use crate::experiments::common::{
    delta_stats_line, make_transport, run_recorder, wrap_retry, write_trace,
};
use crate::models::MockForward;
use crate::testkit::DriftMember;
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Wait until `cond` holds, polling every millisecond; bail after 10s.
fn wait_until(what: &str, cond: impl Fn() -> bool) -> Result<()> {
    let t0 = Instant::now();
    while !cond() {
        if t0.elapsed() > Duration::from_secs(10) {
            bail!("timed out waiting for {what}");
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    Ok(())
}

pub fn run(s: &Settings) -> Result<()> {
    let seed = s.u64_or("seed", 42)?;
    let member = s.usize_or("member", 0)?;
    let publishes = s.u64_or("publishes", 4)?;
    let publish_steps = s.u64_or("publish_steps", 5)?;
    let frozen = s.usize_or("mock_frozen", 256)?;
    let delta = s.bool_or("delta", true)?;
    let verbose = s.bool_or("verbose", false)?;

    let cfg = ServeConfig {
        max_batch_items: s.usize_or("batch", 64)?,
        max_delay: Duration::from_millis(s.u64_or("batch_delay_ms", 2)?),
        workers: s.usize_or("workers", 2)?,
        probe: (0..s.u64_or("probe", 32)?).collect(),
    };
    let load = LoadSpec {
        requests: s.u64_or("requests", 2000)?,
        seed,
        min_features: s.usize_or("min_features", 1)?,
        max_features: s.usize_or("max_features", 8)?,
    };
    let clients = s.usize_or("clients", 0)?;
    let rps = s.f64_or("rps", 5000.0)?;

    let setup = make_transport(s, s.usize_or("history", 8)?)?;
    let recorder = run_recorder(s)?;
    // `upstream=relay:ADDR` interposes a relay hop between the publisher
    // and the subscription: the publisher keeps publishing to the base
    // transport, the subscription reads a relay's mirror of it.
    // `relay:auto` (or plain `auto`) spawns the relay in-process (the
    // one-command demo topology — "auto" resolves to the configured
    // relay); anything else connects to an external `codistill relay`.
    let mut relay: Option<Relay> = None;
    let sub_base: Arc<dyn ExchangeTransport> = match s.get("upstream") {
        None => setup.transport.clone(),
        Some(v) => {
            let addr = if v == "auto" {
                "auto"
            } else {
                v.strip_prefix("relay:").ok_or_else(|| {
                    anyhow::anyhow!("upstream must be auto or relay:ADDR, got {v:?}")
                })?
            };
            let client_addr = if addr == "auto" {
                let r = Relay::spawn_tcp_recorded(
                    setup.transport.clone(),
                    "127.0.0.1:0",
                    RelayConfig {
                        poll_interval: Duration::from_millis(s.u64_or("poll_ms", 2)?),
                        delta,
                        codec: setup.codec,
                        ..RelayConfig::default()
                    },
                    recorder.clone(),
                )?;
                let a = r.addr().to_string();
                relay = Some(r);
                a
            } else {
                addr.to_string()
            };
            let mut t = SocketTransport::connect_tcp(&client_addr);
            if setup.codec != Codec::Raw {
                t = t.with_codec(setup.codec);
            }
            Arc::new(t)
        }
    };
    let (sub_transport, want_retry) = wrap_retry(s, sub_base, seed, recorder.as_ref())?;
    let (transport, _) = wrap_retry(s, setup.transport.clone(), seed, recorder.as_ref())?;
    if verbose {
        eprintln!(
            "[serve] transport: {}{}{}{}{}",
            setup.kind.name(),
            if delta { " (+delta)" } else { "" },
            if setup.codec != Codec::Raw { " (+compress)" } else { "" },
            if relay.is_some() {
                " (via in-process relay)"
            } else if s.get("upstream").is_some() {
                " (via external relay)"
            } else {
                ""
            },
            if want_retry { " (+retry)" } else { "" }
        );
    }

    let server = Arc::new(InferenceServer::start(Arc::new(MockForward::new()), cfg));
    if let Some(rec) = &recorder {
        server.set_recorder(rec.clone());
    }

    // The subscription feeds the swap handle; every verified install is
    // a hot swap under whatever traffic is in flight.
    let sub_server = server.clone();
    let mut sub = Subscription::spawn_recorded(
        sub_transport.clone(),
        SubscribeConfig {
            member,
            poll_interval: Duration::from_millis(s.u64_or("poll_ms", 2)?),
            delta,
            codec: setup.codec,
        },
        recorder.clone(),
        move |ck| sub_server.install(ck),
    );

    // Publisher: gate each publish on the previous install so no
    // checkpoint coalesces into its successor — `publishes` publications
    // become exactly `publishes` installs (`publishes - 1` swaps).
    let (pub_transport, pub_server) = (transport.clone(), server.clone());
    let pub_recorder = recorder.clone();
    let publisher = std::thread::Builder::new()
        .name("serve-publisher".into())
        .spawn(move || -> Result<()> {
            let mut m = DriftMember::with_frozen(member, frozen);
            for _ in 0..publishes {
                for _ in 0..publish_steps {
                    m.train_step(0.0, 0.1)?;
                }
                let step = m.steps_done();
                let ck = m.snapshot()?;
                // Journal the publish *before* the transport call: the
                // subscription cannot see step N until the publish lands,
                // so the trace always orders publish -> fetch -> swap.
                // Duration is left 0 — the gated cadence below measures
                // install latency, not wire time.
                if let Some(rec) = &pub_recorder {
                    rec.record(Event::Publish {
                        member: ck.member,
                        step: ck.step,
                        bytes: ck.flat().layout().total_bytes() as u64,
                        dur_us: 0,
                    });
                }
                pub_transport.publish(ck)?;
                let t0 = Instant::now();
                while pub_server.installed_step() != Some(step) {
                    if t0.elapsed() > Duration::from_secs(10) {
                        bail!("install of published step {step} did not land");
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
            Ok(())
        })
        .expect("spawning publisher thread");

    // Open traffic only once a plane is serving, so a healthy run
    // reports zero failed requests.
    wait_until("first checkpoint install", || {
        server.installed_step().is_some()
    })?;

    let run = if clients > 0 {
        closed_loop(&server, clients, &load)
    } else {
        open_loop(&server, &OpenLoopSpec { load, rps })
    };

    publisher.join().expect("publisher panicked")?;
    sub.stop();
    let sub_stats = sub.stats();
    server.shutdown();

    println!(
        "[serve] load: sent={} ok={} failed={} goodput={:.0} req/s",
        run.report.sent,
        run.report.ok,
        run.report.failed,
        run.report.goodput()
    );
    println!("[serve] latency: {}", run.report.latency.summary_ms());
    for e in run.errors.iter().take(5) {
        eprintln!("[serve] request error: {e}");
    }
    let stats = server.stats();
    println!(
        "[serve] server: served={} failed={} batches={}",
        stats.served, stats.failed, stats.batches
    );
    for line in stats.throughput_lines("serve") {
        println!("{line}");
    }
    let (churn, log) = server.churn();
    println!(
        "[serve] hot swaps: {} over {} installs (zero downtime: every response from exactly one plane)",
        server.swaps(),
        sub_stats.installs
    );
    if !churn.samples.is_empty() {
        println!(
            "[serve] churn across swaps: {:.6} ± {:.6} (mean ± half-range over {} swaps)",
            churn.mean(),
            churn.half_range(),
            churn.samples.len()
        );
    }
    if verbose && !log.is_empty() {
        print!("{log}");
    }
    println!(
        "[serve] subscription: polls={} fetches={} installs={} tolerated_errors={}",
        sub_stats.polls, sub_stats.fetches, sub_stats.installs, sub_stats.tolerated_errors
    );
    if delta {
        delta_stats_line("serve", &sub_stats.delta);
    }
    if let Some(mut r) = relay.take() {
        let rs = r.stats();
        println!(
            "[serve] relay hop: polls={} installs={} tolerated_errors={}",
            rs.polls, rs.installs, rs.tolerated_errors
        );
        r.stop();
    }
    if let Some(rec) = &recorder {
        write_trace(s, rec)?;
    }
    drop(setup);
    Ok(())
}

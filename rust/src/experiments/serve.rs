//! `codistill serve`: the batching inference tier end-to-end.
//!
//! One publisher (a deterministic [`DriftMember`] standing in for the
//! distilled model's training job) publishes checkpoints over the
//! selected `--transport`; a [`Subscription`] follows them (delta-aware
//! with `--delta`, compressed with `--compress`, retrying with
//! `--retry`) and hot-swaps each fresh plane into an
//! [`InferenceServer`] while a seeded load generator drives traffic.
//! Each publish is gated on the previous install landing, so every
//! publication becomes a distinct mid-traffic hot swap.
//!
//! Knobs (all `--set key=value` unless a dedicated flag exists):
//!
//! * `publishes=N` (4), `publish_steps=N` (5), `mock_frozen=N` (256) —
//!   the publisher's checkpoint cadence and plane size
//! * `requests=N` (2000), `rps=R` (5000), `clients=N` (0 = open loop;
//!   >0 runs that many closed-loop callers instead)
//! * `batch=N` (64), `batch_delay_ms=MS` (2), `workers=N` (2),
//!   `probe=N` (32) — server batching and churn-probe knobs
//! * `poll_ms=MS` (2) — subscription heartbeat cadence
//!
//! The run prints the load report (p50/p99/p999 latency, goodput), the
//! server's throughput-vs-batch-size table, the churn-across-swaps
//! aggregate (mean ± half-range, the paper's Table 1 convention applied
//! to serving), and the subscription's delta-exchange accounting.

use crate::codistill::{
    Codec, ExchangeTransport, Member, SubscribeConfig, Subscription,
};
use crate::codistill::serve::{
    closed_loop, open_loop, InferenceServer, LoadSpec, OpenLoopSpec, ServeConfig,
};
use crate::config::Settings;
use crate::experiments::common::{delta_stats_line, make_transport, wrap_retry};
use crate::models::MockForward;
use crate::testkit::DriftMember;
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Wait until `cond` holds, polling every millisecond; bail after 10s.
fn wait_until(what: &str, cond: impl Fn() -> bool) -> Result<()> {
    let t0 = Instant::now();
    while !cond() {
        if t0.elapsed() > Duration::from_secs(10) {
            bail!("timed out waiting for {what}");
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    Ok(())
}

pub fn run(s: &Settings) -> Result<()> {
    let seed = s.u64_or("seed", 42)?;
    let member = s.usize_or("member", 0)?;
    let publishes = s.u64_or("publishes", 4)?;
    let publish_steps = s.u64_or("publish_steps", 5)?;
    let frozen = s.usize_or("mock_frozen", 256)?;
    let delta = s.bool_or("delta", true)?;
    let verbose = s.bool_or("verbose", false)?;

    let cfg = ServeConfig {
        max_batch_items: s.usize_or("batch", 64)?,
        max_delay: Duration::from_millis(s.u64_or("batch_delay_ms", 2)?),
        workers: s.usize_or("workers", 2)?,
        probe: (0..s.u64_or("probe", 32)?).collect(),
    };
    let load = LoadSpec {
        requests: s.u64_or("requests", 2000)?,
        seed,
        min_features: s.usize_or("min_features", 1)?,
        max_features: s.usize_or("max_features", 8)?,
    };
    let clients = s.usize_or("clients", 0)?;
    let rps = s.f64_or("rps", 5000.0)?;

    let setup = make_transport(s, s.usize_or("history", 8)?)?;
    let (transport, want_retry) = wrap_retry(s, setup.transport.clone(), seed)?;
    if verbose {
        eprintln!(
            "[serve] transport: {}{}{}{}",
            setup.kind.name(),
            if delta { " (+delta)" } else { "" },
            if setup.codec != Codec::Raw { " (+compress)" } else { "" },
            if want_retry { " (+retry)" } else { "" }
        );
    }

    let server = Arc::new(InferenceServer::start(Arc::new(MockForward::new()), cfg));

    // The subscription feeds the swap handle; every verified install is
    // a hot swap under whatever traffic is in flight.
    let sub_server = server.clone();
    let mut sub = Subscription::spawn(
        transport.clone(),
        SubscribeConfig {
            member,
            poll_interval: Duration::from_millis(s.u64_or("poll_ms", 2)?),
            delta,
            codec: setup.codec,
        },
        move |ck| sub_server.install(ck),
    );

    // Publisher: gate each publish on the previous install so no
    // checkpoint coalesces into its successor — `publishes` publications
    // become exactly `publishes` installs (`publishes - 1` swaps).
    let (pub_transport, pub_server) = (transport.clone(), server.clone());
    let publisher = std::thread::Builder::new()
        .name("serve-publisher".into())
        .spawn(move || -> Result<()> {
            let mut m = DriftMember::with_frozen(member, frozen);
            for _ in 0..publishes {
                for _ in 0..publish_steps {
                    m.train_step(0.0, 0.1)?;
                }
                let step = m.steps_done();
                pub_transport.publish(m.snapshot()?)?;
                let t0 = Instant::now();
                while pub_server.installed_step() != Some(step) {
                    if t0.elapsed() > Duration::from_secs(10) {
                        bail!("install of published step {step} did not land");
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
            Ok(())
        })
        .expect("spawning publisher thread");

    // Open traffic only once a plane is serving, so a healthy run
    // reports zero failed requests.
    wait_until("first checkpoint install", || {
        server.installed_step().is_some()
    })?;

    let run = if clients > 0 {
        closed_loop(&server, clients, &load)
    } else {
        open_loop(&server, &OpenLoopSpec { load, rps })
    };

    publisher.join().expect("publisher panicked")?;
    sub.stop();
    let sub_stats = sub.stats();
    server.shutdown();

    println!(
        "[serve] load: sent={} ok={} failed={} goodput={:.0} req/s",
        run.report.sent,
        run.report.ok,
        run.report.failed,
        run.report.goodput()
    );
    println!("[serve] latency: {}", run.report.latency.summary_ms());
    for e in run.errors.iter().take(5) {
        eprintln!("[serve] request error: {e}");
    }
    let stats = server.stats();
    println!(
        "[serve] server: served={} failed={} batches={}",
        stats.served, stats.failed, stats.batches
    );
    for line in stats.throughput_lines("serve") {
        println!("{line}");
    }
    let (churn, log) = server.churn();
    println!(
        "[serve] hot swaps: {} over {} installs (zero downtime: every response from exactly one plane)",
        server.swaps(),
        sub_stats.installs
    );
    if !churn.samples.is_empty() {
        println!(
            "[serve] churn across swaps: {:.6} ± {:.6} (mean ± half-range over {} swaps)",
            churn.mean(),
            churn.half_range(),
            churn.samples.len()
        );
    }
    if verbose && !log.is_empty() {
        print!("{log}");
    }
    println!(
        "[serve] subscription: polls={} fetches={} installs={} tolerated_errors={}",
        sub_stats.polls, sub_stats.fetches, sub_stats.installs, sub_stats.tolerated_errors
    );
    if delta {
        delta_stats_line("serve", &sub_stats.delta);
    }
    drop(setup);
    Ok(())
}

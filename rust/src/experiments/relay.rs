//! `codistill relay`: checkpoint fan-out nodes and trees.
//!
//! Two modes:
//!
//! * **Node mode** (`upstream=HOST:PORT|unix:PATH` set): run one
//!   [`Relay`] — subscribe to the upstream hub (or another relay) and
//!   serve downstream readers on `listen` (default `127.0.0.1:0`; the
//!   resolved address is printed so scripts can chain nodes). Runs for
//!   `duration_s` seconds (0 = until killed), then prints the node's
//!   refresh/forwarding stats.
//! * **Demo mode** (no `upstream`): build a self-contained fan-out tree
//!   over an in-process hub — `tree_depth` levels of `tree_fanout`
//!   relays each, `readers` leaf readers — drive a publisher through
//!   `publishes` publications, and verify every reader's final plane is
//!   byte-identical to the hub's before printing per-level stats.
//!
//! Knobs (all `--set key=value` unless a dedicated flag exists):
//!
//! * `upstream=ADDR`, `listen=ADDR` (127.0.0.1:0), `duration_s=N` (0)
//! * `poll_ms=MS` (5), `--delta` (default on; `delta=false` disables),
//!   `--compress` / `codec=raw|shuffle`, `history=N` (4),
//!   `max_connections=N`
//! * demo: `tree_depth=N` (2), `tree_fanout=N` (2), `readers=N` (8),
//!   `publishes=N` (3), `publish_steps=N` (5), `mock_frozen=N` (64),
//!   `member=N` (0)
//! * `--trace FILE` — record forward/fetch/install events from every
//!   node into one shared [`codistill::obs`](crate::codistill::obs)
//!   journal and dump it as JSONL on exit
//!
//! Both modes print each node's [`RelayStats`](crate::codistill::RelayStats)
//! line plus the same refresh loop viewed as
//! [`SubscribeStats`](crate::codistill::SubscribeStats) on exit.

use crate::codistill::obs::Recorder;
use crate::codistill::transport::socket::MAX_CONNECTIONS;
use crate::codistill::{
    Codec, ExchangeTransport, Relay, RelayConfig, SocketTransport,
};
use crate::config::Settings;
use crate::experiments::common::{run_recorder, write_trace};
use crate::testkit::DriftMember;
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn relay_config(s: &Settings) -> Result<RelayConfig> {
    let codec = if s.bool_or("compress", false)? {
        Codec::parse(s.str_or("codec", "shuffle"))?
    } else {
        Codec::Raw
    };
    Ok(RelayConfig {
        poll_interval: Duration::from_millis(s.u64_or("poll_ms", 5)?),
        delta: s.bool_or("delta", true)?,
        codec,
        history: s.usize_or("history", 4)?,
        max_connections: s.usize_or("max_connections", MAX_CONNECTIONS)?,
    })
}

fn stats_line(tag: &str, relay: &Relay) {
    let st = relay.stats();
    println!(
        "[relay] {tag}: polls={} installs={} tolerated_errors={} passthrough={} forwarded_publishes={} \
         delta(full={} delta={} moved={} unchanged={})",
        st.polls,
        st.installs,
        st.tolerated_errors,
        st.passthrough_fetches,
        st.forwarded_publishes,
        st.delta.full_fetches,
        st.delta.delta_fetches,
        st.delta.windows_moved,
        st.delta.windows_unchanged
    );
    // The same refresh loop seen through the subscription lens, so relay
    // nodes and `serve` subscriptions summarise in one vocabulary.
    let sub = relay.subscribe_stats();
    println!(
        "[relay] {tag} subscription: polls={} fetches={} installs={} tolerated_errors={}",
        sub.polls, sub.fetches, sub.installs, sub.tolerated_errors
    );
}

pub fn run(s: &Settings) -> Result<()> {
    match s.get("upstream") {
        Some(addr) => run_node(s, &addr.to_string()),
        None => run_demo_tree(s),
    }
}

/// One fan-out node between a live upstream and downstream readers.
fn run_node(s: &Settings, upstream_addr: &str) -> Result<()> {
    let cfg = relay_config(s)?;
    let recorder = run_recorder(s)?;
    let mut upstream = SocketTransport::connect(upstream_addr)?;
    if cfg.codec != Codec::Raw {
        upstream = upstream.with_codec(cfg.codec);
    }
    let upstream: Arc<dyn ExchangeTransport> = Arc::new(upstream);
    let mut relay = Relay::spawn_tcp_recorded(
        upstream,
        s.str_or("listen", "127.0.0.1:0"),
        cfg,
        recorder.clone(),
    )?;
    println!("[relay] serving {} (upstream {upstream_addr})", relay.addr());

    let duration_s = s.u64_or("duration_s", 0)?;
    let t0 = Instant::now();
    loop {
        if duration_s > 0 && t0.elapsed() >= Duration::from_secs(duration_s) {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    relay.stop();
    stats_line("node", &relay);
    if let Some(rec) = &recorder {
        write_trace(s, rec)?;
    }
    Ok(())
}

/// Self-contained tree: hub -> tree_depth levels of tree_fanout relays
/// -> leaf readers, with a byte-identity check against the hub.
fn run_demo_tree(s: &Settings) -> Result<()> {
    let cfg = relay_config(s)?;
    let depth = s.usize_or("tree_depth", 2)?.max(1);
    let fanout = s.usize_or("tree_fanout", 2)?.max(1);
    let readers = s.usize_or("readers", 8)?;
    let publishes = s.u64_or("publishes", 3)?;
    let publish_steps = s.u64_or("publish_steps", 5)?;
    let frozen = s.usize_or("mock_frozen", 64)?;
    let member = s.usize_or("member", 0)?;
    let verbose = s.bool_or("verbose", false)?;
    // One shared journal across every node in the tree: relay.* counters
    // pool over the whole topology and forward events interleave in
    // arrival order.
    let recorder: Option<Recorder> = run_recorder(s)?;

    let hub: Arc<dyn ExchangeTransport> =
        Arc::new(crate::codistill::InProcess::new(cfg.history));

    // Level by level: each relay's upstream is a socket connection to a
    // parent from the previous level (the hub itself at level 1),
    // assigned round-robin — exactly how real nodes would chain.
    let mut levels: Vec<Vec<Relay>> = Vec::new();
    for level in 0..depth {
        let width = fanout.pow(level as u32 + 1);
        let mut row = Vec::new();
        for i in 0..width {
            let upstream: Arc<dyn ExchangeTransport> = if level == 0 {
                hub.clone()
            } else {
                let parents = &levels[level - 1];
                let parent = &parents[i % parents.len()];
                let mut t = SocketTransport::connect_tcp(parent.addr());
                if cfg.codec != Codec::Raw {
                    t = t.with_codec(cfg.codec);
                }
                Arc::new(t)
            };
            row.push(Relay::spawn_tcp_recorded(
                upstream,
                "127.0.0.1:0",
                cfg.clone(),
                recorder.clone(),
            )?);
        }
        if verbose {
            println!("[relay] level {}: {} nodes", level + 1, row.len());
        }
        levels.push(row);
    }
    let leaves = levels.last().expect("depth >= 1");
    println!(
        "[relay] tree: depth={} fanout={} nodes={} leaf_nodes={} readers={}",
        depth,
        fanout,
        levels.iter().map(Vec::len).sum::<usize>(),
        leaves.len(),
        readers
    );

    // Publisher drives the hub; readers follow leaf relays.
    let mut m = DriftMember::with_frozen(member, frozen);
    for _ in 0..publishes {
        for _ in 0..publish_steps {
            m.train_step(0.0, 0.1)?;
        }
        hub.publish(m.snapshot()?)?;
    }
    let final_step = publishes * publish_steps;

    let mut verified = 0usize;
    let direct = hub
        .latest(member)?
        .expect("hub holds the published plane");
    for r in 0..readers {
        let leaf = &leaves[r % leaves.len()];
        let mut reader = SocketTransport::connect_tcp(leaf.addr());
        if cfg.codec != Codec::Raw {
            reader = reader.with_codec(cfg.codec);
        }
        let t0 = Instant::now();
        let got = loop {
            if let Some(ck) = reader.latest(member)? {
                if ck.step >= final_step {
                    break ck;
                }
            }
            if t0.elapsed() > Duration::from_secs(30) {
                bail!("reader {r} never saw step {final_step}");
            }
            std::thread::sleep(Duration::from_millis(1));
        };
        if got.flat().data() == direct.flat().data() {
            verified += 1;
        } else {
            bail!("reader {r} installed a plane that differs from the hub's");
        }
    }
    println!(
        "[relay] byte-identity: {verified}/{readers} readers match the hub at step {final_step}"
    );

    for (li, row) in levels.iter_mut().enumerate() {
        for (ri, relay) in row.iter_mut().enumerate() {
            relay.stop();
            if verbose || (li + 1 == depth && ri == 0) {
                stats_line(&format!("L{}#{ri}", li + 1), relay);
            }
        }
    }
    if let Some(rec) = &recorder {
        write_trace(s, rec)?;
    }
    Ok(())
}

//! Table 1: prediction churn on Criteo (paper §3.5).
//!
//! Three procedures, each retrained twice per repeat with different
//! init/data-order seeds, churn = mean |Δp| between the two retrains'
//! predictions on a fixed validation set:
//!
//!   * DNN                — single model;
//!   * Ensemble of two    — average of two independently trained DNNs;
//!   * Two-way codistilled — train a codistilling pair, *pick one copy
//!     arbitrarily* (the paper's point: ensemble-like churn without
//!     ensemble serving costs).
//!
//! Reports validation log loss and churn as mean ± half-range over
//! `repeats` repeats (paper: 5). Emits `results/table1.csv`.

use crate::codistill::{DistillSchedule, LrSchedule, Member};
use crate::config::Settings;
use crate::experiments::common::{open_bundle, results_dir};
use crate::metrics::{mean_abs_diff, ChurnReport, CsvWriter};
use crate::models::criteo::{CriteoMember, CriteoValSet};
use crate::runtime::Bundle;
use anyhow::Result;
use std::sync::Arc;

pub struct Table1Row {
    pub name: String,
    pub logloss_mean: f64,
    pub logloss_half_range: f64,
    pub churn_mean: f64,
    pub churn_half_range: f64,
}

pub struct Table1Summary {
    pub rows: Vec<Table1Row>,
}

struct TrainCfg {
    steps: u64,
    lr: f32,
    burn_in: u64,
    weight: f32,
    reload: u64,
    data_seed: u64,
}

/// Train one DNN; returns (val predictions, val log loss).
fn train_dnn(
    bundle: &Bundle,
    cfg: &TrainCfg,
    val: &Arc<CriteoValSet>,
    stream: u64,
    init_seed: i32,
) -> Result<(Vec<f32>, f64)> {
    let mut m = CriteoMember::new(bundle, cfg.data_seed, stream, init_seed, val.clone())?;
    let lr = LrSchedule::Constant(cfg.lr);
    for step in 0..cfg.steps {
        m.train_step(0.0, lr.at(step))?;
    }
    let stats = m.evaluate()?;
    Ok((m.val_predictions()?, stats.loss))
}

/// Train a codistilling pair; returns copy 0's predictions + log loss.
fn train_codistilled_pair(
    bundle: &Bundle,
    cfg: &TrainCfg,
    val: &Arc<CriteoValSet>,
    stream_base: u64,
    init_seed: i32,
) -> Result<(Vec<f32>, f64)> {
    let mut a = CriteoMember::new(bundle, cfg.data_seed, stream_base, init_seed, val.clone())?;
    let mut b =
        CriteoMember::new(bundle, cfg.data_seed, stream_base + 1, init_seed + 100, val.clone())?;
    let sched = DistillSchedule::new(cfg.burn_in, cfg.burn_in / 2, cfg.weight);
    for step in 0..cfg.steps {
        if step % cfg.reload == 0 {
            let ca = Arc::new(a.snapshot()?);
            let cb = Arc::new(b.snapshot()?);
            a.set_teachers(vec![cb])?;
            b.set_teachers(vec![ca])?;
        }
        let w = sched.weight_at(step);
        a.train_step(w, cfg.lr)?;
        b.train_step(w, cfg.lr)?;
    }
    let stats = a.evaluate()?;
    Ok((a.val_predictions()?, stats.loss))
}

fn ensemble_preds(p1: &[f32], p2: &[f32]) -> Vec<f32> {
    p1.iter().zip(p2.iter()).map(|(a, b)| 0.5 * (a + b)).collect()
}

fn ensemble_logloss(preds: &[f32], val: &CriteoValSet) -> f64 {
    let mut labels = Vec::new();
    for b in &val.batches {
        labels.extend_from_slice(b.labels.as_i32().unwrap());
    }
    let mut total = 0.0f64;
    for (&p, &y) in preds.iter().zip(labels.iter()) {
        let p = (p as f64).clamp(1e-7, 1.0 - 1e-7);
        total += if y == 1 { -p.ln() } else { -(1.0 - p).ln() };
    }
    total / preds.len() as f64
}

pub fn run(s: &Settings) -> Result<Table1Summary> {
    let bundle = open_bundle(s, "criteo")?;
    let repeats = s.usize_or("repeats", 3)?; // paper: 5
    let cfg = TrainCfg {
        steps: s.u64_or("steps", 300)?,
        lr: s.f32_or("lr", 0.05)?, // paper uses 0.001 at 43M examples; scaled
        burn_in: s.u64_or("burn_in", 75)?,
        weight: s.f32_or("weight", 1.0)?,
        reload: s.u64_or("reload", 25)?,
        data_seed: s.u64_or("seed", 42)?,
    };
    let buckets = bundle.meta_usize("buckets")?;
    let batch = bundle.meta_usize("batch")?;
    let val = CriteoValSet::generate(cfg.data_seed, 9_999_999, buckets, batch, s.usize_or("val_batches", 8)?)?;

    let mut dnn_loss = ChurnReport::new();
    let mut dnn_churn = ChurnReport::new();
    let mut ens_loss = ChurnReport::new();
    let mut ens_churn = ChurnReport::new();
    let mut cod_loss = ChurnReport::new();
    let mut cod_churn = ChurnReport::new();

    for rep in 0..repeats {
        let base = 1000 * (rep as u64 + 1);
        // Two retrains of the single DNN (different init + data order).
        let (p1, l1) = train_dnn(&bundle, &cfg, &val, base, (base + 1) as i32)?;
        let (p2, l2) = train_dnn(&bundle, &cfg, &val, base + 50, (base + 2) as i32)?;
        dnn_loss.push((l1 + l2) / 2.0);
        dnn_churn.push(mean_abs_diff(&p1, &p2)?);

        // Two retrains of a 2-ensemble (4 trainings).
        let (q1, _) = train_dnn(&bundle, &cfg, &val, base + 100, (base + 3) as i32)?;
        let (q2, _) = train_dnn(&bundle, &cfg, &val, base + 150, (base + 4) as i32)?;
        let e1 = ensemble_preds(&p1, &q1);
        let e2 = ensemble_preds(&p2, &q2);
        ens_loss.push((ensemble_logloss(&e1, &val) + ensemble_logloss(&e2, &val)) / 2.0);
        ens_churn.push(mean_abs_diff(&e1, &e2)?);

        // Two retrains of a codistilled pair (pick copy 0 each time).
        let (c1, cl1) = train_codistilled_pair(&bundle, &cfg, &val, base + 200, (base + 5) as i32)?;
        let (c2, cl2) = train_codistilled_pair(&bundle, &cfg, &val, base + 250, (base + 6) as i32)?;
        cod_loss.push((cl1 + cl2) / 2.0);
        cod_churn.push(mean_abs_diff(&c1, &c2)?);
        println!(
            "[table1] repeat {}/{repeats}: dnn churn {:.4}, ens churn {:.4}, codist churn {:.4}",
            rep + 1,
            dnn_churn.samples.last().unwrap(),
            ens_churn.samples.last().unwrap(),
            cod_churn.samples.last().unwrap()
        );
    }

    let rows = vec![
        Table1Row {
            name: "DNN".into(),
            logloss_mean: dnn_loss.mean(),
            logloss_half_range: dnn_loss.half_range(),
            churn_mean: dnn_churn.mean(),
            churn_half_range: dnn_churn.half_range(),
        },
        Table1Row {
            name: "Ensemble of Two DNNs".into(),
            logloss_mean: ens_loss.mean(),
            logloss_half_range: ens_loss.half_range(),
            churn_mean: ens_churn.mean(),
            churn_half_range: ens_churn.half_range(),
        },
        Table1Row {
            name: "Two-way codistilled DNN".into(),
            logloss_mean: cod_loss.mean(),
            logloss_half_range: cod_loss.half_range(),
            churn_mean: cod_churn.mean(),
            churn_half_range: cod_churn.half_range(),
        },
    ];

    let results = results_dir(s);
    let mut csv = CsvWriter::create(
        &results.join("table1.csv"),
        &["model", "logloss_mean", "logloss_hr", "churn_mean", "churn_hr"],
    )?;
    println!("\n[table1] Model | Validation Log Loss | Mean Abs Pred Diff");
    for r in &rows {
        println!(
            "[table1] {:<26} {:.4} ± {:.4} | {:.4} ± {:.4}",
            r.name, r.logloss_mean, r.logloss_half_range, r.churn_mean, r.churn_half_range
        );
        csv.row(&[
            r.name.replace(' ', "_"),
            format!("{:.5}", r.logloss_mean),
            format!("{:.5}", r.logloss_half_range),
            format!("{:.5}", r.churn_mean),
            format!("{:.5}", r.churn_half_range),
        ])?;
    }
    csv.finish()?;
    if rows[2].churn_mean < rows[0].churn_mean {
        let red = 100.0 * (1.0 - rows[2].churn_mean / rows[0].churn_mean);
        println!("[table1] codistillation reduces churn by {red:.0}% (paper: ~35%)");
    }
    Ok(Table1Summary { rows })
}

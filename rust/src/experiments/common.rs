//! Shared experiment plumbing: bundle opening, member construction, CLI
//! commands, result-table printing.

use crate::codistill::{
    Codec, Coordinator, CoordinatorConfig, DistillSchedule, ExchangeTransport, FaultPlan, Faulty,
    HostedMember, InProcess, LrSchedule, Member, Orchestrator, OrchestratorConfig, Recorder,
    Retry, RetryPolicy, RunLog, Scenario, SocketServer, SocketTransport, SpoolDir, Topology,
    TransportKind,
};
use crate::config::Settings;
use crate::data::corpus::CorpusConfig;
use crate::data::shard::{ShardMode, ShardPlan};
use crate::models::lm::{LmMember, SmoothingMode};
use crate::netsim::ClusterModel;
use crate::runtime::{Bundle, Runtime};
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// Thread-local runtime (PJRT client): the xla wrapper types are not Send,
/// so each thread that touches XLA owns its own client + compile cache.
/// Experiments are single-threaded over XLA, so in practice this is one
/// client per process.
pub fn runtime() -> Result<Arc<Runtime>> {
    thread_local! {
        static RT: std::cell::OnceCell<Arc<Runtime>> = const { std::cell::OnceCell::new() };
    }
    RT.with(|cell| {
        if let Some(rt) = cell.get() {
            return Ok(rt.clone());
        }
        let rt = Arc::new(Runtime::cpu()?);
        let _ = cell.set(rt.clone());
        Ok(rt)
    })
}

pub fn artifacts_dir(s: &Settings) -> PathBuf {
    // Default relative to the crate root so tests/benches work from
    // anywhere inside the repo.
    let p = PathBuf::from(s.str_or("artifacts", ""));
    if !p.as_os_str().is_empty() {
        return p;
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.join("artifacts")
}

pub fn results_dir(s: &Settings) -> PathBuf {
    let p = PathBuf::from(s.str_or("results", ""));
    if !p.as_os_str().is_empty() {
        return p;
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.join("results")
}

pub fn open_bundle(s: &Settings, name: &str) -> Result<Bundle> {
    let dir = artifacts_dir(s).join(name);
    Bundle::open(runtime()?, &dir).with_context(|| format!("opening bundle {name}"))
}

/// Corpus config matching an LM bundle's dims.
pub fn corpus_for(bundle: &Bundle) -> Result<CorpusConfig> {
    Ok(CorpusConfig {
        vocab: bundle.meta_usize("vocab")?,
        ..CorpusConfig::default()
    })
}

/// Build one LM member on a shard plan slot.
#[allow(clippy::too_many_arguments)]
pub fn lm_member(
    bundle: &Bundle,
    plan: &ShardPlan,
    group: usize,
    seed: u64,
    init_seed: i32,
    smoothing: SmoothingMode,
    val_batches: usize,
) -> Result<LmMember> {
    let corpus = corpus_for(bundle)?;
    let streams = plan.group_streams(group);
    let dims_batch = bundle.meta_usize("batch")?;
    let val_streams = plan.validation_streams(dims_batch);
    LmMember::new(
        bundle,
        seed,
        init_seed,
        &streams,
        &val_streams,
        &corpus,
        smoothing,
        val_batches,
    )
}

/// Standard LM experiment knobs with paper-scaled defaults.
pub struct LmExpDefaults {
    pub steps: u64,
    pub eval_every: u64,
    pub reload: u64,
    pub burn_in: u64,
    pub ramp: u64,
    pub weight: f32,
    pub lr: f32,
    pub seed: u64,
    pub val_batches: usize,
    /// Incremental (delta) teacher reloads (`--delta` / `delta=true`).
    pub delta: bool,
    /// Publisher-side error feedback for lossy codecs
    /// (`--error-feedback` / `error_feedback=true`).
    pub error_feedback: bool,
    pub verbose: bool,
}

pub fn lm_defaults(s: &Settings) -> Result<LmExpDefaults> {
    Ok(LmExpDefaults {
        steps: s.u64_or("steps", 600)?,
        eval_every: s.u64_or("eval_every", 30)?,
        reload: s.u64_or("reload", 50)?,
        burn_in: s.u64_or("burn_in", 150)?,
        ramp: s.u64_or("ramp", 50)?,
        weight: s.f32_or("weight", 1.0)?,
        lr: s.f32_or("lr", 0.03)?,
        seed: s.u64_or("seed", 42)?,
        val_batches: s.usize_or("val_batches", 4)?,
        delta: s.bool_or("delta", false)?,
        error_feedback: s.bool_or("error_feedback", false)?,
        verbose: s.bool_or("verbose", false)?,
    })
}

pub fn orch_config(d: &LmExpDefaults, distill: DistillSchedule, cluster: Option<ClusterModel>) -> OrchestratorConfig {
    OrchestratorConfig {
        total_steps: d.steps,
        reload_interval: d.reload,
        extra_staleness: 0,
        eval_every: d.eval_every,
        distill,
        lr: LrSchedule::Constant(d.lr),
        topology: Topology::Pair,
        cluster,
        seed: d.seed,
        delta: d.delta,
        // callers override with the transport setup's codec once
        // make_transport has resolved `--compress` / `codec=`
        publish_codec: Codec::Raw,
        error_feedback: d.error_feedback,
        verbose: d.verbose,
    }
}

/// One-line rendering of a run's publisher-side quantization accounting.
pub fn feedback_stats_line(tag: &str, stats: &crate::codistill::FeedbackStats) {
    println!(
        "[{tag}] lossy publish: publishes={} quantized={} raw={} bytes={}/{} (ratio {:.3}) \
         residual_l2={:.3e} max_bias={:.3e}",
        stats.publishes,
        stats.windows_quantized,
        stats.windows_raw,
        stats.bytes_quantized,
        stats.bytes_raw_equiv,
        stats.compression_ratio(),
        stats.last_residual_l2,
        stats.max_abs_bias
    );
}

/// One-line rendering of a run's delta-exchange accounting.
pub fn delta_stats_line(tag: &str, stats: &crate::codistill::DeltaStats) {
    println!(
        "[{tag}] delta exchange: full={} delta={} moved={} unchanged={} encoded={} payload_bytes={}",
        stats.full_fetches,
        stats.delta_fetches,
        stats.windows_moved,
        stats.windows_unchanged,
        stats.windows_encoded,
        stats.payload_bytes
    );
}

/// A constructed exchange transport plus whatever must stay alive while
/// it is in use (the in-process socket server, when one was spawned).
pub struct TransportSetup {
    pub transport: Arc<dyn ExchangeTransport>,
    /// Keep-alive handle: dropping it shuts the server down.
    pub server: Option<SocketServer>,
    pub kind: TransportKind,
    /// Window codec in effect (`--compress` / `codec=`); [`Codec::Raw`]
    /// when compression is off.
    pub codec: Codec,
}

/// Build the checkpoint-exchange transport selected by `--transport`
/// (default `inproc`):
///
/// * `spool` — a [`SpoolDir`] on `spool_dir` (default
///   `<results>/spool`); point a second process at the same directory to
///   exchange with it.
/// * `socket` — connect to `socket_addr` (`host:port` or `unix:/path`);
///   when unset, serve the exchange in-process on a loopback port
///   (`socket_pool=N` bounds its concurrent connections, default
///   [`MAX_CONNECTIONS`](crate::codistill::transport::socket::MAX_CONNECTIONS)).
///   `socket_windows=N` (default 0 = full-plane) shards teacher reloads
///   to N windows per fetch.
///
/// `--compress` (`compress=true`; `codec=raw|shuffle|fp16|int8`,
/// default `shuffle`) turns on compressed window payloads: spool
/// publications
/// become `CKPT0004` files with per-window encoded ranges, socket reads
/// negotiate encoded `DELTA`/`FETCH` frames via the capability byte.
/// In-process exchange moves no bytes over a medium, so the flag is a
/// no-op there.
pub fn make_transport(s: &Settings, history: usize) -> Result<TransportSetup> {
    let kind = TransportKind::parse(s.str_or("transport", "inproc"))?;
    let codec = if s.bool_or("compress", false)? {
        Codec::parse(s.str_or("codec", "shuffle"))?
    } else {
        Codec::Raw
    };
    match kind {
        TransportKind::InProcess => Ok(TransportSetup {
            transport: Arc::new(InProcess::new(history)),
            server: None,
            kind,
            codec,
        }),
        TransportKind::SpoolDir => {
            let default_dir = results_dir(s).join("spool");
            let dir = match s.get("spool_dir") {
                Some(d) => PathBuf::from(d),
                None => default_dir,
            };
            Ok(TransportSetup {
                transport: Arc::new(SpoolDir::open(&dir, history)?.with_codec(codec)),
                server: None,
                kind,
                codec,
            })
        }
        TransportKind::Socket => {
            let (server, addr) = match s.get("socket_addr") {
                Some(addr) => (None, addr.to_string()),
                None => {
                    // `socket_pool=N` bounds the in-process server's
                    // concurrent connections (default MAX_CONNECTIONS) —
                    // size it to the reader fleet (e.g. a serving
                    // loadgen) so clients don't starve against the hub.
                    let pool = s.usize_or("socket_pool", 0)?;
                    let srv = if pool > 0 {
                        SocketServer::bind_tcp_with("127.0.0.1:0", history, pool)?
                    } else {
                        SocketServer::bind_tcp("127.0.0.1:0", history)?
                    };
                    let addr = srv.addr().to_string();
                    (Some(srv), addr)
                }
            };
            let mut client = SocketTransport::connect(&addr)?;
            let windows = s.usize_or("socket_windows", 0)?;
            if windows > 0 {
                client = client.with_windowed_fetch(windows);
            }
            if codec != Codec::Raw {
                client = client.with_codec(codec);
            }
            // `socket_timeout_ms=N` bounds every response read — pair
            // with `--retry` so a hung server costs one attempt, not
            // the run.
            let timeout_ms = s.u64_or("socket_timeout_ms", 0)?;
            if timeout_ms > 0 {
                client =
                    client.with_read_timeout(std::time::Duration::from_millis(timeout_ms));
            }
            Ok(TransportSetup {
                transport: Arc::new(client),
                server,
                kind,
                codec,
            })
        }
    }
}

/// Wrap `transport` in the retrying decorator when `--retry` (or any
/// `retry_*` knob) is set: `retry_attempts=N`, `retry_base_ms=MS`,
/// `retry_seed=N` (defaulting to `default_seed`). Returns the possibly
/// wrapped transport and whether the wrap happened. Apply outermost —
/// injected faults and flaky media then exercise the retry loop. Pass a
/// `recorder` to journal the retry attempts into a shared `--trace`
/// stream instead of the decorator's private one.
pub fn wrap_retry(
    s: &Settings,
    transport: Arc<dyn ExchangeTransport>,
    default_seed: u64,
    recorder: Option<&Recorder>,
) -> Result<(Arc<dyn ExchangeTransport>, bool)> {
    let want = s.bool_or("retry", false)? || s.get("retry_attempts").is_some();
    if !want {
        return Ok((transport, false));
    }
    let policy = RetryPolicy {
        max_attempts: s.u64_or("retry_attempts", 5)? as u32,
        base_delay: std::time::Duration::from_millis(s.u64_or("retry_base_ms", 1)?),
        seed: s.u64_or("retry_seed", default_seed)?,
        ..RetryPolicy::default()
    };
    let mut retry = Retry::wrap(transport, policy);
    if let Some(rec) = recorder {
        retry = retry.with_recorder(rec.clone());
    }
    Ok((Arc::new(retry), true))
}

/// Build the `--trace` recorder when `trace=FILE` is set: `None` when
/// tracing is off, a wall-clock recorder otherwise (`trace_clock=sim`
/// swaps in the seeded simulated clock, making same-seed traces
/// byte-identical — the journal-determinism tests run exactly that).
pub fn run_recorder(s: &Settings) -> Result<Option<Recorder>> {
    if s.get("trace").is_none() {
        return Ok(None);
    }
    let rec = match s.str_or("trace_clock", "wall") {
        "sim" => Recorder::sim(s.u64_or("seed", 42)?),
        _ => Recorder::wall(),
    };
    Ok(Some(rec))
}

/// Dump a recorder's journal to the `trace=FILE` path as JSONL.
pub fn write_trace(s: &Settings, rec: &Recorder) -> Result<()> {
    let Some(path) = s.get("trace") else {
        return Ok(());
    };
    let jsonl = rec.to_jsonl();
    std::fs::write(path, &jsonl).with_context(|| format!("writing trace {path}"))?;
    println!("[trace] {} events -> {path}", rec.len());
    Ok(())
}

/// Print a run's per-member final summary.
pub fn print_runlog(tag: &str, log: &RunLog) {
    for (i, curve) in log.eval.iter().enumerate() {
        if let Some(last) = curve.last() {
            let best = log.best_loss(i).unwrap_or(f64::NAN);
            println!(
                "[{tag}] member {i}: final val loss {:.4} (best {best:.4}) at step {}",
                last.loss, last.step
            );
        }
    }
}

// ------------------------------------------------------------ CLI commands

/// `codistill train`: single-member baseline.
pub fn cmd_train(s: &Settings) -> Result<()> {
    let d = lm_defaults(s)?;
    let bundle = open_bundle(s, s.str_or("bundle", "lm_b64"))?;
    let plan = ShardPlan::new(1, bundle.meta_usize("batch")?, ShardMode::Disjoint);
    let member = lm_member(&bundle, &plan, 0, d.seed, 1, SmoothingMode::None, d.val_batches)?;
    let cfg = orch_config(&d, DistillSchedule::off(), None);
    let orch = Orchestrator::new(cfg);
    let mut members: Vec<Box<dyn Member>> = vec![Box::new(member)];
    let log = orch.run(&mut members)?;
    print_runlog("train", &log);
    Ok(())
}

/// `codistill codistill`: n-way codistillation.
pub fn cmd_codistill(s: &Settings) -> Result<()> {
    let d = lm_defaults(s)?;
    let n = s.usize_or("members", 2)?;
    let bundle = open_bundle(s, s.str_or("bundle", "lm_b64"))?;
    let mode = ShardMode::parse(s.str_or("shard_mode", "disjoint"))
        .context("shard_mode must be disjoint|same")?;
    let plan = ShardPlan::new(n, bundle.meta_usize("batch")?, mode);
    let mut members: Vec<Box<dyn Member>> = Vec::new();
    for g in 0..n {
        members.push(Box::new(lm_member(
            &bundle,
            &plan,
            g,
            d.seed,
            (g + 1) as i32,
            SmoothingMode::None,
            d.val_batches,
        )?));
    }
    let topology = Topology::parse(s.str_or("topology", "pair")).context("bad topology")?;
    let mut cfg = orch_config(
        &d,
        DistillSchedule::new(d.burn_in, d.ramp, d.weight),
        None,
    );
    cfg.topology = topology;
    let setup = make_transport(s, s.usize_or("history", 8)?)?;
    cfg.publish_codec = setup.codec;
    if d.verbose {
        eprintln!(
            "[codistill] exchange transport: {}{}{}",
            setup.kind.name(),
            if setup.codec != Codec::Raw {
                format!(" (+{})", setup.codec.name())
            } else {
                String::new()
            },
            if setup.codec.is_lossy() && cfg.error_feedback {
                " (error feedback)"
            } else {
                ""
            }
        );
    }
    let recorder = run_recorder(s)?;
    let mut orch = Orchestrator::with_transport(cfg, setup.transport.clone());
    if let Some(rec) = &recorder {
        orch = orch.with_recorder(rec.clone());
    }
    let log = orch.run(&mut members)?;
    print_runlog("codistill", &log);
    if let Some(stats) = &log.delta {
        delta_stats_line("codistill", stats);
    }
    if let Some(stats) = &log.feedback {
        feedback_stats_line("codistill", stats);
    }
    if let Some(rec) = &recorder {
        write_trace(s, rec)?;
    }
    // `setup.server` (if any) stays alive until here by ownership.
    drop(setup);
    Ok(())
}

/// Comma-separated u64 list setting (`key=10,20,30`); empty when unset.
fn u64_list(s: &Settings, key: &str) -> Result<Vec<u64>> {
    match s.get(key) {
        None => Ok(Vec::new()),
        Some(v) => v
            .split(',')
            .map(|p| {
                p.trim()
                    .parse()
                    .with_context(|| format!("{key} entry {p:?} not u64"))
            })
            .collect(),
    }
}

/// Build a [`FaultPlan`] from `fault_*` settings; `None` when no fault
/// key is set (the common, fault-free case).
///
/// * `fault_seed=N` — the deterministic decision seed (default 0)
/// * `fault_delay_p`, `fault_drop_p`, `fault_error_p`, `fault_stale_p`
///   — per-operation probabilities for the four random fault classes
/// * `fault_blackout=member:from:until[,member:from:until...]` —
///   scripted blackout windows in published-step space
pub fn fault_plan(s: &Settings) -> Result<Option<FaultPlan>> {
    let keys = [
        "fault_seed",
        "fault_delay_p",
        "fault_drop_p",
        "fault_error_p",
        "fault_stale_p",
        "fault_blackout",
    ];
    if !keys.iter().any(|k| s.get(k).is_some()) {
        return Ok(None);
    }
    let mut plan = FaultPlan::new(s.u64_or("fault_seed", 0)?)
        .with_delayed_publishes(s.f64_or("fault_delay_p", 0.0)?)
        .with_dropped_fetches(s.f64_or("fault_drop_p", 0.0)?)
        .with_erroring_fetches(s.f64_or("fault_error_p", 0.0)?)
        .with_stale_reads(s.f64_or("fault_stale_p", 0.0)?);
    if let Some(spec) = s.get("fault_blackout") {
        for part in spec.split(',') {
            let mut fields = part.trim().split(':');
            let (m, from, until) = (fields.next(), fields.next(), fields.next());
            match (m, from, until, fields.next()) {
                (Some(m), Some(from), Some(until), None) => {
                    plan = plan.with_blackout(
                        m.parse().with_context(|| format!("blackout member {m:?}"))?,
                        from.parse().with_context(|| format!("blackout from {from:?}"))?,
                        until
                            .parse()
                            .with_context(|| format!("blackout until {until:?}"))?,
                    );
                }
                _ => bail!("fault_blackout entry {part:?} (want member:from:until)"),
            }
        }
    }
    Ok(Some(plan))
}

/// `codistill coordinate`: n-way codistillation through the coordinator —
/// per-member publish cadences (`publish_intervals=50,60`,
/// `publish_offsets=0,7`), mid-run joins (`join_delays=0,0,150`),
/// publish-recency liveness (`liveness_grace=N` ticks), incremental
/// teacher reloads (`--delta`), and optional deterministic fault
/// injection (see [`fault_plan`]) over any `--transport`.
///
/// `--scenario FILE` compiles a declarative churn scenario
/// (`codistill::scenario`: `spot_wave`, `zone_outage`, `flash_crowd`,
/// `diurnal`, `flaky_net`) into the fleet's join/downtime/cadence
/// schedules and the fault plan; the file's `members` count (when
/// declared) overrides `members=N`, and explicit `fault_*` settings
/// overlay the scenario's plan (probabilities combine by max, blackouts
/// concatenate). `--retry` (or `retry_attempts=N`) wraps the transport
/// in a [`Retry`] decorator — `retry_base_ms=MS` and `retry_seed=N`
/// tune the deterministic backoff — and the run summary reports the
/// absorbed/surfaced fault accounting from
/// [`RetryStats`](crate::codistill::RetryStats).
///
/// `mock=true` hosts the deterministic
/// [`DriftMember`](crate::testkit::DriftMember) fleet instead of LM
/// members (no artifact bundle or XLA backend needed) with
/// `mock_frozen=N` extra never-changing plane elements per member — the
/// OS-process harness (`examples/spool_procs.rs`, `make test-procs`)
/// runs exactly this and asserts the children exchanged deltas.
///
/// Global member ids are `member_base..member_base+members`: when several
/// coordinator processes share one exchange, give each a disjoint
/// `member_base` (and its own `seed`) — two processes publishing under
/// the same global id would collide on the exchange's per-member step
/// monotonicity.
pub fn cmd_coordinate(s: &Settings) -> Result<()> {
    let d = lm_defaults(s)?;
    let mock = s.bool_or("mock", false)?;
    let base = s.usize_or("member_base", 0)?;
    let scenario = match s.get("scenario") {
        Some(path) => Some(Scenario::from_file(std::path::Path::new(path))?),
        None => None,
    };
    let n = {
        let n = s.usize_or("members", 2)?;
        scenario.as_ref().map_or(n, |sc| sc.fleet_size(n))
    };
    let compiled = scenario.as_ref().map(|sc| sc.compile(n, base)).transpose()?;
    let topology = Topology::parse(s.str_or("topology", "full")).context("bad topology")?;
    let mut cfg = CoordinatorConfig {
        total_steps: d.steps,
        reload_interval: d.reload,
        eval_every: d.eval_every,
        distill: DistillSchedule::new(d.burn_in, d.ramp, d.weight),
        lr: LrSchedule::Constant(d.lr),
        topology,
        liveness_grace: s.u64_or("liveness_grace", 2 * d.reload + d.reload / 2)?,
        seed: d.seed,
        delta: d.delta,
        publish_codec: Codec::Raw,
        error_feedback: d.error_feedback,
        verbose: d.verbose,
    };

    let setup = make_transport(s, s.usize_or("history", 8)?)?;
    cfg.publish_codec = setup.codec;
    // Fault plan: the scenario's compiled plan, with explicit `fault_*`
    // settings overlaid (probabilities combine by max, blackouts
    // concatenate, an explicit `fault_seed` wins).
    let plan = {
        let explicit = fault_plan(s)?;
        let from_scenario = compiled
            .as_ref()
            .filter(|c| c.has_faults())
            .map(|c| c.plan.clone());
        match (from_scenario, explicit) {
            (None, explicit) => explicit,
            (Some(sp), None) => Some(sp),
            (Some(mut sp), Some(ep)) => {
                if s.get("fault_seed").is_some() {
                    sp.seed = ep.seed;
                }
                sp.delay_publish_p = sp.delay_publish_p.max(ep.delay_publish_p);
                sp.drop_fetch_p = sp.drop_fetch_p.max(ep.drop_fetch_p);
                sp.error_fetch_p = sp.error_fetch_p.max(ep.error_fetch_p);
                sp.stale_read_p = sp.stale_read_p.max(ep.stale_read_p);
                sp.blackouts.extend(ep.blackouts);
                Some(sp)
            }
        }
    };
    let recorder = run_recorder(s)?;
    let (transport, faulty): (Arc<dyn ExchangeTransport>, Option<Arc<Faulty>>) = match plan {
        Some(fp) => {
            let mut f = Faulty::wrap(setup.transport.clone(), fp);
            if let Some(rec) = &recorder {
                f = f.with_recorder(rec.clone());
            }
            let f = Arc::new(f);
            (f.clone() as Arc<dyn ExchangeTransport>, Some(f))
        }
        None => (setup.transport.clone(), None),
    };
    // `--retry` (or any retry_* knob) wraps the stack in the retrying
    // decorator — outermost, so injected faults exercise the retry loop.
    let (transport, want_retry) = wrap_retry(s, transport, d.seed, recorder.as_ref())?;
    if d.verbose {
        eprintln!(
            "[coordinate] transport: {}{}{}{}{}",
            setup.kind.name(),
            if d.delta { " (+delta)" } else { "" },
            if setup.codec.is_lossy() {
                if d.error_feedback {
                    " (+lossy+feedback)"
                } else {
                    " (+lossy)"
                }
            } else if setup.codec != Codec::Raw {
                " (+compress)"
            } else {
                ""
            },
            if faulty.is_some() { " (+faults)" } else { "" },
            if want_retry { " (+retry)" } else { "" }
        );
        if let Some(sc) = &scenario {
            // Analytic price of each scenario event before the run.
            let m = ClusterModel {
                reload_interval: d.reload,
                ..ClusterModel::gpu_cluster(n.max(1), 40_000_000)
            };
            for (name, cost) in sc.price(&m, n, d.steps) {
                eprintln!("[coordinate] scenario {name}: ~{cost:.2}s modeled extra cost");
            }
        }
    }

    let intervals = u64_list(s, "publish_intervals")?;
    let offsets = u64_list(s, "publish_offsets")?;
    let delays = u64_list(s, "join_delays")?;
    let mut members: Vec<Box<dyn Member>> = Vec::with_capacity(n);
    if mock {
        let frozen = s.usize_or("mock_frozen", 256)?;
        // `mock_value=X` pins every frozen table to X — the lossy
        // quality gate uses a value off the int8 grid (e.g. 0.1) so the
        // quantization bias is observable.
        let value = s.get("mock_value").map(|v| v.parse::<f32>()).transpose()?;
        for g in 0..n {
            members.push(Box::new(match value {
                Some(v) => crate::testkit::DriftMember::with_frozen_value(base + g, frozen, v),
                None => crate::testkit::DriftMember::with_frozen(base + g, frozen),
            }));
        }
    } else {
        let bundle = open_bundle(s, s.str_or("bundle", "lm_b64"))?;
        let mode = ShardMode::parse(s.str_or("shard_mode", "disjoint"))
            .context("shard_mode must be disjoint|same")?;
        let plan = ShardPlan::new(n, bundle.meta_usize("batch")?, mode);
        for g in 0..n {
            members.push(Box::new(lm_member(
                &bundle,
                &plan,
                g,
                d.seed,
                (base + g + 1) as i32,
                SmoothingMode::None,
                d.val_batches,
            )?));
        }
    }
    let mut hosted = Vec::with_capacity(n);
    for (g, member) in members.into_iter().enumerate() {
        let mut h = HostedMember::new(
            base + g,
            member,
            intervals.get(g).copied().unwrap_or(d.reload),
        );
        h.publish_offset = offsets.get(g).copied().unwrap_or(0);
        h.join_delay = delays.get(g).copied().unwrap_or(0);
        hosted.push(h);
    }
    // Scenario schedules (downtimes, joins, cadences) overlay the
    // per-member flags.
    if let Some(c) = &compiled {
        c.apply(&mut hosted);
    }

    let mut coord = Coordinator::new(cfg, transport);
    if let Some(rec) = &recorder {
        coord = coord.with_recorder(rec.clone());
    }
    let log = coord.run(&mut hosted)?;
    for (i, curve) in log.eval.iter().enumerate() {
        if let Some(last) = curve.last() {
            println!(
                "[coordinate] member {}: final val loss {:.4} at local step {}",
                log.ids[i], last.loss, last.step
            );
        }
    }
    println!(
        "[coordinate] staleness samples: {}, joins: {}, skipped teachers: {}, tolerated exchange errors: {}",
        log.staleness.len(),
        log.joins.len(),
        log.skipped_teachers.len(),
        log.exchange_errors.len()
    );
    if let Some(stats) = &log.delta {
        delta_stats_line("coordinate", stats);
    }
    if let Some(stats) = &log.feedback {
        feedback_stats_line("coordinate", stats);
    }
    if let Some(f) = &faulty {
        println!("[coordinate] injected faults: {}", f.fault_log().len());
    }
    if let Some(r) = &log.retry {
        println!(
            "[coordinate] retry: ops={} attempts={} transient={} absorbed={} exhausted={} \
             permanent={} absorption={:.3}",
            r.ops,
            r.attempts,
            r.transient_errors,
            r.absorbed,
            r.exhausted + r.exhausted_empty,
            r.permanent_errors,
            r.absorption_rate()
        );
    }
    if let Some(rec) = &recorder {
        write_trace(s, rec)?;
    }
    drop(setup);
    Ok(())
}

/// `codistill inspect`: list a bundle's executables and I/O.
pub fn cmd_inspect(s: &Settings) -> Result<()> {
    let name = s.str_or("bundle", "lm_b64");
    let dir = artifacts_dir(s).join(name);
    println!("bundle {} ({})", name, dir.display());
    let mut entries: Vec<_> = std::fs::read_dir(&dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".spec.txt"))
        .collect();
    entries.sort();
    let rt = runtime()?;
    for e in entries {
        let stem = e.trim_end_matches(".spec.txt").to_string();
        let exe = rt.load(&dir.join(&stem))?;
        let spec = exe.spec();
        let in_elems: usize = spec.inputs.iter().map(|t| t.numel()).sum();
        let out_elems: usize = spec.outputs.iter().map(|t| t.numel()).sum();
        println!(
            "  {stem}: {} inputs ({} elems), {} outputs ({} elems)",
            spec.inputs.len(),
            in_elems,
            spec.outputs.len(),
            out_elems
        );
    }
    Ok(())
}

/// Scale factor mapping our testbed worker counts to the paper's
/// (paper trains with 32-256 GPUs; we simulate 4-32 workers, 1:8).
pub const WORKER_SCALE: usize = 8;

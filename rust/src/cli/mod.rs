//! Hand-rolled CLI (no clap offline).
//!
//! ```text
//! codistill <command> [--transport inproc|spool|socket] [--delta]
//!           [--compress] [--set key=value]... [--config file]
//!
//! commands:
//!   train       single-member LM baseline training
//!   codistill   n-way codistillation on the LM (lockstep orchestrator)
//!   coordinate  n-way codistillation through the multi-process
//!               coordinator: global ids member_base..member_base+members
//!               (disjoint member_base per process sharing an exchange),
//!               per-member publish cadences (publish_intervals=50,60 /
//!               publish_offsets=0,7), mid-run joins
//!               (join_delays=0,0,150), publish-recency liveness
//!               (liveness_grace=N), and deterministic fault injection
//!               (fault_seed=N, fault_delay_p/fault_drop_p/
//!               fault_error_p/fault_stale_p=P,
//!               fault_blackout=member:from:until[,...]), declarative
//!               churn scenarios (--scenario FILE, see
//!               `codistill::scenario`), and a retrying transport
//!               (--retry, retry_attempts=N, retry_base_ms=MS,
//!               retry_seed=N, socket_timeout_ms=MS)
//!   serve       batching inference tier over the latest published
//!               checkpoint: a subscription follows the exchange
//!               (--transport/--delta/--compress/--retry all apply) and
//!               hot-swaps fresh planes mid-traffic while a seeded load
//!               generator drives requests (requests=N, rps=R,
//!               clients=N for closed-loop, batch=N, batch_delay_ms=MS,
//!               workers=N, publishes=N, publish_steps=N, poll_ms=MS);
//!               reports p50/p99/p999 latency, throughput vs batch
//!               size, and prediction churn across swaps
//!   relay       checkpoint fan-out node: subscribe to an upstream hub
//!               (upstream=HOST:PORT, delta-aware, digest-verified) and
//!               serve downstream DELTA/FETCH/STEPS readers from the
//!               mirrored planes (listen=ADDR, poll_ms=MS, history=N,
//!               duration_s=N); with no upstream, builds a demo fan-out
//!               tree (tree_depth=N, tree_fanout=N, readers=N) over an
//!               in-process hub and verifies leaf readers install
//!               byte-identical planes
//!   figures     run every experiment (fig1a/1b, fig2a/2b, fig3, fig4,
//!               table1, sec341) and write results/*.csv
//!   fig1|fig2|fig3|fig4|table1|sec341   run one experiment
//!   inspect     print an artifact bundle's executables and specs
//! ```
//!
//! `--transport` picks the checkpoint-exchange backend for `codistill`
//! and `coordinate` (see `codistill::transport`): `spool` exchanges
//! through `spool_dir=PATH` (shared with other processes), `socket`
//! connects to `socket_addr=HOST:PORT|unix:PATH` (or serves one
//! in-process when unset); `socket_windows=N` shards teacher reloads N
//! windows per fetch. Point several `coordinate` processes at one spool
//! directory or socket server for a true multi-process run.
//!
//! `--delta` (alias `delta=true`) turns on incremental teacher reloads
//! for `codistill` and `coordinate`: readers keep per-teacher installed
//! planes and fetch only the windows whose content digests changed
//! (`codistill::transport::DeltaCache`) — byte-identical installs,
//! strictly less traffic. `--compress` (alias `compress=true`;
//! `codec=raw|shuffle|fp16|int8` picks the codec, default `shuffle`)
//! additionally moves each window's bytes encoded: spool publications
//! become `CKPT0004` files (`CKPT0005` for the lossy `fp16`/`int8`
//! codecs) and socket reads negotiate encoded `DELTA`/`FETCH` frames —
//! installs stay byte-identical to what was published (decoded +
//! digest-verified), a no-op on the in-process transport where no bytes
//! cross a medium. With a lossy codec the published plane itself is the
//! dequantized round-trip, prepared once publisher-side
//! (`codistill::transport::ErrorFeedback`); `--error-feedback` (alias
//! `error_feedback=true`) carries each window's quantization residual
//! into the next publish so the bias telescopes instead of accumulating.
//! `mock=true` on `coordinate` swaps the LM
//! members for the deterministic `testkit::DriftMember` fleet (no
//! artifacts/XLA needed — the OS-process harness `examples/spool_procs.rs`
//! uses this).
//!
//! `--trace FILE` (alias `trace=FILE`) on `codistill` / `coordinate` /
//! `serve` / `relay` records the run into a `codistill::obs` event
//! journal and dumps it as JSONL on exit: publishes, fetches, delta
//! installs, retries, fault decisions, quantizations, hot swaps, and
//! staleness samples, each with a monotonic timestamp. `trace_clock=sim`
//! swaps the wall clock for a seeded simulated clock (`seed=N`), making
//! same-seed traces byte-identical; `netsim::calibrate` fits a
//! `ClusterModel` from a wall-clock trace.

use crate::config::Settings;
use anyhow::{bail, Context, Result};

pub struct Cli {
    pub command: String,
    pub settings: Settings,
}

/// Parse argv into a command + settings.
pub fn parse_args(args: &[String]) -> Result<Cli> {
    if args.is_empty() {
        bail!(usage());
    }
    let command = args[0].clone();
    let mut settings = Settings::new();
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--set" => {
                let kv = args.get(i + 1).context("--set needs key=value")?;
                settings.apply(kv)?;
                i += 2;
            }
            "--config" => {
                let path = args.get(i + 1).context("--config needs a path")?;
                let file = Settings::from_file(std::path::Path::new(path))?;
                // file settings first, CLI --set later still wins because
                // apply overwrites; merge by re-applying file then existing
                let mut merged = file;
                for kv in settings_dump(&settings) {
                    merged.apply(&kv)?;
                }
                settings = merged;
                i += 2;
            }
            "--verbose" | "-v" => {
                settings.apply("verbose=true")?;
                i += 1;
            }
            "--delta" => {
                settings.apply("delta=true")?;
                i += 1;
            }
            "--compress" => {
                settings.apply("compress=true")?;
                i += 1;
            }
            "--error-feedback" => {
                settings.apply("error_feedback=true")?;
                i += 1;
            }
            "--transport" => {
                let v = args.get(i + 1).context("--transport needs inproc|spool|socket")?;
                // validate eagerly so typos fail at parse time, not mid-run
                crate::codistill::TransportKind::parse(v)?;
                settings.apply(&format!("transport={v}"))?;
                i += 2;
            }
            "--scenario" => {
                let path = args.get(i + 1).context("--scenario needs a file path")?;
                // validate eagerly so a malformed scenario fails at parse
                // time, not after artifacts load
                crate::codistill::Scenario::from_file(std::path::Path::new(path))?;
                settings.apply(&format!("scenario={path}"))?;
                i += 2;
            }
            "--retry" => {
                settings.apply("retry=true")?;
                i += 1;
            }
            "--trace" => {
                let path = args.get(i + 1).context("--trace needs a file path")?;
                settings.apply(&format!("trace={path}"))?;
                i += 2;
            }
            other if other.starts_with("--") => bail!("unknown flag {other}\n{}", usage()),
            other => {
                // bare key=value
                settings.apply(other)?;
                i += 1;
            }
        }
    }
    Ok(Cli { command, settings })
}

fn settings_dump(_s: &Settings) -> Vec<String> {
    // Settings does not expose iteration (kept minimal); CLI --set flags
    // applied after --config already overwrite, so nothing to replay.
    Vec::new()
}

pub fn usage() -> String {
    "usage: codistill <train|codistill|coordinate|serve|relay|figures|fig1|fig2|fig3|fig4|table1|sec341|inspect> \
     [--transport inproc|spool|socket] [--delta] [--compress] [--error-feedback] \
     [--scenario FILE] [--retry] [--trace FILE] [--set key=value]... [--config FILE] [--verbose]"
        .to_string()
}

/// Binary entrypoint.
pub fn main_entry() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = match parse_args(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    dispatch(&cli)
}

pub fn dispatch(cli: &Cli) -> Result<()> {
    let s = &cli.settings;
    match cli.command.as_str() {
        "train" => crate::experiments::common::cmd_train(s),
        "codistill" => crate::experiments::common::cmd_codistill(s),
        "coordinate" => crate::experiments::common::cmd_coordinate(s),
        "serve" => crate::experiments::serve::run(s),
        "relay" => crate::experiments::relay::run(s),
        "inspect" => crate::experiments::common::cmd_inspect(s),
        "fig1" => crate::experiments::fig1::run(s).map(|_| ()),
        "fig2" => crate::experiments::fig2::run(s).map(|_| ()),
        "fig3" => crate::experiments::fig3::run(s).map(|_| ()),
        "fig4" => crate::experiments::fig4::run(s).map(|_| ()),
        "table1" => crate::experiments::table1::run(s).map(|_| ()),
        "sec341" => crate::experiments::two_phase::run(s).map(|_| ()),
        "figures" => {
            crate::experiments::fig1::run(s)?;
            crate::experiments::fig2::run(s)?;
            crate::experiments::fig3::run(s)?;
            crate::experiments::fig4::run(s)?;
            crate::experiments::table1::run(s)?;
            crate::experiments::two_phase::run(s)?;
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_and_sets() {
        let cli = parse_args(&sv(&["fig1", "--set", "steps=10", "--verbose"])).unwrap();
        assert_eq!(cli.command, "fig1");
        assert_eq!(cli.settings.usize_or("steps", 0).unwrap(), 10);
        assert!(cli.settings.bool_or("verbose", false).unwrap());
    }

    #[test]
    fn bare_kv_accepted() {
        let cli = parse_args(&sv(&["train", "steps=5"])).unwrap();
        assert_eq!(cli.settings.usize_or("steps", 0).unwrap(), 5);
    }

    #[test]
    fn rejects_empty_and_unknown_flags() {
        assert!(parse_args(&[]).is_err());
        assert!(parse_args(&sv(&["train", "--bogus"])).is_err());
    }

    #[test]
    fn transport_flag_validates_and_applies() {
        let cli = parse_args(&sv(&["codistill", "--transport", "spool"])).unwrap();
        assert_eq!(cli.settings.str_or("transport", "inproc"), "spool");
        assert!(parse_args(&sv(&["codistill", "--transport", "floppy"])).is_err());
        assert!(parse_args(&sv(&["codistill", "--transport"])).is_err());
    }

    #[test]
    fn delta_flag_applies() {
        let cli = parse_args(&sv(&["coordinate", "--delta"])).unwrap();
        assert!(cli.settings.bool_or("delta", false).unwrap());
        assert!(!parse_args(&sv(&["coordinate"]))
            .unwrap()
            .settings
            .bool_or("delta", false)
            .unwrap());
    }

    #[test]
    fn scenario_flag_validates_the_file_eagerly() {
        let dir = std::env::temp_dir().join(format!("cli_scenario_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("good.scn");
        std::fs::write(&good, "seed = 3\n[flash_crowd]\nat = 10\njoiners = 2\n").unwrap();
        let cli =
            parse_args(&sv(&["coordinate", "--scenario", good.to_str().unwrap()])).unwrap();
        assert_eq!(cli.settings.str_or("scenario", ""), good.to_str().unwrap());
        // malformed file and missing file both fail at parse time
        let bad = dir.join("bad.scn");
        std::fs::write(&bad, "[unknown_pattern]\nx = 1\n").unwrap();
        assert!(parse_args(&sv(&["coordinate", "--scenario", bad.to_str().unwrap()])).is_err());
        let missing = dir.join("missing.scn");
        assert!(
            parse_args(&sv(&["coordinate", "--scenario", missing.to_str().unwrap()])).is_err()
        );
        assert!(parse_args(&sv(&["coordinate", "--scenario"])).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retry_flag_applies() {
        let cli = parse_args(&sv(&["coordinate", "--retry"])).unwrap();
        assert!(cli.settings.bool_or("retry", false).unwrap());
    }

    #[test]
    fn trace_flag_applies() {
        let cli = parse_args(&sv(&["codistill", "--trace", "run.jsonl"])).unwrap();
        assert_eq!(cli.settings.str_or("trace", ""), "run.jsonl");
        assert!(parse_args(&sv(&["codistill", "--trace"])).is_err());
    }

    #[test]
    fn compress_flag_applies() {
        let cli = parse_args(&sv(&["coordinate", "--delta", "--compress"])).unwrap();
        assert!(cli.settings.bool_or("compress", false).unwrap());
        assert!(!parse_args(&sv(&["coordinate"]))
            .unwrap()
            .settings
            .bool_or("compress", false)
            .unwrap());
    }

    #[test]
    fn error_feedback_flag_applies() {
        let cli = parse_args(&sv(&[
            "coordinate",
            "--compress",
            "codec=int8",
            "--error-feedback",
        ]))
        .unwrap();
        assert!(cli.settings.bool_or("error_feedback", false).unwrap());
        assert_eq!(cli.settings.str_or("codec", "shuffle"), "int8");
        assert!(!parse_args(&sv(&["coordinate"]))
            .unwrap()
            .settings
            .bool_or("error_feedback", false)
            .unwrap());
    }
}

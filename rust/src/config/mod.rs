//! Experiment configuration: typed defaults + `key=value` overrides.
//!
//! No serde/toml offline, so configuration is a flat string map parsed
//! from CLI `--set key=value` flags and/or a simple `key value` file —
//! enough for every sweep in the experiment harness while staying
//! dependency-free.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Flat string-keyed settings with typed accessors.
#[derive(Debug, Clone, Default)]
pub struct Settings {
    map: HashMap<String, String>,
}

impl Settings {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse a `key value` / `key=value` lines file (# comments allowed).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let mut s = Settings::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            s.apply(line)
                .with_context(|| format!("{}:{}", path.display(), i + 1))?;
        }
        Ok(s)
    }

    /// Apply one `key=value` (or `key value`) override.
    pub fn apply(&mut self, kv: &str) -> Result<()> {
        let (k, v) = if let Some((k, v)) = kv.split_once('=') {
            (k, v)
        } else if let Some((k, v)) = kv.split_once(' ') {
            (k, v)
        } else {
            bail!("expected key=value, got {kv:?}");
        };
        self.map.insert(k.trim().to_string(), v.trim().to_string());
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key}={v} not usize")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key}={v} not u64")),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key}={v} not f32")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key}={v} not f64")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true" | "1" | "yes") => Ok(true),
            Some("false" | "0" | "no") => Ok(false),
            Some(v) => bail!("{key}={v} not a bool"),
        }
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

/// Paths shared by the harness.
#[derive(Debug, Clone)]
pub struct Paths {
    pub artifacts: std::path::PathBuf,
    pub results: std::path::PathBuf,
}

impl Paths {
    pub fn from_settings(s: &Settings) -> Self {
        Paths {
            artifacts: s.str_or("artifacts", "artifacts").into(),
            results: s.str_or("results", "results").into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_and_typed_get() {
        let mut s = Settings::new();
        s.apply("steps=500").unwrap();
        s.apply("lr=0.3").unwrap();
        s.apply("mode same").unwrap();
        assert_eq!(s.usize_or("steps", 1).unwrap(), 500);
        assert!((s.f32_or("lr", 0.0).unwrap() - 0.3).abs() < 1e-6);
        assert_eq!(s.str_or("mode", "x"), "same");
        assert_eq!(s.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bad_values_error() {
        let mut s = Settings::new();
        s.apply("steps=abc").unwrap();
        assert!(s.usize_or("steps", 1).is_err());
        assert!(s.apply("novalue").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let p = std::env::temp_dir().join(format!("codistill_cfg_{}", std::process::id()));
        std::fs::write(&p, "# comment\nsteps=12\nverbose true\n\n").unwrap();
        let s = Settings::from_file(&p).unwrap();
        assert_eq!(s.usize_or("steps", 0).unwrap(), 12);
        assert!(s.bool_or("verbose", false).unwrap());
        std::fs::remove_file(&p).ok();
    }
}

//! codistill — reproduction of "Large Scale Distributed Neural Network
//! Training Through Online Distillation" (Anil et al., ICLR 2018).
//!
//! Three-layer architecture:
//!  - Layer 1 (build time): Pallas kernels in `python/compile/kernels/`.
//!  - Layer 2 (build time): JAX models in `python/compile/model.py`, lowered
//!    once to HLO text artifacts by `python/compile/aot.py`.
//!  - Layer 3 (run time, this crate): the distributed-training coordinator —
//!    synchronous-SGD worker groups, the codistillation orchestrator that
//!    exchanges stale checkpoints between groups, the simulated cluster
//!    (network / straggler model), data substrates, and the experiment
//!    harness that regenerates every figure and table in the paper.
//!
//! Python never runs on the training path: the coordinator loads the
//! `artifacts/*.hlo.txt` executables through PJRT (the `xla` crate) and owns
//! the entire training loop.

pub mod cli;
pub mod codistill;
pub mod config;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod models;
pub mod netsim;
pub mod prng;
pub mod runtime;
pub mod sgd;
pub mod testkit;
